//! Serving-tier integration (artifact-free: synthetic specs, no PJRT).
//!
//! Covers the ISSUE acceptance criteria end to end: the plan-artifact
//! round trip must yield **bit-identical** inference outputs vs the
//! freshly compiled plan (across kernels and both spec families), the
//! registry must single-flight concurrent misses, and the server must
//! reproduce direct-executor outputs under batched concurrent load with
//! working admission control.

use std::sync::Arc;

use repro::config::ServeConfig;
use repro::mobile::engine::{Executor, KernelKind, KERNEL_KINDS};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::{compile_plan, ExecutionPlan};
use repro::mobile::synth;
use repro::serve::artifact;
use repro::serve::loadgen::{self, LoadGenConfig, LoadMode};
use repro::serve::registry::{PlanKey, PlanRegistry};
use repro::serve::server::Server;

fn pruned_plan(
    res: bool,
    threads: usize,
    seed: u64,
) -> ExecutionPlan {
    let (spec, mut params) = if res {
        synth::res_style("sv_res", 16, 6, &[6, 8], seed)
    } else {
        synth::vgg_style("sv_vgg", 16, 6, &[6, 10], seed)
    };
    synth::pattern_prune(&spec, &mut params, 0.25);
    compile_plan(ModelIR::build(&spec, &params).unwrap(), threads)
        .unwrap()
}

/// The tentpole guarantee: save -> load -> execute is bit-identical to
/// the in-memory plan, for every kernel, on both spec families.
#[test]
fn artifact_roundtrip_outputs_bit_identical() {
    for res in [false, true] {
        let plan = pruned_plan(res, 2, 11);
        let bytes = artifact::encode_plan(&plan);
        let loaded = artifact::decode_plan(&bytes).unwrap();
        loaded.validate().unwrap();
        for kind in KERNEL_KINDS {
            let mut a = Executor::new(&plan, kind);
            let mut b = Executor::new(&loaded, kind);
            for i in 0..4u64 {
                let img =
                    loadgen::request_image(plan.in_dims, 500 + i, i);
                let want = a.execute(&img);
                let got = b.execute(&img);
                assert_eq!(want.len(), got.len());
                for (j, (x, y)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "res={res} {:?} probe {i} logit {j}: {x} vs {y}",
                        kind
                    );
                }
            }
        }
        // helper wrapper agrees
        artifact::verify_roundtrip(&plan, &loaded, 3, 99).unwrap();
    }
}

#[test]
fn artifact_file_roundtrip_and_strictness() {
    let plan = pruned_plan(false, 1, 13);
    let dir = std::env::temp_dir().join(format!(
        "repro_serve_it_{}",
        std::process::id()
    ));
    let path = dir.join("vgg.rpln");
    artifact::save(&plan, &path).unwrap();
    let loaded = artifact::load(&path).unwrap();
    artifact::verify_roundtrip(&plan, &loaded, 2, 3).unwrap();
    // strictness: flip one byte anywhere -> load must fail loudly
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 3;
    bytes[mid] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let err = artifact::load(&path).unwrap_err();
    assert!(
        format!("{err:#}").contains("checksum"),
        "expected checksum failure, got: {err:#}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Loaded plans slot into the registry + server exactly like compiled
/// ones: the compile cost is paid once, then every fetch is a hit.
#[test]
fn registry_serves_artifact_loaded_plans() {
    let dir = std::env::temp_dir().join(format!(
        "repro_serve_reg_{}",
        std::process::id()
    ));
    let path = dir.join("plan.rpln");
    let fresh = pruned_plan(false, 1, 17);
    artifact::save(&fresh, &path).unwrap();

    let registry = PlanRegistry::new(2);
    let key = PlanKey::new("sv_vgg", "pattern", 4.0, 1);
    let plan = registry
        .get_or_build(&key, || artifact::load(&path))
        .unwrap();
    // second fetch: hit, same Arc, no load
    let again = registry
        .get_or_build(&key, || panic!("must not rebuild on a hit"))
        .unwrap();
    assert!(Arc::ptr_eq(&plan, &again));
    let s = registry.stats();
    assert_eq!((s.hits, s.misses), (1, 1));

    // the loaded plan serves traffic with outputs matching the fresh one
    let server = Server::builder(plan)
        .config(&ServeConfig {
            workers: 2,
            max_batch: 4,
            max_wait_us: 200,
            queue_cap: 64,
            batch_threads: 1,
        })
        .kernel(KernelKind::PatternScalar)
        .spawn()
        .unwrap();
    let load = loadgen::run(
        &server.handle(),
        fresh.in_dims,
        &LoadGenConfig {
            mode: LoadMode::Closed { clients: 4 },
            requests: 24,
            seed: 77,
        },
    );
    let report = server.shutdown();
    assert_eq!(load.completed, 24);
    assert_eq!(report.errors, 0);
    let mut direct = Executor::new(&fresh, KernelKind::PatternScalar);
    for o in &load.outcomes {
        let img = loadgen::request_image(fresh.in_dims, 77, o.trace_id);
        assert_eq!(
            o.logits.as_deref().unwrap(),
            direct.execute(&img).as_slice(),
            "trace {}",
            o.trace_id
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Open-loop mode at an intentionally silly QPS against a tiny queue:
/// admission control must reject explicitly rather than buffer without
/// bound, and every outcome must be accounted for.
#[test]
fn open_loop_backpressure_is_explicit() {
    let plan = Arc::new(pruned_plan(false, 1, 19));
    let server = Server::builder(plan.clone())
        .config(&ServeConfig {
            workers: 1,
            max_batch: 2,
            max_wait_us: 0,
            queue_cap: 2,
            batch_threads: 1,
        })
        .kernel(KernelKind::PatternScalar)
        .spawn()
        .unwrap();
    let handle = server.handle();
    let load = loadgen::run(
        &handle,
        plan.in_dims,
        &LoadGenConfig {
            mode: LoadMode::Open { qps: 1e6 },
            requests: 64,
            seed: 5,
        },
    );
    let report = server.shutdown();
    assert_eq!(load.outcomes.len(), 64, "every request has an outcome");
    assert_eq!(load.completed + load.rejected, 64);
    assert_eq!(report.completed, load.completed);
    assert_eq!(report.rejected, load.rejected);
    // completed requests still carry correct logits
    let mut direct = Executor::new(&plan, KernelKind::PatternScalar);
    for o in load.outcomes.iter().filter(|o| o.logits.is_some()) {
        let img = loadgen::request_image(plan.in_dims, 5, o.trace_id);
        assert_eq!(
            o.logits.as_deref().unwrap(),
            direct.execute(&img).as_slice()
        );
    }
}
