//! Mobile plan/executor integration (artifact-free: runs on synthetic
//! specs, no PJRT needed). The planned sparse executor must reproduce the
//! dense reference executor across models, kernels, and thread counts —
//! proving the compiler passes and the plan lowering are
//! semantics-preserving — and the plan report must show the pass gains.
//! PJRT parity lives in tests/pjrt_parity.rs (`--features pjrt`).

use repro::mobile::engine::{
    execute_batch_parallel, infer, compile, EngineKind, Executor, Fmap,
    KernelKind, KERNEL_KINDS,
};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::{compile_plan, PassManager};
use repro::mobile::synth;
use repro::rng::Pcg32;
use repro::util::propcheck::check;

fn rand_image(c: usize, hw: usize, seed: u64) -> Fmap {
    let mut rng = Pcg32::seeded(seed);
    Fmap {
        c,
        hw,
        data: (0..c * hw * hw).map(|_| rng.uniform()).collect(),
    }
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() < tol * y.abs().max(1.0),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn sparse_executors_match_dense_on_vgg_model() {
    let (spec, mut params) = synth::vgg_style("vgg", 16, 6, &[6, 10], 21);
    synth::pattern_prune(&spec, &mut params, 0.25);
    let ir = ModelIR::build(&spec, &params).unwrap();
    let plan = compile_plan(ir, 1).unwrap();
    let img = rand_image(3, 16, 7);
    let dense = Executor::new(&plan, KernelKind::DenseRef).execute(&img);
    for kind in [KernelKind::PatternScalar, KernelKind::PatternTiled] {
        let got = Executor::new(&plan, kind).execute(&img);
        assert_close(&got, &dense, 1e-4, kind.name());
    }
}

#[test]
fn sparse_executor_matches_dense_on_residual_model() {
    // exercises Save/Proj/Add/Relu slot machinery incl. stride-2 convs
    let (spec, mut params) = synth::res_style("res", 16, 5, &[6, 10], 33);
    synth::pattern_prune(&spec, &mut params, 0.3);
    let ir = ModelIR::build(&spec, &params).unwrap();
    let plan = compile_plan(ir, 2).unwrap();
    for seed in 0..3u64 {
        let img = rand_image(3, 16, 40 + seed);
        let dense =
            Executor::new(&plan, KernelKind::DenseRef).execute(&img);
        let sparse =
            Executor::new(&plan, KernelKind::PatternScalar).execute(&img);
        assert_close(&sparse, &dense, 1e-4, "residual sparse");
    }
}

/// Property (ISSUE satellite): planned sparse executor output matches the
/// dense reference to 1e-4 across randomized pattern masks (via random
/// pruning ratios incl. heavy connectivity pruning), model shapes, and
/// thread counts. Strides {1,2} and kernel sizes {1,3} are covered by the
/// residual spec (3x3 stride-2 main path + 1x1 stride-2 projection).
#[test]
fn prop_planned_sparse_matches_dense_reference() {
    check("plan-sparse-vs-dense", 4242, 12, 8, |g| {
        let w0 = 4 + g.dim_up_to(4);
        let w1 = 4 + g.dim_up_to(6);
        let residual = g.rng.below(2) == 0;
        let seed = g.rng.next_u64();
        let (spec, mut params) = if residual {
            synth::res_style("p", 8, 4, &[w0, w1], seed)
        } else {
            synth::vgg_style("p", 8, 4, &[w0, w1], seed)
        };
        // alpha down to 1/16: many kernels fully connectivity-pruned
        let alpha = g.alpha();
        synth::pattern_prune(&spec, &mut params, alpha);
        let ir = ModelIR::build(&spec, &params).unwrap();
        let threads = 1 + g.rng.below(4);
        let plan = compile_plan(ir, threads).unwrap();
        let img = rand_image(3, 8, seed ^ 0xF00D);
        let dense =
            Executor::new(&plan, KernelKind::DenseRef).execute(&img);
        for kind in [KernelKind::PatternScalar, KernelKind::PatternTiled] {
            let got = Executor::new(&plan, kind).execute(&img);
            for (i, (x, y)) in got.iter().zip(&dense).enumerate() {
                if (x - y).abs() > 1e-4 * y.abs().max(1.0) {
                    return Err(format!(
                        "{} diverges at logit {i}: {x} vs {y} \
                         (residual={residual} alpha={alpha:.3} \
                         threads={threads})",
                        kind.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn thread_count_does_not_change_results() {
    // per-filter planes are computed identically regardless of the block
    // partition, so outputs are bitwise equal across thread counts
    let (spec, mut params) = synth::vgg_style("t", 16, 8, &[8, 12], 55);
    synth::pattern_prune(&spec, &mut params, 0.25);
    let img = rand_image(3, 16, 3);
    let base = {
        let ir = ModelIR::build(&spec, &params).unwrap();
        let plan = compile_plan(ir, 1).unwrap();
        Executor::new(&plan, KernelKind::PatternScalar).execute(&img)
    };
    for threads in [2usize, 4, 8] {
        let ir = ModelIR::build(&spec, &params).unwrap();
        let plan = compile_plan(ir, threads).unwrap();
        let got =
            Executor::new(&plan, KernelKind::PatternScalar).execute(&img);
        assert_eq!(got, base, "threads={threads}");
    }
}

#[test]
fn executor_is_deterministic_across_calls() {
    let (spec, mut params) = synth::res_style("d", 8, 4, &[4, 6], 77);
    synth::pattern_prune(&spec, &mut params, 0.3);
    let plan =
        compile_plan(ModelIR::build(&spec, &params).unwrap(), 2).unwrap();
    let mut ex = Executor::new(&plan, KernelKind::PatternScalar);
    let img = rand_image(3, 8, 9);
    let a = ex.execute(&img);
    let b = ex.execute(&img);
    assert_eq!(a, b, "arena reuse must not leak state between frames");
    assert_eq!(ex.alloc_events(), 0);
}

#[test]
fn batch_entry_points_match_single_frame_path() {
    let (spec, mut params) = synth::vgg_style("b", 16, 6, &[6, 8], 91);
    synth::pattern_prune(&spec, &mut params, 0.25);
    let plan =
        compile_plan(ModelIR::build(&spec, &params).unwrap(), 1).unwrap();
    let imgs: Vec<Fmap> =
        (0..7).map(|i| rand_image(3, 16, 200 + i)).collect();
    let mut ex = Executor::new(&plan, KernelKind::PatternScalar);
    let single: Vec<Vec<f32>> =
        imgs.iter().map(|i| ex.execute(i)).collect();
    let batch = ex.execute_batch(&imgs).unwrap();
    assert_eq!(batch, single);
    for workers in [1usize, 2, 3, 8] {
        let par = execute_batch_parallel(
            &plan,
            KernelKind::PatternScalar,
            &imgs,
            workers,
        )
        .unwrap();
        assert_eq!(par, single, "workers={workers}");
    }
}

#[test]
fn batch_entry_points_err_on_empty_batch() {
    let (spec, params) = synth::vgg_style("be", 8, 4, &[4], 77);
    let plan =
        compile_plan(ModelIR::build(&spec, &params).unwrap(), 1).unwrap();
    let mut ex = Executor::new(&plan, KernelKind::PatternScalar);
    let err = ex.execute_batch(&[]).unwrap_err().to_string();
    assert!(err.contains("empty batch"), "{err}");
    let err =
        execute_batch_parallel(&plan, KernelKind::PatternScalar, &[], 4)
            .unwrap_err()
            .to_string();
    assert!(err.contains("empty batch"), "{err}");
}

#[test]
fn batch_entry_points_err_on_mismatched_images() {
    let (spec, params) = synth::vgg_style("bm", 8, 4, &[4], 78);
    let plan =
        compile_plan(ModelIR::build(&spec, &params).unwrap(), 1).unwrap();
    // image 1 of the batch has the wrong spatial dims
    let imgs = vec![rand_image(3, 8, 1), rand_image(3, 4, 2)];
    let mut ex = Executor::new(&plan, KernelKind::PatternScalar);
    let err = ex.execute_batch(&imgs).unwrap_err();
    assert!(format!("{err:#}").contains("batch image 1"), "{err:#}");
    let err = execute_batch_parallel(
        &plan,
        KernelKind::PatternScalar,
        &imgs,
        2,
    )
    .unwrap_err()
    .to_string();
    assert!(err.contains("batch image 1"), "{err}");
    // wrong channel count is caught too
    let imgs = vec![rand_image(2, 8, 3)];
    assert!(ex.execute_batch(&imgs).is_err());
    assert!(execute_batch_parallel(
        &plan,
        KernelKind::PatternScalar,
        &imgs,
        1,
    )
    .is_err());
}

#[test]
fn compat_compile_infer_agrees_with_executor() {
    let (spec, mut params) = synth::vgg_style("c", 8, 4, &[4, 6], 13);
    synth::pattern_prune(&spec, &mut params, 0.3);
    let compiled = compile(ModelIR::build(&spec, &params).unwrap());
    let img = rand_image(3, 8, 5);
    let via_compat = infer(&compiled, &img, EngineKind::Sparse);
    let via_executor = Executor::new(&compiled.plan, KernelKind::PatternScalar)
        .execute(&img);
    assert_eq!(via_compat, via_executor);
    assert!(compiled.report().lre_gain() >= 1.0);
}

#[test]
fn compile_report_shows_pass_gains_on_pruned_model() {
    let (spec, mut params) = synth::vgg_style("g", 16, 8, &[8, 12], 6);
    synth::pattern_prune(&spec, &mut params, 0.25);
    let plan =
        compile_plan(ModelIR::build(&spec, &params).unwrap(), 4).unwrap();
    let r = &plan.report;
    assert!(r.total_sparse_macs() * 3 < r.total_dense_macs());
    assert!(
        (r.total_compressed_bytes() as f64)
            < 0.6 * r.total_dense_bytes() as f64
    );
    assert!(r.lre_gain() >= 1.0);
    assert!(r.reorder_gain() >= 1.0);
    // plan stats populated: four timed passes, nonzero footprints
    assert_eq!(plan.stats.pass_ms.len(), 4);
    assert!(plan.stats.payload_bytes > 0);
    assert!(plan.stats.arena_bytes > 0);
    assert!(plan.stats.n_blocks >= plan.layers.len());
}

#[test]
fn pass_manager_rejects_inconsistent_schedules() {
    // a spec whose conv chain mismatches (pool halves hw but the next
    // conv still expects the full size) must fail at compile, not execute
    let (spec, params) = synth::vgg_style("bad", 16, 4, &[4, 6], 8);
    let mut ir = ModelIR::build(&spec, &params).unwrap();
    ir.convs[1].in_hw = 5; // corrupt
    assert!(PassManager::new(1).compile(ir).is_err());
}

#[test]
fn sparse_execution_is_actually_faster() {
    // Real wallclock on the host CPU: the planned sparse form must beat
    // dense execution on a heavily pruned model (this is the "real
    // execution" half of Fig. 3; the cost model extrapolates to mobile).
    let (spec, mut params) =
        synth::vgg_style("f", 32, 10, &[16, 24], 17);
    synth::pattern_prune(&spec, &mut params, 1.0 / 9.0); // 16x-ish
    let plan =
        compile_plan(ModelIR::build(&spec, &params).unwrap(), 1).unwrap();
    let img = rand_image(3, 32, 1);
    let mut logits = vec![0.0f32; plan.ir.classes];
    let mut time = |kind: KernelKind| {
        let mut ex = Executor::new(&plan, kind);
        for _ in 0..3 {
            ex.execute_into(&img, &mut logits).unwrap();
        }
        let t = std::time::Instant::now();
        let reps = 20;
        for _ in 0..reps {
            ex.execute_into(&img, &mut logits).unwrap();
            std::hint::black_box(&logits);
        }
        t.elapsed().as_secs_f64() / reps as f64
    };
    let td = time(KernelKind::DenseRef);
    let ts = time(KernelKind::PatternScalar);
    assert!(
        ts < td,
        "sparse {:.3}ms should beat dense {:.3}ms",
        ts * 1e3,
        td * 1e3
    );
}

#[test]
fn multithreaded_arena_never_grows() {
    // at threads > 1 the scoped spawns allocate inside std, but the
    // executor's own arena must never grow after construction (the
    // counting-allocator hard proof at threads = 1 is tests/zero_alloc.rs)
    let (spec, mut params) = synth::vgg_style("z4", 16, 6, &[8, 12], 9);
    synth::pattern_prune(&spec, &mut params, 0.25);
    let plan =
        compile_plan(ModelIR::build(&spec, &params).unwrap(), 4).unwrap();
    let mut ex = Executor::new(&plan, KernelKind::PatternScalar);
    let img = rand_image(3, 16, 8);
    let mut logits = vec![0.0f32; plan.ir.classes];
    for _ in 0..5 {
        ex.execute_into(&img, &mut logits).unwrap();
    }
    assert_eq!(ex.alloc_events(), 0);
}

#[test]
fn executor_rejects_mismatched_inputs() {
    let (spec, params) = synth::vgg_style("e", 8, 4, &[4], 2);
    let plan =
        compile_plan(ModelIR::build(&spec, &params).unwrap(), 1).unwrap();
    let mut ex = Executor::new(&plan, KernelKind::DenseRef);
    let wrong_hw = rand_image(3, 16, 1);
    let mut out = vec![0.0f32; 4];
    assert!(ex.execute_into(&wrong_hw, &mut out).is_err());
    let good = rand_image(3, 8, 1);
    let mut short = vec![0.0f32; 3];
    assert!(ex.execute_into(&good, &mut short).is_err());
    assert!(ex.execute_into(&good, &mut out).is_ok());
    for kind in KERNEL_KINDS {
        // all registry kernels accept the same plan
        assert!(Executor::new(&plan, kind)
            .execute_into(&good, &mut out)
            .is_ok());
    }
}
