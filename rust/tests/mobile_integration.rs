//! Mobile engine vs PJRT reference: the compiled sparse executor (all
//! three compiler passes applied) must reproduce the `fwd_eval` artifact's
//! logits exactly (up to f32 accumulation order), proving the passes are
//! semantics-preserving on a real model.

use repro::mobile::engine::{self, EngineKind, Fmap};
use repro::mobile::ir::ModelIR;
use repro::pruning::{project, LayerShape, Scheme};
use repro::rng::Pcg32;
use repro::runtime::Runtime;
use repro::tensor::Tensor;
use repro::train::params::init_params;

const MODEL: &str = "lenet_sv10";

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// PJRT logits for a single image (slot 0 of a zero-padded eval batch).
fn pjrt_logits(rt: &Runtime, params: &[Tensor], img: &Fmap) -> Vec<f32> {
    let bsz = rt.manifest.batches.eval;
    let model = rt.model(MODEL).unwrap();
    let hw = model.in_hw;
    let mut x = Tensor::zeros(&[bsz, 3, hw, hw]);
    x.data_mut()[..3 * hw * hw].copy_from_slice(&img.data);
    let mut inputs: Vec<&Tensor> = params.iter().collect();
    inputs.push(&x);
    let outs = rt.exec(MODEL, "fwd_eval", &inputs).unwrap();
    outs[0].row(0).to_vec()
}

fn rand_image(hw: usize, seed: u64) -> Fmap {
    let mut rng = Pcg32::seeded(seed);
    Fmap {
        c: 3,
        hw,
        data: (0..3 * hw * hw).map(|_| rng.uniform()).collect(),
    }
}

fn pattern_prune(rt: &Runtime, params: &mut [Tensor], alpha: f64) {
    let model = rt.model(MODEL).unwrap();
    for (_, op) in model.prunable_convs() {
        let shape = LayerShape::from_conv(op);
        let wg = params[op.w]
            .clone()
            .reshape(&[shape.p, shape.q()])
            .unwrap();
        let pr = project(Scheme::Pattern, &wg, &shape, alpha).unwrap();
        let s4 = params[op.w].shape().to_vec();
        params[op.w] = pr.w.clone().reshape(&s4).unwrap();
    }
}

#[test]
fn dense_engine_matches_pjrt() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let model = rt.model(MODEL).unwrap().clone();
    let params = init_params(&model, 3);
    let compiled =
        engine::compile(ModelIR::build(&model, &params).unwrap());
    for seed in 0..3u64 {
        let img = rand_image(model.in_hw, seed);
        let want = pjrt_logits(&rt, &params, &img);
        let got = engine::infer(&compiled, &img, EngineKind::Dense);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() < 2e-4 * w.abs().max(1.0),
                "seed {seed}: {got:?} vs {want:?}"
            );
        }
    }
}

#[test]
fn sparse_engine_matches_pjrt_on_pruned_model() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let model = rt.model(MODEL).unwrap().clone();
    let mut params = init_params(&model, 4);
    pattern_prune(&rt, &mut params, 0.25);
    let compiled =
        engine::compile(ModelIR::build(&model, &params).unwrap());
    for seed in 10..13u64 {
        let img = rand_image(model.in_hw, seed);
        let want = pjrt_logits(&rt, &params, &img);
        let got = engine::infer(&compiled, &img, EngineKind::Sparse);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() < 2e-4 * w.abs().max(1.0),
                "seed {seed}: {got:?} vs {want:?}"
            );
        }
    }
}

#[test]
fn sparse_and_dense_engines_agree_on_pruned_model() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let model = rt.model(MODEL).unwrap().clone();
    let mut params = init_params(&model, 5);
    pattern_prune(&rt, &mut params, 0.2);
    let compiled =
        engine::compile(ModelIR::build(&model, &params).unwrap());
    let img = rand_image(model.in_hw, 42);
    let d = engine::infer(&compiled, &img, EngineKind::Dense);
    let s = engine::infer(&compiled, &img, EngineKind::Sparse);
    for (a, b) in d.iter().zip(&s) {
        assert!((a - b).abs() < 1e-4, "{d:?} vs {s:?}");
    }
}

#[test]
fn compile_report_shows_pass_gains_on_pruned_model() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let model = rt.model(MODEL).unwrap().clone();
    let mut params = init_params(&model, 6);
    pattern_prune(&rt, &mut params, 0.25);
    let compiled =
        engine::compile(ModelIR::build(&model, &params).unwrap());
    let r = &compiled.report;
    assert!(r.total_sparse_macs() * 3 < r.total_dense_macs());
    assert!(
        (r.total_compressed_bytes() as f64)
            < 0.6 * r.total_dense_bytes() as f64
    );
    assert!(r.lre_gain() >= 1.0);
    assert!(r.reorder_gain() >= 1.0);
}

#[test]
fn sparse_execution_is_actually_faster() {
    // Real wallclock on the host CPU: the compiled sparse form must beat
    // dense execution on a heavily pruned model (this is the "real
    // execution" half of Fig. 3; the cost model extrapolates to mobile).
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let model = rt.model(MODEL).unwrap().clone();
    let mut params = init_params(&model, 7);
    pattern_prune(&rt, &mut params, 1.0 / 9.0); // 16x-ish compression
    let compiled =
        engine::compile(ModelIR::build(&model, &params).unwrap());
    let img = rand_image(model.in_hw, 1);
    // warm up + time
    let time = |kind: EngineKind| {
        for _ in 0..3 {
            engine::infer(&compiled, &img, kind);
        }
        let t = std::time::Instant::now();
        let reps = 20;
        for _ in 0..reps {
            std::hint::black_box(engine::infer(
                &compiled,
                std::hint::black_box(&img),
                kind,
            ));
        }
        t.elapsed().as_secs_f64() / reps as f64
    };
    let td = time(EngineKind::Dense);
    let ts = time(EngineKind::Sparse);
    assert!(
        ts < td,
        "sparse {:.3}ms should beat dense {:.3}ms",
        ts * 1e3,
        td * 1e3
    );
}
