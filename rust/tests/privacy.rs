//! Privacy tier invariants (ISSUE acceptance criteria).
//!
//! The house invariant — bit-identical results at any thread count —
//! extends to the membership-inference harness: every number in a
//! [`MiaReport`] (advantages, AUCs, accuracies, compression rates, the
//! shadow pool threshold) must replay bit-for-bit at 1, 2, and 4
//! service threads, in both one-shot and progressive pruning modes.
//! Separately: the PCG split streams that carve the experiment's
//! datasets must actually be disjoint (no shadow model ever sees a
//! member sample), and pruning must not materially *increase* the
//! measured leakage over the dense baseline.

use repro::config::Preset;
use repro::data::SynthVision;
use repro::privacy::{
    run_mia, shadow_member_split, shadow_out_split, MiaConfig,
    MiaReport, MEMBER_SPLIT, NON_MEMBER_SPLIT,
};
use repro::pruning::Scheme;

/// A tiny-but-real experiment: small enough for CI, large enough that
/// the dense target actually overfits its member set.
fn tiny_cfg(threads: usize) -> MiaConfig {
    let mut cfg = MiaConfig::preset(Preset::Smoke);
    cfg.classes = 6;
    cfg.hw = 8;
    cfg.widths = vec![4, 6];
    cfg.n_members = 40;
    cfg.n_non = 40;
    cfg.n_shadows = 1;
    cfg.train.steps = 100;
    cfg.train.batch = 8;
    cfg.retrain.steps = 40;
    cfg.retrain.batch = 8;
    cfg.schemes = vec![Scheme::Irregular, Scheme::Pattern];
    cfg.rates = vec![8.0];
    cfg.threads = threads;
    cfg
}

/// Every number a [`MiaReport`] carries, as raw bits — `f64::to_bits`
/// makes "bit-identical" literal.
fn fingerprint(r: &MiaReport) -> Vec<(String, u64)> {
    let mut fp = vec![
        ("pool_adv".into(), r.shadow_pool.advantage.to_bits()),
        ("pool_auc".into(), r.shadow_pool.auc.to_bits()),
        ("pool_thr".into(), r.shadow_pool.threshold.to_bits()),
    ];
    for row in &r.rows {
        let k = &row.label;
        fp.push((format!("{k}_rate"), row.rate.to_bits()));
        fp.push((format!("{k}_comp"), row.comp_rate.to_bits()));
        fp.push((format!("{k}_tracc"), row.train_acc.to_bits()));
        fp.push((format!("{k}_teacc"), row.test_acc.to_bits()));
        fp.push((format!("{k}_adv"), row.conf.advantage.to_bits()));
        fp.push((format!("{k}_auc"), row.conf.auc.to_bits()));
        fp.push((format!("{k}_tpr10"), row.conf.tpr_at_fpr10.to_bits()));
        fp.push((format!("{k}_thr"), row.conf.threshold.to_bits()));
        fp.push((format!("{k}_sadv"), row.shadow.advantage.to_bits()));
        fp.push((format!("{k}_sthr"), row.shadow.threshold.to_bits()));
    }
    fp
}

#[test]
fn mia_report_is_bit_identical_across_thread_counts() {
    let r1 = run_mia(&tiny_cfg(1)).unwrap();
    let r2 = run_mia(&tiny_cfg(2)).unwrap();
    let r4 = run_mia(&tiny_cfg(4)).unwrap();
    assert_eq!(r1.rows.len(), 3, "dense + 2 pruned rows");
    assert_eq!(
        fingerprint(&r1),
        fingerprint(&r2),
        "1 vs 2 threads must agree bit-for-bit"
    );
    assert_eq!(
        fingerprint(&r1),
        fingerprint(&r4),
        "1 vs 4 threads must agree bit-for-bit"
    );

    // sanity on the measurements themselves
    for row in &r1.rows {
        assert!((0.0..=1.0).contains(&row.conf.advantage));
        assert!((0.0..=1.0).contains(&row.conf.auc));
        assert!((0.0..=1.0).contains(&row.train_acc));
        assert!((0.0..=1.0).contains(&row.test_acc));
    }
    for row in r1.pruned() {
        assert!(
            row.comp_rate > 1.0,
            "pruned rows must actually compress ({})",
            row.label
        );
    }
    // the dense target must sit at or near the overfit regime the
    // attack needs (members revisited ~20x each)
    let dense = r1.dense();
    assert!(
        dense.train_acc >= dense.test_acc - 0.1,
        "dense member acc {} far below probe acc {}",
        dense.train_acc,
        dense.test_acc
    );
    // pruning must not materially increase membership leakage — the
    // directional claim of the privacy tier (the table shows the full
    // margin; this bound is deliberately loose so CI tracks the
    // invariant, not a point estimate)
    assert!(
        r1.mean_pruned_advantage() <= dense.conf.advantage + 0.15,
        "pruned advantage {} way above dense {}",
        r1.mean_pruned_advantage(),
        dense.conf.advantage
    );
}

#[test]
fn progressive_mode_is_deterministic_and_ladders() {
    let mut cfg = tiny_cfg(2);
    cfg.schemes = vec![Scheme::Irregular];
    cfg.progressive_rounds = 2;
    let a = run_mia(&cfg).unwrap();
    cfg.threads = 4;
    let b = run_mia(&cfg).unwrap();
    assert_eq!(
        fingerprint(&a),
        fingerprint(&b),
        "progressive mode must stay bit-identical across threads"
    );
    assert_eq!(a.progressive_rounds, 2);
    let row = &a.pruned()[0];
    assert!(
        row.comp_rate > 1.0,
        "progressive ladder must land on a real compression rate"
    );
}

#[test]
fn shadow_splits_are_disjoint_from_member_set() {
    let cfg = tiny_cfg(1);
    let members = SynthVision::generate(
        cfg.classes,
        cfg.hw,
        cfg.n_members,
        cfg.data_seed,
        MEMBER_SPLIT,
    );
    let non = SynthVision::generate(
        cfg.classes,
        cfg.hw,
        cfg.n_non,
        cfg.data_seed,
        NON_MEMBER_SPLIT,
    );
    let sample = |d: &SynthVision, i: usize| -> Vec<u32> {
        let len = d.sample_len();
        d.images[i * len..(i + 1) * len]
            .iter()
            .map(|v| v.to_bits())
            .collect()
    };
    let member_set: std::collections::BTreeSet<Vec<u32>> =
        (0..members.n).map(|i| sample(&members, i)).collect();
    let mut checked = 0usize;
    let mut assert_disjoint = |d: &SynthVision, what: &str| {
        for i in 0..d.n {
            assert!(
                !member_set.contains(&sample(d, i)),
                "{what} sample {i} collides with the member set"
            );
            checked += 1;
        }
    };
    assert_disjoint(&non, "non-member probe");
    for k in 0..2 {
        let sm = SynthVision::generate(
            cfg.classes,
            cfg.hw,
            cfg.n_members,
            cfg.data_seed,
            shadow_member_split(k),
        );
        let so = SynthVision::generate(
            cfg.classes,
            cfg.hw,
            cfg.n_non,
            cfg.data_seed,
            shadow_out_split(k),
        );
        assert_disjoint(&sm, "shadow member");
        assert_disjoint(&so, "shadow out");
    }
    assert!(checked >= 5 * cfg.n_members.min(cfg.n_non));
    // distinct split ids must select distinct streams
    assert_ne!(MEMBER_SPLIT, NON_MEMBER_SPLIT);
    for k in 0..4 {
        assert_ne!(shadow_member_split(k), shadow_out_split(k));
        assert!(shadow_member_split(k) > NON_MEMBER_SPLIT);
    }
}
