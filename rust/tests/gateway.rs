//! Multi-tenant gateway integration (the ISSUE acceptance criteria).
//!
//! Determinism is the house invariant extended to the gateway: replaying
//! the same seeded virtual-time trace must produce bit-identical
//! per-request logits, identical per-tenant deterministic counters
//! (admission sheds included), and identical per-tenant registry
//! counters at 1, 2, and 4 workers. Separately, a tenant flooding far
//! past its admission budget must shed at submit time while its
//! neighbors complete everything with bounded tail latency.

use std::sync::Arc;

use repro::mobile::engine::{Executor, KernelKind};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::{compile_plan, ExecutionPlan};
use repro::mobile::synth;
use repro::serve::gateway::{Gateway, Priority, TenantConfig};
use repro::serve::loadgen::{self, DiurnalRamp, TenantLoad};
use repro::serve::registry::{PlanKey, ShardedRegistry};

const SEED: u64 = 0xC0FFEE;

fn tenant_plan(id: &str, seed: u64) -> ExecutionPlan {
    let (spec, mut params) = synth::vgg_style(id, 8, 4, &[4, 6], seed);
    synth::pattern_prune(&spec, &mut params, 0.25);
    compile_plan(ModelIR::build(&spec, &params).unwrap(), 1).unwrap()
}

type Counters = (u64, u64, u64, u64, u64, u64, u64, u64);

/// Everything about a gateway run that must be identical across worker
/// counts: the sorted replay outcomes (logits as bit patterns), each
/// tenant's deterministic counters, and each shard's registry counters.
struct Run {
    outcomes: Vec<(usize, u64, bool, bool, Option<Vec<u32>>)>,
    counters: Vec<Counters>,
    registry: Vec<(String, u64, u64, u64, u64, u64)>,
}

fn run_trace(workers: usize) -> Run {
    let names = ["alpha", "beta", "gamma"];
    let mut reg = ShardedRegistry::new();
    // alpha's shard holds one plan, so building a decoy key first
    // guarantees a deterministic, nonzero eviction count in the report
    reg.add_tenant("alpha", 1, u64::MAX).unwrap();
    reg.add_tenant("beta", 2, u64::MAX).unwrap();
    reg.add_tenant("gamma", 2, u64::MAX).unwrap();
    let reg = Arc::new(reg);
    let decoy = PlanKey::new("alpha_decoy", "pattern", 4.0, 1);
    reg.get_or_build("alpha", &decoy, || Ok(tenant_plan("alpha_decoy", 99)))
        .unwrap();

    let mut builder = Gateway::builder()
        .workers(workers)
        .max_batch(4)
        .max_wait_us(200)
        .registry(reg.clone());
    let qps = [120.0, 40.0, 20.0];
    let requests = [40usize, 16, 8];
    let mut loads = Vec::new();
    for (ti, name) in names.iter().enumerate() {
        let key = PlanKey::new(name, "pattern", 4.0, 1);
        let plan = reg
            .get_or_build(name, &key, || {
                Ok(tenant_plan(name, 30 + ti as u64))
            })
            .unwrap();
        let mut tc = TenantConfig::new(name).queue_cap(256);
        if ti == 0 {
            // the hot tenant runs 3x over its admission budget, so the
            // deterministic shed path is exercised in every run
            tc = tc.priority(Priority::High).admit(40.0, 4.0);
        }
        builder = builder.tenant(tc, plan, KernelKind::PatternScalar);
        loads.push(TenantLoad::new(name, qps[ti], requests[ti]));
    }
    let trace = loadgen::multi_tenant_trace(
        &loads,
        Some(DiurnalRamp::new(500_000, 0.5)),
        SEED,
    );
    let gateway = builder.spawn().unwrap();
    let load =
        loadgen::replay(&gateway.handle(), &loads, &trace, SEED, 0.0)
            .unwrap();
    let report = gateway.shutdown();
    assert_eq!(load.rejected, 0, "queues were sized to never reject");
    Run {
        outcomes: load
            .outcomes
            .iter()
            .map(|o| {
                (
                    o.tenant,
                    o.trace_id,
                    o.shed,
                    o.rejected,
                    o.logits.as_ref().map(|l| {
                        l.iter().map(|x| x.to_bits()).collect()
                    }),
                )
            })
            .collect(),
        counters: report
            .tenants
            .iter()
            .map(|t| t.report.deterministic_counters())
            .collect(),
        registry: report
            .registry
            .iter()
            .map(|(name, s)| {
                (
                    name.clone(),
                    s.lookups(),
                    s.hits,
                    s.misses,
                    s.coalesced,
                    s.evictions,
                )
            })
            .collect(),
    }
}

#[test]
fn replay_is_identical_at_1_2_and_4_workers() {
    let base = run_trace(1);
    // the trace actually exercises both paths: completions and sheds
    let shed: u64 = base.counters.iter().map(|c| c.4).sum();
    let completed: u64 = base.counters.iter().map(|c| c.1).sum();
    assert!(shed > 0, "hot tenant never shed — admission untested");
    assert!(completed > 0);
    assert!(
        base.registry.iter().any(|r| r.5 > 0),
        "decoy eviction missing from the gateway report"
    );

    // ground truth at 1 worker: completed logits match a bare executor
    // fed the same tenant-salted images
    let plans: Vec<ExecutionPlan> = ["alpha", "beta", "gamma"]
        .iter()
        .enumerate()
        .map(|(ti, name)| tenant_plan(name, 30 + ti as u64))
        .collect();
    for (ti, id, _, _, logits) in &base.outcomes {
        let Some(bits) = logits else { continue };
        let plan = &plans[*ti];
        let mut ex = Executor::new(plan, KernelKind::PatternScalar);
        let img = loadgen::tenant_request_image(
            plan.in_dims,
            SEED,
            ["alpha", "beta", "gamma"][*ti],
            *id,
        );
        let want: Vec<u32> =
            ex.execute(&img).iter().map(|x| x.to_bits()).collect();
        assert_eq!(&want, bits, "tenant {ti} trace {id}");
    }

    for workers in [2usize, 4] {
        let run = run_trace(workers);
        assert_eq!(
            run.outcomes, base.outcomes,
            "replay outcomes differ at {workers} workers"
        );
        assert_eq!(
            run.counters, base.counters,
            "per-tenant counters differ at {workers} workers"
        );
        assert_eq!(
            run.registry, base.registry,
            "registry counters differ at {workers} workers"
        );
    }
}

#[test]
fn overloaded_tenant_sheds_without_starving_neighbors() {
    let flood_plan = Arc::new(tenant_plan("flood", 51));
    let steady_plan = Arc::new(tenant_plan("steady", 52));
    let gateway = Gateway::builder()
        .workers(2)
        .max_batch(4)
        .max_wait_us(200)
        .tenant(
            // even at high priority, 50x over budget must not matter:
            // admission drops the excess before it can occupy the pool
            TenantConfig::new("flood")
                .priority(Priority::High)
                .queue_cap(512)
                .admit(20.0, 2.0),
            flood_plan,
            KernelKind::PatternScalar,
        )
        .tenant(
            TenantConfig::new("steady").priority(Priority::Low),
            steady_plan,
            KernelKind::PatternScalar,
        )
        .spawn()
        .unwrap();
    let loads = [
        TenantLoad::new("flood", 1000.0, 300),
        TenantLoad::new("steady", 50.0, 40),
    ];
    let trace = loadgen::multi_tenant_trace(&loads, None, SEED);
    let load =
        loadgen::replay(&gateway.handle(), &loads, &trace, SEED, 0.0)
            .unwrap();
    let report = gateway.shutdown();

    let flood = &report.tenant("flood").unwrap().report;
    let steady = &report.tenant("steady").unwrap().report;
    // every flood request is accounted for: shed at admission or served
    assert_eq!(flood.shed + flood.completed, 300);
    assert!(
        flood.shed >= 250,
        "50x overload shed only {} of 300",
        flood.shed
    );
    assert_eq!(flood.rejected, 0);
    // the neighbor is untouched: everything admitted and completed...
    assert_eq!(steady.shed, 0);
    assert_eq!(steady.rejected, 0);
    assert_eq!(steady.completed, 40);
    // ...with a sane tail (generous sanity bound — the pool was never
    // saturated because the flood was dropped at the door)
    assert!(
        steady.latency.p99_us < 5_000_000,
        "steady p99 {} us",
        steady.latency.p99_us
    );
    // the replay's view agrees with the per-tenant reports
    let fl = &load.per_tenant[0];
    assert_eq!((fl.issued, fl.shed), (300, flood.shed));
    let st = &load.per_tenant[1];
    assert_eq!((st.issued, st.completed), (40, 40));
}
