//! Determinism of the parallel pruning scheduler (admm::scheduler): the
//! `PruneOutcome` must be bit-identical at every thread count, the service
//! sweep must be independent of its worker count, and the host forward
//! pass must match the mobile executor's dense reference numerics. Runs
//! entirely on the host engine — no artifacts or `pjrt` feature required.

use repro::admm::scheduler::{
    fwd_logits_host, prune_layerwise_par, SchedulerCfg,
};
use repro::config::AdmmConfig;
use repro::coordinator::service::{PruneConfig, PruneService};
use repro::mobile::engine::{Executor, Fmap, KernelKind};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::PassManager;
use repro::mobile::synth::{res_style, vgg_style};
use repro::pruning::Scheme;
use repro::rng::Pcg32;

fn admm_cfg() -> AdmmConfig {
    AdmmConfig {
        rhos: vec![1e-2, 1e-1],
        iters_per_rho: 2,
        primal_steps: 2,
        lr: 1e-2,
        lr_layer: 5e-3,
        gauss_seidel: true,
        seed: 0xADA17,
        threads: 1,
    }
}

fn sched_cfg(threads: usize) -> SchedulerCfg {
    SchedulerCfg::new(admm_cfg(), 4, threads)
}

#[test]
fn prune_outcome_bit_identical_across_thread_counts() {
    let (spec, params) = vgg_style("det_vgg", 16, 6, &[6, 10], 7);
    for scheme in Scheme::all() {
        let base = prune_layerwise_par(
            &spec,
            &params,
            scheme,
            0.25,
            &sched_cfg(1),
        )
        .unwrap();
        assert!(
            base.outcome
                .trace
                .primal_loss
                .iter()
                .all(|l| l.is_finite()),
            "{scheme:?}: non-finite primal loss"
        );
        assert_eq!(base.outcome.trace.primal_loss.len(), 4);
        for threads in [2usize, 4] {
            let got = prune_layerwise_par(
                &spec,
                &params,
                scheme,
                0.25,
                &sched_cfg(threads),
            )
            .unwrap();
            assert_eq!(
                base.outcome.params, got.outcome.params,
                "{scheme:?}: params differ at {threads} threads"
            );
            assert_eq!(
                base.outcome.masks, got.outcome.masks,
                "{scheme:?}: masks differ at {threads} threads"
            );
            assert_eq!(
                base.outcome.comp_rate.to_bits(),
                got.outcome.comp_rate.to_bits(),
                "{scheme:?}: comp_rate differs at {threads} threads"
            );
            assert_eq!(
                base.outcome.trace.primal_loss,
                got.outcome.trace.primal_loss,
                "{scheme:?}: loss trace differs at {threads} threads"
            );
            let same_residual = base
                .outcome
                .trace
                .residual
                .iter()
                .zip(&got.outcome.trace.residual)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(
                same_residual,
                "{scheme:?}: residual trace differs at {threads} threads"
            );
        }
    }
}

#[test]
fn residual_spec_prunes_deterministically() {
    // res_style exercises the host forward's Save/Proj/Add/Relu ops
    let (spec, params) = res_style("det_res", 16, 6, &[6, 8], 9);
    let a =
        prune_layerwise_par(&spec, &params, Scheme::Pattern, 0.25, &sched_cfg(1))
            .unwrap();
    let b =
        prune_layerwise_par(&spec, &params, Scheme::Pattern, 0.25, &sched_cfg(4))
            .unwrap();
    assert_eq!(a.outcome.params, b.outcome.params);
    assert_eq!(a.outcome.masks, b.outcome.masks);
    // the achieved compression must actually compress
    assert!(a.outcome.comp_rate > 2.0, "comp {}", a.outcome.comp_rate);
}

#[test]
fn scheduler_prunes_to_the_target_rate() {
    let (spec, params) = vgg_style("det_rate", 16, 6, &[6, 10], 11);
    let out = prune_layerwise_par(
        &spec,
        &params,
        Scheme::Irregular,
        1.0 / 8.0,
        &sched_cfg(4),
    )
    .unwrap();
    // irregular keeps floor(PQ/8) per layer, so the achieved rate is >= 8
    assert!(
        out.outcome.comp_rate >= 8.0,
        "comp rate {} < 8.0",
        out.outcome.comp_rate
    );
    // per-layer timing plumbing: one entry per prunable conv, costs > 0
    assert_eq!(out.sched.per_layer.len(), spec.prunable.len());
    assert!(out.sched.per_layer.iter().all(|l| l.cost > 0));
    assert_eq!(out.sched.rounds, 4);
    let table = out.sched.table().render();
    assert!(table.contains("per-layer ADMM solve time"));
}

#[test]
fn service_sweep_is_independent_of_worker_count() {
    let (spec, params) = vgg_style("det_sweep", 8, 4, &[4, 6], 13);
    let admm = admm_cfg();
    let configs = [
        PruneConfig {
            scheme: Scheme::Irregular,
            rate: 8.0,
        },
        PruneConfig {
            scheme: Scheme::Column,
            rate: 4.0,
        },
        PruneConfig {
            scheme: Scheme::Filter,
            rate: 2.0,
        },
        PruneConfig {
            scheme: Scheme::Pattern,
            rate: 8.0,
        },
    ];
    let a = PruneService::new(1, 4)
        .sweep(&spec, &params, &admm, &configs)
        .unwrap();
    let b = PruneService::new(3, 4)
        .sweep(&spec, &params, &admm, &configs)
        .unwrap();
    assert_eq!(a.len(), configs.len());
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.scheme, rb.scheme);
        assert_eq!(ra.comp_rate.to_bits(), rb.comp_rate.to_bits());
        assert_eq!(ra.masks, rb.masks);
        assert_eq!(
            ra.final_residual.to_bits(),
            rb.final_residual.to_bits()
        );
    }
    let table = PruneService::new(3, 4).sweep_table("det_sweep", &a);
    assert!(table.render().contains("parallel prune sweep"));
}

/// The scheduler's host forward pass reproduces the mobile executor's
/// dense reference kernel on both spec families (paper §V-C semantics
/// preservation, designer side).
#[test]
fn host_forward_matches_dense_executor() {
    for (spec, params) in [
        vgg_style("fwd_vgg", 8, 5, &[4, 6], 3),
        res_style("fwd_res", 8, 5, &[4, 6], 5),
    ] {
        let ir = ModelIR::build(&spec, &params).unwrap();
        let plan = PassManager::new(1).compile(ir).unwrap();
        let mut ex = Executor::new(&plan, KernelKind::DenseRef);
        let mut rng = Pcg32::seeded(17);
        for trial in 0..3 {
            let img = Fmap {
                c: 3,
                hw: spec.in_hw,
                data: (0..3 * spec.in_hw * spec.in_hw)
                    .map(|_| rng.uniform())
                    .collect(),
            };
            let want = ex.execute(&img);
            let got =
                fwd_logits_host(&spec, &params, &img.data).unwrap();
            assert_eq!(want.len(), got.len());
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert!(
                    (w - g).abs() <= 1e-4 * w.abs().max(1.0),
                    "{} trial {trial} logit {i}: executor {w} vs host {g}",
                    spec.id
                );
            }
        }
    }
}
