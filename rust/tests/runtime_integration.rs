//! Integration tests over the PJRT runtime + real AOT artifacts.
//!
//! Numerics are pinned against fixtures computed by the Python L2 graphs
//! (python/tests/make_fixtures.py): parameters/inputs are generated from
//! shared closed-form sin/cos ramps on both sides, so the same computation
//! runs through (a) jax on CPU and (b) HLO-text → PJRT from Rust, and the
//! results must agree to f32 tolerance.
//!
//! Requires `make artifacts` (manifest + lenet artifacts + fixtures.json)
//! and the `pjrt` cargo feature (XLA toolchain).
#![cfg(feature = "pjrt")]

use repro::config::TrainConfig;
use repro::runtime::Runtime;
use repro::tensor::Tensor;
use repro::util::json::Json;

const MODEL: &str = "lenet_sv10";

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Runtime {
    Runtime::new(artifacts_dir()).expect("runtime (run `make artifacts`)")
}

fn fixtures() -> Json {
    let text = std::fs::read_to_string(artifacts_dir().join("fixtures.json"))
        .expect("fixtures.json (run `make artifacts`)");
    Json::parse(&text).unwrap()
}

fn formula_param(shape: &[usize], scale: f32) -> Tensor {
    let n: usize = shape.iter().product::<usize>().max(1);
    let data = (0..n).map(|i| (0.1 * i as f32).sin() * scale).collect();
    Tensor::from_vec(if shape.is_empty() { &[] } else { shape }, data).unwrap()
}

fn formula_input(shape: &[usize]) -> Tensor {
    let n: usize = shape.iter().product();
    let data = (0..n)
        .map(|i| (0.05 * i as f32).cos() * 0.5 + 0.5)
        .collect();
    Tensor::from_vec(shape, data).unwrap()
}

fn formula_params(rt: &Runtime) -> Vec<Tensor> {
    rt.model(MODEL)
        .unwrap()
        .params
        .iter()
        .map(|p| formula_param(&p.shape, 0.1))
        .collect()
}

fn assert_close(got: f32, want: f64, tol: f64, what: &str) {
    assert!(
        (got as f64 - want).abs() <= tol * want.abs().max(1.0),
        "{what}: got {got}, want {want}"
    );
}

#[test]
fn fwd_eval_matches_python_fixture() {
    let rt = runtime();
    let fix = fixtures();
    let params = formula_params(&rt);
    let bsz = rt.manifest.batches.eval;
    let hw = rt.model(MODEL).unwrap().in_hw;
    let x = formula_input(&[bsz, 3, hw, hw]);
    let mut inputs: Vec<&Tensor> = params.iter().collect();
    inputs.push(&x);
    let outs = rt.exec(MODEL, "fwd_eval", &inputs).unwrap();
    let logits = &outs[0];
    for (row_key, r) in
        [("fwd_eval_logits_row0", 0usize), ("fwd_eval_logits_row7", 7)]
    {
        let want = fix.get(row_key).unwrap().as_arr().unwrap();
        for (c, w) in want.iter().enumerate() {
            assert_close(
                logits.at2(r, c),
                w.as_f64().unwrap(),
                1e-4,
                &format!("{row_key}[{c}]"),
            );
        }
    }
}

#[test]
fn train_step_matches_python_fixture() {
    let rt = runtime();
    let fix = fixtures();
    let params = formula_params(&rt);
    let bsz = rt.manifest.batches.train;
    let model = rt.model(MODEL).unwrap();
    let x = formula_input(&[bsz, 3, model.in_hw, model.in_hw]);
    let mut y = Tensor::zeros(&[bsz, model.classes]);
    for b in 0..bsz {
        y.set2(b, b % model.classes, 1.0);
    }
    let lr = Tensor::scalar(0.05);
    let mut inputs: Vec<&Tensor> = params.iter().collect();
    inputs.push(&x);
    inputs.push(&y);
    inputs.push(&lr);
    let outs = rt.exec(MODEL, "train_step", &inputs).unwrap();
    let loss = outs.last().unwrap().data()[0];
    assert_close(
        loss,
        fix.get("train_step_loss").unwrap().as_f64().unwrap(),
        1e-4,
        "train_step loss",
    );
    let w0_sum: f32 = outs[0].data().iter().sum();
    assert_close(
        w0_sum,
        fix.get("train_step_w0_sum").unwrap().as_f64().unwrap(),
        1e-3,
        "train_step w0 sum",
    );
}

#[test]
fn layer_primal_matches_python_fixture() {
    let rt = runtime();
    let fix = fixtures();
    let params = formula_params(&rt);
    let model = rt.model(MODEL).unwrap();
    let convs = model.prunable_convs();
    let (_, op) = convs[0];
    let bsz = rt.manifest.batches.admm;
    let act_in = formula_input(&[bsz, op.c, op.in_hw, op.in_hw]);
    let target = formula_input(&[bsz, op.a, op.out_hw, op.out_hw]);
    let (p, q) = op.gemm_shape();
    let z = formula_param(&[p, q], 0.05);
    let u = formula_param(&[p, q], 0.01);
    let rho = Tensor::scalar(1e-2);
    let lr = Tensor::scalar(1e-3);
    let outs = rt
        .exec(
            MODEL,
            "layer_primal_0",
            &[
                &params[op.w],
                &params[op.b],
                &act_in,
                &target,
                &z,
                &u,
                &rho,
                &lr,
            ],
        )
        .unwrap();
    assert_close(
        outs[2].data()[0],
        fix.get("layer_primal_loss").unwrap().as_f64().unwrap(),
        1e-4,
        "layer_primal loss",
    );
    let w_sum: f32 = outs[0].data().iter().sum();
    assert_close(
        w_sum,
        fix.get("layer_primal_w_sum").unwrap().as_f64().unwrap(),
        1e-3,
        "layer_primal w sum",
    );
}

#[test]
fn exec_rejects_wrong_shapes() {
    let rt = runtime();
    let params = formula_params(&rt);
    let inputs: Vec<&Tensor> = params.iter().collect();
    // missing x input
    assert!(rt.exec(MODEL, "fwd_eval", &inputs).is_err());
}

#[test]
fn masked_train_step_keeps_pruned_weights_zero() {
    use repro::pruning::{project, LayerShape, Scheme};
    let rt = runtime();
    let model = rt.model(MODEL).unwrap();
    let mut params = formula_params(&rt);
    // project conv weights irregular @ alpha 0.25, collect masks
    let mut masks = Vec::new();
    for (_, op) in model.prunable_convs() {
        let shape = LayerShape::from_conv(op);
        let wg = params[op.w]
            .clone()
            .reshape(&[shape.p, shape.q()])
            .unwrap();
        let pr = project(Scheme::Irregular, &wg, &shape, 0.25).unwrap();
        let s4 = params[op.w].shape().to_vec();
        params[op.w] = pr.w.clone().reshape(&s4).unwrap();
        masks.push(pr.mask);
    }
    let bsz = rt.manifest.batches.train;
    let x = formula_input(&[bsz, 3, model.in_hw, model.in_hw]);
    let mut y = Tensor::zeros(&[bsz, model.classes]);
    for b in 0..bsz {
        y.set2(b, b % model.classes, 1.0);
    }
    let lr = Tensor::scalar(0.05);
    let mut inputs: Vec<&Tensor> = params.iter().collect();
    inputs.extend(masks.iter());
    inputs.push(&x);
    inputs.push(&y);
    inputs.push(&lr);
    let outs = rt.exec(MODEL, "masked_train_step", &inputs).unwrap();
    for ((_, op), mask) in
        model.prunable_convs().iter().zip(&masks)
    {
        let w = &outs[op.w];
        for (wi, mi) in w.data().iter().zip(mask.data()) {
            if *mi == 0.0 {
                assert_eq!(*wi, 0.0, "pruned weight updated");
            }
        }
    }
}

#[test]
fn end_to_end_smoke_pipeline_on_lenet() {
    use repro::admm::{prune_layerwise, DataSource};
    use repro::config::{AdmmConfig, Preset};
    use repro::data::SynthVision;
    use repro::pruning::Scheme;
    use repro::train;
    use repro::train::params::init_params;

    let rt = runtime();
    let model = rt.model(MODEL).unwrap().clone();
    let tr = SynthVision::generate(model.classes, model.in_hw, 200, 11, 0);
    let te = SynthVision::generate(model.classes, model.in_hw, 100, 11, 1);
    let mut params = init_params(&model, 1);

    let mut cfg = TrainConfig::pretrain(Preset::Smoke);
    cfg.steps = 40;
    cfg.log_every = 0;
    let trace =
        train::pretrain(&rt, MODEL, &mut params, &tr, &te, &cfg).unwrap();
    let base_acc = trace.final_acc();
    assert!(
        base_acc > 0.25,
        "lenet should beat chance after 40 steps, got {base_acc}"
    );

    let admm_cfg = AdmmConfig::preset(Preset::Smoke);
    let out = prune_layerwise(
        &rt,
        MODEL,
        &params,
        Scheme::Irregular,
        0.25,
        &admm_cfg,
        DataSource::Synthetic,
    )
    .unwrap();
    assert!(out.comp_rate > 3.9 && out.comp_rate < 4.3, "{}", out.comp_rate);

    let mut pruned = out.params.clone();
    let mut rcfg = TrainConfig::retrain(Preset::Smoke);
    rcfg.steps = 30;
    rcfg.log_every = 0;
    let rt_trace = train::retrain_masked(
        &rt, MODEL, &mut pruned, &out.masks, &tr, &te, &rcfg,
    )
    .unwrap();
    // retraining should not be catastrophically below the dense model
    assert!(
        rt_trace.final_acc() > base_acc - 0.25,
        "retrain acc {} vs base {base_acc}",
        rt_trace.final_acc()
    );
    // pruned weights stay zero through retraining
    for ((_, op), mask) in
        model.prunable_convs().iter().zip(&out.masks)
    {
        for (wi, mi) in pruned[op.w].data().iter().zip(mask.data()) {
            if *mi == 0.0 {
                assert_eq!(*wi, 0.0);
            }
        }
    }
}
