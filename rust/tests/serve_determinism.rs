//! Serving determinism (mirror of `scheduler_determinism.rs` for the
//! serving tier): the same seed + the same request trace must produce
//! identical per-request outputs and identical deterministic aggregate
//! stats at 1, 2, and 4 workers. Batching, batch windows, and worker
//! scheduling may change *when* a request runs and in which micro-batch —
//! never *what* it computes.

use std::sync::Arc;

use repro::config::ServeConfig;
use repro::mobile::engine::{Executor, KernelKind};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::{compile_plan, ExecutionPlan};
use repro::mobile::synth::{res_style, vgg_style};
use repro::serve::loadgen::{self, LoadGenConfig, LoadMode};
use repro::serve::server::Server;

const SEED: u64 = 0x5E27E;
const REQUESTS: usize = 48;

fn serve_trace(
    plan: &Arc<ExecutionPlan>,
    workers: usize,
) -> (Vec<Vec<f32>>, (u64, u64, u64, u64, u64, u64, u64, u64)) {
    let cfg = ServeConfig {
        workers,
        max_batch: 4,
        max_wait_us: 500,
        // >= in-flight requests, so closed-loop clients never hit
        // admission control and the deterministic counters stay exact
        queue_cap: 64,
        batch_threads: 1,
    };
    let server = Server::builder(plan.clone())
        .config(&cfg)
        .kernel(KernelKind::PatternScalar)
        .spawn()
        .unwrap();
    let load = loadgen::run(
        &server.handle(),
        plan.in_dims,
        &LoadGenConfig {
            mode: LoadMode::Closed { clients: 4 },
            requests: REQUESTS,
            seed: SEED,
        },
    );
    let report = server.shutdown();
    assert_eq!(load.outcomes.len(), REQUESTS);
    let outputs: Vec<Vec<f32>> = load
        .outcomes
        .into_iter()
        .map(|o| match o.logits {
            Some(logits) => logits,
            None => panic!("trace {} unresolved", o.trace_id),
        })
        .collect();
    (outputs, report.deterministic_counters())
}

#[test]
fn outputs_and_counters_identical_across_worker_counts() {
    for (name, plan) in [
        ("vgg", {
            let (spec, mut params) =
                vgg_style("det_srv_vgg", 16, 6, &[6, 10], 7);
            repro::mobile::synth::pattern_prune(&spec, &mut params, 0.25);
            Arc::new(
                compile_plan(ModelIR::build(&spec, &params).unwrap(), 1)
                    .unwrap(),
            )
        }),
        ("res", {
            let (spec, mut params) =
                res_style("det_srv_res", 16, 6, &[6, 8], 9);
            repro::mobile::synth::pattern_prune(&spec, &mut params, 0.25);
            Arc::new(
                compile_plan(ModelIR::build(&spec, &params).unwrap(), 1)
                    .unwrap(),
            )
        }),
    ] {
        // ground truth: the trace run through a bare executor
        let mut direct =
            Executor::new(&plan, KernelKind::PatternScalar);
        let want: Vec<Vec<f32>> = (0..REQUESTS as u64)
            .map(|id| {
                direct.execute(&loadgen::request_image(
                    plan.in_dims,
                    SEED,
                    id,
                ))
            })
            .collect();

        let (base_out, base_counters) = serve_trace(&plan, 1);
        assert_eq!(base_out, want, "{name}: served != direct executor");
        let (
            submitted,
            completed,
            rejected,
            errors,
            shed,
            dispatched,
            worker_lost,
            restarts,
        ) = base_counters;
        assert_eq!(submitted, REQUESTS as u64, "{name}");
        assert_eq!(completed, REQUESTS as u64, "{name}");
        assert_eq!(rejected, 0, "{name}");
        assert_eq!(errors, 0, "{name}");
        assert_eq!(shed, 0, "{name}");
        assert_eq!(dispatched, REQUESTS as u64, "{name}");
        assert_eq!(worker_lost, 0, "{name}: no chaos armed");
        assert_eq!(restarts, 0, "{name}: no chaos armed");

        for workers in [2usize, 4] {
            let (out, counters) = serve_trace(&plan, workers);
            // bit-identical logits per trace id
            for (id, (a, b)) in base_out.iter().zip(&out).enumerate() {
                assert_eq!(a.len(), b.len());
                for (j, (x, y)) in a.iter().zip(b).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "{name}: trace {id} logit {j} differs at \
                         {workers} workers"
                    );
                }
            }
            assert_eq!(
                counters, base_counters,
                "{name}: aggregate stats differ at {workers} workers"
            );
        }
    }
}

/// The request trace itself is reproducible: regenerating it yields
/// bit-identical images, so two whole runs (not just worker counts)
/// agree.
#[test]
fn whole_run_repeats_bit_identically() {
    let (spec, mut params) = vgg_style("det_srv_rep", 8, 4, &[4, 6], 3);
    repro::mobile::synth::pattern_prune(&spec, &mut params, 0.25);
    let plan = Arc::new(
        compile_plan(ModelIR::build(&spec, &params).unwrap(), 1).unwrap(),
    );
    let (a, ca) = serve_trace(&plan, 2);
    let (b, cb) = serve_trace(&plan, 2);
    assert_eq!(a, b);
    assert_eq!(ca, cb);
}
