//! Coordinator-level integration: Ctx caching (checkpoints + result rows),
//! baselines, and the Table-IV formulation machinery on the micro model at
//! smoke scale. Requires `make artifacts` and the `pjrt` cargo feature.
#![cfg(feature = "pjrt")]

use repro::config::Preset;
use repro::coordinator::{Ctx, Method};
use repro::pruning::Scheme;

fn ctx_in_tempdir() -> (Ctx, std::path::PathBuf) {
    let dir = std::env::temp_dir().join(format!(
        "repro_pipe_{}",
        std::process::id()
    ));
    let mut ctx = Ctx::new(
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        Preset::Smoke,
    )
    .expect("runtime");
    ctx.runs = dir.clone();
    ctx.verbose = false;
    (ctx, dir)
}

#[test]
fn pretrained_checkpoint_cache_roundtrip() {
    let (ctx, dir) = ctx_in_tempdir();
    let (p1, a1) = ctx.pretrained("lenet_sv10").unwrap();
    // second call must come from cache with identical params + acc
    let (p2, a2) = ctx.pretrained("lenet_sv10").unwrap();
    assert_eq!(p1, p2);
    assert_eq!(a1, a2);
    assert!(dir.join("ckpt").exists());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn row_results_are_cached_and_stable() {
    let (ctx, dir) = ctx_in_tempdir();
    let r1 = ctx
        .prune_retrain("lenet_sv10", Method::Uniform, Scheme::Irregular, 4.0)
        .unwrap();
    let t = std::time::Instant::now();
    let r2 = ctx
        .prune_retrain("lenet_sv10", Method::Uniform, Scheme::Irregular, 4.0)
        .unwrap();
    // cache hit: instant and bit-identical
    assert!(t.elapsed().as_secs_f64() < 0.5);
    assert_eq!(r1.comp_rate, r2.comp_rate);
    assert_eq!(r1.prune_acc, r2.prune_acc);
    assert!((r1.comp_rate - 4.0).abs() < 0.2);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn all_methods_produce_valid_rows_on_lenet() {
    let (ctx, dir) = ctx_in_tempdir();
    for method in [
        Method::Uniform,
        Method::OneShot,
        Method::Privacy,
        Method::PrivacyWhole,
        Method::Traditional,
    ] {
        let row = ctx
            .prune_retrain("lenet_sv10", method, Scheme::Irregular, 4.0)
            .unwrap();
        assert!(
            row.comp_rate > 3.5 && row.comp_rate < 4.5,
            "{method:?}: comp {}",
            row.comp_rate
        );
        assert!(
            row.prune_acc > 0.05,
            "{method:?}: acc {} (worse than chance)",
            row.prune_acc
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn pattern_scheme_rate_is_bounded_by_connectivity() {
    let (ctx, dir) = ctx_in_tempdir();
    // pattern pruning cannot go below 2.25x (4-of-9 kernels all kept)
    let row = ctx
        .prune_retrain("lenet_sv10", Method::Uniform, Scheme::Pattern, 16.0)
        .unwrap();
    assert!(row.comp_rate >= 15.0, "comp {}", row.comp_rate);
    let row2 = ctx
        .prune_retrain("lenet_sv10", Method::Uniform, Scheme::Pattern, 2.0)
        .unwrap();
    assert!(
        (row2.comp_rate - 2.25).abs() < 0.1,
        "pattern floor: {}",
        row2.comp_rate
    );
    std::fs::remove_dir_all(dir).ok();
}
