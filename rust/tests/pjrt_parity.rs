//! Mobile executor vs PJRT reference (requires `--features pjrt` and
//! `make artifacts`): the planned sparse executor (all three compiler
//! passes applied) must reproduce the `fwd_eval` artifact's logits exactly
//! (up to f32 accumulation order), proving the passes are
//! semantics-preserving on a real model. The artifact-free engine
//! consistency suite lives in tests/mobile_integration.rs.
#![cfg(feature = "pjrt")]

use repro::mobile::engine::{
    compile, infer, EngineKind, Executor, Fmap, KernelKind,
};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::compile_plan;
use repro::mobile::synth;
use repro::rng::Pcg32;
use repro::runtime::Runtime;
use repro::tensor::Tensor;
use repro::train::params::init_params;

const MODEL: &str = "lenet_sv10";

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// PJRT logits for a single image (slot 0 of a zero-padded eval batch).
fn pjrt_logits(rt: &Runtime, params: &[Tensor], img: &Fmap) -> Vec<f32> {
    let bsz = rt.manifest.batches.eval;
    let model = rt.model(MODEL).unwrap();
    let hw = model.in_hw;
    let mut x = Tensor::zeros(&[bsz, 3, hw, hw]);
    x.data_mut()[..3 * hw * hw].copy_from_slice(&img.data);
    let mut inputs: Vec<&Tensor> = params.iter().collect();
    inputs.push(&x);
    let outs = rt.exec(MODEL, "fwd_eval", &inputs).unwrap();
    outs[0].row(0).to_vec()
}

fn rand_image(hw: usize, seed: u64) -> Fmap {
    let mut rng = Pcg32::seeded(seed);
    Fmap {
        c: 3,
        hw,
        data: (0..3 * hw * hw).map(|_| rng.uniform()).collect(),
    }
}

fn pattern_prune(rt: &Runtime, params: &mut [Tensor], alpha: f64) {
    synth::pattern_prune(rt.model(MODEL).unwrap(), params, alpha);
}

#[test]
fn dense_engine_matches_pjrt() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let model = rt.model(MODEL).unwrap().clone();
    let params = init_params(&model, 3);
    let compiled = compile(ModelIR::build(&model, &params).unwrap());
    for seed in 0..3u64 {
        let img = rand_image(model.in_hw, seed);
        let want = pjrt_logits(&rt, &params, &img);
        let got = infer(&compiled, &img, EngineKind::Dense);
        for (g, w) in got.iter().zip(&want) {
            assert!(
                (g - w).abs() < 2e-4 * w.abs().max(1.0),
                "seed {seed}: {got:?} vs {want:?}"
            );
        }
    }
}

#[test]
fn sparse_executor_matches_pjrt_on_pruned_model() {
    let rt = Runtime::new(artifacts_dir()).unwrap();
    let model = rt.model(MODEL).unwrap().clone();
    let mut params = init_params(&model, 4);
    pattern_prune(&rt, &mut params, 0.25);
    // multi-threaded plan, both sparse kernels
    let plan =
        compile_plan(ModelIR::build(&model, &params).unwrap(), 4).unwrap();
    for seed in 10..13u64 {
        let img = rand_image(model.in_hw, seed);
        let want = pjrt_logits(&rt, &params, &img);
        for kind in [KernelKind::PatternScalar, KernelKind::PatternTiled] {
            let got = Executor::new(&plan, kind).execute(&img);
            for (g, w) in got.iter().zip(&want) {
                assert!(
                    (g - w).abs() < 2e-4 * w.abs().max(1.0),
                    "seed {seed} {:?}: {got:?} vs {want:?}",
                    kind
                );
            }
        }
    }
}
