//! Zero-allocation invariant of the execute phase (ISSUE acceptance
//! criterion): after plan construction and executor warm-up, `execute_into`
//! performs no heap allocation at all. Verified two ways: a counting
//! global allocator wrapped around the system allocator (hard proof, kept
//! in its own integration binary so no concurrent test thread can perturb
//! the counter), and the arena's own growth counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use repro::mobile::engine::{Executor, Fmap, KernelKind};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::compile_plan;
use repro::mobile::synth;
use repro::rng::Pcg32;

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(
        &self,
        ptr: *mut u8,
        layout: Layout,
        new_size: usize,
    ) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::SeqCst);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn execute_into_is_allocation_free_after_plan_construction() {
    // residual model: exercises every step kind (Conv/Pool-free path,
    // Save/Proj/Add/Relu/Gap/Fc) on the allocation-free path
    let (spec, mut params) = synth::res_style("z", 16, 6, &[6, 10], 3);
    synth::pattern_prune(&spec, &mut params, 0.25);
    let ir = ModelIR::build(&spec, &params).unwrap();
    // threads = 1: per-layer thread spawning is the one std-level
    // allocation source at threads > 1; the executor's own data path must
    // be allocation-free, which single-thread plans expose exactly
    let plan = compile_plan(ir, 1).unwrap();
    let mut ex = Executor::new(&plan, KernelKind::PatternScalar);
    let mut rng = Pcg32::seeded(5);
    let img = Fmap {
        c: 3,
        hw: 16,
        data: (0..3 * 16 * 16).map(|_| rng.uniform()).collect(),
    };
    let mut logits = vec![0.0f32; plan.ir.classes];
    // warm-up (first call touches every arena buffer)
    ex.execute_into(&img, &mut logits).unwrap();
    let expected = logits.clone();

    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..10 {
        ex.execute_into(&img, &mut logits).unwrap();
        std::hint::black_box(&logits);
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    assert_eq!(
        after - before,
        0,
        "inference path allocated {} times",
        after - before
    );
    assert_eq!(ex.alloc_events(), 0, "arena grew post-construction");
    assert_eq!(logits, expected, "warm path changed results");
}

// NOTE: exactly one test lives in this binary on purpose — a second test
// running on a sibling libtest thread would allocate inside the counting
// window. The threads>1 arena variant lives in mobile_integration.rs.
