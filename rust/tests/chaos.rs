//! Deterministic chaos: the fault-injection harness (ISSUE acceptance
//! criteria).
//!
//! The house invariant — bit-identical results at any worker count —
//! extends to injected faults: a [`FaultPlan`] decides panics, stalls,
//! and corruption as a pure function of `(seed, site, request id)`, so
//! the set of chaos victims, every survivor's logits, and the
//! supervision counters must all replay bit-identically at 1, 2, and 4
//! workers. Separately: a fully-poisoned run must resolve every request
//! with a typed error (never a hang), a flood of build failures must
//! trip the registry circuit breaker without starving a healthy
//! co-tenant, a failed i8 build must degrade to its f32 twin, and every
//! single-byte artifact corruption must surface as a typed
//! [`ServeError::Artifact`].

use std::collections::BTreeSet;
use std::sync::Arc;

use repro::config::ServeConfig;
use repro::mobile::engine::{Executor, KernelKind};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::{compile_plan, ExecutionPlan};
use repro::mobile::synth;
use repro::rng::Pcg32;
use repro::serve::artifact;
use repro::serve::error::ServeError;
use repro::serve::faults::{FaultPlan, FaultSite};
use repro::serve::gateway::{Gateway, TenantConfig};
use repro::serve::loadgen::{self, LoadGenConfig, LoadMode, TenantLoad};
use repro::serve::registry::{PlanKey, ShardedRegistry};
use repro::serve::server::Server;

const SEED: u64 = 0xBAD5EED;
const CHAOS_SEED: u64 = 42;

fn tenant_plan(id: &str, seed: u64) -> ExecutionPlan {
    let (spec, mut params) = synth::vgg_style(id, 8, 4, &[4, 6], seed);
    synth::pattern_prune(&spec, &mut params, 0.25);
    compile_plan(ModelIR::build(&spec, &params).unwrap(), 1).unwrap()
}

type Counters = (u64, u64, u64, u64, u64, u64, u64, u64);

// ---------------------------------------------------------------------------
// Gateway: fault schedule and recovery identical across worker counts
// ---------------------------------------------------------------------------

/// Panic often enough that a ~60-event trace sees several victims, and
/// stall occasionally (timing-only noise that must not leak into any
/// deterministic output).
fn chaos_plan() -> Arc<FaultPlan> {
    Arc::new(
        FaultPlan::new(CHAOS_SEED)
            .rate(FaultSite::WorkerPanic, 150)
            .rate(FaultSite::SlowExec, 30)
            .stall_us(200),
    )
}

fn chaos_loads() -> Vec<TenantLoad> {
    vec![
        TenantLoad::new("alpha", 80.0, 40),
        TenantLoad::new("beta", 40.0, 20),
    ]
}

struct ChaosRun {
    /// (tenant, trace id, lost, logits bits) sorted by (tenant, id)
    outcomes: Vec<(usize, u64, bool, Option<Vec<u32>>)>,
    counters: Vec<Counters>,
}

fn chaos_trace(workers: usize) -> ChaosRun {
    let loads = chaos_loads();
    let mut builder = Gateway::builder()
        .workers(workers)
        .max_batch(4)
        .max_wait_us(200)
        .chaos(chaos_plan());
    for (ti, load) in loads.iter().enumerate() {
        let plan = Arc::new(tenant_plan(&load.tenant, 60 + ti as u64));
        builder = builder.tenant(
            // caps sized to never reject: queue-full rejection is
            // timing-dependent and would break the determinism claim
            TenantConfig::new(&load.tenant).queue_cap(256),
            plan,
            KernelKind::PatternScalar,
        );
    }
    let trace = loadgen::multi_tenant_trace(&loads, None, SEED);
    let gateway = builder.spawn().unwrap();
    let load =
        loadgen::replay(&gateway.handle(), &loads, &trace, SEED, 0.0)
            .unwrap();
    let report = gateway.shutdown();
    assert_eq!(load.rejected, 0, "queues were sized to never reject");
    assert_eq!(load.shed, 0, "no admission control configured");
    ChaosRun {
        outcomes: load
            .outcomes
            .iter()
            .map(|o| {
                (
                    o.tenant,
                    o.trace_id,
                    o.lost,
                    o.logits.as_ref().map(|l| {
                        l.iter().map(|x| x.to_bits()).collect()
                    }),
                )
            })
            .collect(),
        counters: report
            .tenants
            .iter()
            .map(|t| t.report.deterministic_counters())
            .collect(),
    }
}

#[test]
fn chaos_schedule_and_recovery_identical_at_1_2_and_4_workers() {
    let base = chaos_trace(1);
    let lost: BTreeSet<(usize, u64)> = base
        .outcomes
        .iter()
        .filter(|o| o.2)
        .map(|o| (o.0, o.1))
        .collect();
    assert!(!lost.is_empty(), "chaos rate chosen to kill several");
    assert!(base.outcomes.iter().any(|o| o.3.is_some()));

    // the victim set is exactly the schedule's poisoned ids: replay
    // submits single-threaded in trace order, so event k holds gateway
    // id k, and `fires` is pure in (seed, site, id)
    let schedule = chaos_plan();
    let trace = loadgen::multi_tenant_trace(&chaos_loads(), None, SEED);
    let want_lost: BTreeSet<(usize, u64)> = trace
        .iter()
        .enumerate()
        .filter(|(k, _)| {
            schedule.fires(FaultSite::WorkerPanic, *k as u64)
        })
        .map(|(_, ev)| (ev.tenant, ev.id))
        .collect();
    assert_eq!(lost, want_lost, "victims != poisoned schedule");

    // every survivor's logits bit-match a bare executor on the same
    // tenant-salted image: recovery re-executes innocents exactly
    let plans: Vec<ExecutionPlan> = ["alpha", "beta"]
        .iter()
        .enumerate()
        .map(|(ti, name)| tenant_plan(name, 60 + ti as u64))
        .collect();
    for (ti, id, _, logits) in &base.outcomes {
        let Some(bits) = logits else { continue };
        let plan = &plans[*ti];
        let mut ex = Executor::new(plan, KernelKind::PatternScalar);
        let img = loadgen::tenant_request_image(
            plan.in_dims,
            SEED,
            ["alpha", "beta"][*ti],
            *id,
        );
        let want: Vec<u32> =
            ex.execute(&img).iter().map(|x| x.to_bits()).collect();
        assert_eq!(&want, bits, "tenant {ti} trace {id}");
    }

    // supervision counters: one restart per victim, and the dispatch
    // ledger balances per tenant
    let total_lost: u64 = base.counters.iter().map(|c| c.6).sum();
    assert_eq!(total_lost, want_lost.len() as u64);
    for (ti, c) in base.counters.iter().enumerate() {
        let (sub, comp, rej, err, shed, disp, wl, rs) = *c;
        assert_eq!(wl, rs, "tenant {ti}: one restart per victim");
        assert_eq!(
            disp,
            comp + err + wl,
            "tenant {ti}: dispatched = completed + errors + lost"
        );
        assert_eq!((rej, err, shed), (0, 0, 0), "tenant {ti}");
        assert_eq!(sub, comp + wl, "tenant {ti}: every request resolved");
    }

    for workers in [2usize, 4] {
        let run = chaos_trace(workers);
        assert_eq!(
            run.outcomes, base.outcomes,
            "chaos outcomes differ at {workers} workers"
        );
        assert_eq!(
            run.counters, base.counters,
            "chaos counters differ at {workers} workers"
        );
    }
}

// ---------------------------------------------------------------------------
// Server: victims are a pure function of request id
// ---------------------------------------------------------------------------

#[test]
fn server_chaos_victims_are_a_pure_function_of_request_id() {
    const REQUESTS: usize = 48;
    let plan = Arc::new(tenant_plan("chaos_srv", 11));
    let schedule = || {
        Arc::new(
            FaultPlan::new(7).rate(FaultSite::WorkerPanic, 200),
        )
    };
    let run = |workers: usize| {
        let cfg = ServeConfig {
            workers,
            max_batch: 4,
            max_wait_us: 300,
            queue_cap: 64,
            batch_threads: 1,
        };
        let server = Server::builder(plan.clone())
            .config(&cfg)
            .kernel(KernelKind::PatternScalar)
            .chaos(schedule())
            .spawn()
            .unwrap();
        // open loop: one submitting thread, so server id k == trace id
        // k and the poisoned set is computable up front
        let load = loadgen::run(
            &server.handle(),
            plan.in_dims,
            &LoadGenConfig {
                mode: LoadMode::Open { qps: 1e6 },
                requests: REQUESTS,
                seed: SEED,
            },
        );
        let report = server.shutdown();
        let outcomes: Vec<(u64, Option<Vec<u32>>)> = load
            .outcomes
            .iter()
            .map(|o| {
                (
                    o.trace_id,
                    o.logits.as_ref().map(|l| {
                        l.iter().map(|x| x.to_bits()).collect()
                    }),
                )
            })
            .collect();
        (outcomes, report.deterministic_counters())
    };

    let fp = schedule();
    let poisoned: Vec<u64> = (0..REQUESTS as u64)
        .filter(|id| fp.fires(FaultSite::WorkerPanic, *id))
        .collect();
    assert!(!poisoned.is_empty(), "rate chosen to kill several");
    assert!(poisoned.len() < REQUESTS, "and spare the rest");

    let mut direct = Executor::new(&plan, KernelKind::PatternScalar);
    let (base, base_counters) = run(1);
    for (id, logits) in &base {
        if poisoned.contains(id) {
            assert!(logits.is_none(), "poisoned {id} completed");
        } else {
            let img = loadgen::request_image(plan.in_dims, SEED, *id);
            let want: Vec<u32> = direct
                .execute(&img)
                .iter()
                .map(|x| x.to_bits())
                .collect();
            assert_eq!(Some(&want), logits.as_ref(), "trace {id}");
        }
    }
    let (sub, comp, rej, err, shed, disp, wl, rs) = base_counters;
    assert_eq!(sub, REQUESTS as u64);
    assert_eq!(wl, poisoned.len() as u64);
    assert_eq!(rs, wl, "one worker restart per victim");
    assert_eq!(comp, sub - wl);
    assert_eq!((rej, err, shed), (0, 0, 0));
    assert_eq!(disp, comp + wl);

    for workers in [2usize, 4] {
        let (out, counters) = run(workers);
        assert_eq!(out, base, "outcomes differ at {workers} workers");
        assert_eq!(counters, base_counters, "{workers} workers");
    }
}

// ---------------------------------------------------------------------------
// An armed-but-inert FaultPlan must not perturb the serve path
// ---------------------------------------------------------------------------

#[test]
fn disarmed_sites_leave_the_serve_path_byte_identical() {
    const REQUESTS: usize = 24;
    let plan = Arc::new(tenant_plan("chaos_inert", 17));
    let run = |chaos: Option<Arc<FaultPlan>>| {
        let cfg = ServeConfig {
            workers: 2,
            max_batch: 4,
            max_wait_us: 200,
            queue_cap: 64,
            batch_threads: 1,
        };
        let mut sb = Server::builder(plan.clone())
            .config(&cfg)
            .kernel(KernelKind::PatternScalar);
        if let Some(fp) = chaos {
            sb = sb.chaos(fp);
        }
        let server = sb.spawn().unwrap();
        let load = loadgen::run(
            &server.handle(),
            plan.in_dims,
            &LoadGenConfig {
                mode: LoadMode::Closed { clients: 4 },
                requests: REQUESTS,
                seed: SEED,
            },
        );
        let report = server.shutdown();
        let bits: Vec<(u64, Option<Vec<u32>>)> = load
            .outcomes
            .iter()
            .map(|o| {
                (
                    o.trace_id,
                    o.logits.as_ref().map(|l| {
                        l.iter().map(|x| x.to_bits()).collect()
                    }),
                )
            })
            .collect();
        (bits, report.deterministic_counters())
    };
    // all-zero rates: every hook runs, nothing ever fires
    let inert = Arc::new(
        FaultPlan::new(9)
            .rate(FaultSite::WorkerPanic, 0)
            .rate(FaultSite::ArtifactCorrupt, 0)
            .rate(FaultSite::SlowExec, 0)
            .rate(FaultSite::BuildFail, 0),
    );
    let (with_bits, with_counters) = run(Some(inert));
    let (bare_bits, bare_counters) = run(None);
    assert_eq!(with_bits, bare_bits, "inert chaos changed outputs");
    assert_eq!(with_counters, bare_counters);
    assert_eq!(with_counters.6, 0, "no victims");
    assert_eq!(with_counters.7, 0, "no restarts");
}

// ---------------------------------------------------------------------------
// A fully-poisoned run still resolves every request: no hangs
// ---------------------------------------------------------------------------

#[test]
fn fully_poisoned_run_resolves_every_request_with_typed_errors() {
    let plan = Arc::new(tenant_plan("chaos_all", 23));
    let chaos =
        Arc::new(FaultPlan::new(3).rate(FaultSite::WorkerPanic, 1000));
    let cfg = ServeConfig {
        workers: 2,
        max_batch: 4,
        max_wait_us: 200,
        queue_cap: 32,
        batch_threads: 1,
    };
    let server = Server::builder(plan.clone())
        .config(&cfg)
        .kernel(KernelKind::PatternScalar)
        .chaos(chaos)
        .spawn()
        .unwrap();
    let handle = server.handle();
    let tickets: Vec<_> = (0..16u64)
        .map(|id| {
            handle
                .submit(loadgen::request_image(plan.in_dims, SEED, id))
                .unwrap()
        })
        .collect();
    // every dispatch panics; the supervisor must fail each admitted
    // request with the typed error — a dropped channel (Canceled) or a
    // hang here is the bug this test exists to catch
    for t in tickets {
        match t.wait() {
            Err(ServeError::WorkerLost { .. }) => {}
            Ok(_) => panic!("poisoned request completed"),
            Err(e) => panic!("expected WorkerLost, got {e}"),
        }
    }
    let report = server.shutdown();
    let (sub, comp, _, _, _, disp, wl, rs) =
        report.deterministic_counters();
    assert_eq!(
        (sub, comp, disp, wl, rs),
        (16, 0, 16, 16, 16),
        "every request dispatched once and lost exactly once"
    );
}

// ---------------------------------------------------------------------------
// Registry circuit breaker: a broken tenant sheds fast, neighbors live
// ---------------------------------------------------------------------------

#[test]
fn broken_tenant_sheds_fast_without_starving_its_neighbor() {
    let mut reg = ShardedRegistry::new();
    reg.add_tenant("broken", 2, u64::MAX).unwrap();
    reg.add_tenant("steady", 2, u64::MAX).unwrap();
    let reg = Arc::new(reg);
    let steady_key = PlanKey::new("steady", "pattern", 4.0, 1);
    let steady_plan = reg
        .get_or_build("steady", &steady_key, || {
            Ok(tenant_plan("steady", 5))
        })
        .unwrap();
    let gateway = Gateway::builder()
        .workers(2)
        .max_batch(4)
        .max_wait_us(200)
        .registry(reg.clone())
        .tenant(
            TenantConfig::new("steady"),
            steady_plan,
            KernelKind::PatternScalar,
        )
        .spawn()
        .unwrap();

    // flood the broken tenant's shard from a side thread while the
    // neighbor serves: every build fails slowly, so unbounded retries
    // would burn ~128 ms of builder time — the breaker must cut the
    // admitted attempts to a handful and shed the rest instantly
    let reg2 = reg.clone();
    let flood = std::thread::spawn(move || {
        let key = PlanKey::new("broken", "pattern", 4.0, 1);
        let mut attempts = 0u64;
        for _ in 0..64 {
            let r = reg2.get_or_build("broken", &key, || {
                attempts += 1;
                std::thread::sleep(
                    std::time::Duration::from_millis(2),
                );
                Err(ServeError::Config {
                    msg: "flooded builder always fails".into(),
                })
            });
            assert!(matches!(r, Err(ServeError::Build { .. })));
        }
        attempts
    });

    let loads = [TenantLoad::new("steady", 50.0, 40)];
    let trace = loadgen::multi_tenant_trace(&loads, None, SEED);
    let load =
        loadgen::replay(&gateway.handle(), &loads, &trace, SEED, 0.0)
            .unwrap();
    let attempts = flood.join().unwrap();
    let report = gateway.shutdown();

    assert!(
        attempts < 16,
        "breaker admitted {attempts} of 64 flood builds"
    );
    let stats = reg.stats();
    let broken = &stats.iter().find(|(n, _)| n == "broken").unwrap().1;
    assert_eq!(broken.build_failures, attempts);
    assert!(
        broken.shed_broken >= 48,
        "only {} of 64 lookups shed fast",
        broken.shed_broken
    );
    assert_eq!(broken.broken, 1, "one permanently-broken key");

    // the co-tenant is untouched: all requests served, bounded tail
    let steady = &report.tenant("steady").unwrap().report;
    assert_eq!(steady.completed, 40);
    assert_eq!(load.per_tenant[0].completed, 40);
    assert!(
        steady.latency.p99_us < 5_000_000,
        "steady p99 {} us",
        steady.latency.p99_us
    );
}

// ---------------------------------------------------------------------------
// Degraded mode: a failed i8 build falls back to the f32 twin
// ---------------------------------------------------------------------------

#[test]
fn quantized_build_failure_degrades_to_the_f32_twin() {
    let mut reg = ShardedRegistry::new();
    reg.add_tenant("q", 2, u64::MAX).unwrap();
    let reg = Arc::new(reg);
    let key_i8 = PlanKey::new("q", "pattern", 4.0, 1).quantized();
    let key_f32 = PlanKey::new("q", "pattern", 4.0, 1);
    let (plan, degraded) = reg
        .get_or_build_with_fallback(
            "q",
            &key_i8,
            || {
                Err(ServeError::Config {
                    msg: "quantizer exploded".into(),
                })
            },
            &key_f32,
            || Ok(tenant_plan("q", 21)),
        )
        .unwrap();
    assert!(degraded, "fallback must report the degraded mode");

    let gateway = Gateway::builder()
        .workers(1)
        .max_batch(2)
        .max_wait_us(100)
        .registry(reg.clone())
        .tenant(
            TenantConfig::new("q").degraded(degraded),
            plan.clone(),
            KernelKind::PatternScalar,
        )
        .spawn()
        .unwrap();
    let handle = gateway.handle();
    for id in 0..6u64 {
        let img = loadgen::request_image(plan.in_dims, SEED, id);
        handle.infer("q", img).unwrap();
    }
    let report = gateway.shutdown();
    let tr = report.tenant("q").unwrap();
    assert!(tr.degraded, "degraded flag lost on the way to the report");
    assert_eq!(tr.report.completed, 6, "the f32 twin serves fine");
    // the shard remembers the failed i8 build for the breaker
    let stats = reg.stats();
    assert_eq!(stats[0].1.build_failures, 1);
}

// ---------------------------------------------------------------------------
// Artifact fuzz: every single-byte flip is a typed error, never a panic
// ---------------------------------------------------------------------------

#[test]
fn every_single_byte_flip_of_an_artifact_is_a_typed_error() {
    let plan = tenant_plan("chaos_fuzz", 13);
    let bytes = artifact::encode_plan(&plan);
    let total = bytes.len();
    assert!(total > 160, "artifact too small to sweep");

    // full sweep of the header region plus seeded positions across the
    // whole body (including the trailing checksum itself)
    let mut positions: Vec<usize> = (0..160).collect();
    let mut rng = Pcg32::split_stream(0xF1A5, 0);
    for _ in 0..256 {
        positions.push(rng.below(total));
    }
    for (i, pos) in positions.into_iter().enumerate() {
        let mut corrupted = bytes.clone();
        // nonzero mask with bit 0 set: the byte always changes
        let mut mrng = Pcg32::split_stream(0xF1A6, i as u64);
        let mask = 1u8 | (mrng.below(255) as u8);
        corrupted[pos] ^= mask;
        match artifact::decode_plan(&corrupted) {
            Err(ServeError::Artifact { .. }) => {}
            Ok(_) => panic!(
                "flip of byte {pos} (mask {mask:#04x}) decoded silently"
            ),
            Err(e) => panic!("flip of byte {pos}: wrong error kind {e}"),
        }
    }
}
