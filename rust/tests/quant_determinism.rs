//! INT8 quantized-path determinism (the ISSUE acceptance criteria).
//!
//! The quantized path must be bit-reproducible: i8 weights and
//! per-filter scales are fixed at compile time, activations quantize on
//! the calling thread, and the i8 x i8 -> i32 accumulation is exact, so
//! thread count, worker count, and kernel selection must never change a
//! single output bit. The plan must also survive an artifact v3 round
//! trip bit-identically, and its payload must be a small fraction of
//! the f32 plan's.

use repro::mobile::engine::{
    execute_batch_parallel, Executor, Fmap, KernelSel, KERNEL_KINDS,
};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::{
    compile_plan, compile_plan_quant, ElemType, ExecutionPlan,
};
use repro::mobile::synth;
use repro::rng::Pcg32;
use repro::serve::artifact;

fn quant_plan(kind: &str, threads: usize) -> ExecutionPlan {
    let (spec, mut params) = synth::spec_by_kind(
        kind,
        &format!("qdet_{kind}"),
        16,
        10,
        &[8, 16],
        7,
    )
    .unwrap();
    synth::pattern_prune(&spec, &mut params, 0.25);
    compile_plan_quant(ModelIR::build(&spec, &params).unwrap(), threads)
        .unwrap()
}

fn images(hw: usize, n: usize, seed: u64) -> Vec<Fmap> {
    let mut rng = Pcg32::seeded(seed);
    (0..n)
        .map(|_| Fmap {
            c: 3,
            hw,
            data: (0..3 * hw * hw).map(|_| rng.normal()).collect(),
        })
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn quantized_outputs_bit_identical_across_threads_and_workers() {
    for kind in ["vgg", "res"] {
        let imgs = images(16, 6, 0xBEEF);
        let p1 = quant_plan(kind, 1);
        assert_eq!(p1.elem, ElemType::I8);
        let base: Vec<Vec<u32>> = {
            let mut ex = Executor::auto(&p1);
            imgs.iter().map(|i| bits(&ex.execute(i))).collect()
        };
        for threads in [2usize, 4] {
            let p = quant_plan(kind, threads);
            let mut ex = Executor::auto(&p);
            for (img, want) in imgs.iter().zip(&base) {
                assert_eq!(
                    &bits(&ex.execute(img)),
                    want,
                    "{kind} @ {threads} threads"
                );
            }
        }
        for workers in [1usize, 2, 4] {
            let out = execute_batch_parallel(
                &p1,
                KernelSel::Auto,
                &imgs,
                workers,
            )
            .unwrap();
            for (o, want) in out.iter().zip(&base) {
                assert_eq!(&bits(o), want, "{kind} @ {workers} workers");
            }
        }
    }
}

#[test]
fn quantized_outputs_identical_across_kernel_selections() {
    let plan = quant_plan("vgg", 2);
    let imgs = images(16, 4, 11);
    let mut auto_ex = Executor::auto(&plan);
    let want: Vec<Vec<u32>> =
        imgs.iter().map(|i| bits(&auto_ex.execute(i))).collect();
    // every uniform selection projects onto the plan's i8 codelets; the
    // exact integer accumulation makes them all bit-agree
    for kind in KERNEL_KINDS {
        let mut ex = Executor::new(&plan, kind);
        for (img, w) in imgs.iter().zip(&want) {
            assert_eq!(
                &bits(&ex.execute(img)),
                w,
                "kernel {}",
                kind.name()
            );
        }
    }
}

#[test]
fn quantized_plan_survives_artifact_round_trip() {
    let plan = quant_plan("vgg", 2);
    let dir = std::env::temp_dir()
        .join(format!("repro_qdet_{}", std::process::id()));
    let path = dir.join("plan.rpln");
    artifact::save(&plan, &path).unwrap();
    let loaded = artifact::load(&path).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert_eq!(loaded.elem, ElemType::I8);
    artifact::verify_roundtrip(&plan, &loaded, 3, 5).unwrap();
    let imgs = images(16, 3, 21);
    let mut a = Executor::auto(&plan);
    let mut b = Executor::auto(&loaded);
    for img in &imgs {
        assert_eq!(bits(&a.execute(img)), bits(&b.execute(img)));
    }
}

#[test]
fn quantized_payload_is_a_fraction_of_f32() {
    let (spec, mut params) =
        synth::vgg_style("qdet_ratio", 16, 10, &[16, 32], 7);
    synth::pattern_prune(&spec, &mut params, 0.25);
    let ir = ModelIR::build(&spec, &params).unwrap();
    let f = compile_plan(ir.clone(), 1).unwrap();
    let q = compile_plan_quant(ir, 1).unwrap();
    assert_eq!(f.elem, ElemType::F32);
    assert_eq!(q.elem, ElemType::I8);
    assert!(
        q.stats.payload_bytes * 3 <= f.stats.payload_bytes,
        "i8 payload {} vs f32 {}",
        q.stats.payload_bytes,
        f.stats.payload_bytes
    );
}
