//! PJRT runtime: loads the AOT HLO-text artifacts and executes them on the
//! CPU PJRT client. This is the only place the `xla` crate is touched, and
//! the crate is optional: without the `pjrt` cargo feature this module
//! compiles to a stub with the same API whose constructor reports a clear
//! error, so the rest of the workspace (pruning math, the whole mobile
//! compile/execute stack) builds and tests on machines without an XLA
//! toolchain.
//!
//! Python never runs here: `make artifacts` happens once at build time, and
//! this module gives the coordinator a `exec(model, artifact, inputs)` call
//! with Tensor⇄Literal marshalling, shape checking against the manifest,
//! and a compile cache (each HLO module is parsed + compiled exactly once
//! per process).

#[cfg(feature = "pjrt")]
use std::cell::RefCell;
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::rc::Rc;

use anyhow::Result;
#[cfg(feature = "pjrt")]
use anyhow::{bail, Context};

use crate::config::Manifest;
#[cfg(feature = "pjrt")]
use crate::config::{ArtifactSpec, ModelSpec};
#[cfg(not(feature = "pjrt"))]
use crate::config::ModelSpec;
use crate::tensor::Tensor;

/// Cumulative PJRT execute count + wall time (perf accounting).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub executions: u64,
    pub exec_secs: f64,
    pub compile_secs: f64,
    pub marshal_secs: f64,
}

#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<ExecStats>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            stats: RefCell::new(ExecStats::default()),
        })
    }

    pub fn model(&self, id: &str) -> Result<&ModelSpec> {
        self.manifest.model(id)
    }

    pub fn stats(&self) -> ExecStats {
        *self.stats.borrow()
    }

    fn executable(
        &self,
        model: &ModelSpec,
        artifact: &str,
    ) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        let key = format!("{}/{}", model.id, artifact);
        if let Some(exe) = self.cache.borrow().get(&key) {
            return Ok(exe.clone());
        }
        let spec = model.artifact(artifact)?;
        let path = self.manifest.artifact_path(spec);
        let t = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {key}"))?,
        );
        self.stats.borrow_mut().compile_secs += t.elapsed().as_secs_f64();
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Pre-compile an artifact (used to pull compilation out of timed
    /// regions in the benches).
    pub fn warm(&self, model_id: &str, artifact: &str) -> Result<()> {
        let model = self.manifest.model(model_id)?;
        self.executable(model, artifact).map(|_| ())
    }

    /// Execute `model/artifact` on `inputs`, validating shapes against the
    /// manifest. Returns the flattened outputs in manifest order.
    pub fn exec(
        &self,
        model_id: &str,
        artifact: &str,
        inputs: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        let model = self.manifest.model(model_id)?;
        let spec = model.artifact(artifact)?;
        validate_inputs(spec, inputs)
            .with_context(|| format!("inputs of {model_id}/{artifact}"))?;
        let exe = self.executable(model, artifact)?;

        let tm = std::time::Instant::now();
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| tensor_to_literal(t))
            .collect::<Result<_>>()?;
        let marshal_in = tm.elapsed().as_secs_f64();

        let te = std::time::Instant::now();
        let result = exe
            .execute::<xla::Literal>(&lits)
            .with_context(|| format!("executing {model_id}/{artifact}"))?;
        let exec = te.elapsed().as_secs_f64();

        let tm2 = std::time::Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result")?
            .to_tuple()
            .context("untupling result")?;
        if tuple.len() != spec.outputs.len() {
            bail!(
                "{model_id}/{artifact}: expected {} outputs, got {}",
                spec.outputs.len(),
                tuple.len()
            );
        }
        let outs = tuple
            .into_iter()
            .zip(&spec.outputs)
            .map(|(lit, shape)| literal_to_tensor(lit, shape))
            .collect::<Result<Vec<_>>>()?;
        let marshal_out = tm2.elapsed().as_secs_f64();

        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.exec_secs += exec;
        s.marshal_secs += marshal_in + marshal_out;
        Ok(outs)
    }
}

#[cfg(feature = "pjrt")]
fn validate_inputs(spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "expected {} inputs ({:?}...), got {}",
            spec.inputs.len(),
            spec.inputs.iter().take(4).map(|(n, _)| n).collect::<Vec<_>>(),
            inputs.len()
        );
    }
    for (t, (name, shape)) in inputs.iter().zip(&spec.inputs) {
        if t.shape() != shape.as_slice() {
            bail!(
                "input {name:?}: expected shape {:?}, got {:?}",
                shape,
                t.shape()
            );
        }
    }
    Ok(())
}

#[cfg(feature = "pjrt")]
fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    if t.shape().is_empty() {
        return Ok(xla::Literal::scalar(t.data()[0]));
    }
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(t.data())
        .reshape(&dims)
        .context("reshaping literal")
}

#[cfg(feature = "pjrt")]
fn literal_to_tensor(lit: xla::Literal, shape: &[usize]) -> Result<Tensor> {
    let data = lit.to_vec::<f32>().context("reading f32 literal")?;
    Tensor::from_vec(shape, data)
}

// ---------------------------------------------------------------------------
// Stub runtime (no XLA toolchain): same API surface, constructor errors.
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
const NO_PJRT: &str = "this build has no PJRT runtime: rebuild with \
                       `cargo build --features pjrt` (requires an XLA \
                       toolchain) to execute AOT artifacts";

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    pub fn new(_artifact_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn model(&self, id: &str) -> Result<&ModelSpec> {
        self.manifest.model(id)
    }

    pub fn stats(&self) -> ExecStats {
        ExecStats::default()
    }

    pub fn warm(&self, _model_id: &str, _artifact: &str) -> Result<()> {
        anyhow::bail!(NO_PJRT)
    }

    pub fn exec(
        &self,
        _model_id: &str,
        _artifact: &str,
        _inputs: &[&Tensor],
    ) -> Result<Vec<Tensor>> {
        anyhow::bail!(NO_PJRT)
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    #[test]
    fn validate_checks_count_and_shape() {
        let spec = ArtifactSpec {
            file: "x.hlo.txt".into(),
            inputs: vec![("a".into(), vec![2, 3])],
            outputs: vec![vec![2, 3]],
        };
        let good = Tensor::zeros(&[2, 3]);
        let bad = Tensor::zeros(&[3, 2]);
        assert!(validate_inputs(&spec, &[&good]).is_ok());
        assert!(validate_inputs(&spec, &[&bad]).is_err());
        assert!(validate_inputs(&spec, &[]).is_err());
    }

    #[test]
    fn scalar_literal_roundtrip() {
        let t = Tensor::scalar(3.5);
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![3.5]);
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_constructor_reports_missing_feature() {
        let err = Runtime::new("artifacts").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
