//! Minimal criterion-replacement bench harness (criterion is unavailable
//! offline). Provides warmup, repeated timing, and mean ± stddev reporting
//! in a stable, grep-friendly format shared by all `rust/benches/*.rs`.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub reps: usize,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:44} {:>10.4} ms ± {:>8.4} (n={})",
            self.name, self.mean_ms, self.std_ms, self.reps
        );
    }
}

/// Time `f` for `reps` repetitions after `warmup` calls.
pub fn bench(name: &str, warmup: usize, reps: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / reps as f64;
    let var = samples
        .iter()
        .map(|s| (s - mean).powi(2))
        .sum::<f64>()
        / reps as f64;
    let r = BenchResult {
        name: name.into(),
        mean_ms: mean,
        std_ms: var.sqrt(),
        reps,
    };
    r.print();
    r
}

/// Section header for grouping bench output.
pub fn section(title: &str) {
    println!("\n### {title}");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("sleep-free", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(r.mean_ms >= 0.0);
        assert!(r.std_ms >= 0.0);
        assert_eq!(r.reps, 5);
    }
}
