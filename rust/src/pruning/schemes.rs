//! Closed-form solutions to the (Proximal) problem, Eqn. (11), for each
//! constraint set of paper §IV-D. Each function takes the GEMM-layout
//! weights and returns the projected weights + support mask.

use crate::tensor::{top_k_indices, Tensor};
use crate::util::keep_count;

use super::{LayerShape, Projected};

fn zero_outside(w: &Tensor, keep: impl Fn(usize) -> bool) -> Projected {
    let mut out = w.clone();
    let mut mask = Tensor::zeros(w.shape());
    for (i, v) in out.data_mut().iter_mut().enumerate() {
        if keep(i) {
            mask.data_mut()[i] = 1.0;
        } else {
            *v = 0.0;
        }
    }
    Projected { w: out, mask }
}

/// Irregular pruning (Eqn. 13): keep the ⌊αPQ⌋ largest magnitudes.
pub fn irregular(w: &Tensor, alpha: f64) -> Projected {
    let k = keep_count(alpha, w.len());
    let scores: Vec<f64> =
        w.data().iter().map(|&v| (v as f64).abs()).collect();
    let kept: std::collections::HashSet<usize> =
        top_k_indices(&scores, k).into_iter().collect();
    zero_outside(w, |i| kept.contains(&i))
}

/// Filter pruning (Eqn. 14): keep the ⌊αP⌋ rows with largest ‖·‖²_F.
pub fn filter(w: &Tensor, alpha: f64) -> Projected {
    let p = w.rows();
    let k = keep_count(alpha, p);
    let scores: Vec<f64> = (0..p)
        .map(|r| w.row(r).iter().map(|&v| (v as f64).powi(2)).sum())
        .collect();
    let kept: std::collections::HashSet<usize> =
        top_k_indices(&scores, k).into_iter().collect();
    let q = w.cols();
    zero_outside(w, |i| kept.contains(&(i / q)))
}

/// Column pruning (Eqn. 15): keep the ⌊αQ⌋ columns with largest ‖·‖²_F.
pub fn column(w: &Tensor, alpha: f64) -> Projected {
    let (p, q) = (w.rows(), w.cols());
    let k = keep_count(alpha, q);
    let mut scores = vec![0.0f64; q];
    for r in 0..p {
        for (cidx, &v) in w.row(r).iter().enumerate() {
            scores[cidx] += (v as f64).powi(2);
        }
    }
    let kept: std::collections::HashSet<usize> =
        top_k_indices(&scores, k).into_iter().collect();
    zero_outside(w, |i| kept.contains(&(i % q)))
}

/// How many entries a kernel pattern reserves (paper: 4, to fill one
/// 128-bit SIMD lane of the mobile CPU).
pub const PATTERN_ENTRIES: usize = 4;

/// Connectivity pruning (Eqn. 18): the ⌊2.25·α·A·B⌋ kernels with largest
/// pattern norm (clamped to [1, A·B]). Shared by the serial, parallel,
/// and pattern-library variants so the keep rule can never diverge.
fn connectivity_keep(
    kernel_norm: &[f64],
    alpha: f64,
) -> std::collections::HashSet<usize> {
    let n_kernels = kernel_norm.len();
    let keep_kernels = ((2.25 * alpha * n_kernels as f64).floor() as usize)
        .clamp(1, n_kernels);
    top_k_indices(kernel_norm, keep_kernels)
        .into_iter()
        .collect()
}

/// Pattern-based pruning = kernel-pattern pruning (Eqns. 16/17, keep the 4
/// largest-magnitude taps of every kernel) followed by connectivity pruning
/// (Eqn. 18, keep the ⌊2.25·α·A·B⌋ kernels with largest norm).
pub fn pattern(w: &Tensor, shape: &LayerShape, alpha: f64) -> Projected {
    let ks = shape.kernel_size();
    assert_eq!(ks, 9, "pattern pruning requires 3x3 kernels (paper IV-D.4)");
    let (p, q) = (w.rows(), w.cols());
    let n_kernels = p * shape.c;

    // Step 1 — kernel pattern: per kernel keep the PATTERN_ENTRIES largest.
    let mut keep_flags = vec![false; p * q];
    let mut kernel_norm = vec![0.0f64; n_kernels];
    for r in 0..p {
        for ch in 0..shape.c {
            let base = r * q + ch * ks;
            let taps = &w.data()[base..base + ks];
            let scores: Vec<f64> =
                taps.iter().map(|&v| (v as f64).abs()).collect();
            let top = top_k_indices(&scores, PATTERN_ENTRIES);
            let mut norm = 0.0;
            for &t in &top {
                keep_flags[base + t] = true;
                norm += (taps[t] as f64).powi(2);
            }
            kernel_norm[r * shape.c + ch] = norm;
        }
    }

    // Step 2 — connectivity: keep ⌊2.25·α·(A·B)⌋ kernels by pattern norm.
    let kept_kernels = connectivity_keep(&kernel_norm, alpha);

    zero_outside(w, |i| {
        let r = i / q;
        let ch = (i % q) / ks;
        keep_flags[i] && kept_kernels.contains(&(r * shape.c + ch))
    })
}

/// PCONV-style *pattern library* variant (extension / ablation, DESIGN.md):
/// kernel patterns are restricted to the `lib_size` most frequent 4-entry
/// patterns across the layer, which makes the mobile compiler's codelets
/// denser. Returns (projected, pattern-ids-per-kernel, library).
pub fn pattern_with_library(
    w: &Tensor,
    shape: &LayerShape,
    alpha: f64,
    lib_size: usize,
) -> (Projected, Vec<u16>, Vec<u16>) {
    let ks = shape.kernel_size();
    assert_eq!(ks, 9);
    let (p, q) = (w.rows(), w.cols());
    let n_kernels = p * shape.c;

    // natural top-4 pattern of each kernel, as a 9-bit bitmask
    let natural: Vec<u16> = (0..n_kernels)
        .map(|ki| {
            let (r, ch) = (ki / shape.c, ki % shape.c);
            let base = r * q + ch * ks;
            let taps = &w.data()[base..base + ks];
            let scores: Vec<f64> =
                taps.iter().map(|&v| (v as f64).abs()).collect();
            top_k_indices(&scores, PATTERN_ENTRIES)
                .iter()
                .fold(0u16, |m, &t| m | (1 << t))
        })
        .collect();

    // library = most frequent natural patterns
    let mut freq = std::collections::HashMap::<u16, usize>::new();
    for &pat in &natural {
        *freq.entry(pat).or_insert(0) += 1;
    }
    let mut pats: Vec<(u16, usize)> = freq.into_iter().collect();
    pats.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    let library: Vec<u16> = pats
        .into_iter()
        .take(lib_size.max(1))
        .map(|(p, _)| p)
        .collect();

    // per kernel: pick the library pattern preserving the most magnitude
    let mut keep_flags = vec![false; p * q];
    let mut kernel_norm = vec![0.0f64; n_kernels];
    let mut chosen = vec![0u16; n_kernels];
    for ki in 0..n_kernels {
        let (r, ch) = (ki / shape.c, ki % shape.c);
        let base = r * q + ch * ks;
        let taps = &w.data()[base..base + ks];
        let (best_pat, best_norm) = library
            .iter()
            .map(|&pat| {
                let norm: f64 = (0..ks)
                    .filter(|&t| pat & (1 << t) != 0)
                    .map(|t| (taps[t] as f64).powi(2))
                    .sum();
                (pat, norm)
            })
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        chosen[ki] = best_pat;
        kernel_norm[ki] = best_norm;
        for t in 0..ks {
            if best_pat & (1 << t) != 0 {
                keep_flags[base + t] = true;
            }
        }
    }

    let kept_kernels = connectivity_keep(&kernel_norm, alpha);
    let projected = zero_outside(w, |i| {
        let r = i / q;
        let ch = (i % q) / ks;
        keep_flags[i] && kept_kernels.contains(&(r * shape.c + ch))
    });
    (projected, chosen, library)
}

// ---------------------------------------------------------------------------
// Parallel projections (the proximal step of the pruning scheduler)
// ---------------------------------------------------------------------------
//
// Every parallel variant is **bit-identical** to its serial counterpart at
// any thread count. The rule that makes this hold: each score *group* (an
// element, a row, a column, a kernel) is computed entirely by one worker
// with exactly the serial inner-loop order, so no floating-point sum is
// ever re-associated; the global top-k selection then runs on the full
// score vector exactly as in the serial path.

/// Fill `out[i] = score(i)` with group indices sharded across up to
/// `threads` scoped workers (contiguous chunks; each group computed whole
/// by one worker).
fn parallel_scores(
    threads: usize,
    out: &mut [f64],
    score: impl Fn(usize) -> f64 + Sync,
) {
    let n = out.len();
    let t = threads.max(1).min(n.max(1));
    if t <= 1 {
        for (i, v) in out.iter_mut().enumerate() {
            *v = score(i);
        }
        return;
    }
    let chunk = n.div_ceil(t);
    let score_ref = &score;
    std::thread::scope(|s| {
        for (ci, slot) in out.chunks_mut(chunk).enumerate() {
            s.spawn(move || {
                for (j, v) in slot.iter_mut().enumerate() {
                    *v = score_ref(ci * chunk + j);
                }
            });
        }
    });
}

/// Irregular pruning, parallel scoring (Eqn. 13).
pub fn irregular_par(w: &Tensor, alpha: f64, threads: usize) -> Projected {
    if threads <= 1 {
        return irregular(w, alpha);
    }
    let k = keep_count(alpha, w.len());
    let data = w.data();
    let mut scores = vec![0.0f64; w.len()];
    parallel_scores(threads, &mut scores, |i| (data[i] as f64).abs());
    let kept: std::collections::HashSet<usize> =
        top_k_indices(&scores, k).into_iter().collect();
    zero_outside(w, |i| kept.contains(&i))
}

/// Filter pruning, parallel per-row norms (Eqn. 14).
pub fn filter_par(w: &Tensor, alpha: f64, threads: usize) -> Projected {
    if threads <= 1 {
        return filter(w, alpha);
    }
    let p = w.rows();
    let k = keep_count(alpha, p);
    let mut scores = vec![0.0f64; p];
    parallel_scores(threads, &mut scores, |r| {
        w.row(r).iter().map(|&v| (v as f64).powi(2)).sum()
    });
    let kept: std::collections::HashSet<usize> =
        top_k_indices(&scores, k).into_iter().collect();
    let q = w.cols();
    zero_outside(w, |i| kept.contains(&(i / q)))
}

/// Column pruning, parallel per-column norms (Eqn. 15). Each column's sum
/// runs over rows in ascending order — the same accumulation sequence the
/// serial row-major loop produces for that column.
pub fn column_par(w: &Tensor, alpha: f64, threads: usize) -> Projected {
    if threads <= 1 {
        return column(w, alpha);
    }
    let (p, q) = (w.rows(), w.cols());
    let k = keep_count(alpha, q);
    let mut scores = vec![0.0f64; q];
    parallel_scores(threads, &mut scores, |c| {
        (0..p).map(|r| (w.at2(r, c) as f64).powi(2)).sum()
    });
    let kept: std::collections::HashSet<usize> =
        top_k_indices(&scores, k).into_iter().collect();
    zero_outside(w, |i| kept.contains(&(i % q)))
}

/// Pattern-based pruning, parallel over kernels (Eqns. 16-18): the
/// per-kernel top-4 selection and pattern norm — the compute-heavy step —
/// shard across workers; connectivity pruning then selects over the full
/// kernel-norm vector exactly as in the serial path.
pub fn pattern_par(
    w: &Tensor,
    shape: &LayerShape,
    alpha: f64,
    threads: usize,
) -> Projected {
    if threads <= 1 {
        return pattern(w, shape, alpha);
    }
    let ks = shape.kernel_size();
    assert_eq!(ks, 9, "pattern pruning requires 3x3 kernels (paper IV-D.4)");
    let (p, q) = (w.rows(), w.cols());
    let n_kernels = p * shape.c;

    // Step 1 in parallel: kernel regions are contiguous and kernel-ordered
    // in the GEMM layout (base = ki * ks since q = c·ks), so keep_flags and
    // kernel_norm chunk into disjoint aligned slices.
    let mut keep_flags = vec![false; p * q];
    let mut kernel_norm = vec![0.0f64; n_kernels];
    let t = threads.max(1).min(n_kernels.max(1));
    let kchunk = n_kernels.div_ceil(t);
    let wd = w.data();
    std::thread::scope(|s| {
        for (ci, (flags, norms)) in keep_flags
            .chunks_mut(kchunk * ks)
            .zip(kernel_norm.chunks_mut(kchunk))
            .enumerate()
        {
            s.spawn(move || {
                for (j, nslot) in norms.iter_mut().enumerate() {
                    let ki = ci * kchunk + j;
                    let taps = &wd[ki * ks..(ki + 1) * ks];
                    let scores: Vec<f64> =
                        taps.iter().map(|&v| (v as f64).abs()).collect();
                    let top = top_k_indices(&scores, PATTERN_ENTRIES);
                    let mut norm = 0.0;
                    for &tp in &top {
                        flags[j * ks + tp] = true;
                        norm += (taps[tp] as f64).powi(2);
                    }
                    *nslot = norm;
                }
            });
        }
    });

    // Step 2 — connectivity, identical to the serial path.
    let kept_kernels = connectivity_keep(&kernel_norm, alpha);

    zero_outside(w, |i| {
        let r = i / q;
        let ch = (i % q) / ks;
        keep_flags[i] && kept_kernels.contains(&(r * shape.c + ch))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    fn randw(p: usize, q: usize, seed: u64) -> Tensor {
        let mut r = Pcg32::seeded(seed);
        Tensor::from_vec(&[p, q], (0..p * q).map(|_| r.normal()).collect())
            .unwrap()
    }

    #[test]
    fn irregular_keeps_largest() {
        let w = Tensor::from_vec(&[2, 3], vec![3.0, -1.0, 0.5, -4.0, 2.0, 0.1])
            .unwrap();
        let pr = irregular(&w, 0.5); // keep 3 of 6
        assert_eq!(
            pr.w.data(),
            &[3.0, 0.0, 0.0, -4.0, 2.0, 0.0]
        );
    }

    #[test]
    fn filter_keeps_whole_rows() {
        let w = randw(8, 18, 1);
        let pr = filter(&w, 0.5);
        for r in 0..8 {
            let nz = pr.w.row(r).iter().filter(|&&v| v != 0.0).count();
            assert!(nz == 0 || nz == 18, "row {r} partially pruned");
        }
        let kept_rows = (0..8)
            .filter(|&r| pr.w.row(r).iter().any(|&v| v != 0.0))
            .count();
        assert_eq!(kept_rows, 4);
    }

    #[test]
    fn column_keeps_whole_columns() {
        let w = randw(6, 18, 2);
        let pr = column(&w, 1.0 / 3.0);
        let kept_cols: Vec<usize> = (0..18)
            .filter(|&c| (0..6).any(|r| pr.w.at2(r, c) != 0.0))
            .collect();
        assert_eq!(kept_cols.len(), 6);
        for c in 0..18 {
            let full = (0..6).all(|r| {
                (pr.w.at2(r, c) != 0.0) == kept_cols.contains(&c)
                    || w.at2(r, c) == 0.0
            });
            assert!(full);
        }
    }

    #[test]
    fn pattern_reserves_four_per_kept_kernel() {
        let shape = LayerShape {
            p: 4,
            c: 3,
            kh: 3,
            kw: 3,
        };
        let w = randw(4, 27, 3);
        // alpha = 4/9 -> keep all kernels, 4 taps each
        let pr = pattern(&w, &shape, 4.0 / 9.0);
        for r in 0..4 {
            for ch in 0..3 {
                let taps: Vec<f32> = (0..9)
                    .map(|t| pr.w.at2(r, ch * 9 + t))
                    .collect();
                let nz = taps.iter().filter(|&&v| v != 0.0).count();
                assert_eq!(nz, 4, "kernel ({r},{ch})");
            }
        }
        // tighter alpha drops whole kernels
        let pr2 = pattern(&w, &shape, 1.0 / 9.0);
        let kernels_kept = (0..4)
            .flat_map(|r| (0..3).map(move |ch| (r, ch)))
            .filter(|&(r, ch)| {
                (0..9).any(|t| pr2.w.at2(r, ch * 9 + t) != 0.0)
            })
            .count();
        assert_eq!(kernels_kept, (2.25f64 * (1.0 / 9.0) * 12.0) as usize);
    }

    #[test]
    fn pattern_kept_taps_are_the_largest() {
        let shape = LayerShape {
            p: 1,
            c: 1,
            kh: 3,
            kw: 3,
        };
        let w = Tensor::from_vec(
            &[1, 9],
            vec![0.9, -0.8, 0.1, 0.7, -0.05, 0.02, 0.6, 0.0, 0.3],
        )
        .unwrap();
        let pr = pattern(&w, &shape, 4.0 / 9.0);
        assert_eq!(
            pr.w.data(),
            &[0.9, -0.8, 0.0, 0.7, 0.0, 0.0, 0.6, 0.0, 0.0]
        );
    }

    /// The parallel projections are bit-identical to the serial ones at
    /// every thread count, across all four schemes (proptest-style).
    #[test]
    fn prop_parallel_projection_matches_serial_bitwise() {
        use crate::util::propcheck::check;
        check("par-projection-vs-serial", 77, 60, 20, |g| {
            let shape = LayerShape {
                p: g.dim_up_to(16),
                c: g.dim_up_to(8),
                kh: 3,
                kw: 3,
            };
            let w = Tensor::from_vec(
                &[shape.p, shape.q()],
                g.vec_f32(shape.p * shape.q()),
            )
            .unwrap();
            let alpha = g.alpha();
            let threads = 2 + g.rng.below(4);
            let pairs: [(Projected, Projected); 4] = [
                (irregular(&w, alpha), irregular_par(&w, alpha, threads)),
                (filter(&w, alpha), filter_par(&w, alpha, threads)),
                (column(&w, alpha), column_par(&w, alpha, threads)),
                (
                    pattern(&w, &shape, alpha),
                    pattern_par(&w, &shape, alpha, threads),
                ),
            ];
            for (i, (ser, par)) in pairs.iter().enumerate() {
                if ser.w != par.w || ser.mask != par.mask {
                    return Err(format!(
                        "scheme #{i} diverges at {threads} threads \
                         (p={} c={} alpha={alpha})",
                        shape.p, shape.c
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn pattern_library_restricts_styles() {
        let shape = LayerShape {
            p: 8,
            c: 4,
            kh: 3,
            kw: 3,
        };
        let w = randw(8, 36, 4);
        let (pr, chosen, lib) =
            pattern_with_library(&w, &shape, 4.0 / 9.0, 6);
        assert!(lib.len() <= 6);
        for pat in &chosen {
            assert!(lib.contains(pat));
            assert_eq!(pat.count_ones(), 4);
        }
        // every kept kernel uses its chosen pattern
        for ki in 0..32 {
            let (r, ch) = (ki / 4, ki % 4);
            let kept: u16 = (0..9)
                .filter(|&t| pr.w.at2(r, ch * 9 + t) != 0.0)
                .fold(0, |m, t| m | (1 << t));
            if kept != 0 {
                assert_eq!(kept & !chosen[ki], 0);
            }
        }
    }
}
