//! Weight-pruning schemes: the Euclidean projections Π_Sₙ of paper §IV-D
//! and the mask function they induce.
//!
//! All projections operate on the GEMM matrix view **W ∈ R^{P×Q}** with
//! P = Aₙ (filters) and Q = Bₙ·Cₙ·Dₙ (channels × kernel), exactly the
//! paper's §IV-A notation. The 4-D kernel structure needed by pattern
//! pruning is recovered from [`LayerShape`].
//!
//! Each scheme returns both the projected weights and the 0/1 support mask
//! — the "mask function" shipped to the client for retraining.

pub mod schemes;

use anyhow::{bail, Result};

use crate::config::ConvOp;
use crate::tensor::Tensor;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Eqn. (13): keep the ⌊αPQ⌋ largest-magnitude weights anywhere.
    Irregular,
    /// Eqn. (14): keep the ⌊αP⌋ rows with largest Frobenius norm.
    Filter,
    /// Eqn. (15): keep the ⌊αQ⌋ columns with largest Frobenius norm.
    Column,
    /// Eqns. (16)-(18): 4-entry kernel patterns + connectivity pruning.
    Pattern,
}

impl Scheme {
    pub fn parse(s: &str) -> Result<Scheme> {
        Ok(match s {
            "irregular" => Scheme::Irregular,
            "filter" => Scheme::Filter,
            "column" => Scheme::Column,
            "pattern" => Scheme::Pattern,
            _ => bail!("unknown scheme {s:?} (irregular|filter|column|pattern)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Irregular => "irregular",
            Scheme::Filter => "filter",
            Scheme::Column => "column",
            Scheme::Pattern => "pattern",
        }
    }

    pub fn all() -> [Scheme; 4] {
        [
            Scheme::Irregular,
            Scheme::Filter,
            Scheme::Column,
            Scheme::Pattern,
        ]
    }
}

/// Kernel geometry of one conv layer's GEMM matrix.
#[derive(Clone, Copy, Debug)]
pub struct LayerShape {
    /// filters (GEMM rows)
    pub p: usize,
    /// channels
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
}

impl LayerShape {
    pub fn q(&self) -> usize {
        self.c * self.kh * self.kw
    }

    pub fn kernel_size(&self) -> usize {
        self.kh * self.kw
    }

    pub fn from_conv(op: &ConvOp) -> Self {
        LayerShape {
            p: op.a,
            c: op.c,
            kh: op.kh,
            kw: op.kw,
        }
    }
}

/// Projection output: pruned weights + the 0/1 support mask (same shape).
#[derive(Clone, Debug)]
pub struct Projected {
    pub w: Tensor,
    pub mask: Tensor,
}

impl Projected {
    pub fn kept(&self) -> usize {
        self.mask.data().iter().filter(|&&m| m != 0.0).count()
    }
}

fn validate_projection_args(
    w: &Tensor,
    shape: &LayerShape,
    alpha: f64,
) -> Result<()> {
    if w.shape() != [shape.p, shape.q()] {
        bail!(
            "weight shape {:?} != layer GEMM shape {:?}",
            w.shape(),
            [shape.p, shape.q()]
        );
    }
    if !(0.0 < alpha && alpha <= 1.0) {
        bail!("alpha must be in (0,1], got {alpha}");
    }
    Ok(())
}

/// Π_Sₙ — Euclidean projection of `w` (P×Q GEMM layout) onto the scheme's
/// constraint set at remaining-weight ratio `alpha` (paper's α).
pub fn project(
    scheme: Scheme,
    w: &Tensor,
    shape: &LayerShape,
    alpha: f64,
) -> Result<Projected> {
    validate_projection_args(w, shape, alpha)?;
    Ok(match scheme {
        Scheme::Irregular => schemes::irregular(w, alpha),
        Scheme::Filter => schemes::filter(w, alpha),
        Scheme::Column => schemes::column(w, alpha),
        Scheme::Pattern => schemes::pattern(w, shape, alpha),
    })
}

/// Parallel Π_Sₙ: fans the score computation (magnitudes / group norms /
/// kernel patterns) out across up to `threads` scoped workers. The result
/// is **bit-identical** to [`project`] at any thread count — each score
/// group is computed whole by one worker in the serial inner-loop order,
/// so no floating-point sum is re-associated (see
/// [`schemes`] module notes).
pub fn project_par(
    scheme: Scheme,
    w: &Tensor,
    shape: &LayerShape,
    alpha: f64,
    threads: usize,
) -> Result<Projected> {
    if threads <= 1 {
        return project(scheme, w, shape, alpha);
    }
    validate_projection_args(w, shape, alpha)?;
    Ok(match scheme {
        Scheme::Irregular => schemes::irregular_par(w, alpha, threads),
        Scheme::Filter => schemes::filter_par(w, alpha, threads),
        Scheme::Column => schemes::column_par(w, alpha, threads),
        Scheme::Pattern => schemes::pattern_par(w, shape, alpha, threads),
    })
}

/// Achieved CONV compression rate over a set of layers:
/// total weights / remaining weights (the paper's "CONV Comp. Rate").
pub fn compression_rate(projected: &[Projected]) -> f64 {
    let total: usize = projected.iter().map(|p| p.w.len()).sum();
    let kept: usize = projected.iter().map(|p| p.kept()).sum();
    total as f64 / kept.max(1) as f64
}

/// Fraction of zero weights.
pub fn sparsity(w: &Tensor) -> f64 {
    1.0 - w.count_nonzero() as f64 / w.len().max(1) as f64
}

/// ASCII rendering of a small GEMM mask — the Fig. 1 illustration used by
/// the quickstart example ('█' kept, '·' pruned; kernels separated).
pub fn render_ascii(mask: &Tensor, shape: &LayerShape) -> String {
    let q = shape.q();
    let ks = shape.kernel_size();
    let mut s = String::new();
    for r in 0..shape.p.min(16) {
        for col in 0..q.min(72) {
            if col > 0 && col % ks == 0 {
                s.push(' ');
            }
            s.push(if mask.at2(r, col) != 0.0 { '█' } else { '·' });
        }
        s.push('\n');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::util::propcheck::{check, Gen};

    fn rand_w(g: &mut Gen, p: usize, q: usize) -> Tensor {
        Tensor::from_vec(&[p, q], g.vec_f32(p * q)).unwrap()
    }

    fn rand_shape(g: &mut Gen) -> LayerShape {
        LayerShape {
            p: g.dim_up_to(24),
            c: g.dim_up_to(12),
            kh: 3,
            kw: 3,
        }
    }

    #[test]
    fn rejects_bad_inputs() {
        let shape = LayerShape {
            p: 2,
            c: 1,
            kh: 3,
            kw: 3,
        };
        let w = Tensor::zeros(&[2, 9]);
        assert!(project(Scheme::Irregular, &w, &shape, 0.0).is_err());
        assert!(project(Scheme::Irregular, &w, &shape, 1.5).is_err());
        let bad = Tensor::zeros(&[3, 9]);
        assert!(project(Scheme::Irregular, &bad, &shape, 0.5).is_err());
    }

    /// Every scheme satisfies its constraint-set cardinality and the mask
    /// matches the support exactly. (proptest-style invariant)
    #[test]
    fn prop_projection_satisfies_constraint_and_mask_support() {
        for scheme in Scheme::all() {
            check(
                &format!("constraint-{}", scheme.name()),
                42,
                60,
                24,
                |g| {
                    let shape = rand_shape(g);
                    let w = rand_w(g, shape.p, shape.q());
                    let alpha = g.alpha();
                    let pr = project(scheme, &w, &shape, alpha).unwrap();
                    // mask is exactly the support of w
                    for (wi, mi) in
                        pr.w.data().iter().zip(pr.mask.data())
                    {
                        if *mi == 0.0 && *wi != 0.0 {
                            return Err("pruned coord nonzero".into());
                        }
                        if *mi != 0.0 && *mi != 1.0 {
                            return Err("mask not 0/1".into());
                        }
                    }
                    // cardinality constraint
                    let total = shape.p * shape.q();
                    let bound = match scheme {
                        Scheme::Irregular => {
                            crate::util::keep_count(alpha, total)
                        }
                        Scheme::Filter => {
                            crate::util::keep_count(alpha, shape.p)
                                * shape.q()
                        }
                        Scheme::Column => {
                            crate::util::keep_count(alpha, shape.q())
                                * shape.p
                        }
                        Scheme::Pattern => {
                            let kb = shape.p * shape.c;
                            let keep = ((2.25 * alpha * kb as f64).floor()
                                as usize)
                                .clamp(1, kb);
                            keep * 4
                        }
                    };
                    if pr.kept() > bound {
                        return Err(format!(
                            "kept {} > bound {bound} (alpha={alpha})",
                            pr.kept()
                        ));
                    }
                    Ok(())
                },
            );
        }
    }

    /// Projection is idempotent: Π(Π(w)) == Π(w). (proptest-style)
    #[test]
    fn prop_projection_idempotent() {
        for scheme in Scheme::all() {
            check(
                &format!("idempotent-{}", scheme.name()),
                7,
                40,
                20,
                |g| {
                    let shape = rand_shape(g);
                    let w = rand_w(g, shape.p, shape.q());
                    let alpha = g.alpha();
                    let p1 = project(scheme, &w, &shape, alpha).unwrap();
                    let p2 =
                        project(scheme, &p1.w, &shape, alpha).unwrap();
                    if p1.w.max_abs_diff(&p2.w) > 0.0 {
                        return Err("not idempotent".into());
                    }
                    Ok(())
                },
            );
        }
    }

    /// Kept coordinates are unchanged (projection only zeroes).
    #[test]
    fn prop_projection_only_zeroes() {
        for scheme in Scheme::all() {
            check(&format!("zero-only-{}", scheme.name()), 9, 40, 20, |g| {
                let shape = rand_shape(g);
                let w = rand_w(g, shape.p, shape.q());
                let alpha = g.alpha();
                let pr = project(scheme, &w, &shape, alpha).unwrap();
                for ((a, b), m) in w
                    .data()
                    .iter()
                    .zip(pr.w.data())
                    .zip(pr.mask.data())
                {
                    if *m != 0.0 && a != b {
                        return Err("kept coord modified".into());
                    }
                    if *m == 0.0 && *b != 0.0 {
                        return Err("pruned coord not zeroed".into());
                    }
                }
                Ok(())
            });
        }
    }

    /// Structured schemes keep the highest-norm groups: every kept
    /// row/column has norm ≥ every pruned row/column. (proptest-style)
    #[test]
    fn prop_structured_schemes_keep_largest_norm_groups() {
        check("filter-column-norm-order", 21, 50, 20, |g| {
            let shape = rand_shape(g);
            let w = rand_w(g, shape.p, shape.q());
            let alpha = g.alpha();
            // filter: rows
            let pr = project(Scheme::Filter, &w, &shape, alpha).unwrap();
            let row_norm = |r: usize| -> f64 {
                w.row(r).iter().map(|&v| (v as f64).powi(2)).sum()
            };
            let kept: Vec<usize> = (0..shape.p)
                .filter(|&r| pr.w.row(r).iter().any(|&v| v != 0.0))
                .collect();
            let min_kept = kept
                .iter()
                .map(|&r| row_norm(r))
                .fold(f64::INFINITY, f64::min);
            for r in 0..shape.p {
                if !kept.contains(&r) && row_norm(r) > min_kept + 1e-9 {
                    return Err(format!(
                        "pruned row {r} has higher norm than a kept row"
                    ));
                }
            }
            // column: columns
            let pr = project(Scheme::Column, &w, &shape, alpha).unwrap();
            let q = shape.q();
            let col_norm = |c: usize| -> f64 {
                (0..shape.p)
                    .map(|r| (w.at2(r, c) as f64).powi(2))
                    .sum()
            };
            let keptc: Vec<usize> = (0..q)
                .filter(|&c| (0..shape.p).any(|r| pr.w.at2(r, c) != 0.0))
                .collect();
            let min_keptc = keptc
                .iter()
                .map(|&c| col_norm(c))
                .fold(f64::INFINITY, f64::min);
            for c in 0..q {
                if !keptc.contains(&c) && col_norm(c) > min_keptc + 1e-9 {
                    return Err(format!(
                        "pruned col {c} has higher norm than a kept col"
                    ));
                }
            }
            Ok(())
        });
    }

    /// Irregular keeps exactly the global top-k by |w| (threshold check).
    #[test]
    fn prop_irregular_is_magnitude_thresholding() {
        check("irregular-threshold", 23, 50, 24, |g| {
            let shape = rand_shape(g);
            let w = rand_w(g, shape.p, shape.q());
            let alpha = g.alpha();
            let pr = project(Scheme::Irregular, &w, &shape, alpha).unwrap();
            let kept_min = w
                .data()
                .iter()
                .zip(pr.mask.data())
                .filter(|(_, &m)| m != 0.0)
                .map(|(&v, _)| v.abs())
                .fold(f32::INFINITY, f32::min);
            for (&v, &m) in w.data().iter().zip(pr.mask.data()) {
                if m == 0.0 && v.abs() > kept_min + 1e-7 {
                    return Err(format!(
                        "pruned |{v}| > kept min {kept_min}"
                    ));
                }
            }
            Ok(())
        });
    }

    /// α = 1 keeps everything for irregular/filter/column.
    #[test]
    fn alpha_one_is_identity_for_unstructured() {
        let mut rng = Pcg32::seeded(5);
        let shape = LayerShape {
            p: 6,
            c: 2,
            kh: 3,
            kw: 3,
        };
        let w = Tensor::from_vec(
            &[6, 18],
            (0..108).map(|_| rng.normal()).collect(),
        )
        .unwrap();
        for scheme in [Scheme::Irregular, Scheme::Filter, Scheme::Column] {
            let pr = project(scheme, &w, &shape, 1.0).unwrap();
            assert_eq!(pr.w, w, "{scheme:?}");
        }
        // pattern always enforces 4-of-9 (2.25x floor)
        let pr = project(Scheme::Pattern, &w, &shape, 1.0).unwrap();
        assert_eq!(pr.kept(), 6 * 2 * 4);
    }

    #[test]
    fn compression_rate_math() {
        let shape = LayerShape {
            p: 4,
            c: 1,
            kh: 3,
            kw: 3,
        };
        let mut rng = Pcg32::seeded(6);
        let w = Tensor::from_vec(
            &[4, 9],
            (0..36).map(|_| rng.normal()).collect(),
        )
        .unwrap();
        let pr = project(Scheme::Irregular, &w, &shape, 0.25).unwrap();
        assert_eq!(pr.kept(), 9); // floor(0.25*36)
        let rate = compression_rate(&[pr]);
        assert!((rate - 4.0).abs() < 1e-9);
    }

    #[test]
    fn render_ascii_smoke() {
        let shape = LayerShape {
            p: 2,
            c: 1,
            kh: 3,
            kw: 3,
        };
        let mask =
            Tensor::from_vec(&[2, 9], vec![1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 0.0,
                                           0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0, 1.0, 0.0])
                .unwrap();
        let s = render_ascii(&mask, &shape);
        assert!(s.contains('█') && s.contains('·'));
    }
}
