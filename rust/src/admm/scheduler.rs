//! Parallel layer-wise ADMM pruning scheduler — the designer-side host
//! engine (paper §IV, problem (3)).
//!
//! The paper's central observation is that privacy-preserving pruning
//! decomposes into **independent per-layer subproblems** driven by
//! synthetic data: layer n's primal solve needs only the frozen
//! pre-trained model's activations F′(X) as inputs and targets, never
//! another in-flight layer. This module exploits that independence:
//!
//! * each prunable conv becomes a [`PruneJob`] owning its own W/Z/U shard
//!   and a [`Pcg32`] stream split deterministically from the job seed
//!   ([`Pcg32::split_stream`]), so a job's result depends only on
//!   (seed, layer) — never on which worker runs it;
//! * every ADMM round generates **one** synthetic batch
//!   ([`crate::data::designer_round_batch`]) and computes the pre-trained
//!   activations once (sharded over images across the worker pool), shared
//!   read-only by all jobs;
//! * jobs are partitioned across scoped worker threads by a
//!   costmodel-style per-layer estimate ([`layer_solve_cost`], ~P·Q·iters)
//!   using deterministic LPT assignment ([`partition_lpt`]), mirroring the
//!   cost-balanced filter blocks of `mobile/plan.rs`.
//!
//! **Determinism guarantee:** `PruneOutcome` (params, masks, comp_rate,
//! loss/residual traces) is bit-identical at any thread count. Scheduling
//! only decides *where* a job runs; all cross-layer reductions (mean loss,
//! feasibility residual, compression rate) run on the main thread in layer
//! order, and the parallel proximal projections are bit-equal to the
//! serial ones (see [`crate::pruning::project_par`]).
//!
//! Relation to the PJRT drivers in [`crate::admm`]: `prune_layerwise`
//! follows Algorithm 1's Gauss-Seidel refresh (layer n+1 sees layer n's
//! fresh update within an iteration), which serializes layers. This engine
//! solves the *anchored* (Jacobi-style) decomposition — inputs and targets
//! both come from the frozen pre-trained model — which is exactly what
//! makes the subproblems independent. Both land on the same constraint set
//! via the same final hard projection. The `gauss_seidel` config flag is
//! therefore ignored here.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::{Act, AdmmConfig, ConvOp, ModelSpec, Op};
use crate::data::designer_round_batch;
use crate::mobile::engine::x_range;
use crate::mobile::plan::same_pad_lo;
use crate::pruning::{compression_rate, project, LayerShape, Scheme};
use crate::rng::Pcg32;
use crate::report::Table;
use crate::tensor::Tensor;

use super::{AdmmTrace, PruneOutcome};

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Scheduler knobs on top of the shared ADMM schedule. The PJRT path reads
/// its batch size from the artifact manifest; the host engine takes it
/// explicitly so it runs without any artifacts.
#[derive(Clone, Debug)]
pub struct SchedulerCfg {
    pub admm: AdmmConfig,
    /// synthetic images per ADMM round
    pub batch: usize,
    /// worker threads solving layer subproblems (1 = serial)
    pub threads: usize,
}

impl SchedulerCfg {
    pub fn new(admm: AdmmConfig, batch: usize, threads: usize) -> Self {
        SchedulerCfg {
            admm,
            batch: batch.max(1),
            threads: threads.max(1),
        }
    }
}

// ---------------------------------------------------------------------------
// Host convolution substrate (dense, pre-activation)
// ---------------------------------------------------------------------------

/// Geometry of one conv layer's host compute. Forward accumulation streams
/// taps in the same order as the mobile executor's dense reference kernel,
/// so host activations match the deployed numerics. Shared with the
/// host-native trainer ([`crate::train::host`]), which adds full backprop
/// on top of the same substrate.
#[derive(Clone, Copy, Debug)]
pub(crate) struct ConvGeom {
    a: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: i64,
    in_hw: usize,
    out_hw: usize,
}

impl ConvGeom {
    pub(crate) fn from_op(cv: &ConvOp) -> Self {
        let (out_hw, pad) = same_pad_lo(cv.in_hw, cv.kh, cv.stride);
        debug_assert_eq!(out_hw, cv.out_hw);
        ConvGeom {
            a: cv.a,
            c: cv.c,
            kh: cv.kh,
            kw: cv.kw,
            stride: cv.stride,
            pad,
            in_hw: cv.in_hw,
            out_hw: cv.out_hw,
        }
    }

    /// Dense direct convolution: bias fill then per-tap accumulation;
    /// pre-activation output.
    pub(crate) fn fwd(
        &self,
        w: &[f32],
        bias: &[f32],
        x: &[f32],
        out: &mut [f32],
    ) {
        let ihw = self.in_hw as i64;
        let plane = self.out_hw * self.out_hw;
        let in_plane = self.in_hw * self.in_hw;
        for f in 0..self.a {
            let o = &mut out[f * plane..(f + 1) * plane];
            o.fill(bias[f]);
            for ch in 0..self.c {
                let xin = &x[ch * in_plane..(ch + 1) * in_plane];
                let wbase = (f * self.c + ch) * self.kh * self.kw;
                for ky in 0..self.kh {
                    let dy = ky as i64 - self.pad;
                    for kx in 0..self.kw {
                        let wv = w[wbase + ky * self.kw + kx];
                        let dx = kx as i64 - self.pad;
                        self.accumulate_tap(o, xin, wv, dy, dx, ihw);
                    }
                }
            }
        }
    }

    #[inline]
    fn accumulate_tap(
        &self,
        o: &mut [f32],
        xin: &[f32],
        wv: f32,
        dy: i64,
        dx: i64,
        ihw: i64,
    ) {
        for oy in 0..self.out_hw {
            let iy = (oy * self.stride) as i64 + dy;
            if iy < 0 || iy >= ihw {
                continue;
            }
            let irow = iy as usize * self.in_hw;
            let orow = oy * self.out_hw;
            let (ox0, ox1) = x_range(self.out_hw, self.stride, dx, ihw);
            let mut ix = (ox0 * self.stride) as i64 + dx;
            for ox in ox0..ox1 {
                o[orow + ox] += wv * xin[irow + ix as usize];
                ix += self.stride as i64;
            }
        }
    }

    /// d/dW of the squared reconstruction error for one image (without the
    /// factor 2, applied by the caller's normalization):
    /// grad[f,ch,ky,kx] += Σ resid[f,oy,ox] · x[ch, oy·s+ky−pad, ox·s+kx−pad]
    /// over valid output positions.
    pub(crate) fn grad_w(&self, resid: &[f32], x: &[f32], grad: &mut [f32]) {
        let ihw = self.in_hw as i64;
        let plane = self.out_hw * self.out_hw;
        let in_plane = self.in_hw * self.in_hw;
        for f in 0..self.a {
            let r = &resid[f * plane..(f + 1) * plane];
            for ch in 0..self.c {
                let xin = &x[ch * in_plane..(ch + 1) * in_plane];
                let wbase = (f * self.c + ch) * self.kh * self.kw;
                for ky in 0..self.kh {
                    let dy = ky as i64 - self.pad;
                    for kx in 0..self.kw {
                        let dx = kx as i64 - self.pad;
                        let mut acc = 0.0f32;
                        for oy in 0..self.out_hw {
                            let iy = (oy * self.stride) as i64 + dy;
                            if iy < 0 || iy >= ihw {
                                continue;
                            }
                            let irow = iy as usize * self.in_hw;
                            let orow = oy * self.out_hw;
                            let (ox0, ox1) =
                                x_range(self.out_hw, self.stride, dx, ihw);
                            let mut ix = (ox0 * self.stride) as i64 + dx;
                            for ox in ox0..ox1 {
                                acc += r[orow + ox] * xin[irow + ix as usize];
                                ix += self.stride as i64;
                            }
                        }
                        grad[wbase + ky * self.kw + kx] += acc;
                    }
                }
            }
        }
    }

    /// d/dX of the squared reconstruction error for one image (without the
    /// factor 2): the backward-data scatter
    /// gx[ch,iy,ix] += Σ w[f,ch,ky,kx] · resid[f,oy,ox]
    /// over the output positions whose receptive field covers (iy,ix).
    /// Streams the same tap ranges as `fwd`, in the same order, so the host
    /// trainer's backprop is deterministic by construction.
    pub(crate) fn grad_x(&self, w: &[f32], resid: &[f32], gx: &mut [f32]) {
        let ihw = self.in_hw as i64;
        let plane = self.out_hw * self.out_hw;
        let in_plane = self.in_hw * self.in_hw;
        for f in 0..self.a {
            let r = &resid[f * plane..(f + 1) * plane];
            for ch in 0..self.c {
                let gxin =
                    &mut gx[ch * in_plane..(ch + 1) * in_plane];
                let wbase = (f * self.c + ch) * self.kh * self.kw;
                for ky in 0..self.kh {
                    let dy = ky as i64 - self.pad;
                    for kx in 0..self.kw {
                        let wv = w[wbase + ky * self.kw + kx];
                        let dx = kx as i64 - self.pad;
                        for oy in 0..self.out_hw {
                            let iy = (oy * self.stride) as i64 + dy;
                            if iy < 0 || iy >= ihw {
                                continue;
                            }
                            let irow = iy as usize * self.in_hw;
                            let orow = oy * self.out_hw;
                            let (ox0, ox1) =
                                x_range(self.out_hw, self.stride, dx, ihw);
                            let mut ix = (ox0 * self.stride) as i64 + dx;
                            for ox in ox0..ox1 {
                                gxin[irow + ix as usize] +=
                                    wv * r[orow + ox];
                                ix += self.stride as i64;
                            }
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Host forward pass with activation capture
// ---------------------------------------------------------------------------

/// Per-image activations: for each prunable conv (network order), the
/// input feature map and the **pre-activation** conv output — the Eqn. (8)
/// distillation target (measuring the reconstruction distance before the
/// nonlinearity keeps the per-layer primal an exact least-squares
/// objective).
struct ImgActs {
    ins: Vec<Vec<f32>>,
    tgts: Vec<Vec<f32>>,
    logits: Vec<f32>,
}

fn fwd_image_acts(
    spec: &ModelSpec,
    params: &[Tensor],
    img: &[f32],
) -> Result<ImgActs> {
    let mut ins = Vec::new();
    let mut tgts = Vec::new();
    let mut cur = img.to_vec();
    let mut cur_c = spec
        .ops
        .iter()
        .find_map(|op| match op {
            Op::Conv(cv) => Some(cv.c),
            _ => None,
        })
        .unwrap_or(3);
    let mut cur_hw = spec.in_hw;
    let mut saved: BTreeMap<&str, Vec<f32>> = BTreeMap::new();
    let mut logits = Vec::new();
    for op in &spec.ops {
        match op {
            Op::Conv(cv) => {
                let geom = ConvGeom::from_op(cv);
                let mut out = vec![0.0f32; cv.a * cv.out_hw * cv.out_hw];
                geom.fwd(
                    params[cv.w].data(),
                    params[cv.b].data(),
                    &cur,
                    &mut out,
                );
                if cv.prunable {
                    ins.push(cur.clone());
                    tgts.push(out.clone());
                }
                if cv.act == Act::Relu {
                    for v in &mut out {
                        *v = v.max(0.0);
                    }
                }
                cur = out;
                cur_c = cv.a;
                cur_hw = cv.out_hw;
            }
            Op::Pool => {
                let oh = cur_hw / 2;
                let mut out = vec![0.0f32; cur_c * oh * oh];
                for ch in 0..cur_c {
                    let p = &cur
                        [ch * cur_hw * cur_hw..(ch + 1) * cur_hw * cur_hw];
                    let ob = ch * oh * oh;
                    for y in 0..oh {
                        for xx in 0..oh {
                            let i = 2 * y * cur_hw + 2 * xx;
                            out[ob + y * oh + xx] = p[i]
                                .max(p[i + 1])
                                .max(p[i + cur_hw])
                                .max(p[i + cur_hw + 1]);
                        }
                    }
                }
                cur = out;
                cur_hw = oh;
            }
            Op::Save { tag } => {
                saved.insert(tag.as_str(), cur.clone());
            }
            Op::Proj(cv) => {
                let src = saved.get(cv.tag.as_str()).with_context(|| {
                    format!("proj: no saved fmap {:?}", cv.tag)
                })?;
                let geom = ConvGeom::from_op(cv);
                let mut out = vec![0.0f32; cv.a * cv.out_hw * cv.out_hw];
                geom.fwd(
                    params[cv.w].data(),
                    params[cv.b].data(),
                    src,
                    &mut out,
                );
                if cv.act == Act::Relu {
                    for v in &mut out {
                        *v = v.max(0.0);
                    }
                }
                saved.insert(cv.tag.as_str(), out);
            }
            Op::Add { tag } => {
                let src = saved.get(tag.as_str()).with_context(|| {
                    format!("add: no saved fmap {tag:?}")
                })?;
                if src.len() != cur.len() {
                    bail!(
                        "add {tag:?}: fmap len {} vs {}",
                        src.len(),
                        cur.len()
                    );
                }
                for (a, b) in cur.iter_mut().zip(src) {
                    *a += b;
                }
            }
            Op::Relu => {
                for v in &mut cur {
                    *v = v.max(0.0);
                }
            }
            Op::Gap => {
                let plane = cur_hw * cur_hw;
                let inv = 1.0 / plane as f32;
                cur = (0..cur_c)
                    .map(|ch| {
                        cur[ch * plane..(ch + 1) * plane]
                            .iter()
                            .sum::<f32>()
                            * inv
                    })
                    .collect();
                cur_hw = 1;
            }
            Op::Fc { w, b, a, c } => {
                let wt = &params[*w];
                let bt = &params[*b];
                logits = (0..*a)
                    .map(|k| {
                        bt.data()[k]
                            + wt.row(k)
                                .iter()
                                .zip(&cur[..*c])
                                .map(|(wv, v)| wv * v)
                                .sum::<f32>()
                    })
                    .collect();
            }
        }
    }
    Ok(ImgActs { ins, tgts, logits })
}

/// Host forward pass of `spec` on one (C,H,W) image; returns the class
/// logits. Matches the mobile executor's dense reference kernel numerics
/// (same tap-streaming accumulation order) — asserted in the integration
/// tests.
pub fn fwd_logits_host(
    spec: &ModelSpec,
    params: &[Tensor],
    img: &[f32],
) -> Result<Vec<f32>> {
    Ok(fwd_image_acts(spec, params, img)?.logits)
}

/// One round's pre-trained activations, shared read-only by all jobs.
struct RoundActs {
    batch: usize,
    /// [layer] → per-image input fmaps, concatenated in image order
    inputs: Vec<Vec<f32>>,
    /// [layer] → per-image pre-activation conv outputs (targets)
    targets: Vec<Vec<f32>>,
}

/// Compute the frozen pre-trained activations for a whole synthetic batch,
/// sharding images across up to `threads` scoped workers. Per-image
/// compute is independent, so the assembled result is bit-identical at any
/// thread count.
fn fwd_round_acts(
    spec: &ModelSpec,
    params: &[Tensor],
    x: &Tensor,
    threads: usize,
) -> Result<RoundActs> {
    let n = x.shape()[0];
    let sl = x.len() / n.max(1);
    let n_layers = spec.prunable_convs().len();
    let imgs: Vec<&[f32]> =
        (0..n).map(|i| &x.data()[i * sl..(i + 1) * sl]).collect();
    let t = threads.max(1).min(n.max(1));
    let per_chunk: Vec<Result<Vec<ImgActs>>> = if t <= 1 {
        vec![imgs
            .iter()
            .map(|img| fwd_image_acts(spec, params, img))
            .collect()]
    } else {
        let chunk = n.div_ceil(t);
        let mut out = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = imgs
                .chunks(chunk)
                .map(|ch| {
                    s.spawn(move || {
                        ch.iter()
                            .map(|img| fwd_image_acts(spec, params, img))
                            .collect::<Result<Vec<_>>>()
                    })
                })
                .collect();
            out = handles
                .into_iter()
                .map(|h| h.join().expect("acts worker panicked"))
                .collect();
        });
        out
    };
    let mut acts = RoundActs {
        batch: n,
        inputs: vec![Vec::new(); n_layers],
        targets: vec![Vec::new(); n_layers],
    };
    for chunk in per_chunk {
        for ia in chunk? {
            if ia.ins.len() != n_layers {
                bail!(
                    "spec {:?}: captured {} prunable acts, expected {}",
                    spec.id,
                    ia.ins.len(),
                    n_layers
                );
            }
            for l in 0..n_layers {
                acts.inputs[l].extend_from_slice(&ia.ins[l]);
                acts.targets[l].extend_from_slice(&ia.tgts[l]);
            }
        }
    }
    Ok(acts)
}

// ---------------------------------------------------------------------------
// Jobs and scheduling
// ---------------------------------------------------------------------------

/// One independent per-layer ADMM subproblem: the layer's W/Z/U shard plus
/// the geometry to run its primal SGD steps against the shared frozen
/// activations. Jobs never touch each other's state; the dedicated rng
/// stream keeps their stochastic subsampling scheduling-independent.
pub struct PruneJob {
    /// index among the spec's prunable convs (network order)
    pub layer: usize,
    /// modeled solve cost (the LPT scheduling weight)
    pub cost: u64,
    wi: usize,
    bi: usize,
    shape: LayerShape,
    geom: ConvGeom,
    w: Tensor,
    b: Tensor,
    z: Tensor,
    u: Tensor,
    rng: Pcg32,
    secs: f64,
    last_loss: f32,
    losses: Vec<f32>,
}

/// Costmodel-style per-layer solve estimate: the primal tap streams
/// dominate (P·Q MACs per output position, forward + gradient, per sampled
/// image per step); the trailing term covers the per-round projection.
pub fn layer_solve_cost(
    shape: &LayerShape,
    out_hw: usize,
    cfg: &SchedulerCfg,
) -> u64 {
    let pq = (shape.p * shape.q()) as u64;
    let plane = (out_hw * out_hw) as u64;
    let sub = (cfg.batch / 2).max(1) as u64;
    let steps = cfg.admm.primal_steps.max(1) as u64;
    pq * plane * sub * steps * 2 + pq * 8
}

/// Longest-processing-time assignment of job indices to at most `workers`
/// bins: jobs in descending cost order each go to the least-loaded bin.
/// Deterministic (ties break toward the lower index), and it only decides
/// *placement* — job results never depend on it.
pub fn partition_lpt(costs: &[u64], workers: usize) -> Vec<Vec<usize>> {
    let w = workers.max(1).min(costs.len().max(1));
    let mut order: Vec<usize> = (0..costs.len()).collect();
    order.sort_by(|&a, &b| costs[b].cmp(&costs[a]).then(a.cmp(&b)));
    let mut bins: Vec<Vec<usize>> = vec![Vec::new(); w];
    let mut load = vec![0u64; w];
    for j in order {
        let k = (0..w).min_by_key(|&k| (load[k], k)).expect("w >= 1");
        bins[k].push(j);
        load[k] += costs[j];
    }
    bins
}

/// One ADMM round of one job: `primal_steps` SGD steps on the Eqn. (8)
/// objective (stochastic image subsample from the job's own stream),
/// then the proximal projection Z ← Π(W+U) and dual update U ← U + W − Z.
fn solve_round(
    job: &mut PruneJob,
    acts: &RoundActs,
    scheme: Scheme,
    alpha: f64,
    rho: f32,
    cfg: &AdmmConfig,
) -> Result<()> {
    let t0 = Instant::now();
    let g = job.geom;
    let plane = g.out_hw * g.out_hw;
    let in_sl = g.c * g.in_hw * g.in_hw;
    let out_sl = g.a * plane;
    let ins = &acts.inputs[job.layer];
    let tgts = &acts.targets[job.layer];
    let sub = (acts.batch / 2).max(1);
    let pq = job.w.len();
    let mut pre = vec![0.0f32; out_sl];
    let mut grad_w = vec![0.0f32; pq];
    let mut grad_b = vec![0.0f32; g.a];
    for _step in 0..cfg.primal_steps {
        let picks: Vec<usize> =
            (0..sub).map(|_| job.rng.below(acts.batch)).collect();
        grad_w.fill(0.0);
        grad_b.fill(0.0);
        let mut loss = 0.0f64;
        for &i in &picks {
            let x = &ins[i * in_sl..(i + 1) * in_sl];
            let tgt = &tgts[i * out_sl..(i + 1) * out_sl];
            g.fwd(job.w.data(), job.b.data(), x, &mut pre);
            for (pv, tv) in pre.iter_mut().zip(tgt) {
                *pv -= tv;
                loss += (*pv as f64) * (*pv as f64);
            }
            g.grad_w(&pre, x, &mut grad_w);
            for (f, gb) in grad_b.iter_mut().enumerate() {
                let mut s = 0.0f32;
                for v in &pre[f * plane..(f + 1) * plane] {
                    s += v;
                }
                *gb += s;
            }
        }
        let step_loss =
            (loss / (picks.len() * out_sl).max(1) as f64) as f32;
        if !step_loss.is_finite() {
            // divergence guard (mirrors the PJRT path): reject the step,
            // keep the last finite loss, and leave the layer to the
            // proximal/dual machinery this round
            break;
        }
        job.last_loss = step_loss;
        // feature-map-normalized data term + the ρ(W − Z + U) penalty
        let norm = 2.0 / (picks.len() * plane) as f32;
        let lr = cfg.lr_layer;
        let wd = job.w.data();
        let zd = job.z.data();
        let ud = job.u.data();
        let mut new_w = Vec::with_capacity(pq);
        for i in 0..pq {
            let gv = norm * grad_w[i] + rho * (wd[i] - zd[i] + ud[i]);
            new_w.push(wd[i] - lr * gv);
        }
        let new_b: Vec<f32> = job
            .b
            .data()
            .iter()
            .zip(&grad_b)
            .map(|(bv, gb)| bv - lr * norm * gb)
            .collect();
        if new_w.iter().any(|v| !v.is_finite())
            || new_b.iter().any(|v| !v.is_finite())
        {
            break;
        }
        job.w.data_mut().copy_from_slice(&new_w);
        job.b.data_mut().copy_from_slice(&new_b);
    }
    // proximal: Z ← Π(W + U); dual: U ← U + W − Z. Serial projection — the
    // layer jobs themselves carry the parallelism here.
    let mut wu = job.w.clone();
    wu.axpy(1.0, &job.u);
    job.z = project(scheme, &wu, &job.shape, alpha)?.w;
    let mut u = job.u.clone();
    u.axpy(1.0, &job.w);
    u.axpy(-1.0, &job.z);
    job.u = u;
    job.losses.push(job.last_loss);
    job.secs += t0.elapsed().as_secs_f64();
    Ok(())
}

/// Run one round of every job under the precomputed LPT assignment.
fn run_round(
    jobs: &mut [PruneJob],
    assign: &[Vec<usize>],
    acts: &RoundActs,
    scheme: Scheme,
    alpha: f64,
    rho: f32,
    cfg: &AdmmConfig,
) -> Result<()> {
    if assign.len() <= 1 {
        for j in jobs.iter_mut() {
            solve_round(j, acts, scheme, alpha, rho, cfg)?;
        }
        return Ok(());
    }
    let mut owner = vec![0usize; jobs.len()];
    for (wi, bin) in assign.iter().enumerate() {
        for &j in bin {
            owner[j] = wi;
        }
    }
    let mut slots: Vec<Vec<&mut PruneJob>> =
        assign.iter().map(|b| Vec::with_capacity(b.len())).collect();
    for (ji, job) in jobs.iter_mut().enumerate() {
        slots[owner[ji]].push(job);
    }
    let mut results: Vec<Result<()>> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = slots
            .into_iter()
            .map(|mut bin| {
                s.spawn(move || -> Result<()> {
                    for j in bin.iter_mut() {
                        solve_round(j, acts, scheme, alpha, rho, cfg)?;
                    }
                    Ok(())
                })
            })
            .collect();
        results = handles
            .into_iter()
            .map(|h| h.join().expect("prune worker panicked"))
            .collect();
    });
    for r in results {
        r?;
    }
    Ok(())
}

fn residual_of(jobs: &[PruneJob]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for j in jobs {
        den += j.w.sq_frobenius();
        for (w, z) in j.w.data().iter().zip(j.z.data()) {
            num += ((w - z) as f64).powi(2);
        }
    }
    (num / den.max(1e-12)).sqrt()
}

// ---------------------------------------------------------------------------
// Trace / report plumbing
// ---------------------------------------------------------------------------

/// Per-layer solve accounting of one scheduler run.
#[derive(Clone, Debug)]
pub struct LayerTiming {
    pub layer: usize,
    pub p: usize,
    pub q: usize,
    pub cost: u64,
    pub secs: f64,
    pub final_loss: f32,
    /// per-round primal loss curve of this layer's subproblem
    pub losses: Vec<f32>,
}

/// Scheduler-level trace: wall time of the shared forward passes plus the
/// per-layer solve timings (the load-balance evidence).
#[derive(Clone, Debug, Default)]
pub struct SchedTrace {
    pub rounds: usize,
    pub threads: usize,
    pub fwd_secs: f64,
    pub per_layer: Vec<LayerTiming>,
}

impl SchedTrace {
    /// Render the per-layer timings as a report table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            &format!(
                "per-layer ADMM solve time ({} rounds, {} threads)",
                self.rounds, self.threads
            ),
            &["Layer", "P", "Q", "Cost share", "Solve secs", "Final loss"],
        );
        let total: u64 = self.per_layer.iter().map(|l| l.cost).sum();
        for l in &self.per_layer {
            t.row(&[
                format!("{}", l.layer),
                format!("{}", l.p),
                format!("{}", l.q),
                format!(
                    "{:.1}%",
                    100.0 * l.cost as f64 / total.max(1) as f64
                ),
                format!("{:.3}", l.secs),
                format!("{:.4}", l.final_loss),
            ]);
        }
        t
    }
}

/// [`PruneOutcome`] plus the scheduler trace.
pub struct ParPruneOutcome {
    pub outcome: PruneOutcome,
    pub sched: SchedTrace,
}

// ---------------------------------------------------------------------------
// Entry point
// ---------------------------------------------------------------------------

/// Parallel layer-wise privacy-preserving pruning on the host engine (no
/// PJRT, no artifacts): solves every prunable conv's ADMM subproblem
/// concurrently across `cfg.threads` workers. Bit-identical results at any
/// thread count (see module docs).
pub fn prune_layerwise_par(
    spec: &ModelSpec,
    pretrained: &[Tensor],
    scheme: Scheme,
    alpha: f64,
    cfg: &SchedulerCfg,
) -> Result<ParPruneOutcome> {
    let convs = spec.prunable_convs();
    if convs.is_empty() {
        bail!("model {:?} has no prunable conv layers", spec.id);
    }
    if cfg.batch == 0 {
        bail!("scheduler batch must be >= 1");
    }
    let threads = cfg.threads.max(1);

    let mut jobs = convs
        .iter()
        .enumerate()
        .map(|(n, (_, op))| {
            let shape = LayerShape::from_conv(op);
            let wg = pretrained[op.w]
                .clone()
                .reshape(&[shape.p, shape.q()])?;
            let z = project(scheme, &wg, &shape, alpha)?.w;
            let u = Tensor::zeros(&[shape.p, shape.q()]);
            Ok(PruneJob {
                layer: n,
                cost: layer_solve_cost(&shape, op.out_hw, cfg),
                wi: op.w,
                bi: op.b,
                shape,
                geom: ConvGeom::from_op(op),
                w: wg,
                b: pretrained[op.b].clone(),
                z,
                u,
                rng: Pcg32::split_stream(cfg.admm.seed, n as u64),
                secs: 0.0,
                last_loss: 0.0,
                losses: Vec::new(),
            })
        })
        .collect::<Result<Vec<PruneJob>>>()?;

    let costs: Vec<u64> = jobs.iter().map(|j| j.cost).collect();
    let assign = partition_lpt(&costs, threads);

    let mut trace = AdmmTrace::default();
    let mut sched = SchedTrace {
        rounds: 0,
        threads,
        fwd_secs: 0.0,
        per_layer: Vec::new(),
    };
    let mut round = 0u64;
    for &rho in &cfg.admm.rhos {
        for _ in 0..cfg.admm.iters_per_rho {
            let t0 = Instant::now();
            // one batch per round, shared by every layer job
            let x = designer_round_batch(
                cfg.admm.seed,
                round,
                cfg.batch,
                spec.in_hw,
            );
            let tf = Instant::now();
            let acts = fwd_round_acts(spec, pretrained, &x, threads)?;
            sched.fwd_secs += tf.elapsed().as_secs_f64();
            run_round(
                &mut jobs,
                &assign,
                &acts,
                scheme,
                alpha,
                rho,
                &cfg.admm,
            )?;
            // cross-layer reductions on the main thread, in layer order
            trace.primal_loss.push(
                jobs.iter().map(|j| j.last_loss).sum::<f32>()
                    / jobs.len() as f32,
            );
            trace.residual.push(residual_of(&jobs));
            trace.per_iter_secs.push(t0.elapsed().as_secs_f64());
            round += 1;
            sched.rounds += 1;
        }
    }

    // final hard projection + reassembly of the full parameter set
    let mut params = pretrained.to_vec();
    let mut masks = Vec::with_capacity(jobs.len());
    let mut projections = Vec::with_capacity(jobs.len());
    for j in &jobs {
        let pr = project(scheme, &j.w, &j.shape, alpha)?;
        let s4 = pretrained[j.wi].shape().to_vec();
        params[j.wi] = pr.w.clone().reshape(&s4)?;
        params[j.bi] = j.b.clone();
        masks.push(pr.mask.clone());
        projections.push(pr);
        sched.per_layer.push(LayerTiming {
            layer: j.layer,
            p: j.shape.p,
            q: j.shape.q(),
            cost: j.cost,
            secs: j.secs,
            final_loss: j.last_loss,
            losses: j.losses.clone(),
        });
    }
    let comp_rate = compression_rate(&projections);
    Ok(ParPruneOutcome {
        outcome: PruneOutcome {
            params,
            masks,
            comp_rate,
            trace,
        },
        sched,
    })
}

// ---------------------------------------------------------------------------
// Progressive multi-round pruning (rate ladder, arxiv 1810.07378)
// ---------------------------------------------------------------------------

/// One rung of a progressive schedule.
#[derive(Clone, Debug)]
pub struct ProgressiveRound {
    pub round: usize,
    /// keep-fraction target of this rung
    pub alpha: f64,
    /// compression rate measured after this rung's hard projection
    pub comp_rate: f64,
    /// final ADMM feasibility residual of this rung
    pub residual: f64,
}

/// Final outcome of a progressive run plus the per-rung trail.
pub struct ProgressiveOutcome {
    pub outcome: PruneOutcome,
    /// scheduler trace of the last (tightest) rung
    pub sched: SchedTrace,
    pub rounds: Vec<ProgressiveRound>,
}

/// The rate ladder: geometric interpolation from dense (α = 1) down to the
/// final keep fraction, so each rung removes roughly the same *ratio* of
/// what survived the previous one — the schedule of arxiv 1810.07378 that
/// keeps the network retrainable between rungs.
pub fn progressive_alphas(final_alpha: f64, rounds: usize) -> Vec<f64> {
    let r = rounds.max(1);
    (1..=r)
        .map(|k| final_alpha.powf(k as f64 / r as f64))
        .collect()
}

/// Progressive multi-round pruning: walk the [`progressive_alphas`] ladder,
/// running one full [`prune_layerwise_par`] pass per rung (each rung's
/// synthetic batches and job streams reseeded with `seed + rung` so rungs
/// are decorrelated but deterministic), then hand the rung's params and
/// masks to `retrain` for masked fine-tuning before the next rung tightens
/// the constraint. The callback keeps this module free of any training-data
/// dependency: the privacy tier passes the host SGD trainer, a no-op
/// closure gives pure multi-round ADMM. Determinism: with a deterministic
/// callback the outcome is bit-identical at any `cfg.threads`.
pub fn prune_progressive_par<F>(
    spec: &ModelSpec,
    pretrained: &[Tensor],
    scheme: Scheme,
    final_alpha: f64,
    rounds: usize,
    cfg: &SchedulerCfg,
    mut retrain: F,
) -> Result<ProgressiveOutcome>
where
    F: FnMut(&mut Vec<Tensor>, &[Tensor], usize) -> Result<()>,
{
    let ladder = progressive_alphas(final_alpha, rounds);
    let mut cur = pretrained.to_vec();
    let mut trail = Vec::with_capacity(ladder.len());
    let mut last: Option<ParPruneOutcome> = None;
    for (r, &alpha) in ladder.iter().enumerate() {
        let mut rung_cfg = cfg.clone();
        rung_cfg.admm.seed = cfg.admm.seed.wrapping_add(r as u64);
        let out =
            prune_layerwise_par(spec, &cur, scheme, alpha, &rung_cfg)?;
        cur = out.outcome.params.clone();
        retrain(&mut cur, &out.outcome.masks, r)?;
        trail.push(ProgressiveRound {
            round: r,
            alpha,
            comp_rate: out.outcome.comp_rate,
            residual: out
                .outcome
                .trace
                .residual
                .last()
                .copied()
                .unwrap_or(0.0),
        });
        last = Some(out);
    }
    let last = last.expect("ladder has at least one rung");
    let mut outcome = last.outcome;
    // the retrained (still mask-respecting) params are the deliverable
    outcome.params = cur;
    Ok(ProgressiveOutcome {
        outcome,
        sched: last.sched,
        rounds: trail,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn lpt_partition_covers_each_job_once_and_balances() {
        let costs = [10u64, 9, 8, 1, 1, 1, 7, 2];
        let bins = partition_lpt(&costs, 3);
        assert_eq!(bins.len(), 3);
        let mut seen: Vec<usize> =
            bins.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..costs.len()).collect::<Vec<_>>());
        let loads: Vec<u64> = bins
            .iter()
            .map(|b| b.iter().map(|&j| costs[j]).sum())
            .collect();
        let (lo, hi) = (
            *loads.iter().min().unwrap(),
            *loads.iter().max().unwrap(),
        );
        // LPT keeps the spread below the largest single job
        assert!(hi - lo <= 10, "loads {loads:?}");
        // deterministic
        assert_eq!(bins, partition_lpt(&costs, 3));
    }

    #[test]
    fn lpt_caps_workers_at_job_count() {
        let bins = partition_lpt(&[5, 3], 8);
        assert_eq!(bins.len(), 2);
        assert!(bins.iter().all(|b| b.len() == 1));
    }

    #[test]
    fn layer_cost_orders_by_work() {
        let cfg = SchedulerCfg::new(
            crate::config::AdmmConfig::preset(crate::config::Preset::Smoke),
            8,
            1,
        );
        let small = LayerShape {
            p: 4,
            c: 3,
            kh: 3,
            kw: 3,
        };
        let big = LayerShape {
            p: 16,
            c: 8,
            kh: 3,
            kw: 3,
        };
        assert!(
            layer_solve_cost(&big, 8, &cfg)
                > layer_solve_cost(&small, 8, &cfg)
        );
        // larger fmaps cost more at equal PQ
        assert!(
            layer_solve_cost(&small, 16, &cfg)
                > layer_solve_cost(&small, 4, &cfg)
        );
    }

    /// The analytic conv gradient matches central finite differences of
    /// the squared reconstruction error.
    #[test]
    fn conv_grad_matches_finite_differences() {
        let g = ConvGeom {
            a: 2,
            c: 2,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            in_hw: 5,
            out_hw: 5,
        };
        let mut rng = Pcg32::seeded(31);
        let nw = g.a * g.c * g.kh * g.kw;
        let w: Vec<f32> = (0..nw).map(|_| rng.normal() * 0.3).collect();
        let bias: Vec<f32> = (0..g.a).map(|_| rng.normal() * 0.1).collect();
        let x: Vec<f32> =
            (0..g.c * g.in_hw * g.in_hw).map(|_| rng.normal()).collect();
        let tgt: Vec<f32> = (0..g.a * g.out_hw * g.out_hw)
            .map(|_| rng.normal())
            .collect();
        let loss = |w: &[f32]| -> f64 {
            let mut out = vec![0.0f32; g.a * g.out_hw * g.out_hw];
            g.fwd(w, &bias, &x, &mut out);
            out.iter()
                .zip(&tgt)
                .map(|(o, t)| ((o - t) as f64).powi(2))
                .sum()
        };
        // analytic: grad of Σ resid² is 2·Σ resid·x
        let mut out = vec![0.0f32; g.a * g.out_hw * g.out_hw];
        g.fwd(&w, &bias, &x, &mut out);
        for (o, t) in out.iter_mut().zip(&tgt) {
            *o -= t;
        }
        let mut ana = vec![0.0f32; nw];
        g.grad_w(&out, &x, &mut ana);
        let eps = 1e-2f32;
        for i in (0..nw).step_by(7) {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut wm = w.clone();
            wm[i] -= eps;
            let num = (loss(&wp) - loss(&wm)) / (2.0 * eps as f64);
            let a = 2.0 * ana[i] as f64;
            assert!(
                (num - a).abs() <= 1e-2 * a.abs().max(1.0),
                "tap {i}: numeric {num} vs analytic {a}"
            );
        }
    }

    /// The backward-data gradient matches central finite differences of
    /// the squared reconstruction error wrt the input feature map.
    #[test]
    fn conv_grad_x_matches_finite_differences() {
        let g = ConvGeom {
            a: 2,
            c: 2,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
            in_hw: 5,
            out_hw: 5,
        };
        let mut rng = Pcg32::seeded(77);
        let nw = g.a * g.c * g.kh * g.kw;
        let nx = g.c * g.in_hw * g.in_hw;
        let w: Vec<f32> = (0..nw).map(|_| rng.normal() * 0.3).collect();
        let bias: Vec<f32> = (0..g.a).map(|_| rng.normal() * 0.1).collect();
        let x: Vec<f32> = (0..nx).map(|_| rng.normal()).collect();
        let tgt: Vec<f32> = (0..g.a * g.out_hw * g.out_hw)
            .map(|_| rng.normal())
            .collect();
        let loss = |x: &[f32]| -> f64 {
            let mut out = vec![0.0f32; g.a * g.out_hw * g.out_hw];
            g.fwd(&w, &bias, x, &mut out);
            out.iter()
                .zip(&tgt)
                .map(|(o, t)| ((o - t) as f64).powi(2))
                .sum()
        };
        let mut out = vec![0.0f32; g.a * g.out_hw * g.out_hw];
        g.fwd(&w, &bias, &x, &mut out);
        for (o, t) in out.iter_mut().zip(&tgt) {
            *o -= t;
        }
        let mut ana = vec![0.0f32; nx];
        g.grad_x(&w, &out, &mut ana);
        let eps = 1e-2f32;
        for i in (0..nx).step_by(5) {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let num = (loss(&xp) - loss(&xm)) / (2.0 * eps as f64);
            let a = 2.0 * ana[i] as f64;
            assert!(
                (num - a).abs() <= 1e-2 * a.abs().max(1.0),
                "pixel {i}: numeric {num} vs analytic {a}"
            );
        }
    }

    #[test]
    fn progressive_ladder_descends_to_final_alpha() {
        let ladder = progressive_alphas(0.125, 3);
        assert_eq!(ladder.len(), 3);
        for pair in ladder.windows(2) {
            assert!(pair[0] > pair[1], "ladder not descending: {ladder:?}");
        }
        assert!((ladder[2] - 0.125).abs() < 1e-12);
        // single round degenerates to one-shot at the final rate
        assert_eq!(progressive_alphas(0.25, 1), vec![0.25]);
    }
}
