//! ADMM-based weight pruning (paper §IV, Algorithm 1).
//!
//! Three drivers share the W/Z/U machinery:
//!
//! * [`prune_layerwise`] — the paper's main contribution: problem (3),
//!   layer-wise distillation on **randomly generated synthetic data**,
//!   solved per layer with the (Primal)/(Proximal) split of Proposition 1.
//! * [`prune_whole`] — problem (2): whole-model distillation on synthetic
//!   data (the Table IV comparison).
//! * [`prune_traditional`] — ADMM† (Zhang et al. [9]): cross-entropy on the
//!   client's real training data; the no-privacy comparator of Tables I-III.
//!
//! The primal SGD steps run as PJRT artifacts; the proximal step is the
//! exact Euclidean projection from [`crate::pruning`] (parallelized across
//! `cfg.threads` workers via [`crate::pruning::project_par`]); the dual
//! update is plain host arithmetic. ρ follows the paper's ramp
//! (1e-4 ×10 → 1e-1).
//!
//! The PJRT drivers here solve layers strictly serially (Gauss-Seidel
//! coupling + a non-`Sync` runtime); [`scheduler`] is the host-native
//! **parallel** layer-wise engine that solves the independent per-layer
//! subproblems concurrently with bit-identical results at any thread
//! count.

pub mod scheduler;

use anyhow::{Context, Result};

use crate::config::AdmmConfig;
use crate::data::{designer_batch, SynthVision};
use crate::pruning::{project_par, LayerShape, Projected, Scheme};
use crate::rng::Pcg32;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Where the pruning data comes from.
pub enum DataSource<'a> {
    /// The system designer's uniform-random pixels (privacy-preserving).
    Synthetic,
    /// The client's confidential dataset (no-privacy baselines / ablation).
    Client(&'a SynthVision),
}

#[derive(Clone, Debug, Default)]
pub struct AdmmTrace {
    pub primal_loss: Vec<f32>,
    /// ‖W − Z‖_F / ‖W‖_F after each iteration (ADMM feasibility residual)
    pub residual: Vec<f64>,
    pub per_iter_secs: Vec<f64>,
}

pub struct PruneOutcome {
    /// pruned model parameters (projected onto Sₙ)
    pub params: Vec<Tensor>,
    /// the mask function, one (P,Q) 0/1 tensor per prunable conv
    pub masks: Vec<Tensor>,
    pub comp_rate: f64,
    pub trace: AdmmTrace,
}

struct LayerState {
    /// index into the params vec of this conv's weight
    wi: usize,
    shape: LayerShape,
    z: Tensor,
    u: Tensor,
}

fn gemm_view(w: &Tensor, shape: &LayerShape) -> Tensor {
    w.clone().reshape(&[shape.p, shape.q()]).unwrap()
}

fn init_layers(
    rt: &Runtime,
    model_id: &str,
    params: &[Tensor],
    scheme: Scheme,
    alpha: f64,
    threads: usize,
) -> Result<Vec<LayerState>> {
    let model = rt.model(model_id)?;
    model
        .prunable_convs()
        .iter()
        .map(|(_, op)| {
            let shape = LayerShape::from_conv(op);
            let wg = gemm_view(&params[op.w], &shape);
            let z = project_par(scheme, &wg, &shape, alpha, threads)?.w;
            let u = Tensor::zeros(&[shape.p, shape.q()]);
            Ok(LayerState {
                wi: op.w,
                shape,
                z,
                u,
            })
        })
        .collect()
}

fn residual(params: &[Tensor], layers: &[LayerState]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for l in layers {
        let wg = gemm_view(&params[l.wi], &l.shape);
        den += wg.sq_frobenius();
        for (w, z) in wg.data().iter().zip(l.z.data()) {
            num += ((w - z) as f64).powi(2);
        }
    }
    (num / den.max(1e-12)).sqrt()
}

/// Proximal + dual updates for one layer: Z ← Π(W+U); U ← U + W − Z.
fn proximal_dual(
    params: &[Tensor],
    l: &mut LayerState,
    scheme: Scheme,
    alpha: f64,
    threads: usize,
) -> Result<()> {
    let wg = gemm_view(&params[l.wi], &l.shape);
    let mut wu = wg.clone();
    wu.axpy(1.0, &l.u);
    l.z = project_par(scheme, &wu, &l.shape, alpha, threads)?.w;
    // U += W - Z
    let mut u = l.u.clone();
    u.axpy(1.0, &wg);
    u.axpy(-1.0, &l.z);
    l.u = u;
    Ok(())
}

/// Final hard projection of every prunable layer; returns the pruned
/// params (4-D layout restored) and the mask function.
fn finalize(
    mut params: Vec<Tensor>,
    layers: &[LayerState],
    scheme: Scheme,
    alpha: f64,
    threads: usize,
    trace: AdmmTrace,
) -> Result<PruneOutcome> {
    let mut masks = Vec::with_capacity(layers.len());
    let mut projections: Vec<Projected> = Vec::with_capacity(layers.len());
    for l in layers {
        let wg = gemm_view(&params[l.wi], &l.shape);
        let pr = project_par(scheme, &wg, &l.shape, alpha, threads)?;
        let shape4 = params[l.wi].shape().to_vec();
        params[l.wi] = pr.w.clone().reshape(&shape4)?;
        masks.push(pr.mask.clone());
        projections.push(pr);
    }
    let comp_rate = crate::pruning::compression_rate(&projections);
    Ok(PruneOutcome {
        params,
        masks,
        comp_rate,
        trace,
    })
}

/// Draw the iteration's data batch (X and, for client data, labels).
fn draw_batch(
    src: &DataSource,
    rng: &mut Pcg32,
    bsz: usize,
    hw: usize,
    classes: usize,
) -> (Tensor, Option<Tensor>) {
    match src {
        DataSource::Synthetic => (designer_batch(rng, bsz, hw), None),
        DataSource::Client(d) => {
            let (x, y) = d.batch(rng, bsz);
            let _ = classes;
            (x, Some(y))
        }
    }
}

/// Problem (3) / Algorithm 1: layer-wise privacy-preserving pruning.
///
/// Per iteration: draw a synthetic batch, compute the pre-trained model's
/// layer outputs F′:n(X) once, then for each prunable layer run
/// `primal_steps` SGD steps on Eqn. (8) via the `layer_primal_n` artifact,
/// followed by the proximal projection and dual update. With
/// `cfg.gauss_seidel`, the current model's activations are refreshed after
/// every layer update (the paper's "get the output ... from the current
/// model"); otherwise they are refreshed once per iteration (Jacobi
/// ablation, ~L× fewer forward passes).
pub fn prune_layerwise(
    rt: &Runtime,
    model_id: &str,
    pretrained: &[Tensor],
    scheme: Scheme,
    alpha: f64,
    cfg: &AdmmConfig,
    src: DataSource,
) -> Result<PruneOutcome> {
    let model = rt.model(model_id)?;
    let (hw, classes) = (model.in_hw, model.classes);
    let bsz = rt.manifest.batches.admm;
    let n_layers = model.prunable_convs().len();
    let bias_idx: Vec<usize> =
        model.prunable_convs().iter().map(|(_, op)| op.b).collect();

    let mut params = pretrained.to_vec();
    let mut layers =
        init_layers(rt, model_id, &params, scheme, alpha, cfg.threads)?;
    let mut rng = Pcg32::seeded(cfg.seed);
    let lr = Tensor::scalar(cfg.lr_layer);
    let mut trace = AdmmTrace::default();

    // target activations come from the frozen pre-trained model
    let pre_params = pretrained.to_vec();

    for (ri, &rho_v) in cfg.rhos.iter().enumerate() {
        let rho = Tensor::scalar(rho_v);
        for _it in 0..cfg.iters_per_rho {
            let t0 = std::time::Instant::now();
            let (x, _) = draw_batch(&src, &mut rng, bsz, hw, classes);

            // F′:n(X): pre-trained inputs/outputs per prunable conv
            let pre_acts = fwd_acts(rt, model_id, &pre_params, &x)?;
            // current model activations (refreshed per layer if GS)
            let mut cur_acts = fwd_acts(rt, model_id, &params, &x)?;

            let mut iter_loss = 0.0f32;
            for n in 0..n_layers {
                let l = &mut layers[n];
                let act_in = &cur_acts.inputs[n];
                let target = &pre_acts.outputs[n];
                let mut loss = 0.0f32;
                for _s in 0..cfg.primal_steps {
                    let w = &params[l.wi];
                    let b = &params[bias_idx[n]];
                    let outs = rt
                        .exec(
                            model_id,
                            &format!("layer_primal_{n}"),
                            &[w, b, act_in, target, &l.z, &l.u, &rho, &lr],
                        )
                        .with_context(|| format!("layer_primal_{n}"))?;
                    let [w_new, b_new, loss_t]: [Tensor; 3] =
                        outs.try_into().ok().context("3 outputs")?;
                    let new_loss = loss_t.data()[0];
                    // divergence guard: a non-finite primal loss means the
                    // step overshot (the Eqn. (8) objective is unnormalized
                    // over feature maps); reject the update and leave the
                    // layer to the proximal/dual machinery this iteration.
                    if !new_loss.is_finite()
                        || w_new.data().iter().any(|v| !v.is_finite())
                    {
                        break;
                    }
                    params[l.wi] = w_new;
                    params[bias_idx[n]] = b_new;
                    loss = new_loss;
                }
                iter_loss += loss;
                proximal_dual(&params, l, scheme, alpha, cfg.threads)?;
                if cfg.gauss_seidel && n + 1 < n_layers {
                    cur_acts = fwd_acts(rt, model_id, &params, &x)?;
                }
            }
            trace.primal_loss.push(iter_loss / n_layers as f32);
            trace.residual.push(residual(&params, &layers));
            trace.per_iter_secs.push(t0.elapsed().as_secs_f64());
        }
        let _ = ri;
    }
    finalize(params, &layers, scheme, alpha, cfg.threads, trace)
}

/// Per-layer activations of one forward pass (admm batch).
pub struct Acts {
    pub logits: Tensor,
    pub inputs: Vec<Tensor>,
    pub outputs: Vec<Tensor>,
}

pub fn fwd_acts(
    rt: &Runtime,
    model_id: &str,
    params: &[Tensor],
    x: &Tensor,
) -> Result<Acts> {
    let model = rt.model(model_id)?;
    let n = model.prunable_convs().len();
    let mut inputs: Vec<&Tensor> = params.iter().collect();
    inputs.push(x);
    let mut outs = rt.exec(model_id, "fwd_acts", &inputs)?;
    let logits = outs.remove(0);
    let rest: Vec<Tensor> = outs;
    let (ins, outs2) = rest.split_at(n);
    Ok(Acts {
        logits,
        inputs: ins.to_vec(),
        outputs: outs2.to_vec(),
    })
}

/// Shared driver for the whole-model primal formulations (problem (2) and
/// ADMM†), which differ only in artifact + data + target tensor.
fn prune_whole_driver(
    rt: &Runtime,
    model_id: &str,
    pretrained: &[Tensor],
    scheme: Scheme,
    alpha: f64,
    cfg: &AdmmConfig,
    src: DataSource,
    artifact: &str,
) -> Result<PruneOutcome> {
    let model = rt.model(model_id)?;
    let (hw, classes) = (model.in_hw, model.classes);
    let bsz = match artifact {
        "whole_primal_step" => rt.manifest.batches.admm,
        _ => rt.manifest.batches.train,
    };
    let np = pretrained.len();
    let mut params = pretrained.to_vec();
    let mut layers =
        init_layers(rt, model_id, &params, scheme, alpha, cfg.threads)?;
    let mut rng = Pcg32::seeded(cfg.seed);
    let lr = Tensor::scalar(cfg.lr);
    let pre_params = pretrained.to_vec();
    let mut trace = AdmmTrace::default();

    for &rho_v in &cfg.rhos {
        let rho = Tensor::scalar(rho_v);
        for _it in 0..cfg.iters_per_rho {
            let t0 = std::time::Instant::now();
            let (x, y) = draw_batch(&src, &mut rng, bsz, hw, classes);
            // target: soft logits of the pre-trained model (problem (2))
            // or the real labels (ADMM†)
            let target = match artifact {
                "whole_primal_step" => {
                    fwd_acts(rt, model_id, &pre_params, &x)?.logits
                }
                _ => y.context("ADMM† requires client data")?,
            };
            let mut loss = 0.0f32;
            for _s in 0..cfg.primal_steps {
                let mut ins: Vec<&Tensor> = params.iter().collect();
                ins.push(&x);
                ins.push(&target);
                for l in &layers {
                    ins.push(&l.z);
                }
                for l in &layers {
                    ins.push(&l.u);
                }
                ins.push(&rho);
                ins.push(&lr);
                let mut outs = rt.exec(model_id, artifact, &ins)?;
                loss = outs.pop().context("loss")?.data()[0];
                params = outs;
                debug_assert_eq!(params.len(), np);
            }
            for l in &mut layers {
                proximal_dual(&params, l, scheme, alpha, cfg.threads)?;
            }
            trace.primal_loss.push(loss);
            trace.residual.push(residual(&params, &layers));
            trace.per_iter_secs.push(t0.elapsed().as_secs_f64());
        }
    }
    finalize(params, &layers, scheme, alpha, cfg.threads, trace)
}

/// Problem (2): whole-model distillation pruning on synthetic data.
pub fn prune_whole(
    rt: &Runtime,
    model_id: &str,
    pretrained: &[Tensor],
    scheme: Scheme,
    alpha: f64,
    cfg: &AdmmConfig,
) -> Result<PruneOutcome> {
    prune_whole_driver(
        rt,
        model_id,
        pretrained,
        scheme,
        alpha,
        cfg,
        DataSource::Synthetic,
        "whole_primal_step",
    )
}

/// ADMM† (traditional, no privacy): cross-entropy on client data + ADMM
/// penalty — the paper's strongest comparator in Tables I-III.
pub fn prune_traditional(
    rt: &Runtime,
    model_id: &str,
    pretrained: &[Tensor],
    scheme: Scheme,
    alpha: f64,
    cfg: &AdmmConfig,
    client_data: &SynthVision,
) -> Result<PruneOutcome> {
    prune_whole_driver(
        rt,
        model_id,
        pretrained,
        scheme,
        alpha,
        cfg,
        DataSource::Client(client_data),
        "admm_train_primal_step",
    )
}
