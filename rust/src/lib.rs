//! Privacy-preserving DNN pruning + mobile acceleration framework.
//!
//! Rust L3 coordinator of the three-layer reproduction of Zhan et al. 2020
//! (see DESIGN.md). Python/JAX/Pallas exist only at build time; this crate
//! loads the AOT-lowered HLO artifacts and owns the entire pipeline:
//! pre-training, privacy-preserving ADMM pruning, masked client retraining,
//! and compiler-assisted mobile deployment.
pub mod util;
pub mod rng;
pub mod tensor;
pub mod config;
pub mod data;
pub mod runtime;
pub mod pruning;
pub mod admm;
pub mod train;
pub mod baselines;
pub mod mobile;
pub mod serve;
pub mod coordinator;
pub mod privacy;
pub mod report;
