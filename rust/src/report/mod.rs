//! Paper-style table rendering + persistence of experiment results.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A simple column-aligned table that renders like the paper's tables.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> =
            self.headers.iter().map(|h| h.chars().count()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let mut s = String::new();
        let _ = writeln!(s, "== {} ==", self.title);
        let line = |s: &mut String, cells: &[String]| {
            for i in 0..ncol {
                let w = widths[i];
                let c = &cells[i];
                let pad = w - c.chars().count();
                let _ = write!(s, "| {}{} ", c, " ".repeat(pad));
            }
            let _ = writeln!(s, "|");
        };
        line(&mut s, &self.headers);
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        let _ = writeln!(s, "{}", "-".repeat(total));
        for r in &self.rows {
            line(&mut s, r);
        }
        s
    }

    pub fn render_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {}\n", self.title);
        let _ = writeln!(s, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            s,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(s, "| {} |", r.join(" | "));
        }
        s
    }

    pub fn save(&self, dir: impl AsRef<Path>, name: &str) -> Result<()> {
        std::fs::create_dir_all(&dir)?;
        std::fs::write(
            dir.as_ref().join(format!("{name}.txt")),
            self.render(),
        )?;
        std::fs::write(
            dir.as_ref().join(format!("{name}.md")),
            self.render_markdown(),
        )?;
        Ok(())
    }
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}

pub fn rate(x: f64) -> String {
    format!("{x:.1}x")
}

pub fn ms(x: f64) -> String {
    format!("{x:.1} ms")
}

pub fn secs(x: f64) -> String {
    format!("{x:.2} s")
}

/// Microsecond latency cell for the serving-tier tables.
pub fn us(x: f64) -> String {
    format!("{x:.0} us")
}

/// Requests-per-second cell for the serving-tier tables.
pub fn qps(x: f64) -> String {
    format!("{x:.1} req/s")
}

/// Humanized byte count for plan/arena stats ("512 B", "3.4 KiB",
/// "1.2 MiB").
pub fn human_bytes(n: usize) -> String {
    const KIB: f64 = 1024.0;
    let b = n as f64;
    if b < KIB {
        format!("{n} B")
    } else if b < KIB * KIB {
        format!("{:.1} KiB", b / KIB)
    } else {
        format!("{:.1} MiB", b / (KIB * KIB))
    }
}

/// Accuracy loss cell with the paper's sign convention (negative = gain).
pub fn loss_cell(base: f64, pruned: f64) -> String {
    format!("{:+.1}%", 100.0 * (base - pruned))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["a", "bbbb"]);
        t.row(&["xx".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("| xx | y    |"));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let md = t.render_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("|---|---|"));
        assert!(md.contains("| 1 | 2 |"));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.941), "94.1%");
        assert_eq!(rate(16.0), "16.0x");
        assert_eq!(secs(1.234), "1.23 s");
        assert_eq!(us(412.6), "413 us");
        assert_eq!(qps(87.25), "87.2 req/s");
        assert_eq!(loss_cell(0.941, 0.942), "-0.1%");
        assert_eq!(loss_cell(0.941, 0.930), "+1.1%");
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(3 * 1024 + 512), "3.5 KiB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.0 MiB");
    }
}
