//! Dataset substrates.
//!
//! * [`SynthVision`] — the *client's confidential dataset*: a procedural
//!   class-conditional image classification task standing in for
//!   CIFAR-10/100/ImageNet (DESIGN.md §2). Each class has a deterministic
//!   signature (base color + oriented stripe field + blob); samples add
//!   pixel noise. Learnable by the mini nets to high accuracy, yet
//!   non-trivial (greedy privacy-free pruning visibly degrades it).
//! * [`designer_batch`] — the *system designer's* synthetic data: i.i.d.
//!   discrete-uniform pixels, exactly the paper's generator (§III-B). It
//!   encodes zero knowledge of the client data.

use crate::rng::Pcg32;
use crate::tensor::Tensor;

#[derive(Clone, Debug)]
struct ClassSig {
    base: [f32; 3],
    freq_x: f32,
    freq_y: f32,
    phase: f32,
    blob_x: f32,
    blob_y: f32,
    blob_amp: [f32; 3],
}

impl ClassSig {
    fn new(dataset_seed: u64, class: usize) -> Self {
        let mut r = Pcg32::new(dataset_seed ^ 0x51_6e47, class as u64 + 1);
        ClassSig {
            base: [r.uniform(), r.uniform(), r.uniform()],
            freq_x: r.uniform_in(0.5, 3.0),
            freq_y: r.uniform_in(0.5, 3.0),
            phase: r.uniform_in(0.0, std::f32::consts::TAU),
            blob_x: r.uniform_in(0.2, 0.8),
            blob_y: r.uniform_in(0.2, 0.8),
            blob_amp: [
                r.uniform_in(-0.8, 0.8),
                r.uniform_in(-0.8, 0.8),
                r.uniform_in(-0.8, 0.8),
            ],
        }
    }

    fn pixel(&self, c: usize, i: usize, j: usize, hw: usize) -> f32 {
        let y = i as f32 / hw as f32;
        let x = j as f32 / hw as f32;
        let stripe = (self.freq_x * std::f32::consts::TAU * x
            + self.freq_y * std::f32::consts::TAU * y
            + self.phase)
            .sin()
            * 0.25;
        let d2 = (x - self.blob_x).powi(2) + (y - self.blob_y).powi(2);
        let blob = self.blob_amp[c] * (-d2 / 0.02).exp();
        self.base[c] + stripe + blob
    }
}

/// In-memory labelled image set, NCHW f32 in [0, 1].
pub struct SynthVision {
    pub images: Vec<f32>,
    pub labels: Vec<usize>,
    pub classes: usize,
    pub hw: usize,
    pub n: usize,
}

impl SynthVision {
    /// `split` separates train/test streams for the same class signatures.
    pub fn generate(
        classes: usize,
        hw: usize,
        n: usize,
        seed: u64,
        split: u64,
    ) -> Self {
        let sigs: Vec<ClassSig> =
            (0..classes).map(|k| ClassSig::new(seed, k)).collect();
        let mut rng = Pcg32::new(seed ^ 0xda7a, split);
        let mut images = vec![0.0f32; n * 3 * hw * hw];
        let mut labels = vec![0usize; n];
        let noise = 0.18;
        for s in 0..n {
            let k = s % classes; // balanced
            labels[s] = k;
            let sig = &sigs[k];
            let img = &mut images[s * 3 * hw * hw..(s + 1) * 3 * hw * hw];
            for c in 0..3 {
                for i in 0..hw {
                    for j in 0..hw {
                        let v = sig.pixel(c, i, j, hw)
                            + rng.normal_scaled(noise);
                        img[c * hw * hw + i * hw + j] = v.clamp(0.0, 1.0);
                    }
                }
            }
        }
        SynthVision {
            images,
            labels,
            classes,
            hw,
            n,
        }
    }

    /// Flat f32 length of one (3, hw, hw) sample.
    pub fn sample_len(&self) -> usize {
        3 * self.hw * self.hw
    }

    /// Copy samples `idx` into an NCHW batch tensor (zero-padded to `bsz`)
    /// plus the one-hot label tensor.
    pub fn gather(&self, idx: &[usize], bsz: usize) -> (Tensor, Tensor) {
        assert!(idx.len() <= bsz);
        let sl = self.sample_len();
        let mut x = vec![0.0f32; bsz * sl];
        let mut y = vec![0.0f32; bsz * self.classes];
        for (bi, &s) in idx.iter().enumerate() {
            x[bi * sl..(bi + 1) * sl]
                .copy_from_slice(&self.images[s * sl..(s + 1) * sl]);
            y[bi * self.classes + self.labels[s]] = 1.0;
        }
        (
            Tensor::from_vec(&[bsz, 3, self.hw, self.hw], x).unwrap(),
            Tensor::from_vec(&[bsz, self.classes], y).unwrap(),
        )
    }

    /// Random batch of `bsz` samples.
    pub fn batch(&self, rng: &mut Pcg32, bsz: usize) -> (Tensor, Tensor) {
        let idx: Vec<usize> =
            (0..bsz).map(|_| rng.below(self.n)).collect();
        self.gather(&idx, bsz)
    }

    /// Deterministic eval chunks of size `bsz` (last chunk zero-padded);
    /// returns (x, labels-in-chunk).
    pub fn eval_chunks(
        &self,
        bsz: usize,
    ) -> Vec<(Tensor, Vec<usize>)> {
        let mut out = Vec::new();
        let mut s = 0;
        while s < self.n {
            let e = (s + bsz).min(self.n);
            let idx: Vec<usize> = (s..e).collect();
            let (x, _) = self.gather(&idx, bsz);
            out.push((x, self.labels[s..e].to_vec()));
            s = e;
        }
        out
    }
}

/// One ADMM round's synthetic batch, addressed by (seed, round) instead of
/// by generator state: the pruning scheduler generates each round's batch
/// exactly once and shares it read-only across every layer job, so the
/// data a round sees is a pure function of the experiment seed and the
/// round index — independent of thread count, scheduling order, or how
/// many layers the model has.
pub fn designer_round_batch(
    seed: u64,
    round: u64,
    bsz: usize,
    hw: usize,
) -> Tensor {
    let mut rng = Pcg32::new(seed ^ 0xBA7C_4000, round.wrapping_add(1));
    designer_batch(&mut rng, bsz, hw)
}

/// The paper's privacy-preserving synthetic batch: every pixel i.i.d.
/// discrete Uniform{0..255}/255 — no prior knowledge of the client data.
pub fn designer_batch(rng: &mut Pcg32, bsz: usize, hw: usize) -> Tensor {
    let mut x = vec![0.0f32; bsz * 3 * hw * hw];
    for v in &mut x {
        *v = rng.uniform_pixel();
    }
    Tensor::from_vec(&[bsz, 3, hw, hw], x).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = SynthVision::generate(10, 16, 40, 7, 0);
        let b = SynthVision::generate(10, 16, 40, 7, 0);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn splits_differ_but_share_signatures() {
        let tr = SynthVision::generate(10, 16, 40, 7, 0);
        let te = SynthVision::generate(10, 16, 40, 7, 1);
        assert_ne!(tr.images, te.images);
        // same class => same mean signature (noise averages out);
        // compare class-0 mean pixel between splits
        let mean = |d: &SynthVision, k: usize| -> f32 {
            let sl = d.sample_len();
            let mut acc = 0.0;
            let mut cnt = 0;
            for s in 0..d.n {
                if d.labels[s] == k {
                    acc += d.images[s * sl..(s + 1) * sl]
                        .iter()
                        .sum::<f32>();
                    cnt += 1;
                }
            }
            acc / (cnt as f32 * sl as f32)
        };
        assert!((mean(&tr, 0) - mean(&te, 0)).abs() < 0.02);
    }

    #[test]
    fn balanced_labels_and_range() {
        let d = SynthVision::generate(10, 16, 100, 3, 0);
        for k in 0..10 {
            assert_eq!(d.labels.iter().filter(|&&l| l == k).count(), 10);
        }
        assert!(d.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn classes_are_separable_by_mean_color() {
        // nearest-class-mean classifier on raw pixels should beat chance
        // by a lot — guarantees the task is learnable.
        let tr = SynthVision::generate(10, 16, 200, 11, 0);
        let te = SynthVision::generate(10, 16, 100, 11, 1);
        let sl = tr.sample_len();
        let mut means = vec![vec![0.0f32; sl]; 10];
        let mut counts = vec![0usize; 10];
        for s in 0..tr.n {
            let k = tr.labels[s];
            counts[k] += 1;
            for (m, v) in means[k]
                .iter_mut()
                .zip(&tr.images[s * sl..(s + 1) * sl])
            {
                *m += v;
            }
        }
        for k in 0..10 {
            for m in &mut means[k] {
                *m /= counts[k] as f32;
            }
        }
        let mut correct = 0;
        for s in 0..te.n {
            let img = &te.images[s * sl..(s + 1) * sl];
            let pred = (0..10)
                .min_by(|&a, &b| {
                    let da: f32 = means[a]
                        .iter()
                        .zip(img)
                        .map(|(m, v)| (m - v).powi(2))
                        .sum();
                    let db: f32 = means[b]
                        .iter()
                        .zip(img)
                        .map(|(m, v)| (m - v).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred == te.labels[s] {
                correct += 1;
            }
        }
        let acc = correct as f32 / te.n as f32;
        assert!(acc > 0.6, "nearest-mean acc {acc}");
    }

    #[test]
    fn gather_pads_and_one_hots() {
        let d = SynthVision::generate(10, 16, 20, 5, 0);
        let (x, y) = d.gather(&[0, 1, 2], 8);
        assert_eq!(x.shape(), &[8, 3, 16, 16]);
        assert_eq!(y.shape(), &[8, 10]);
        // padded rows are zero
        assert!(x.data()[3 * 768..].iter().all(|&v| v == 0.0));
        assert_eq!(
            y.data().iter().filter(|&&v| v == 1.0).count(),
            3
        );
    }

    #[test]
    fn round_batches_are_stable_per_round_and_differ_across_rounds() {
        let a = designer_round_batch(9, 0, 4, 8);
        let b = designer_round_batch(9, 0, 4, 8);
        assert_eq!(a, b);
        let c = designer_round_batch(9, 1, 4, 8);
        assert_ne!(a, c);
        let d = designer_round_batch(10, 0, 4, 8);
        assert_ne!(a, d);
    }

    #[test]
    fn designer_batch_is_uniform_pixels() {
        let mut r = Pcg32::seeded(1);
        let x = designer_batch(&mut r, 4, 16);
        assert_eq!(x.shape(), &[4, 3, 16, 16]);
        let mean: f32 =
            x.data().iter().sum::<f32>() / x.len() as f32;
        assert!((mean - 0.5).abs() < 0.03, "mean {mean}");
    }
}
