//! Dynamic micro-batching primitives: a bounded MPMC queue with explicit
//! admission control and the batch-formation state machine.
//!
//! [`BoundedQueue`] is the server's single request queue (std `Mutex` +
//! `Condvar`; no async runtime). Producers [`BoundedQueue::push`] and get
//! an explicit [`PushError::Full`] back when the queue is at capacity —
//! backpressure is a visible signal, never an unbounded buffer. Consumers
//! call [`BoundedQueue::pop_batch`], which implements the batcher state
//! machine:
//!
//! 1. **idle** — block until a first item arrives (or the queue closes);
//! 2. **filling** — drain immediately-available items up to
//!    [`BatchPolicy::max_batch`];
//! 3. **waiting** — if the batch is still short, wait up to
//!    [`BatchPolicy::max_wait`] past the *first* item for stragglers, so a
//!    lone request never stalls longer than the window;
//! 4. **dispatch** — return the batch (never empty while the queue is
//!    open).
//!
//! Multiple workers can sit in `pop_batch` concurrently; the lock is
//! released while waiting, so batches form in parallel under load.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::{lock_clean, wait_clean, wait_timeout_clean};

/// Micro-batch formation knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// dispatch as soon as this many requests are in hand
    pub max_batch: usize,
    /// dispatch at latest this long after the first request of the batch
    pub max_wait: Duration,
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_wait_us: u64) -> Self {
        BatchPolicy {
            max_batch: max_batch.max(1),
            max_wait: Duration::from_micros(max_wait_us),
        }
    }
}

/// Why a push was refused.
#[derive(Debug)]
pub enum PushError<T> {
    /// the queue is at capacity — admission control rejects the request
    /// (the item is handed back so the caller can respond to its client)
    Full(T),
    /// the queue is shutting down
    Closed(T),
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Bounded MPMC queue with condvar wakeups and explicit rejection.
pub struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    nonempty: Condvar,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        BoundedQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            nonempty: Condvar::new(),
            cap: cap.max(1),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        lock_clean(&self.state).items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enqueue, or reject with [`PushError::Full`] when at capacity /
    /// [`PushError::Closed`] after [`BoundedQueue::close`]. Returns the
    /// queue depth after the push.
    pub fn push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut g = lock_clean(&self.state);
        if g.closed {
            return Err(PushError::Closed(item));
        }
        if g.items.len() >= self.cap {
            return Err(PushError::Full(item));
        }
        g.items.push_back(item);
        let depth = g.items.len();
        drop(g);
        self.nonempty.notify_all();
        Ok(depth)
    }

    /// Put already-admitted work back at the *front* of the queue (a
    /// supervisor requeueing the innocent batch-mates of a panicked
    /// dispatch). Capacity was paid at the original push and closing
    /// must not drop admitted work, so this bypasses both the cap and
    /// the closed check — the requeueing worker is still in its pop
    /// loop, so a drain-in-progress always picks these back up.
    pub fn requeue(&self, item: T) {
        let mut g = lock_clean(&self.state);
        g.items.push_front(item);
        drop(g);
        self.nonempty.notify_all();
    }

    /// Take everything still queued (shutdown leftovers after the
    /// workers exited), so each item can be failed with a typed error
    /// instead of a silently dropped channel.
    pub fn drain(&self) -> Vec<T> {
        let mut g = lock_clean(&self.state);
        g.items.drain(..).collect()
    }

    /// Close the queue: further pushes fail, consumers drain what is left
    /// and then see `None`.
    pub fn close(&self) {
        // close must succeed even after a producer/consumer panic, or
        // shutdown would wedge behind a poisoned lock
        lock_clean(&self.state).closed = true;
        self.nonempty.notify_all();
    }

    /// Block for the next micro-batch per `policy`, with the batch
    /// window anchored at `Instant::now()` when the first item is
    /// drained. `None` once the queue is closed *and* drained; otherwise
    /// the batch holds 1..=max_batch items.
    pub fn pop_batch(&self, policy: &BatchPolicy) -> Option<Vec<T>> {
        self.pop_batch_by(policy, |_| Instant::now())
    }

    /// [`BoundedQueue::pop_batch`] with an explicit window anchor: the
    /// batch dispatches at latest `max_wait` past `anchor(first item)`.
    /// The server anchors at the first request's *enqueue* time, so a
    /// request that already waited in a backlog is never further delayed
    /// by the straggler window.
    pub fn pop_batch_by(
        &self,
        policy: &BatchPolicy,
        anchor: impl Fn(&T) -> Instant,
    ) -> Option<Vec<T>> {
        let mut g = lock_clean(&self.state);
        // idle: wait for the first item
        loop {
            if !g.items.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = wait_clean(&self.nonempty, g);
        }
        // filling: take whatever is already here
        let mut batch = Vec::with_capacity(policy.max_batch);
        while batch.len() < policy.max_batch {
            match g.items.pop_front() {
                Some(x) => batch.push(x),
                None => break,
            }
        }
        // waiting: hold the window open for stragglers
        if batch.len() < policy.max_batch
            && policy.max_wait > Duration::ZERO
        {
            let deadline = anchor(&batch[0]) + policy.max_wait;
            loop {
                if batch.len() >= policy.max_batch || g.closed {
                    break;
                }
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                let (g2, timed_out) = wait_timeout_clean(
                    &self.nonempty,
                    g,
                    deadline - now,
                );
                g = g2;
                while batch.len() < policy.max_batch {
                    match g.items.pop_front() {
                        Some(x) => batch.push(x),
                        None => break,
                    }
                }
                if timed_out {
                    break;
                }
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn push_pop_fifo_up_to_max_batch() {
        let q = BoundedQueue::new(16);
        for i in 0..6 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 6);
        let p = BatchPolicy::new(4, 0);
        let b = q.pop_batch(&p).unwrap();
        assert_eq!(b, vec![0, 1, 2, 3]);
        let b = q.pop_batch(&p).unwrap();
        assert_eq!(b, vec![4, 5]);
        assert!(q.is_empty());
    }

    #[test]
    fn full_queue_rejects_with_item_back() {
        let q = BoundedQueue::new(2);
        q.push("a").unwrap();
        q.push("b").unwrap();
        match q.push("c") {
            Err(PushError::Full(item)) => assert_eq!(item, "c"),
            other => panic!("expected Full, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        assert_eq!(q.capacity(), 2);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = BoundedQueue::new(8);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        match q.push(3) {
            Err(PushError::Closed(item)) => assert_eq!(item, 3),
            other => panic!("expected Closed, got {other:?}"),
        }
        let p = BatchPolicy::new(8, 0);
        assert_eq!(q.pop_batch(&p).unwrap(), vec![1, 2]);
        assert!(q.pop_batch(&p).is_none());
    }

    #[test]
    fn waiting_state_collects_stragglers() {
        let q = Arc::new(BoundedQueue::new(8));
        q.push(0u32).unwrap();
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            // lands inside the 500ms window after the first item
            std::thread::sleep(Duration::from_millis(30));
            q2.push(1).unwrap();
            q2.push(2).unwrap();
        });
        // max_batch 3: the batch completes as soon as the stragglers land
        let p = BatchPolicy::new(3, 500_000);
        let b = q.pop_batch(&p).unwrap();
        producer.join().unwrap();
        assert_eq!(b, vec![0, 1, 2]);
    }

    #[test]
    fn window_expiry_dispatches_partial_batch() {
        let q = BoundedQueue::new(8);
        q.push(7u32).unwrap();
        // nothing else arrives: a 1ms window must still dispatch
        let p = BatchPolicy::new(4, 1_000);
        let b = q.pop_batch(&p).unwrap();
        assert_eq!(b, vec![7]);
    }

    #[test]
    fn stale_anchor_skips_the_straggler_window() {
        // a request that already sat in a backlog opens no fresh window:
        // the anchored deadline is in the past, so dispatch is immediate
        let q = BoundedQueue::new(8);
        q.push(1u32).unwrap();
        let anchored_in_past =
            Instant::now() - Duration::from_millis(100);
        let p = BatchPolicy::new(4, 50_000);
        let t = Instant::now();
        let b = q.pop_batch_by(&p, |_| anchored_in_past).unwrap();
        assert_eq!(b, vec![1]);
        assert!(
            t.elapsed() < Duration::from_millis(40),
            "stale anchor must not wait the full window"
        );
    }

    #[test]
    fn requeue_jumps_the_line_and_ignores_cap_and_close() {
        let q = BoundedQueue::new(2);
        q.push(1u32).unwrap();
        q.push(2).unwrap();
        // at capacity and even closed, admitted work goes back in front
        q.close();
        q.requeue(0);
        assert_eq!(q.len(), 3);
        let p = BatchPolicy::new(8, 0);
        assert_eq!(q.pop_batch(&p).unwrap(), vec![0, 1, 2]);
        assert!(q.pop_batch(&p).is_none());
    }

    #[test]
    fn drain_empties_leftovers() {
        let q = BoundedQueue::new(4);
        q.push("a").unwrap();
        q.push("b").unwrap();
        q.close();
        assert_eq!(q.drain(), vec!["a", "b"]);
        assert!(q.is_empty());
        assert!(q.drain().is_empty());
    }

    #[test]
    fn blocked_consumer_wakes_on_push() {
        let q = Arc::new(BoundedQueue::new(4));
        let q2 = q.clone();
        let consumer = std::thread::spawn(move || {
            q2.pop_batch(&BatchPolicy::new(2, 1_000)).unwrap()
        });
        std::thread::sleep(Duration::from_millis(20));
        q.push(9u32).unwrap();
        let b = consumer.join().unwrap();
        assert_eq!(b, vec![9]);
    }
}
