//! Seeded load generation against a [`ServeHandle`] — the measurement
//! half of the serving tier.
//!
//! Two standard load models:
//!
//! * **closed-loop** ([`LoadMode::Closed`]): `clients` synchronous client
//!   threads, each submitting its next request only after the previous
//!   response (classic think-time-zero closed system; throughput is
//!   latency-bound);
//! * **open-loop** ([`LoadMode::Open`]): one dispatcher paces submissions
//!   at a target QPS with exponential (Poisson) interarrival gaps,
//!   independent of completions — the model that exposes queueing collapse
//!   and admission-control rejections.
//!
//! Every request image is a pure function of `(seed, request id)` via
//! [`request_image`] ([`Pcg32::split_stream`]), so a trace is bit-for-bit
//! reproducible regardless of client interleaving — the property the
//! serving determinism tests lean on.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::mobile::engine::Fmap;
use crate::mobile::plan::StepDims;
use crate::rng::Pcg32;

use super::server::{ServeHandle, SubmitError};

/// Load model for a run.
#[derive(Clone, Copy, Debug)]
pub enum LoadMode {
    /// `clients` synchronous closed-loop clients
    Closed { clients: usize },
    /// open-loop Poisson arrivals at `qps`
    Open { qps: f64 },
}

#[derive(Clone, Copy, Debug)]
pub struct LoadGenConfig {
    pub mode: LoadMode,
    /// total requests to issue
    pub requests: usize,
    /// trace seed: request `i`'s image is `request_image(dims, seed, i)`
    pub seed: u64,
}

/// Outcome of one generated request.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    /// trace index (== image id fed to [`request_image`])
    pub trace_id: u64,
    /// logits, when the request completed
    pub logits: Option<Vec<f32>>,
    /// set when admission control bounced the request
    pub rejected: bool,
}

/// Aggregate result of a load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// one entry per trace id, in trace order
    pub outcomes: Vec<RequestOutcome>,
    pub completed: u64,
    pub rejected: u64,
    pub wall_secs: f64,
    pub achieved_qps: f64,
}

/// The deterministic request trace: image `id` under `seed` for a plan
/// with input `dims`. Pure in `(dims, seed, id)`.
pub fn request_image(dims: StepDims, seed: u64, id: u64) -> Fmap {
    let mut rng = Pcg32::split_stream(seed, id);
    Fmap {
        c: dims.c,
        hw: dims.hw,
        data: (0..dims.elems()).map(|_| rng.uniform()).collect(),
    }
}

/// Drive `handle` with the configured load; blocks until every issued
/// request resolved (response, rejection, or cancellation).
pub fn run(
    handle: &ServeHandle,
    dims: StepDims,
    cfg: &LoadGenConfig,
) -> LoadReport {
    let t0 = Instant::now();
    let mut outcomes: Vec<RequestOutcome> = match cfg.mode {
        LoadMode::Closed { clients } => {
            run_closed(handle, dims, cfg, clients.max(1))
        }
        LoadMode::Open { qps } => run_open(handle, dims, cfg, qps),
    };
    let wall_secs = t0.elapsed().as_secs_f64();
    outcomes.sort_by_key(|o| o.trace_id);
    let completed =
        outcomes.iter().filter(|o| o.logits.is_some()).count() as u64;
    let rejected = outcomes.iter().filter(|o| o.rejected).count() as u64;
    LoadReport {
        outcomes,
        completed,
        rejected,
        wall_secs,
        achieved_qps: if wall_secs > 0.0 {
            completed as f64 / wall_secs
        } else {
            0.0
        },
    }
}

fn run_closed(
    handle: &ServeHandle,
    dims: StepDims,
    cfg: &LoadGenConfig,
    clients: usize,
) -> Vec<RequestOutcome> {
    let results = Mutex::new(Vec::with_capacity(cfg.requests));
    std::thread::scope(|s| {
        for client in 0..clients {
            let results = &results;
            let handle = handle.clone();
            s.spawn(move || {
                // client k owns trace ids k, k+C, k+2C, ... — the id set
                // (and so the image set) is independent of timing
                let mut id = client as u64;
                while (id as usize) < cfg.requests {
                    let img = request_image(dims, cfg.seed, id);
                    let outcome = match handle.infer(img) {
                        Ok(resp) => RequestOutcome {
                            trace_id: id,
                            logits: Some(resp.logits),
                            rejected: false,
                        },
                        Err(e) => RequestOutcome {
                            trace_id: id,
                            logits: None,
                            rejected: matches!(
                                e.downcast_ref::<SubmitError>(),
                                Some(SubmitError::Rejected)
                            ),
                        },
                    };
                    results.lock().unwrap().push(outcome);
                    id += clients as u64;
                }
            });
        }
    });
    results.into_inner().unwrap()
}

fn run_open(
    handle: &ServeHandle,
    dims: StepDims,
    cfg: &LoadGenConfig,
    qps: f64,
) -> Vec<RequestOutcome> {
    let qps = qps.max(1e-3);
    let mut gaps = Pcg32::split_stream(cfg.seed, u64::MAX);
    let mut pending = Vec::new();
    let mut outcomes = Vec::with_capacity(cfg.requests);
    let mut next_at = Instant::now();
    for id in 0..cfg.requests as u64 {
        let now = Instant::now();
        if next_at > now {
            std::thread::sleep(next_at - now);
        }
        let img = request_image(dims, cfg.seed, id);
        match handle.submit(img) {
            Ok(ticket) => pending.push((id, ticket)),
            Err(e) => outcomes.push(RequestOutcome {
                trace_id: id,
                logits: None,
                rejected: matches!(e, SubmitError::Rejected),
            }),
        }
        let gap_secs = gaps.exponential(1.0 / qps as f32);
        next_at += Duration::from_secs_f64(gap_secs as f64);
    }
    for (id, ticket) in pending {
        outcomes.push(match ticket.wait() {
            Ok(resp) => RequestOutcome {
                trace_id: id,
                logits: Some(resp.logits),
                rejected: false,
            },
            Err(_) => RequestOutcome {
                trace_id: id,
                logits: None,
                rejected: false,
            },
        });
    }
    outcomes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_images_are_pure_in_seed_and_id() {
        let dims = StepDims { c: 3, hw: 8 };
        let a = request_image(dims, 9, 4);
        let b = request_image(dims, 9, 4);
        assert_eq!(a.data, b.data);
        assert_eq!(a.data.len(), 3 * 8 * 8);
        let c = request_image(dims, 9, 5);
        assert_ne!(a.data, c.data, "distinct ids must differ");
        let d = request_image(dims, 10, 4);
        assert_ne!(a.data, d.data, "distinct seeds must differ");
    }
}
