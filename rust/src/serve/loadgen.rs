//! Seeded load generation — the measurement half of the serving tier.
//!
//! Against a single-plan [`ServeHandle`], two standard load models:
//!
//! * **closed-loop** ([`LoadMode::Closed`]): `clients` synchronous client
//!   threads, each submitting its next request only after the previous
//!   response (classic think-time-zero closed system; throughput is
//!   latency-bound);
//! * **open-loop** ([`LoadMode::Open`]): one dispatcher paces submissions
//!   at a target QPS with exponential (Poisson) interarrival gaps,
//!   independent of completions — the model that exposes queueing collapse
//!   and admission-control rejections.
//!
//! Against a multi-tenant [`GatewayHandle`], a **trace** model:
//! [`trace_stream`] lazily merges per-tenant Poisson arrival streams
//! (independent [`Pcg32::split_stream`] streams, optional diurnal ramp,
//! Zipf hot-key skew via [`skewed_qps`]) in O(tenants) memory, stamping
//! every event with a *virtual-time* microsecond timestamp
//! ([`multi_tenant_trace`] is its materialized form); [`replay`] feeds
//! the merged trace — slice or stream — through
//! [`GatewayHandle::submit_at`] in trace order, so the gateway's
//! admission decisions are a pure function of the trace — the property
//! the gateway determinism tests assert at 1/2/4 workers.
//!
//! Every request image is a pure function of `(seed, tenant, id)` via
//! [`request_image`] / [`tenant_request_image`], so a trace is bit-for-bit
//! reproducible regardless of client or worker interleaving.

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::mobile::engine::Fmap;
use crate::mobile::plan::StepDims;
use crate::rng::Pcg32;

use super::artifact::fnv1a64;
use super::error::ServeError;
use super::gateway::GatewayHandle;
use super::server::ServeHandle;

/// Load model for a single-plan run.
#[derive(Clone, Copy, Debug)]
pub enum LoadMode {
    /// `clients` synchronous closed-loop clients
    Closed { clients: usize },
    /// open-loop Poisson arrivals at `qps`
    Open { qps: f64 },
}

#[derive(Clone, Copy, Debug)]
pub struct LoadGenConfig {
    pub mode: LoadMode,
    /// total requests to issue
    pub requests: usize,
    /// trace seed: request `i`'s image is `request_image(dims, seed, i)`
    pub seed: u64,
}

/// Outcome of one generated request.
#[derive(Clone, Debug)]
pub struct RequestOutcome {
    /// trace index (== image id fed to [`request_image`])
    pub trace_id: u64,
    /// logits, when the request completed
    pub logits: Option<Vec<f32>>,
    /// set when admission control bounced the request
    pub rejected: bool,
}

/// Aggregate result of a load run.
#[derive(Clone, Debug)]
pub struct LoadReport {
    /// one entry per trace id, in trace order
    pub outcomes: Vec<RequestOutcome>,
    pub completed: u64,
    pub rejected: u64,
    pub wall_secs: f64,
    pub achieved_qps: f64,
}

/// The deterministic request trace: image `id` under `seed` for a plan
/// with input `dims`. Pure in `(dims, seed, id)`.
pub fn request_image(dims: StepDims, seed: u64, id: u64) -> Fmap {
    let mut rng = Pcg32::split_stream(seed, id);
    Fmap {
        c: dims.c,
        hw: dims.hw,
        data: (0..dims.elems()).map(|_| rng.uniform()).collect(),
    }
}

/// Per-tenant request stream: image `id` of `tenant` under `seed`. The
/// tenant name is folded into the stream seed, so tenants sharing a
/// model never share images.
pub fn tenant_request_image(
    dims: StepDims,
    seed: u64,
    tenant: &str,
    id: u64,
) -> Fmap {
    request_image(dims, seed ^ fnv1a64(tenant.as_bytes()), id)
}

/// Drive `handle` with the configured load; blocks until every issued
/// request resolved (response, rejection, or cancellation).
pub fn run(
    handle: &ServeHandle,
    dims: StepDims,
    cfg: &LoadGenConfig,
) -> LoadReport {
    let t0 = Instant::now();
    let mut outcomes: Vec<RequestOutcome> = match cfg.mode {
        LoadMode::Closed { clients } => {
            run_closed(handle, dims, cfg, clients.max(1))
        }
        LoadMode::Open { qps } => run_open(handle, dims, cfg, qps),
    };
    let wall_secs = t0.elapsed().as_secs_f64();
    outcomes.sort_by_key(|o| o.trace_id);
    let completed =
        outcomes.iter().filter(|o| o.logits.is_some()).count() as u64;
    let rejected = outcomes.iter().filter(|o| o.rejected).count() as u64;
    LoadReport {
        outcomes,
        completed,
        rejected,
        wall_secs,
        achieved_qps: if wall_secs > 0.0 {
            completed as f64 / wall_secs
        } else {
            0.0
        },
    }
}

fn run_closed(
    handle: &ServeHandle,
    dims: StepDims,
    cfg: &LoadGenConfig,
    clients: usize,
) -> Vec<RequestOutcome> {
    let results = Mutex::new(Vec::with_capacity(cfg.requests));
    std::thread::scope(|s| {
        for client in 0..clients {
            let results = &results;
            let handle = handle.clone();
            s.spawn(move || {
                // client k owns trace ids k, k+C, k+2C, ... — the id set
                // (and so the image set) is independent of timing
                let mut id = client as u64;
                while (id as usize) < cfg.requests {
                    let img = request_image(dims, cfg.seed, id);
                    let outcome = match handle.infer(img) {
                        Ok(resp) => RequestOutcome {
                            trace_id: id,
                            logits: Some(resp.logits),
                            rejected: false,
                        },
                        Err(e) => RequestOutcome {
                            trace_id: id,
                            logits: None,
                            rejected: matches!(e, ServeError::Rejected),
                        },
                    };
                    results.lock().unwrap().push(outcome);
                    id += clients as u64;
                }
            });
        }
    });
    results.into_inner().unwrap()
}

fn run_open(
    handle: &ServeHandle,
    dims: StepDims,
    cfg: &LoadGenConfig,
    qps: f64,
) -> Vec<RequestOutcome> {
    let qps = qps.max(1e-3);
    let mut gaps = Pcg32::split_stream(cfg.seed, u64::MAX);
    let mut pending = Vec::new();
    let mut outcomes = Vec::with_capacity(cfg.requests);
    let mut next_at = Instant::now();
    for id in 0..cfg.requests as u64 {
        let now = Instant::now();
        if next_at > now {
            std::thread::sleep(next_at - now);
        }
        let img = request_image(dims, cfg.seed, id);
        match handle.submit(img) {
            Ok(ticket) => pending.push((id, ticket)),
            Err(e) => outcomes.push(RequestOutcome {
                trace_id: id,
                logits: None,
                rejected: matches!(e, ServeError::Rejected),
            }),
        }
        let gap_secs = gaps.exponential(1.0 / qps as f32);
        next_at += Duration::from_secs_f64(gap_secs as f64);
    }
    for (id, ticket) in pending {
        outcomes.push(match ticket.wait() {
            Ok(resp) => RequestOutcome {
                trace_id: id,
                logits: Some(resp.logits),
                rejected: false,
            },
            Err(_) => RequestOutcome {
                trace_id: id,
                logits: None,
                rejected: false,
            },
        });
    }
    outcomes
}

// ---------------------------------------------------------------------------
// Multi-tenant traces
// ---------------------------------------------------------------------------

/// One tenant's offered load in a multi-tenant trace.
#[derive(Clone, Debug)]
pub struct TenantLoad {
    pub tenant: String,
    /// mean arrival rate in requests per *virtual* second
    pub qps: f64,
    /// events to draw for this tenant
    pub requests: usize,
}

impl TenantLoad {
    pub fn new(tenant: &str, qps: f64, requests: usize) -> Self {
        TenantLoad {
            tenant: tenant.to_string(),
            qps: qps.max(1e-3),
            requests,
        }
    }
}

/// Sinusoidal diurnal modulation of arrival rates: the instantaneous
/// rate is `qps · multiplier(vt)`, cycling between `floor · qps` (the
/// trough) and `qps` (the peak) once per `period_us` of virtual time.
#[derive(Clone, Copy, Debug)]
pub struct DiurnalRamp {
    pub period_us: u64,
    /// trough fraction of peak rate, in (0, 1]
    pub floor: f64,
}

impl DiurnalRamp {
    pub fn new(period_us: u64, floor: f64) -> Self {
        DiurnalRamp {
            period_us: period_us.max(1),
            floor: floor.clamp(1e-3, 1.0),
        }
    }

    /// Rate multiplier at virtual time `vt_us`, in `[floor, 1]`; starts
    /// at the trough (`vt = 0` is "night").
    pub fn multiplier(&self, vt_us: u64) -> f64 {
        let phase = (vt_us % self.period_us) as f64
            / self.period_us as f64
            * std::f64::consts::TAU;
        self.floor + (1.0 - self.floor) * 0.5 * (1.0 - phase.cos())
    }
}

/// Zipf-skewed split of `total` QPS across `n` tenants (exponent `s`;
/// `s = 0` is uniform). Hot-key skew for gateway traces: tenant 0 is the
/// hot model.
pub fn skewed_qps(total: f64, n: usize, s: f64) -> Vec<f64> {
    let n = n.max(1);
    let weights: Vec<f64> =
        (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(s)).collect();
    let norm: f64 = weights.iter().sum();
    weights.iter().map(|w| total * w / norm).collect()
}

/// One arrival in a merged multi-tenant trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    /// virtual-time arrival stamp, microseconds
    pub vt_us: u64,
    /// index into the [`TenantLoad`] slice the trace was drawn from
    pub tenant: usize,
    /// per-tenant request sequence number (feeds
    /// [`tenant_request_image`])
    pub id: u64,
}

/// One tenant's in-flight Poisson generator state inside a
/// [`TraceStream`].
struct TenantGen {
    rng: Pcg32,
    vt_us: u64,
    next_id: u64,
    remaining: u64,
    qps: f64,
}

impl TenantGen {
    /// Draw this tenant's next arrival, advancing its virtual clock.
    fn draw(&mut self, ti: usize, ramp: Option<DiurnalRamp>) -> Option<TraceEvent> {
        if self.remaining == 0 {
            return None;
        }
        // thinning-free modulation: scale the mean gap by the ramp at
        // the current virtual time
        let rate = match ramp {
            Some(r) => self.qps * r.multiplier(self.vt_us),
            None => self.qps,
        };
        let gap_secs =
            self.rng.exponential(1.0) as f64 / rate.max(1e-9);
        // strictly advancing stamps keep per-tenant virtual time
        // monotone for the admission bucket
        self.vt_us += ((gap_secs * 1e6).round() as u64).max(1);
        let ev = TraceEvent {
            vt_us: self.vt_us,
            tenant: ti,
            id: self.next_id,
        };
        self.next_id += 1;
        self.remaining -= 1;
        Some(ev)
    }
}

/// Lazy merged multi-tenant arrival stream: yields the exact
/// `(vt_us, tenant, id)`-ordered event sequence of
/// [`multi_tenant_trace`] without ever materializing it. Memory is
/// O(tenants) — one Poisson generator plus one heap slot per tenant — so
/// million-request traces stream in constant space.
///
/// The k-way merge is exact because each tenant's stream is strictly
/// `vt`-monotone (stamps advance by ≥ 1 µs per event): the heap's
/// smallest pending `(vt_us, tenant, id)` key is always the globally next
/// event of the fully-sorted trace.
pub struct TraceStream {
    gens: Vec<TenantGen>,
    ramp: Option<DiurnalRamp>,
    heap: BinaryHeap<Reverse<(u64, usize, u64)>>,
}

/// Open the lazy stream over every tenant's Poisson arrivals. Pure in
/// `(loads, ramp, seed)` — same per-tenant [`Pcg32::split_stream`]
/// streams as the materialized trace.
pub fn trace_stream(
    loads: &[TenantLoad],
    ramp: Option<DiurnalRamp>,
    seed: u64,
) -> TraceStream {
    let mut gens: Vec<TenantGen> = loads
        .iter()
        .enumerate()
        .map(|(ti, load)| TenantGen {
            rng: Pcg32::split_stream(seed, ti as u64),
            vt_us: 0,
            next_id: 0,
            remaining: load.requests as u64,
            qps: load.qps,
        })
        .collect();
    let mut heap = BinaryHeap::with_capacity(gens.len());
    for (ti, g) in gens.iter_mut().enumerate() {
        if let Some(ev) = g.draw(ti, ramp) {
            heap.push(Reverse((ev.vt_us, ev.tenant, ev.id)));
        }
    }
    TraceStream { gens, ramp, heap }
}

impl Iterator for TraceStream {
    type Item = TraceEvent;

    fn next(&mut self) -> Option<TraceEvent> {
        let Reverse((vt_us, tenant, id)) = self.heap.pop()?;
        if let Some(next) = self.gens[tenant].draw(tenant, self.ramp) {
            self.heap
                .push(Reverse((next.vt_us, next.tenant, next.id)));
        }
        Some(TraceEvent { vt_us, tenant, id })
    }
}

/// Draw every tenant's Poisson arrival stream (its own
/// [`Pcg32::split_stream`] stream, optionally diurnally modulated)
/// merged by `(vt_us, tenant, id)`. Pure in `(loads, ramp, seed)` — the
/// foundation of gateway replay determinism. Materializes
/// [`trace_stream`]; callers that never need the whole trace at once
/// (replay, counting) should iterate the stream instead.
pub fn multi_tenant_trace(
    loads: &[TenantLoad],
    ramp: Option<DiurnalRamp>,
    seed: u64,
) -> Vec<TraceEvent> {
    trace_stream(loads, ramp, seed).collect()
}

/// Outcome of one replayed trace event.
#[derive(Clone, Debug)]
pub struct GwOutcome {
    pub tenant: usize,
    pub trace_id: u64,
    pub vt_us: u64,
    pub logits: Option<Vec<f32>>,
    /// admission-control shed (deterministic)
    pub shed: bool,
    /// queue-full rejection (timing-dependent)
    pub rejected: bool,
    /// failed typed [`ServeError::WorkerLost`] — the request was the
    /// schedule-selected victim of a worker panic (deterministic under
    /// seeded chaos)
    pub lost: bool,
}

/// Per-tenant roll-up of a replayed trace.
#[derive(Clone, Debug)]
pub struct TenantCounts {
    pub tenant: String,
    pub issued: u64,
    pub completed: u64,
    pub shed: u64,
    pub rejected: u64,
    /// requests lost to worker panics (victims of the chaos schedule)
    pub lost: u64,
}

/// Aggregate result of a gateway trace replay.
#[derive(Clone, Debug)]
pub struct GatewayLoadReport {
    /// sorted by `(tenant, trace_id)` — directly comparable across runs
    pub outcomes: Vec<GwOutcome>,
    /// [`TenantLoad`] order
    pub per_tenant: Vec<TenantCounts>,
    pub completed: u64,
    pub shed: u64,
    pub rejected: u64,
    pub wall_secs: f64,
}

/// Replay a merged trace through [`GatewayHandle::submit_at`] in trace
/// order. `pace` scales virtual to wall time: `0` replays as fast as
/// possible (virtual time still drives admission — the deterministic
/// mode), `1` paces arrivals in real time, `2` at double speed, etc.
/// Blocks until every admitted request resolved.
///
/// `trace` is anything iterable over [`TraceEvent`]s — a materialized
/// `&[TraceEvent]` slice or a lazy [`TraceStream`] — so arbitrarily long
/// traces replay without being held in memory.
pub fn replay<I>(
    handle: &GatewayHandle,
    loads: &[TenantLoad],
    trace: I,
    seed: u64,
    pace: f64,
) -> Result<GatewayLoadReport, ServeError>
where
    I: IntoIterator,
    I::Item: std::borrow::Borrow<TraceEvent>,
{
    let t0 = Instant::now();
    let dims: Vec<StepDims> = loads
        .iter()
        .map(|l| handle.in_dims(&l.tenant))
        .collect::<Result<_, _>>()?;
    let mut pending = Vec::new();
    let mut outcomes = Vec::new();
    for ev in trace {
        let ev = *std::borrow::Borrow::borrow(&ev);
        if pace > 0.0 {
            let target = t0
                + Duration::from_micros(
                    (ev.vt_us as f64 / pace) as u64,
                );
            let now = Instant::now();
            if target > now {
                std::thread::sleep(target - now);
            }
        }
        let name = &loads[ev.tenant].tenant;
        let img =
            tenant_request_image(dims[ev.tenant], seed, name, ev.id);
        match handle.submit_at(name, img, ev.vt_us) {
            Ok(ticket) => pending.push((ev, ticket)),
            Err(ServeError::Shed { .. }) => outcomes.push(GwOutcome {
                tenant: ev.tenant,
                trace_id: ev.id,
                vt_us: ev.vt_us,
                logits: None,
                shed: true,
                rejected: false,
                lost: false,
            }),
            Err(ServeError::Rejected) => outcomes.push(GwOutcome {
                tenant: ev.tenant,
                trace_id: ev.id,
                vt_us: ev.vt_us,
                logits: None,
                shed: false,
                rejected: true,
                lost: false,
            }),
            Err(other) => return Err(other),
        }
    }
    for (ev, ticket) in pending {
        let (logits, lost) = match ticket.wait() {
            Ok(r) => (Some(r.logits), false),
            Err(ServeError::WorkerLost { .. }) => (None, true),
            Err(_) => (None, false),
        };
        outcomes.push(GwOutcome {
            tenant: ev.tenant,
            trace_id: ev.id,
            vt_us: ev.vt_us,
            logits,
            shed: false,
            rejected: false,
            lost,
        });
    }
    let wall_secs = t0.elapsed().as_secs_f64();
    outcomes.sort_by_key(|o| (o.tenant, o.trace_id));
    let per_tenant = loads
        .iter()
        .enumerate()
        .map(|(ti, l)| {
            let mine =
                outcomes.iter().filter(|o| o.tenant == ti);
            let mut c = TenantCounts {
                tenant: l.tenant.clone(),
                issued: 0,
                completed: 0,
                shed: 0,
                rejected: 0,
                lost: 0,
            };
            for o in mine {
                c.issued += 1;
                c.completed += o.logits.is_some() as u64;
                c.shed += o.shed as u64;
                c.rejected += o.rejected as u64;
                c.lost += o.lost as u64;
            }
            c
        })
        .collect::<Vec<_>>();
    Ok(GatewayLoadReport {
        completed: per_tenant.iter().map(|c| c.completed).sum(),
        shed: per_tenant.iter().map(|c| c.shed).sum(),
        rejected: per_tenant.iter().map(|c| c.rejected).sum(),
        outcomes,
        per_tenant,
        wall_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_images_are_pure_in_seed_and_id() {
        let dims = StepDims { c: 3, hw: 8 };
        let a = request_image(dims, 9, 4);
        let b = request_image(dims, 9, 4);
        assert_eq!(a.data, b.data);
        assert_eq!(a.data.len(), 3 * 8 * 8);
        let c = request_image(dims, 9, 5);
        assert_ne!(a.data, c.data, "distinct ids must differ");
        let d = request_image(dims, 10, 4);
        assert_ne!(a.data, d.data, "distinct seeds must differ");
    }

    #[test]
    fn tenant_images_are_pure_and_tenant_distinct() {
        let dims = StepDims { c: 3, hw: 8 };
        let a = tenant_request_image(dims, 9, "alice", 4);
        let b = tenant_request_image(dims, 9, "alice", 4);
        assert_eq!(a.data, b.data);
        let c = tenant_request_image(dims, 9, "bob", 4);
        assert_ne!(
            a.data, c.data,
            "tenants sharing a model must not share images"
        );
    }

    #[test]
    fn trace_is_deterministic_sorted_and_complete() {
        let loads = vec![
            TenantLoad::new("hot", 100.0, 40),
            TenantLoad::new("warm", 10.0, 20),
        ];
        let ramp = Some(DiurnalRamp::new(2_000_000, 0.25));
        let t1 = multi_tenant_trace(&loads, ramp, 42);
        let t2 = multi_tenant_trace(&loads, ramp, 42);
        assert_eq!(t1, t2, "same seed => identical trace");
        assert_eq!(t1.len(), 60);
        assert!(t1.windows(2).all(|w| (
            w[0].vt_us,
            w[0].tenant,
            w[0].id
        ) <= (w[1].vt_us, w[1].tenant, w[1].id)));
        // per-tenant ids are each a complete 0..n sequence
        for (ti, load) in loads.iter().enumerate() {
            let mut ids: Vec<u64> = t1
                .iter()
                .filter(|e| e.tenant == ti)
                .map(|e| e.id)
                .collect();
            ids.sort_unstable();
            let want: Vec<u64> = (0..load.requests as u64).collect();
            assert_eq!(ids, want);
        }
        let t3 = multi_tenant_trace(&loads, ramp, 43);
        assert_ne!(t1, t3, "distinct seeds must differ");
        // the hot tenant's arrivals are denser (larger qps => smaller
        // mean gap => earlier last stamp for equal counts scaled)
        let last_hot = t1
            .iter()
            .filter(|e| e.tenant == 0)
            .map(|e| e.vt_us)
            .max()
            .unwrap();
        let last_warm = t1
            .iter()
            .filter(|e| e.tenant == 1)
            .map(|e| e.vt_us)
            .max()
            .unwrap();
        // 40 reqs at ~100qps ≪ 20 reqs at ~10qps in virtual time
        assert!(last_hot < last_warm);
    }

    #[test]
    fn trace_stream_matches_materialize_then_sort() {
        let loads = vec![
            TenantLoad::new("hot", 120.0, 50),
            TenantLoad::new("warm", 15.0, 25),
            TenantLoad::new("cold", 2.0, 10),
        ];
        for ramp in [None, Some(DiurnalRamp::new(1_500_000, 0.3))] {
            // reference: draw each tenant independently, then sort —
            // the pre-stream implementation of multi_tenant_trace
            let mut want = Vec::new();
            for (ti, load) in loads.iter().enumerate() {
                let mut rng = Pcg32::split_stream(7, ti as u64);
                let mut vt_us = 0u64;
                for id in 0..load.requests as u64 {
                    let rate = match ramp {
                        Some(r) => load.qps * r.multiplier(vt_us),
                        None => load.qps,
                    };
                    let gap =
                        rng.exponential(1.0) as f64 / rate.max(1e-9);
                    vt_us += ((gap * 1e6).round() as u64).max(1);
                    want.push(TraceEvent {
                        vt_us,
                        tenant: ti,
                        id,
                    });
                }
            }
            want.sort_by_key(|e| (e.vt_us, e.tenant, e.id));
            let got: Vec<TraceEvent> =
                trace_stream(&loads, ramp, 7).collect();
            assert_eq!(got, want, "lazy merge must equal sort");
            assert_eq!(got, multi_tenant_trace(&loads, ramp, 7));
        }
    }

    #[test]
    fn diurnal_ramp_cycles_between_floor_and_peak() {
        let r = DiurnalRamp::new(1_000_000, 0.2);
        assert!((r.multiplier(0) - 0.2).abs() < 1e-9, "trough at 0");
        assert!(
            (r.multiplier(500_000) - 1.0).abs() < 1e-9,
            "peak at half period"
        );
        assert!(
            (r.multiplier(1_000_000) - 0.2).abs() < 1e-9,
            "periodic"
        );
        for vt in (0..2_000_000).step_by(50_000) {
            let m = r.multiplier(vt);
            assert!((0.2..=1.0).contains(&m));
        }
    }

    #[test]
    fn skewed_qps_is_zipf_and_conserves_total() {
        let q = skewed_qps(100.0, 4, 1.0);
        assert_eq!(q.len(), 4);
        assert!((q.iter().sum::<f64>() - 100.0).abs() < 1e-9);
        assert!(q[0] > q[1] && q[1] > q[2] && q[2] > q[3]);
        // harmonic weights: q0/q1 == 2
        assert!((q[0] / q[1] - 2.0).abs() < 1e-9);
        let flat = skewed_qps(100.0, 4, 0.0);
        assert!(flat.iter().all(|&x| (x - 25.0).abs() < 1e-9));
    }
}
