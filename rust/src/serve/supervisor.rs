//! Worker supervision: catch panics at the dispatch boundary, fail the
//! poisoned request typed, requeue the innocent batch-mates, restart.
//!
//! Both worker loops (single-model server and multi-tenant gateway)
//! follow the same contract:
//!
//! 1. Pop a batch; split it into metas (identity + response channel)
//!    and images **outside** the unwind boundary, so a panic can never
//!    take the response channels down with it.
//! 2. Run the executor (plus any chaos hooks) inside
//!    [`dispatch`](dispatch)'s `catch_unwind`.
//! 3. On unwind, hand the batch to [`recover_poisoned`]: exactly one
//!    victim — the lowest poisoned request id under the fault plan, or
//!    the lowest id overall for an organic panic — is failed with a
//!    typed [`ServeError::WorkerLost`]; everyone else is returned for
//!    requeue. The worker then drops its lazy executors (their arenas
//!    are mid-batch garbage after an unwind) and re-enters the loop.
//!
//! The supervisor *is* the outer worker loop: dispatch runs in a
//! sacrificial unwind scope, and recovery rebuilds per-worker state
//! exactly as a kill-and-respawn would — without losing the thread
//! slot, so `shutdown`'s joins and the drain guarantee are unaffected.
//! Restarts are counted in [`ServeReport`](super::stats::ServeReport).
//!
//! One victim per unwind is what keeps chaos deterministic: batch
//! composition is timing-dependent, but "which requests end up
//! `WorkerLost`" must not be. Failing only the schedule-selected
//! victim and requeueing the rest makes the outcome of every request a
//! pure function of its id, at any worker count.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::time::Instant;

use super::error::ServeError;
use super::faults::{FaultSite, Faults};
use super::server::ServeResponse;
use super::stats::ServeStats;

/// Response channel payload: a completed response or a typed error.
/// A dropped sender still maps to `Canceled` on the ticket side, so
/// the channel can never hang a waiting client.
pub(crate) type RespTx = mpsc::Sender<Result<ServeResponse, ServeError>>;

/// Identity + response channel of one in-flight request, held outside
/// the unwind boundary while its image is dispatched.
pub(crate) struct Meta {
    pub id: u64,
    pub enqueued: Instant,
    pub tx: RespTx,
}

/// Run one dispatch attempt inside `catch_unwind`, mapping a panic
/// payload to its message. The closure borrows executors and images;
/// `AssertUnwindSafe` is justified because the caller rebuilds every
/// touched executor after an `Err` before reusing it.
pub(crate) fn dispatch<R>(
    f: impl FnOnce() -> R,
) -> Result<R, String> {
    catch_unwind(AssertUnwindSafe(f)).map_err(|payload| {
        if let Some(s) = payload.downcast_ref::<&str>() {
            (*s).to_string()
        } else if let Some(s) = payload.downcast_ref::<String>() {
            s.clone()
        } else {
            "worker panicked (non-string payload)".to_string()
        }
    })
}

/// Triage a batch whose dispatch unwound. Exactly one victim is failed
/// with [`ServeError::WorkerLost`] (and counted as a worker loss + one
/// restart); the rest come back paired with their images for requeue.
pub(crate) fn recover_poisoned<T>(
    metas: Vec<Meta>,
    imgs: Vec<T>,
    faults: &Faults,
    stats: &ServeStats,
) -> Vec<(Meta, T)> {
    debug_assert_eq!(metas.len(), imgs.len());
    let poisoned = |id: u64| match faults {
        Some(p) => p.fires(FaultSite::WorkerPanic, id),
        None => false,
    };
    // the victim is the lowest *poisoned* id so the loss set is the
    // fault schedule's, independent of batch composition; an organic
    // panic (no schedule match) consumes the lowest id, which bounds
    // retries: every unwind shrinks the batch by one
    let victim = metas
        .iter()
        .enumerate()
        .filter(|(_, m)| poisoned(m.id))
        .map(|(i, m)| (i, m.id))
        .min_by_key(|&(_, id)| id)
        .or_else(|| {
            metas
                .iter()
                .enumerate()
                .map(|(i, m)| (i, m.id))
                .min_by_key(|&(_, id)| id)
        });
    let mut survivors = Vec::with_capacity(metas.len());
    if let Some((vi, vid)) = victim {
        if poisoned(vid) {
            if let Some(p) = faults {
                p.record(FaultSite::WorkerPanic);
            }
        }
        stats.batch_dispatched(1);
        stats.worker_lost(1);
        stats.restart();
        for (i, (meta, img)) in
            metas.into_iter().zip(imgs).enumerate()
        {
            if i == vi {
                // typed, never a hung or silently dropped channel; a
                // gone client (recv side dropped) is fine to ignore
                let _ = meta
                    .tx
                    .send(Err(ServeError::WorkerLost { id: meta.id }));
            } else {
                survivors.push((meta, img));
            }
        }
    }
    survivors
}

/// Shutdown-drain helper: a request still queued after every worker
/// has exited gets a typed `Canceled`, never a dropped channel.
pub(crate) fn fail_canceled(id: u64, tx: &RespTx) {
    let _ = tx.send(Err(ServeError::Canceled { id }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::faults::FaultPlan;
    use std::sync::Arc;

    fn meta(
        id: u64,
    ) -> (Meta, mpsc::Receiver<Result<ServeResponse, ServeError>>)
    {
        let (tx, rx) = mpsc::channel();
        (
            Meta {
                id,
                enqueued: Instant::now(),
                tx,
            },
            rx,
        )
    }

    #[test]
    fn dispatch_catches_and_stringifies_panics() {
        assert_eq!(dispatch(|| 7).unwrap(), 7);
        let err = dispatch(|| panic!("kernel exploded")).unwrap_err();
        assert!(err.contains("kernel exploded"), "{err}");
    }

    #[test]
    fn organic_panic_consumes_lowest_id_only() {
        let (m3, rx3) = meta(3);
        let (m1, rx1) = meta(1);
        let (m2, rx2) = meta(2);
        let stats = ServeStats::new();
        let survivors = recover_poisoned(
            vec![m3, m1, m2],
            vec![30u8, 10, 20],
            &None,
            &stats,
        );
        // id 1 is the victim; 3 and 2 survive with their images
        match rx1.recv().unwrap() {
            Err(ServeError::WorkerLost { id: 1 }) => {}
            other => panic!("expected WorkerLost(1), got {other:?}"),
        }
        let ids: Vec<(u64, u8)> =
            survivors.iter().map(|(m, i)| (m.id, *i)).collect();
        assert_eq!(ids, vec![(3, 30), (2, 20)]);
        // survivors' channels are still open (senders alive)
        assert!(rx3.try_recv().is_err());
        assert!(rx2.try_recv().is_err());
        let r = stats.report(0.0);
        assert_eq!((r.worker_lost, r.restarts), (1, 1));
    }

    #[test]
    fn poisoned_victim_wins_over_lower_innocent_ids() {
        // schedule poisons every id; victim = lowest poisoned = lowest
        let plan = Arc::new(
            FaultPlan::new(5).rate(FaultSite::WorkerPanic, 1000),
        );
        let faults: Faults = Some(plan.clone());
        let (m9, rx9) = meta(9);
        let (m4, _rx4) = meta(4);
        let stats = ServeStats::new();
        let survivors = recover_poisoned(
            vec![m9, m4],
            vec![(), ()],
            &faults,
            &stats,
        );
        assert_eq!(survivors.len(), 1);
        assert_eq!(survivors[0].0.id, 9);
        assert!(rx9.try_recv().is_err(), "9 must not be failed");
        assert_eq!(plan.injected()[0].1, 1, "injection recorded once");
    }

    #[test]
    fn fail_canceled_delivers_typed_error() {
        let (m, rx) = meta(12);
        fail_canceled(m.id, &m.tx);
        match rx.recv().unwrap() {
            Err(ServeError::Canceled { id: 12 }) => {}
            other => panic!("expected Canceled(12), got {other:?}"),
        }
    }
}
