//! Serving metrics + the in-tree bench harness.
//!
//! [`ServeStats`] is the server's shared metrics sink: every worker folds
//! per-response latencies (end-to-end and queue wait) and per-batch sizes
//! into it, and [`ServeStats::report`] snapshots a [`ServeReport`] with
//! nearest-rank p50/p95/p99 percentiles, a batch-size histogram, and
//! throughput — the numbers `repro serve` and `bench_serve` print.
//!
//! The module also hosts the criterion-replacement bench helpers
//! ([`bench`], [`section`], [`BenchResult`]) shared by all
//! `rust/benches/*.rs`; they moved here from the old top-level
//! `bench_harness` module when the serving tier became their primary
//! consumer (criterion is unavailable offline).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::report::Table;
use crate::rng::Pcg32;
use crate::util::json::Json;

use super::lock_clean;

/// Latency samples kept resident per series; beyond this the recorder
/// switches to uniform reservoir sampling, so a long-running server's
/// memory and `report()` cost stay bounded no matter how many requests
/// it has served.
pub const SAMPLE_CAP: usize = 1 << 16;

fn reservoir(samples: &mut Vec<u64>, rng: &mut Pcg32, seen: u64, v: u64) {
    if samples.len() < SAMPLE_CAP {
        samples.push(v);
    } else {
        // classic Algorithm R: keep v with probability CAP/seen
        let j = (rng.next_u64() % seen) as usize;
        if j < SAMPLE_CAP {
            samples[j] = v;
        }
    }
}

/// Percentile summary over a set of microsecond samples (nearest-rank).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub n: usize,
    pub mean_us: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl LatencySummary {
    /// Nearest-rank percentiles over `samples` (consumed; order-free).
    pub fn from_samples(mut samples: Vec<u64>) -> Self {
        if samples.is_empty() {
            return LatencySummary::default();
        }
        samples.sort_unstable();
        let n = samples.len();
        let mean_us =
            samples.iter().map(|&s| s as f64).sum::<f64>() / n as f64;
        let rank = |q: f64| -> u64 {
            let idx = ((q * n as f64).ceil() as usize)
                .saturating_sub(1)
                .min(n - 1);
            samples[idx]
        };
        LatencySummary {
            n,
            mean_us,
            p50_us: rank(0.50),
            p95_us: rank(0.95),
            p99_us: rank(0.99),
            max_us: samples[n - 1],
        }
    }
}

struct StatsInner {
    total_us: Vec<u64>,
    queue_us: Vec<u64>,
    /// reservoir positions; fixed seed — the *sampled* latency sets are
    /// scheduling-dependent anyway and are excluded from the
    /// deterministic counters
    rng: Pcg32,
    batch_hist: BTreeMap<usize, u64>,
    submitted: u64,
    completed: u64,
    rejected: u64,
    errors: u64,
    shed: u64,
    shed_deadline: u64,
    worker_lost: u64,
    restarts: u64,
}

impl Default for StatsInner {
    fn default() -> Self {
        StatsInner {
            total_us: Vec::new(),
            queue_us: Vec::new(),
            rng: Pcg32::seeded(0x57A7_5EED),
            batch_hist: BTreeMap::new(),
            submitted: 0,
            completed: 0,
            rejected: 0,
            errors: 0,
            shed: 0,
            shed_deadline: 0,
            worker_lost: 0,
            restarts: 0,
        }
    }
}

/// Shared, thread-safe serving metrics sink (one per [`crate::serve::server::Server`]).
#[derive(Default)]
pub struct ServeStats {
    inner: Mutex<StatsInner>,
}

impl ServeStats {
    pub fn new() -> Self {
        ServeStats::default()
    }

    /// A request is about to enter the queue. Counted *before* the push,
    /// so a live snapshot can never observe `completed > submitted`;
    /// refused pushes take it back via [`ServeStats::reject`] /
    /// [`ServeStats::unsubmit`].
    pub fn submit(&self) {
        lock_clean(&self.inner).submitted += 1;
    }

    /// A pre-counted request bounced off the full queue (admission
    /// control): moves it from `submitted` to `rejected`.
    pub fn reject(&self) {
        let mut g = lock_clean(&self.inner);
        g.submitted -= 1;
        g.rejected += 1;
    }

    /// A pre-counted request was refused for a non-backpressure reason
    /// (server shutting down): takes the submit back without counting a
    /// rejection.
    pub fn unsubmit(&self) {
        lock_clean(&self.inner).submitted -= 1;
    }

    /// A whole batch failed to execute (its `n` requests get no response).
    pub fn error_batch(&self, n: usize) {
        lock_clean(&self.inner).errors += n as u64;
    }

    /// Admission control refused the request before it was submitted
    /// (per-tenant token budget exhausted). Deterministic under
    /// virtual-time replay, so it lands in the deterministic counters —
    /// unlike [`ServeStats::reject`], which depends on physical queue
    /// occupancy.
    pub fn shed(&self) {
        lock_clean(&self.inner).shed += 1;
    }

    /// An *admitted* request was dropped at dispatch because its deadline
    /// had already passed (shed-on-overload). Wall-clock dependent, so it
    /// is excluded from the deterministic counters.
    pub fn shed_deadline(&self) {
        lock_clean(&self.inner).shed_deadline += 1;
    }

    /// `n` in-flight requests were lost to a worker panic and failed
    /// with a typed `WorkerLost`. Under seeded chaos the loss set is a
    /// pure function of request ids, so this counter *is* in the
    /// deterministic set.
    pub fn worker_lost(&self, n: usize) {
        lock_clean(&self.inner).worker_lost += n as u64;
    }

    /// The supervisor recovered from one worker panic (executors were
    /// rebuilt and the worker re-entered its loop).
    pub fn restart(&self) {
        lock_clean(&self.inner).restarts += 1;
    }

    /// One response completed: end-to-end and queue-wait micros
    /// (reservoir-sampled past [`SAMPLE_CAP`]).
    pub fn complete(&self, total_us: u64, queue_us: u64) {
        let mut g = lock_clean(&self.inner);
        g.completed += 1;
        let seen = g.completed;
        let inner = &mut *g;
        reservoir(&mut inner.total_us, &mut inner.rng, seen, total_us);
        reservoir(&mut inner.queue_us, &mut inner.rng, seen, queue_us);
    }

    /// One micro-batch of `size` requests was dispatched.
    pub fn batch_dispatched(&self, size: usize) {
        let mut g = lock_clean(&self.inner);
        *g.batch_hist.entry(size).or_insert(0) += 1;
    }

    /// Snapshot everything into a report; `elapsed_secs` is the serving
    /// window the throughput is computed over.
    pub fn report(&self, elapsed_secs: f64) -> ServeReport {
        let g = lock_clean(&self.inner);
        let batch_hist: Vec<(usize, u64)> =
            g.batch_hist.iter().map(|(&s, &c)| (s, c)).collect();
        let batches: u64 = batch_hist.iter().map(|&(_, c)| c).sum();
        let batched_reqs: u64 =
            batch_hist.iter().map(|&(s, c)| s as u64 * c).sum();
        ServeReport {
            submitted: g.submitted,
            completed: g.completed,
            rejected: g.rejected,
            errors: g.errors,
            shed: g.shed,
            shed_deadline: g.shed_deadline,
            worker_lost: g.worker_lost,
            restarts: g.restarts,
            elapsed_secs,
            throughput_rps: if elapsed_secs > 0.0 {
                g.completed as f64 / elapsed_secs
            } else {
                0.0
            },
            latency: LatencySummary::from_samples(g.total_us.clone()),
            queue: LatencySummary::from_samples(g.queue_us.clone()),
            batch_hist,
            mean_batch: if batches > 0 {
                batched_reqs as f64 / batches as f64
            } else {
                0.0
            },
        }
    }
}

/// Snapshot of one serving window: counters, latency percentiles, and the
/// batch-size histogram.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub errors: u64,
    /// admission-control sheds (virtual-time token bucket; deterministic)
    pub shed: u64,
    /// deadline sheds of already-admitted requests (wall-clock dependent)
    pub shed_deadline: u64,
    /// in-flight requests failed typed after a worker panic; under
    /// seeded chaos a pure function of request ids (deterministic)
    pub worker_lost: u64,
    /// supervisor recoveries: one per worker panic, executors rebuilt
    pub restarts: u64,
    pub elapsed_secs: f64,
    pub throughput_rps: f64,
    /// end-to-end latency (submit -> response)
    pub latency: LatencySummary,
    /// queue wait (submit -> batch formation)
    pub queue: LatencySummary,
    /// (batch size, dispatch count)
    pub batch_hist: Vec<(usize, u64)>,
    pub mean_batch: f64,
}

impl ServeReport {
    /// Requests dispatched through the batcher (must equal `completed +
    /// errors + worker_lost` once the server drained).
    pub fn dispatched(&self) -> u64 {
        self.batch_hist.iter().map(|&(s, c)| s as u64 * c).sum()
    }

    /// The timing-free part of the report: bit-comparable across runs and
    /// worker counts (the serving determinism tests assert on this).
    /// Admission `shed` is included — it is a pure function of the trace
    /// under virtual-time replay; `shed_deadline` is not (wall clock).
    /// `worker_lost` and `restarts` are included because the chaos
    /// schedule selects victims by request id, never by batch or timing.
    pub fn deterministic_counters(
        &self,
    ) -> (u64, u64, u64, u64, u64, u64, u64, u64) {
        (
            self.submitted,
            self.completed,
            self.rejected,
            self.errors,
            self.shed,
            self.dispatched(),
            self.worker_lost,
            self.restarts,
        )
    }

    /// Render the per-model serving summary as a table.
    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &[
                "completed", "rejected", "shed", "errors", "lost",
                "restarts", "rps", "mean batch", "p50", "p95", "p99",
                "max",
            ],
        );
        t.row(&[
            format!("{}", self.completed),
            format!("{}", self.rejected),
            format!("{}", self.shed + self.shed_deadline),
            format!("{}", self.errors),
            format!("{}", self.worker_lost),
            format!("{}", self.restarts),
            format!("{:.1}", self.throughput_rps),
            format!("{:.2}", self.mean_batch),
            format!("{} us", self.latency.p50_us),
            format!("{} us", self.latency.p95_us),
            format!("{} us", self.latency.p99_us),
            format!("{} us", self.latency.max_us),
        ]);
        t
    }

    /// Render the batch-size histogram as a table.
    pub fn batch_table(&self, title: &str) -> Table {
        let mut t = Table::new(title, &["batch size", "dispatches"]);
        for &(size, count) in &self.batch_hist {
            t.row(&[format!("{size}"), format!("{count}")]);
        }
        t
    }
}

// ---------------------------------------------------------------------------
// Bench harness (criterion is unavailable offline)
// ---------------------------------------------------------------------------

/// Mean/median ± stddev of one benched closure, in a stable,
/// grep-friendly shape.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub mean_ms: f64,
    /// median over the timed repetitions — the steady-state number the
    /// speedup claims in `BENCH_*.json` are computed from (robust to a
    /// single preempted rep in a way the mean is not)
    pub median_ms: f64,
    pub std_ms: f64,
    pub reps: usize,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:44} {:>10.4} ms ± {:>8.4} (n={})",
            self.name, self.median_ms, self.std_ms, self.reps
        );
    }
}

/// Time `f` for `reps` repetitions after `warmup` calls.
pub fn bench(
    name: &str,
    warmup: usize,
    reps: usize,
    mut f: impl FnMut(),
) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    let mean = samples.iter().sum::<f64>() / reps as f64;
    let var = samples.iter().map(|s| (s - mean).powi(2)).sum::<f64>()
        / reps as f64;
    let mut sorted = samples.clone();
    sorted.sort_by(f64::total_cmp);
    let median = if sorted.len() % 2 == 1 {
        sorted[sorted.len() / 2]
    } else {
        (sorted[sorted.len() / 2 - 1] + sorted[sorted.len() / 2]) / 2.0
    };
    let r = BenchResult {
        name: name.into(),
        mean_ms: mean,
        median_ms: median,
        std_ms: var.sqrt(),
        reps,
    };
    r.print();
    r
}

/// Section header for grouping bench output.
pub fn section(title: &str) {
    println!("\n### {title}");
}

/// Machine-readable bench recorder behind the `BENCH_*.json` files the
/// CI uploads as workflow artifacts: every [`bench`] run through
/// [`BenchLog::bench`] is kept, named scalar metrics (speedups, scaling
/// ratios) land next to them, and [`BenchLog::write`] emits one JSON
/// document stamped with an environment fingerprint so numbers from
/// different machines are never compared blindly.
pub struct BenchLog {
    name: String,
    results: Vec<BenchResult>,
    metrics: Vec<(String, f64)>,
}

impl BenchLog {
    pub fn new(name: &str) -> Self {
        BenchLog {
            name: name.into(),
            results: Vec::new(),
            metrics: Vec::new(),
        }
    }

    /// Run [`bench`] and record its result.
    pub fn bench(
        &mut self,
        name: &str,
        warmup: usize,
        reps: usize,
        f: impl FnMut(),
    ) -> BenchResult {
        let r = bench(name, warmup, reps, f);
        self.results.push(r.clone());
        r
    }

    /// Record an already-run result (e.g. one timed by hand).
    pub fn push(&mut self, r: BenchResult) {
        self.results.push(r);
    }

    /// Record a named scalar (speedup, ratio, throughput).
    pub fn metric(&mut self, name: &str, value: f64) {
        self.metrics.push((name.into(), value));
    }

    /// Median of a recorded result by name (for speedup math on top of
    /// already-benched entries).
    pub fn median_of(&self, name: &str) -> Option<f64> {
        self.results
            .iter()
            .find(|r| r.name == name)
            .map(|r| r.median_ms)
    }

    pub fn to_json(&self) -> Json {
        let mut results = Vec::with_capacity(self.results.len());
        for r in &self.results {
            let mut o = BTreeMap::new();
            o.insert("name".into(), Json::Str(r.name.clone()));
            o.insert("mean_ms".into(), Json::Num(r.mean_ms));
            o.insert("median_ms".into(), Json::Num(r.median_ms));
            o.insert("std_ms".into(), Json::Num(r.std_ms));
            o.insert("reps".into(), Json::Num(r.reps as f64));
            results.push(Json::Obj(o));
        }
        let mut metrics = BTreeMap::new();
        for (k, v) in &self.metrics {
            metrics.insert(k.clone(), Json::Num(*v));
        }
        let mut doc = BTreeMap::new();
        doc.insert("bench".into(), Json::Str(self.name.clone()));
        doc.insert("env".into(), env_fingerprint());
        doc.insert("results".into(), Json::Arr(results));
        doc.insert("metrics".into(), Json::Obj(metrics));
        Json::Obj(doc)
    }

    /// Write the log to `path` and print where it went.
    pub fn write(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        println!("bench log -> {}", path.display());
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Bench regression diffing (`repro bench diff`)
// ---------------------------------------------------------------------------

/// One series (timed result or scalar metric) present in both bench
/// logs being compared.
#[derive(Clone, Debug)]
pub struct BenchDelta {
    pub name: String,
    pub base: f64,
    pub cur: f64,
    /// signed percent change relative to `base` (positive = `cur` is
    /// larger)
    pub change_pct: f64,
    /// direction of goodness for this series (timings/bytes/ratios
    /// shrink, speedups/throughput grow)
    pub lower_is_better: bool,
    /// worsened beyond the threshold in this series' bad direction
    pub regressed: bool,
}

/// Outcome of comparing two `BENCH_*.json` documents
/// ([`diff_bench_logs`]).
#[derive(Clone, Debug)]
pub struct BenchDiff {
    /// series present in both logs, in name order
    pub rows: Vec<BenchDelta>,
    /// series only in the baseline (informational, never a regression)
    pub only_base: Vec<String>,
    /// series only in the current log (new benches are not regressions)
    pub only_cur: Vec<String>,
    pub threshold_pct: f64,
}

impl BenchDiff {
    pub fn regressions(&self) -> Vec<&BenchDelta> {
        self.rows.iter().filter(|r| r.regressed).collect()
    }

    /// One row per compared series; regressions flagged in the verdict
    /// column.
    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &["series", "base", "current", "change", "verdict"],
        );
        for r in &self.rows {
            t.row(&[
                r.name.clone(),
                format!("{:.4}", r.base),
                format!("{:.4}", r.cur),
                format!("{:+.1}%", r.change_pct),
                if r.regressed {
                    "REGRESSED".into()
                } else {
                    "ok".into()
                },
            ]);
        }
        t
    }
}

/// Whether a scalar metric improves by shrinking. Timings, footprints,
/// and compression ratios shrink; speedups and throughput grow. Raw
/// membership-inference leakage series (`mia_*` in `BENCH_privacy.json`)
/// shrink — less measured attack advantage is better — while the derived
/// `privacy_gain_*` series keep the grow-is-better default.
fn metric_lower_is_better(name: &str) -> bool {
    name.starts_with("mia_")
        || ["ms", "us", "bytes", "ratio", "latency"]
            .iter()
            .any(|k| name.contains(k))
}

/// Pull the comparable series out of one bench-log document: every
/// result's `median_ms` (lower is better) plus every named metric.
fn bench_series(doc: &Json) -> Result<BTreeMap<String, (f64, bool)>> {
    let mut out = BTreeMap::new();
    for r in doc.get("results")?.as_arr()? {
        let name = r.get("name")?.as_str()?;
        let median = r.get("median_ms")?.as_f64()?;
        out.insert(format!("{name} [median_ms]"), (median, true));
    }
    for (name, v) in doc.get("metrics")?.as_obj()? {
        out.insert(
            name.clone(),
            (v.as_f64()?, metric_lower_is_better(name)),
        );
    }
    Ok(out)
}

/// Compare two bench-log documents (the `BENCH_*.json` shape written by
/// [`BenchLog::write`]). A series regresses when it worsens by more
/// than `threshold_pct` percent in its bad direction — slower for
/// timings, smaller for speedups. Series present in only one document
/// are reported but never count as regressions, so adding or retiring
/// a bench does not fail the diff. Bench logs from different
/// machines/build modes are legitimate inputs — the caller decides
/// whether the env fingerprints make the comparison meaningful.
pub fn diff_bench_logs(
    base: &Json,
    cur: &Json,
    threshold_pct: f64,
) -> Result<BenchDiff> {
    let base = bench_series(base).context("baseline bench log")?;
    let cur = bench_series(cur).context("current bench log")?;
    let threshold_pct = threshold_pct.max(0.0);
    let mut rows = Vec::new();
    for (name, &(b, lower)) in &base {
        let Some(&(c, _)) = cur.get(name) else { continue };
        // a zero/negative baseline has no meaningful percent change;
        // report it as unchanged rather than dividing by zero
        let change_pct = if b.abs() > f64::EPSILON {
            (c - b) / b.abs() * 100.0
        } else {
            0.0
        };
        let worsened_pct =
            if lower { change_pct } else { -change_pct };
        rows.push(BenchDelta {
            name: name.clone(),
            base: b,
            cur: c,
            change_pct,
            lower_is_better: lower,
            regressed: worsened_pct > threshold_pct,
        });
    }
    let only_base = base
        .keys()
        .filter(|k| !cur.contains_key(*k))
        .cloned()
        .collect();
    let only_cur = cur
        .keys()
        .filter(|k| !base.contains_key(*k))
        .cloned()
        .collect();
    Ok(BenchDiff {
        rows,
        only_base,
        only_cur,
        threshold_pct,
    })
}

/// The machine/build context a bench number is only valid within.
fn env_fingerprint() -> Json {
    let mut o = BTreeMap::new();
    o.insert("os".into(), Json::Str(std::env::consts::OS.into()));
    o.insert("arch".into(), Json::Str(std::env::consts::ARCH.into()));
    o.insert(
        "family".into(),
        Json::Str(std::env::consts::FAMILY.into()),
    );
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    o.insert("hw_threads".into(), Json::Num(threads as f64));
    o.insert(
        "crate_version".into(),
        Json::Str(env!("CARGO_PKG_VERSION").into()),
    );
    o.insert(
        "debug_assertions".into(),
        Json::Bool(cfg!(debug_assertions)),
    );
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("sleep-free", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(r.mean_ms >= 0.0);
        assert!(r.std_ms >= 0.0);
        assert_eq!(r.reps, 5);
    }

    #[test]
    fn bench_log_round_trips_through_json() {
        let mut log = BenchLog::new("unit");
        log.bench("warm-noop", 0, 4, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        log.push(BenchResult {
            name: "handmade".into(),
            mean_ms: 2.0,
            median_ms: 1.5,
            std_ms: 0.1,
            reps: 3,
        });
        log.metric("speedup", 1.75);
        assert_eq!(log.median_of("handmade"), Some(1.5));
        assert_eq!(log.median_of("missing"), None);
        let doc = Json::parse(&log.to_json().to_string()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "unit");
        let env = doc.get("env").unwrap();
        assert!(env.get("hw_threads").unwrap().as_usize().unwrap() >= 1);
        assert!(!env.get("os").unwrap().as_str().unwrap().is_empty());
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[1].get("median_ms").unwrap().as_f64().unwrap(),
            1.5
        );
        assert_eq!(
            doc.get("metrics")
                .unwrap()
                .get("speedup")
                .unwrap()
                .as_f64()
                .unwrap(),
            1.75
        );
    }

    fn log_with(results: &[(&str, f64)], metrics: &[(&str, f64)]) -> Json {
        let mut log = BenchLog::new("unit");
        for &(name, median) in results {
            log.push(BenchResult {
                name: name.into(),
                mean_ms: median,
                median_ms: median,
                std_ms: 0.0,
                reps: 1,
            });
        }
        for &(name, v) in metrics {
            log.metric(name, v);
        }
        Json::parse(&log.to_json().to_string()).unwrap()
    }

    #[test]
    fn bench_diff_flags_directional_regressions() {
        let base = log_with(
            &[("conv", 10.0), ("retired", 5.0)],
            &[("speedup_4t", 3.0), ("payload_ratio_i8", 0.30)],
        );
        let cur = log_with(
            &[("conv", 12.0), ("fresh", 1.0)],
            &[("speedup_4t", 2.0), ("payload_ratio_i8", 0.29)],
        );
        let d = diff_bench_logs(&base, &cur, 5.0).unwrap();
        // conv slowed 20% (> 5%): regression. speedup fell 33%: a
        // higher-is-better metric regresses by shrinking. the ratio
        // shrank: improvement for a lower-is-better metric.
        let names: Vec<&str> = d
            .regressions()
            .iter()
            .map(|r| r.name.as_str())
            .collect();
        assert_eq!(names, ["conv [median_ms]", "speedup_4t"]);
        let ratio = d
            .rows
            .iter()
            .find(|r| r.name == "payload_ratio_i8")
            .unwrap();
        assert!(ratio.lower_is_better && !ratio.regressed);
        // series on one side only are informational, not regressions
        assert_eq!(d.only_base, ["retired [median_ms]"]);
        assert_eq!(d.only_cur, ["fresh [median_ms]"]);
        assert!(d.table("diff").render().contains("REGRESSED"));
        // generous threshold: nothing regresses
        assert!(diff_bench_logs(&base, &cur, 50.0)
            .unwrap()
            .regressions()
            .is_empty());
        // within-threshold drift is not a regression
        let near = log_with(&[("conv", 10.4)], &[]);
        assert!(diff_bench_logs(&base, &near, 5.0)
            .unwrap()
            .regressions()
            .is_empty());
    }

    #[test]
    fn privacy_metric_directions() {
        // raw leakage shrinking is an improvement; the derived gain
        // shrinking is a regression
        assert!(metric_lower_is_better("mia_adv_dense"));
        assert!(metric_lower_is_better("mia_auc_pattern_x8"));
        assert!(!metric_lower_is_better("privacy_gain_adv_mean"));
        let base = log_with(
            &[],
            &[("mia_adv_dense", 0.40), ("privacy_gain_adv_mean", 0.25)],
        );
        let cur = log_with(
            &[],
            &[("mia_adv_dense", 0.60), ("privacy_gain_adv_mean", 0.10)],
        );
        let d = diff_bench_logs(&base, &cur, 5.0).unwrap();
        let names: Vec<&str> = d
            .regressions()
            .iter()
            .map(|r| r.name.as_str())
            .collect();
        assert_eq!(
            names,
            ["mia_adv_dense", "privacy_gain_adv_mean"]
        );
    }

    #[test]
    fn latency_summary_nearest_rank() {
        let s = LatencySummary::from_samples((1..=100).collect());
        assert_eq!(s.n, 100);
        assert_eq!(s.p50_us, 50);
        assert_eq!(s.p95_us, 95);
        assert_eq!(s.p99_us, 99);
        assert_eq!(s.max_us, 100);
        assert!((s.mean_us - 50.5).abs() < 1e-9);
        // tiny sample: every percentile collapses to the only value
        let one = LatencySummary::from_samples(vec![7]);
        assert_eq!((one.p50_us, one.p99_us, one.max_us), (7, 7, 7));
        assert_eq!(LatencySummary::from_samples(vec![]).n, 0);
    }

    #[test]
    fn reservoir_caps_resident_samples() {
        let st = ServeStats::new();
        let n = SAMPLE_CAP as u64 + 500;
        for i in 0..n {
            st.submit();
            st.complete(i, i / 2);
        }
        let r = st.report(1.0);
        assert_eq!(r.completed, n);
        // resident sample count is capped; percentiles stay plausible
        assert_eq!(r.latency.n, SAMPLE_CAP);
        assert_eq!(r.queue.n, SAMPLE_CAP);
        assert!(r.latency.max_us < n);
    }

    #[test]
    fn stats_fold_and_report() {
        let st = ServeStats::new();
        // 7 offered: 5 accepted, 1 rejected (backpressure), 1 refused at
        // shutdown — submitted must settle on the accepted count
        for _ in 0..7 {
            st.submit();
        }
        st.reject();
        st.unsubmit();
        st.batch_dispatched(2);
        st.batch_dispatched(2);
        st.complete(100, 10);
        st.complete(200, 20);
        st.complete(300, 30);
        st.complete(400, 40);
        st.error_batch(1);
        st.shed();
        st.shed();
        st.shed_deadline();
        st.worker_lost(1);
        st.restart();
        let r = st.report(2.0);
        assert_eq!(r.submitted, 5);
        assert_eq!(r.completed, 4);
        assert_eq!(r.rejected, 1);
        assert_eq!(r.errors, 1);
        assert_eq!(r.shed, 2);
        assert_eq!(r.shed_deadline, 1);
        assert_eq!(r.worker_lost, 1);
        assert_eq!(r.restarts, 1);
        assert_eq!(r.dispatched(), 4);
        // admission sheds and chaos losses are deterministic; deadline
        // sheds are not
        assert_eq!(
            r.deterministic_counters(),
            (5, 4, 1, 1, 2, 4, 1, 1)
        );
        assert!((r.throughput_rps - 2.0).abs() < 1e-9);
        assert!((r.mean_batch - 2.0).abs() < 1e-9);
        assert_eq!(r.latency.max_us, 400);
        assert_eq!(r.queue.p50_us, 20);
        let rendered = r.table("serve").render();
        assert!(rendered.contains("completed"));
        assert!(r.batch_table("hist").render().contains("batch size"));
    }
}
