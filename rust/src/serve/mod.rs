//! Serving tier: plan artifacts, a compiled-plan registry, and a
//! dynamic-batching inference server (DESIGN.md §11).
//!
//! The paper's end state is that users "directly benefit from compressed
//! models" without re-running the pruning pipeline — i.e. pruned models
//! are *deployed and served*. This subsystem is that missing tier on top
//! of the mobile plan/executor split:
//!
//! * [`artifact`] — versioned, checksummed binary serialization of an
//!   [`ExecutionPlan`](crate::mobile::plan::ExecutionPlan), so the
//!   expensive `PassManager` lowering is paid once per deployment
//!   (strict round-trip guarantee: loaded plans produce bit-identical
//!   inference outputs);
//! * [`registry`] — a concurrent `(model, scheme, rate, threads)` →
//!   plan cache with single-flight misses and LRU eviction;
//! * [`batcher`] — bounded request queue with explicit admission control
//!   plus the micro-batch formation state machine (`max_batch` /
//!   `max_wait_us`);
//! * [`server`] — the multi-worker request loop over std
//!   threads/channels (no async runtime), routing per-request responses
//!   and folding latency/batch metrics into [`stats`];
//! * [`loadgen`] — seeded open/closed-loop load generation for benches,
//!   tests, and the `repro serve` CLI;
//! * [`stats`] — latency percentiles, batch histograms, and the shared
//!   bench harness.
//!
//! Everything here is artifact-free and PJRT-free: the CLI serves
//! synthetic specs (`mobile::synth`) end to end on a bare machine.

pub mod artifact;
pub mod batcher;
pub mod loadgen;
pub mod registry;
pub mod server;
pub mod stats;

pub use artifact::{load as load_plan, save as save_plan};
pub use registry::{PlanKey, PlanRegistry};
pub use server::{ServeHandle, Server, SubmitError};
pub use stats::{ServeReport, ServeStats};
