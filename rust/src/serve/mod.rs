//! Serving tier: plan artifacts, compiled-plan registries, a
//! dynamic-batching server, and a multi-tenant gateway (DESIGN.md
//! §11/§13).
//!
//! The paper's end state is that users "directly benefit from compressed
//! models" without re-running the pruning pipeline — i.e. pruned models
//! are *deployed and served*. This subsystem is that tier on top of the
//! mobile plan/executor split:
//!
//! * [`artifact`] — versioned, checksummed binary serialization of an
//!   [`ExecutionPlan`](crate::mobile::plan::ExecutionPlan), so the
//!   expensive `PassManager` lowering is paid once per deployment
//!   (strict round-trip guarantee: loaded plans produce bit-identical
//!   inference outputs);
//! * [`registry`] — a concurrent `(model, scheme, rate, threads)` →
//!   plan cache with single-flight misses, LRU + byte-budget eviction,
//!   and per-tenant shards ([`ShardedRegistry`]);
//! * [`server`] — a single-plan multi-worker request loop over std
//!   threads/channels (no async runtime), built via [`Server::builder`],
//!   with dynamic micro-batching and explicit queue-full backpressure;
//! * [`gateway`] — many `(model, scheme, rate, kernel)` tenants
//!   multiplexed over one worker pool: per-tenant bounded queues,
//!   priority classes, virtual-time admission control, deadline
//!   shedding, and per-tenant reports rolled into a gateway report;
//! * [`loadgen`] — seeded open/closed-loop and multi-tenant trace load
//!   generation for benches, tests, and the `repro serve` CLI;
//! * [`stats`] — latency percentiles, batch histograms, and the shared
//!   bench harness.
//!
//! Every fallible surface here reports the one public [`ServeError`]
//! enum. Everything is artifact-free and PJRT-free: the CLI serves
//! synthetic specs (`mobile::synth`) end to end on a bare machine.

pub mod artifact;
pub(crate) mod batcher;
pub mod error;
pub mod gateway;
pub mod loadgen;
pub mod registry;
pub mod server;
pub mod stats;

pub use artifact::{load as load_plan, save as save_plan};
pub use error::ServeError;
pub use gateway::{
    Gateway, GatewayHandle, GatewayReport, Priority, TenantConfig,
    TenantReport,
};
pub use registry::{PlanKey, PlanRegistry, ShardedRegistry};
pub use server::{ServeHandle, Server, ServerBuilder};
pub use stats::{ServeReport, ServeStats};
