//! Serving tier: plan artifacts, compiled-plan registries, a
//! dynamic-batching server, and a multi-tenant gateway (DESIGN.md
//! §11/§13).
//!
//! The paper's end state is that users "directly benefit from compressed
//! models" without re-running the pruning pipeline — i.e. pruned models
//! are *deployed and served*. This subsystem is that tier on top of the
//! mobile plan/executor split:
//!
//! * [`artifact`] — versioned, checksummed binary serialization of an
//!   [`ExecutionPlan`](crate::mobile::plan::ExecutionPlan), so the
//!   expensive `PassManager` lowering is paid once per deployment
//!   (strict round-trip guarantee: loaded plans produce bit-identical
//!   inference outputs);
//! * [`registry`] — a concurrent `(model, scheme, rate, threads)` →
//!   plan cache with single-flight misses, LRU + byte-budget eviction,
//!   and per-tenant shards ([`ShardedRegistry`]);
//! * [`server`] — a single-plan multi-worker request loop over std
//!   threads/channels (no async runtime), built via [`Server::builder`],
//!   with dynamic micro-batching and explicit queue-full backpressure;
//! * [`gateway`] — many `(model, scheme, rate, kernel)` tenants
//!   multiplexed over one worker pool: per-tenant bounded queues,
//!   priority classes, virtual-time admission control, deadline
//!   shedding, and per-tenant reports rolled into a gateway report;
//! * [`loadgen`] — seeded open/closed-loop and multi-tenant trace load
//!   generation for benches, tests, and the `repro serve` CLI;
//! * [`stats`] — latency percentiles, batch histograms, and the shared
//!   bench harness;
//! * [`faults`] — the deterministic chaos harness: a seeded
//!   [`FaultPlan`](faults::FaultPlan) injects worker panics, artifact
//!   corruption, slow executors and plan-build failures as a pure
//!   function of `(seed, site, request id)`, so fault schedules are
//!   bit-reproducible at any worker count;
//! * `supervisor` — the worker supervision layer: dispatch runs inside
//!   `catch_unwind`, a poisoned batch fails exactly one victim with a
//!   typed [`ServeError::WorkerLost`], innocents are requeued, and the
//!   worker restarts with executors rebuilt.
//!
//! Every fallible surface here reports the one public [`ServeError`]
//! enum. Everything is artifact-free and PJRT-free: the CLI serves
//! synthetic specs (`mobile::synth`) end to end on a bare machine.

pub mod artifact;
pub(crate) mod batcher;
pub mod error;
pub mod faults;
pub mod gateway;
pub mod loadgen;
pub mod registry;
pub mod server;
pub mod stats;
pub(crate) mod supervisor;

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
///
/// Serve-tier shared state (registry slots, batcher queues, gateway
/// tenant tables) is **counter-consistent at every lock release**: each
/// critical section either completes its bookkeeping or never starts it,
/// so a poisoned mutex carries valid data and the poison flag is noise
/// from an unrelated panic (e.g. a panicking plan builder observed by
/// `catch_unwind` in tests). Recovering keeps the serving tier available
/// instead of cascading one worker's panic into every caller.
pub(crate) fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait`] with the same poison-recovery contract as
/// [`lock_clean`].
pub(crate) fn wait_clean<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// [`Condvar::wait_timeout`] with the same poison-recovery contract as
/// [`lock_clean`]. Returns the guard and whether the wait timed out.
pub(crate) fn wait_timeout_clean<'a, T>(
    cv: &Condvar,
    g: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, bool) {
    let (g, res) = cv
        .wait_timeout(g, dur)
        .unwrap_or_else(PoisonError::into_inner);
    (g, res.timed_out())
}

pub use artifact::{load as load_plan, save as save_plan};
pub use error::ServeError;
pub use faults::{FaultPlan, FaultSite};
pub use gateway::{
    Gateway, GatewayHandle, GatewayReport, Priority, TenantConfig,
    TenantReport,
};
pub use registry::{PlanKey, PlanRegistry, ShardedRegistry};
pub use server::{ServeHandle, Server, ServerBuilder};
pub use stats::{ServeReport, ServeStats};
