//! Deterministic fault injection for the serve tier.
//!
//! A [`FaultPlan`] is a *seeded chaos schedule*: whether a fault fires
//! at a given site is a pure function of `(seed, site, sequence#)`,
//! evaluated through a split PCG stream per decision. Nothing about
//! wall-clock time, worker count, or batch formation enters the
//! decision, so the same seed replays the exact same fault set at 1, 2,
//! or 4 workers — the house determinism invariant extended to failure
//! behavior.
//!
//! Sites ([`FaultSite`]) name *where* a fault can strike:
//!
//! - [`FaultSite::WorkerPanic`] — the dispatching worker panics while a
//!   batch containing the selected request is in flight (sequence# =
//!   request id). The supervisor in the worker loop catches the unwind,
//!   fails exactly the selected request with
//!   [`ServeError::WorkerLost`](super::error::ServeError::WorkerLost),
//!   requeues its batch-mates, and rebuilds the worker's executors.
//! - [`FaultSite::ArtifactCorrupt`] — a loaded artifact byte stream is
//!   corrupted before decode (sequence# = load attempt), exercising the
//!   typed `ServeError::Artifact` path and recompile-from-spec fallback.
//! - [`FaultSite::SlowExec`] — the executor stalls for
//!   [`FaultPlan::stall_us`] before a batch (sequence# = head request
//!   id). Only wall-clock latency is affected, never results, so the
//!   deterministic counters are untouched by this site.
//! - [`FaultSite::BuildFail`] — a plan build returns a synthetic error
//!   (sequence# = build attempt per key), feeding the registry's
//!   failure counters and circuit breaker.
//!
//! The plan is threaded through `ServerBuilder`/`GatewayBuilder` as an
//! `Option<Arc<FaultPlan>>` and is **off by default**: every hook takes
//! the `Option`, and the `None` arm is a branch — no hashing, no RNG,
//! no atomics on the fault-free path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::rng::Pcg32;

/// Shared handle threaded through the serve builders. `None` disables
/// every site at zero cost.
pub type Faults = Option<Arc<FaultPlan>>;

/// Named places where the chaos schedule can strike.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultSite {
    /// worker panics mid-dispatch; the selected request is lost
    WorkerPanic,
    /// artifact bytes corrupted before decode
    ArtifactCorrupt,
    /// executor stalls before a batch (latency only, never results)
    SlowExec,
    /// plan build returns a synthetic error
    BuildFail,
}

impl FaultSite {
    pub const ALL: [FaultSite; 4] = [
        FaultSite::WorkerPanic,
        FaultSite::ArtifactCorrupt,
        FaultSite::SlowExec,
        FaultSite::BuildFail,
    ];

    fn idx(self) -> usize {
        match self {
            FaultSite::WorkerPanic => 0,
            FaultSite::ArtifactCorrupt => 1,
            FaultSite::SlowExec => 2,
            FaultSite::BuildFail => 3,
        }
    }

    /// Per-site stream salt: decisions at different sites are drawn
    /// from unrelated PCG streams even for equal sequence numbers.
    fn salt(self) -> u64 {
        [
            0x9E37_79B9_7F4A_7C15,
            0xC2B2_AE3D_27D4_EB4F,
            0x1656_67B1_9E37_79F9,
            0x27D4_EB2F_1656_67C5,
        ][self.idx()]
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultSite::WorkerPanic => "worker_panic",
            FaultSite::ArtifactCorrupt => "artifact_corrupt",
            FaultSite::SlowExec => "slow_exec",
            FaultSite::BuildFail => "build_fail",
        }
    }
}

/// A seeded, replayable chaos schedule. Construct with
/// [`FaultPlan::new`], tune per-site rates with the builder methods,
/// wrap in an `Arc`, and hand it to the serve builders.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    /// per-site fire rate in per-mille (0 = site disabled)
    rates: [u16; 4],
    /// stall length for [`FaultSite::SlowExec`]
    stall_us: u64,
    /// how many times each site actually struck (telemetry only — the
    /// schedule itself is pure; these count the acted-on injections)
    injected: [AtomicU64; 4],
}

impl FaultPlan {
    /// A plan with the default chaos mix: panics, stalls, and artifact
    /// corruption at 30‰ each; build failures off (opt in via
    /// [`FaultPlan::rate`] so plan standup stays reliable by default).
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            seed,
            rates: [30, 30, 30, 0],
            stall_us: 2_000,
            injected: Default::default(),
        }
    }

    /// Override one site's fire rate (per-mille, clamped to 1000).
    pub fn rate(mut self, site: FaultSite, per_mille: u16) -> Self {
        self.rates[site.idx()] = per_mille.min(1000);
        self
    }

    /// Override the [`FaultSite::SlowExec`] stall length.
    pub fn stall_us(mut self, us: u64) -> Self {
        self.stall_us = us;
        self
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Pure decision: does `site` fire at `seq`? Same `(seed, site,
    /// seq)` always answers the same — this is the whole determinism
    /// story. Callers pick a `seq` that is itself reproducible (request
    /// id, build attempt, load attempt).
    pub fn fires(&self, site: FaultSite, seq: u64) -> bool {
        let rate = self.rates[site.idx()];
        if rate == 0 {
            return false;
        }
        let mut rng = Pcg32::split_stream(self.seed ^ site.salt(), seq);
        rng.below(1000) < rate as usize
    }

    /// Record that a fault decided by [`FaultPlan::fires`] was acted
    /// on. Kept separate from the decision so re-checking a request id
    /// (e.g. during unwind triage) never double-counts.
    pub fn record(&self, site: FaultSite) {
        self.injected[site.idx()].fetch_add(1, Ordering::Relaxed);
    }

    /// How often each site actually struck, as `(site, count)` pairs.
    pub fn injected(&self) -> Vec<(FaultSite, u64)> {
        FaultSite::ALL
            .iter()
            .map(|&s| (s, self.injected[s.idx()].load(Ordering::Relaxed)))
            .collect()
    }

    /// One-line summary for reports: `chaos seed=42: worker_panic=3 ...`
    pub fn summary(&self) -> String {
        let fired: Vec<String> = self
            .injected()
            .into_iter()
            .filter(|(s, n)| *n > 0 || self.rates[s.idx()] > 0)
            .map(|(s, n)| format!("{}={n}", s.name()))
            .collect();
        format!("chaos seed={}: {}", self.seed, fired.join(" "))
    }

    /// Stall length used by [`FaultSite::SlowExec`].
    pub fn stall(&self) -> std::time::Duration {
        std::time::Duration::from_micros(self.stall_us)
    }

    /// Deterministically corrupt one byte of `bytes` (position drawn
    /// from the site stream at `seq`). Records the injection. No-op on
    /// an empty buffer.
    pub fn corrupt(&self, bytes: &mut [u8], seq: u64) {
        if bytes.is_empty() {
            return;
        }
        let mut rng = Pcg32::split_stream(
            self.seed ^ FaultSite::ArtifactCorrupt.salt(),
            seq.wrapping_add(1) << 1,
        );
        let pos = rng.below(bytes.len());
        bytes[pos] ^= 0x01 | (rng.below(255) as u8);
        self.record(FaultSite::ArtifactCorrupt);
    }
}

/// Zero-cost hook: does `site` fire at `seq` under `faults`? The
/// `None` arm is a single branch.
pub fn fires(faults: &Faults, site: FaultSite, seq: u64) -> bool {
    match faults {
        None => false,
        Some(p) => p.fires(site, seq),
    }
}

/// Dispatch-side panic hook: if any id in `ids` is poisoned by the
/// schedule, panic (inside the supervisor's `catch_unwind`) exactly as
/// a buggy kernel would. The supervisor triages the unwind.
pub fn maybe_panic(faults: &Faults, ids: &[u64]) {
    let Some(p) = faults else { return };
    if let Some(id) =
        ids.iter().find(|&&id| p.fires(FaultSite::WorkerPanic, id))
    {
        panic!("chaos: injected worker panic on request {id}");
    }
}

/// Dispatch-side stall hook: sleep `stall_us` when the site fires for
/// the batch head. Latency-only — results and deterministic counters
/// are unaffected.
pub fn maybe_stall(faults: &Faults, head_id: u64) {
    let Some(p) = faults else { return };
    if p.fires(FaultSite::SlowExec, head_id) {
        p.record(FaultSite::SlowExec);
        std::thread::sleep(p.stall());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_is_pure_and_seed_sensitive() {
        let a = FaultPlan::new(42);
        let b = FaultPlan::new(42);
        let c = FaultPlan::new(43);
        let mut diverged = false;
        for site in FaultSite::ALL {
            let a = a.rates[site.idx()];
            assert_eq!(a, b.rates[site.idx()]);
            let _ = a;
        }
        for seq in 0..4096u64 {
            for site in FaultSite::ALL {
                assert_eq!(
                    a.fires(site, seq),
                    b.fires(site, seq),
                    "same seed must agree at ({site:?}, {seq})"
                );
            }
            if a.fires(FaultSite::WorkerPanic, seq)
                != c.fires(FaultSite::WorkerPanic, seq)
            {
                diverged = true;
            }
        }
        assert!(diverged, "different seeds never diverged");
    }

    #[test]
    fn sites_draw_independent_streams() {
        let p = FaultPlan::new(7)
            .rate(FaultSite::WorkerPanic, 500)
            .rate(FaultSite::BuildFail, 500);
        let mut differs = false;
        for seq in 0..512u64 {
            if p.fires(FaultSite::WorkerPanic, seq)
                != p.fires(FaultSite::BuildFail, seq)
            {
                differs = true;
                break;
            }
        }
        assert!(differs, "sites share a stream");
    }

    #[test]
    fn rates_are_respected() {
        let off = FaultPlan::new(9).rate(FaultSite::WorkerPanic, 0);
        let always =
            FaultPlan::new(9).rate(FaultSite::WorkerPanic, 1000);
        for seq in 0..256u64 {
            assert!(!off.fires(FaultSite::WorkerPanic, seq));
            assert!(always.fires(FaultSite::WorkerPanic, seq));
        }
        // ~30/1000 default rate lands in a sane band over 10k draws
        let p = FaultPlan::new(1);
        let n = (0..10_000u64)
            .filter(|&s| p.fires(FaultSite::WorkerPanic, s))
            .count();
        assert!((100..=700).contains(&n), "30/1000 rate fired {n}/10000");
    }

    #[test]
    fn disabled_handle_never_fires() {
        let none: Faults = None;
        for seq in 0..64 {
            assert!(!fires(&none, FaultSite::WorkerPanic, seq));
        }
        maybe_panic(&none, &[1, 2, 3]); // must not panic
        maybe_stall(&none, 0); // must not sleep
    }

    #[test]
    fn maybe_panic_fires_on_poisoned_id() {
        let p = Arc::new(
            FaultPlan::new(11).rate(FaultSite::WorkerPanic, 1000),
        );
        let faults: Faults = Some(p);
        let got = std::panic::catch_unwind(|| {
            maybe_panic(&faults, &[5]);
        });
        assert!(got.is_err(), "poisoned id must panic");
    }

    #[test]
    fn corrupt_flips_a_byte_deterministically() {
        let p = FaultPlan::new(3);
        let orig: Vec<u8> = (0..200u8).collect();
        let mut a = orig.clone();
        let mut b = orig.clone();
        p.corrupt(&mut a, 4);
        p.corrupt(&mut b, 4);
        assert_ne!(a, orig, "corruption must change the buffer");
        assert_eq!(a, b, "same (seed, seq) must corrupt identically");
        let flipped =
            a.iter().zip(&orig).filter(|(x, y)| x != y).count();
        assert_eq!(flipped, 1, "exactly one byte flips");
        assert_eq!(
            p.injected()[FaultSite::ArtifactCorrupt.idx()].1,
            2
        );
    }
}
