//! In-process multi-worker inference server with dynamic micro-batching.
//!
//! Construction is builder-style: [`Server::builder`] takes the plan,
//! knobs are chained (`.workers(n).max_batch(b).max_wait_us(w)
//! .kernel(sel)`), and [`ServerBuilder::spawn`] starts the worker pool.
//! Each worker owns an [`Executor`] (arena allocated once) and loops:
//! form a micro-batch via the batcher state machine (up to `max_batch`,
//! at most `max_wait_us` past the first request), execute it, route each
//! response back through its request's own channel. No async runtime —
//! the whole serving tier is std threads + channels, matching the rest
//! of the crate.
//!
//! Admission control is explicit: the queue is bounded at `queue_cap` and
//! a full queue rejects with [`ServeError::Rejected`] instead of
//! buffering without bound (the load generator counts these). Per-model
//! latency/throughput stats (p50/p95/p99, batch-size histogram) accumulate
//! in [`ServeStats`] and surface through
//! [`Server::shutdown`]/[`ServeStats::report`].
//!
//! Determinism: a request's logits depend only on its image — batching,
//! worker count, and batch windows never change outputs (asserted across
//! 1/2/4 workers in `tests/serve_determinism.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

use crate::config::ServeConfig;
use crate::mobile::engine::{
    execute_batch_parallel, Executor, Fmap, KernelSel,
};
use crate::mobile::plan::{ExecutionPlan, StepDims};

use super::batcher::{BatchPolicy, BoundedQueue, PushError};
use super::error::ServeError;
use super::faults::{self, FaultPlan, Faults};
use super::stats::{ServeReport, ServeStats};
use super::supervisor::{self, Meta, RespTx};

/// One queued inference request: the image plus everything needed to
/// route and time its response.
pub struct ServeRequest {
    pub id: u64,
    pub img: Fmap,
    pub enqueued: Instant,
    tx: RespTx,
}

/// A completed inference.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub id: u64,
    pub logits: Vec<f32>,
    /// submit -> batch formation
    pub queue_us: u64,
    /// submit -> response
    pub total_us: u64,
    /// size of the micro-batch this request rode in
    pub batch: usize,
}

/// Claim on an in-flight request; [`Ticket::wait`] blocks for the
/// response.
pub struct Ticket {
    pub id: u64,
    rx: mpsc::Receiver<Result<ServeResponse, ServeError>>,
}

impl Ticket {
    pub(crate) fn new(
        id: u64,
        rx: mpsc::Receiver<Result<ServeResponse, ServeError>>,
    ) -> Self {
        Ticket { id, rx }
    }

    /// Block until the response arrives. The channel carries typed
    /// errors — [`ServeError::WorkerLost`] from the supervisor,
    /// [`ServeError::Canceled`] from a shutdown drain — and a dropped
    /// sender (batch failed mid-flight) also maps to `Canceled`, so a
    /// waiter can never hang and never sees an untyped disconnect.
    pub fn wait(self) -> Result<ServeResponse, ServeError> {
        match self.rx.recv() {
            Ok(res) => res,
            Err(_) => Err(ServeError::Canceled { id: self.id }),
        }
    }
}

/// Validate an image against a plan's input dims — the shared submit-time
/// guard for the server and the gateway (a bad buffer must never reach a
/// worker).
pub(crate) fn check_image(
    img: &Fmap,
    want: StepDims,
) -> Result<(), ServeError> {
    if img.c != want.c || img.hw != want.hw {
        return Err(ServeError::BadShape {
            got: (img.c, img.hw),
            want: (want.c, want.hw),
        });
    }
    if img.data.len() != want.elems() {
        return Err(ServeError::BadLength {
            got: img.data.len(),
            want: want.elems(),
        });
    }
    Ok(())
}

struct Shared {
    queue: BoundedQueue<ServeRequest>,
    stats: ServeStats,
    next_id: AtomicU64,
    in_dims: StepDims,
}

/// Cloneable client handle: submit requests, read live stats.
#[derive(Clone)]
pub struct ServeHandle {
    shared: Arc<Shared>,
}

impl ServeHandle {
    /// Enqueue one image; returns a [`Ticket`] or an explicit
    /// [`ServeError`] (shape mismatch / backpressure / shutdown).
    pub fn submit(&self, img: Fmap) -> Result<Ticket, ServeError> {
        check_image(&img, self.shared.in_dims)?;
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let req = ServeRequest {
            id,
            img,
            enqueued: Instant::now(),
            tx,
        };
        // count the submit before the push: a worker can complete the
        // request before push() even returns, and a live report must
        // never show completed > submitted
        self.shared.stats.submit();
        match self.shared.queue.push(req) {
            Ok(_) => Ok(Ticket::new(id, rx)),
            Err(PushError::Full(_)) => {
                self.shared.stats.reject();
                Err(ServeError::Rejected)
            }
            Err(PushError::Closed(_)) => {
                self.shared.stats.unsubmit();
                Err(ServeError::Closed)
            }
        }
    }

    /// Submit and block for the response (closed-loop client path).
    pub fn infer(&self, img: Fmap) -> Result<ServeResponse, ServeError> {
        let ticket = self.submit(img)?;
        ticket.wait()
    }

    /// Snapshot the stats without stopping the server.
    pub fn report(&self, elapsed_secs: f64) -> ServeReport {
        self.shared.stats.report(elapsed_secs)
    }

    pub fn queue_depth(&self) -> usize {
        self.shared.queue.len()
    }
}

/// Builder for a [`Server`] — replaces the old positional
/// `Server::start(plan, kernel, cfg)` signature, so call sites name the
/// knobs they change and inherit sane defaults for the rest:
///
/// ```ignore
/// let server = Server::builder(plan)
///     .workers(2)
///     .max_batch(8)
///     .max_wait_us(500)
///     .kernel(KernelSel::Auto)
///     .spawn()?;
/// ```
///
/// Defaults come from [`ServeConfig::default`]; [`ServerBuilder::config`]
/// bulk-loads a preset before individual overrides. The gateway's
/// [`GatewayBuilder`](super::gateway::GatewayBuilder) follows the same
/// shape.
#[derive(Clone)]
pub struct ServerBuilder {
    plan: Arc<ExecutionPlan>,
    kernel: KernelSel,
    cfg: ServeConfig,
    faults: Faults,
}

impl ServerBuilder {
    /// Bulk-load every knob from a [`ServeConfig`] (individual setters
    /// chained after this still override).
    pub fn config(mut self, cfg: &ServeConfig) -> Self {
        self.cfg = *cfg;
        self
    }

    /// Batching worker threads (each owns one executor + arena).
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n.max(1);
        self
    }

    /// Dispatch a micro-batch as soon as it holds this many requests.
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n.max(1);
        self
    }

    /// Dispatch at latest this long after the first request of a batch.
    pub fn max_wait_us(mut self, us: u64) -> Self {
        self.cfg.max_wait_us = us;
        self
    }

    /// Bounded queue capacity; a full queue rejects (backpressure).
    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.cfg.queue_cap = cap.max(1);
        self
    }

    /// Intra-batch executor threads (1 = sequential on the worker's
    /// long-lived, allocation-free executor).
    pub fn batch_threads(mut self, n: usize) -> Self {
        self.cfg.batch_threads = n.max(1);
        self
    }

    /// Kernel selection: a uniform
    /// [`KernelKind`](crate::mobile::engine::KernelKind) for every
    /// layer, or [`KernelSel::Auto`] to dispatch each layer through the
    /// kernel choice baked into the plan (the autotuner's winners on a
    /// tuned plan).
    pub fn kernel(mut self, sel: impl Into<KernelSel>) -> Self {
        self.kernel = sel.into();
        self
    }

    /// Arm a seeded chaos schedule (see [`FaultPlan`]): worker panics,
    /// executor stalls, and friends fire deterministically from
    /// `(seed, site, request id)`. Off by default — without this call
    /// the fault hooks are a single `None` branch.
    pub fn chaos(mut self, plan: Arc<FaultPlan>) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Spawn the worker pool and start serving. A failed OS thread
    /// spawn tears the partial pool back down and returns a typed
    /// [`ServeError::Spawn`] instead of panicking mid-construction.
    pub fn spawn(self) -> Result<Server, ServeError> {
        let ServerBuilder {
            plan,
            kernel,
            cfg,
            faults,
        } = self;
        let shared = Arc::new(Shared {
            queue: BoundedQueue::new(cfg.queue_cap),
            stats: ServeStats::new(),
            next_id: AtomicU64::new(0),
            in_dims: plan.in_dims,
        });
        let policy = BatchPolicy::new(cfg.max_batch, cfg.max_wait_us);
        let batch_threads = cfg.batch_threads.max(1);
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            let plan = plan.clone();
            let shared = shared.clone();
            let faults = faults.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("serve-worker-{i}"))
                .spawn(move || {
                    worker_loop(
                        &plan,
                        kernel,
                        &shared,
                        &policy,
                        batch_threads,
                        faults,
                    )
                });
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    // drain the partial pool so no thread leaks
                    shared.queue.close();
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(ServeError::Spawn {
                        msg: e.to_string(),
                    });
                }
            }
        }
        Ok(Server {
            shared,
            workers,
            started: Instant::now(),
        })
    }
}

/// The serving engine: owns the worker threads; dropped via
/// [`Server::shutdown`] for an orderly drain + final report.
pub struct Server {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    started: Instant,
}

impl Server {
    /// Start configuring a server over `plan` (shared read-only; each
    /// worker builds its own executor + arena once). Defaults:
    /// [`ServeConfig::default`] and per-layer [`KernelSel::Auto`]
    /// dispatch.
    pub fn builder(plan: Arc<ExecutionPlan>) -> ServerBuilder {
        ServerBuilder {
            plan,
            kernel: KernelSel::Auto,
            cfg: ServeConfig::default(),
            faults: None,
        }
    }

    pub fn handle(&self) -> ServeHandle {
        ServeHandle {
            shared: self.shared.clone(),
        }
    }

    /// Stop accepting requests, drain the queue, join the workers, and
    /// return the final report over the whole serving window.
    ///
    /// The drain guarantee holds under active faults: the supervisor
    /// keeps workers alive through dispatch panics, and anything still
    /// queued after the joins (possible only if a worker died outside
    /// the supervised scope) is failed with a typed `Canceled` — an
    /// admitted request never ends as a silently dropped channel.
    pub fn shutdown(self) -> ServeReport {
        self.shared.queue.close();
        for w in self.workers {
            // a worker lost outside the supervised dispatch scope must
            // not panic the caller; its queued work is drained below
            let _ = w.join();
        }
        for req in self.shared.queue.drain() {
            supervisor::fail_canceled(req.id, &req.tx);
        }
        self.shared
            .stats
            .report(self.started.elapsed().as_secs_f64())
    }
}

fn worker_loop(
    plan: &ExecutionPlan,
    kernel: KernelSel,
    shared: &Shared,
    policy: &BatchPolicy,
    batch_threads: usize,
    faults: Faults,
) {
    // the long-lived executor (arena allocated once) only serves the
    // sequential path; the parallel path shards each batch across fresh
    // scoped executors inside execute_batch_parallel. Built lazily so
    // the supervisor can drop and rebuild it after a dispatch panic
    // (the arena is mid-batch garbage once an unwind crossed it).
    let seq = batch_threads <= 1;
    let mut ex: Option<Executor<'_>> = None;
    // window anchored at the first request's enqueue time: a backlogged
    // request is never further delayed by the straggler window
    while let Some(batch) =
        shared.queue.pop_batch_by(policy, |r| r.enqueued)
    {
        if batch.is_empty() {
            continue;
        }
        if seq && ex.is_none() {
            ex = Some(Executor::with_sel(plan, kernel));
        }
        let formed = Instant::now();
        let n = batch.len();
        let mut metas = Vec::with_capacity(n);
        let mut imgs = Vec::with_capacity(n);
        for req in batch {
            metas.push(Meta {
                id: req.id,
                enqueued: req.enqueued,
                tx: req.tx,
            });
            imgs.push(req.img);
        }
        // metas stay outside the unwind boundary: a panic below can
        // never take the response channels down with it
        let outs = supervisor::dispatch(|| {
            if faults.is_some() {
                let ids: Vec<u64> =
                    metas.iter().map(|m| m.id).collect();
                faults::maybe_panic(&faults, &ids);
                faults::maybe_stall(&faults, ids[0]);
            }
            match ex.as_mut() {
                Some(ex) => ex.execute_batch(&imgs),
                None => execute_batch_parallel(
                    plan,
                    kernel,
                    &imgs,
                    batch_threads,
                ),
            }
        });
        match outs {
            Ok(Ok(outs)) => {
                shared.stats.batch_dispatched(n);
                for (meta, logits) in metas.into_iter().zip(outs) {
                    let queue_us = formed
                        .saturating_duration_since(meta.enqueued)
                        .as_micros()
                        as u64;
                    let total_us =
                        meta.enqueued.elapsed().as_micros() as u64;
                    shared.stats.complete(total_us, queue_us);
                    // a departed client is not an error: drop its response
                    let _ = meta.tx.send(Ok(ServeResponse {
                        id: meta.id,
                        logits,
                        queue_us,
                        total_us,
                        batch: n,
                    }));
                }
            }
            Ok(Err(_)) => {
                // shape errors are caught at submit; an execute error here
                // cancels the whole batch (clients see recv disconnect)
                shared.stats.batch_dispatched(n);
                shared.stats.error_batch(n);
            }
            Err(_panic) => {
                // supervision: the executor's arena is untrustworthy
                // after an unwind — rebuild lazily on the next batch
                ex = None;
                let survivors = supervisor::recover_poisoned(
                    metas,
                    imgs,
                    &faults,
                    &shared.stats,
                );
                // requeue front-most last so FIFO order is preserved;
                // this worker is still in its pop loop, so a
                // shutdown-drain in progress picks these back up
                for (meta, img) in survivors.into_iter().rev() {
                    shared.queue.requeue(ServeRequest {
                        id: meta.id,
                        img,
                        enqueued: meta.enqueued,
                        tx: meta.tx,
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mobile::engine::KernelKind;
    use crate::mobile::ir::ModelIR;
    use crate::mobile::plan::{compile_plan, compile_plan_quant};
    use crate::mobile::synth;

    fn tiny_plan() -> Arc<ExecutionPlan> {
        let (spec, mut params) =
            synth::vgg_style("srv_vgg", 8, 4, &[4, 6], 31);
        synth::pattern_prune(&spec, &mut params, 0.25);
        Arc::new(
            compile_plan(ModelIR::build(&spec, &params).unwrap(), 1)
                .unwrap(),
        )
    }

    fn tiny_quant_plan() -> Arc<ExecutionPlan> {
        let (spec, mut params) =
            synth::vgg_style("srv_vgg", 8, 4, &[4, 6], 31);
        synth::pattern_prune(&spec, &mut params, 0.25);
        Arc::new(
            compile_plan_quant(
                ModelIR::build(&spec, &params).unwrap(),
                1,
            )
            .unwrap(),
        )
    }

    fn img_for(plan: &ExecutionPlan, seed: u64) -> Fmap {
        crate::serve::loadgen::request_image(plan.in_dims, seed, 0)
    }

    #[test]
    fn serves_and_matches_direct_executor() {
        let plan = tiny_plan();
        let server = Server::builder(plan.clone())
            .workers(2)
            .max_batch(4)
            .max_wait_us(200)
            .queue_cap(32)
            .batch_threads(1)
            .kernel(KernelKind::PatternScalar)
            .spawn()
            .unwrap();
        let handle = server.handle();
        let mut direct =
            Executor::new(&plan, KernelKind::PatternScalar);
        for seed in 0..10u64 {
            let img = img_for(&plan, seed);
            let want = direct.execute(&img);
            let resp = handle.infer(img).unwrap();
            assert_eq!(resp.logits, want, "seed {seed}");
            assert!(resp.batch >= 1);
            assert!(resp.total_us >= resp.queue_us);
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 10);
        assert_eq!(report.rejected, 0);
        assert_eq!(report.errors, 0);
        assert_eq!(report.dispatched(), 10);
    }

    #[test]
    fn auto_kernel_serving_matches_direct_executor() {
        let plan = tiny_plan();
        // builder default kernel is KernelSel::Auto
        let server = Server::builder(plan.clone())
            .workers(2)
            .max_batch(4)
            .max_wait_us(200)
            .queue_cap(32)
            .spawn()
            .unwrap();
        let handle = server.handle();
        let mut direct = Executor::auto(&plan);
        for seed in 0..6u64 {
            let img = img_for(&plan, seed);
            let want = direct.execute(&img);
            let resp = handle.infer(img).unwrap();
            assert_eq!(resp.logits, want, "seed {seed}");
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 6);
        assert_eq!(report.errors, 0);
    }

    #[test]
    fn quantized_plan_serving_matches_direct_executor() {
        let plan = tiny_quant_plan();
        let server = Server::builder(plan.clone())
            .workers(2)
            .max_batch(4)
            .max_wait_us(200)
            .queue_cap(32)
            .spawn()
            .unwrap();
        let handle = server.handle();
        // same-image requests are bit-identical no matter which worker
        // or batch shape served them: i8 accumulation is exact
        let mut direct = Executor::auto(&plan);
        for seed in 0..8u64 {
            let img = img_for(&plan, seed);
            let want = direct.execute(&img);
            let resp = handle.infer(img).unwrap();
            assert_eq!(resp.logits, want, "seed {seed}");
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 8);
        assert_eq!(report.errors, 0);
    }

    #[test]
    fn bad_shape_is_rejected_at_submit() {
        let plan = tiny_plan();
        let server = Server::builder(plan.clone())
            .config(&ServeConfig::preset(crate::config::Preset::Smoke))
            .kernel(KernelKind::PatternScalar)
            .spawn()
            .unwrap();
        let handle = server.handle();
        let bad = Fmap::zeros(1, 3);
        match handle.submit(bad) {
            Err(ServeError::BadShape { got, want }) => {
                assert_eq!(got, (1, 3));
                assert_eq!(want, (plan.in_dims.c, plan.in_dims.hw));
            }
            other => panic!("expected BadShape, got {:?}", other.is_ok()),
        }
        // right dims, wrong buffer length (Fmap fields are pub): must be
        // refused at submit, never panic a worker
        let mut hollow = Fmap::zeros(plan.in_dims.c, plan.in_dims.hw);
        hollow.data.truncate(1);
        match handle.submit(hollow) {
            Err(ServeError::BadLength { got, want }) => {
                assert_eq!(got, 1);
                assert_eq!(want, plan.in_dims.elems());
            }
            other => {
                panic!("expected BadLength, got {:?}", other.is_ok())
            }
        }
        let report = server.shutdown();
        assert_eq!(report.completed, 0);
    }

    #[test]
    fn shutdown_drains_inflight_requests() {
        let plan = tiny_plan();
        let server = Server::builder(plan.clone())
            .workers(1)
            .max_batch(8)
            .max_wait_us(0)
            .queue_cap(64)
            .kernel(KernelKind::PatternScalar)
            .spawn()
            .unwrap();
        let handle = server.handle();
        let tickets: Vec<Ticket> = (0..16)
            .map(|s| handle.submit(img_for(&plan, s)).unwrap())
            .collect();
        let report = server.shutdown();
        assert_eq!(report.completed, 16);
        for t in tickets {
            assert_eq!(t.wait().unwrap().logits.len(), plan.classes());
        }
    }

    #[test]
    fn closed_server_refuses_submits() {
        let plan = tiny_plan();
        let server = Server::builder(plan.clone())
            .config(&ServeConfig::preset(crate::config::Preset::Smoke))
            .kernel(KernelKind::PatternScalar)
            .spawn()
            .unwrap();
        let handle = server.handle();
        server.shutdown();
        match handle.submit(Fmap::zeros(3, 8)) {
            Err(ServeError::Closed) => {}
            other => panic!("expected Closed, got {:?}", other.is_ok()),
        }
    }
}
