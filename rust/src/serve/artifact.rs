//! Versioned, checksummed binary serialization of a compiled
//! [`ExecutionPlan`] — the deployable *plan artifact*.
//!
//! The expensive part of mobile deployment is the
//! [`PassManager`](crate::mobile::plan::PassManager) lowering (measured in
//! `bench_mobile`/`bench_serve`); an artifact pays it once. Layout, all
//! little-endian:
//!
//! ```text
//! magic  b"RPLN"
//! u32    FORMAT_VERSION
//! sections, each framed as (u32 id, u64 byte length, payload):
//!   1 IR        model id, op stream, conv tensors + pattern masks, fc head
//!   2 LAYERS    per layer: packed payload buffer, kernel headers,
//!               row-grouped codelets, filter schedule, worker blocks
//!   3 SCHEDULE  lowered steps + per-step dims + arena sizing
//!   4 REPORT    compile report (pass gains; feeds the cost model)
//!   5 STATS     plan stats (byte footprints, block/thread counts)
//!   6 TUNING    per-layer kernel choice: kind tag, row tile, filter
//!               block, tuned flag (analytic default or autotuner winner)
//!   7 QUANT     element tag (f32/i8); for i8 plans the per-layer i8
//!               tap payload + per-filter scale table (the LAYERS
//!               payload field is empty on i8 plans)
//! u64    FNV-1a checksum of every preceding byte
//! ```
//!
//! Loading is strict: bad magic, unknown version, checksum mismatch,
//! section framing drift, truncation, or trailing bytes are all hard
//! errors, the codelet section is cross-checked against a recomputation
//! from the style table, and the reconstructed plan must pass
//! [`ExecutionPlan::validate`]. The round-trip guarantee — an executor
//! over `load(save(plan))` produces **bit-identical** outputs to one over
//! `plan` — is asserted by [`verify_roundtrip`], `tests/serve_integration.rs`,
//! and a CI smoke step.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::Act;
use crate::mobile::costmodel::KernelChoice;
use crate::mobile::engine::{Executor, KernelKind, KERNEL_KINDS};
use crate::mobile::ir::{ConvIR, IrOp, ModelIR};
use crate::mobile::passes::{self, CompileReport, LayerReport, StyleRows};
use crate::mobile::plan::{
    ElemType, ExecutionPlan, FilterBlock, LayerPlan, PackedKernel,
    Payload, PlanStats, PlanStep, StepDims,
};
use crate::tensor::Tensor;
use crate::util::Stopwatch;

use super::error::ServeError;

/// Bump on any incompatible layout change.
/// History: 1 = initial format; 2 = added the TUNING section carrying
/// per-layer [`KernelChoice`] (kernel kind + tile shapes); 3 = added
/// the QUANT section (element tag + i8 payloads + per-filter scales).
pub const FORMAT_VERSION: u32 = 3;

/// Oldest version this build still reads. v2 artifacts predate
/// quantization and load as f32-only plans; v1 (pre-TUNING) is
/// rejected with a clear error.
pub const MIN_FORMAT_VERSION: u32 = 2;

const MAGIC: &[u8; 4] = b"RPLN";

const SEC_IR: u32 = 1;
const SEC_LAYERS: u32 = 2;
const SEC_SCHEDULE: u32 = 3;
const SEC_REPORT: u32 = 4;
const SEC_STATS: u32 = 5;
const SEC_TUNING: u32 = 6;
const SEC_QUANT: u32 = 7;

/// FNV-1a 64-bit over `bytes` (no external crates offline; collision
/// resistance is not a goal — this catches disk/transport corruption).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Little-endian byte cursor
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    fn u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    fn usz(&mut self, v: usize) {
        self.u64(v as u64);
    }

    fn i64v(&mut self, v: i64) {
        self.u64(v as u64);
    }

    fn f32v(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    fn f32s(&mut self, xs: &[f32]) {
        self.usz(xs.len());
        for &x in xs {
            self.f32v(x);
        }
    }

    fn i8s(&mut self, xs: &[i8]) {
        self.usz(xs.len());
        for &x in xs {
            self.buf.push(x as u8);
        }
    }

    fn str_(&mut self, s: &str) {
        self.usz(s.len());
        self.buf.extend_from_slice(s.as_bytes());
    }

    fn tensor(&mut self, t: &Tensor) {
        self.usz(t.shape().len());
        for &d in t.shape() {
            self.usz(d);
        }
        self.f32s(t.data());
    }

    fn section(&mut self, id: u32, body: Writer) {
        self.u32(id);
        self.u64(body.buf.len() as u64);
        self.buf.extend_from_slice(&body.buf);
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "artifact truncated: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn usz(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    /// Collection length, capped by the bytes actually left in this
    /// reader: every element consumes at least `min_encoded` bytes of
    /// input, so any larger count is guaranteed truncation — reject it
    /// before a `Vec::with_capacity` can reserve a multiple of the file
    /// size on garbage. (Scalar size fields like `fmap_elems` go through
    /// plain [`Reader::usz`]: arenas are legitimately larger than the
    /// weight file, and validate() pins them to the schedule.)
    fn count(&mut self, min_encoded: usize) -> Result<usize> {
        let v = self.u64()?;
        let cap = (self.remaining() / min_encoded.max(1)) as u64;
        if v > cap {
            bail!(
                "artifact corrupt: count {v} exceeds remaining data \
                 ({} bytes / {min_encoded} per element)",
                self.remaining()
            );
        }
        Ok(v as usize)
    }

    fn i64v(&mut self) -> Result<i64> {
        Ok(self.u64()? as i64)
    }

    fn f32v(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.count(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32v()?);
        }
        Ok(out)
    }

    fn i8s(&mut self) -> Result<Vec<i8>> {
        let n = self.count(1)?;
        Ok(self.take(n)?.iter().map(|&b| b as i8).collect())
    }

    fn str_(&mut self) -> Result<String> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .context("artifact corrupt: non-utf8 string")
    }

    fn tensor(&mut self) -> Result<Tensor> {
        let ndim = self.count(8)?;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(self.usz()?);
        }
        let data = self.f32s()?;
        Tensor::from_vec(&shape, data)
            .context("artifact corrupt: tensor shape/data mismatch")
    }

    /// Open section `id`, returning a sub-reader clamped to its length.
    fn section(&mut self, id: u32) -> Result<Reader<'a>> {
        let got = self.u32()?;
        if got != id {
            bail!("artifact corrupt: expected section {id}, found {got}");
        }
        let len = self.usz()?;
        Ok(Reader::new(self.take(len)?))
    }

    fn finish_section(self, id: u32) -> Result<()> {
        if self.remaining() != 0 {
            bail!(
                "artifact corrupt: section {id} has {} unread bytes",
                self.remaining()
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Encode
// ---------------------------------------------------------------------------

fn act_tag(a: Act) -> u8 {
    match a {
        Act::None => 0,
        Act::Relu => 1,
    }
}

fn act_from(tag: u8) -> Result<Act> {
    Ok(match tag {
        0 => Act::None,
        1 => Act::Relu,
        other => bail!("artifact corrupt: unknown activation tag {other}"),
    })
}

fn encode_ir(ir: &ModelIR) -> Writer {
    let mut w = Writer::default();
    w.str_(&ir.model_id);
    w.usz(ir.in_hw);
    w.usz(ir.classes);
    w.usz(ir.convs.len());
    for c in &ir.convs {
        w.usz(c.op_idx);
        w.usz(c.a);
        w.usz(c.c);
        w.usz(c.kh);
        w.usz(c.kw);
        w.usz(c.stride);
        w.u8(act_tag(c.act));
        w.usz(c.in_hw);
        w.usz(c.out_hw);
        w.tensor(&c.w);
        w.tensor(&c.bias);
        w.usz(c.pattern.len());
        for &p in &c.pattern {
            w.u16(p);
        }
        w.str_(&c.tag);
        w.u8(c.is_proj as u8);
    }
    w.usz(ir.ops.len());
    for op in &ir.ops {
        match op {
            IrOp::Conv(ci) => {
                w.u8(0);
                w.usz(*ci);
            }
            IrOp::Pool => w.u8(1),
            IrOp::Save { tag } => {
                w.u8(2);
                w.str_(tag);
            }
            IrOp::Proj(ci) => {
                w.u8(3);
                w.usz(*ci);
            }
            IrOp::Add { tag } => {
                w.u8(4);
                w.str_(tag);
            }
            IrOp::Relu => w.u8(5),
            IrOp::Gap => w.u8(6),
            IrOp::Fc => w.u8(7),
        }
    }
    w.tensor(&ir.fc_w);
    w.tensor(&ir.fc_b);
    w
}

fn decode_ir(r: &mut Reader<'_>) -> Result<ModelIR> {
    let model_id = r.str_()?;
    let in_hw = r.usz()?;
    let classes = r.usz()?;
    let n_convs = r.count(64)?;
    let mut convs = Vec::with_capacity(n_convs);
    for _ in 0..n_convs {
        let op_idx = r.usz()?;
        let a = r.usz()?;
        let c = r.usz()?;
        let kh = r.usz()?;
        let kw = r.usz()?;
        let stride = r.usz()?;
        let act = act_from(r.u8()?)?;
        let c_in_hw = r.usz()?;
        let c_out_hw = r.usz()?;
        let wt = r.tensor()?;
        let bias = r.tensor()?;
        let n_pat = r.count(2)?;
        let mut pattern = Vec::with_capacity(n_pat);
        for _ in 0..n_pat {
            pattern.push(r.u16()?);
        }
        let tag = r.str_()?;
        let is_proj = r.u8()? != 0;
        convs.push(ConvIR {
            op_idx,
            a,
            c,
            kh,
            kw,
            stride,
            act,
            in_hw: c_in_hw,
            out_hw: c_out_hw,
            w: wt,
            bias,
            pattern,
            tag,
            is_proj,
        });
    }
    let n_ops = r.count(1)?;
    let mut ops = Vec::with_capacity(n_ops);
    for _ in 0..n_ops {
        let op = match r.u8()? {
            0 => IrOp::Conv(r.usz()?),
            1 => IrOp::Pool,
            2 => IrOp::Save { tag: r.str_()? },
            3 => IrOp::Proj(r.usz()?),
            4 => IrOp::Add { tag: r.str_()? },
            5 => IrOp::Relu,
            6 => IrOp::Gap,
            7 => IrOp::Fc,
            other => bail!("artifact corrupt: unknown ir op tag {other}"),
        };
        ops.push(op);
    }
    let fc_w = r.tensor()?;
    let fc_b = r.tensor()?;
    Ok(ModelIR {
        model_id,
        in_hw,
        classes,
        convs,
        ops,
        fc_w,
        fc_b,
    })
}

fn encode_style_rows(w: &mut Writer, rows: &StyleRows) {
    w.usz(rows.len());
    for (ky, taps) in rows {
        w.usz(*ky);
        w.usz(taps.len());
        for &(kx, slot) in taps {
            w.usz(kx);
            w.usz(slot);
        }
    }
}

fn decode_style_rows(r: &mut Reader<'_>) -> Result<StyleRows> {
    let n = r.count(16)?;
    let mut rows = Vec::with_capacity(n);
    for _ in 0..n {
        let ky = r.usz()?;
        let n_taps = r.count(16)?;
        let mut taps = Vec::with_capacity(n_taps);
        for _ in 0..n_taps {
            let kx = r.usz()?;
            let slot = r.usz()?;
            taps.push((kx, slot));
        }
        rows.push((ky, taps));
    }
    Ok(rows)
}

fn encode_layers(layers: &[LayerPlan]) -> Writer {
    let mut w = Writer::default();
    w.usz(layers.len());
    for lp in layers {
        w.usz(lp.conv);
        w.usz(lp.a);
        w.usz(lp.c);
        w.usz(lp.kh);
        w.usz(lp.kw);
        w.usz(lp.stride);
        w.usz(lp.in_hw);
        w.usz(lp.out_hw);
        w.i64v(lp.pad);
        w.u8(act_tag(lp.act));
        w.f32s(&lp.bias);
        // i8 payloads travel in the QUANT section; the f32 field stays
        // in the frame (empty) so the v2 layout is a strict subset
        match &lp.payload {
            Payload::F32(taps) => w.f32s(taps),
            Payload::I8 { .. } => w.f32s(&[]),
        }
        w.usz(lp.kernels.len());
        for k in &lp.kernels {
            w.u32(k.ch);
            w.u16(k.style);
            w.u32(k.off);
        }
        w.usz(lp.filter_ranges.len());
        for r in &lp.filter_ranges {
            w.usz(r.start);
            w.usz(r.end);
        }
        w.usz(lp.styles.len());
        for &s in &lp.styles {
            w.u16(s);
        }
        w.usz(lp.style_rows.len());
        for rows in &lp.style_rows {
            encode_style_rows(&mut w, rows);
        }
        w.usz(lp.exec_order.len());
        for &f in &lp.exec_order {
            w.usz(f);
        }
        w.usz(lp.blocks.len());
        for b in &lp.blocks {
            w.usz(b.span.start);
            w.usz(b.span.end);
            w.u64(b.cost);
        }
    }
    w
}

fn decode_layers(r: &mut Reader<'_>) -> Result<Vec<LayerPlan>> {
    let n = r.count(64)?;
    let mut layers = Vec::with_capacity(n);
    for li in 0..n {
        let conv = r.usz()?;
        let a = r.usz()?;
        let c = r.usz()?;
        let kh = r.usz()?;
        let kw = r.usz()?;
        // kh/kw feed loop bounds (row_group below, kernel inner loops)
        // and the u16 style mask holds at most 16 taps — reject garbage
        // before it can spin or overflow a shift
        if kh == 0 || kw == 0 || kh.saturating_mul(kw) > 16 {
            bail!(
                "artifact corrupt: layer {li} kernel geometry {kh}x{kw} \
                 (the pattern mask supports at most 16 taps)"
            );
        }
        let stride = r.usz()?;
        let in_hw = r.usz()?;
        let out_hw = r.usz()?;
        let pad = r.i64v()?;
        let act = act_from(r.u8()?)?;
        let bias = r.f32s()?;
        // f32 taps; replaced from the QUANT section on i8 plans
        let payload = Payload::F32(r.f32s()?);
        let n_kernels = r.count(10)?;
        let mut kernels = Vec::with_capacity(n_kernels);
        for _ in 0..n_kernels {
            let ch = r.u32()?;
            let style = r.u16()?;
            let off = r.u32()?;
            kernels.push(PackedKernel { ch, style, off });
        }
        let n_ranges = r.count(16)?;
        let mut filter_ranges = Vec::with_capacity(n_ranges);
        for _ in 0..n_ranges {
            let start = r.usz()?;
            let end = r.usz()?;
            filter_ranges.push(start..end);
        }
        let n_styles = r.count(2)?;
        let mut styles = Vec::with_capacity(n_styles);
        for _ in 0..n_styles {
            styles.push(r.u16()?);
        }
        let n_rows = r.count(8)?;
        let mut style_rows = Vec::with_capacity(n_rows);
        for _ in 0..n_rows {
            style_rows.push(decode_style_rows(r)?);
        }
        // the codelet section must agree with a recomputation from the
        // style table — a drifted row grouping would silently mis-index
        // the packed payload
        if style_rows.len() != styles.len() {
            bail!("artifact corrupt: layer {li} codelet arity");
        }
        for (si, (&pat, rows)) in
            styles.iter().zip(&style_rows).enumerate()
        {
            if *rows != passes::row_group(pat, kh, kw) {
                bail!(
                    "artifact corrupt: layer {li} style {si} codelets \
                     disagree with the style table"
                );
            }
        }
        let n_order = r.count(8)?;
        let mut exec_order = Vec::with_capacity(n_order);
        for _ in 0..n_order {
            exec_order.push(r.usz()?);
        }
        let n_blocks = r.count(24)?;
        let mut blocks = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            let start = r.usz()?;
            let end = r.usz()?;
            let cost = r.u64()?;
            blocks.push(FilterBlock {
                span: start..end,
                cost,
            });
        }
        layers.push(LayerPlan {
            conv,
            a,
            c,
            kh,
            kw,
            stride,
            in_hw,
            out_hw,
            pad,
            act,
            bias,
            payload,
            kernels,
            filter_ranges,
            styles,
            style_rows,
            exec_order,
            blocks,
            // placeholder — the TUNING section overwrites this before
            // the decoded plan is validated
            choice: KernelChoice {
                kind: KernelKind::PatternScalar,
                row_tile: 1,
                fblock: 1,
                tuned: false,
            },
        });
    }
    Ok(layers)
}

fn encode_schedule(p: &ExecutionPlan) -> Writer {
    let mut w = Writer::default();
    w.usz(p.steps.len());
    for s in &p.steps {
        match s {
            PlanStep::Conv { layer } => {
                w.u8(0);
                w.usz(*layer);
            }
            PlanStep::Pool => w.u8(1),
            PlanStep::Save { slot } => {
                w.u8(2);
                w.usz(*slot);
            }
            PlanStep::Proj { layer, slot } => {
                w.u8(3);
                w.usz(*layer);
                w.usz(*slot);
            }
            PlanStep::Add { slot } => {
                w.u8(4);
                w.usz(*slot);
            }
            PlanStep::Relu => w.u8(5),
            PlanStep::Gap => w.u8(6),
            PlanStep::Fc => w.u8(7),
        }
    }
    w.usz(p.dims.len());
    for d in &p.dims {
        w.usz(d.c);
        w.usz(d.hw);
    }
    w.usz(p.in_dims.c);
    w.usz(p.in_dims.hw);
    w.usz(p.slot_sizes.len());
    for &s in &p.slot_sizes {
        w.usz(s);
    }
    w.usz(p.fmap_elems);
    w.usz(p.proj_scratch_elems);
    w.usz(p.gap_len);
    w.usz(p.threads);
    w
}

struct ScheduleSection {
    steps: Vec<PlanStep>,
    dims: Vec<StepDims>,
    in_dims: StepDims,
    slot_sizes: Vec<usize>,
    fmap_elems: usize,
    proj_scratch_elems: usize,
    gap_len: usize,
    threads: usize,
}

fn decode_schedule(r: &mut Reader<'_>) -> Result<ScheduleSection> {
    let n_steps = r.count(1)?;
    let mut steps = Vec::with_capacity(n_steps);
    for _ in 0..n_steps {
        let step = match r.u8()? {
            0 => PlanStep::Conv { layer: r.usz()? },
            1 => PlanStep::Pool,
            2 => PlanStep::Save { slot: r.usz()? },
            3 => PlanStep::Proj {
                layer: r.usz()?,
                slot: r.usz()?,
            },
            4 => PlanStep::Add { slot: r.usz()? },
            5 => PlanStep::Relu,
            6 => PlanStep::Gap,
            7 => PlanStep::Fc,
            other => bail!("artifact corrupt: unknown step tag {other}"),
        };
        steps.push(step);
    }
    let n_dims = r.count(16)?;
    let mut dims = Vec::with_capacity(n_dims);
    for _ in 0..n_dims {
        let c = r.usz()?;
        let hw = r.usz()?;
        dims.push(StepDims { c, hw });
    }
    let in_c = r.usz()?;
    let in_hw = r.usz()?;
    let n_slots = r.count(8)?;
    let mut slot_sizes = Vec::with_capacity(n_slots);
    for _ in 0..n_slots {
        slot_sizes.push(r.usz()?);
    }
    Ok(ScheduleSection {
        steps,
        dims,
        in_dims: StepDims { c: in_c, hw: in_hw },
        slot_sizes,
        fmap_elems: r.usz()?,
        proj_scratch_elems: r.usz()?,
        gap_len: r.usz()?,
        threads: r.usz()?,
    })
}

fn encode_report(rep: &CompileReport) -> Writer {
    let mut w = Writer::default();
    w.usz(rep.layers.len());
    for l in &rep.layers {
        w.usz(l.dense_macs);
        w.usz(l.sparse_macs);
        w.usz(l.dense_bytes);
        w.usz(l.compressed_bytes);
        w.usz(l.styles);
        w.usz(l.switches_before);
        w.usz(l.switches_after);
        w.usz(l.loads_naive);
        w.usz(l.loads_lre);
    }
    w
}

fn decode_report(r: &mut Reader<'_>) -> Result<CompileReport> {
    let n = r.count(72)?;
    let mut layers = Vec::with_capacity(n);
    for _ in 0..n {
        layers.push(LayerReport {
            dense_macs: r.usz()?,
            sparse_macs: r.usz()?,
            dense_bytes: r.usz()?,
            compressed_bytes: r.usz()?,
            styles: r.usz()?,
            switches_before: r.usz()?,
            switches_after: r.usz()?,
            loads_naive: r.usz()?,
            loads_lre: r.usz()?,
        });
    }
    Ok(CompileReport { layers })
}

fn kind_tag(k: KernelKind) -> u8 {
    match k {
        KernelKind::DenseRef => 0,
        KernelKind::PatternScalar => 1,
        KernelKind::PatternTiled => 2,
        KernelKind::PatternVec => 3,
        KernelKind::PatternVecTiled => 4,
        KernelKind::QuantScalar => 5,
        KernelKind::QuantVec => 6,
    }
}

fn kind_from(tag: u8) -> Result<KernelKind> {
    Ok(match tag {
        0 => KernelKind::DenseRef,
        1 => KernelKind::PatternScalar,
        2 => KernelKind::PatternTiled,
        3 => KernelKind::PatternVec,
        4 => KernelKind::PatternVecTiled,
        5 => KernelKind::QuantScalar,
        6 => KernelKind::QuantVec,
        other => bail!("artifact corrupt: unknown kernel kind tag {other}"),
    })
}

fn elem_tag(e: ElemType) -> u8 {
    match e {
        ElemType::F32 => 0,
        ElemType::I8 => 1,
    }
}

fn elem_from(tag: u8) -> Result<ElemType> {
    Ok(match tag {
        0 => ElemType::F32,
        1 => ElemType::I8,
        other => bail!("artifact corrupt: unknown element tag {other}"),
    })
}

fn encode_quant(p: &ExecutionPlan) -> Writer {
    let mut w = Writer::default();
    w.u8(elem_tag(p.elem));
    if p.elem == ElemType::I8 {
        w.usz(p.layers.len());
        for lp in &p.layers {
            match &lp.payload {
                Payload::I8 { taps, scales } => {
                    w.i8s(taps);
                    w.f32s(scales);
                }
                // unreachable on a validated plan (validate pins every
                // layer to the plan element); keep the frame parseable
                Payload::F32(_) => {
                    w.i8s(&[]);
                    w.f32s(&[]);
                }
            }
        }
    }
    w
}

fn decode_quant(
    r: &mut Reader<'_>,
    layers: &mut [LayerPlan],
) -> Result<ElemType> {
    let elem = elem_from(r.u8()?)?;
    if elem == ElemType::I8 {
        let n = r.count(16)?;
        if n != layers.len() {
            bail!(
                "artifact corrupt: quant section covers {n} layers, \
                 plan has {}",
                layers.len()
            );
        }
        for (li, lp) in layers.iter_mut().enumerate() {
            let taps = r.i8s()?;
            let scales = r.f32s()?;
            if let Payload::F32(f) = &lp.payload {
                if !f.is_empty() {
                    bail!(
                        "artifact corrupt: layer {li} carries both f32 \
                         and i8 payloads"
                    );
                }
            }
            lp.payload = Payload::I8 { taps, scales };
        }
    }
    Ok(elem)
}

fn encode_tuning(layers: &[LayerPlan]) -> Writer {
    let mut w = Writer::default();
    w.usz(layers.len());
    for lp in layers {
        w.u8(kind_tag(lp.choice.kind));
        w.u8(lp.choice.tuned as u8);
        w.u16(lp.choice.row_tile);
        w.u16(lp.choice.fblock);
    }
    w
}

fn decode_tuning(
    r: &mut Reader<'_>,
    n_layers: usize,
) -> Result<Vec<KernelChoice>> {
    let n = r.count(6)?;
    if n != n_layers {
        bail!(
            "artifact corrupt: tuning section covers {n} layers, \
             plan has {n_layers}"
        );
    }
    let mut choices = Vec::with_capacity(n);
    for li in 0..n {
        let kind = kind_from(r.u8()?)?;
        let tuned = match r.u8()? {
            0 => false,
            1 => true,
            other => bail!(
                "artifact corrupt: layer {li} tuned flag {other}"
            ),
        };
        let row_tile = r.u16()?;
        let fblock = r.u16()?;
        choices.push(KernelChoice {
            kind,
            row_tile,
            fblock,
            tuned,
        });
    }
    Ok(choices)
}

fn encode_stats(s: &PlanStats) -> Writer {
    // pass_ms is intentionally dropped: wall times of the original compile
    // are not plan state, and a loaded plan reports its own load time
    let mut w = Writer::default();
    w.usz(s.payload_bytes);
    w.usz(s.header_bytes);
    w.usz(s.arena_bytes);
    w.usz(s.n_blocks);
    w.usz(s.threads);
    w
}

/// Serialize `plan` to its canonical artifact byte form.
pub fn encode_plan(plan: &ExecutionPlan) -> Vec<u8> {
    let mut w = Writer::default();
    w.buf.extend_from_slice(MAGIC);
    w.u32(FORMAT_VERSION);
    w.section(SEC_IR, encode_ir(&plan.ir));
    w.section(SEC_LAYERS, encode_layers(&plan.layers));
    w.section(SEC_SCHEDULE, encode_schedule(plan));
    w.section(SEC_REPORT, encode_report(&plan.report));
    w.section(SEC_STATS, encode_stats(&plan.stats));
    w.section(SEC_TUNING, encode_tuning(&plan.layers));
    w.section(SEC_QUANT, encode_quant(plan));
    let sum = fnv1a64(&w.buf);
    w.u64(sum);
    w.buf
}

/// Deserialize and validate an artifact produced by [`encode_plan`].
/// Failures (truncation, checksum, framing, validation) surface as
/// [`ServeError::Artifact`] with the full cause chain in the message.
pub fn decode_plan(
    bytes: &[u8],
) -> Result<ExecutionPlan, ServeError> {
    decode_plan_impl(bytes).map_err(|e| ServeError::artifact(&e))
}

fn decode_plan_impl(bytes: &[u8]) -> Result<ExecutionPlan> {
    let t = Stopwatch::start();
    if bytes.len() < MAGIC.len() + 4 + 8 {
        bail!("artifact truncated: {} bytes", bytes.len());
    }
    let (body, tail) = bytes.split_at(bytes.len() - 8);
    let stored = u64::from_le_bytes(tail.try_into().unwrap());
    let computed = fnv1a64(body);
    if stored != computed {
        bail!(
            "artifact checksum mismatch: stored {stored:#018x}, \
             computed {computed:#018x}"
        );
    }
    let mut r = Reader::new(body);
    if r.take(4)? != MAGIC {
        bail!("not a plan artifact (bad magic)");
    }
    let version = r.u32()?;
    if !(MIN_FORMAT_VERSION..=FORMAT_VERSION).contains(&version) {
        bail!(
            "unsupported plan artifact version {version} \
             (this build reads {MIN_FORMAT_VERSION}..={FORMAT_VERSION})"
        );
    }
    let mut sec = r.section(SEC_IR)?;
    let ir = decode_ir(&mut sec)?;
    sec.finish_section(SEC_IR)?;
    let mut sec = r.section(SEC_LAYERS)?;
    let mut layers = decode_layers(&mut sec)?;
    sec.finish_section(SEC_LAYERS)?;
    let mut sec = r.section(SEC_SCHEDULE)?;
    let sched = decode_schedule(&mut sec)?;
    sec.finish_section(SEC_SCHEDULE)?;
    let mut sec = r.section(SEC_REPORT)?;
    let report = decode_report(&mut sec)?;
    sec.finish_section(SEC_REPORT)?;
    let mut sec = r.section(SEC_STATS)?;
    let payload_bytes = sec.usz()?;
    let header_bytes = sec.usz()?;
    let arena_bytes = sec.usz()?;
    let n_blocks = sec.usz()?;
    let stat_threads = sec.usz()?;
    sec.finish_section(SEC_STATS)?;
    let mut sec = r.section(SEC_TUNING)?;
    let choices = decode_tuning(&mut sec, layers.len())?;
    sec.finish_section(SEC_TUNING)?;
    for (lp, choice) in layers.iter_mut().zip(choices) {
        lp.choice = choice;
    }
    // v2 predates quantization: no QUANT section, always f32
    let elem = if version >= 3 {
        let mut sec = r.section(SEC_QUANT)?;
        let elem = decode_quant(&mut sec, &mut layers)?;
        sec.finish_section(SEC_QUANT)?;
        elem
    } else {
        ElemType::F32
    };
    if r.remaining() != 0 {
        bail!("artifact corrupt: {} trailing bytes", r.remaining());
    }
    let plan = ExecutionPlan {
        ir,
        layers,
        steps: sched.steps,
        dims: sched.dims,
        in_dims: sched.in_dims,
        slot_sizes: sched.slot_sizes,
        fmap_elems: sched.fmap_elems,
        proj_scratch_elems: sched.proj_scratch_elems,
        gap_len: sched.gap_len,
        threads: sched.threads,
        elem,
        report,
        stats: PlanStats {
            pass_ms: vec![("artifact-load", t.ms())],
            payload_bytes,
            header_bytes,
            arena_bytes,
            n_blocks,
            threads: stat_threads,
        },
    };
    plan.validate()?;
    Ok(plan)
}

/// Write `plan` to `path` (atomically: temp file + rename, so a torn
/// write never leaves a half-artifact where a registry might load it).
pub fn save(
    plan: &ExecutionPlan,
    path: impl AsRef<Path>,
) -> Result<(), ServeError> {
    save_impl(plan, path.as_ref())
        .map_err(|e| ServeError::artifact(&e))
}

fn save_impl(plan: &ExecutionPlan, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let bytes = encode_plan(plan);
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, &bytes)
        .with_context(|| format!("writing {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    Ok(())
}

/// Read, checksum-verify, and validate a plan artifact from `path`.
pub fn load(
    path: impl AsRef<Path>,
) -> Result<ExecutionPlan, ServeError> {
    load_impl(path.as_ref()).map_err(|e| ServeError::artifact(&e))
}

fn load_impl(path: &Path) -> Result<ExecutionPlan> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading plan artifact {}", path.display()))?;
    decode_plan_impl(&bytes)
        .with_context(|| format!("loading plan artifact {}", path.display()))
}

/// Prove the round-trip guarantee on `probes` seeded random images: the
/// loaded plan's executor must produce **bit-identical** logits to the
/// original's, for every kernel in the registry and for the per-layer
/// auto dispatch through the (possibly tuned) baked kernel choices.
pub fn verify_roundtrip(
    original: &ExecutionPlan,
    loaded: &ExecutionPlan,
    probes: usize,
    seed: u64,
) -> Result<(), ServeError> {
    verify_roundtrip_impl(original, loaded, probes, seed)
        .map_err(|e| ServeError::artifact(&e))
}

fn verify_roundtrip_impl(
    original: &ExecutionPlan,
    loaded: &ExecutionPlan,
    probes: usize,
    seed: u64,
) -> Result<()> {
    let mut pairs: Vec<(&'static str, Executor<'_>, Executor<'_>)> =
        KERNEL_KINDS
            .into_iter()
            .map(|kind| {
                (
                    kind.name(),
                    Executor::new(original, kind),
                    Executor::new(loaded, kind),
                )
            })
            .collect();
    pairs.push((
        "auto",
        Executor::auto(original),
        Executor::auto(loaded),
    ));
    for (name, a, b) in &mut pairs {
        for i in 0..probes {
            // probes come from the canonical request-trace generator, so
            // round-trip verification exercises exactly what serving does
            let img = super::loadgen::request_image(
                original.in_dims,
                seed,
                i as u64,
            );
            let want = a.execute(&img);
            let got = b.execute(&img);
            if want
                .iter()
                .zip(&got)
                .any(|(x, y)| x.to_bits() != y.to_bits())
            {
                bail!(
                    "artifact round-trip drift: probe {i} ({name}) \
                     differs from the in-memory plan"
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mobile::costmodel::TuneConfig;
    use crate::mobile::plan::{
        compile_plan, compile_plan_quant, compile_plan_tuned,
    };
    use crate::mobile::synth;

    fn small_plan(threads: usize) -> ExecutionPlan {
        let (spec, mut params) =
            synth::vgg_style("art_vgg", 8, 4, &[4, 6], 5);
        synth::pattern_prune(&spec, &mut params, 0.25);
        let ir = ModelIR::build(&spec, &params).unwrap();
        compile_plan(ir, threads).unwrap()
    }

    fn small_quant_plan(threads: usize) -> ExecutionPlan {
        let (spec, mut params) =
            synth::vgg_style("art_vgg", 8, 4, &[4, 6], 5);
        synth::pattern_prune(&spec, &mut params, 0.25);
        let ir = ModelIR::build(&spec, &params).unwrap();
        compile_plan_quant(ir, threads).unwrap()
    }

    /// Locate the (id, len, payload) frame of section `id` in an
    /// encoded artifact; returns the offset of the frame header.
    fn section_frame(bytes: &[u8], id: u32) -> usize {
        let body = &bytes[..bytes.len() - 8];
        let mut pos = 8;
        while pos < body.len() {
            let got = u32::from_le_bytes(
                body[pos..pos + 4].try_into().unwrap(),
            );
            let len = u64::from_le_bytes(
                body[pos + 4..pos + 12].try_into().unwrap(),
            ) as usize;
            if got == id {
                return pos;
            }
            pos += 12 + len;
        }
        panic!("section {id} not found");
    }

    fn restamp(bytes: &mut [u8]) {
        let blen = bytes.len() - 8;
        let sum = fnv1a64(&bytes[..blen]);
        bytes[blen..].copy_from_slice(&sum.to_le_bytes());
    }

    /// Rewrite a v3 f32 artifact into the v2 layout: drop the QUANT
    /// section, stamp version 2, recompute the checksum.
    fn downgrade_to_v2(bytes: &[u8]) -> Vec<u8> {
        let quant = section_frame(bytes, SEC_QUANT);
        let mut out = bytes[..quant].to_vec();
        out[4..8].copy_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&[0u8; 8]);
        restamp(&mut out);
        out
    }

    #[test]
    fn encode_is_canonical_and_decodes() {
        let plan = small_plan(2);
        let bytes = encode_plan(&plan);
        let back = decode_plan(&bytes).unwrap();
        // canonical form: re-encoding the decoded plan is byte-identical
        assert_eq!(encode_plan(&back), bytes);
        assert_eq!(back.threads, plan.threads);
        assert_eq!(back.layers.len(), plan.layers.len());
        assert_eq!(back.slot_sizes, plan.slot_sizes);
        assert_eq!(back.fmap_elems, plan.fmap_elems);
        verify_roundtrip(&plan, &back, 3, 42).unwrap();
    }

    #[test]
    fn corruption_is_rejected() {
        let plan = small_plan(1);
        let bytes = encode_plan(&plan);
        // flip one payload byte -> checksum must catch it
        let mut bad = bytes.clone();
        let mid = bad.len() / 2;
        bad[mid] ^= 0x40;
        let err = decode_plan(&bad).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // truncation
        assert!(decode_plan(&bytes[..bytes.len() - 9]).is_err());
        assert!(decode_plan(&bytes[..4]).is_err());
        // bad magic (checksum recomputed so the magic check itself fires)
        let mut nm = bytes.clone();
        nm[0] = b'X';
        let blen = nm.len() - 8;
        let sum = fnv1a64(&nm[..blen]);
        nm[blen..].copy_from_slice(&sum.to_le_bytes());
        let err = decode_plan(&nm).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        // future version
        let mut nv = bytes.clone();
        nv[4..8].copy_from_slice(&99u32.to_le_bytes());
        let blen = nv.len() - 8;
        let sum = fnv1a64(&nv[..blen]);
        nv[blen..].copy_from_slice(&sum.to_le_bytes());
        let err = decode_plan(&nv).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
    }

    #[test]
    fn tuned_choices_survive_roundtrip() {
        let (spec, mut params) =
            synth::vgg_style("art_tuned", 8, 4, &[4, 6], 5);
        synth::pattern_prune(&spec, &mut params, 0.25);
        let ir = ModelIR::build(&spec, &params).unwrap();
        let (plan, report) =
            compile_plan_tuned(ir, 2, TuneConfig::smoke()).unwrap();
        assert_eq!(report.layers.len(), plan.layers.len());
        assert!(plan.layers.iter().all(|lp| lp.choice.tuned));
        let back = decode_plan(&encode_plan(&plan)).unwrap();
        for (a, b) in plan.layers.iter().zip(&back.layers) {
            assert_eq!(a.choice, b.choice);
        }
        // canonical even with tuned choices baked in
        assert_eq!(encode_plan(&back), encode_plan(&plan));
        // the tuned plan executes bit-identically after the round trip,
        // including per-layer auto dispatch over the tuned choices
        verify_roundtrip(&plan, &back, 2, 11).unwrap();
    }

    #[test]
    fn older_version_is_rejected() {
        let plan = small_plan(1);
        // rewrite the version field to 1 (pre-TUNING layout) and fix the
        // checksum so the version check itself fires with a clear error
        let mut v1 = encode_plan(&plan);
        v1[4..8].copy_from_slice(&1u32.to_le_bytes());
        let blen = v1.len() - 8;
        let sum = fnv1a64(&v1[..blen]);
        v1[blen..].copy_from_slice(&sum.to_le_bytes());
        let err = decode_plan(&v1).unwrap_err().to_string();
        assert!(err.contains("version 1"), "{err}");
        assert!(err.contains("reads 2"), "{err}");
    }

    #[test]
    fn v2_artifacts_load_as_f32_only() {
        let plan = small_plan(2);
        let v2 = downgrade_to_v2(&encode_plan(&plan));
        let back = decode_plan(&v2).unwrap();
        assert_eq!(back.elem, ElemType::F32);
        assert_eq!(back.layers.len(), plan.layers.len());
        verify_roundtrip(&plan, &back, 2, 5).unwrap();
    }

    #[test]
    fn quantized_plan_roundtrips_bit_identically() {
        let plan = small_quant_plan(2);
        assert_eq!(plan.elem, ElemType::I8);
        let bytes = encode_plan(&plan);
        let back = decode_plan(&bytes).unwrap();
        assert_eq!(back.elem, ElemType::I8);
        // canonical form survives the i8 payload detour
        assert_eq!(encode_plan(&back), bytes);
        for (a, b) in plan.layers.iter().zip(&back.layers) {
            assert_eq!(a.payload.i8_taps(), b.payload.i8_taps());
        }
        // save -> load -> execute is bit-identical, every kernel + auto
        verify_roundtrip(&plan, &back, 3, 21).unwrap();
        // the artifact carries the shrunken payload on the wire too
        let f32_plan = small_plan(2);
        assert!(
            plan.stats.payload_bytes * 2
                <= f32_plan.stats.payload_bytes,
            "i8 {} vs f32 {}",
            plan.stats.payload_bytes,
            f32_plan.stats.payload_bytes
        );
    }

    #[test]
    fn corrupt_quant_section_is_rejected() {
        let plan = small_quant_plan(1);
        let bytes = encode_plan(&plan);
        let frame = section_frame(&bytes, SEC_QUANT);
        // plain bit flip inside QUANT -> the checksum catches it
        let mut bad = bytes.clone();
        bad[frame + 13] ^= 0x20;
        let err = decode_plan(&bad).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
        // unknown element tag, checksum restamped -> strict decode
        let mut tag = bytes.clone();
        tag[frame + 12] = 9;
        restamp(&mut tag);
        let err = decode_plan(&tag).unwrap_err().to_string();
        assert!(err.contains("element tag"), "{err}");
        // shrink the section length field -> framing/truncation
        let mut tr = bytes.clone();
        let len = u64::from_le_bytes(
            tr[frame + 4..frame + 12].try_into().unwrap(),
        );
        tr[frame + 4..frame + 12]
            .copy_from_slice(&(len - 1).to_le_bytes());
        restamp(&mut tr);
        let err = decode_plan(&tr).unwrap_err().to_string();
        assert!(
            err.contains("truncated") || err.contains("corrupt"),
            "{err}"
        );
    }

    #[test]
    fn save_load_file_roundtrip() {
        let plan = small_plan(2);
        let dir = std::env::temp_dir()
            .join(format!("repro_artifact_{}", std::process::id()));
        let path = dir.join("plan.rpln");
        save(&plan, &path).unwrap();
        let back = load(&path).unwrap();
        verify_roundtrip(&plan, &back, 2, 7).unwrap();
        // the loader reports its own timing, not the compile passes
        assert_eq!(back.stats.pass_ms.len(), 1);
        assert_eq!(back.stats.pass_ms[0].0, "artifact-load");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
