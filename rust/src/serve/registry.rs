//! Concurrent compiled-plan cache with single-flight misses and LRU
//! eviction.
//!
//! A deployment fleet serves many `(model, scheme, rate, threads)`
//! configurations; compiling an [`ExecutionPlan`] is the expensive step
//! (the whole `PassManager` lowering), so it must happen **at most once
//! per key** even when many requests miss simultaneously. The registry
//! does not know how plans are produced — callers pass a build closure
//! (compile from a spec, or load a [`super::artifact`]) and the registry
//! guarantees:
//!
//! * **hit**: a cached `Arc<ExecutionPlan>` is returned without building;
//! * **miss**: exactly one caller runs the closure (single-flight); every
//!   concurrent caller for the same key blocks on a condvar and receives
//!   the same `Arc`;
//! * **failure**: the builder's error propagates to it alone, the
//!   in-flight marker is removed, and blocked callers retry (the next one
//!   becomes the builder);
//! * **eviction**: beyond `capacity` ready plans, the least-recently-used
//!   entry is dropped (in-flight builds are never evicted).

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use crate::mobile::plan::ExecutionPlan;

/// Cache key for one servable configuration. `rate` is quantized to
/// milli-units so the key is `Eq`/`Ord` without float comparisons.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct PlanKey {
    pub model: String,
    pub scheme: String,
    pub rate_milli: u64,
    pub threads: usize,
    /// whether the plan was compiled with the empirical kernel autotuner
    /// — tuned and analytic plans carry different baked
    /// [`KernelChoice`](crate::mobile::costmodel::KernelChoice)s and
    /// must never alias in the cache
    pub tuned: bool,
}

impl PlanKey {
    pub fn new(
        model: &str,
        scheme: &str,
        rate: f64,
        threads: usize,
    ) -> Self {
        PlanKey {
            model: model.to_string(),
            scheme: scheme.to_string(),
            rate_milli: (rate.max(0.0) * 1000.0).round() as u64,
            threads,
            tuned: false,
        }
    }

    /// Mark the key as an autotuned-plan configuration.
    pub fn tuned(mut self) -> Self {
        self.tuned = true;
        self
    }

    pub fn rate(&self) -> f64 {
        self.rate_milli as f64 / 1000.0
    }
}

impl std::fmt::Display for PlanKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}/{}@{:.1}x/t{}{}",
            self.model,
            self.scheme,
            self.rate(),
            self.threads,
            if self.tuned { "/tuned" } else { "" }
        )
    }
}

enum Slot {
    Ready { plan: Arc<ExecutionPlan>, last_used: u64 },
    Building,
}

/// Clears a key's in-flight `Building` marker (and wakes waiters) unless
/// disarmed — the builder's panic-safety net.
struct BuildGuard<'a> {
    reg: &'a PlanRegistry,
    key: &'a PlanKey,
    armed: bool,
}

impl Drop for BuildGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.reg.remove_building_marker(self.key);
        }
    }
}

#[derive(Default)]
struct Inner {
    slots: BTreeMap<PlanKey, Slot>,
    tick: u64,
    hits: u64,
    misses: u64,
    coalesced: u64,
    evictions: u64,
}

/// Point-in-time registry counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegistryStats {
    pub ready: usize,
    pub building: usize,
    pub capacity: usize,
    pub hits: u64,
    /// builds started (one per single-flight miss)
    pub misses: u64,
    /// callers that waited on someone else's in-flight build
    pub coalesced: u64,
    pub evictions: u64,
}

/// Concurrent `(model, scheme, rate, threads) -> Arc<ExecutionPlan>`
/// cache; see the module docs for the miss/eviction contract.
pub struct PlanRegistry {
    inner: Mutex<Inner>,
    ready_cv: Condvar,
    capacity: usize,
}

impl PlanRegistry {
    /// `capacity` bounds the number of *ready* plans kept resident.
    pub fn new(capacity: usize) -> Self {
        PlanRegistry {
            inner: Mutex::new(Inner::default()),
            ready_cv: Condvar::new(),
            capacity: capacity.max(1),
        }
    }

    /// Fetch `key`, running `build` at most once across all concurrent
    /// callers when it is absent.
    pub fn get_or_build(
        &self,
        key: &PlanKey,
        build: impl FnOnce() -> Result<ExecutionPlan>,
    ) -> Result<Arc<ExecutionPlan>> {
        let mut g = self.inner.lock().unwrap();
        let mut waited = false;
        loop {
            let cached = match g.slots.get(key) {
                Some(Slot::Ready { plan, .. }) => Some(plan.clone()),
                Some(Slot::Building) => {
                    if !waited {
                        waited = true;
                        g.coalesced += 1;
                    }
                    g = self.ready_cv.wait(g).unwrap();
                    continue;
                }
                None => None,
            };
            match cached {
                Some(plan) => {
                    g.tick += 1;
                    let tick = g.tick;
                    if let Some(Slot::Ready { last_used, .. }) =
                        g.slots.get_mut(key)
                    {
                        *last_used = tick;
                    }
                    g.hits += 1;
                    return Ok(plan);
                }
                None => {
                    g.slots.insert(key.clone(), Slot::Building);
                    g.misses += 1;
                    break;
                }
            }
        }
        drop(g);
        // expensive: compile or artifact-load, outside the lock. The
        // guard clears the Building marker on *any* exit that did not
        // install a Ready plan — error return or panic unwind — so a
        // failed builder can never wedge the key for the waiters.
        let mut guard = BuildGuard {
            reg: self,
            key,
            armed: true,
        };
        let plan = Arc::new(build()?);
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        g.slots.insert(
            key.clone(),
            Slot::Ready {
                plan: plan.clone(),
                last_used: tick,
            },
        );
        self.evict_lru(&mut g);
        drop(g);
        guard.armed = false;
        self.ready_cv.notify_all();
        Ok(plan)
    }

    fn remove_building_marker(&self, key: &PlanKey) {
        let mut g = self.inner.lock().unwrap();
        if matches!(g.slots.get(key), Some(Slot::Building)) {
            g.slots.remove(key);
        }
        drop(g);
        self.ready_cv.notify_all();
    }

    fn evict_lru(&self, g: &mut Inner) {
        loop {
            let ready = g
                .slots
                .iter()
                .filter(|(_, s)| matches!(s, Slot::Ready { .. }))
                .count();
            if ready <= self.capacity {
                return;
            }
            let victim = g
                .slots
                .iter()
                .filter_map(|(k, s)| match s {
                    Slot::Ready { last_used, .. } => {
                        Some((*last_used, k.clone()))
                    }
                    Slot::Building => None,
                })
                .min()
                .map(|(_, k)| k);
            match victim {
                Some(k) => {
                    g.slots.remove(&k);
                    g.evictions += 1;
                }
                None => return,
            }
        }
    }

    /// Drop a specific entry (e.g. after its artifact was republished).
    /// No-op for in-flight builds.
    pub fn evict(&self, key: &PlanKey) -> bool {
        let mut g = self.inner.lock().unwrap();
        if matches!(g.slots.get(key), Some(Slot::Ready { .. })) {
            g.slots.remove(key);
            g.evictions += 1;
            true
        } else {
            false
        }
    }

    pub fn stats(&self) -> RegistryStats {
        let g = self.inner.lock().unwrap();
        let ready = g
            .slots
            .values()
            .filter(|s| matches!(s, Slot::Ready { .. }))
            .count();
        RegistryStats {
            ready,
            building: g.slots.len() - ready,
            capacity: self.capacity,
            hits: g.hits,
            misses: g.misses,
            coalesced: g.coalesced,
            evictions: g.evictions,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mobile::ir::ModelIR;
    use crate::mobile::plan::compile_plan;
    use crate::mobile::synth;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn build_plan(seed: u64) -> Result<ExecutionPlan> {
        let (spec, mut params) =
            synth::vgg_style("reg_vgg", 8, 4, &[4], seed);
        synth::pattern_prune(&spec, &mut params, 0.25);
        compile_plan(ModelIR::build(&spec, &params)?, 1)
    }

    #[test]
    fn key_quantizes_rate() {
        let a = PlanKey::new("m", "pattern", 8.0, 2);
        let b = PlanKey::new("m", "pattern", 8.0001, 2);
        assert_eq!(a, b);
        assert_eq!(a.rate(), 8.0);
        let c = PlanKey::new("m", "pattern", 8.1, 2);
        assert_ne!(a, c);
        assert!(format!("{a}").contains("pattern"));
    }

    #[test]
    fn tuned_key_never_aliases_analytic() {
        let a = PlanKey::new("m", "pattern", 8.0, 2);
        let t = PlanKey::new("m", "pattern", 8.0, 2).tuned();
        assert_ne!(a, t);
        assert!(format!("{t}").contains("tuned"));
        assert!(!format!("{a}").contains("tuned"));
        // both fit in the cache side by side
        let reg = PlanRegistry::new(4);
        let pa = reg.get_or_build(&a, || build_plan(1)).unwrap();
        let pt = reg.get_or_build(&t, || build_plan(1)).unwrap();
        assert!(!Arc::ptr_eq(&pa, &pt));
        assert_eq!(reg.stats().ready, 2);
    }

    #[test]
    fn hit_returns_same_arc_without_rebuilding() {
        let reg = PlanRegistry::new(4);
        let key = PlanKey::new("m", "pattern", 8.0, 1);
        let builds = AtomicUsize::new(0);
        let a = reg
            .get_or_build(&key, || {
                builds.fetch_add(1, Ordering::SeqCst);
                build_plan(1)
            })
            .unwrap();
        let b = reg
            .get_or_build(&key, || {
                builds.fetch_add(1, Ordering::SeqCst);
                build_plan(1)
            })
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(builds.load(Ordering::SeqCst), 1);
        let s = reg.stats();
        assert_eq!((s.hits, s.misses, s.ready), (1, 1, 1));
    }

    #[test]
    fn concurrent_misses_single_flight() {
        let reg = PlanRegistry::new(4);
        let key = PlanKey::new("m", "pattern", 8.0, 1);
        let builds = AtomicUsize::new(0);
        let plans = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let p = reg
                        .get_or_build(&key, || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            // hold the build long enough that the other
                            // threads observe the Building slot
                            std::thread::sleep(
                                std::time::Duration::from_millis(40),
                            );
                            build_plan(1)
                        })
                        .unwrap();
                    plans.lock().unwrap().push(p);
                });
            }
        });
        assert_eq!(builds.load(Ordering::SeqCst), 1, "single flight");
        let plans = plans.into_inner().unwrap();
        assert_eq!(plans.len(), 8);
        assert!(plans.iter().all(|p| Arc::ptr_eq(p, &plans[0])));
        let s = reg.stats();
        assert_eq!(s.misses, 1, "exactly one build started");
        assert_eq!(s.hits, 7, "every non-builder resolved to a hit");
    }

    #[test]
    fn failed_build_propagates_and_allows_retry() {
        let reg = PlanRegistry::new(4);
        let key = PlanKey::new("m", "pattern", 8.0, 1);
        let err = reg
            .get_or_build(&key, || anyhow::bail!("synthetic build failure"))
            .unwrap_err();
        assert!(err.to_string().contains("synthetic"));
        assert_eq!(reg.stats().ready, 0);
        assert_eq!(reg.stats().building, 0);
        // the key is buildable again afterwards
        let p = reg.get_or_build(&key, || build_plan(1)).unwrap();
        assert_eq!(p.threads, 1);
    }

    #[test]
    fn panicking_build_does_not_wedge_the_key() {
        let reg = PlanRegistry::new(4);
        let key = PlanKey::new("m", "pattern", 8.0, 1);
        let unwound = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let _ = reg.get_or_build(&key, || panic!("builder died"));
            }),
        );
        assert!(unwound.is_err());
        // the Building marker was cleared by the drop guard: the key is
        // immediately buildable again, no waiter can hang on it
        assert_eq!(reg.stats().building, 0);
        let p = reg.get_or_build(&key, || build_plan(1)).unwrap();
        assert_eq!(p.threads, 1);
    }

    #[test]
    fn lru_eviction_beyond_capacity() {
        let reg = PlanRegistry::new(2);
        let k1 = PlanKey::new("m1", "pattern", 8.0, 1);
        let k2 = PlanKey::new("m2", "pattern", 8.0, 1);
        let k3 = PlanKey::new("m3", "pattern", 8.0, 1);
        reg.get_or_build(&k1, || build_plan(1)).unwrap();
        reg.get_or_build(&k2, || build_plan(2)).unwrap();
        // touch k1 so k2 is the LRU
        reg.get_or_build(&k1, || build_plan(1)).unwrap();
        reg.get_or_build(&k3, || build_plan(3)).unwrap();
        let s = reg.stats();
        assert_eq!(s.ready, 2);
        assert_eq!(s.evictions, 1);
        // k2 was evicted: fetching it builds again
        let builds = AtomicUsize::new(0);
        reg.get_or_build(&k2, || {
            builds.fetch_add(1, Ordering::SeqCst);
            build_plan(2)
        })
        .unwrap();
        assert_eq!(builds.load(Ordering::SeqCst), 1);
        // ... and k1 was not: no rebuild
        reg.get_or_build(&k1, || {
            builds.fetch_add(1, Ordering::SeqCst);
            build_plan(1)
        })
        .unwrap();
        assert_eq!(builds.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn explicit_evict() {
        let reg = PlanRegistry::new(4);
        let key = PlanKey::new("m", "pattern", 4.0, 1);
        reg.get_or_build(&key, || build_plan(1)).unwrap();
        assert!(reg.evict(&key));
        assert!(!reg.evict(&key));
        assert_eq!(reg.stats().ready, 0);
    }
}
