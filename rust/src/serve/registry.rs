//! Concurrent compiled-plan caches with single-flight misses, LRU + byte
//! -budget eviction, and per-tenant sharding.
//!
//! A deployment fleet serves many `(model, scheme, rate, threads)`
//! configurations; compiling an [`ExecutionPlan`] is the expensive step
//! (the whole `PassManager` lowering), so it must happen **at most once
//! per key** even when many requests miss simultaneously. The registry
//! does not know how plans are produced — callers pass a build closure
//! (compile from a spec, or load a [`super::artifact`]) and the registry
//! guarantees:
//!
//! * **hit**: a cached `Arc<ExecutionPlan>` is returned without building;
//! * **miss**: exactly one caller runs the closure (single-flight); every
//!   concurrent caller for the same key blocks on a condvar and receives
//!   the same `Arc` (counted as **coalesced**);
//! * every lookup resolves as *exactly one* of hit / miss / coalesced, so
//!   `hits + misses + coalesced == lookups` holds at any concurrency
//!   (the churn test hammers this invariant);
//! * **failure**: the builder's error surfaces as a typed
//!   [`ServeError::Build`] to it alone, the in-flight marker is removed,
//!   and blocked callers retry (the next one becomes the builder);
//! * **circuit breaking**: consecutive build failures per key are
//!   counted; after [`BREAK_AFTER`] in a row the key's circuit opens
//!   and lookups fail fast (counted as `shed_broken`) for an
//!   exponentially growing number of *lookup ticks* — a deterministic
//!   logical clock, not wall time — so a permanently broken
//!   configuration sheds its load instead of re-running a doomed
//!   compile on every request. One probe build is admitted when the
//!   window lapses (half-open); success resets the key;
//! * **eviction**: beyond `capacity` ready plans — or beyond the
//!   registry's byte budget, measured by [`plan_bytes`] — the
//!   least-recently-used entry is dropped (in-flight builds are never
//!   evicted, and at least one ready plan always survives).
//!
//! [`ShardedRegistry`] gives every gateway tenant its own
//! [`PlanRegistry`] shard with an independent capacity + memory budget,
//! so one tenant churning through variants can never evict another
//! tenant's plans.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};

use crate::mobile::plan::ExecutionPlan;

use super::error::ServeError;
use super::{lock_clean, wait_clean};

/// Resident footprint the registry charges for one plan: packed payload
/// taps + packed kernel headers + the per-executor arena the plan sizes.
pub fn plan_bytes(plan: &ExecutionPlan) -> u64 {
    (plan.stats.payload_bytes
        + plan.stats.header_bytes
        + plan.stats.arena_bytes) as u64
}

/// Cache key for one servable configuration. `rate` is quantized to
/// milli-units so the key is `Eq`/`Ord` without float comparisons.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct PlanKey {
    pub model: String,
    pub scheme: String,
    pub rate_milli: u64,
    pub threads: usize,
    /// whether the plan was compiled with the empirical kernel autotuner
    /// — tuned and analytic plans carry different baked
    /// [`KernelChoice`](crate::mobile::costmodel::KernelChoice)s and
    /// must never alias in the cache
    pub tuned: bool,
    /// whether the plan carries an i8 payload
    /// ([`ElemType::I8`](crate::mobile::plan::ElemType)) — quantized and
    /// f32 plans produce different bits and must never alias in the
    /// cache; the quantized entry also charges ~4x fewer payload bytes
    /// against the shard budget ([`plan_bytes`])
    pub quant: bool,
}

impl PlanKey {
    pub fn new(
        model: &str,
        scheme: &str,
        rate: f64,
        threads: usize,
    ) -> Self {
        PlanKey {
            model: model.to_string(),
            scheme: scheme.to_string(),
            rate_milli: (rate.max(0.0) * 1000.0).round() as u64,
            threads,
            tuned: false,
            quant: false,
        }
    }

    /// Mark the key as an autotuned-plan configuration.
    pub fn tuned(mut self) -> Self {
        self.tuned = true;
        self
    }

    /// Mark the key as an i8-quantized-plan configuration.
    pub fn quantized(mut self) -> Self {
        self.quant = true;
        self
    }

    pub fn rate(&self) -> f64 {
        self.rate_milli as f64 / 1000.0
    }
}

impl std::fmt::Display for PlanKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}/{}@{:.1}x/t{}{}{}",
            self.model,
            self.scheme,
            self.rate(),
            self.threads,
            if self.tuned { "/tuned" } else { "" },
            if self.quant { "/i8" } else { "" }
        )
    }
}

enum Slot {
    Ready {
        plan: Arc<ExecutionPlan>,
        last_used: u64,
        bytes: u64,
    },
    Building,
}

/// Consecutive build failures open a key's circuit after this many in a
/// row.
pub const BREAK_AFTER: u64 = 3;

/// Base open-window length, in lookup ticks; doubles per additional
/// consecutive failure (capped at `<< 6`).
pub const BREAK_BACKOFF: u64 = 8;

/// Per-key consecutive-failure record (the circuit breaker's state).
#[derive(Clone, Copy, Debug, Default)]
struct FailState {
    /// consecutive failures; reset to 0 by any successful build
    failures: u64,
    /// circuit is open (lookups fail fast) until this lookup tick
    open_until: Option<u64>,
}

/// Clears a key's in-flight `Building` marker (and wakes waiters) unless
/// disarmed — the builder's panic-safety net.
struct BuildGuard<'a> {
    reg: &'a PlanRegistry,
    key: &'a PlanKey,
    armed: bool,
}

impl Drop for BuildGuard<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.reg.remove_building_marker(self.key);
        }
    }
}

#[derive(Default)]
struct Inner {
    slots: BTreeMap<PlanKey, Slot>,
    fail: BTreeMap<PlanKey, FailState>,
    tick: u64,
    resident_bytes: u64,
    hits: u64,
    misses: u64,
    coalesced: u64,
    evictions: u64,
    build_failures: u64,
    shed_broken: u64,
}

/// Point-in-time registry counters. `hits + misses + coalesced` always
/// equals the number of [`PlanRegistry::get_or_build`] calls that have
/// returned.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegistryStats {
    pub ready: usize,
    pub building: usize,
    pub capacity: usize,
    /// resident plan footprint, bytes ([`plan_bytes`] summed)
    pub resident_bytes: u64,
    /// byte budget (`u64::MAX` = unbounded)
    pub byte_budget: u64,
    pub hits: u64,
    /// builds started (one per single-flight miss)
    pub misses: u64,
    /// callers that waited on someone else's in-flight build and received
    /// its plan
    pub coalesced: u64,
    pub evictions: u64,
    /// builds that returned an error (feeds the per-key circuit breaker)
    pub build_failures: u64,
    /// keys whose circuit is currently open (failing fast)
    pub broken: usize,
    /// lookups failed fast by an open circuit, without running a build
    pub shed_broken: u64,
}

impl RegistryStats {
    /// Fold another shard's counters into this one (capacity/budget sum;
    /// `ready`/`building` sum; counters sum).
    pub fn absorb(&mut self, other: &RegistryStats) {
        self.ready += other.ready;
        self.building += other.building;
        self.capacity += other.capacity;
        self.resident_bytes += other.resident_bytes;
        self.byte_budget = self.byte_budget.saturating_add(other.byte_budget);
        self.hits += other.hits;
        self.misses += other.misses;
        self.coalesced += other.coalesced;
        self.evictions += other.evictions;
        self.build_failures += other.build_failures;
        self.broken += other.broken;
        self.shed_broken += other.shed_broken;
    }

    /// Every [`PlanRegistry::get_or_build`] call that has returned
    /// resolves as exactly one of hit / miss / coalesced / shed-broken.
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses + self.coalesced + self.shed_broken
    }
}

/// Concurrent `(model, scheme, rate, threads) -> Arc<ExecutionPlan>`
/// cache; see the module docs for the miss/eviction contract.
pub struct PlanRegistry {
    inner: Mutex<Inner>,
    ready_cv: Condvar,
    capacity: usize,
    byte_budget: u64,
}

impl PlanRegistry {
    /// `capacity` bounds the number of *ready* plans kept resident; the
    /// byte footprint is unbounded.
    pub fn new(capacity: usize) -> Self {
        Self::with_byte_budget(capacity, u64::MAX)
    }

    /// Bound both the ready-plan count and their byte footprint
    /// ([`plan_bytes`] summed); whichever limit is exceeded first evicts
    /// LRU-wise. A single plan larger than the budget still resides (the
    /// registry never evicts below one plan) — gateways that need a hard
    /// refusal check [`plan_bytes`] against the budget at spawn.
    pub fn with_byte_budget(capacity: usize, byte_budget: u64) -> Self {
        PlanRegistry {
            inner: Mutex::new(Inner::default()),
            ready_cv: Condvar::new(),
            capacity: capacity.max(1),
            byte_budget: byte_budget.max(1),
        }
    }

    /// Fetch `key`, running `build` at most once across all concurrent
    /// callers when it is absent. Build failures come back as
    /// [`ServeError::Build`] carrying the key and the underlying
    /// message, and count toward the key's circuit breaker: after
    /// [`BREAK_AFTER`] consecutive failures the circuit opens and
    /// lookups fail fast (no build) for an exponentially-backed-off
    /// number of lookup ticks.
    pub fn get_or_build(
        &self,
        key: &PlanKey,
        build: impl FnOnce() -> Result<ExecutionPlan, ServeError>,
    ) -> Result<Arc<ExecutionPlan>, ServeError> {
        let mut g = lock_clean(&self.inner);
        // the lookup tick is the breaker's logical clock: deterministic
        // in the lookup sequence, independent of wall time
        g.tick += 1;
        let entry_tick = g.tick;
        if let Some(fs) = g.fail.get(key) {
            if let Some(open_until) = fs.open_until {
                if entry_tick < open_until {
                    let failures = fs.failures;
                    g.shed_broken += 1;
                    return Err(ServeError::Build {
                        key: key.to_string(),
                        msg: format!(
                            "circuit open after {failures} consecutive \
                             build failures; retry admitted in {} \
                             lookups",
                            open_until - entry_tick
                        ),
                    });
                }
                // window lapsed: half-open, this caller probes
            }
        }
        let mut waited = false;
        loop {
            let cached = match g.slots.get(key) {
                Some(Slot::Ready { plan, .. }) => Some(plan.clone()),
                Some(Slot::Building) => {
                    waited = true;
                    g = wait_clean(&self.ready_cv, g);
                    continue;
                }
                None => None,
            };
            match cached {
                Some(plan) => {
                    g.tick += 1;
                    let tick = g.tick;
                    if let Some(Slot::Ready { last_used, .. }) =
                        g.slots.get_mut(key)
                    {
                        *last_used = tick;
                    }
                    // exactly one of hit/miss/coalesced per lookup: a
                    // caller that waited on someone else's build is
                    // coalesced, never a hit
                    if waited {
                        g.coalesced += 1;
                    } else {
                        g.hits += 1;
                    }
                    return Ok(plan);
                }
                None => {
                    g.slots.insert(key.clone(), Slot::Building);
                    g.misses += 1;
                    break;
                }
            }
        }
        drop(g);
        // expensive: compile or artifact-load, outside the lock. The
        // guard clears the Building marker on *any* exit that did not
        // install a Ready plan — error return or panic unwind — so a
        // failed builder can never wedge the key for the waiters.
        let mut guard = BuildGuard {
            reg: self,
            key,
            armed: true,
        };
        let plan = match build() {
            Ok(plan) => Arc::new(plan),
            Err(err) => {
                {
                    // consecutive-failure bookkeeping; scope the lock
                    // so the BuildGuard's own lock (taken when it drops
                    // armed, clearing the marker) cannot deadlock
                    let mut g = lock_clean(&self.inner);
                    g.build_failures += 1;
                    let tick = g.tick;
                    let fs = g.fail.entry(key.clone()).or_default();
                    fs.failures += 1;
                    if fs.failures >= BREAK_AFTER {
                        let excess =
                            (fs.failures - BREAK_AFTER).min(6);
                        fs.open_until =
                            Some(tick + (BREAK_BACKOFF << excess));
                    }
                }
                // guard drops armed: marker cleared, waiters retry
                return Err(match err {
                    b @ ServeError::Build { .. } => b,
                    other => ServeError::Build {
                        key: key.to_string(),
                        msg: other.to_string(),
                    },
                });
            }
        };
        let bytes = plan_bytes(&plan);
        let mut g = lock_clean(&self.inner);
        g.tick += 1;
        let tick = g.tick;
        // a successful build closes the breaker and forgets the streak
        g.fail.remove(key);
        g.slots.insert(
            key.clone(),
            Slot::Ready {
                plan: plan.clone(),
                last_used: tick,
                bytes,
            },
        );
        g.resident_bytes += bytes;
        self.evict_over_limits(&mut g);
        drop(g);
        guard.armed = false;
        self.ready_cv.notify_all();
        Ok(plan)
    }

    fn remove_building_marker(&self, key: &PlanKey) {
        // called from BuildGuard::drop during a panic unwind — this is
        // exactly the path where the mutex may be poisoned, and exactly
        // the path that must still wake the waiters
        let mut g = lock_clean(&self.inner);
        if matches!(g.slots.get(key), Some(Slot::Building)) {
            g.slots.remove(key);
        }
        drop(g);
        self.ready_cv.notify_all();
    }

    fn evict_over_limits(&self, g: &mut Inner) {
        loop {
            let ready = g
                .slots
                .values()
                .filter(|s| matches!(s, Slot::Ready { .. }))
                .count();
            let over_count = ready > self.capacity;
            let over_bytes =
                g.resident_bytes > self.byte_budget && ready > 1;
            if !over_count && !over_bytes {
                return;
            }
            let victim = g
                .slots
                .iter()
                .filter_map(|(k, s)| match s {
                    Slot::Ready { last_used, .. } => {
                        Some((*last_used, k.clone()))
                    }
                    Slot::Building => None,
                })
                .min()
                .map(|(_, k)| k);
            match victim {
                Some(k) => {
                    if let Some(Slot::Ready { bytes, .. }) =
                        g.slots.remove(&k)
                    {
                        g.resident_bytes -= bytes;
                    }
                    g.evictions += 1;
                }
                None => return,
            }
        }
    }

    /// Try `key` first; on a typed build failure (including a fast-fail
    /// from its open circuit), fall back to `fb_key` — the degraded
    /// path, e.g. an i8 plan falling back to its f32 twin. Returns the
    /// plan and whether the fallback was taken (`true` = degraded).
    /// Non-build errors surface unchanged.
    pub fn get_or_build_with_fallback(
        &self,
        key: &PlanKey,
        build: impl FnOnce() -> Result<ExecutionPlan, ServeError>,
        fb_key: &PlanKey,
        fb_build: impl FnOnce() -> Result<ExecutionPlan, ServeError>,
    ) -> Result<(Arc<ExecutionPlan>, bool), ServeError> {
        match self.get_or_build(key, build) {
            Ok(plan) => Ok((plan, false)),
            Err(ServeError::Build { .. }) => self
                .get_or_build(fb_key, fb_build)
                .map(|plan| (plan, true)),
            Err(other) => Err(other),
        }
    }

    /// Consecutive build failures recorded against `key` (0 once a
    /// build succeeds).
    pub fn failures(&self, key: &PlanKey) -> u64 {
        lock_clean(&self.inner)
            .fail
            .get(key)
            .map(|fs| fs.failures)
            .unwrap_or(0)
    }

    /// Whether `key`'s circuit is open right now (the next lookup would
    /// fail fast instead of building).
    pub fn circuit_open(&self, key: &PlanKey) -> bool {
        let g = lock_clean(&self.inner);
        match g.fail.get(key).and_then(|fs| fs.open_until) {
            // the probing lookup will run at tick + 1
            Some(open_until) => g.tick + 1 < open_until,
            None => false,
        }
    }

    /// Drop a specific entry (e.g. after its artifact was republished).
    /// No-op for in-flight builds.
    pub fn evict(&self, key: &PlanKey) -> bool {
        let mut g = lock_clean(&self.inner);
        if matches!(g.slots.get(key), Some(Slot::Ready { .. })) {
            if let Some(Slot::Ready { bytes, .. }) = g.slots.remove(key)
            {
                g.resident_bytes -= bytes;
            }
            g.evictions += 1;
            true
        } else {
            false
        }
    }

    pub fn stats(&self) -> RegistryStats {
        let g = lock_clean(&self.inner);
        let ready = g
            .slots
            .values()
            .filter(|s| matches!(s, Slot::Ready { .. }))
            .count();
        let broken = g
            .fail
            .values()
            .filter(|fs| {
                fs.open_until.is_some_and(|until| g.tick + 1 < until)
            })
            .count();
        RegistryStats {
            ready,
            building: g.slots.len() - ready,
            capacity: self.capacity,
            resident_bytes: g.resident_bytes,
            byte_budget: self.byte_budget,
            hits: g.hits,
            misses: g.misses,
            coalesced: g.coalesced,
            evictions: g.evictions,
            build_failures: g.build_failures,
            broken,
            shed_broken: g.shed_broken,
        }
    }
}

/// Per-tenant plan shards: each tenant gets its own [`PlanRegistry`]
/// (independent capacity + byte budget), so tenants cannot evict each
/// other's plans and registry contention splits per tenant. Shards are
/// registered up front (gateway build time); lookups on unknown tenants
/// fail typed.
pub struct ShardedRegistry {
    shards: BTreeMap<String, PlanRegistry>,
}

impl Default for ShardedRegistry {
    fn default() -> Self {
        Self::new()
    }
}

impl ShardedRegistry {
    pub fn new() -> Self {
        ShardedRegistry {
            shards: BTreeMap::new(),
        }
    }

    /// Register a tenant shard. Duplicate names are a config error.
    pub fn add_tenant(
        &mut self,
        tenant: &str,
        capacity: usize,
        byte_budget: u64,
    ) -> Result<(), ServeError> {
        if self.shards.contains_key(tenant) {
            return Err(ServeError::Config {
                msg: format!("duplicate tenant {tenant:?}"),
            });
        }
        self.shards.insert(
            tenant.to_string(),
            PlanRegistry::with_byte_budget(capacity, byte_budget),
        );
        Ok(())
    }

    pub fn tenants(&self) -> impl Iterator<Item = &str> {
        self.shards.keys().map(String::as_str)
    }

    /// A tenant's own shard (typed [`ServeError::UnknownTenant`] when
    /// absent).
    pub fn shard(
        &self,
        tenant: &str,
    ) -> Result<&PlanRegistry, ServeError> {
        self.shards.get(tenant).ok_or_else(|| {
            ServeError::UnknownTenant {
                tenant: tenant.to_string(),
            }
        })
    }

    /// [`PlanRegistry::get_or_build`] on the tenant's shard.
    pub fn get_or_build(
        &self,
        tenant: &str,
        key: &PlanKey,
        build: impl FnOnce() -> Result<ExecutionPlan, ServeError>,
    ) -> Result<Arc<ExecutionPlan>, ServeError> {
        self.shard(tenant)?.get_or_build(key, build)
    }

    /// [`PlanRegistry::get_or_build_with_fallback`] on the tenant's
    /// shard.
    pub fn get_or_build_with_fallback(
        &self,
        tenant: &str,
        key: &PlanKey,
        build: impl FnOnce() -> Result<ExecutionPlan, ServeError>,
        fb_key: &PlanKey,
        fb_build: impl FnOnce() -> Result<ExecutionPlan, ServeError>,
    ) -> Result<(Arc<ExecutionPlan>, bool), ServeError> {
        self.shard(tenant)?
            .get_or_build_with_fallback(key, build, fb_key, fb_build)
    }

    /// Per-tenant counters in deterministic (name) order.
    pub fn stats(&self) -> Vec<(String, RegistryStats)> {
        self.shards
            .iter()
            .map(|(name, reg)| (name.clone(), reg.stats()))
            .collect()
    }

    /// All shards folded into one summary.
    pub fn total(&self) -> RegistryStats {
        let mut total = RegistryStats::default();
        for reg in self.shards.values() {
            total.absorb(&reg.stats());
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mobile::ir::ModelIR;
    use crate::mobile::plan::{
        compile_plan, compile_plan_quant, ElemType,
    };
    use crate::mobile::synth;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn build_plan(seed: u64) -> Result<ExecutionPlan, ServeError> {
        let (spec, mut params) =
            synth::vgg_style("reg_vgg", 8, 4, &[4], seed);
        synth::pattern_prune(&spec, &mut params, 0.25);
        let ir = ModelIR::build(&spec, &params).expect("ir");
        Ok(compile_plan(ir, 1).expect("compile"))
    }

    fn build_quant_plan(seed: u64) -> Result<ExecutionPlan, ServeError> {
        let (spec, mut params) =
            synth::vgg_style("reg_vgg", 8, 4, &[4], seed);
        synth::pattern_prune(&spec, &mut params, 0.25);
        let ir = ModelIR::build(&spec, &params).expect("ir");
        Ok(compile_plan_quant(ir, 1).expect("compile"))
    }

    #[test]
    fn key_quantizes_rate() {
        let a = PlanKey::new("m", "pattern", 8.0, 2);
        let b = PlanKey::new("m", "pattern", 8.0001, 2);
        assert_eq!(a, b);
        assert_eq!(a.rate(), 8.0);
        let c = PlanKey::new("m", "pattern", 8.1, 2);
        assert_ne!(a, c);
        assert!(format!("{a}").contains("pattern"));
    }

    #[test]
    fn tuned_key_never_aliases_analytic() {
        let a = PlanKey::new("m", "pattern", 8.0, 2);
        let t = PlanKey::new("m", "pattern", 8.0, 2).tuned();
        assert_ne!(a, t);
        assert!(format!("{t}").contains("tuned"));
        assert!(!format!("{a}").contains("tuned"));
        // both fit in the cache side by side
        let reg = PlanRegistry::new(4);
        let pa = reg.get_or_build(&a, || build_plan(1)).unwrap();
        let pt = reg.get_or_build(&t, || build_plan(1)).unwrap();
        assert!(!Arc::ptr_eq(&pa, &pt));
        assert_eq!(reg.stats().ready, 2);
    }

    #[test]
    fn quantized_key_never_aliases_f32() {
        let a = PlanKey::new("m", "pattern", 8.0, 2);
        let q = PlanKey::new("m", "pattern", 8.0, 2).quantized();
        assert_ne!(a, q);
        assert!(format!("{q}").ends_with("/i8"));
        assert!(!format!("{a}").contains("i8"));
        // tuned and quantized compose into a third distinct key
        let tq = PlanKey::new("m", "pattern", 8.0, 2).tuned().quantized();
        assert_ne!(tq, q);
        assert!(format!("{tq}").contains("/tuned/i8"));
        // both live side by side, and the i8 entry charges fewer
        // payload bytes against the budget
        let reg = PlanRegistry::new(4);
        let pa = reg.get_or_build(&a, || build_plan(1)).unwrap();
        let pq = reg.get_or_build(&q, || build_quant_plan(1)).unwrap();
        assert!(!Arc::ptr_eq(&pa, &pq));
        assert_eq!(pa.elem, ElemType::F32);
        assert_eq!(pq.elem, ElemType::I8);
        assert!(
            pq.stats.payload_bytes < pa.stats.payload_bytes,
            "i8 {} vs f32 {}",
            pq.stats.payload_bytes,
            pa.stats.payload_bytes
        );
        assert_eq!(reg.stats().ready, 2);
        assert_eq!(
            reg.stats().resident_bytes,
            plan_bytes(&pa) + plan_bytes(&pq)
        );
    }

    #[test]
    fn hit_returns_same_arc_without_rebuilding() {
        let reg = PlanRegistry::new(4);
        let key = PlanKey::new("m", "pattern", 8.0, 1);
        let builds = AtomicUsize::new(0);
        let a = reg
            .get_or_build(&key, || {
                builds.fetch_add(1, Ordering::SeqCst);
                build_plan(1)
            })
            .unwrap();
        let b = reg
            .get_or_build(&key, || {
                builds.fetch_add(1, Ordering::SeqCst);
                build_plan(1)
            })
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(builds.load(Ordering::SeqCst), 1);
        let s = reg.stats();
        assert_eq!((s.hits, s.misses, s.ready), (1, 1, 1));
        assert_eq!(s.resident_bytes, plan_bytes(&a));
        assert_eq!(s.lookups(), 2);
    }

    #[test]
    fn concurrent_misses_single_flight() {
        let reg = PlanRegistry::new(4);
        let key = PlanKey::new("m", "pattern", 8.0, 1);
        let builds = AtomicUsize::new(0);
        let plans = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    let p = reg
                        .get_or_build(&key, || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            // hold the build long enough that the other
                            // threads observe the Building slot
                            std::thread::sleep(
                                std::time::Duration::from_millis(40),
                            );
                            build_plan(1)
                        })
                        .unwrap();
                    plans.lock().unwrap().push(p);
                });
            }
        });
        assert_eq!(builds.load(Ordering::SeqCst), 1, "single flight");
        let plans = plans.into_inner().unwrap();
        assert_eq!(plans.len(), 8);
        assert!(plans.iter().all(|p| Arc::ptr_eq(p, &plans[0])));
        let s = reg.stats();
        assert_eq!(s.misses, 1, "exactly one build started");
        // each lookup resolves as exactly one of hit/miss/coalesced; the
        // non-builders waited on the in-flight build, so they are
        // coalesced, not hits (threads that never saw the Building slot
        // land in hits instead — either way the sum is exact)
        assert_eq!(s.lookups(), 8);
        assert_eq!(s.hits + s.coalesced, 7);
    }

    #[test]
    fn failed_build_is_typed_and_allows_retry() {
        let reg = PlanRegistry::new(4);
        let key = PlanKey::new("m", "pattern", 8.0, 1);
        let err = reg
            .get_or_build(&key, || {
                Err(ServeError::Config {
                    msg: "synthetic build failure".into(),
                })
            })
            .unwrap_err();
        match &err {
            ServeError::Build { key: k, msg } => {
                assert!(k.contains("pattern"));
                assert!(msg.contains("synthetic"));
            }
            other => panic!("expected Build, got {other:?}"),
        }
        assert!(err.to_string().contains("synthetic"));
        assert_eq!(reg.stats().ready, 0);
        assert_eq!(reg.stats().building, 0);
        // the key is buildable again afterwards
        let p = reg.get_or_build(&key, || build_plan(1)).unwrap();
        assert_eq!(p.threads, 1);
        // the failed lookup still counted as the miss it was
        assert_eq!(reg.stats().lookups(), 2);
    }

    #[test]
    fn panicking_build_does_not_wedge_the_key() {
        let reg = PlanRegistry::new(4);
        let key = PlanKey::new("m", "pattern", 8.0, 1);
        let unwound = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                let _ = reg.get_or_build(&key, || panic!("builder died"));
            }),
        );
        assert!(unwound.is_err());
        // the Building marker was cleared by the drop guard: the key is
        // immediately buildable again, no waiter can hang on it
        assert_eq!(reg.stats().building, 0);
        let p = reg.get_or_build(&key, || build_plan(1)).unwrap();
        assert_eq!(p.threads, 1);
    }

    #[test]
    fn lru_eviction_beyond_capacity() {
        let reg = PlanRegistry::new(2);
        let k1 = PlanKey::new("m1", "pattern", 8.0, 1);
        let k2 = PlanKey::new("m2", "pattern", 8.0, 1);
        let k3 = PlanKey::new("m3", "pattern", 8.0, 1);
        reg.get_or_build(&k1, || build_plan(1)).unwrap();
        reg.get_or_build(&k2, || build_plan(2)).unwrap();
        // touch k1 so k2 is the LRU
        reg.get_or_build(&k1, || build_plan(1)).unwrap();
        reg.get_or_build(&k3, || build_plan(3)).unwrap();
        let s = reg.stats();
        assert_eq!(s.ready, 2);
        assert_eq!(s.evictions, 1);
        // k2 was evicted: fetching it builds again
        let builds = AtomicUsize::new(0);
        reg.get_or_build(&k2, || {
            builds.fetch_add(1, Ordering::SeqCst);
            build_plan(2)
        })
        .unwrap();
        assert_eq!(builds.load(Ordering::SeqCst), 1);
        // ... and k1 was not: no rebuild
        reg.get_or_build(&k1, || {
            builds.fetch_add(1, Ordering::SeqCst);
            build_plan(1)
        })
        .unwrap();
        assert_eq!(builds.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn byte_budget_evicts_lru() {
        let probe = build_plan(1).unwrap();
        let one = plan_bytes(&probe);
        assert!(one > 0);
        // budget fits exactly one plan (all builds share a shape): the
        // second insert pushes the first out even though capacity is 8
        let reg = PlanRegistry::with_byte_budget(8, one);
        let k1 = PlanKey::new("m1", "pattern", 8.0, 1);
        let k2 = PlanKey::new("m2", "pattern", 8.0, 1);
        reg.get_or_build(&k1, || build_plan(1)).unwrap();
        assert_eq!(reg.stats().resident_bytes, one);
        reg.get_or_build(&k2, || build_plan(2)).unwrap();
        let s = reg.stats();
        assert_eq!(s.ready, 1, "budget holds one plan");
        assert_eq!(s.evictions, 1);
        assert_eq!(s.resident_bytes, one);
        // bookkeeping stays exact through explicit eviction too
        assert!(reg.evict(&k2));
        assert_eq!(reg.stats().resident_bytes, 0);
    }

    #[test]
    fn sharded_registry_isolates_tenants() {
        let mut sharded = ShardedRegistry::new();
        sharded.add_tenant("alice", 1, u64::MAX).unwrap();
        sharded.add_tenant("bob", 4, u64::MAX).unwrap();
        assert!(matches!(
            sharded.add_tenant("alice", 1, u64::MAX),
            Err(ServeError::Config { .. })
        ));
        let k1 = PlanKey::new("m1", "pattern", 8.0, 1);
        let k2 = PlanKey::new("m2", "pattern", 8.0, 1);
        // alice churns through two keys at capacity 1...
        sharded.get_or_build("alice", &k1, || build_plan(1)).unwrap();
        sharded.get_or_build("alice", &k2, || build_plan(2)).unwrap();
        // ...bob's shard is untouched by alice's eviction
        sharded.get_or_build("bob", &k1, || build_plan(1)).unwrap();
        let stats = sharded.stats();
        assert_eq!(stats.len(), 2);
        let alice = &stats[0].1;
        let bob = &stats[1].1;
        assert_eq!((alice.ready, alice.evictions), (1, 1));
        assert_eq!((bob.ready, bob.evictions), (1, 0));
        let total = sharded.total();
        assert_eq!(total.ready, 2);
        assert_eq!(total.misses, 3);
        assert!(matches!(
            sharded.get_or_build("mallory", &k1, || build_plan(1)),
            Err(ServeError::UnknownTenant { .. })
        ));
    }

    fn failing_build() -> Result<ExecutionPlan, ServeError> {
        Err(ServeError::Config {
            msg: "synthetic: build always fails".into(),
        })
    }

    #[test]
    fn breaker_opens_after_consecutive_failures_and_sheds_fast() {
        let reg = PlanRegistry::new(4);
        let key = PlanKey::new("broken", "pattern", 8.0, 1);
        let builds = AtomicUsize::new(0);
        let mut shed_msgs = 0;
        // hammer a permanently-broken key: the breaker must bound how
        // many doomed builds actually run
        for _ in 0..64 {
            let err = reg
                .get_or_build(&key, || {
                    builds.fetch_add(1, Ordering::SeqCst);
                    failing_build()
                })
                .unwrap_err();
            match err {
                ServeError::Build { msg, .. } => {
                    if msg.contains("circuit open") {
                        shed_msgs += 1;
                    }
                }
                other => panic!("expected Build, got {other:?}"),
            }
        }
        let ran = builds.load(Ordering::SeqCst);
        assert!(
            ran < 16,
            "breaker must bound doomed builds, ran {ran}"
        );
        assert!(shed_msgs > 0, "some lookups must shed fast");
        let s = reg.stats();
        assert_eq!(s.build_failures, ran as u64);
        assert_eq!(s.shed_broken, shed_msgs);
        assert_eq!(s.broken, 1, "one key's circuit is open");
        assert_eq!(
            s.lookups(),
            64,
            "every lookup resolves exactly once (got {s:?})"
        );
        assert!(reg.circuit_open(&key));
        assert_eq!(reg.failures(&key), ran as u64);
    }

    #[test]
    fn breaker_closes_on_probe_success() {
        let reg = PlanRegistry::new(4);
        let key = PlanKey::new("flaky", "pattern", 8.0, 1);
        // open the circuit with BREAK_AFTER straight failures
        for _ in 0..BREAK_AFTER {
            let _ = reg.get_or_build(&key, failing_build);
        }
        assert!(reg.circuit_open(&key));
        // burn through the open window (fast-fails advance the tick)
        let mut probes = 0;
        for _ in 0..(2 * BREAK_BACKOFF) {
            if reg
                .get_or_build(&key, || {
                    probes += 1;
                    build_plan(1)
                })
                .is_ok()
            {
                break;
            }
        }
        assert_eq!(probes, 1, "exactly one probe ran when half-open");
        assert!(!reg.circuit_open(&key));
        assert_eq!(reg.failures(&key), 0, "success resets the streak");
        // and the plan is now a plain cache hit
        let before = reg.stats().hits;
        reg.get_or_build(&key, || build_plan(1)).unwrap();
        assert_eq!(reg.stats().hits, before + 1);
    }

    #[test]
    fn fallback_degrades_to_secondary_key() {
        let reg = PlanRegistry::new(4);
        let q = PlanKey::new("m", "pattern", 8.0, 1).quantized();
        let f = PlanKey::new("m", "pattern", 8.0, 1);
        // primary (i8) build fails -> fallback (f32) serves, degraded
        let (plan, degraded) = reg
            .get_or_build_with_fallback(
                &q,
                failing_build,
                &f,
                || build_plan(1),
            )
            .unwrap();
        assert!(degraded);
        assert_eq!(plan.elem, ElemType::F32);
        // primary succeeding is not degraded
        let (_, degraded) = reg
            .get_or_build_with_fallback(
                &q,
                || build_quant_plan(1),
                &f,
                || build_plan(1),
            )
            .unwrap();
        assert!(!degraded);
        // any builder error is wrapped into Build by get_or_build, so
        // every primary failure takes the degraded path — including
        // non-compile errors like a missing artifact
        let (plan, degraded) = reg
            .get_or_build_with_fallback(
                &PlanKey::new("x", "pattern", 8.0, 1),
                || Err(ServeError::Closed),
                &f,
                || build_plan(1),
            )
            .unwrap();
        assert!(degraded);
        assert_eq!(plan.elem, ElemType::F32);
    }

    #[test]
    fn eviction_churn_keeps_counters_consistent() {
        // N threads hammer more keys than capacity: the single-flight
        // path must never deadlock, and every lookup must resolve as
        // exactly one of hit/miss/coalesced
        const THREADS: usize = 8;
        const ITERS: usize = 24;
        let reg = PlanRegistry::new(2);
        let keys: Vec<PlanKey> = (0..6)
            .map(|i| PlanKey::new(&format!("m{i}"), "pattern", 8.0, 1))
            .collect();
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let reg = &reg;
                let keys = &keys;
                s.spawn(move || {
                    for i in 0..ITERS {
                        // deterministic per-thread walk over the keys,
                        // skewed so threads collide on hot keys
                        let k = &keys[(t + i * (1 + t % 3)) % keys.len()];
                        reg.get_or_build(k, || build_plan(7)).unwrap();
                    }
                });
            }
        });
        let s = reg.stats();
        assert_eq!(
            s.lookups(),
            (THREADS * ITERS) as u64,
            "hits + misses + coalesced must equal lookups \
             (got {s:?})"
        );
        assert_eq!(s.building, 0, "no wedged in-flight markers");
        assert!(s.ready <= 2, "capacity respected under churn");
        assert!(s.evictions > 0, "churn actually evicted");
    }
}
