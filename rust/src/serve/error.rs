//! The serving tier's single public error type.
//!
//! Every fallible public surface in `serve/` — request submission,
//! ticket waits, registry builds, artifact IO, gateway construction and
//! admission — reports a [`ServeError`]. The old `SubmitError` /
//! `PushError` pair and the ad-hoc `anyhow` strings are gone: callers
//! match one enum, and the distinctions that drive control flow
//! (backpressure-`Rejected` vs caller-bug `BadShape`, deterministic
//! admission `Shed` vs timing-dependent queue `Rejected`) stay typed.

/// Why a serving-tier operation failed.
///
/// `Rejected`, `Shed`, and `Closed` are *flow* signals — the request was
/// refused before any work happened and the caller may retry or give up.
/// `BadShape` / `BadLength` are caller bugs. `Build`, `Artifact`,
/// `OverBudget`, and `Config` surface deployment problems that used to
/// be stringly-typed `anyhow` chains (or, for registry builds, panics).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// bounded queue at capacity — explicit backpressure, try again later
    Rejected,
    /// per-tenant admission control refused the request (token budget
    /// exhausted); deterministic under virtual-time replay
    Shed {
        /// tenant whose budget was exhausted
        tenant: String,
    },
    /// the gateway has no tenant by this name / index
    UnknownTenant { tenant: String },
    /// image dims do not match the plan input
    BadShape {
        got: (usize, usize),
        want: (usize, usize),
    },
    /// image buffer length disagrees with its own dims (`Fmap` fields
    /// are pub) — caught at submit so it can never panic a worker
    BadLength { got: usize, want: usize },
    /// the server / gateway is shutting down
    Closed,
    /// the request was dropped before a response (batch failed, deadline
    /// shed, or shutdown raced the in-flight work)
    Canceled { id: u64 },
    /// the dispatching worker panicked while this request was in flight;
    /// the supervisor failed it typed (never a hung channel), requeued
    /// its batch-mates, and restarted the worker
    WorkerLost { id: u64 },
    /// an OS-level thread spawn failed while standing up a worker pool
    Spawn { msg: String },
    /// a registry plan build failed (compile or artifact load); the key
    /// stays buildable — the next caller retries
    Build { key: String, msg: String },
    /// plan artifact encode/decode/IO failure
    Artifact { msg: String },
    /// a tenant's compiled plan does not fit its memory budget
    OverBudget {
        tenant: String,
        need: u64,
        budget: u64,
    },
    /// invalid serving configuration (duplicate tenant, empty gateway, …)
    Config { msg: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Rejected => {
                write!(f, "request rejected: queue at capacity")
            }
            ServeError::Shed { tenant } => write!(
                f,
                "request shed: tenant {tenant:?} admission budget \
                 exhausted"
            ),
            ServeError::UnknownTenant { tenant } => {
                write!(f, "unknown tenant {tenant:?}")
            }
            ServeError::BadShape { got, want } => write!(
                f,
                "image ({}, {}hw) does not match plan input ({}, {}hw)",
                got.0, got.1, want.0, want.1
            ),
            ServeError::BadLength { got, want } => write!(
                f,
                "image buffer holds {got} elems, plan input needs {want}"
            ),
            ServeError::Closed => write!(f, "server is shutting down"),
            ServeError::Canceled { id } => {
                write!(f, "request {id} canceled before a response")
            }
            ServeError::WorkerLost { id } => write!(
                f,
                "request {id} lost to a worker panic (worker restarted)"
            ),
            ServeError::Spawn { msg } => {
                write!(f, "spawning worker thread failed: {msg}")
            }
            ServeError::Build { key, msg } => {
                write!(f, "building plan {key} failed: {msg}")
            }
            ServeError::Artifact { msg } => {
                write!(f, "plan artifact error: {msg}")
            }
            ServeError::OverBudget {
                tenant,
                need,
                budget,
            } => write!(
                f,
                "tenant {tenant:?} plan needs {need} bytes but its \
                 memory budget is {budget} bytes"
            ),
            ServeError::Config { msg } => {
                write!(f, "invalid serving config: {msg}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

impl ServeError {
    /// Wrap an `anyhow` chain from the artifact codec as a typed
    /// [`ServeError::Artifact`] (the full cause chain is preserved in the
    /// message, so substring checks like "checksum" keep working).
    pub(crate) fn artifact(err: &anyhow::Error) -> Self {
        ServeError::Artifact {
            msg: format!("{err:#}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_keeps_flow_distinctions() {
        assert!(ServeError::Rejected.to_string().contains("capacity"));
        assert!(ServeError::Closed.to_string().contains("shutting down"));
        let shed = ServeError::Shed {
            tenant: "alice".into(),
        };
        assert!(shed.to_string().contains("alice"));
        assert_ne!(shed, ServeError::Rejected);
        let bad = ServeError::BadShape {
            got: (1, 2),
            want: (3, 4),
        };
        assert!(bad.to_string().contains("does not match"));
        let build = ServeError::Build {
            key: "m/pattern@8.0x/t1".into(),
            msg: "boom".into(),
        };
        assert!(build.to_string().contains("boom"));
        let over = ServeError::OverBudget {
            tenant: "bob".into(),
            need: 10,
            budget: 5,
        };
        assert!(over.to_string().contains("budget"));
        let lost = ServeError::WorkerLost { id: 7 };
        assert!(lost.to_string().contains("worker panic"));
        assert_ne!(lost, ServeError::Canceled { id: 7 });
        let spawn = ServeError::Spawn { msg: "EAGAIN".into() };
        assert!(spawn.to_string().contains("EAGAIN"));
    }

    #[test]
    fn is_a_std_error() {
        fn takes_err<E: std::error::Error + Send + Sync + 'static>(_: E) {}
        takes_err(ServeError::Rejected);
        // and therefore converts into anyhow
        let err = anyhow::Error::from(ServeError::Closed);
        assert!(err.to_string().contains("shutting down"));
    }
}
