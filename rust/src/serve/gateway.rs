//! Multi-tenant serving gateway: many `(model, scheme, rate, kernel)`
//! deployments multiplexed over one worker pool (DESIGN.md §13).
//!
//! One [`Gateway`] owns N tenants. Each tenant brings its own compiled
//! plan + kernel selection, a **bounded queue** (per-tenant
//! backpressure), a **priority class**, an optional **deadline**, and an
//! optional **admission budget**. A shared pool of workers picks, at
//! every dispatch, the highest-priority tenant with the oldest waiting
//! request, forms a *single-tenant* micro-batch (batches never mix
//! plans), and executes it on a lazily-built per-`(worker, tenant)`
//! executor — so a worker that never serves a tenant never pays for its
//! arena.
//!
//! Two shed layers, deliberately split by determinism:
//!
//! * **Admission shed** ([`ServeError::Shed`]): a per-tenant token
//!   bucket refilled in *virtual time* — the `vt_us` timestamps carried
//!   by the seeded trace ([`super::loadgen::multi_tenant_trace`]) — via
//!   [`GatewayHandle::submit_at`]. Because refill depends only on the
//!   trace, shed decisions are a pure function of `(trace, budget)`:
//!   identical at any worker count, and counted in the deterministic
//!   counters.
//! * **Deadline shed** ([`ServeStats::shed_deadline`]): an admitted
//!   request whose wall-clock deadline passed before dispatch is dropped
//!   at batch formation (its client observes
//!   [`ServeError::Canceled`]). Wall-clock dependent, excluded from the
//!   deterministic counters.
//!
//! Per-tenant [`ServeReport`]s (latency percentiles, shed/reject
//! counters, batch histogram) roll up into a [`GatewayReport`]; when the
//! gateway is built over a [`ShardedRegistry`], per-tenant registry
//! counters (hits/misses/evictions/resident bytes) ride along.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::config::GatewayConfig;
use crate::mobile::engine::{
    execute_batch_parallel, Executor, Fmap, KernelSel,
};
use crate::mobile::plan::{ExecutionPlan, StepDims};
use crate::report::Table;

use super::error::ServeError;
use super::faults::{self, FaultPlan, Faults};
use super::registry::{plan_bytes, RegistryStats, ShardedRegistry};
use super::{lock_clean, wait_clean, wait_timeout_clean};
use super::server::{check_image, ServeResponse, Ticket};
use super::stats::{ServeReport, ServeStats};
use super::supervisor::{self, Meta, RespTx};

/// Dispatch priority class. Workers always serve every waiting `High`
/// request before any `Normal` one, and `Normal` before `Low`; within a
/// class, the oldest waiting head wins (deadline-aware FIFO).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    High,
    #[default]
    Normal,
    Low,
}

impl Priority {
    pub fn rank(self) -> u8 {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    pub fn parse(s: &str) -> Result<Priority, ServeError> {
        match s {
            "high" => Ok(Priority::High),
            "normal" => Ok(Priority::Normal),
            "low" => Ok(Priority::Low),
            _ => Err(ServeError::Config {
                msg: format!("unknown priority {s:?} (high|normal|low)"),
            }),
        }
    }
}

/// Per-tenant deployment knobs. Start from [`TenantConfig::new`] and
/// chain overrides, mirroring the server/gateway builder style.
#[derive(Clone, Debug)]
pub struct TenantConfig {
    pub name: String,
    pub priority: Priority,
    /// bounded queue capacity — a full tenant queue rejects *that
    /// tenant's* submits without touching anyone else's
    pub queue_cap: usize,
    /// admission budget in requests/sec of *virtual* (trace) time;
    /// `f64::INFINITY` disables the bucket. Only
    /// [`GatewayHandle::submit_at`] consults it.
    pub admit_qps: f64,
    /// token bucket burst capacity, requests
    pub admit_burst: f64,
    /// wall-clock dispatch deadline; an admitted request older than this
    /// at batch formation is shed. 0 disables.
    pub deadline_us: u64,
    /// memory budget for this tenant's plan footprint
    /// ([`plan_bytes`]); exceeding it at spawn is a typed
    /// [`ServeError::OverBudget`]
    pub mem_budget: u64,
    /// the tenant is serving a fallback plan (i8 build fell back to
    /// f32, or a corrupt artifact was recompiled from spec); carried
    /// through to [`TenantReport::degraded`] so fleet reports show it
    pub degraded: bool,
}

impl TenantConfig {
    pub fn new(name: &str) -> Self {
        TenantConfig {
            name: name.to_string(),
            priority: Priority::Normal,
            queue_cap: 256,
            admit_qps: f64::INFINITY,
            admit_burst: 8.0,
            deadline_us: 0,
            mem_budget: u64::MAX,
            degraded: false,
        }
    }

    pub fn priority(mut self, p: Priority) -> Self {
        self.priority = p;
        self
    }

    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = cap.max(1);
        self
    }

    /// Cap admission at `qps` requests per virtual second (with `burst`
    /// tokens of headroom).
    pub fn admit(mut self, qps: f64, burst: f64) -> Self {
        self.admit_qps = qps.max(0.0);
        self.admit_burst = burst.max(1.0);
        self
    }

    pub fn deadline_us(mut self, us: u64) -> Self {
        self.deadline_us = us;
        self
    }

    pub fn mem_budget(mut self, bytes: u64) -> Self {
        self.mem_budget = bytes.max(1);
        self
    }

    /// Mark the tenant as running in a degraded mode (fallback plan).
    pub fn degraded(mut self, flag: bool) -> Self {
        self.degraded = flag;
        self
    }
}

/// Virtual-time token bucket — refill is driven by the trace timestamps
/// handed to [`GatewayHandle::submit_at`], never the wall clock, so the
/// admit/shed sequence is a pure function of the trace.
struct Bucket {
    tokens: f64,
    last_vt_us: u64,
    primed: bool,
}

struct TenantRt {
    cfg: TenantConfig,
    plan: Arc<ExecutionPlan>,
    kernel: KernelSel,
    stats: ServeStats,
    bucket: Mutex<Bucket>,
}

struct GwRequest {
    id: u64,
    img: Fmap,
    enqueued: Instant,
    deadline: Option<Instant>,
    tx: RespTx,
}

struct GwState {
    queues: Vec<VecDeque<GwRequest>>,
    closed: bool,
}

struct GwShared {
    state: Mutex<GwState>,
    work_cv: Condvar,
    tenants: Vec<TenantRt>,
    by_name: BTreeMap<String, usize>,
    next_id: AtomicU64,
}

impl GwShared {
    fn tenant_index(&self, name: &str) -> Result<usize, ServeError> {
        self.by_name.get(name).copied().ok_or_else(|| {
            ServeError::UnknownTenant {
                tenant: name.to_string(),
            }
        })
    }
}

/// Builder for a [`Gateway`]; same shape as
/// [`ServerBuilder`](super::server::ServerBuilder), plus `tenant()`
/// registrations.
pub struct GatewayBuilder {
    cfg: GatewayConfig,
    tenants: Vec<(TenantConfig, Arc<ExecutionPlan>, KernelSel)>,
    registry: Option<Arc<ShardedRegistry>>,
    faults: Faults,
}

impl GatewayBuilder {
    /// Bulk-load the pool knobs from a [`GatewayConfig`].
    pub fn config(mut self, cfg: &GatewayConfig) -> Self {
        self.cfg = *cfg;
        self
    }

    /// Shared worker threads for the whole gateway.
    pub fn workers(mut self, n: usize) -> Self {
        self.cfg.workers = n.max(1);
        self
    }

    /// Per-dispatch micro-batch cap (batches are single-tenant).
    pub fn max_batch(mut self, n: usize) -> Self {
        self.cfg.max_batch = n.max(1);
        self
    }

    /// Straggler window past a batch's head-of-queue enqueue time.
    pub fn max_wait_us(mut self, us: u64) -> Self {
        self.cfg.max_wait_us = us;
        self
    }

    /// Intra-batch executor threads (1 = sequential on the lazily-built
    /// per-`(worker, tenant)` executor).
    pub fn batch_threads(mut self, n: usize) -> Self {
        self.cfg.batch_threads = n.max(1);
        self
    }

    /// Attach the plan registry the tenants were built through; its
    /// per-tenant counters (hits/misses/evictions/resident bytes) are
    /// folded into the final [`GatewayReport`].
    pub fn registry(mut self, reg: Arc<ShardedRegistry>) -> Self {
        self.registry = Some(reg);
        self
    }

    /// Arm a seeded [`FaultPlan`]: workers will deterministically
    /// panic / stall per the plan's schedule. Off by default; the
    /// fault-free path pays one `Option` branch per batch.
    pub fn chaos(mut self, plan: Arc<FaultPlan>) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Register one tenant: its deployment knobs, compiled plan, and
    /// kernel selection.
    pub fn tenant(
        mut self,
        cfg: TenantConfig,
        plan: Arc<ExecutionPlan>,
        kernel: impl Into<KernelSel>,
    ) -> Self {
        self.tenants.push((cfg, plan, kernel.into()));
        self
    }

    /// Validate the fleet and start the worker pool. Typed failures:
    /// [`ServeError::Config`] (no tenants / duplicate names) and
    /// [`ServeError::OverBudget`] (a plan that does not fit its tenant's
    /// memory budget).
    pub fn spawn(self) -> Result<Gateway, ServeError> {
        let GatewayBuilder {
            cfg,
            tenants,
            registry,
            faults,
        } = self;
        if tenants.is_empty() {
            return Err(ServeError::Config {
                msg: "gateway has no tenants".into(),
            });
        }
        let mut by_name = BTreeMap::new();
        for (i, (tc, plan, _)) in tenants.iter().enumerate() {
            if by_name.insert(tc.name.clone(), i).is_some() {
                return Err(ServeError::Config {
                    msg: format!("duplicate tenant {:?}", tc.name),
                });
            }
            let need = plan_bytes(plan);
            if need > tc.mem_budget {
                return Err(ServeError::OverBudget {
                    tenant: tc.name.clone(),
                    need,
                    budget: tc.mem_budget,
                });
            }
        }
        let rts: Vec<TenantRt> = tenants
            .into_iter()
            .map(|(tc, plan, kernel)| TenantRt {
                bucket: Mutex::new(Bucket {
                    tokens: tc.admit_burst,
                    last_vt_us: 0,
                    primed: false,
                }),
                cfg: tc,
                plan,
                kernel,
                stats: ServeStats::new(),
            })
            .collect();
        let n_tenants = rts.len();
        let shared = Arc::new(GwShared {
            state: Mutex::new(GwState {
                queues: (0..n_tenants).map(|_| VecDeque::new()).collect(),
                closed: false,
            }),
            work_cv: Condvar::new(),
            tenants: rts,
            by_name,
            next_id: AtomicU64::new(0),
        });
        let max_batch = cfg.max_batch.max(1);
        let max_wait = Duration::from_micros(cfg.max_wait_us);
        let batch_threads = cfg.batch_threads.max(1);
        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for i in 0..cfg.workers.max(1) {
            let shared = shared.clone();
            let faults = faults.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("gw-worker-{i}"))
                .spawn(move || {
                    worker_loop(
                        &shared,
                        max_batch,
                        max_wait,
                        batch_threads,
                        faults,
                    )
                });
            match spawned {
                Ok(h) => workers.push(h),
                Err(e) => {
                    // tear down the partial pool before surfacing the
                    // typed error, so no worker thread leaks
                    lock_clean(&shared.state).closed = true;
                    shared.work_cv.notify_all();
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(ServeError::Spawn {
                        msg: e.to_string(),
                    });
                }
            }
        }
        Ok(Gateway {
            shared,
            workers,
            started: Instant::now(),
            registry,
        })
    }
}

/// Cloneable client handle onto a running [`Gateway`].
#[derive(Clone)]
pub struct GatewayHandle {
    shared: Arc<GwShared>,
}

impl GatewayHandle {
    /// The input dims a tenant's plan expects (for building request
    /// images).
    pub fn in_dims(&self, tenant: &str) -> Result<StepDims, ServeError> {
        let ti = self.shared.tenant_index(tenant)?;
        Ok(self.shared.tenants[ti].plan.in_dims)
    }

    /// Submit bypassing admission control (interactive / closed-loop
    /// clients with no trace clock). Still subject to the tenant's
    /// bounded queue.
    pub fn submit(
        &self,
        tenant: &str,
        img: Fmap,
    ) -> Result<Ticket, ServeError> {
        let ti = self.shared.tenant_index(tenant)?;
        self.submit_inner(ti, img, None)
    }

    /// Submit at virtual time `vt_us` (monotone per tenant, from the
    /// trace): the tenant's token bucket refills by
    /// `admit_qps · Δvt` and sheds with a typed [`ServeError::Shed`]
    /// when empty. Replayed in trace order this is deterministic — the
    /// shed set depends only on the trace and the budget.
    pub fn submit_at(
        &self,
        tenant: &str,
        img: Fmap,
        vt_us: u64,
    ) -> Result<Ticket, ServeError> {
        let ti = self.shared.tenant_index(tenant)?;
        self.submit_inner(ti, img, Some(vt_us))
    }

    /// Submit and block for the response.
    pub fn infer(
        &self,
        tenant: &str,
        img: Fmap,
    ) -> Result<ServeResponse, ServeError> {
        self.submit(tenant, img)?.wait()
    }

    /// Live per-tenant stats snapshot.
    pub fn tenant_report(
        &self,
        tenant: &str,
        elapsed_secs: f64,
    ) -> Result<ServeReport, ServeError> {
        let ti = self.shared.tenant_index(tenant)?;
        Ok(self.shared.tenants[ti].stats.report(elapsed_secs))
    }

    pub fn queue_depth(
        &self,
        tenant: &str,
    ) -> Result<usize, ServeError> {
        let ti = self.shared.tenant_index(tenant)?;
        Ok(lock_clean(&self.shared.state).queues[ti].len())
    }

    fn submit_inner(
        &self,
        ti: usize,
        img: Fmap,
        vt_us: Option<u64>,
    ) -> Result<Ticket, ServeError> {
        let t = &self.shared.tenants[ti];
        check_image(&img, t.plan.in_dims)?;
        if let Some(vt) = vt_us {
            if t.cfg.admit_qps.is_finite() && !self.admit(ti, vt) {
                t.stats.shed();
                return Err(ServeError::Shed {
                    tenant: t.cfg.name.clone(),
                });
            }
        }
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        let enqueued = Instant::now();
        let deadline = (t.cfg.deadline_us > 0)
            .then(|| enqueued + Duration::from_micros(t.cfg.deadline_us));
        t.stats.submit();
        let mut g = lock_clean(&self.shared.state);
        if g.closed {
            t.stats.unsubmit();
            return Err(ServeError::Closed);
        }
        if g.queues[ti].len() >= t.cfg.queue_cap {
            t.stats.reject();
            return Err(ServeError::Rejected);
        }
        g.queues[ti].push_back(GwRequest {
            id,
            img,
            enqueued,
            deadline,
            tx,
        });
        drop(g);
        self.shared.work_cv.notify_all();
        Ok(Ticket::new(id, rx))
    }

    /// Token-bucket decision in virtual time. A non-monotone `vt` (clock
    /// replayed out of order) refills nothing rather than going
    /// backwards.
    fn admit(&self, ti: usize, vt_us: u64) -> bool {
        let t = &self.shared.tenants[ti];
        let mut b = lock_clean(&t.bucket);
        if !b.primed {
            // the first event anchors the clock; the initial burst is the
            // whole budget
            b.primed = true;
            b.last_vt_us = vt_us;
        } else if vt_us > b.last_vt_us {
            let dt = (vt_us - b.last_vt_us) as f64 / 1e6;
            b.tokens =
                (b.tokens + dt * t.cfg.admit_qps).min(t.cfg.admit_burst);
            b.last_vt_us = vt_us;
        }
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }
}

/// Final per-tenant slice of a [`GatewayReport`].
#[derive(Clone, Debug)]
pub struct TenantReport {
    pub name: String,
    pub priority: Priority,
    /// the tenant served a fallback plan (i8→f32 or recompiled from
    /// spec after artifact corruption)
    pub degraded: bool,
    pub report: ServeReport,
}

/// Everything a gateway run produced, per tenant and rolled up.
#[derive(Clone, Debug)]
pub struct GatewayReport {
    /// tenant registration order
    pub tenants: Vec<TenantReport>,
    pub elapsed_secs: f64,
    /// per-tenant registry counters when the gateway was built over a
    /// [`ShardedRegistry`] (empty otherwise)
    pub registry: Vec<(String, RegistryStats)>,
}

impl GatewayReport {
    pub fn tenant(&self, name: &str) -> Option<&TenantReport> {
        self.tenants.iter().find(|t| t.name == name)
    }

    /// Gateway-level counter roll-up:
    /// `(submitted, completed, rejected, errors, shed, shed_deadline)`.
    pub fn totals(&self) -> (u64, u64, u64, u64, u64, u64) {
        let mut acc = (0, 0, 0, 0, 0, 0);
        for t in &self.tenants {
            acc.0 += t.report.submitted;
            acc.1 += t.report.completed;
            acc.2 += t.report.rejected;
            acc.3 += t.report.errors;
            acc.4 += t.report.shed;
            acc.5 += t.report.shed_deadline;
        }
        acc
    }

    /// One row per tenant: the fleet operator's overview.
    pub fn table(&self, title: &str) -> Table {
        let mut t = Table::new(
            title,
            &[
                "tenant", "prio", "mode", "completed", "rejected",
                "shed", "shed-ddl", "lost", "rps", "p50", "p99",
            ],
        );
        for tr in &self.tenants {
            let r = &tr.report;
            t.row(&[
                tr.name.clone(),
                tr.priority.name().into(),
                if tr.degraded { "degraded" } else { "ok" }.into(),
                format!("{}", r.completed),
                format!("{}", r.rejected),
                format!("{}", r.shed),
                format!("{}", r.shed_deadline),
                format!("{}", r.worker_lost),
                format!("{:.1}", r.throughput_rps),
                format!("{} us", r.latency.p50_us),
                format!("{} us", r.latency.p99_us),
            ]);
        }
        t
    }
}

/// The multi-tenant serving engine. Build with [`Gateway::builder`].
pub struct Gateway {
    shared: Arc<GwShared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    started: Instant,
    registry: Option<Arc<ShardedRegistry>>,
}

impl Gateway {
    pub fn builder() -> GatewayBuilder {
        GatewayBuilder {
            cfg: GatewayConfig::default(),
            tenants: Vec::new(),
            registry: None,
            faults: None,
        }
    }

    pub fn handle(&self) -> GatewayHandle {
        GatewayHandle {
            shared: self.shared.clone(),
        }
    }

    /// Stop accepting, drain every tenant queue, join the pool, and
    /// report.
    pub fn shutdown(self) -> GatewayReport {
        {
            // shutdown must drain even after a worker panic left the
            // state mutex poisoned
            let mut g = lock_clean(&self.shared.state);
            g.closed = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers {
            // a worker that died to an unsupervised panic must not
            // wedge shutdown; its queued work is drained typed below
            let _ = w.join();
        }
        // drain guarantee: anything still queued after the pool exited
        // gets a typed Canceled, never a silently dropped channel
        let leftovers: Vec<GwRequest> = {
            let mut g = lock_clean(&self.shared.state);
            g.queues
                .iter_mut()
                .flat_map(|q| q.drain(..))
                .collect()
        };
        for req in leftovers {
            supervisor::fail_canceled(req.id, &req.tx);
        }
        let elapsed_secs = self.started.elapsed().as_secs_f64();
        let tenants = self
            .shared
            .tenants
            .iter()
            .map(|t| TenantReport {
                name: t.cfg.name.clone(),
                priority: t.cfg.priority,
                degraded: t.cfg.degraded,
                report: t.stats.report(elapsed_secs),
            })
            .collect();
        GatewayReport {
            tenants,
            elapsed_secs,
            registry: self
                .registry
                .map(|r| r.stats())
                .unwrap_or_default(),
        }
    }
}

/// Pick the tenant to serve next: lowest priority rank first, oldest
/// head-of-queue within a rank, registration order as the final
/// tie-break (the `min_by_key` scan order).
fn pick_tenant(g: &GwState, shared: &GwShared) -> Option<usize> {
    (0..g.queues.len())
        .filter(|&ti| !g.queues[ti].is_empty())
        .min_by_key(|&ti| {
            (
                shared.tenants[ti].cfg.priority.rank(),
                g.queues[ti].front().map(|r| r.enqueued),
            )
        })
}

/// Drop already-expired heads across all tenants (shed-on-overload).
/// Only called with the state lock held; senders are dropped so waiting
/// clients observe `Canceled`.
fn shed_expired(g: &mut GwState, shared: &GwShared, now: Instant) {
    for (ti, q) in g.queues.iter_mut().enumerate() {
        while let Some(front) = q.front() {
            match front.deadline {
                Some(d) if d <= now => {
                    q.pop_front();
                    shared.tenants[ti].stats.shed_deadline();
                }
                _ => break,
            }
        }
    }
}

/// Form the next single-tenant micro-batch, or `None` at drain + close.
fn next_batch(
    shared: &GwShared,
    max_batch: usize,
    max_wait: Duration,
) -> Option<(usize, Vec<GwRequest>)> {
    let mut g = lock_clean(&shared.state);
    let ti = loop {
        // during shutdown everything still queued is served, not shed —
        // a drained gateway reports completed == submitted
        if !g.closed {
            shed_expired(&mut g, shared, Instant::now());
        }
        match pick_tenant(&g, shared) {
            Some(ti) => break ti,
            None => {
                if g.closed {
                    return None;
                }
                g = wait_clean(&shared.work_cv, g);
            }
        }
    };
    let mut batch = Vec::with_capacity(max_batch);
    while batch.len() < max_batch {
        match g.queues[ti].pop_front() {
            Some(r) => batch.push(r),
            None => break,
        }
    }
    // straggler window anchored at the head's enqueue time, same
    // contract as the single-plan batcher: backlogged requests are
    // never further delayed
    if batch.len() < max_batch && max_wait > Duration::ZERO {
        let deadline = batch[0].enqueued + max_wait;
        loop {
            if batch.len() >= max_batch || g.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (g2, timed_out) = wait_timeout_clean(
                &shared.work_cv,
                g,
                deadline - now,
            );
            g = g2;
            while batch.len() < max_batch {
                match g.queues[ti].pop_front() {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
            if timed_out {
                break;
            }
        }
    }
    Some((ti, batch))
}

fn worker_loop(
    shared: &GwShared,
    max_batch: usize,
    max_wait: Duration,
    batch_threads: usize,
    faults: Faults,
) {
    // executors are built lazily per (worker, tenant): a worker that
    // never draws a tenant's batch never allocates that tenant's arena
    let mut execs: Vec<Option<Executor>> =
        (0..shared.tenants.len()).map(|_| None).collect();
    while let Some((ti, batch)) =
        next_batch(shared, max_batch, max_wait)
    {
        if batch.is_empty() {
            continue;
        }
        let t = &shared.tenants[ti];
        let formed = Instant::now();
        let n = batch.len();
        // metas live outside the unwind boundary: a panic inside
        // dispatch can never take the response channels with it
        let mut metas = Vec::with_capacity(n);
        let mut imgs = Vec::with_capacity(n);
        for req in batch {
            metas.push(Meta {
                id: req.id,
                enqueued: req.enqueued,
                tx: req.tx,
            });
            imgs.push(req.img);
        }
        let outs = supervisor::dispatch(|| {
            if faults.is_some() {
                let ids: Vec<u64> =
                    metas.iter().map(|m| m.id).collect();
                faults::maybe_panic(&faults, &ids);
                faults::maybe_stall(&faults, ids[0]);
            }
            if batch_threads <= 1 {
                let ex = execs[ti].get_or_insert_with(|| {
                    Executor::with_sel(&t.plan, t.kernel)
                });
                ex.execute_batch(&imgs)
            } else {
                execute_batch_parallel(
                    &t.plan,
                    t.kernel,
                    &imgs,
                    batch_threads,
                )
            }
        });
        match outs {
            Ok(Ok(outs)) => {
                t.stats.batch_dispatched(n);
                for (meta, logits) in metas.into_iter().zip(outs) {
                    let queue_us = formed
                        .saturating_duration_since(meta.enqueued)
                        .as_micros() as u64;
                    let total_us =
                        meta.enqueued.elapsed().as_micros() as u64;
                    t.stats.complete(total_us, queue_us);
                    let _ = meta.tx.send(Ok(ServeResponse {
                        id: meta.id,
                        logits,
                        queue_us,
                        total_us,
                        batch: n,
                    }));
                }
            }
            Ok(Err(_)) => {
                t.stats.batch_dispatched(n);
                t.stats.error_batch(n);
            }
            Err(_panic) => {
                // every lazily-built executor may hold mid-batch arena
                // garbage after an unwind; a respawned worker would
                // start cold, so do the same here
                execs.iter_mut().for_each(|e| *e = None);
                let survivors = supervisor::recover_poisoned(
                    metas, imgs, &faults, &t.stats,
                );
                let mut g = lock_clean(&shared.state);
                for (meta, img) in survivors.into_iter().rev() {
                    // deadline is cleared on requeue: once admitted and
                    // dispatched, a survivor of a worker loss completes
                    // rather than racing a wall-clock shed (which would
                    // make chaos outcomes timing-dependent)
                    g.queues[ti].push_front(GwRequest {
                        id: meta.id,
                        img,
                        enqueued: meta.enqueued,
                        deadline: None,
                        tx: meta.tx,
                    });
                }
                drop(g);
                shared.work_cv.notify_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mobile::engine::KernelKind;
    use crate::mobile::ir::ModelIR;
    use crate::mobile::plan::{compile_plan, compile_plan_quant};
    use crate::mobile::synth;
    use crate::serve::loadgen::request_image;

    fn tiny_plan(id: &str, seed: u64) -> Arc<ExecutionPlan> {
        let (spec, mut params) =
            synth::vgg_style(id, 8, 4, &[4, 6], seed);
        synth::pattern_prune(&spec, &mut params, 0.25);
        Arc::new(
            compile_plan(ModelIR::build(&spec, &params).unwrap(), 1)
                .unwrap(),
        )
    }

    fn tiny_quant_plan(id: &str, seed: u64) -> Arc<ExecutionPlan> {
        let (spec, mut params) =
            synth::vgg_style(id, 8, 4, &[4, 6], seed);
        synth::pattern_prune(&spec, &mut params, 0.25);
        Arc::new(
            compile_plan_quant(
                ModelIR::build(&spec, &params).unwrap(),
                1,
            )
            .unwrap(),
        )
    }

    #[test]
    fn empty_gateway_is_a_config_error() {
        match Gateway::builder().spawn() {
            Err(ServeError::Config { msg }) => {
                assert!(msg.contains("no tenants"));
            }
            _ => panic!("expected Config error"),
        }
    }

    #[test]
    fn duplicate_tenant_is_a_config_error() {
        let plan = tiny_plan("gw_dup", 1);
        let res = Gateway::builder()
            .tenant(
                TenantConfig::new("a"),
                plan.clone(),
                KernelKind::PatternScalar,
            )
            .tenant(TenantConfig::new("a"), plan, KernelSel::Auto)
            .spawn();
        assert!(matches!(res, Err(ServeError::Config { .. })));
    }

    #[test]
    fn over_budget_plan_is_typed() {
        let plan = tiny_plan("gw_big", 1);
        let need = plan_bytes(&plan);
        let res = Gateway::builder()
            .tenant(
                TenantConfig::new("tight").mem_budget(need - 1),
                plan,
                KernelKind::PatternScalar,
            )
            .spawn();
        match res {
            Err(ServeError::OverBudget {
                tenant,
                need: n,
                budget,
            }) => {
                assert_eq!(tenant, "tight");
                assert_eq!(n, need);
                assert_eq!(budget, need - 1);
            }
            _ => panic!("expected OverBudget"),
        }
    }

    #[test]
    fn routes_tenants_to_their_own_plans() {
        let plan_a = tiny_plan("gw_a", 11);
        let plan_b = tiny_plan("gw_b", 22);
        let gw = Gateway::builder()
            .workers(2)
            .max_batch(4)
            .max_wait_us(200)
            .tenant(
                TenantConfig::new("alice"),
                plan_a.clone(),
                KernelKind::PatternScalar,
            )
            .tenant(
                TenantConfig::new("bob").priority(Priority::High),
                plan_b.clone(),
                KernelSel::Auto,
            )
            .spawn()
            .unwrap();
        let h = gw.handle();
        assert_eq!(h.in_dims("alice").unwrap(), plan_a.in_dims);
        let mut direct_a =
            Executor::new(&plan_a, KernelKind::PatternScalar);
        let mut direct_b = Executor::auto(&plan_b);
        for seed in 0..6u64 {
            let img = request_image(plan_a.in_dims, seed, 0);
            let want = direct_a.execute(&img);
            assert_eq!(
                h.infer("alice", img).unwrap().logits,
                want,
                "alice seed {seed}"
            );
            let img = request_image(plan_b.in_dims, 100 + seed, 0);
            let want = direct_b.execute(&img);
            assert_eq!(
                h.infer("bob", img).unwrap().logits,
                want,
                "bob seed {seed}"
            );
        }
        assert!(matches!(
            h.infer("mallory", Fmap::zeros(1, 1)),
            Err(ServeError::UnknownTenant { .. })
        ));
        assert!(matches!(
            h.infer("alice", Fmap::zeros(1, 1)),
            Err(ServeError::BadShape { .. })
        ));
        let report = gw.shutdown();
        let a = report.tenant("alice").unwrap();
        let b = report.tenant("bob").unwrap();
        assert_eq!(a.report.completed, 6);
        assert_eq!(b.report.completed, 6);
        assert_eq!(b.priority, Priority::High);
        assert_eq!(report.totals().1, 12);
        assert!(report.table("gw").render().contains("alice"));
    }

    #[test]
    fn quantized_and_f32_tenants_coexist() {
        // same weights, one tenant serving i8 and one f32: each tenant's
        // responses match its own plan's direct executor bit for bit
        let plan_f = tiny_plan("gw_mixed", 17);
        let plan_q = tiny_quant_plan("gw_mixed", 17);
        let gw = Gateway::builder()
            .workers(2)
            .max_batch(4)
            .max_wait_us(200)
            .tenant(
                TenantConfig::new("full"),
                plan_f.clone(),
                KernelSel::Auto,
            )
            .tenant(
                TenantConfig::new("quant"),
                plan_q.clone(),
                KernelSel::Auto,
            )
            .spawn()
            .unwrap();
        let h = gw.handle();
        let mut direct_f = Executor::auto(&plan_f);
        let mut direct_q = Executor::auto(&plan_q);
        for seed in 0..6u64 {
            let img = request_image(plan_f.in_dims, seed, 0);
            let want_f = direct_f.execute(&img);
            let want_q = direct_q.execute(&img);
            assert_eq!(
                h.infer("full", img.clone()).unwrap().logits,
                want_f,
                "f32 seed {seed}"
            );
            assert_eq!(
                h.infer("quant", img).unwrap().logits,
                want_q,
                "i8 seed {seed}"
            );
        }
        let report = gw.shutdown();
        assert_eq!(report.tenant("full").unwrap().report.completed, 6);
        assert_eq!(report.tenant("quant").unwrap().report.completed, 6);
    }

    #[test]
    fn virtual_time_admission_sheds_deterministically() {
        let plan = tiny_plan("gw_admit", 3);
        // 2-token burst, 1 token per virtual second
        let mk = || {
            Gateway::builder()
                .workers(1)
                .tenant(
                    TenantConfig::new("t").admit(1.0, 2.0),
                    plan.clone(),
                    KernelKind::PatternScalar,
                )
                .spawn()
                .unwrap()
        };
        let run = |gw: &Gateway| -> Vec<bool> {
            let h = gw.handle();
            // events at 0s,0s,0s,0s,2.5s: burst admits 2, then sheds 2,
            // then the refill admits the late one
            [0u64, 0, 0, 0, 2_500_000]
                .iter()
                .enumerate()
                .map(|(i, &vt)| {
                    let img =
                        request_image(plan.in_dims, 9, i as u64);
                    match h.submit_at("t", img, vt) {
                        Ok(tk) => {
                            tk.wait().unwrap();
                            true
                        }
                        Err(ServeError::Shed { tenant }) => {
                            assert_eq!(tenant, "t");
                            false
                        }
                        Err(e) => panic!("unexpected {e}"),
                    }
                })
                .collect()
        };
        let gw1 = mk();
        let out1 = run(&gw1);
        assert_eq!(out1, vec![true, true, false, false, true]);
        let r1 = gw1.shutdown();
        let gw2 = mk();
        let out2 = run(&gw2);
        assert_eq!(out1, out2, "admission is trace-pure");
        let r2 = gw2.shutdown();
        let t1 = &r1.tenant("t").unwrap().report;
        let t2 = &r2.tenant("t").unwrap().report;
        assert_eq!(t1.shed, 2);
        assert_eq!(
            t1.deterministic_counters(),
            t2.deterministic_counters()
        );
    }

    #[test]
    fn full_tenant_queue_rejects_only_that_tenant() {
        let plan = tiny_plan("gw_full", 5);
        let gw = Gateway::builder()
            .workers(1)
            .max_batch(1)
            .max_wait_us(0)
            .tenant(
                TenantConfig::new("small").queue_cap(1),
                plan.clone(),
                KernelKind::PatternScalar,
            )
            .tenant(
                TenantConfig::new("roomy").queue_cap(64),
                plan.clone(),
                KernelKind::PatternScalar,
            )
            .spawn()
            .unwrap();
        let h = gw.handle();
        // saturate "small" far past its 1-slot queue; with one worker
        // draining, some submits must bounce — and "roomy" stays open
        let mut small_rejected = 0;
        let mut tickets = Vec::new();
        for i in 0..64u64 {
            match h.submit("small", request_image(plan.in_dims, 1, i))
            {
                Ok(t) => tickets.push(t),
                Err(ServeError::Rejected) => small_rejected += 1,
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert!(small_rejected > 0, "tiny queue must bounce");
        for i in 0..4u64 {
            tickets.push(
                h.submit("roomy", request_image(plan.in_dims, 2, i))
                    .unwrap(),
            );
        }
        let report = gw.shutdown();
        for t in tickets {
            t.wait().unwrap();
        }
        let small = &report.tenant("small").unwrap().report;
        let roomy = &report.tenant("roomy").unwrap().report;
        assert_eq!(small.rejected, small_rejected);
        assert_eq!(roomy.rejected, 0);
        assert_eq!(roomy.completed, 4);
        assert_eq!(
            small.submitted, small.completed,
            "accepted requests all drained"
        );
    }

    #[test]
    fn deadline_shed_drops_expired_requests() {
        let plan = tiny_plan("gw_ddl", 7);
        let gw = Gateway::builder()
            .workers(1)
            .max_batch(4)
            .max_wait_us(0)
            .tenant(
                // 1µs deadline: by the time a worker forms a batch the
                // head is always expired
                TenantConfig::new("rushed").deadline_us(1),
                plan.clone(),
                KernelKind::PatternScalar,
            )
            .spawn()
            .unwrap();
        let h = gw.handle();
        let mut tickets = Vec::new();
        for i in 0..8u64 {
            tickets
                .push(h.submit("rushed", request_image(plan.in_dims, 1, i)).unwrap());
        }
        // give the worker time to shed/serve everything submitted
        std::thread::sleep(Duration::from_millis(100));
        let report = gw.shutdown();
        let r = &report.tenant("rushed").unwrap().report;
        assert!(r.shed_deadline > 0, "expired heads must shed");
        assert_eq!(r.completed + r.shed_deadline, 8);
        let canceled = tickets
            .into_iter()
            .map(Ticket::wait)
            .filter(|w| {
                matches!(w, Err(ServeError::Canceled { .. }))
            })
            .count() as u64;
        assert_eq!(canceled, r.shed_deadline);
    }

    #[test]
    fn closed_gateway_refuses_submits() {
        let plan = tiny_plan("gw_closed", 9);
        let gw = Gateway::builder()
            .workers(1)
            .tenant(
                TenantConfig::new("t"),
                plan.clone(),
                KernelKind::PatternScalar,
            )
            .spawn()
            .unwrap();
        let h = gw.handle();
        gw.shutdown();
        assert!(matches!(
            h.submit("t", request_image(plan.in_dims, 1, 0)),
            Err(ServeError::Closed)
        ));
    }

    #[test]
    fn priority_orders_pending_dispatch() {
        let plan = tiny_plan("gw_prio", 13);
        let gw = Gateway::builder()
            .workers(1)
            .max_batch(1)
            .max_wait_us(0)
            .tenant(
                TenantConfig::new("bulk").priority(Priority::Low),
                plan.clone(),
                KernelKind::PatternScalar,
            )
            .tenant(
                TenantConfig::new("urgent").priority(Priority::High),
                plan.clone(),
                KernelKind::PatternScalar,
            )
            .spawn()
            .unwrap();
        let h = gw.handle();
        // interleave submissions into both queues; dispatch order is the
        // priority policy's business, completion totals are ours
        let mut tickets = Vec::new();
        for i in 0..6u64 {
            tickets.push(
                h.submit("bulk", request_image(plan.in_dims, 1, i))
                    .unwrap(),
            );
            tickets.push(
                h.submit("urgent", request_image(plan.in_dims, 2, i))
                    .unwrap(),
            );
        }
        let report = gw.shutdown();
        for t in tickets {
            t.wait().unwrap();
        }
        assert_eq!(report.tenant("urgent").unwrap().report.completed, 6);
        assert_eq!(report.tenant("bulk").unwrap().report.completed, 6);
        assert_eq!(report.totals().1, 12);
    }
}
