//! Host-side ND tensor substrate (f32, row-major), shared by the runtime
//! marshalling layer, the pruning projections, and the mobile engine.

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn filled(shape: &[usize], v: f32) -> Self {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        if shape.iter().product::<usize>() != data.len() {
            bail!(
                "shape {:?} (={}) does not match data len {}",
                shape,
                shape.iter().product::<usize>(),
                data.len()
            );
        }
        Ok(Tensor {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn scalar(v: f32) -> Self {
        Tensor {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Row-major reshape (no data movement).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        if shape.iter().product::<usize>() != self.data.len() {
            bail!("cannot reshape {:?} -> {:?}", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() on {:?}", self.shape);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() on {:?}", self.shape);
        self.shape[1]
    }

    pub fn at2(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.shape[1] + c]
    }

    pub fn set2(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.shape[1] + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let c = self.shape[1];
        &self.data[r * c..(r + 1) * c]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let c = self.shape[1];
        &mut self.data[r * c..(r + 1) * c]
    }

    pub fn sq_frobenius(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }

    pub fn count_nonzero(&self) -> usize {
        self.data.iter().filter(|&&x| x != 0.0).count()
    }

    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Self {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    /// Elementwise `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// Elementwise product (used for mask application on host).
    pub fn hadamard(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a *= b;
        }
    }

    /// Max |a - b| over all elements.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Argmax along the last axis of a 2D tensor, per row.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.shape.len(), 2);
        (0..self.shape[0])
            .map(|r| {
                let row = self.row(r);
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

/// Select the indices of the `k` largest values (by `score`) out of `n`,
/// ordered descending. Deterministic tie-break by lower index. Partial
/// selection: O(n) to isolate the top k, then O(k log k) to order them —
/// the full sort only ever touches k elements.
pub fn top_k_indices(scores: &[f64], k: usize) -> Vec<usize> {
    // NaN-safe total order: NaN ranks below everything (a diverged weight
    // must never be selected as a "largest magnitude").
    let key = |i: usize| -> f64 {
        let s = scores[i];
        if s.is_nan() {
            f64::NEG_INFINITY
        } else {
            s
        }
    };
    let cmp = |a: &usize, b: &usize| {
        key(*b)
            .partial_cmp(&key(*a))
            .expect("keys are never NaN")
            .then(a.cmp(b))
    };
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    let k = k.min(idx.len());
    if k == 0 {
        return Vec::new();
    }
    if k < idx.len() {
        idx.select_nth_unstable_by(k - 1, cmp);
        idx.truncate(k);
    }
    idx.sort_by(cmp);
    idx
}

/// Borrowed (C, H, W) feature-map view over a flat f32 slice — the shape
/// the mobile executor streams through its buffer arena (no ownership, no
/// copies; `Copy` so it crosses `thread::scope` spawns freely).
#[derive(Clone, Copy, Debug)]
pub struct Chw<'a> {
    pub c: usize,
    pub hw: usize,
    pub data: &'a [f32],
}

impl<'a> Chw<'a> {
    pub fn new(c: usize, hw: usize, data: &'a [f32]) -> Self {
        debug_assert!(data.len() >= c * hw * hw);
        Chw { c, hw, data }
    }

    #[inline]
    pub fn plane(&self, ch: usize) -> &'a [f32] {
        &self.data[ch * self.hw * self.hw..(ch + 1) * self.hw * self.hw]
    }
}

/// Preallocated f32 scratch buffer that counts post-construction growth.
/// The mobile buffer arena is built from these: a plan sizes every buffer
/// up front, so `grows()` staying at 0 across inference calls is the
/// zero-allocation invariant the tests assert.
#[derive(Clone, Debug, Default)]
pub struct ScratchBuf {
    data: Vec<f32>,
    grows: usize,
}

impl ScratchBuf {
    pub fn with_len(n: usize) -> Self {
        ScratchBuf {
            data: vec![0.0; n],
            grows: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Times a `slice_mut` request exceeded the preallocated length and
    /// forced a heap growth.
    pub fn grows(&self) -> usize {
        self.grows
    }

    #[inline]
    pub fn slice(&self, n: usize) -> &[f32] {
        &self.data[..n]
    }

    /// First `n` elements, growing (and counting the growth) if the buffer
    /// was under-provisioned.
    #[inline]
    pub fn slice_mut(&mut self, n: usize) -> &mut [f32] {
        if n > self.data.len() {
            self.grows += 1;
            self.data.resize(n, 0.0);
        }
        &mut self.data[..n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_validates_shape() {
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::from_vec(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[2, 6], (0..12).map(|i| i as f32).collect())
            .unwrap();
        let t = t.reshape(&[3, 4]).unwrap();
        assert_eq!(t.at2(1, 0), 4.0);
        assert!(t.clone().reshape(&[5, 5]).is_err());
    }

    #[test]
    fn row_and_at2_agree() {
        let t = Tensor::from_vec(&[3, 4], (0..12).map(|i| i as f32).collect())
            .unwrap();
        assert_eq!(t.row(2), &[8.0, 9.0, 10.0, 11.0]);
        assert_eq!(t.at2(2, 3), 11.0);
    }

    #[test]
    fn argmax_rows_basic() {
        let t =
            Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.2, 3.0, -1.0, 2.0])
                .unwrap();
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn top_k_orders_and_breaks_ties() {
        let s = vec![1.0, 5.0, 5.0, 0.0];
        assert_eq!(top_k_indices(&s, 2), vec![1, 2]);
        assert_eq!(top_k_indices(&s, 3), vec![1, 2, 0]);
        assert_eq!(top_k_indices(&s, 0), Vec::<usize>::new());
        // k >= n returns the full descending order
        assert_eq!(top_k_indices(&s, 9), vec![1, 2, 0, 3]);
    }

    #[test]
    fn top_k_ranks_nan_last() {
        let s = vec![f64::NAN, 2.0, f64::NAN, 1.0, 3.0];
        // NaNs must never displace finite scores...
        assert_eq!(top_k_indices(&s, 3), vec![4, 1, 3]);
        // ...and when forced into the tail they tie-break by lower index.
        assert_eq!(top_k_indices(&s, 5), vec![4, 1, 3, 0, 2]);
        let all_nan = vec![f64::NAN; 3];
        assert_eq!(top_k_indices(&all_nan, 2), vec![0, 1]);
    }

    #[test]
    fn top_k_matches_full_sort_on_random_input() {
        use crate::rng::Pcg32;
        let mut rng = Pcg32::seeded(31);
        for n in [1usize, 7, 64, 257] {
            let s: Vec<f64> =
                (0..n).map(|_| rng.normal() as f64).collect();
            let mut full: Vec<usize> = (0..n).collect();
            full.sort_by(|&a, &b| {
                s[b].partial_cmp(&s[a]).unwrap().then(a.cmp(&b))
            });
            for k in [0usize, 1, n / 2, n] {
                assert_eq!(top_k_indices(&s, k), full[..k], "n={n} k={k}");
            }
        }
    }

    #[test]
    fn chw_view_planes() {
        let data: Vec<f32> = (0..2 * 9).map(|i| i as f32).collect();
        let v = Chw::new(2, 3, &data);
        assert_eq!(v.plane(0), &data[..9]);
        assert_eq!(v.plane(1), &data[9..18]);
    }

    #[test]
    fn scratch_buf_counts_growth() {
        let mut b = ScratchBuf::with_len(8);
        b.slice_mut(4)[0] = 1.0;
        b.slice_mut(8)[7] = 2.0;
        assert_eq!(b.grows(), 0);
        assert_eq!(b.slice(8)[7], 2.0);
        b.slice_mut(16);
        assert_eq!(b.grows(), 1);
        assert_eq!(b.len(), 16);
    }

    #[test]
    fn axpy_hadamard() {
        let mut a = Tensor::from_vec(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_vec(&[2], vec![3.0, 4.0]).unwrap();
        a.axpy(2.0, &b);
        assert_eq!(a.data(), &[7.0, 10.0]);
        a.hadamard(&b);
        assert_eq!(a.data(), &[21.0, 40.0]);
    }
}
