//! Minimal JSON parser + writer (serde is unavailable offline).
//!
//! Parses the full JSON grammar into a dynamic [`Json`] value; enough for
//! `artifacts/manifest.json`, experiment configs, and report output. Not a
//! streaming parser — manifests are a few hundred KB at most.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a usize: {x}");
        }
        Ok(x as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek()? as char
            );
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}', found {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']', found {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i..self.i + 4],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            // surrogate pairs: manifests are ASCII; accept
                            // BMP and replace others.
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    s.push_str(std::str::from_utf8(
                        &self.b[start..self.i],
                    )?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i],
                b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{e}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(
            r#"{"models": {"m": {"shape": [1, 2, 3], "ok": true}}}"#,
        )
        .unwrap();
        let shape = v
            .get("models")
            .unwrap()
            .get("m")
            .unwrap()
            .get("shape")
            .unwrap()
            .usize_arr()
            .unwrap();
        assert_eq!(shape, vec![1, 2, 3]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn roundtrips_display() {
        let src = r#"{"a":[1,2.5,"x\"y"],"b":{"c":null,"d":false}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse("\"π≈3\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "π≈3");
        let v = Json::parse("\"\\u00e9\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }
}
