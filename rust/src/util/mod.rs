//! Small in-tree substrates that replace unavailable external crates:
//! JSON (`json`), property testing (`propcheck`), and misc helpers.
pub mod json;
pub mod propcheck;

use std::time::Instant;

/// Wall-clock timer for coarse phase logging.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

/// `floor(alpha * n)` with the paper's ⌊·⌋ semantics, clamped to ≥1 so a
/// layer never loses all its weights (matches ADMM-pruning practice).
pub fn keep_count(alpha: f64, n: usize) -> usize {
    ((alpha * n as f64).floor() as usize).clamp(1, n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keep_count_floor_and_clamp() {
        assert_eq!(keep_count(0.25, 100), 25);
        assert_eq!(keep_count(0.0624, 16), 1); // floor(0.9984) -> 0 -> clamp 1
        assert_eq!(keep_count(1.0, 7), 7);
        assert_eq!(keep_count(2.0, 7), 7); // over-asking caps at n
    }
}
