//! Property-testing harness (proptest is unavailable offline).
//!
//! A deliberately small propcheck: run a property over `n` cases drawn from
//! a seeded [`Pcg32`]; on failure, report the case index and seed so the
//! exact counterexample replays deterministically. Shrinking is replaced by
//! generator-side size ramping (cases grow from tiny to large, so the first
//! failure tends to be near-minimal).

use crate::rng::Pcg32;

/// Per-case generation context: `size` ramps from 1..=max over the run.
pub struct Gen<'a> {
    pub rng: &'a mut Pcg32,
    pub size: usize,
}

impl<'a> Gen<'a> {
    /// dimension in [1, size]
    pub fn dim(&mut self) -> usize {
        1 + self.rng.below(self.size)
    }

    pub fn dim_up_to(&mut self, cap: usize) -> usize {
        1 + self.rng.below(self.size.min(cap))
    }

    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    pub fn alpha(&mut self) -> f64 {
        // sparsity ratios of interest: 1/16 .. 1.0
        self.rng.uniform_in(0.0625, 1.0) as f64
    }
}

/// Run `prop` over `cases` ramped cases. Panics with a replayable report on
/// the first failure (propcheck properties return `Err(reason)` to fail).
pub fn check<F>(name: &str, seed: u64, cases: usize, max_size: usize, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        // ramp: early cases small, later cases near max_size
        let size = 1 + (max_size - 1) * case / cases.max(1);
        let mut rng = Pcg32::new(seed, case as u64);
        let mut g = Gen {
            rng: &mut rng,
            size,
        };
        if let Err(reason) = prop(&mut g) {
            panic!(
                "property {name:?} failed at case {case} \
                 (seed={seed}, stream={case}, size={size}): {reason}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("uniform-bounds", 1, 200, 64, |g| {
            let x = g.rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn reports_failures() {
        check("always-fails-at-big-size", 1, 50, 32, |g| {
            if g.size < 16 {
                Ok(())
            } else {
                Err("size reached 16".into())
            }
        });
    }

    #[test]
    fn size_ramps() {
        let mut max_seen = 0;
        check("ramp", 3, 100, 40, |g| {
            max_seen = max_seen.max(g.size);
            Ok(())
        });
        assert!(max_seen >= 30);
    }
}
