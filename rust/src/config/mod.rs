//! Typed configuration: the artifact manifest written by `python -m
//! compile.aot` (single source of truth for model semantics) and the
//! experiment schedules (ρ ramp, learning rates, step budgets).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Manifest
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelSpec>,
    pub batches: Batches,
}

#[derive(Clone, Copy, Debug)]
pub struct Batches {
    pub train: usize,
    pub admm: usize,
    pub eval: usize,
}

#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub id: String,
    pub arch: String,
    pub classes: usize,
    pub in_hw: usize,
    pub ops: Vec<Op>,
    pub params: Vec<ParamSpec>,
    /// op indices of prunable conv layers, in network order
    pub prunable: Vec<usize>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

/// The op vocabulary mirrors python/compile/arch.py exactly.
#[derive(Clone, Debug)]
pub enum Op {
    Conv(ConvOp),
    Pool,
    Save { tag: String },
    Proj(ConvOp),
    Add { tag: String },
    Relu,
    Gap,
    Fc { w: usize, b: usize, a: usize, c: usize },
}

#[derive(Clone, Debug)]
pub struct ConvOp {
    pub w: usize,
    pub b: usize,
    pub stride: usize,
    pub act: Act,
    pub prunable: bool,
    pub a: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub in_hw: usize,
    pub out_hw: usize,
    /// residual tag for `proj` ops, empty for main-path convs
    pub tag: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Relu,
    None,
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<(String, Vec<usize>)>,
    pub outputs: Vec<Vec<usize>>,
}

impl ConvOp {
    /// GEMM matrix shape (P, Q) = (A, C·kh·kw) — paper §IV-A.
    pub fn gemm_shape(&self) -> (usize, usize) {
        (self.a, self.c * self.kh * self.kw)
    }
}

impl ModelSpec {
    /// Prunable conv layers in network order: (op index, ConvOp).
    pub fn prunable_convs(&self) -> Vec<(usize, &ConvOp)> {
        self.prunable
            .iter()
            .map(|&i| match &self.ops[i] {
                Op::Conv(c) => (i, c),
                other => panic!("prunable op {i} is not a conv: {other:?}"),
            })
            .collect()
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("model {} has no artifact {name}", self.id))
    }

    pub fn total_prunable_weights(&self) -> usize {
        self.prunable_convs()
            .iter()
            .map(|(_, c)| {
                let (p, q) = c.gemm_shape();
                p * q
            })
            .sum()
    }
}

fn parse_act(s: &str) -> Result<Act> {
    match s {
        "relu" => Ok(Act::Relu),
        "none" => Ok(Act::None),
        _ => bail!("unknown act {s:?}"),
    }
}

fn parse_conv(o: &Json, tag: String) -> Result<ConvOp> {
    Ok(ConvOp {
        w: o.get("w")?.as_usize()?,
        b: o.get("b")?.as_usize()?,
        stride: o.get("stride")?.as_usize()?,
        act: parse_act(o.get("act")?.as_str()?)?,
        prunable: o.get("prunable")?.as_bool()?,
        a: o.get("A")?.as_usize()?,
        c: o.get("C")?.as_usize()?,
        kh: o.get("kh")?.as_usize()?,
        kw: o.get("kw")?.as_usize()?,
        in_hw: o.get("in_hw")?.as_usize()?,
        out_hw: o.get("out_hw")?.as_usize()?,
        tag,
    })
}

fn parse_op(o: &Json) -> Result<Op> {
    let kind = o.get("op")?.as_str()?;
    Ok(match kind {
        "conv" => Op::Conv(parse_conv(o, String::new())?),
        "pool" => Op::Pool,
        "save" => Op::Save {
            tag: o.get("tag")?.as_str()?.to_string(),
        },
        "proj" => {
            let tag = o.get("tag")?.as_str()?.to_string();
            Op::Proj(parse_conv(o, tag)?)
        }
        "add" => Op::Add {
            tag: o.get("tag")?.as_str()?.to_string(),
        },
        "relu" => Op::Relu,
        "gap" => Op::Gap,
        "fc" => Op::Fc {
            w: o.get("w")?.as_usize()?,
            b: o.get("b")?.as_usize()?,
            a: o.get("A")?.as_usize()?,
            c: o.get("C")?.as_usize()?,
        },
        _ => bail!("unknown op kind {kind:?}"),
    })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let root = Json::parse(&text).context("parsing manifest.json")?;
        let b = root.get("batches")?;
        let batches = Batches {
            train: b.get("train")?.as_usize()?,
            admm: b.get("admm")?.as_usize()?,
            eval: b.get("eval")?.as_usize()?,
        };
        let mut models = BTreeMap::new();
        for (id, m) in root.get("models")?.as_obj()? {
            let ops = m
                .get("ops")?
                .as_arr()?
                .iter()
                .map(parse_op)
                .collect::<Result<Vec<_>>>()?;
            let params = m
                .get("params")?
                .as_arr()?
                .iter()
                .map(|p| {
                    Ok(ParamSpec {
                        name: p.get("name")?.as_str()?.to_string(),
                        shape: p.get("shape")?.usize_arr()?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let mut artifacts = BTreeMap::new();
            for (name, a) in m.get("artifacts")?.as_obj()? {
                let inputs = a
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(|i| {
                        Ok((
                            i.get("name")?.as_str()?.to_string(),
                            i.get("shape")?.usize_arr()?,
                        ))
                    })
                    .collect::<Result<Vec<_>>>()?;
                let outputs = a
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(|s| s.usize_arr())
                    .collect::<Result<Vec<_>>>()?;
                artifacts.insert(
                    name.clone(),
                    ArtifactSpec {
                        file: a.get("file")?.as_str()?.to_string(),
                        inputs,
                        outputs,
                    },
                );
            }
            models.insert(
                id.clone(),
                ModelSpec {
                    id: id.clone(),
                    arch: m.get("arch")?.as_str()?.to_string(),
                    classes: m.get("classes")?.as_usize()?,
                    in_hw: m.get("in_hw")?.as_usize()?,
                    ops,
                    params,
                    prunable: m.get("prunable")?.usize_arr()?,
                    artifacts,
                },
            );
        }
        Ok(Manifest {
            dir,
            models,
            batches,
        })
    }

    pub fn model(&self, id: &str) -> Result<&ModelSpec> {
        self.models
            .get(id)
            .with_context(|| format!("manifest has no model {id:?}"))
    }

    pub fn artifact_path(&self, spec: &ArtifactSpec) -> PathBuf {
        self.dir.join(&spec.file)
    }
}

// ---------------------------------------------------------------------------
// Experiment schedules
// ---------------------------------------------------------------------------

/// ADMM schedule — the paper's: ρ starts at 1e-4, ×10 until 1e-1, a fixed
/// number of iterations per ρ segment, SGD lr 1e-3, batch M=32 synthetic
/// samples per iteration. Budgets are compressed for the CPU testbed
/// (DESIGN.md §9); `Preset::Paper` keeps the original proportions.
#[derive(Clone, Debug)]
pub struct AdmmConfig {
    pub rhos: Vec<f32>,
    pub iters_per_rho: usize,
    /// SGD steps inside each primal solve (problem (8))
    pub primal_steps: usize,
    /// lr of the whole-model primal steps (CE / logit-distillation scale)
    pub lr: f32,
    /// lr of the layer-wise primal steps — the Eqn. (8) reconstruction
    /// loss is a per-sample Frobenius norm over whole feature maps, so its
    /// gradients are ~10x larger than the CE/logit losses
    pub lr_layer: f32,
    /// refresh layer inputs after each layer update (Gauss-Seidel, the
    /// paper's Algorithm 1) vs once per iteration (Jacobi ablation)
    pub gauss_seidel: bool,
    pub seed: u64,
    /// worker threads for the proximal projections (and, in the host
    /// scheduler, for layer subproblems); 1 = serial. Pruning results are
    /// bit-identical at any value (see `admm::scheduler`).
    pub threads: usize,
}

impl AdmmConfig {
    pub fn preset(p: Preset) -> Self {
        let (iters, primal) = match p {
            Preset::Smoke => (2, 2),
            Preset::Quick => (5, 3),
            Preset::Full => (15, 4),
        };
        AdmmConfig {
            // the paper ramps 1e-4 -> 1e-1 over ~44 epochs; with compressed
            // budgets the ramp starts higher and ends harder so the primal
            // iterate actually reaches the constraint set before the final
            // hard projection (EXPERIMENTS.md §Tuning).
            rhos: vec![1e-3, 1e-2, 1e-1, 3e-1],
            iters_per_rho: iters,
            primal_steps: primal,
            lr: 1e-2,
            lr_layer: 3e-4,
            gauss_seidel: true,
            seed: 0xADA17,
            threads: 1,
        }
    }

    /// Builder-style thread override (clamped to ≥ 1).
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub seed: u64,
    /// report eval accuracy every `log_every` steps (0 = only at end)
    pub log_every: usize,
}

impl TrainConfig {
    pub fn pretrain(p: Preset) -> Self {
        TrainConfig {
            steps: match p {
                Preset::Smoke => 10,
                Preset::Quick => 150,
                Preset::Full => 400,
            },
            lr: 0.05,
            seed: 0x7EA1,
            log_every: 50,
        }
    }

    pub fn retrain(p: Preset) -> Self {
        TrainConfig {
            steps: match p {
                Preset::Smoke => 10,
                Preset::Quick => 100,
                Preset::Full => 350,
            },
            lr: 0.04,
            seed: 0x2E72,
            log_every: 50,
        }
    }
}

/// Serving-tier knobs (`serve::server::Server`): worker pool size,
/// micro-batch formation, and admission control. Scaled by
/// [`Preset`] like the training/pruning budgets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// batching worker threads, each owning one executor + arena
    pub workers: usize,
    /// dispatch a micro-batch as soon as it holds this many requests
    pub max_batch: usize,
    /// dispatch at latest this long after the first request of a batch
    pub max_wait_us: u64,
    /// bounded queue capacity; a full queue rejects (backpressure)
    pub queue_cap: usize,
    /// intra-batch executor threads (1 = each worker runs its batch
    /// sequentially on its long-lived, allocation-free executor; >1 =
    /// `execute_batch_parallel` inside the worker, which trades per-batch
    /// setup cost — scoped thread spawns + fresh arenas — for parallel
    /// batch execution; only worth it when per-image compute dominates)
    pub batch_threads: usize,
}

impl ServeConfig {
    pub fn preset(p: Preset) -> Self {
        match p {
            Preset::Smoke => ServeConfig {
                workers: 1,
                max_batch: 4,
                max_wait_us: 200,
                queue_cap: 64,
                batch_threads: 1,
            },
            Preset::Quick => ServeConfig {
                workers: 2,
                max_batch: 8,
                max_wait_us: 500,
                queue_cap: 256,
                batch_threads: 1,
            },
            Preset::Full => ServeConfig {
                workers: 4,
                max_batch: 16,
                max_wait_us: 1000,
                queue_cap: 1024,
                batch_threads: 2,
            },
        }
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig::preset(Preset::Quick)
    }
}

/// Shared-pool knobs for the multi-tenant gateway
/// (`serve::gateway::Gateway`). Queue capacity, priority, admission, and
/// memory budgets are *per tenant* (`serve::gateway::TenantConfig`) —
/// this is only the worker pool + micro-batch shape every tenant shares.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GatewayConfig {
    /// worker threads shared across all tenants
    pub workers: usize,
    /// micro-batch cap per dispatch (batches are single-tenant)
    pub max_batch: usize,
    /// straggler window past the head-of-queue enqueue time
    pub max_wait_us: u64,
    /// intra-batch executor threads (as in [`ServeConfig`])
    pub batch_threads: usize,
}

impl GatewayConfig {
    pub fn preset(p: Preset) -> Self {
        let s = ServeConfig::preset(p);
        GatewayConfig {
            workers: s.workers,
            max_batch: s.max_batch,
            max_wait_us: s.max_wait_us,
            batch_threads: s.batch_threads,
        }
    }
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig::preset(Preset::Quick)
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preset {
    /// CI-speed: exercises every code path in seconds
    Smoke,
    /// development default
    Quick,
    /// the EXPERIMENTS.md numbers
    Full,
}

impl Preset {
    pub fn parse(s: &str) -> Result<Preset> {
        match s {
            "smoke" => Ok(Preset::Smoke),
            "quick" => Ok(Preset::Quick),
            "full" => Ok(Preset::Full),
            _ => bail!("unknown preset {s:?} (smoke|quick|full)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_presets_scale_and_stay_sane() {
        for p in [Preset::Smoke, Preset::Quick, Preset::Full] {
            let c = ServeConfig::preset(p);
            assert!(c.workers >= 1);
            assert!(c.max_batch >= 1);
            assert!(c.queue_cap >= c.max_batch);
            assert!(c.batch_threads >= 1);
        }
        assert_eq!(ServeConfig::default(), ServeConfig::preset(Preset::Quick));
        assert!(
            ServeConfig::preset(Preset::Full).max_batch
                > ServeConfig::preset(Preset::Smoke).max_batch
        );
        // the gateway pool inherits the serve preset's shape
        for p in [Preset::Smoke, Preset::Quick, Preset::Full] {
            let g = GatewayConfig::preset(p);
            let s = ServeConfig::preset(p);
            assert_eq!(g.workers, s.workers);
            assert_eq!(g.max_batch, s.max_batch);
        }
        assert_eq!(
            GatewayConfig::default(),
            GatewayConfig::preset(Preset::Quick)
        );
    }

    #[test]
    fn admm_preset_has_compressed_rho_ramp() {
        // the paper ramps 1e-4 -> 1e-1; the compressed schedule starts
        // higher and ends harder (EXPERIMENTS.md §Tuning)
        let c = AdmmConfig::preset(Preset::Full);
        assert_eq!(c.rhos, vec![1e-3, 1e-2, 1e-1, 3e-1]);
        assert!(c.gauss_seidel);
        assert!(c.lr_layer < c.lr);
        assert_eq!(c.threads, 1);
        assert_eq!(c.with_threads(0).threads, 1);
    }

    #[test]
    fn preset_parse() {
        assert_eq!(Preset::parse("quick").unwrap(), Preset::Quick);
        assert!(Preset::parse("bogus").is_err());
    }
}
