//! L3 coordinator: experiment context, pipeline stages, result caching.
//!
//! [`Ctx`] owns the runtime, the preset-scaled budgets, and the `runs/`
//! directory. Every pipeline stage (pretrain → prune → retrain → deploy) is
//! resumable: pre-trained checkpoints and per-row experiment results are
//! cached on disk, so `repro exp all` can be interrupted and rerun.

pub mod cli;
pub mod experiments;
pub mod service;

use std::path::PathBuf;

use anyhow::Result;

use crate::admm::{self, DataSource};
use crate::baselines;
use crate::config::{AdmmConfig, Preset, TrainConfig};
use crate::data::SynthVision;
use crate::pruning::Scheme;
use crate::runtime::Runtime;
use crate::tensor::Tensor;
use crate::train::{self, params as pstore};
use crate::util::json::Json;

/// How a pruned model is produced (the paper's method column).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// problem (3) on synthetic data — the paper's framework
    Privacy,
    /// problem (2) on synthetic data (Table IV comparison)
    PrivacyWhole,
    /// ADMM† on the client's data (no privacy)
    Traditional,
    /// greedy magnitude projection (Table V "Uniform")
    Uniform,
    /// one-shot magnitude pruning [6]
    OneShot,
    /// iterative magnitude pruning [6]
    Iterative,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Privacy => "Privacy-Preserving",
            Method::PrivacyWhole => "Privacy-Preserving (whole, prob. 2)",
            Method::Traditional => "ADMM\u{2020}",
            Method::Uniform => "Uniform",
            Method::OneShot => "One Shot Pruning",
            Method::Iterative => "Iterative Pruning",
        }
    }

    pub fn key(&self) -> &'static str {
        match self {
            Method::Privacy => "privacy",
            Method::PrivacyWhole => "whole",
            Method::Traditional => "admm",
            Method::Uniform => "uniform",
            Method::OneShot => "oneshot",
            Method::Iterative => "iterative",
        }
    }

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "privacy" => Method::Privacy,
            "whole" => Method::PrivacyWhole,
            "admm" => Method::Traditional,
            "uniform" => Method::Uniform,
            "oneshot" => Method::OneShot,
            "iterative" => Method::Iterative,
            _ => anyhow::bail!(
                "unknown method {s:?} \
                 (privacy|whole|admm|uniform|oneshot|iterative)"
            ),
        })
    }

    pub fn preserves_privacy(&self) -> bool {
        matches!(
            self,
            Method::Privacy | Method::PrivacyWhole | Method::Uniform
        )
    }
}

/// Output of a prune stage: (pruned params, masks, achieved compression,
/// wall seconds, mean ADMM-iteration seconds).
pub type PruneStage = (Vec<Tensor>, Vec<Tensor>, f64, f64, f64);

/// One pruning-experiment row (a line of Tables I/II/III/V).
#[derive(Clone, Debug)]
pub struct RowResult {
    pub model: String,
    pub scheme: Scheme,
    pub method: Method,
    pub target_rate: f64,
    pub comp_rate: f64,
    pub base_acc: f64,
    pub prune_acc: f64,
    pub prune_secs: f64,
    pub retrain_secs: f64,
    pub mean_iter_secs: f64,
}

/// Default executor worker-thread count: the host's parallelism capped at
/// 4 (the mobile target's big-core count; more threads than that stops
/// modeling the deployment and only adds scheduling noise to benches).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(4)
}

pub struct Ctx {
    pub rt: Runtime,
    pub preset: Preset,
    pub runs: PathBuf,
    pub verbose: bool,
    /// worker threads for mobile execution plans (deploy / fig3) and for
    /// the prune stage's proximal projections (`--threads` on the CLI)
    pub threads: usize,
}

impl Ctx {
    pub fn new(
        artifacts: impl AsRef<std::path::Path>,
        preset: Preset,
    ) -> Result<Self> {
        Ok(Ctx {
            rt: Runtime::new(artifacts)?,
            preset,
            runs: PathBuf::from("runs"),
            verbose: true,
            threads: default_threads(),
        })
    }

    pub fn log(&self, msg: &str) {
        if self.verbose {
            eprintln!("[repro] {msg}");
        }
    }

    fn dataset_sizes(&self) -> (usize, usize) {
        match self.preset {
            Preset::Smoke => (200, 100),
            Preset::Quick => (1600, 600),
            Preset::Full => (3000, 1000),
        }
    }

    /// Client train/test splits. The dataset seed depends only on
    /// (classes, hw) so every model of a family sees the same data.
    pub fn data(&self, model_id: &str) -> Result<(SynthVision, SynthVision)> {
        let m = self.rt.model(model_id)?;
        let (ntr, nte) = self.dataset_sizes();
        let seed = 0x5EED_0000 + (m.classes * 131 + m.in_hw) as u64;
        Ok((
            SynthVision::generate(m.classes, m.in_hw, ntr, seed, 0),
            SynthVision::generate(m.classes, m.in_hw, nte, seed, 1),
        ))
    }

    fn ckpt_path(&self, model_id: &str) -> PathBuf {
        self.runs
            .join("ckpt")
            .join(format!("{model_id}_{:?}.ckpt", self.preset))
    }

    /// Pre-trained params + base accuracy, cached under runs/ckpt/.
    pub fn pretrained(&self, model_id: &str) -> Result<(Vec<Tensor>, f64)> {
        let spec = self.rt.model(model_id)?.clone();
        let path = self.ckpt_path(model_id);
        let acc_path = path.with_extension("acc");
        if path.exists() && acc_path.exists() {
            let params = pstore::load(&path, &spec)?;
            let acc: f64 =
                std::fs::read_to_string(&acc_path)?.trim().parse()?;
            return Ok((params, acc));
        }
        self.log(&format!("pretraining {model_id} ({:?})", self.preset));
        let (tr, te) = self.data(model_id)?;
        let mut params = pstore::init_params(&spec, 0xBA5E);
        let cfg = TrainConfig::pretrain(self.preset);
        let t = crate::util::Stopwatch::start();
        let trace =
            train::pretrain(&self.rt, model_id, &mut params, &tr, &te, &cfg)?;
        let acc = trace.final_acc();
        self.log(&format!(
            "pretrained {model_id}: acc {:.3} in {:.0}s",
            acc,
            t.secs()
        ));
        pstore::save(&path, &spec, &params)?;
        std::fs::write(&acc_path, format!("{acc}"))?;
        Ok((params, acc))
    }

    /// Run one pruning method at `rate`× target compression. Returns
    /// (pruned params, masks, achieved rate, wall secs, mean iter secs).
    pub fn prune(
        &self,
        model_id: &str,
        method: Method,
        scheme: Scheme,
        rate: f64,
    ) -> Result<PruneStage> {
        let alpha = 1.0 / rate;
        let (pre, _) = self.pretrained(model_id)?;
        let cfg =
            AdmmConfig::preset(self.preset).with_threads(self.threads);
        let t = crate::util::Stopwatch::start();
        let (params, masks, comp, iters) = match method {
            Method::Privacy => {
                let o = admm::prune_layerwise(
                    &self.rt,
                    model_id,
                    &pre,
                    scheme,
                    alpha,
                    &cfg,
                    DataSource::Synthetic,
                )?;
                let mi = mean(&o.trace.per_iter_secs);
                (o.params, o.masks, o.comp_rate, mi)
            }
            Method::PrivacyWhole => {
                let o = admm::prune_whole(
                    &self.rt, model_id, &pre, scheme, alpha, &cfg,
                )?;
                let mi = mean(&o.trace.per_iter_secs);
                (o.params, o.masks, o.comp_rate, mi)
            }
            Method::Traditional => {
                let (tr, _) = self.data(model_id)?;
                let o = admm::prune_traditional(
                    &self.rt, model_id, &pre, scheme, alpha, &cfg, &tr,
                )?;
                let mi = mean(&o.trace.per_iter_secs);
                (o.params, o.masks, o.comp_rate, mi)
            }
            Method::Uniform => {
                let o = baselines::greedy_uniform(
                    &self.rt, model_id, &pre, scheme, alpha,
                )?;
                (o.params, o.masks, o.comp_rate, 0.0)
            }
            Method::OneShot => {
                let o = baselines::one_shot_magnitude(
                    &self.rt, model_id, &pre, alpha,
                )?;
                (o.params, o.masks, o.comp_rate, 0.0)
            }
            Method::Iterative => {
                let (tr, te) = self.data(model_id)?;
                let rcfg = TrainConfig::retrain(self.preset);
                let o = baselines::iterative_magnitude(
                    &self.rt, model_id, &pre, alpha, 3, &tr, &te, &rcfg,
                )?;
                (o.params, o.masks, o.comp_rate, 0.0)
            }
        };
        Ok((params, masks, comp, t.secs(), iters))
    }

    fn row_cache_path(
        &self,
        model_id: &str,
        method: Method,
        scheme: Scheme,
        rate: f64,
    ) -> PathBuf {
        self.runs.join("results").join(format!(
            "{model_id}_{}_{}_{rate:.1}_{:?}.json",
            scheme.name(),
            method.key(),
            self.preset
        ))
    }

    /// Full prune→retrain row, cached under runs/results/.
    pub fn prune_retrain(
        &self,
        model_id: &str,
        method: Method,
        scheme: Scheme,
        rate: f64,
    ) -> Result<RowResult> {
        let cache = self.row_cache_path(model_id, method, scheme, rate);
        if let Some(row) =
            self.load_row(&cache, model_id, method, scheme, rate)
        {
            return Ok(row);
        }
        let (_, base_acc) = self.pretrained(model_id)?;
        self.log(&format!(
            "prune {model_id} {} {} {rate}x",
            method.key(),
            scheme.name()
        ));
        let (mut params, masks, comp, prune_secs, mean_iter) =
            self.prune(model_id, method, scheme, rate)?;
        let (tr, te) = self.data(model_id)?;
        let rcfg = TrainConfig::retrain(self.preset);
        let t = crate::util::Stopwatch::start();
        let trace = train::retrain_masked(
            &self.rt, model_id, &mut params, &masks, &tr, &te, &rcfg,
        )?;
        let row = RowResult {
            model: model_id.into(),
            scheme,
            method,
            target_rate: rate,
            comp_rate: comp,
            base_acc,
            prune_acc: trace.final_acc(),
            prune_secs,
            retrain_secs: t.secs(),
            mean_iter_secs: mean_iter,
        };
        self.log(&format!(
            "row {model_id}/{}/{}: comp {:.1}x base {:.3} pruned {:.3}",
            scheme.name(),
            method.key(),
            row.comp_rate,
            row.base_acc,
            row.prune_acc
        ));
        self.save_row(&cache, &row)?;
        Ok(row)
    }

    fn save_row(&self, path: &PathBuf, row: &RowResult) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("comp_rate".into(), Json::Num(row.comp_rate));
        obj.insert("base_acc".into(), Json::Num(row.base_acc));
        obj.insert("prune_acc".into(), Json::Num(row.prune_acc));
        obj.insert("prune_secs".into(), Json::Num(row.prune_secs));
        obj.insert("retrain_secs".into(), Json::Num(row.retrain_secs));
        obj.insert("mean_iter_secs".into(), Json::Num(row.mean_iter_secs));
        std::fs::write(path, Json::Obj(obj).to_string())?;
        Ok(())
    }

    fn load_row(
        &self,
        path: &PathBuf,
        model_id: &str,
        method: Method,
        scheme: Scheme,
        rate: f64,
    ) -> Option<RowResult> {
        let text = std::fs::read_to_string(path).ok()?;
        let j = Json::parse(&text).ok()?;
        let f = |k: &str| j.get(k).ok().and_then(|v| v.as_f64().ok());
        Some(RowResult {
            model: model_id.into(),
            scheme,
            method,
            target_rate: rate,
            comp_rate: f("comp_rate")?,
            base_acc: f("base_acc")?,
            prune_acc: f("prune_acc")?,
            prune_secs: f("prune_secs")?,
            retrain_secs: f("retrain_secs")?,
            mean_iter_secs: f("mean_iter_secs")?,
        })
    }
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            Method::Privacy,
            Method::PrivacyWhole,
            Method::Traditional,
            Method::Uniform,
            Method::OneShot,
            Method::Iterative,
        ] {
            assert_eq!(Method::parse(m.key()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn default_threads_in_mobile_band() {
        let t = default_threads();
        assert!((1..=4).contains(&t), "{t}");
    }

    #[test]
    fn privacy_flags() {
        assert!(Method::Privacy.preserves_privacy());
        assert!(Method::Uniform.preserves_privacy());
        assert!(!Method::Traditional.preserves_privacy());
        assert!(!Method::Iterative.preserves_privacy());
    }
}
