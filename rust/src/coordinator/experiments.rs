//! Experiment drivers: one function per paper table/figure (DESIGN.md §4).
//!
//! Model/dataset mapping (DESIGN.md §2):
//!   CIFAR-10   → SynthVision-10 @ 16px  (vgg_sv10 / res_sv10)
//!   CIFAR-100  → SynthVision-20 @ 16px  (vgg_sv20 / res_sv20 / resdeep_sv20)
//!   ImageNet   → SynthVision-20 @ 32px  (res32_sv20)
//!
//! Each driver regenerates the table rows by running the pipeline for real
//! (rows are cached under runs/results/, so reruns are incremental) and
//! saves text + markdown renderings under runs/tables/.

use anyhow::Result;

use crate::config::{AdmmConfig, Preset};
use crate::mobile::costmodel::{
    self, latency_ms, AnalyticModel, Device, ALL_ENGINES, GALAXY_S10,
};
use crate::mobile::engine::{Executor, Fmap, KernelKind};
use crate::mobile::ir::ModelIR;
use crate::mobile::plan::PassManager;
use crate::mobile::synth::vgg_style;
use crate::pruning::Scheme;
use crate::report::{loss_cell, pct, rate, Table};
use crate::rng::Pcg32;

use super::service::{PruneConfig, PruneService};
use super::{Ctx, Method, RowResult};

fn acc_row(t: &mut Table, r: &RowResult) {
    t.row(&[
        r.model.clone(),
        r.scheme.name().into(),
        r.method.name().into(),
        rate(r.comp_rate),
        pct(r.base_acc),
        pct(r.prune_acc),
        loss_cell(r.base_acc, r.prune_acc),
        if r.method.preserves_privacy() { "yes" } else { "no" }.into(),
    ]);
}

fn acc_table(title: &str) -> Table {
    Table::new(
        title,
        &[
            "Network",
            "Pruning Scheme",
            "Method",
            "CONV Comp. Rate",
            "Base Accuracy",
            "Pruning Accuracy",
            "Accuracy loss",
            "Privacy",
        ],
    )
}

/// Table I — CIFAR-10 analogue: ResNet & VGG × four schemes ×
/// {ADMM†, Privacy-Preserving} (+ magnitude-pruning baselines on VGG).
pub fn table1(ctx: &Ctx) -> Result<Table> {
    let mut t = acc_table(
        "Table I analogue: SynthVision-10 (CIFAR-10 stand-in)",
    );
    for model in ["res_sv10", "vgg_sv10"] {
        let filter_rate = if model == "res_sv10" { 4.0 } else { 2.3 };
        let cases: Vec<(Scheme, f64)> = vec![
            (Scheme::Irregular, 16.0),
            (Scheme::Column, 6.0),
            (Scheme::Filter, filter_rate),
        ];
        for (scheme, r) in cases {
            for method in [Method::Traditional, Method::Privacy] {
                acc_row(&mut t, &ctx.prune_retrain(model, method, scheme, r)?);
            }
        }
        // magnitude-pruning baselines (paper rows [6], VGG only)
        if model == "vgg_sv10" {
            acc_row(
                &mut t,
                &ctx.prune_retrain(model, Method::Iterative, Scheme::Irregular, 2.0)?,
            );
            acc_row(
                &mut t,
                &ctx.prune_retrain(model, Method::OneShot, Scheme::Irregular, 2.5)?,
            );
        }
        // pattern sweep 8/12/16x
        acc_row(
            &mut t,
            &ctx.prune_retrain(model, Method::Traditional, Scheme::Pattern, 16.0)?,
        );
        for r in [8.0, 16.0] {
            acc_row(
                &mut t,
                &ctx.prune_retrain(model, Method::Privacy, Scheme::Pattern, r)?,
            );
        }
    }
    t.save(ctx.runs.join("tables"), "table1")?;
    Ok(t)
}

/// Table II — CIFAR-100 analogue: pattern pruning across three networks.
pub fn table2(ctx: &Ctx) -> Result<Table> {
    let mut t = acc_table(
        "Table II analogue: SynthVision-20 (CIFAR-100 stand-in), pattern",
    );
    for (model, rates) in [
        ("res_sv20", vec![8.0, 16.0]),
        ("resdeep_sv20", vec![8.0, 16.0]),
        ("vgg_sv20", vec![8.0, 12.0]),
    ] {
        for r in rates {
            acc_row(
                &mut t,
                &ctx.prune_retrain(model, Method::Privacy, Scheme::Pattern, r)?,
            );
        }
    }
    t.save(ctx.runs.join("tables"), "table2")?;
    Ok(t)
}

/// Table III — ImageNet analogue: pattern 4x/6x (+ ADMM† 6x) on the
/// 20-class ResNet. The 32px variant (res32_sv20) is in the manifest and
/// runnable via `repro retrain --model res32_sv20 ...`, but its 4x compute
/// is excluded from the default suite (quick preset is CPU-budgeted).
pub fn table3(ctx: &Ctx) -> Result<Table> {
    let mut t = acc_table(
        "Table III analogue: SynthVision-20 ResNet (ImageNet stand-in)",
    );
    let model = "res_sv20";
    acc_row(
        &mut t,
        &ctx.prune_retrain(model, Method::Traditional, Scheme::Pattern, 6.0)?,
    );
    for r in [4.0, 6.0] {
        acc_row(
            &mut t,
            &ctx.prune_retrain(model, Method::Privacy, Scheme::Pattern, r)?,
        );
    }
    t.save(ctx.runs.join("tables"), "table3")?;
    Ok(t)
}

/// Table IV — problem (3) vs problem (2): accuracy + per-iteration runtime.
pub fn table4(ctx: &Ctx) -> Result<Table> {
    let mut t = Table::new(
        "Table IV analogue: problem formulations (VGG, irregular 16x)",
        &[
            "Method",
            "Pruning Scheme",
            "Base Accuracy",
            "Prune Accuracy",
            "CONV Comp. Rate",
            "Per Iter. Run Time",
        ],
    );
    let model = "vgg_sv10";
    let p3 = ctx.prune_retrain(model, Method::Privacy, Scheme::Irregular, 16.0)?;
    let p2 =
        ctx.prune_retrain(model, Method::PrivacyWhole, Scheme::Irregular, 16.0)?;
    for (name, r) in [("Problem (3) layer-wise", &p3), ("Problem (2) whole-model", &p2)]
    {
        t.row(&[
            name.into(),
            r.scheme.name().into(),
            pct(r.base_acc),
            pct(r.prune_acc),
            rate(r.comp_rate),
            format!("{:.3} secs", r.mean_iter_secs),
        ]);
    }
    t.save(ctx.runs.join("tables"), "table4")?;
    Ok(t)
}

/// Table V — ADMM vs greedy/Uniform under privacy, all four schemes.
pub fn table5(ctx: &Ctx) -> Result<Table> {
    let mut t = acc_table(
        "Table V analogue: effectiveness vs greedy (Uniform) pruning",
    );
    for model in ["res_sv10", "vgg_sv10"] {
        let filter_rate = if model == "res_sv10" { 4.0 } else { 2.3 };
        for (scheme, r) in [
            (Scheme::Irregular, 16.0),
            (Scheme::Column, 6.0),
            (Scheme::Filter, filter_rate),
            (Scheme::Pattern, 16.0),
        ] {
            for method in [Method::Uniform, Method::Privacy] {
                acc_row(&mut t, &ctx.prune_retrain(model, method, scheme, r)?);
            }
        }
    }
    t.save(ctx.runs.join("tables"), "table5")?;
    Ok(t)
}

/// Fig. 3 — mobile CPU/GPU inference latency, ours vs TFLite/TVM/MNN.
///
/// Two parts: (a) *measured* host-CPU wallclock of the compiled sparse
/// engine vs the dense engine on our pattern-pruned mini models, and (b)
/// the calibrated S10 cost model applied to the paper-scale VGG-16@12x and
/// ResNet-18@6x conv stacks using the compiler-pass gains measured in (a).
pub fn fig3(ctx: &Ctx) -> Result<(Table, Table)> {
    // -- part (a): real execution on pruned minis --------------------------
    let mut meas = Table::new(
        &format!(
            "Fig. 3 (measured): host CPU per-frame latency, planned \
             sparse vs dense ({} executor threads)",
            ctx.threads
        ),
        &[
            "Model",
            "Comp. Rate",
            "Dense ms",
            "Sparse ms",
            "Speedup",
            "LRE gain",
            "Reorder gain",
            "Compressed bytes",
        ],
    );
    let mut gains = Vec::new();
    for (model_id, r) in [("vgg_sv20", 12.0), ("res_sv20", 6.0)] {
        // latency depends only on the sparsity structure (same α ⇒ same
        // kept-kernel counts); magnitude projection produces an identical
        // structure class without re-running ADMM (EXPERIMENTS.md §Fig3)
        let (params, _, comp, _, _) =
            ctx.prune(model_id, Method::Uniform, Scheme::Pattern, r)?;
        let spec = ctx.rt.model(model_id)?.clone();
        let plan = PassManager::new(ctx.threads)
            .compile(ModelIR::build(&spec, &params)?)?;
        let mut rng = Pcg32::seeded(99);
        let img = Fmap {
            c: 3,
            hw: spec.in_hw,
            data: (0..3 * spec.in_hw * spec.in_hw)
                .map(|_| rng.uniform())
                .collect(),
        };
        let time = |kind: KernelKind| {
            let mut ex = Executor::new(&plan, kind);
            for _ in 0..3 {
                ex.execute(&img);
            }
            let reps = 30;
            let t = std::time::Instant::now();
            for _ in 0..reps {
                std::hint::black_box(
                    ex.execute(std::hint::black_box(&img)),
                );
            }
            t.elapsed().as_secs_f64() * 1e3 / reps as f64
        };
        let td = time(KernelKind::DenseRef);
        let ts = time(KernelKind::PatternScalar);
        let rep = &plan.report;
        gains.push((rep.lre_gain(), rep.reorder_gain()));
        meas.row(&[
            model_id.into(),
            rate(comp),
            format!("{td:.3}"),
            format!("{ts:.3}"),
            format!("{:.2}x", td / ts),
            format!("{:.2}x", rep.lre_gain()),
            format!("{:.2}x", rep.reorder_gain()),
            format!(
                "{} (dense {})",
                rep.total_compressed_bytes(),
                rep.total_dense_bytes()
            ),
        ]);
    }
    meas.save(ctx.runs.join("tables"), "fig3_measured")?;

    // -- part (b): S10 cost model at paper scale ---------------------------
    let mut est = Table::new(
        "Fig. 3 (estimated, Galaxy S10 cost model): ms per frame",
        &[
            "Model",
            "Device",
            "TFLite",
            "TVM",
            "MNN",
            "Ours",
            "Speedup vs TFLite/TVM/MNN",
        ],
    );
    let (lre_vgg, ro_vgg) = gains[0];
    let (lre_r18, ro_r18) = gains[1];
    let models = [
        AnalyticModel::paper_scale(
            "VGG-16 CIFAR-100 12x",
            &costmodel::vgg16_cifar(),
            12.0,
            lre_vgg,
            ro_vgg,
        ),
        AnalyticModel::paper_scale(
            "ResNet-18 ImageNet 6x",
            &costmodel::resnet18_imagenet(),
            6.0,
            lre_r18,
            ro_r18,
        ),
    ];
    for m in &models {
        for dev in [Device::Cpu, Device::Gpu] {
            let ts: Vec<f64> = ALL_ENGINES
                .iter()
                .map(|e| latency_ms(m, e, &GALAXY_S10, dev))
                .collect();
            let ours = ts[3];
            est.row(&[
                m.name.clone(),
                format!("{dev:?}"),
                format!("{:.1}", ts[0]),
                format!("{:.1}", ts[1]),
                format!("{:.1}", ts[2]),
                format!("{:.1}", ours),
                format!(
                    "{:.1}x / {:.1}x / {:.1}x",
                    ts[0] / ours,
                    ts[1] / ours,
                    ts[2] / ours
                ),
            ]);
        }
    }
    est.save(ctx.runs.join("tables"), "fig3_estimated")?;
    Ok((meas, est))
}

/// `repro exp sweep` — the Tables I–IV prune-stage grid as **one parallel
/// sweep** through the host scheduler (no artifacts or PJRT required): a
/// synthetic VGG spec is pruned under every (scheme, rate) configuration
/// concurrently, and the per-layer solve timings of one fully-parallel run
/// show the scheduler's load balance. Returns (sweep table, per-layer
/// timing table); both are saved under runs/tables/.
pub fn sweep_host(threads: usize, preset: Preset) -> Result<(Table, Table)> {
    let (spec, params) = vgg_style("vgg_host", 16, 10, &[8, 16], 0xBA5E);
    let mut admm = AdmmConfig::preset(preset);
    // the host primal is feature-map normalized (admm::scheduler), so it
    // takes a generic SGD-scale step size
    admm.lr_layer = 5e-3;
    let svc = PruneService::new(threads, 8);
    let configs = [
        PruneConfig {
            scheme: Scheme::Irregular,
            rate: 16.0,
        },
        PruneConfig {
            scheme: Scheme::Irregular,
            rate: 8.0,
        },
        PruneConfig {
            scheme: Scheme::Column,
            rate: 6.0,
        },
        PruneConfig {
            scheme: Scheme::Filter,
            rate: 2.3,
        },
        PruneConfig {
            scheme: Scheme::Pattern,
            rate: 8.0,
        },
        PruneConfig {
            scheme: Scheme::Pattern,
            rate: 16.0,
        },
    ];
    let rows = svc.sweep(&spec, &params, &admm, &configs)?;
    let table = svc.sweep_table(&spec.id, &rows);
    table.save("runs/tables", "sweep_host")?;
    // one latency-mode run to surface the per-layer timing plumbing
    let one = svc.prune_one(&spec, &params, &admm, configs[4])?;
    let timing = one.sched.table();
    timing.save("runs/tables", "sweep_host_layers")?;
    Ok((table, timing))
}

/// Run every experiment and print the tables.
pub fn all(ctx: &Ctx) -> Result<()> {
    let (f3a, f3b) = fig3(ctx)?;
    println!("{}", f3a.render());
    println!("{}", f3b.render());
    let t1 = table1(ctx)?;
    println!("{}", t1.render());
    let t5 = table5(ctx)?;
    println!("{}", t5.render());
    let t4 = table4(ctx)?;
    println!("{}", t4.render());
    let t2 = table2(ctx)?;
    println!("{}", t2.render());
    let t3 = table3(ctx)?;
    println!("{}", t3.render());
    Ok(())
}
