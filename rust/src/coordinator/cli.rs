//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! ```text
//! repro pretrain --model vgg_sv10 [--preset quick]
//! repro prune    --model vgg_sv10 --scheme pattern --rate 8
//!                [--method privacy] [--preset quick]
//! repro retrain  --model ... --scheme ... --rate ...   (prune+retrain row)
//! repro eval     --model vgg_sv10
//! repro deploy   --model vgg_sv20 --rate 12            (compile + report)
//! repro exp      table1|table2|table3|table4|table5|fig3|all [--preset ..]
//! repro pipeline --model res_sv10 --scheme pattern --rate 8  (end-to-end)
//! ```

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{GatewayConfig, Preset, ServeConfig};
use crate::mobile::costmodel::{TuneConfig, TuneReport};
use crate::mobile::engine::{Executor, Fmap, KernelSel, KERNEL_KINDS};
use crate::mobile::ir::ModelIR;
use crate::mobile::plan::{ElemType, ExecutionPlan, PassManager};
use crate::mobile::synth;
use crate::pruning::Scheme;
use crate::report::human_bytes;
use crate::rng::Pcg32;
use crate::serve::artifact;
use crate::serve::error::ServeError;
use crate::serve::faults::{FaultPlan, FaultSite, Faults};
use crate::serve::gateway::{Gateway, Priority, TenantConfig};
use crate::serve::loadgen::{self, LoadGenConfig, LoadMode};
use crate::serve::registry::{PlanKey, PlanRegistry, ShardedRegistry};
use crate::serve::server::Server;

use super::{default_threads, experiments, Ctx, Method};

struct Args {
    cmd: String,
    flags: std::collections::BTreeMap<String, String>,
    positional: Vec<String>,
}

/// Flags that take no value: present means on.
const BOOL_FLAGS: &[&str] = &["quantize"];

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let Some(cmd) = it.next() else {
        bail!("usage: repro <command> [--flags]; see `repro help`");
    };
    let mut flags = std::collections::BTreeMap::new();
    let mut positional = Vec::new();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            if BOOL_FLAGS.contains(&name) {
                flags.insert(name.to_string(), "true".into());
                continue;
            }
            let val = it
                .next()
                .with_context(|| format!("flag --{name} needs a value"))?;
            flags.insert(name.to_string(), val);
        } else {
            positional.push(a);
        }
    }
    Ok(Args {
        cmd,
        flags,
        positional,
    })
}

impl Args {
    fn model(&self) -> Result<&str> {
        self.flags
            .get("model")
            .map(|s| s.as_str())
            .context("--model <id> required (see artifacts/manifest.json)")
    }

    fn preset(&self) -> Result<Preset> {
        match self.flags.get("preset") {
            Some(p) => Preset::parse(p),
            None => Ok(Preset::Quick),
        }
    }

    fn scheme(&self) -> Result<Scheme> {
        Scheme::parse(
            self.flags
                .get("scheme")
                .map(|s| s.as_str())
                .unwrap_or("pattern"),
        )
    }

    fn rate(&self) -> Result<f64> {
        self.flags
            .get("rate")
            .map(|s| s.parse::<f64>().context("--rate must be a number"))
            .unwrap_or(Ok(8.0))
    }

    fn method(&self) -> Result<Method> {
        Method::parse(
            self.flags
                .get("method")
                .map(|s| s.as_str())
                .unwrap_or("privacy"),
        )
    }

    fn artifacts(&self) -> String {
        self.flags
            .get("artifacts")
            .cloned()
            .unwrap_or_else(|| "artifacts".into())
    }

    fn threads(&self) -> Result<usize> {
        match self.flags.get("threads") {
            Some(t) => {
                let n: usize =
                    t.parse().context("--threads must be an integer")?;
                if n == 0 {
                    bail!("--threads must be >= 1");
                }
                Ok(n)
            }
            None => Ok(default_threads()),
        }
    }

    fn ctx(&self) -> Result<Ctx> {
        let mut ctx = Ctx::new(self.artifacts(), self.preset()?)?;
        ctx.threads = self.threads()?;
        Ok(ctx)
    }

    fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            Some(v) => v
                .parse::<usize>()
                .with_context(|| format!("--{name} must be an integer")),
            None => Ok(default),
        }
    }

    fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flags.get(name) {
            Some(v) => v
                .parse::<u64>()
                .with_context(|| format!("--{name} must be an integer")),
            None => Ok(default),
        }
    }

    fn flag_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.flags.get(name) {
            Some(v) => v
                .parse::<f64>()
                .with_context(|| format!("--{name} must be a number")),
            None => Ok(default),
        }
    }

    /// Presence of a valueless flag from [`BOOL_FLAGS`].
    fn flag_bool(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

/// Flags shared by every command that compiles and runs an execution
/// plan (`deploy`, `serve`): one parse path so the commands can never
/// drift in how they read `--threads/--workers/--kernel/--scheme/--rate`.
struct SharedServeFlags {
    /// plan-compile (and pruning) worker threads
    threads: usize,
    /// serving worker threads
    workers: usize,
    /// kernel selection; `None` keeps the command's default
    kernel: Option<KernelSel>,
    scheme: Scheme,
    rate: f64,
}

impl SharedServeFlags {
    fn parse(args: &Args, default_workers: usize) -> Result<Self> {
        Ok(SharedServeFlags {
            threads: args.threads()?,
            workers: args.flag_usize("workers", default_workers)?,
            kernel: match args.flags.get("kernel") {
                Some(k) => Some(KernelSel::parse(k)?),
                None => None,
            },
            scheme: args.scheme()?,
            rate: args.rate()?,
        })
    }
}

const HELP: &str = "\
privacy-preserving DNN pruning + mobile acceleration (Zhan et al. 2020)

commands:
  pretrain  --model <id> [--preset smoke|quick|full]
  prune     --model <id> [--scheme irregular|filter|column|pattern]
            [--rate N] [--threads N]
            [--method privacy|whole|admm|uniform|oneshot|iterative]
  retrain   --model <id> --scheme .. --rate ..      full prune+retrain row
  eval      --model <id>                            pre-trained accuracy
  deploy    --model <id> | --spec vgg|res [--hw N] [--classes N]
            [--seed N] [--scheme ..] [--rate N] [--threads N]
            [--kernel auto|dense|sparse|tiled|vec|vec-tiled]
            [--quantize]
            compile plan + executor report (auto = run the plan-time
            autotuner and print its per-layer table; a named kernel
            times just that one; no flag compares every kernel and
            prints the analytic per-layer choices); --spec builds a
            synthetic pruned model so no artifacts are needed;
            --quantize also compiles the INT8 twin and prints its
            payload shrink, logits error vs f32, and speed delta
  exp       <table1|table2|table3|table4|table5|fig3|sweep|mia|all>
            [--preset ..]
            (sweep = host-engine parallel prune sweep; no artifacts needed)
  exp mia   [--preset smoke|quick|full] [--progressive N] [--threads N]
            privacy evaluation tier: membership-inference attacks
            (confidence-threshold + shadow-model) against the dense
            host-trained target and every (scheme x rate) pruned+
            retrained variant; prints the privacy-vs-compression table,
            saves runs/tables/mia.*, writes BENCH_privacy.json;
            --progressive N prunes each row through an N-rung
            progressive ADMM rate ladder with masked retraining
            between rungs; artifact-free and bit-identical at any
            --threads
  pipeline  --model <id> [--scheme ..] [--rate N]   end-to-end demo
  serve     [--spec vgg|res] [--hw N] [--classes N] [--scheme ..]
            [--rate N] [--threads N] [--workers N] [--batch N]
            [--wait-us N] [--queue N] [--batch-threads N] [--clients N]
            [--qps N] [--requests N]
            [--kernel auto|dense|sparse|tiled|vec|vec-tiled]
            (auto = autotune the plan at compile time, then dispatch
            each layer to its tuned codelet; --threads also sets the
            plan-compile thread count)
            [--artifact <path>] [--seed N] [--quantize]
            [--chaos <seed>]
            dynamic-batching inference server on a synthetic spec
            (no PJRT/artifacts needed); --artifact saves/loads the
            compiled plan and verifies the save->load round trip;
            --quantize serves the INT8 plan (cached and persisted
            under its own registry key / artifact element type);
            --chaos arms the seeded fault injector: worker panics
            (supervised + restarted), artifact byte corruption
            (recompile-from-spec fallback), and slow-executor stalls,
            all a pure function of (seed, site, request id)
  serve --tenants N   multi-tenant gateway mode: N synthetic tenants
            sharing one worker pool, each with its own plan, registry
            shard, bounded queue, and priority class (cycling
            high/normal/low); a seeded virtual-time trace splits --qps
            across tenants zipf(--skew S)-wise and is replayed
            deterministically ([--pace X] > 0 paces it in wall time);
            [--admit-qps N] enables per-tenant admission control,
            [--ramp-us N] adds a diurnal rate ramp of that period;
            [--chaos <seed>] injects deterministic faults as above,
            with per-tenant lost/restart counts in the report
  bench diff <baseline.json> <current.json> [--threshold pct]
            compare two BENCH_*.json logs series-by-series (default
            threshold 5%); exits nonzero when any series worsened
            beyond the threshold in its bad direction
  bench baseline [--dir <path>]
            capture every BENCH_*.json in the current directory under
            benches/baselines/<os>-<arch>/ (the checked-in per-runner
            baselines that CI gates against when present)
  models                                            list models in manifest
  help
common flags: --artifacts <dir> (default ./artifacts), --preset (default quick),
              --threads <n> (worker threads for pruning + the executor,
                             default min(cores, 4); results are identical
                             at any thread count)
";

/// Print the per-layer autotuner results table: layer geometry, the
/// winning [`KernelChoice`](crate::mobile::costmodel::KernelChoice), and
/// how many candidate codelets were raced for it.
fn print_tune_table(plan: &ExecutionPlan, report: &TuneReport) {
    println!(
        "  autotuner: {:>5}  {:>10}  {:<34}  {}",
        "layer", "geometry", "chosen kernel", "candidates"
    );
    for lt in &report.layers {
        let lp = &plan.layers[lt.layer];
        // KernelChoice's Display ignores width flags; pad the rendered
        // string so the table stays aligned
        let chosen = lt.chosen.to_string();
        println!(
            "  autotuner: {:>5}  {:>4}x{:<3}s{}  {chosen:<34}  {}",
            lt.layer,
            lp.a,
            lp.in_hw,
            lp.stride,
            lt.timings.len()
        );
    }
}

/// Wrap an `anyhow` compile error for the typed registry boundary.
fn config_err(e: anyhow::Error) -> ServeError {
    ServeError::Config {
        msg: format!("{e:#}"),
    }
}

/// Parse `--chaos <seed>` into an armed [`FaultPlan`] (None when the
/// flag is absent — the fault hooks then cost one branch).
fn chaos_flag(args: &Args) -> Result<Faults> {
    match args.flags.get("chaos") {
        Some(s) => {
            let seed: u64 =
                s.parse().context("--chaos must be a seed (u64)")?;
            Ok(Some(Arc::new(FaultPlan::new(seed))))
        }
        None => Ok(None),
    }
}

/// Load a plan artifact with the chaos corruption hook applied: when
/// the schedule fires [`FaultSite::ArtifactCorrupt`] for this load
/// attempt, one byte is flipped before decode — exercising the typed
/// `ServeError::Artifact` path exactly as real disk corruption would.
fn load_artifact_chaos(
    path: &str,
    chaos: &Faults,
) -> Result<ExecutionPlan, ServeError> {
    let mut bytes = std::fs::read(path).map_err(|e| {
        ServeError::Artifact {
            msg: format!("reading plan artifact {path}: {e}"),
        }
    })?;
    if let Some(plan) = chaos {
        if plan.fires(FaultSite::ArtifactCorrupt, 0) {
            plan.corrupt(&mut bytes, 0);
            println!(
                "chaos: corrupted one byte of artifact {path} \
                 before decode"
            );
        }
    }
    artifact::decode_plan(&bytes)
}

/// `repro serve`: compile-or-load a plan through the registry, stand up
/// the dynamic-batching server, drive it with the seeded load generator,
/// and print the serving report. With `--tenants N` the single server is
/// replaced by the multi-tenant gateway driven from a seeded
/// virtual-time trace.
fn serve_cmd(args: &Args) -> Result<()> {
    let mut cfg = ServeConfig::preset(args.preset()?);
    let shared = SharedServeFlags::parse(args, cfg.workers)?;
    let tenants = args.flag_usize("tenants", 0)?;
    if tenants > 0 {
        return serve_tenants_cmd(args, &shared, tenants);
    }
    let spec_kind = args
        .flags
        .get("spec")
        .map(|s| s.as_str())
        .unwrap_or("vgg")
        .to_string();
    let hw = args.flag_usize("hw", 16)?;
    let classes = args.flag_usize("classes", 10)?;
    let seed = args.flag_u64("seed", 42)?;
    cfg.workers = shared.workers;
    cfg.max_batch = args.flag_usize("batch", cfg.max_batch)?;
    cfg.max_wait_us = args.flag_u64("wait-us", cfg.max_wait_us)?;
    cfg.queue_cap = args.flag_usize("queue", cfg.queue_cap)?;
    cfg.batch_threads =
        args.flag_usize("batch-threads", cfg.batch_threads)?;
    let requests = args.flag_usize("requests", 64)?;
    let clients = args.flag_usize("clients", 8)?;
    let kernel = match shared.kernel {
        Some(k) => k,
        None => KernelSel::parse("sparse")?,
    };
    // `--kernel auto` serves per-layer tuned codelets, so the plan must
    // be compiled through the autotuner (and cached under a key that can
    // never alias the analytic plan)
    let tune = matches!(kernel, KernelSel::Auto);
    let quantize = args.flag_bool("quantize");
    let want_elem = if quantize { ElemType::I8 } else { ElemType::F32 };
    let mode = match args.flags.get("qps") {
        Some(q) => LoadMode::Open {
            qps: q.parse().context("--qps must be a number")?,
        },
        None => LoadMode::Closed { clients },
    };

    // the id encodes every flag the compiled plan depends on, so the
    // stale-artifact guard below catches any drift in spec, geometry,
    // scheme, pruning rate, class count, or seed
    let model_id = format!(
        "serve_{spec_kind}{hw}_c{classes}_{}_r{}m_s{seed}",
        shared.scheme.name(),
        (shared.rate * 1000.0).round() as u64
    );
    let build_spec = |quant: bool| -> Result<ExecutionPlan> {
        let (spec, mut params) = match spec_kind.as_str() {
            "vgg" => {
                synth::vgg_style(&model_id, hw, classes, &[16, 32], seed)
            }
            "res" => {
                synth::res_style(&model_id, hw, classes, &[8, 16], seed)
            }
            other => bail!("unknown --spec {other:?} (vgg|res)"),
        };
        synth::scheme_prune(
            &spec,
            &mut params,
            shared.scheme,
            1.0 / shared.rate,
        );
        let ir = ModelIR::build(&spec, &params)?;
        let mut pm = PassManager::new(shared.threads);
        if quant {
            pm = pm.with_quantize();
        }
        if tune {
            pm = pm.with_tuning(TuneConfig::default());
        }
        let (plan, report) = pm.compile_reported(ir)?;
        if let Some(report) = &report {
            print_tune_table(&plan, report);
        }
        Ok(plan)
    };

    let registry = PlanRegistry::new(4);
    let mut key = PlanKey::new(
        &model_id,
        shared.scheme.name(),
        shared.rate,
        shared.threads,
    );
    if tune {
        key = key.tuned();
    }
    if quantize {
        key = key.quantized();
    }
    let artifact_path = args.flags.get("artifact").cloned();
    let chaos = chaos_flag(args)?;
    let t = crate::util::Stopwatch::start();
    let build_primary = || match &artifact_path {
        Some(p) if std::path::Path::new(p).exists() => {
            let plan = match load_artifact_chaos(p, &chaos) {
                Ok(plan) => plan,
                // degraded mode: a corrupt artifact falls back to
                // recompiling from the spec flags rather than failing
                Err(ServeError::Artifact { msg }) => {
                    println!(
                        "artifact {p} unreadable ({msg}); degraded: \
                         recompiling the plan from its spec"
                    );
                    return build_spec(quantize).map_err(config_err);
                }
                Err(e) => return Err(e),
            };
            // a stale artifact for a different spec must not be served
            // under this run's flags
            if plan.ir.model_id != model_id
                || plan.threads != shared.threads
                || plan.elem != want_elem
            {
                return Err(ServeError::Config {
                    msg: format!(
                        "artifact {p} holds model {:?} compiled for {} \
                         thread(s) with {} payload, but the requested \
                         flags describe {model_id:?} at {} thread(s) \
                         with {} payload; delete it or pass a \
                         different --artifact path",
                        plan.ir.model_id,
                        plan.threads,
                        plan.elem.name(),
                        shared.threads,
                        want_elem.name()
                    ),
                });
            }
            println!(
                "loaded plan artifact {p} ({} layers, arena {})",
                plan.layers.len(),
                human_bytes(plan.stats.arena_bytes)
            );
            Ok(plan)
        }
        Some(p) => {
            let plan = build_spec(quantize).map_err(config_err)?;
            artifact::save(&plan, p)?;
            let loaded = artifact::load(p)?;
            artifact::verify_roundtrip(&plan, &loaded, 4, seed)?;
            let bytes =
                std::fs::metadata(p).map(|m| m.len()).unwrap_or(0);
            println!(
                "artifact round-trip OK: {p} ({bytes} bytes, \
                 bit-identical outputs)"
            );
            Ok(loaded)
        }
        None => build_spec(quantize).map_err(config_err),
    };
    let (plan, degraded) = if quantize {
        // the i8 plan's degraded twin: same flags, f32 payload
        let mut fb_key = PlanKey::new(
            &model_id,
            shared.scheme.name(),
            shared.rate,
            shared.threads,
        );
        if tune {
            fb_key = fb_key.tuned();
        }
        registry.get_or_build_with_fallback(&key, build_primary, &fb_key, || {
            build_spec(false).map_err(config_err)
        })?
    } else {
        (registry.get_or_build(&key, build_primary)?, false)
    };
    if degraded {
        println!(
            "degraded: i8 plan build failed; serving the f32 fallback"
        );
    }
    println!("plan {key} ready in {:.2} ms", t.ms());

    let mut sb = Server::builder(plan.clone()).config(&cfg).kernel(kernel);
    if let Some(fp) = &chaos {
        sb = sb.chaos(fp.clone());
    }
    let server = sb.spawn()?;
    let handle = server.handle();
    let lg = LoadGenConfig {
        mode,
        requests,
        seed,
    };
    let load = loadgen::run(&handle, plan.in_dims, &lg);
    let report = server.shutdown();
    println!(
        "{}",
        report
            .table(&format!(
                "serve {model_id} ({} workers, batch {} / {} us window, \
                 kernel {})",
                cfg.workers,
                cfg.max_batch,
                cfg.max_wait_us,
                kernel.name()
            ))
            .render()
    );
    println!("{}", report.batch_table("batch-size histogram").render());
    println!(
        "loadgen: {requests} issued, {} completed, {} rejected, \
         {:.1} req/s over {:.2} s",
        load.completed, load.rejected, load.achieved_qps, load.wall_secs
    );
    let rs = registry.stats();
    println!(
        "registry: {} ready / cap {} ({} resident), {} hits, \
         {} misses, {} coalesced, {} evictions",
        rs.ready,
        rs.capacity,
        human_bytes(rs.resident_bytes as usize),
        rs.hits,
        rs.misses,
        rs.coalesced,
        rs.evictions
    );
    if let Some(fp) = &chaos {
        println!("{}", fp.summary());
        println!(
            "supervisor: {} request(s) lost to panics, {} worker \
             restart(s)",
            report.worker_lost, report.restarts
        );
    }
    Ok(())
}

/// `repro serve --tenants N`: compile one synthetic plan per tenant
/// through its own [`ShardedRegistry`] shard, stand up the gateway over
/// a shared worker pool, replay a seeded multi-tenant virtual-time
/// trace against it, and print the per-tenant gateway report.
fn serve_tenants_cmd(
    args: &Args,
    shared: &SharedServeFlags,
    n_tenants: usize,
) -> Result<()> {
    let spec_kind = args
        .flags
        .get("spec")
        .map(|s| s.as_str())
        .unwrap_or("vgg")
        .to_string();
    let hw = args.flag_usize("hw", 16)?;
    let classes = args.flag_usize("classes", 10)?;
    let seed = args.flag_u64("seed", 42)?;
    let requests = args.flag_usize("requests", 64)?;
    let total_qps = args.flag_f64("qps", 64.0)?;
    let skew = args.flag_f64("skew", 1.0)?;
    let pace = args.flag_f64("pace", 0.0)?;
    let admit_qps = args.flag_f64("admit-qps", f64::INFINITY)?;
    let ramp_us = args.flag_u64("ramp-us", 0)?;
    let queue_cap = args.flag_usize("queue", 256)?;
    let mut cfg = GatewayConfig::preset(args.preset()?);
    cfg.workers = shared.workers;
    cfg.max_batch = args.flag_usize("batch", cfg.max_batch)?;
    cfg.max_wait_us = args.flag_u64("wait-us", cfg.max_wait_us)?;
    cfg.batch_threads =
        args.flag_usize("batch-threads", cfg.batch_threads)?;
    let kernel = match shared.kernel {
        Some(k) => k,
        None => KernelSel::parse("sparse")?,
    };
    let quantize = args.flag_bool("quantize");
    let chaos = chaos_flag(args)?;

    let mut registry = ShardedRegistry::new();
    let names: Vec<String> =
        (0..n_tenants).map(|ti| format!("t{ti}")).collect();
    for name in &names {
        registry.add_tenant(name, 2, u64::MAX)?;
    }
    let registry = Arc::new(registry);

    let qps = loadgen::skewed_qps(total_qps, n_tenants, skew);
    let per_tenant_requests = requests.div_ceil(n_tenants).max(1);
    let prio = [Priority::High, Priority::Normal, Priority::Low];
    let mut builder =
        Gateway::builder().config(&cfg).registry(registry.clone());
    let mut loads = Vec::with_capacity(n_tenants);
    let t = crate::util::Stopwatch::start();
    for (ti, name) in names.iter().enumerate() {
        let model_id =
            format!("gw_{spec_kind}{hw}_c{classes}_{name}_s{seed}");
        let mut key = PlanKey::new(
            &model_id,
            shared.scheme.name(),
            shared.rate,
            shared.threads,
        );
        if quantize {
            key = key.quantized();
        }
        // per-tenant seed: every tenant gets genuinely different weights
        let tseed = seed.wrapping_add(ti as u64);
        let compile = |quant: bool| -> Result<ExecutionPlan, ServeError> {
            let (spec, mut params) = match spec_kind.as_str() {
                "vgg" => synth::vgg_style(
                    &model_id,
                    hw,
                    classes,
                    &[16, 32],
                    tseed,
                ),
                "res" => synth::res_style(
                    &model_id,
                    hw,
                    classes,
                    &[8, 16],
                    tseed,
                ),
                other => {
                    return Err(ServeError::Config {
                        msg: format!(
                            "unknown --spec {other:?} (vgg|res)"
                        ),
                    })
                }
            };
            synth::scheme_prune(
                &spec,
                &mut params,
                shared.scheme,
                1.0 / shared.rate,
            );
            let ir =
                ModelIR::build(&spec, &params).map_err(config_err)?;
            let mut pm = PassManager::new(shared.threads);
            if quant {
                pm = pm.with_quantize();
            }
            pm.compile(ir).map_err(config_err)
        };
        let (plan, degraded) = if quantize {
            // degraded twin: the same tenant spec compiled to f32
            let fb_key = PlanKey::new(
                &model_id,
                shared.scheme.name(),
                shared.rate,
                shared.threads,
            );
            registry.get_or_build_with_fallback(
                name,
                &key,
                || compile(true),
                &fb_key,
                || compile(false),
            )?
        } else {
            (registry.get_or_build(name, &key, || compile(false))?, false)
        };
        if degraded {
            println!(
                "  tenant {name}: degraded — i8 build failed, serving \
                 the f32 fallback"
            );
        }
        let mut tc = TenantConfig::new(name)
            .priority(prio[ti % prio.len()])
            .queue_cap(queue_cap)
            .degraded(degraded);
        if admit_qps.is_finite() {
            tc = tc.admit(admit_qps, 8.0);
        }
        builder = builder.tenant(tc, plan, kernel);
        loads.push(loadgen::TenantLoad::new(
            name,
            qps[ti],
            per_tenant_requests,
        ));
    }
    println!(
        "compiled {n_tenants} tenant plan(s) in {:.2} ms \
         (zipf s={skew} share of {total_qps} virtual qps each)",
        t.ms()
    );

    let ramp =
        (ramp_us > 0).then(|| loadgen::DiurnalRamp::new(ramp_us, 0.25));
    if let Some(fp) = &chaos {
        builder = builder.chaos(fp.clone());
    }
    let gateway = builder.spawn()?;
    let handle = gateway.handle();
    // the lazy trace streams straight into replay — O(tenants) memory
    // regardless of --requests
    let load = loadgen::replay(
        &handle,
        &loads,
        loadgen::trace_stream(&loads, ramp, seed),
        seed,
        pace,
    )?;
    let report = gateway.shutdown();
    println!(
        "{}",
        report
            .table(&format!(
                "gateway {n_tenants} tenants ({} workers, batch {} / \
                 {} us window, kernel {})",
                cfg.workers,
                cfg.max_batch,
                cfg.max_wait_us,
                kernel.name()
            ))
            .render()
    );
    for c in &load.per_tenant {
        println!(
            "  tenant {:>6}: {} issued, {} completed, {} shed, \
             {} rejected, {} lost",
            c.tenant, c.issued, c.completed, c.shed, c.rejected, c.lost
        );
    }
    let issued: u64 =
        load.per_tenant.iter().map(|c| c.issued).sum();
    println!(
        "replay: {issued} events, {} completed, {} shed, {} rejected \
         in {:.2} s",
        load.completed,
        load.shed,
        load.rejected,
        load.wall_secs
    );
    let total = registry.total();
    println!(
        "registry: {} ready across {} shards, {} hits, {} misses, \
         {} coalesced, {} evictions, {} build failures \
         ({} broken, {} shed fast)",
        total.ready,
        n_tenants,
        total.hits,
        total.misses,
        total.coalesced,
        total.evictions,
        total.build_failures,
        total.broken,
        total.shed_broken
    );
    if let Some(fp) = &chaos {
        println!("{}", fp.summary());
        let lost: u64 =
            report.tenants.iter().map(|t| t.report.worker_lost).sum();
        let restarts: u64 =
            report.tenants.iter().map(|t| t.report.restarts).sum();
        println!(
            "supervisor: {lost} request(s) lost to panics, \
             {restarts} worker restart(s)"
        );
    }
    Ok(())
}

/// `repro deploy`: prune + compile one model and print the full plan
/// report. The pruned weights come either from the artifacts pipeline
/// (`--model <id>`) or, with `--spec vgg|res`, from a synthetic
/// in-Rust spec so the command runs without any artifacts. With
/// `--quantize` the same IR is additionally compiled through the INT8
/// pass and the accuracy/size/speed deltas vs the f32 plan are
/// reported.
fn deploy_cmd(args: &Args) -> Result<()> {
    let shared = SharedServeFlags::parse(args, 1)?;
    let sel = shared.kernel;
    let quantize = args.flag_bool("quantize");
    let (model, spec, params, comp) =
        if let Some(kind) = args.flags.get("spec") {
            let hw = args.flag_usize("hw", 16)?;
            let classes = args.flag_usize("classes", 10)?;
            let seed = args.flag_u64("seed", 42)?;
            let widths: &[usize] =
                if kind == "res" { &[8, 16] } else { &[16, 32] };
            let id = format!("deploy_{kind}{hw}_c{classes}_s{seed}");
            let (spec, mut params) =
                synth::spec_by_kind(kind, &id, hw, classes, widths, seed)?;
            synth::scheme_prune(
                &spec,
                &mut params,
                shared.scheme,
                1.0 / shared.rate,
            );
            (id, spec, params, shared.rate)
        } else {
            let ctx = args.ctx()?;
            let model = args.model()?.to_string();
            let (params, _, comp, _, _) = ctx.prune(
                &model,
                args.method()?,
                shared.scheme,
                shared.rate,
            )?;
            let spec = ctx.rt.model(&model)?.clone();
            (model, spec, params, comp)
        };
    let ir = ModelIR::build(&spec, &params)?;
    let t = crate::util::Stopwatch::start();
    let mut pm = PassManager::new(shared.threads);
    let tune = matches!(sel, Some(KernelSel::Auto));
    if tune {
        pm = pm.with_tuning(TuneConfig::default());
    }
    let (plan, tune_report) = pm.compile_reported(ir.clone())?;
    let plan_ms = t.ms();
    let rep = &plan.report;
    println!(
        "compiled {model} @ {comp:.1}x ({} threads, plan built \
         in {plan_ms:.2} ms):",
        plan.threads
    );
    println!(
        "  MACs dense {} -> sparse {} ({:.2}x)",
        rep.total_dense_macs(),
        rep.total_sparse_macs(),
        rep.total_dense_macs() as f64
            / rep.total_sparse_macs().max(1) as f64
    );
    println!(
        "  weights dense {} -> compressed {} ({:.2}x)",
        human_bytes(rep.total_dense_bytes()),
        human_bytes(rep.total_compressed_bytes()),
        rep.total_dense_bytes() as f64
            / rep.total_compressed_bytes().max(1) as f64
    );
    println!(
        "  LRE gain {:.2}x, reorder gain {:.2}x",
        rep.lre_gain(),
        rep.reorder_gain()
    );
    println!(
        "  plan: payload {} + headers {}, arena {}, {} worker \
         blocks",
        human_bytes(plan.stats.payload_bytes),
        human_bytes(plan.stats.header_bytes),
        human_bytes(plan.stats.arena_bytes),
        plan.stats.n_blocks
    );
    for (name, ms) in &plan.stats.pass_ms {
        println!("    pass {name:14} {ms:9.3} ms");
    }
    match &tune_report {
        Some(rep) => print_tune_table(&plan, rep),
        None => {
            println!(
                "  per-layer kernel choices (analytic; pass \
                 --kernel auto to autotune):"
            );
            for (i, lp) in plan.layers.iter().enumerate() {
                let chosen = lp.choice.to_string();
                println!(
                    "    layer {i:>2}  {:>4}x{:<3}s{}  {chosen}",
                    lp.a, lp.in_hw, lp.stride
                );
            }
        }
    }
    let mut rng = Pcg32::seeded(7);
    let img = Fmap {
        c: 3,
        hw: spec.in_hw,
        data: (0..3 * spec.in_hw * spec.in_hw)
            .map(|_| rng.uniform())
            .collect(),
    };
    // no --kernel: compare every registered kernel; --kernel:
    // time exactly the requested selection (auto = per-layer
    // dispatch through the baked choices)
    let sels: Vec<KernelSel> = match sel {
        Some(s) => vec![s],
        None => KERNEL_KINDS
            .into_iter()
            .map(KernelSel::Uniform)
            .collect(),
    };
    for s in sels {
        let mut ex = Executor::with_sel(&plan, s);
        for _ in 0..3 {
            ex.execute(&img);
        }
        let t = std::time::Instant::now();
        for _ in 0..20 {
            std::hint::black_box(ex.execute(&img));
        }
        println!(
            "  host {:14} inference: {:.3} ms/frame \
             (arena growths: {})",
            ex.kernel_name(),
            t.elapsed().as_secs_f64() * 50.0,
            ex.alloc_events()
        );
    }
    if quantize {
        deploy_quant_report(&plan, ir, shared.threads, tune, spec.in_hw)?;
    }
    Ok(())
}

/// Compile the INT8 twin of `f32_plan` from the same IR and print the
/// `--quantize` deployment report: payload shrink, logits accuracy
/// deltas vs the bit-exact f32 outputs over seeded probe images, and
/// steady-state per-frame speed for both plans.
fn deploy_quant_report(
    f32_plan: &ExecutionPlan,
    ir: ModelIR,
    threads: usize,
    tune: bool,
    in_hw: usize,
) -> Result<()> {
    let t = crate::util::Stopwatch::start();
    let mut pm = PassManager::new(threads).with_quantize();
    if tune {
        pm = pm.with_tuning(TuneConfig::default());
    }
    let (qplan, _) = pm.compile_reported(ir)?;
    println!(
        "  int8: per-filter weight scales + dynamic activation \
         quantization (plan built in {:.2} ms)",
        t.ms()
    );
    println!(
        "    payload {} -> {} ({:.2}x of f32)",
        human_bytes(f32_plan.stats.payload_bytes),
        human_bytes(qplan.stats.payload_bytes),
        qplan.stats.payload_bytes as f64
            / f32_plan.stats.payload_bytes.max(1) as f64
    );
    let mut fex = Executor::auto(f32_plan);
    let mut qex = Executor::auto(&qplan);
    let mut rng = Pcg32::seeded(11);
    let imgs: Vec<Fmap> = (0..8)
        .map(|_| Fmap {
            c: 3,
            hw: in_hw,
            data: (0..3 * in_hw * in_hw)
                .map(|_| rng.uniform())
                .collect(),
        })
        .collect();
    let mut max_abs = 0.0f32;
    let mut rel_sum = 0.0f64;
    let mut rel_n = 0usize;
    for img in &imgs {
        let want = fex.execute(img);
        let got = qex.execute(img);
        for (w, g) in want.iter().zip(&got) {
            let abs = (w - g).abs();
            max_abs = max_abs.max(abs);
            if w.abs() > 1e-6 {
                rel_sum += f64::from(abs / w.abs());
                rel_n += 1;
            }
        }
    }
    println!(
        "    logits vs f32 over {} probe images: max abs err \
         {:.3e}, mean rel err {:.3e}",
        imgs.len(),
        max_abs,
        rel_sum / rel_n.max(1) as f64
    );
    fn steady_ms(ex: &mut Executor<'_>, img: &Fmap) -> f64 {
        for _ in 0..3 {
            ex.execute(img);
        }
        let t = std::time::Instant::now();
        for _ in 0..20 {
            std::hint::black_box(ex.execute(img));
        }
        t.elapsed().as_secs_f64() * 50.0
    }
    let f32_ms = steady_ms(&mut fex, &imgs[0]);
    let i8_ms = steady_ms(&mut qex, &imgs[0]);
    println!(
        "    inference f32 {:.3} ms/frame -> i8 {:.3} ms/frame \
         ({:.2}x)",
        f32_ms,
        i8_ms,
        f32_ms / i8_ms.max(1e-9)
    );
    Ok(())
}

/// `repro exp mia [--preset ..] [--progressive N] [--threads N]`: the
/// privacy evaluation tier. Trains the dense host target, builds the
/// shadow-model pool, prunes+retrains the (scheme × rate) grid
/// (progressively when `--progressive > 1`), and prints the
/// privacy-vs-compression table. Entirely artifact-free (host engine
/// only); results are bit-identical at any `--threads`.
fn exp_mia_cmd(args: &Args) -> Result<()> {
    let mut cfg = crate::privacy::MiaConfig::preset(args.preset()?);
    cfg.threads = args.threads()?;
    cfg.progressive_rounds = args.flag_usize("progressive", 0)?;
    let report = crate::privacy::run_mia(&cfg)?;
    let table = crate::privacy::report::mia_table(&report);
    println!("{}", table.render());
    table.save("runs/tables", "mia")?;
    let log = crate::privacy::report::privacy_bench_log(&report);
    log.write("BENCH_privacy.json")?;
    let dense = report.dense().conf.advantage;
    let pruned = report.mean_pruned_advantage();
    println!(
        "confidence-attack advantage: dense {dense:.3} -> mean pruned \
         {pruned:.3} (privacy gain {:+.3}) in {:.1} s",
        dense - pruned,
        report.secs
    );
    println!("wrote BENCH_privacy.json and runs/tables/mia.*");
    Ok(())
}

/// `repro bench baseline [--dir <path>]`: capture every `BENCH_*.json`
/// in the current directory as the checked-in baseline for this runner
/// class (`<os>-<arch>`). CI diffs fresh logs against these with
/// `repro bench diff` when a baseline directory exists for its runner.
fn bench_baseline_cmd(args: &Args) -> Result<()> {
    let runner = format!(
        "{}-{}",
        std::env::consts::OS,
        std::env::consts::ARCH
    );
    let dir = args
        .flags
        .get("dir")
        .cloned()
        .unwrap_or_else(|| format!("benches/baselines/{runner}"));
    std::fs::create_dir_all(&dir)
        .with_context(|| format!("creating baseline dir {dir}"))?;
    let mut copied = Vec::new();
    for entry in std::fs::read_dir(".")? {
        let entry = entry?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.starts_with("BENCH_") && name.ends_with(".json") {
            let dst = format!("{dir}/{name}");
            std::fs::copy(entry.path(), &dst)
                .with_context(|| format!("copying {name} to {dst}"))?;
            copied.push(name);
        }
    }
    if copied.is_empty() {
        bail!(
            "no BENCH_*.json logs in the current directory; run \
             `cargo bench` and/or `repro exp mia` first"
        );
    }
    copied.sort();
    for name in &copied {
        println!("  {name} -> {dir}/{name}");
    }
    println!(
        "captured {} baseline log(s) for runner class {runner}",
        copied.len()
    );
    Ok(())
}

/// `repro bench diff <baseline.json> <current.json> [--threshold pct]`:
/// compare two `BENCH_*.json` logs series-by-series and exit nonzero if
/// any series worsened beyond the threshold in its bad direction.
/// `repro bench baseline` captures the current logs as the checked-in
/// baseline for this runner class.
fn bench_cmd(args: &Args) -> Result<()> {
    let sub = args.positional.first().map(|s| s.as_str());
    if sub == Some("baseline") {
        return bench_baseline_cmd(args);
    }
    if sub != Some("diff") {
        bail!(
            "usage: repro bench diff <baseline.json> <current.json> \
             [--threshold pct] | repro bench baseline [--dir <path>]"
        );
    }
    let [base_path, cur_path] = &args.positional[1..] else {
        bail!(
            "bench diff takes exactly two positional paths: \
             <baseline.json> <current.json>"
        );
    };
    let threshold = args.flag_f64("threshold", 5.0)?;
    let read = |p: &str| -> Result<crate::util::json::Json> {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading bench log {p}"))?;
        crate::util::json::Json::parse(&text)
            .with_context(|| format!("parsing bench log {p}"))
    };
    let base = read(base_path)?;
    let cur = read(cur_path)?;
    let diff =
        crate::serve::stats::diff_bench_logs(&base, &cur, threshold)?;
    println!(
        "{}",
        diff.table(&format!(
            "bench diff {base_path} -> {cur_path} \
             (threshold {threshold}%)"
        ))
        .render()
    );
    if !diff.only_base.is_empty() {
        println!("  only in baseline: {}", diff.only_base.join(", "));
    }
    if !diff.only_cur.is_empty() {
        println!("  only in current:  {}", diff.only_cur.join(", "));
    }
    let regs = diff.regressions();
    if !regs.is_empty() {
        let names: Vec<&str> =
            regs.iter().map(|r| r.name.as_str()).collect();
        bail!(
            "{} series regressed beyond {threshold}%: {}",
            regs.len(),
            names.join(", ")
        );
    }
    println!(
        "no regressions beyond {threshold}% across {} compared \
         series",
        diff.rows.len()
    );
    Ok(())
}

pub fn main() -> Result<()> {
    let args = parse_args().inspect_err(|_| {
        eprintln!("{HELP}");
    })?;
    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "models" => {
            let ctx = Ctx::new(args.artifacts(), Preset::Quick)?;
            for (id, m) in &ctx.rt.manifest.models {
                println!(
                    "{id:16} arch={:12} classes={:3} in={}x{} prunable convs={}",
                    m.arch,
                    m.classes,
                    m.in_hw,
                    m.in_hw,
                    m.prunable.len()
                );
            }
            Ok(())
        }
        "pretrain" => {
            let ctx = args.ctx()?;
            let (_, acc) = ctx.pretrained(args.model()?)?;
            println!("base accuracy: {acc:.4}");
            Ok(())
        }
        "eval" => {
            let ctx = args.ctx()?;
            let model = args.model()?;
            let (params, _) = ctx.pretrained(model)?;
            let (_, te) = ctx.data(model)?;
            let acc = crate::train::evaluate(&ctx.rt, model, &params, &te)?;
            println!("accuracy: {acc:.4}");
            Ok(())
        }
        "prune" => {
            let ctx = args.ctx()?;
            let model = args.model()?;
            let (_, masks, comp, secs, _) = ctx.prune(
                model,
                args.method()?,
                args.scheme()?,
                args.rate()?,
            )?;
            println!(
                "pruned {model}: comp rate {comp:.2}x, {} masks, {secs:.1}s",
                masks.len()
            );
            Ok(())
        }
        "retrain" => {
            let ctx = args.ctx()?;
            let row = ctx.prune_retrain(
                args.model()?,
                args.method()?,
                args.scheme()?,
                args.rate()?,
            )?;
            println!(
                "comp {:.1}x  base {:.3}  pruned {:.3}  loss {:+.3}",
                row.comp_rate,
                row.base_acc,
                row.prune_acc,
                row.base_acc - row.prune_acc
            );
            Ok(())
        }
        "deploy" => deploy_cmd(&args),
        "bench" => bench_cmd(&args),
        "exp" => {
            let which = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all");
            if which == "sweep" {
                // host-engine parallel sweep: needs no artifacts/PJRT
                let (table, timing) = experiments::sweep_host(
                    args.threads()?,
                    args.preset()?,
                )?;
                println!("{}\n{}", table.render(), timing.render());
                return Ok(());
            }
            if which == "mia" {
                return exp_mia_cmd(&args);
            }
            let ctx = args.ctx()?;
            match which {
                "table1" => println!("{}", experiments::table1(&ctx)?.render()),
                "table2" => println!("{}", experiments::table2(&ctx)?.render()),
                "table3" => println!("{}", experiments::table3(&ctx)?.render()),
                "table4" => println!("{}", experiments::table4(&ctx)?.render()),
                "table5" => println!("{}", experiments::table5(&ctx)?.render()),
                "fig3" => {
                    let (a, b) = experiments::fig3(&ctx)?;
                    println!("{}\n{}", a.render(), b.render());
                }
                "all" => experiments::all(&ctx)?,
                _ => bail!("unknown experiment {which:?}"),
            }
            Ok(())
        }
        "serve" => serve_cmd(&args),
        "pipeline" => {
            let ctx = args.ctx()?;
            let model = args.model()?;
            let scheme = args.scheme()?;
            let rate = args.rate()?;
            println!(
                "=== privacy-preserving pipeline: {model} {} {rate}x ===",
                scheme.name()
            );
            let (_, base) = ctx.pretrained(model)?;
            println!("[1/3] client pre-trained model: acc {base:.3}");
            let row = ctx.prune_retrain(model, Method::Privacy, scheme, rate)?;
            println!(
                "[2/3] designer pruned on synthetic data: {:.1}x compression",
                row.comp_rate
            );
            println!(
                "[3/3] client retrained with mask: acc {:.3} (loss {:+.3})",
                row.prune_acc,
                row.base_acc - row.prune_acc
            );
            Ok(())
        }
        other => {
            eprintln!("{HELP}");
            bail!("unknown command {other:?}");
        }
    }
}
