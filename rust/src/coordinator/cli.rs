//! Command-line interface (hand-rolled; clap is unavailable offline).
//!
//! ```text
//! repro pretrain --model vgg_sv10 [--preset quick]
//! repro prune    --model vgg_sv10 --scheme pattern --rate 8
//!                [--method privacy] [--preset quick]
//! repro retrain  --model ... --scheme ... --rate ...   (prune+retrain row)
//! repro eval     --model vgg_sv10
//! repro deploy   --model vgg_sv20 --rate 12            (compile + report)
//! repro exp      table1|table2|table3|table4|table5|fig3|all [--preset ..]
//! repro pipeline --model res_sv10 --scheme pattern --rate 8  (end-to-end)
//! ```

use anyhow::{bail, Context, Result};

use crate::config::{Preset, ServeConfig};
use crate::mobile::costmodel::{TuneConfig, TuneReport};
use crate::mobile::engine::{Executor, Fmap, KernelSel, KERNEL_KINDS};
use crate::mobile::ir::ModelIR;
use crate::mobile::plan::{
    compile_plan, compile_plan_tuned, ExecutionPlan, PassManager,
};
use crate::mobile::synth;
use crate::pruning::Scheme;
use crate::report::human_bytes;
use crate::rng::Pcg32;
use crate::serve::artifact;
use crate::serve::loadgen::{self, LoadGenConfig, LoadMode};
use crate::serve::registry::{PlanKey, PlanRegistry};
use crate::serve::server::Server;

use super::{default_threads, experiments, Ctx, Method};

struct Args {
    cmd: String,
    flags: std::collections::BTreeMap<String, String>,
    positional: Vec<String>,
}

fn parse_args() -> Result<Args> {
    let mut it = std::env::args().skip(1);
    let Some(cmd) = it.next() else {
        bail!("usage: repro <command> [--flags]; see `repro help`");
    };
    let mut flags = std::collections::BTreeMap::new();
    let mut positional = Vec::new();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = it
                .next()
                .with_context(|| format!("flag --{name} needs a value"))?;
            flags.insert(name.to_string(), val);
        } else {
            positional.push(a);
        }
    }
    Ok(Args {
        cmd,
        flags,
        positional,
    })
}

impl Args {
    fn model(&self) -> Result<&str> {
        self.flags
            .get("model")
            .map(|s| s.as_str())
            .context("--model <id> required (see artifacts/manifest.json)")
    }

    fn preset(&self) -> Result<Preset> {
        match self.flags.get("preset") {
            Some(p) => Preset::parse(p),
            None => Ok(Preset::Quick),
        }
    }

    fn scheme(&self) -> Result<Scheme> {
        Scheme::parse(
            self.flags
                .get("scheme")
                .map(|s| s.as_str())
                .unwrap_or("pattern"),
        )
    }

    fn rate(&self) -> Result<f64> {
        self.flags
            .get("rate")
            .map(|s| s.parse::<f64>().context("--rate must be a number"))
            .unwrap_or(Ok(8.0))
    }

    fn method(&self) -> Result<Method> {
        Method::parse(
            self.flags
                .get("method")
                .map(|s| s.as_str())
                .unwrap_or("privacy"),
        )
    }

    fn artifacts(&self) -> String {
        self.flags
            .get("artifacts")
            .cloned()
            .unwrap_or_else(|| "artifacts".into())
    }

    fn threads(&self) -> Result<usize> {
        match self.flags.get("threads") {
            Some(t) => {
                let n: usize =
                    t.parse().context("--threads must be an integer")?;
                if n == 0 {
                    bail!("--threads must be >= 1");
                }
                Ok(n)
            }
            None => Ok(default_threads()),
        }
    }

    fn ctx(&self) -> Result<Ctx> {
        let mut ctx = Ctx::new(self.artifacts(), self.preset()?)?;
        ctx.threads = self.threads()?;
        Ok(ctx)
    }

    fn flag_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.flags.get(name) {
            Some(v) => v
                .parse::<usize>()
                .with_context(|| format!("--{name} must be an integer")),
            None => Ok(default),
        }
    }

    fn flag_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.flags.get(name) {
            Some(v) => v
                .parse::<u64>()
                .with_context(|| format!("--{name} must be an integer")),
            None => Ok(default),
        }
    }
}

const HELP: &str = "\
privacy-preserving DNN pruning + mobile acceleration (Zhan et al. 2020)

commands:
  pretrain  --model <id> [--preset smoke|quick|full]
  prune     --model <id> [--scheme irregular|filter|column|pattern]
            [--rate N] [--threads N]
            [--method privacy|whole|admm|uniform|oneshot|iterative]
  retrain   --model <id> --scheme .. --rate ..      full prune+retrain row
  eval      --model <id>                            pre-trained accuracy
  deploy    --model <id> [--rate N] [--threads N]   compile plan + executor report
            [--kernel auto|dense|sparse|tiled|vec|vec-tiled]
            (auto = run the plan-time autotuner and print its per-layer
            table; a named kernel times just that one; no flag compares
            every kernel and prints the analytic per-layer choices)
  exp       <table1|table2|table3|table4|table5|fig3|sweep|all> [--preset ..]
            (sweep = host-engine parallel prune sweep; no artifacts needed)
  pipeline  --model <id> [--scheme ..] [--rate N]   end-to-end demo
  serve     [--spec vgg|res] [--hw N] [--classes N] [--rate N]
            [--workers N] [--batch N] [--wait-us N] [--queue N]
            [--batch-threads N] [--plan-threads N] [--clients N]
            [--qps N] [--requests N]
            [--kernel auto|dense|sparse|tiled|vec|vec-tiled]
            (auto = autotune the plan at compile time, then dispatch
            each layer to its tuned codelet)
            [--artifact <path>] [--seed N]
            dynamic-batching inference server on a synthetic spec
            (no PJRT/artifacts needed); --artifact saves/loads the
            compiled plan and verifies the save->load round trip
  models                                            list models in manifest
  help
common flags: --artifacts <dir> (default ./artifacts), --preset (default quick),
              --threads <n> (worker threads for pruning + the executor,
                             default min(cores, 4); results are identical
                             at any thread count)
";

/// Print the per-layer autotuner results table: layer geometry, the
/// winning [`KernelChoice`](crate::mobile::costmodel::KernelChoice), and
/// how many candidate codelets were raced for it.
fn print_tune_table(plan: &ExecutionPlan, report: &TuneReport) {
    println!(
        "  autotuner: {:>5}  {:>10}  {:<34}  {}",
        "layer", "geometry", "chosen kernel", "candidates"
    );
    for lt in &report.layers {
        let lp = &plan.layers[lt.layer];
        // KernelChoice's Display ignores width flags; pad the rendered
        // string so the table stays aligned
        let chosen = lt.chosen.to_string();
        println!(
            "  autotuner: {:>5}  {:>4}x{:<3}s{}  {chosen:<34}  {}",
            lt.layer,
            lp.a,
            lp.in_hw,
            lp.stride,
            lt.timings.len()
        );
    }
}

/// `repro serve`: compile-or-load a plan through the registry, stand up
/// the dynamic-batching server, drive it with the seeded load generator,
/// and print the serving report.
fn serve_cmd(args: &Args) -> Result<()> {
    let spec_kind = args
        .flags
        .get("spec")
        .map(|s| s.as_str())
        .unwrap_or("vgg")
        .to_string();
    let hw = args.flag_usize("hw", 16)?;
    let classes = args.flag_usize("classes", 10)?;
    let rate = args.rate()?;
    let plan_threads = args.flag_usize("plan-threads", 1)?;
    let seed = args.flag_u64("seed", 42)?;
    let mut cfg = ServeConfig::preset(args.preset()?);
    cfg.workers = args.flag_usize("workers", cfg.workers)?;
    cfg.max_batch = args.flag_usize("batch", cfg.max_batch)?;
    cfg.max_wait_us = args.flag_u64("wait-us", cfg.max_wait_us)?;
    cfg.queue_cap = args.flag_usize("queue", cfg.queue_cap)?;
    cfg.batch_threads =
        args.flag_usize("batch-threads", cfg.batch_threads)?;
    let requests = args.flag_usize("requests", 64)?;
    let clients = args.flag_usize("clients", 8)?;
    let kernel = KernelSel::parse(
        args.flags
            .get("kernel")
            .map(|s| s.as_str())
            .unwrap_or("sparse"),
    )?;
    // `--kernel auto` serves per-layer tuned codelets, so the plan must
    // be compiled through the autotuner (and cached under a key that can
    // never alias the analytic plan)
    let tune = matches!(kernel, KernelSel::Auto);
    let mode = match args.flags.get("qps") {
        Some(q) => LoadMode::Open {
            qps: q.parse().context("--qps must be a number")?,
        },
        None => LoadMode::Closed { clients },
    };

    // the id encodes every flag the compiled plan depends on, so the
    // stale-artifact guard below catches any drift in spec, geometry,
    // pruning rate, class count, or seed
    let model_id = format!(
        "serve_{spec_kind}{hw}_c{classes}_r{}m_s{seed}",
        (rate * 1000.0).round() as u64
    );
    let build_spec = || -> Result<ExecutionPlan> {
        let (spec, mut params) = match spec_kind.as_str() {
            "vgg" => {
                synth::vgg_style(&model_id, hw, classes, &[16, 32], seed)
            }
            "res" => {
                synth::res_style(&model_id, hw, classes, &[8, 16], seed)
            }
            other => bail!("unknown --spec {other:?} (vgg|res)"),
        };
        synth::pattern_prune(&spec, &mut params, 1.0 / rate);
        let ir = ModelIR::build(&spec, &params)?;
        if tune {
            let (plan, report) =
                compile_plan_tuned(ir, plan_threads, TuneConfig::default())?;
            print_tune_table(&plan, &report);
            Ok(plan)
        } else {
            compile_plan(ir, plan_threads)
        }
    };

    let registry = PlanRegistry::new(4);
    let mut key = PlanKey::new(&model_id, "pattern", rate, plan_threads);
    if tune {
        key = key.tuned();
    }
    let artifact_path = args.flags.get("artifact").cloned();
    let t = crate::util::Stopwatch::start();
    let plan = registry.get_or_build(&key, || match &artifact_path {
        Some(p) if std::path::Path::new(p).exists() => {
            let plan = artifact::load(p)?;
            // a stale artifact for a different spec must not be served
            // under this run's flags
            if plan.ir.model_id != model_id || plan.threads != plan_threads
            {
                bail!(
                    "artifact {p} holds model {:?} compiled for {} \
                     thread(s), but the requested flags describe \
                     {model_id:?} at {plan_threads} thread(s); delete \
                     it or pass a different --artifact path",
                    plan.ir.model_id,
                    plan.threads
                );
            }
            println!(
                "loaded plan artifact {p} ({} layers, arena {})",
                plan.layers.len(),
                human_bytes(plan.stats.arena_bytes)
            );
            Ok(plan)
        }
        Some(p) => {
            let plan = build_spec()?;
            artifact::save(&plan, p)?;
            let loaded = artifact::load(p)?;
            artifact::verify_roundtrip(&plan, &loaded, 4, seed)?;
            let bytes =
                std::fs::metadata(p).map(|m| m.len()).unwrap_or(0);
            println!(
                "artifact round-trip OK: {p} ({bytes} bytes, \
                 bit-identical outputs)"
            );
            Ok(loaded)
        }
        None => build_spec(),
    })?;
    println!("plan {key} ready in {:.2} ms", t.ms());

    let server = Server::start(plan.clone(), kernel, &cfg);
    let handle = server.handle();
    let lg = LoadGenConfig {
        mode,
        requests,
        seed,
    };
    let load = loadgen::run(&handle, plan.in_dims, &lg);
    let report = server.shutdown();
    println!(
        "{}",
        report
            .table(&format!(
                "serve {model_id} ({} workers, batch {} / {} us window, \
                 kernel {})",
                cfg.workers,
                cfg.max_batch,
                cfg.max_wait_us,
                kernel.name()
            ))
            .render()
    );
    println!("{}", report.batch_table("batch-size histogram").render());
    println!(
        "loadgen: {requests} issued, {} completed, {} rejected, \
         {:.1} req/s over {:.2} s",
        load.completed, load.rejected, load.achieved_qps, load.wall_secs
    );
    let rs = registry.stats();
    println!(
        "registry: {} ready / cap {}, {} hits, {} misses, \
         {} coalesced, {} evictions",
        rs.ready, rs.capacity, rs.hits, rs.misses, rs.coalesced,
        rs.evictions
    );
    Ok(())
}

pub fn main() -> Result<()> {
    let args = parse_args().inspect_err(|_| {
        eprintln!("{HELP}");
    })?;
    match args.cmd.as_str() {
        "help" | "--help" | "-h" => {
            println!("{HELP}");
            Ok(())
        }
        "models" => {
            let ctx = Ctx::new(args.artifacts(), Preset::Quick)?;
            for (id, m) in &ctx.rt.manifest.models {
                println!(
                    "{id:16} arch={:12} classes={:3} in={}x{} prunable convs={}",
                    m.arch,
                    m.classes,
                    m.in_hw,
                    m.in_hw,
                    m.prunable.len()
                );
            }
            Ok(())
        }
        "pretrain" => {
            let ctx = args.ctx()?;
            let (_, acc) = ctx.pretrained(args.model()?)?;
            println!("base accuracy: {acc:.4}");
            Ok(())
        }
        "eval" => {
            let ctx = args.ctx()?;
            let model = args.model()?;
            let (params, _) = ctx.pretrained(model)?;
            let (_, te) = ctx.data(model)?;
            let acc = crate::train::evaluate(&ctx.rt, model, &params, &te)?;
            println!("accuracy: {acc:.4}");
            Ok(())
        }
        "prune" => {
            let ctx = args.ctx()?;
            let model = args.model()?;
            let (_, masks, comp, secs, _) = ctx.prune(
                model,
                args.method()?,
                args.scheme()?,
                args.rate()?,
            )?;
            println!(
                "pruned {model}: comp rate {comp:.2}x, {} masks, {secs:.1}s",
                masks.len()
            );
            Ok(())
        }
        "retrain" => {
            let ctx = args.ctx()?;
            let row = ctx.prune_retrain(
                args.model()?,
                args.method()?,
                args.scheme()?,
                args.rate()?,
            )?;
            println!(
                "comp {:.1}x  base {:.3}  pruned {:.3}  loss {:+.3}",
                row.comp_rate,
                row.base_acc,
                row.prune_acc,
                row.base_acc - row.prune_acc
            );
            Ok(())
        }
        "deploy" => {
            let ctx = args.ctx()?;
            let model = args.model()?;
            let sel = match args.flags.get("kernel") {
                Some(k) => Some(KernelSel::parse(k)?),
                None => None,
            };
            let (params, _, comp, _, _) = ctx.prune(
                model,
                args.method()?,
                Scheme::Pattern,
                args.rate()?,
            )?;
            let spec = ctx.rt.model(model)?.clone();
            let t = crate::util::Stopwatch::start();
            let mut pm = PassManager::new(ctx.threads);
            if matches!(sel, Some(KernelSel::Auto)) {
                pm = pm.with_tuning(TuneConfig::default());
            }
            let (plan, tune_report) =
                pm.compile_reported(ModelIR::build(&spec, &params)?)?;
            let plan_ms = t.ms();
            let rep = &plan.report;
            println!(
                "compiled {model} @ {comp:.1}x ({} threads, plan built \
                 in {plan_ms:.2} ms):",
                plan.threads
            );
            println!(
                "  MACs dense {} -> sparse {} ({:.2}x)",
                rep.total_dense_macs(),
                rep.total_sparse_macs(),
                rep.total_dense_macs() as f64
                    / rep.total_sparse_macs().max(1) as f64
            );
            println!(
                "  weights dense {} -> compressed {} ({:.2}x)",
                human_bytes(rep.total_dense_bytes()),
                human_bytes(rep.total_compressed_bytes()),
                rep.total_dense_bytes() as f64
                    / rep.total_compressed_bytes().max(1) as f64
            );
            println!(
                "  LRE gain {:.2}x, reorder gain {:.2}x",
                rep.lre_gain(),
                rep.reorder_gain()
            );
            println!(
                "  plan: payload {} + headers {}, arena {}, {} worker \
                 blocks",
                human_bytes(plan.stats.payload_bytes),
                human_bytes(plan.stats.header_bytes),
                human_bytes(plan.stats.arena_bytes),
                plan.stats.n_blocks
            );
            for (name, ms) in &plan.stats.pass_ms {
                println!("    pass {name:14} {ms:9.3} ms");
            }
            match &tune_report {
                Some(rep) => print_tune_table(&plan, rep),
                None => {
                    println!(
                        "  per-layer kernel choices (analytic; pass \
                         --kernel auto to autotune):"
                    );
                    for (i, lp) in plan.layers.iter().enumerate() {
                        let chosen = lp.choice.to_string();
                        println!(
                            "    layer {i:>2}  {:>4}x{:<3}s{}  {chosen}",
                            lp.a, lp.in_hw, lp.stride
                        );
                    }
                }
            }
            let mut rng = Pcg32::seeded(7);
            let img = Fmap {
                c: 3,
                hw: spec.in_hw,
                data: (0..3 * spec.in_hw * spec.in_hw)
                    .map(|_| rng.uniform())
                    .collect(),
            };
            // no --kernel: compare every registered kernel; --kernel:
            // time exactly the requested selection (auto = per-layer
            // dispatch through the baked choices)
            let sels: Vec<KernelSel> = match sel {
                Some(s) => vec![s],
                None => KERNEL_KINDS
                    .into_iter()
                    .map(KernelSel::Uniform)
                    .collect(),
            };
            for s in sels {
                let mut ex = Executor::with_sel(&plan, s);
                for _ in 0..3 {
                    ex.execute(&img);
                }
                let t = std::time::Instant::now();
                for _ in 0..20 {
                    std::hint::black_box(ex.execute(&img));
                }
                println!(
                    "  host {:14} inference: {:.3} ms/frame \
                     (arena growths: {})",
                    ex.kernel_name(),
                    t.elapsed().as_secs_f64() * 50.0,
                    ex.alloc_events()
                );
            }
            Ok(())
        }
        "exp" => {
            let which = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all");
            if which == "sweep" {
                // host-engine parallel sweep: needs no artifacts/PJRT
                let (table, timing) = experiments::sweep_host(
                    args.threads()?,
                    args.preset()?,
                )?;
                println!("{}\n{}", table.render(), timing.render());
                return Ok(());
            }
            let ctx = args.ctx()?;
            match which {
                "table1" => println!("{}", experiments::table1(&ctx)?.render()),
                "table2" => println!("{}", experiments::table2(&ctx)?.render()),
                "table3" => println!("{}", experiments::table3(&ctx)?.render()),
                "table4" => println!("{}", experiments::table4(&ctx)?.render()),
                "table5" => println!("{}", experiments::table5(&ctx)?.render()),
                "fig3" => {
                    let (a, b) = experiments::fig3(&ctx)?;
                    println!("{}\n{}", a.render(), b.render());
                }
                "all" => experiments::all(&ctx)?,
                _ => bail!("unknown experiment {which:?}"),
            }
            Ok(())
        }
        "serve" => serve_cmd(&args),
        "pipeline" => {
            let ctx = args.ctx()?;
            let model = args.model()?;
            let scheme = args.scheme()?;
            let rate = args.rate()?;
            println!(
                "=== privacy-preserving pipeline: {model} {} {rate}x ===",
                scheme.name()
            );
            let (_, base) = ctx.pretrained(model)?;
            println!("[1/3] client pre-trained model: acc {base:.3}");
            let row = ctx.prune_retrain(model, Method::Privacy, scheme, rate)?;
            println!(
                "[2/3] designer pruned on synthetic data: {:.1}x compression",
                row.comp_rate
            );
            println!(
                "[3/3] client retrained with mask: acc {:.3} (loss {:+.3})",
                row.prune_acc,
                row.base_acc - row.prune_acc
            );
            Ok(())
        }
        other => {
            eprintln!("{HELP}");
            bail!("unknown command {other:?}");
        }
    }
}
