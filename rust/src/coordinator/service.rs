//! PruneService: the designer-side sweep entry point over the parallel
//! pruning scheduler.
//!
//! The paper's Tables I–IV are grids of (scheme, compression-rate)
//! configurations whose prune stages are mutually independent — each is a
//! separate ADMM solve against the same pre-trained model. The service
//! runs them as **one parallel sweep**: configurations shard across the
//! service's worker pool, each solved by a single-threaded scheduler so
//! config-level and layer-level parallelism do not multiply
//! (throughput mode). [`PruneService::prune_one`] is the complementary
//! latency mode: one configuration with full layer-level parallelism.
//!
//! Everything here is host-native (no PJRT, no artifacts): it accepts any
//! [`ModelSpec`] + parameter set — a manifest model's pre-trained weights
//! when a runtime exists, or a `mobile::synth` spec on a bare machine.

use anyhow::Result;

use crate::admm::scheduler::{
    prune_layerwise_par, ParPruneOutcome, SchedulerCfg,
};
use crate::config::{AdmmConfig, ModelSpec};
use crate::pruning::Scheme;
use crate::report::{rate, secs, Table};
use crate::tensor::Tensor;

/// One (scheme, target-rate) configuration of a sweep.
#[derive(Clone, Copy, Debug)]
pub struct PruneConfig {
    pub scheme: Scheme,
    /// target CONV compression rate (α = 1/rate)
    pub rate: f64,
}

/// Result row of one sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepRow {
    pub scheme: Scheme,
    pub rate: f64,
    pub comp_rate: f64,
    pub secs: f64,
    /// final ADMM feasibility residual ‖W − Z‖_F / ‖W‖_F
    pub final_residual: f64,
    /// the mask function shipped to the client
    pub masks: Vec<Tensor>,
}

/// Parallel pruning sweep executor.
pub struct PruneService {
    /// total worker threads shared by a sweep (or used whole by
    /// [`PruneService::prune_one`])
    pub threads: usize,
    /// synthetic images per ADMM round
    pub batch: usize,
}

impl PruneService {
    pub fn new(threads: usize, batch: usize) -> Self {
        PruneService {
            threads: threads.max(1),
            batch: batch.max(1),
        }
    }

    /// Solve one configuration with full layer-level parallelism.
    pub fn prune_one(
        &self,
        spec: &ModelSpec,
        pretrained: &[Tensor],
        admm: &AdmmConfig,
        config: PruneConfig,
    ) -> Result<ParPruneOutcome> {
        let cfg = SchedulerCfg::new(admm.clone(), self.batch, self.threads);
        prune_layerwise_par(
            spec,
            pretrained,
            config.scheme,
            1.0 / config.rate,
            &cfg,
        )
    }

    /// Solve many configurations concurrently. Each configuration runs a
    /// single-threaded scheduler, so results are identical to solving it
    /// alone — the sweep's row list does not depend on `threads`.
    pub fn sweep(
        &self,
        spec: &ModelSpec,
        pretrained: &[Tensor],
        admm: &AdmmConfig,
        configs: &[PruneConfig],
    ) -> Result<Vec<SweepRow>> {
        let inner = SchedulerCfg::new(admm.clone(), self.batch, 1);
        self.shard_map(configs, |&c| {
            solve_row(spec, pretrained, &inner, c)
        })
    }

    /// Shard arbitrary independent jobs across the service's worker pool:
    /// `items` split into contiguous chunks, one scoped thread per chunk,
    /// results reassembled in item order on the caller's thread. As long
    /// as each job is internally deterministic and self-contained (the
    /// sweep's single-threaded scheduler runs, the privacy tier's MIA grid
    /// rows and shadow-model trainings), the output vector is bit-identical
    /// at any `threads` — sharding only decides *where* a job runs.
    pub fn shard_map<T, R, F>(&self, items: &[T], f: F) -> Result<Vec<R>>
    where
        T: Sync,
        R: Send,
        F: Fn(&T) -> Result<R> + Sync,
    {
        let t = self.threads.min(items.len().max(1));
        if t <= 1 {
            return items.iter().map(&f).collect();
        }
        let chunk = items.len().div_ceil(t);
        let fr = &f;
        let mut per_chunk: Vec<Result<Vec<R>>> = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = items
                .chunks(chunk)
                .map(|ch| {
                    s.spawn(move || {
                        ch.iter().map(fr).collect::<Result<Vec<R>>>()
                    })
                })
                .collect();
            per_chunk = handles
                .into_iter()
                .map(|h| h.join().expect("shard worker panicked"))
                .collect();
        });
        let mut out = Vec::with_capacity(items.len());
        for c in per_chunk {
            out.extend(c?);
        }
        Ok(out)
    }

    /// Render sweep rows as a paper-style table.
    pub fn sweep_table(&self, model: &str, rows: &[SweepRow]) -> Table {
        let mut t = Table::new(
            &format!(
                "parallel prune sweep on {model} ({} threads)",
                self.threads
            ),
            &[
                "Pruning Scheme",
                "Target Rate",
                "CONV Comp. Rate",
                "Residual",
                "Prune Time",
            ],
        );
        for r in rows {
            t.row(&[
                r.scheme.name().into(),
                rate(r.rate),
                rate(r.comp_rate),
                format!("{:.4}", r.final_residual),
                secs(r.secs),
            ]);
        }
        t
    }
}

fn solve_row(
    spec: &ModelSpec,
    pretrained: &[Tensor],
    cfg: &SchedulerCfg,
    c: PruneConfig,
) -> Result<SweepRow> {
    let t = crate::util::Stopwatch::start();
    let out = prune_layerwise_par(
        spec,
        pretrained,
        c.scheme,
        1.0 / c.rate,
        cfg,
    )?;
    Ok(SweepRow {
        scheme: c.scheme,
        rate: c.rate,
        comp_rate: out.outcome.comp_rate,
        secs: t.secs(),
        final_residual: out
            .outcome
            .trace
            .residual
            .last()
            .copied()
            .unwrap_or(0.0),
        masks: out.outcome.masks,
    })
}
