//! Seeded PRNG substrate (no external crates are available offline).
//!
//! `Pcg32` is the PCG-XSH-RR generator — small, fast, and statistically
//! solid for everything this framework needs: synthetic-data generation,
//! weight init, shuffling, and the property-test harness. All experiment
//! entry points take explicit seeds so runs are reproducible bit-for-bit.

/// PCG-XSH-RR 64/32 generator.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Deterministic stream split: an independent generator for unit `id`
    /// derived from `seed`. Every id selects a distinct PCG increment
    /// (golden-ratio spaced), so per-job randomness in the pruning
    /// scheduler depends only on (seed, id) — never on which worker
    /// thread runs the job or in what order jobs are scheduled.
    pub fn split_stream(seed: u64, id: u64) -> Self {
        Self::new(
            seed,
            0x9E37_79B9_7F4A_7C15u64.wrapping_mul(id.wrapping_add(1)),
        )
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free (slight bias negligible
        // at our n << 2^32 scales).
        ((self.next_u32() as u64 * n as u64) >> 32) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-9);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    pub fn normal_scaled(&mut self, std: f32) -> f32 {
        self.normal() * std
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// The paper's synthetic-data generator: each pixel i.i.d. from the
    /// discrete Uniform{0..255}, normalized to the training input scale.
    pub fn uniform_pixel(&mut self) -> f32 {
        self.below(256) as f32 / 255.0
    }

    /// Exponential variate with the given mean (> 0): the Poisson
    /// interarrival gaps of the open-loop load generator
    /// (`serve::loadgen`).
    pub fn exponential(&mut self, mean: f32) -> f32 {
        // 1 - uniform() lies in (0, 1], so ln() is finite and the variate
        // is non-negative
        let u = 1.0 - self.uniform();
        -mean * u.ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn split_streams_are_deterministic_and_distinct() {
        let mut a = Pcg32::split_stream(42, 3);
        let mut b = Pcg32::split_stream(42, 3);
        for _ in 0..64 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        let mut c = Pcg32::split_stream(42, 4);
        let mut d = Pcg32::split_stream(42, 3);
        let same = (0..32)
            .filter(|_| d.next_u32() == c.next_u32())
            .count();
        assert!(same < 4, "streams 3 and 4 look correlated: {same}/32");
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        let mut mean = 0.0;
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
            mean += x as f64;
        }
        mean /= 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(9);
        let (mut m, mut v) = (0.0f64, 0.0f64);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        for &x in &xs {
            m += x as f64;
        }
        m /= n as f64;
        for &x in &xs {
            v += (x as f64 - m).powi(2);
        }
        v /= n as f64;
        assert!(m.abs() < 0.03, "mean={m}");
        assert!((v - 1.0).abs() < 0.05, "var={v}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Pcg32::seeded(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_pixel_range() {
        let mut r = Pcg32::seeded(13);
        for _ in 0..1000 {
            let p = r.uniform_pixel();
            assert!((0.0..=1.0).contains(&p));
            // quantized to the 256-level grid
            let q = (p * 255.0).round() / 255.0;
            assert!((p - q).abs() < 1e-6);
        }
    }

    #[test]
    fn exponential_moments_and_support() {
        let mut r = Pcg32::seeded(23);
        let n = 20_000;
        let mut mean = 0.0f64;
        for _ in 0..n {
            let x = r.exponential(2.0);
            assert!(x >= 0.0 && x.is_finite(), "x={x}");
            mean += x as f64;
        }
        mean /= n as f64;
        assert!((mean - 2.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(17);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
