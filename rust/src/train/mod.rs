//! Training substrate: parameter init, the client's pre-training loop, the
//! masked retraining loop (paper Fig. 2(b) right side), the evaluator, and
//! a checkpoint store. The loops in this file run through PJRT artifacts;
//! [`host`] is the artifact-free CPU twin used by the privacy tier.

pub mod host;
pub mod params;

use anyhow::{Context, Result};

use crate::config::TrainConfig;
use crate::data::SynthVision;
use crate::rng::Pcg32;
use crate::runtime::Runtime;
use crate::tensor::Tensor;

/// Loss/accuracy trace of a training run.
#[derive(Clone, Debug, Default)]
pub struct TrainTrace {
    pub losses: Vec<f32>,
    /// (step, accuracy) pairs at `log_every` cadence
    pub accs: Vec<(usize, f64)>,
}

impl TrainTrace {
    pub fn final_acc(&self) -> f64 {
        self.accs.last().map(|&(_, a)| a).unwrap_or(0.0)
    }
}

/// Client pre-training: plain SGD on the confidential dataset.
pub fn pretrain(
    rt: &Runtime,
    model_id: &str,
    params: &mut Vec<Tensor>,
    train: &SynthVision,
    test: &SynthVision,
    cfg: &TrainConfig,
) -> Result<TrainTrace> {
    run_sgd(rt, model_id, params, None, train, test, cfg)
}

/// Client retraining with the designer's mask function: identical to the
/// training loop except the `masked_train_step` artifact zeroes pruned
/// weights and their gradients (observation (iii), §III-B).
pub fn retrain_masked(
    rt: &Runtime,
    model_id: &str,
    params: &mut Vec<Tensor>,
    masks: &[Tensor],
    train: &SynthVision,
    test: &SynthVision,
    cfg: &TrainConfig,
) -> Result<TrainTrace> {
    run_sgd(rt, model_id, params, Some(masks), train, test, cfg)
}

fn run_sgd(
    rt: &Runtime,
    model_id: &str,
    params: &mut Vec<Tensor>,
    masks: Option<&[Tensor]>,
    train: &SynthVision,
    test: &SynthVision,
    cfg: &TrainConfig,
) -> Result<TrainTrace> {
    let np = params.len();
    let bsz = rt.manifest.batches.train;
    let artifact = if masks.is_some() {
        "masked_train_step"
    } else {
        "train_step"
    };
    let mut rng = Pcg32::seeded(cfg.seed);
    let lr = Tensor::scalar(cfg.lr);
    let mut trace = TrainTrace::default();
    for step in 0..cfg.steps {
        let (x, y) = train.batch(&mut rng, bsz);
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        if let Some(ms) = masks {
            inputs.extend(ms.iter());
        }
        inputs.push(&x);
        inputs.push(&y);
        inputs.push(&lr);
        let mut outs = rt
            .exec(model_id, artifact, &inputs)
            .with_context(|| format!("{artifact} step {step}"))?;
        let loss = outs
            .pop()
            .with_context(|| {
                format!("{artifact} step {step} returned no outputs")
            })?
            .data()[0];
        trace.losses.push(loss);
        *params = outs;
        debug_assert_eq!(params.len(), np);
        if cfg.log_every > 0 && (step + 1) % cfg.log_every == 0 {
            let acc = evaluate(rt, model_id, params, test)?;
            trace.accs.push((step + 1, acc));
        }
    }
    let acc = evaluate(rt, model_id, params, test)?;
    trace.accs.push((cfg.steps, acc));
    Ok(trace)
}

/// Top-1 accuracy of `params` on `data` via the `fwd_eval` artifact.
pub fn evaluate(
    rt: &Runtime,
    model_id: &str,
    params: &[Tensor],
    data: &SynthVision,
) -> Result<f64> {
    let bsz = rt.manifest.batches.eval;
    let mut correct = 0usize;
    let mut total = 0usize;
    for (x, labels) in data.eval_chunks(bsz) {
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        inputs.push(&x);
        let outs = rt.exec(model_id, "fwd_eval", &inputs)?;
        let preds = outs[0].argmax_rows();
        for (p, l) in preds.iter().zip(&labels) {
            if p == l {
                correct += 1;
            }
            total += 1;
        }
    }
    Ok(correct as f64 / total.max(1) as f64)
}

/// Forward pass on an admm-batch input returning logits — used for the
/// problem-(2) distillation targets (fwd_acts output 0).
pub fn logits_admm(
    rt: &Runtime,
    model_id: &str,
    params: &[Tensor],
    x: &Tensor,
) -> Result<Tensor> {
    let mut inputs: Vec<&Tensor> = params.iter().collect();
    inputs.push(x);
    let mut outs = rt.exec(model_id, "fwd_acts", &inputs)?;
    Ok(outs.remove(0))
}
