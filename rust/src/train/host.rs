//! Host-native SGD trainer: full forward/backward on the CPU, no PJRT
//! artifacts required.
//!
//! The privacy evaluation tier ([`crate::privacy`]) has to train target
//! and shadow models *inside* the harness — including in CI where no XLA
//! runtime exists — so this module reimplements the training loop of
//! [`crate::train`] on top of the scheduler's host conv substrate
//! (`ConvGeom`): the same tap-streaming forward as `fwd_logits_host`,
//! plus an explicit per-image tape (conv inputs, post-activation outputs,
//! pool argmax routes, saved-map gradients) driving exact backprop through
//! every `Op` kind, softmax cross-entropy at the head.
//!
//! **Determinism:** everything here is sequential per model — batch
//! sampling comes from one seeded [`Pcg32`], gradients accumulate in image
//! order, and pool ties break toward the first maximum in scan order — so
//! a training run is a pure function of (spec, init params, dataset, cfg).
//! Callers parallelize across *models* (shadow models, grid rows), never
//! inside one.
//!
//! Masked retraining (paper Fig. 2(b) right side) re-applies the pruning
//! masks to both gradients and weights every step, keeping pruned
//! positions exactly zero — the host twin of the PJRT `masked_train_step`
//! artifact.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::admm::scheduler::ConvGeom;
use crate::config::{Act, ConvOp, ModelSpec, Op};
use crate::data::SynthVision;
use crate::rng::Pcg32;
use crate::tensor::Tensor;

/// Knobs of one host training run. Much smaller than
/// [`crate::config::TrainConfig`] on purpose: the host path has no
/// artifact manifest to read batch sizes from.
#[derive(Clone, Copy, Debug)]
pub struct HostTrainCfg {
    pub steps: usize,
    pub batch: usize,
    pub lr: f32,
    /// batch-sampling stream seed
    pub seed: u64,
}

/// Loss trace of a host training run.
#[derive(Clone, Debug, Default)]
pub struct HostTrainTrace {
    /// mean cross-entropy per step
    pub losses: Vec<f32>,
}

/// Per-op tape record of one forward pass; indices parallel `spec.ops`.
enum Rec {
    Conv { x: Vec<f32>, post: Vec<f32> },
    /// `arg[o]` = flat input index feeding output `o`; `in_len` sizes the
    /// input gradient buffer
    Pool { arg: Vec<usize>, in_len: usize },
    Save,
    Proj { x: Vec<f32>, post: Vec<f32> },
    Add,
    Relu { post: Vec<f32> },
    Gap { c: usize, hw: usize },
    Fc { x: Vec<f32> },
}

fn relu_mask(g: &mut [f32], post: &[f32]) {
    for (gv, pv) in g.iter_mut().zip(post) {
        if *pv <= 0.0 {
            *gv = 0.0;
        }
    }
}

/// Conv/Proj backward: activation mask, then grad_b, grad_w, grad_x.
/// Returns the gradient wrt the op's input feature map.
fn conv_backward(
    cv: &ConvOp,
    params: &[Tensor],
    grads: &mut [Vec<f32>],
    x: &[f32],
    post: &[f32],
    mut g: Vec<f32>,
) -> Vec<f32> {
    if cv.act == Act::Relu {
        relu_mask(&mut g, post);
    }
    let plane = cv.out_hw * cv.out_hw;
    for f in 0..cv.a {
        let mut s = 0.0f32;
        for v in &g[f * plane..(f + 1) * plane] {
            s += v;
        }
        grads[cv.b][f] += s;
    }
    let geom = ConvGeom::from_op(cv);
    geom.grad_w(&g, x, &mut grads[cv.w]);
    let mut gx = vec![0.0f32; cv.c * cv.in_hw * cv.in_hw];
    geom.grad_x(params[cv.w].data(), &g, &mut gx);
    gx
}

/// One image's forward + backward: accumulates parameter gradients into
/// `grads` (flat, parallel to `params`) and returns the cross-entropy
/// loss. The tape mirrors `fwd_image_acts`' forward exactly, so host
/// training and host evaluation share numerics.
fn fwd_backward(
    spec: &ModelSpec,
    params: &[Tensor],
    img: &[f32],
    label: usize,
    grads: &mut [Vec<f32>],
) -> Result<f32> {
    let mut tape: Vec<Rec> = Vec::with_capacity(spec.ops.len());
    let mut cur = img.to_vec();
    let mut cur_c = spec
        .ops
        .iter()
        .find_map(|op| match op {
            Op::Conv(cv) => Some(cv.c),
            _ => None,
        })
        .unwrap_or(3);
    let mut cur_hw = spec.in_hw;
    let mut saved: BTreeMap<&str, Vec<f32>> = BTreeMap::new();
    let mut logits = Vec::new();
    for op in &spec.ops {
        match op {
            Op::Conv(cv) => {
                let geom = ConvGeom::from_op(cv);
                let mut out = vec![0.0f32; cv.a * cv.out_hw * cv.out_hw];
                geom.fwd(
                    params[cv.w].data(),
                    params[cv.b].data(),
                    &cur,
                    &mut out,
                );
                if cv.act == Act::Relu {
                    for v in &mut out {
                        *v = v.max(0.0);
                    }
                }
                tape.push(Rec::Conv {
                    x: std::mem::take(&mut cur),
                    post: out.clone(),
                });
                cur = out;
                cur_c = cv.a;
                cur_hw = cv.out_hw;
            }
            Op::Pool => {
                let oh = cur_hw / 2;
                let mut out = vec![0.0f32; cur_c * oh * oh];
                let mut arg = vec![0usize; cur_c * oh * oh];
                for ch in 0..cur_c {
                    let pb = ch * cur_hw * cur_hw;
                    let p = &cur[pb..pb + cur_hw * cur_hw];
                    let ob = ch * oh * oh;
                    for y in 0..oh {
                        for xx in 0..oh {
                            let i = 2 * y * cur_hw + 2 * xx;
                            // first max in scan order wins ties — the
                            // deterministic route for backprop
                            let cand =
                                [i, i + 1, i + cur_hw, i + cur_hw + 1];
                            let mut best = cand[0];
                            for &c in &cand[1..] {
                                if p[c] > p[best] {
                                    best = c;
                                }
                            }
                            out[ob + y * oh + xx] = p[best];
                            arg[ob + y * oh + xx] = pb + best;
                        }
                    }
                }
                tape.push(Rec::Pool {
                    arg,
                    in_len: cur.len(),
                });
                cur = out;
                cur_hw = oh;
            }
            Op::Save { tag } => {
                saved.insert(tag.as_str(), cur.clone());
                tape.push(Rec::Save);
            }
            Op::Proj(cv) => {
                let src = saved.get(cv.tag.as_str()).with_context(|| {
                    format!("proj: no saved fmap {:?}", cv.tag)
                })?;
                let geom = ConvGeom::from_op(cv);
                let mut out = vec![0.0f32; cv.a * cv.out_hw * cv.out_hw];
                geom.fwd(
                    params[cv.w].data(),
                    params[cv.b].data(),
                    src,
                    &mut out,
                );
                if cv.act == Act::Relu {
                    for v in &mut out {
                        *v = v.max(0.0);
                    }
                }
                tape.push(Rec::Proj {
                    x: src.clone(),
                    post: out.clone(),
                });
                saved.insert(cv.tag.as_str(), out);
            }
            Op::Add { tag } => {
                let src = saved.get(tag.as_str()).with_context(|| {
                    format!("add: no saved fmap {tag:?}")
                })?;
                if src.len() != cur.len() {
                    bail!(
                        "add {tag:?}: fmap len {} vs {}",
                        src.len(),
                        cur.len()
                    );
                }
                for (a, b) in cur.iter_mut().zip(src) {
                    *a += b;
                }
                tape.push(Rec::Add);
            }
            Op::Relu => {
                for v in &mut cur {
                    *v = v.max(0.0);
                }
                tape.push(Rec::Relu { post: cur.clone() });
            }
            Op::Gap => {
                let plane = cur_hw * cur_hw;
                let inv = 1.0 / plane as f32;
                let pooled: Vec<f32> = (0..cur_c)
                    .map(|ch| {
                        cur[ch * plane..(ch + 1) * plane]
                            .iter()
                            .sum::<f32>()
                            * inv
                    })
                    .collect();
                tape.push(Rec::Gap {
                    c: cur_c,
                    hw: cur_hw,
                });
                cur = pooled;
                cur_hw = 1;
            }
            Op::Fc { w, b, a, c } => {
                let wt = &params[*w];
                let bt = &params[*b];
                logits = (0..*a)
                    .map(|k| {
                        bt.data()[k]
                            + wt.row(k)
                                .iter()
                                .zip(&cur[..*c])
                                .map(|(wv, v)| wv * v)
                                .sum::<f32>()
                    })
                    .collect();
                tape.push(Rec::Fc {
                    x: std::mem::take(&mut cur),
                });
            }
        }
    }
    if logits.is_empty() {
        bail!("spec {:?} has no Fc head", spec.id);
    }

    // softmax cross-entropy and its gradient wrt the logits
    let p = softmax(&logits);
    let loss = -(p[label].max(1e-12)).ln();
    let mut g: Vec<f32> = p;
    g[label] -= 1.0;

    // reverse walk; gradients flowing through Save/Proj/Add ride a
    // tag-keyed side map, mirroring the forward's saved-fmap map
    let mut gsaved: BTreeMap<&str, Vec<f32>> = BTreeMap::new();
    for (op, rec) in spec.ops.iter().zip(&tape).rev() {
        match (op, rec) {
            (Op::Fc { w, b, a, c }, Rec::Fc { x }) => {
                let wt = &params[*w];
                for k in 0..*a {
                    let gk = g[k];
                    grads[*b][k] += gk;
                    let gw = &mut grads[*w][k * c..(k + 1) * c];
                    for (gv, xv) in gw.iter_mut().zip(&x[..*c]) {
                        *gv += gk * xv;
                    }
                }
                let mut gx = vec![0.0f32; *c];
                for k in 0..*a {
                    let gk = g[k];
                    for (gv, wv) in gx.iter_mut().zip(wt.row(k)) {
                        *gv += gk * wv;
                    }
                }
                g = gx;
            }
            (Op::Gap, Rec::Gap { c, hw }) => {
                let plane = hw * hw;
                let inv = 1.0 / plane as f32;
                let mut gx = vec![0.0f32; c * plane];
                for ch in 0..*c {
                    let gv = g[ch] * inv;
                    gx[ch * plane..(ch + 1) * plane].fill(gv);
                }
                g = gx;
            }
            (Op::Relu, Rec::Relu { post }) => {
                relu_mask(&mut g, post);
            }
            (Op::Add { tag }, Rec::Add) => {
                let e = gsaved
                    .entry(tag.as_str())
                    .or_insert_with(|| vec![0.0f32; g.len()]);
                for (ev, gv) in e.iter_mut().zip(&g) {
                    *ev += gv;
                }
            }
            (Op::Proj(cv), Rec::Proj { x, post }) => {
                let gp = gsaved
                    .remove(cv.tag.as_str())
                    .unwrap_or_else(|| {
                        vec![0.0f32; cv.a * cv.out_hw * cv.out_hw]
                    });
                let gx = conv_backward(cv, params, grads, x, post, gp);
                gsaved.insert(cv.tag.as_str(), gx);
            }
            (Op::Save { tag }, Rec::Save) => {
                if let Some(gs) = gsaved.remove(tag.as_str()) {
                    for (gv, sv) in g.iter_mut().zip(&gs) {
                        *gv += sv;
                    }
                }
            }
            (Op::Conv(cv), Rec::Conv { x, post }) => {
                g = conv_backward(cv, params, grads, x, post, g);
            }
            (Op::Pool, Rec::Pool { arg, in_len }) => {
                let mut gx = vec![0.0f32; *in_len];
                for (o, &src) in arg.iter().enumerate() {
                    gx[src] += g[o];
                }
                g = gx;
            }
            _ => bail!("op/tape mismatch in spec {:?}", spec.id),
        }
    }
    Ok(loss)
}

fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&v| (v - m).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / sum.max(1e-12)).collect()
}

/// Zero the pruned positions of every prunable conv weight (and of its
/// gradient when given). The [P, Q] mask layout is the GEMM view of the
/// [A, C, kh, kw] weight — identical element order — so the mask applies
/// elementwise.
fn apply_masks(
    spec: &ModelSpec,
    masks: &[Tensor],
    bufs: &mut [impl AsMut<[f32]>],
) -> Result<()> {
    let convs = spec.prunable_convs();
    if convs.len() != masks.len() {
        bail!(
            "mask count {} vs {} prunable convs",
            masks.len(),
            convs.len()
        );
    }
    for ((_, op), m) in convs.iter().zip(masks) {
        let buf = bufs[op.w].as_mut();
        if buf.len() != m.len() {
            bail!("mask len {} vs weight len {}", m.len(), buf.len());
        }
        for (v, mv) in buf.iter_mut().zip(m.data()) {
            *v *= mv;
        }
    }
    Ok(())
}

fn run_sgd_host(
    spec: &ModelSpec,
    params: &mut [Tensor],
    masks: Option<&[Tensor]>,
    train: &SynthVision,
    cfg: &HostTrainCfg,
) -> Result<HostTrainTrace> {
    if train.n == 0 {
        bail!("host training set is empty");
    }
    let bsz = cfg.batch.max(1);
    let sl = train.sample_len();
    let mut rng = Pcg32::seeded(cfg.seed);
    let mut trace = HostTrainTrace::default();
    let mut grads: Vec<Vec<f32>> =
        params.iter().map(|p| vec![0.0f32; p.len()]).collect();
    if let Some(ms) = masks {
        // start from a mask-consistent point
        let mut views: Vec<&mut [f32]> =
            params.iter_mut().map(|p| p.data_mut()).collect();
        apply_masks(spec, ms, &mut views)?;
    }
    for _step in 0..cfg.steps {
        for gbuf in &mut grads {
            gbuf.fill(0.0);
        }
        let mut loss = 0.0f64;
        for _ in 0..bsz {
            let s = rng.below(train.n);
            let img = &train.images[s * sl..(s + 1) * sl];
            loss += fwd_backward(
                spec,
                params,
                img,
                train.labels[s],
                &mut grads,
            )? as f64;
        }
        if let Some(ms) = masks {
            apply_masks(spec, ms, &mut grads)?;
        }
        let scale = cfg.lr / bsz as f32;
        for (p, gbuf) in params.iter_mut().zip(&grads) {
            for (pv, gv) in p.data_mut().iter_mut().zip(gbuf) {
                *pv -= scale * gv;
            }
        }
        if let Some(ms) = masks {
            let mut views: Vec<&mut [f32]> =
                params.iter_mut().map(|p| p.data_mut()).collect();
            apply_masks(spec, ms, &mut views)?;
        }
        trace.losses.push((loss / bsz as f64) as f32);
    }
    Ok(trace)
}

/// Plain SGD on the host — the no-artifact twin of
/// [`crate::train::pretrain`].
pub fn train_host(
    spec: &ModelSpec,
    params: &mut [Tensor],
    train: &SynthVision,
    cfg: &HostTrainCfg,
) -> Result<HostTrainTrace> {
    run_sgd_host(spec, params, None, train, cfg)
}

/// Masked SGD on the host — the no-artifact twin of
/// [`crate::train::retrain_masked`]: pruned weights and their gradients
/// are zeroed every step.
pub fn retrain_masked_host(
    spec: &ModelSpec,
    params: &mut [Tensor],
    masks: &[Tensor],
    train: &SynthVision,
    cfg: &HostTrainCfg,
) -> Result<HostTrainTrace> {
    run_sgd_host(spec, params, Some(masks), train, cfg)
}

/// Top-1 accuracy of `params` on `data`, via the host forward pass.
/// Argmax ties break toward the lower class index.
pub fn evaluate_host(
    spec: &ModelSpec,
    params: &[Tensor],
    data: &SynthVision,
) -> Result<f64> {
    let sl = data.sample_len();
    let mut correct = 0usize;
    for s in 0..data.n {
        let img = &data.images[s * sl..(s + 1) * sl];
        let logits =
            crate::admm::scheduler::fwd_logits_host(spec, params, img)?;
        let mut best = 0usize;
        for (k, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = k;
            }
        }
        if best == data.labels[s] {
            correct += 1;
        }
    }
    Ok(correct as f64 / data.n.max(1) as f64)
}

/// Per-sample softmax probability of the *true* class — the membership
/// signal the confidence attack thresholds (members of an overfit model
/// score systematically higher than non-members).
pub fn confidence_scores(
    spec: &ModelSpec,
    params: &[Tensor],
    data: &SynthVision,
) -> Result<Vec<f32>> {
    let sl = data.sample_len();
    let mut out = Vec::with_capacity(data.n);
    for s in 0..data.n {
        let img = &data.images[s * sl..(s + 1) * sl];
        let logits =
            crate::admm::scheduler::fwd_logits_host(spec, params, img)?;
        let p = softmax(&logits);
        out.push(p[data.labels[s]]);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mobile::synth::{res_style, vgg_style};

    fn tiny() -> (ModelSpec, Vec<Tensor>, SynthVision) {
        let (spec, params) = vgg_style("host_t", 8, 4, &[4], 0x11);
        let data = SynthVision::generate(4, 8, 24, 0x22, 0);
        (spec, params, data)
    }

    /// Full-model parameter gradients match central finite differences of
    /// the cross-entropy loss — exercises every Op kind's backward via the
    /// residual spec.
    #[test]
    fn backward_matches_finite_differences() {
        for (spec, params) in [
            vgg_style("fd_v", 8, 3, &[3], 0x31),
            res_style("fd_r", 8, 3, &[3, 4], 0x32),
        ] {
            let data = SynthVision::generate(3, 8, 3, 0x33, 0);
            let sl = data.sample_len();
            let img = &data.images[..sl];
            let label = data.labels[0];
            let mut grads: Vec<Vec<f32>> =
                params.iter().map(|p| vec![0.0f32; p.len()]).collect();
            fwd_backward(&spec, &params, img, label, &mut grads)
                .unwrap();
            let loss_of = |ps: &[Tensor]| -> f64 {
                let mut g: Vec<Vec<f32>> = ps
                    .iter()
                    .map(|p| vec![0.0f32; p.len()])
                    .collect();
                fwd_backward(&spec, ps, img, label, &mut g).unwrap()
                    as f64
            };
            let eps = 1e-2f32;
            for pi in 0..params.len() {
                for i in (0..params[pi].len()).step_by(17) {
                    let mut pp = params.clone();
                    pp[pi].data_mut()[i] += eps;
                    let mut pm = params.clone();
                    pm[pi].data_mut()[i] -= eps;
                    let num = (loss_of(&pp) - loss_of(&pm))
                        / (2.0 * eps as f64);
                    let ana = grads[pi][i] as f64;
                    assert!(
                        (num - ana).abs() <= 2e-2 * ana.abs().max(1.0),
                        "{} param {pi}[{i}]: numeric {num} vs \
                         analytic {ana}",
                        spec.id
                    );
                }
            }
        }
    }

    #[test]
    fn training_reduces_loss_and_learns() {
        let (spec, mut params, data) = tiny();
        let cfg = HostTrainCfg {
            steps: 60,
            batch: 8,
            lr: 0.05,
            seed: 0x44,
        };
        let trace =
            train_host(&spec, &mut params, &data, &cfg).unwrap();
        let head = trace.losses[..5].iter().sum::<f32>() / 5.0;
        let tail =
            trace.losses[trace.losses.len() - 5..].iter().sum::<f32>()
                / 5.0;
        assert!(tail < head, "loss head {head} tail {tail}");
        let acc = evaluate_host(&spec, &params, &data).unwrap();
        assert!(acc > 0.5, "train acc {acc}");
    }

    #[test]
    fn training_is_deterministic() {
        let (spec, params0, data) = tiny();
        let cfg = HostTrainCfg {
            steps: 10,
            batch: 4,
            lr: 0.05,
            seed: 0x55,
        };
        let mut a = params0.clone();
        let mut b = params0.clone();
        train_host(&spec, &mut a, &data, &cfg).unwrap();
        train_host(&spec, &mut b, &data, &cfg).unwrap();
        for (ta, tb) in a.iter().zip(&b) {
            assert_eq!(ta.data(), tb.data());
        }
    }

    #[test]
    fn masked_retraining_keeps_pruned_weights_zero() {
        let (spec, mut params, data) = tiny();
        let cfg = HostTrainCfg {
            steps: 15,
            batch: 4,
            lr: 0.05,
            seed: 0x66,
        };
        train_host(&spec, &mut params, &data, &cfg).unwrap();
        let out = crate::admm::scheduler::prune_layerwise_par(
            &spec,
            &params,
            crate::pruning::Scheme::Irregular,
            0.5,
            &crate::admm::scheduler::SchedulerCfg::new(
                crate::config::AdmmConfig::preset(
                    crate::config::Preset::Smoke,
                ),
                4,
                1,
            ),
        )
        .unwrap();
        let mut pruned = out.outcome.params.clone();
        retrain_masked_host(
            &spec,
            &mut pruned,
            &out.outcome.masks,
            &data,
            &cfg,
        )
        .unwrap();
        for ((_, op), m) in
            spec.prunable_convs().iter().zip(&out.outcome.masks)
        {
            for (wv, mv) in pruned[op.w].data().iter().zip(m.data()) {
                if *mv == 0.0 {
                    assert_eq!(*wv, 0.0);
                }
            }
        }
    }
}
