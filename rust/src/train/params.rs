//! Parameter initialization and the checkpoint store.
//!
//! Init matches the Python test reference (He-normal fan-in for weights,
//! zeros for biases) but runs entirely in Rust — no weight files cross the
//! Python/Rust boundary; the manifest's shape list is the contract.
//!
//! Checkpoints are a simple self-describing binary: magic, param count,
//! then per param: name, rank, dims (u32 LE) and raw f32 LE data.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelSpec;
use crate::rng::Pcg32;
use crate::tensor::Tensor;

const MAGIC: &[u8; 8] = b"REPROCK1";

/// He-normal init for weights (fan-in over all but the leading dim),
/// zeros for rank-1 biases.
pub fn init_params(spec: &ModelSpec, seed: u64) -> Vec<Tensor> {
    spec.params
        .iter()
        .enumerate()
        .map(|(i, p)| {
            if p.shape.len() > 1 {
                let fan_in: usize = p.shape[1..].iter().product();
                let std = (2.0 / fan_in as f32).sqrt();
                let mut rng = Pcg32::new(seed, i as u64 + 1);
                let data = (0..p.shape.iter().product::<usize>())
                    .map(|_| rng.normal_scaled(std))
                    .collect();
                Tensor::from_vec(&p.shape, data).unwrap()
            } else {
                Tensor::zeros(&p.shape)
            }
        })
        .collect()
}

pub fn save(path: impl AsRef<Path>, spec: &ModelSpec, params: &[Tensor]) -> Result<()> {
    if params.len() != spec.params.len() {
        bail!(
            "param count mismatch: {} vs spec {}",
            params.len(),
            spec.params.len()
        );
    }
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u32).to_le_bytes())?;
    for (t, p) in params.iter().zip(&spec.params) {
        let name = p.name.as_bytes();
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name)?;
        f.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        for &v in t.data() {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>, spec: &ModelSpec) -> Result<Vec<Tensor>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(&path)
            .with_context(|| format!("opening checkpoint {:?}", path.as_ref()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad checkpoint magic");
    }
    let n = read_u32(&mut f)? as usize;
    if n != spec.params.len() {
        bail!("checkpoint has {n} params, spec wants {}", spec.params.len());
    }
    let mut out = Vec::with_capacity(n);
    for p in &spec.params {
        let name_len = read_u32(&mut f)? as usize;
        let mut name = vec![0u8; name_len];
        f.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        if name != p.name {
            bail!("checkpoint param {name:?} != spec {:?}", p.name);
        }
        let rank = read_u32(&mut f)? as usize;
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(read_u32(&mut f)? as usize);
        }
        if shape != p.shape {
            bail!("checkpoint shape {shape:?} != spec {:?}", p.shape);
        }
        let count: usize = shape.iter().product();
        let mut buf = vec![0u8; count * 4];
        f.read_exact(&mut buf)?;
        let data = buf
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        out.push(Tensor::from_vec(&shape, data)?);
    }
    Ok(out)
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ParamSpec;

    fn tiny_spec() -> ModelSpec {
        ModelSpec {
            id: "t".into(),
            arch: "t".into(),
            classes: 2,
            in_hw: 4,
            ops: vec![],
            params: vec![
                ParamSpec {
                    name: "w".into(),
                    shape: vec![4, 3, 3, 3],
                },
                ParamSpec {
                    name: "b".into(),
                    shape: vec![4],
                },
            ],
            prunable: vec![],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn init_shapes_and_stats() {
        let spec = tiny_spec();
        let ps = init_params(&spec, 1);
        assert_eq!(ps[0].shape(), &[4, 3, 3, 3]);
        assert!(ps[1].data().iter().all(|&v| v == 0.0));
        // deterministic
        let ps2 = init_params(&spec, 1);
        assert_eq!(ps[0], ps2[0]);
        let ps3 = init_params(&spec, 2);
        assert_ne!(ps[0], ps3[0]);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let spec = tiny_spec();
        let ps = init_params(&spec, 7);
        let dir = std::env::temp_dir().join("repro_ckpt_test");
        let path = dir.join("m.ckpt");
        save(&path, &spec, &ps).unwrap();
        let loaded = load(&path, &spec).unwrap();
        assert_eq!(ps, loaded);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn checkpoint_rejects_wrong_spec() {
        let spec = tiny_spec();
        let ps = init_params(&spec, 7);
        let dir = std::env::temp_dir().join("repro_ckpt_test2");
        let path = dir.join("m.ckpt");
        save(&path, &spec, &ps).unwrap();
        let mut other = tiny_spec();
        other.params[1].shape = vec![5];
        assert!(load(&path, &other).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}
