//! Pruning baselines the paper compares against.
//!
//! * [`greedy_uniform`] — the "Uniform" greedy method of Table V: project
//!   every layer's pre-trained weights directly onto Sₙ by magnitude (no
//!   ADMM, no data) and hand the mask to the client for retraining. With
//!   privacy this is the natural strawman; the paper shows ADMM beats it.
//! * [`one_shot_magnitude`] — one-shot irregular magnitude pruning (Liu et
//!   al. [6], Table I); identical machinery with Scheme::Irregular.
//! * [`iterative_magnitude`] — iterative magnitude pruning [6]: T stages of
//!   geometric sparsity ramp, retraining between stages (uses the client's
//!   data, so it is *not* privacy-preserving — matching the paper's row).

use anyhow::{bail, Context, Result};

use crate::config::TrainConfig;
use crate::data::SynthVision;
use crate::pruning::{project, LayerShape, Projected, Scheme};
use crate::runtime::Runtime;
use crate::tensor::Tensor;

pub struct BaselineOutcome {
    pub params: Vec<Tensor>,
    pub masks: Vec<Tensor>,
    pub comp_rate: f64,
}

/// Magnitude-project every prunable layer of `pretrained` onto Sₙ(α).
pub fn greedy_uniform(
    rt: &Runtime,
    model_id: &str,
    pretrained: &[Tensor],
    scheme: Scheme,
    alpha: f64,
) -> Result<BaselineOutcome> {
    let model = rt.model(model_id)?;
    let mut params = pretrained.to_vec();
    let mut masks = Vec::new();
    let mut prs: Vec<Projected> = Vec::new();
    for (_, op) in model.prunable_convs() {
        let shape = LayerShape::from_conv(op);
        let wg = params[op.w]
            .clone()
            .reshape(&[shape.p, shape.q()])?;
        let pr = project(scheme, &wg, &shape, alpha)?;
        let shape4 = params[op.w].shape().to_vec();
        params[op.w] = pr.w.clone().reshape(&shape4)?;
        masks.push(pr.mask.clone());
        prs.push(pr);
    }
    let comp_rate = crate::pruning::compression_rate(&prs);
    Ok(BaselineOutcome {
        params,
        masks,
        comp_rate,
    })
}

/// One-shot magnitude pruning [6]: greedy projection + a single retraining
/// run (driven by the caller).
pub fn one_shot_magnitude(
    rt: &Runtime,
    model_id: &str,
    pretrained: &[Tensor],
    alpha: f64,
) -> Result<BaselineOutcome> {
    greedy_uniform(rt, model_id, pretrained, Scheme::Irregular, alpha)
}

/// Iterative magnitude pruning [6]: `stages` rounds of
/// project(α_t) → masked retrain, with α_t on a geometric ramp from 1 to α.
pub fn iterative_magnitude(
    rt: &Runtime,
    model_id: &str,
    pretrained: &[Tensor],
    alpha: f64,
    stages: usize,
    train: &SynthVision,
    test: &SynthVision,
    retrain_cfg: &TrainConfig,
) -> Result<BaselineOutcome> {
    if stages == 0 {
        bail!("iterative magnitude pruning needs stages >= 1");
    }
    let mut params = pretrained.to_vec();
    let mut outcome = None;
    for t in 1..=stages {
        let alpha_t = alpha.powf(t as f64 / stages as f64);
        let o = greedy_uniform(rt, model_id, &params, Scheme::Irregular, alpha_t)?;
        params = o.params.clone();
        let mut cfg = retrain_cfg.clone();
        cfg.steps = retrain_cfg.steps / stages;
        cfg.log_every = 0;
        crate::train::retrain_masked(
            rt, model_id, &mut params, &o.masks, train, test, &cfg,
        )?;
        outcome = Some(BaselineOutcome {
            params: params.clone(),
            masks: o.masks,
            comp_rate: o.comp_rate,
        });
    }
    outcome.context("iterative magnitude pruning produced no outcome")
}
