//! Shadow-model membership inference (Shokri et al. style, simplified to
//! a global-threshold attack).
//!
//! The attacker cannot threshold on the *target's* scores — that would
//! assume knowledge of the membership labels it is trying to infer.
//! Instead it trains `n_shadows` stand-in models with the **same
//! architecture and training recipe** as the target, each on its own
//! member set drawn from a disjoint PCG split stream
//! ([`crate::privacy::shadow_member_split`]), where membership *is* known
//! by construction. Pooling every shadow's member/non-member confidence
//! scores and sweeping a threshold over the pool
//! ([`super::mia::threshold_attack`]) yields one transferred threshold
//! τ*; the attack on the target just applies τ* to the target's scores.
//!
//! Shadow trainings are mutually independent, so they shard across the
//! [`crate::coordinator::service::PruneService`] worker pool; scores are
//! reassembled in shadow order on the caller's thread, keeping the pooled
//! threshold bit-identical at any thread count.

use anyhow::Result;

use crate::config::ModelSpec;
use crate::coordinator::service::PruneService;
use crate::data::SynthVision;
use crate::train::host::{
    confidence_scores, train_host, HostTrainCfg,
};
use crate::train::params::init_params;

use super::mia::{attack_at_threshold, threshold_attack, AttackResult};
use super::{shadow_member_split, shadow_out_split};

/// Shadow-attack knobs. Shadow member/out set sizes mirror the target's
/// so the pooled score distribution matches the attack surface.
#[derive(Clone, Copy, Debug)]
pub struct ShadowCfg {
    pub n_shadows: usize,
    /// members per shadow model
    pub n_train: usize,
    /// held-out (non-member) probes per shadow model
    pub n_out: usize,
    /// shadow training recipe — should match the target's
    pub train: HostTrainCfg,
}

/// The transferred attack state: one threshold learned on the pooled
/// shadow scores, plus the pool's own ROC summary (attack quality *on the
/// shadows*, an upper bound on what transfers).
#[derive(Clone, Copy, Debug)]
pub struct ShadowPool {
    pub threshold: f32,
    pub pool: AttackResult,
}

/// Result of applying the transferred threshold to one target model.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShadowResult {
    /// TPR − FPR at the transferred threshold (can go negative when the
    /// shadow threshold does not transfer)
    pub advantage: f64,
    pub tpr: f64,
    pub fpr: f64,
    pub threshold: f32,
}

/// Train the shadow fleet and learn the pooled threshold. `data_seed`
/// addresses the class signatures shared with the target's dataset;
/// `weight_seed` decorrelates shadow inits from the target's.
pub fn build_pool(
    spec: &ModelSpec,
    cfg: &ShadowCfg,
    data_seed: u64,
    weight_seed: u64,
    svc: &PruneService,
) -> Result<ShadowPool> {
    let ks: Vec<usize> = (0..cfg.n_shadows.max(1)).collect();
    let per_shadow: Vec<(Vec<f32>, Vec<f32>)> =
        svc.shard_map(&ks, |&k| {
            let tr = SynthVision::generate(
                spec.classes,
                spec.in_hw,
                cfg.n_train,
                data_seed,
                shadow_member_split(k),
            );
            let out = SynthVision::generate(
                spec.classes,
                spec.in_hw,
                cfg.n_out,
                data_seed,
                shadow_out_split(k),
            );
            let mut params = init_params(
                spec,
                weight_seed.wrapping_add(0x5AD0_0000 + k as u64),
            );
            let mut tc = cfg.train;
            tc.seed = tc.seed.wrapping_add(k as u64);
            train_host(spec, &mut params, &tr, &tc)?;
            Ok((
                confidence_scores(spec, &params, &tr)?,
                confidence_scores(spec, &params, &out)?,
            ))
        })?;
    let mut member = Vec::new();
    let mut non = Vec::new();
    for (m, o) in per_shadow {
        member.extend(m);
        non.extend(o);
    }
    let pool = threshold_attack(&member, &non)?;
    Ok(ShadowPool {
        threshold: pool.threshold,
        pool,
    })
}

impl ShadowPool {
    /// Attack one target model's score sets with the transferred
    /// threshold.
    pub fn apply(&self, member: &[f32], non: &[f32]) -> ShadowResult {
        let (tpr, fpr) =
            attack_at_threshold(member, non, self.threshold);
        ShadowResult {
            advantage: tpr - fpr,
            tpr,
            fpr,
            threshold: self.threshold,
        }
    }
}
