//! Rendering of one MIA experiment: the privacy-vs-compression table and
//! the `BENCH_privacy.json` bench log.
//!
//! Metric naming carries direction for `repro bench diff`
//! ([`crate::serve::stats`]): raw leakage series are prefixed `mia_`
//! (lower is better — less measured attack advantage), while the derived
//! `privacy_gain_*` series (dense-minus-pruned advantage) keep the
//! grow-is-better default. A future PR that *increases* any `mia_*`
//! number or *shrinks* a `privacy_gain_*` number past the threshold fails
//! the gate.

use crate::report::{pct, rate, Table};
use crate::serve::stats::{BenchLog, BenchResult};

use super::{MiaReport, MiaRow};

fn row_key(r: &MiaRow) -> String {
    match r.scheme {
        None => "dense".into(),
        Some(s) => {
            let rk = if r.rate.fract().abs() < 1e-9 {
                format!("{:.0}", r.rate)
            } else {
                format!("{}", r.rate).replace('.', "p")
            };
            format!("{}_x{rk}", s.name())
        }
    }
}

/// The privacy-vs-compression table: dense baseline row first, then one
/// row per (scheme × rate) pruned variant.
pub fn mia_table(r: &MiaReport) -> Table {
    let mut t = Table::new(
        &format!(
            "membership inference vs compression — {} \
             ({} threads, progressive rounds {}, shadow pool adv {:.3})",
            r.model,
            r.threads,
            r.progressive_rounds,
            r.shadow_pool.advantage
        ),
        &[
            "Variant",
            "Target Rate",
            "CONV Comp.",
            "Member Acc",
            "Probe Acc",
            "Conf Adv",
            "Conf AUC",
            "TPR@.1FPR",
            "Shadow Adv",
        ],
    );
    for row in &r.rows {
        t.row(&[
            row.label.clone(),
            if row.scheme.is_none() {
                "--".into()
            } else {
                rate(row.rate)
            },
            rate(row.comp_rate),
            pct(row.train_acc),
            pct(row.test_acc),
            format!("{:.3}", row.conf.advantage),
            format!("{:.3}", row.conf.auc),
            format!("{:.3}", row.conf.tpr_at_fpr10),
            format!("{:.3}", row.shadow.advantage),
        ]);
    }
    t
}

/// `BENCH_privacy.json` contents: per-row leakage series plus the derived
/// privacy gains and total wall time.
pub fn privacy_bench_log(r: &MiaReport) -> BenchLog {
    let mut log = BenchLog::new("privacy");
    log.push(BenchResult {
        name: "exp_mia_total".into(),
        mean_ms: r.secs * 1e3,
        median_ms: r.secs * 1e3,
        std_ms: 0.0,
        reps: 1,
    });
    for row in &r.rows {
        let key = row_key(row);
        log.metric(&format!("mia_adv_{key}"), row.conf.advantage);
        log.metric(&format!("mia_auc_{key}"), row.conf.auc);
        log.metric(
            &format!("mia_shadow_adv_{key}"),
            row.shadow.advantage,
        );
    }
    log.metric("mia_tpr10_dense", r.dense().conf.tpr_at_fpr10);
    let dense = r.dense().conf;
    let pruned = r.pruned();
    if !pruned.is_empty() {
        let mean_auc = pruned.iter().map(|p| p.conf.auc).sum::<f64>()
            / pruned.len() as f64;
        log.metric(
            "privacy_gain_adv_mean",
            dense.advantage - r.mean_pruned_advantage(),
        );
        log.metric("privacy_gain_auc_mean", dense.auc - mean_auc);
    }
    log
}
