//! Prune-and-retrain drivers for the privacy grid: one-shot and
//! progressive.
//!
//! Each MIA grid row needs "the pruned model the client would actually
//! deploy": ADMM-prune the dense target, then masked-retrain on the
//! *member* set (the client's confidential data — retraining on anything
//! else would be a different threat model). With `rounds > 1` the row
//! instead walks the progressive rate ladder
//! ([`crate::admm::scheduler::prune_progressive_par`], arxiv 1810.07378),
//! masked-retraining between rungs; the retrain budget is split evenly
//! across rungs so progressive and one-shot rows spend comparable
//! optimizer steps and stay comparable in the report.
//!
//! Everything here runs single-threaded per row (`SchedulerCfg` with
//! `threads = 1`, sequential host SGD): rows are the unit of parallelism,
//! sharded by the caller over [`PruneService::shard_map`] — the house
//! bit-identical-at-any-thread-count invariant holds because a row's
//! result never depends on where it runs.

use anyhow::Result;

use crate::admm::scheduler::{
    prune_layerwise_par, prune_progressive_par, SchedulerCfg,
};
use crate::config::{AdmmConfig, ModelSpec};
use crate::coordinator::service::PruneConfig;
use crate::data::SynthVision;
use crate::tensor::Tensor;
use crate::train::host::{retrain_masked_host, HostTrainCfg};

#[allow(unused_imports)] // doc link
use crate::coordinator::service::PruneService;

/// Everything a grid row's prune+retrain shares across configurations.
#[derive(Clone, Copy, Debug)]
pub struct RowRecipe<'a> {
    pub admm: &'a AdmmConfig,
    /// synthetic images per ADMM round
    pub admm_batch: usize,
    /// 0 or 1 = one-shot; otherwise progressive ladder rungs
    pub rounds: usize,
    pub retrain: &'a HostTrainCfg,
}

/// Deployed-model artifacts of one grid row.
pub struct PrunedModel {
    pub params: Vec<Tensor>,
    pub masks: Vec<Tensor>,
    pub comp_rate: f64,
}

/// Prune `dense` per `pc` and masked-retrain on `members`.
/// `recipe.rounds <= 1` is the one-shot path; otherwise the progressive
/// ladder with per-rung retraining.
pub fn prune_and_retrain(
    spec: &ModelSpec,
    dense: &[Tensor],
    pc: PruneConfig,
    recipe: &RowRecipe,
    members: &SynthVision,
) -> Result<PrunedModel> {
    let alpha = 1.0 / pc.rate;
    let cfg =
        SchedulerCfg::new(recipe.admm.clone(), recipe.admm_batch, 1);
    if recipe.rounds <= 1 {
        let out =
            prune_layerwise_par(spec, dense, pc.scheme, alpha, &cfg)?;
        let mut params = out.outcome.params;
        retrain_masked_host(
            spec,
            &mut params,
            &out.outcome.masks,
            members,
            recipe.retrain,
        )?;
        return Ok(PrunedModel {
            params,
            masks: out.outcome.masks,
            comp_rate: out.outcome.comp_rate,
        });
    }
    // split the retrain budget evenly across rungs (at least one step
    // each) so total optimizer work matches the one-shot path
    let mut rung_cfg = *recipe.retrain;
    rung_cfg.steps = (recipe.retrain.steps / recipe.rounds).max(1);
    let out = prune_progressive_par(
        spec,
        dense,
        pc.scheme,
        alpha,
        recipe.rounds,
        &cfg,
        |params, masks, rung| {
            let mut rc = rung_cfg;
            rc.seed = rung_cfg.seed.wrapping_add(rung as u64);
            retrain_masked_host(spec, params, masks, members, &rc)?;
            Ok(())
        },
    )?;
    Ok(PrunedModel {
        params: out.outcome.params,
        masks: out.outcome.masks,
        comp_rate: out.outcome.comp_rate,
    })
}
