//! Membership-inference attack metrics: ROC machinery over per-sample
//! membership scores.
//!
//! The attacker holds a score for every probe sample (here: the model's
//! softmax confidence on the true class,
//! [`confidence_scores`](crate::train::host::confidence_scores)) and
//! predicts "member" when the score clears a threshold. Sweeping the threshold over the pooled member/non-member
//! score sets yields the ROC curve; we report the three standard summary
//! numbers:
//!
//! * **attack advantage** — max over thresholds of (TPR − FPR), the
//!   membership experiment's distinguishing advantage (Yeom et al.);
//! * **AUC** — threshold-free ranking quality of the score;
//! * **TPR at FPR ≤ 0.1** — the low-false-positive operating point that
//!   actually matters for a realistic attacker.
//!
//! Everything is exact and deterministic: scores sort by `f32::total_cmp`,
//! equal scores collapse into one threshold group (so ties cannot make the
//! curve order-dependent), and all accumulation runs in f64 in sorted
//! order.

use anyhow::{bail, Result};

/// Summary of one threshold-sweep attack.
#[derive(Clone, Copy, Debug, Default)]
pub struct AttackResult {
    /// max over thresholds of TPR − FPR
    pub advantage: f64,
    /// area under the ROC curve (0.5 = chance)
    pub auc: f64,
    /// best TPR among operating points with FPR ≤ 0.1
    pub tpr_at_fpr10: f64,
    /// score threshold attaining `advantage` ("member" iff score ≥ t)
    pub threshold: f32,
}

/// Sweep every distinct score as a threshold over the two score sets and
/// summarize the resulting ROC curve.
pub fn threshold_attack(
    member: &[f32],
    non_member: &[f32],
) -> Result<AttackResult> {
    if member.is_empty() || non_member.is_empty() {
        bail!(
            "threshold attack needs non-empty score sets \
             ({} member, {} non-member)",
            member.len(),
            non_member.len()
        );
    }
    let mut scored: Vec<(f32, bool)> = member
        .iter()
        .map(|&s| (s, true))
        .chain(non_member.iter().map(|&s| (s, false)))
        .collect();
    scored.sort_by(|a, b| b.0.total_cmp(&a.0));

    let nm = member.len() as f64;
    let nn = non_member.len() as f64;
    let mut tp = 0.0f64;
    let mut fp = 0.0f64;
    let mut prev = (0.0f64, 0.0f64); // (fpr, tpr)
    let mut auc = 0.0f64;
    let mut best_adv = 0.0f64;
    let mut best_thr = f32::INFINITY;
    let mut tpr10 = 0.0f64;
    let mut i = 0;
    while i < scored.len() {
        let t = scored[i].0;
        // consume the whole tie group at this threshold
        let mut j = i;
        while j < scored.len() && scored[j].0.total_cmp(&t).is_eq() {
            if scored[j].1 {
                tp += 1.0;
            } else {
                fp += 1.0;
            }
            j += 1;
        }
        i = j;
        let tpr = tp / nm;
        let fpr = fp / nn;
        auc += (fpr - prev.0) * (tpr + prev.1) * 0.5;
        if tpr - fpr > best_adv {
            best_adv = tpr - fpr;
            best_thr = t;
        }
        if fpr <= 0.1 && tpr > tpr10 {
            tpr10 = tpr;
        }
        prev = (fpr, tpr);
    }
    Ok(AttackResult {
        advantage: best_adv,
        auc,
        tpr_at_fpr10: tpr10,
        threshold: best_thr,
    })
}

/// Evaluate a *fixed* threshold (e.g. one transferred from shadow models)
/// against the two score sets; returns (TPR, FPR).
pub fn attack_at_threshold(
    member: &[f32],
    non_member: &[f32],
    threshold: f32,
) -> (f64, f64) {
    let frac = |scores: &[f32]| -> f64 {
        let hits = scores.iter().filter(|&&s| s >= threshold).count();
        hits as f64 / scores.len().max(1) as f64
    };
    (frac(member), frac(non_member))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_separation_scores_one() {
        let m = [0.9f32, 0.95, 0.99];
        let n = [0.1f32, 0.2, 0.3];
        let r = threshold_attack(&m, &n).unwrap();
        assert!((r.advantage - 1.0).abs() < 1e-12);
        assert!((r.auc - 1.0).abs() < 1e-12);
        assert!((r.tpr_at_fpr10 - 1.0).abs() < 1e-12);
        assert!(r.threshold >= 0.9 - 1e-6);
    }

    #[test]
    fn identical_sets_score_chance() {
        let s = [0.5f32, 0.6, 0.7, 0.8];
        let r = threshold_attack(&s, &s).unwrap();
        assert!(r.advantage.abs() < 1e-12);
        assert!((r.auc - 0.5).abs() < 1e-12);
    }

    #[test]
    fn known_small_roc() {
        // member 0.9, 0.4; non 0.6, 0.1 → points (0,.5) (.5,.5) (.5,1) (1,1)
        let m = [0.9f32, 0.4];
        let n = [0.6f32, 0.1];
        let r = threshold_attack(&m, &n).unwrap();
        assert!((r.advantage - 0.5).abs() < 1e-12);
        assert!((r.auc - 0.75).abs() < 1e-12);
        // FPR ≤ 0.1 only holds before any non-member crosses: TPR 0.5
        assert!((r.tpr_at_fpr10 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tie_groups_are_order_independent() {
        // all scores equal → single group at (1,1): chance metrics
        let m = [0.5f32; 6];
        let n = [0.5f32; 4];
        let r = threshold_attack(&m, &n).unwrap();
        assert!(r.advantage.abs() < 1e-12);
        assert!((r.auc - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fixed_threshold_counts_rates() {
        let m = [0.9f32, 0.8, 0.2];
        let n = [0.85f32, 0.1, 0.1, 0.1];
        let (tpr, fpr) = attack_at_threshold(&m, &n, 0.8);
        assert!((tpr - 2.0 / 3.0).abs() < 1e-12);
        assert!((fpr - 0.25).abs() < 1e-12);
    }

    #[test]
    fn empty_sets_rejected() {
        assert!(threshold_attack(&[], &[0.5]).is_err());
        assert!(threshold_attack(&[0.5], &[]).is_err());
    }
}
