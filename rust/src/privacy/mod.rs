//! Privacy evaluation tier: does pruning actually reduce membership
//! leakage?
//!
//! The paper's framework is *privacy-preserving-oriented* — the designer
//! prunes against synthetic data so the client's confidential set never
//! leaves the client — but the deployed model itself can still leak
//! membership through its confidences. This tier quantifies that leakage
//! with two standard membership-inference attacks (DESIGN.md §16):
//!
//! * the **confidence-threshold attack** ([`mia`]): sweep a threshold
//!   over the model's true-class softmax confidence on member vs
//!   non-member probes; report advantage / AUC / TPR@0.1FPR;
//! * the **shadow-model attack** ([`shadow`]): learn the threshold on a
//!   fleet of shadow models trained like the target, then transfer it —
//!   the attacker never sees the target's membership labels.
//!
//! [`run_mia`] scores a dense target and a (scheme × rate) grid of
//! pruned-and-retrained variants ([`progressive`]) and emits the
//! privacy-vs-compression table ([`report`]). The expected shape, per
//! "Against Membership Inference Attack: Pruning is All You Need"
//! (arxiv 2008.13578): the dense model overfits its small member set and
//! leaks; pruning removes memorization capacity, so pruned rows show
//! lower measured advantage at mild accuracy cost.
//!
//! **Split-stream seeding.** All datasets share one `data_seed` (same
//! class signatures) and differ only in the PCG *split* id of
//! [`SynthVision::generate`]: members = split [`MEMBER_SPLIT`],
//! non-member probes = [`NON_MEMBER_SPLIT`], shadow k's member/out sets =
//! [`shadow_member_split`]`(k)` / [`shadow_out_split`]`(k)`. Distinct
//! split ids select disjoint Pcg32 streams, so every set is sampled from
//! the same task distribution while sharing no samples — the
//! member-disjointness the attack definition requires (asserted in
//! `tests/privacy.rs`).
//!
//! **Determinism.** Target and shadow training are sequential per model;
//! grid rows and shadow fleets shard over
//! [`PruneService::shard_map`] with results reassembled in
//! item order. The whole report is bit-identical at any thread count.

pub mod mia;
pub mod progressive;
pub mod report;
pub mod shadow;

use anyhow::Result;

use crate::config::{AdmmConfig, Preset};
use crate::coordinator::service::{PruneConfig, PruneService};
use crate::data::SynthVision;
use crate::mobile::synth::vgg_style;
use crate::pruning::Scheme;
use crate::tensor::Tensor;
use crate::train::host::{
    confidence_scores, evaluate_host, train_host, HostTrainCfg,
};
use crate::util::Stopwatch;

use mia::{threshold_attack, AttackResult};
use shadow::{ShadowCfg, ShadowPool, ShadowResult};

/// Split id of the client's confidential member set.
pub const MEMBER_SPLIT: u64 = 0;
/// Split id of the non-member probe set.
pub const NON_MEMBER_SPLIT: u64 = 1;
/// Shadow splits start far from the member/non-member/test ids.
pub const SHADOW_SPLIT_BASE: u64 = 100;

/// Split id of shadow `k`'s member set.
pub fn shadow_member_split(k: usize) -> u64 {
    SHADOW_SPLIT_BASE + 2 * k as u64
}

/// Split id of shadow `k`'s held-out (non-member) set.
pub fn shadow_out_split(k: usize) -> u64 {
    SHADOW_SPLIT_BASE + 2 * k as u64 + 1
}

/// Full configuration of one MIA experiment.
#[derive(Clone, Debug)]
pub struct MiaConfig {
    pub classes: usize,
    pub hw: usize,
    /// per-stage conv widths of the VGG-style target
    pub widths: Vec<usize>,
    /// member-set size — small on purpose, so the dense target overfits
    pub n_members: usize,
    /// non-member probe count
    pub n_non: usize,
    pub n_shadows: usize,
    /// dense target (and shadow) training recipe
    pub train: HostTrainCfg,
    /// masked-retrain recipe for pruned rows
    pub retrain: HostTrainCfg,
    pub admm: AdmmConfig,
    /// synthetic images per ADMM round
    pub admm_batch: usize,
    pub schemes: Vec<Scheme>,
    /// target CONV compression rates (the grid's columns)
    pub rates: Vec<f64>,
    /// 0 or 1 = one-shot pruning; otherwise progressive ladder rungs
    pub progressive_rounds: usize,
    /// addresses class signatures + every split stream
    pub data_seed: u64,
    /// addresses target/shadow weight inits
    pub weight_seed: u64,
    pub threads: usize,
}

impl MiaConfig {
    /// Preset-scaled experiment. The dense target is trained long on a
    /// deliberately small member set (each member is revisited dozens of
    /// times — the overfit regime where membership leaks); pruned rows
    /// get a much shorter masked retrain.
    pub fn preset(p: Preset) -> Self {
        let mut admm = AdmmConfig::preset(p);
        // host primal runs generic SGD — same scale the host sweep uses
        admm.lr_layer = 5e-3;
        let (classes, hw, widths, n_members, n_shadows) = match p {
            Preset::Smoke => (6, 8, vec![4, 6], 48, 2),
            Preset::Quick => (10, 16, vec![8, 16], 96, 3),
            Preset::Full => (10, 16, vec![8, 16], 128, 5),
        };
        let train_steps = match p {
            Preset::Smoke => 160,
            Preset::Quick => 400,
            Preset::Full => 700,
        };
        let retrain_steps = match p {
            Preset::Smoke => 60,
            Preset::Quick => 120,
            Preset::Full => 200,
        };
        let rates = match p {
            Preset::Smoke => vec![8.0],
            Preset::Quick => vec![4.0, 8.0],
            Preset::Full => vec![2.0, 4.0, 8.0],
        };
        MiaConfig {
            classes,
            hw,
            widths,
            n_members,
            n_non: n_members,
            n_shadows,
            train: HostTrainCfg {
                steps: train_steps,
                batch: 16.min(n_members),
                lr: 0.05,
                seed: 0x7EA1_0001,
            },
            retrain: HostTrainCfg {
                steps: retrain_steps,
                batch: 16.min(n_members),
                lr: 0.04,
                seed: 0x2E72_0001,
            },
            admm,
            admm_batch: 8,
            schemes: Scheme::all().to_vec(),
            rates,
            progressive_rounds: 0,
            data_seed: 0x5EED_31A0,
            weight_seed: 0xBA5E_31A0,
            threads: crate::coordinator::default_threads(),
        }
    }
}

/// One row of the privacy-vs-compression table.
#[derive(Clone, Debug)]
pub struct MiaRow {
    /// "dense" or the pruning scheme name
    pub label: String,
    pub scheme: Option<Scheme>,
    /// target CONV compression rate (1 for the dense baseline)
    pub rate: f64,
    /// measured CONV compression rate
    pub comp_rate: f64,
    /// accuracy on the member set (the memorization signal)
    pub train_acc: f64,
    /// accuracy on the non-member probes (generalization)
    pub test_acc: f64,
    /// confidence-threshold attack summary
    pub conf: AttackResult,
    /// shadow-transferred attack summary
    pub shadow: ShadowResult,
}

/// Full MIA experiment result: dense baseline row first, then the grid.
pub struct MiaReport {
    pub model: String,
    pub threads: usize,
    pub progressive_rounds: usize,
    /// attack quality on the pooled shadow scores (the transfer source)
    pub shadow_pool: AttackResult,
    pub rows: Vec<MiaRow>,
    pub secs: f64,
}

impl MiaReport {
    /// The dense baseline row.
    pub fn dense(&self) -> &MiaRow {
        &self.rows[0]
    }

    /// Grid rows (everything but the dense baseline).
    pub fn pruned(&self) -> &[MiaRow] {
        &self.rows[1..]
    }

    /// Mean confidence-attack advantage over the pruned rows.
    pub fn mean_pruned_advantage(&self) -> f64 {
        let p = self.pruned();
        if p.is_empty() {
            return 0.0;
        }
        p.iter().map(|r| r.conf.advantage).sum::<f64>()
            / p.len() as f64
    }
}

/// Identity of a row under scoring.
struct RowMeta {
    label: String,
    scheme: Option<Scheme>,
    rate: f64,
    comp_rate: f64,
}

fn score_row(
    spec: &crate::config::ModelSpec,
    params: &[Tensor],
    probes: (&SynthVision, &SynthVision),
    pool: &ShadowPool,
    meta: RowMeta,
) -> Result<MiaRow> {
    let (members, non) = probes;
    let ms = confidence_scores(spec, params, members)?;
    let ns = confidence_scores(spec, params, non)?;
    Ok(MiaRow {
        label: meta.label,
        scheme: meta.scheme,
        rate: meta.rate,
        comp_rate: meta.comp_rate,
        train_acc: evaluate_host(spec, params, members)?,
        test_acc: evaluate_host(spec, params, non)?,
        conf: threshold_attack(&ms, &ns)?,
        shadow: pool.apply(&ms, &ns),
    })
}

/// Run the full experiment: train the dense target, build the shadow
/// pool, then attack dense + every (scheme × rate) pruned variant.
pub fn run_mia(cfg: &MiaConfig) -> Result<MiaReport> {
    let sw = Stopwatch::start();
    let (spec, init) = vgg_style(
        "mia_vgg",
        cfg.hw,
        cfg.classes,
        &cfg.widths,
        cfg.weight_seed,
    );
    let members = SynthVision::generate(
        cfg.classes,
        cfg.hw,
        cfg.n_members,
        cfg.data_seed,
        MEMBER_SPLIT,
    );
    let non = SynthVision::generate(
        cfg.classes,
        cfg.hw,
        cfg.n_non,
        cfg.data_seed,
        NON_MEMBER_SPLIT,
    );

    let mut dense = init;
    train_host(&spec, &mut dense, &members, &cfg.train)?;

    let svc = PruneService::new(cfg.threads, cfg.admm_batch);
    let pool = shadow::build_pool(
        &spec,
        &ShadowCfg {
            n_shadows: cfg.n_shadows,
            n_train: cfg.n_members,
            n_out: cfg.n_non,
            train: cfg.train,
        },
        cfg.data_seed,
        cfg.weight_seed,
        &svc,
    )?;

    let mut rows = vec![score_row(
        &spec,
        &dense,
        (&members, &non),
        &pool,
        RowMeta {
            label: "dense".into(),
            scheme: None,
            rate: 1.0,
            comp_rate: 1.0,
        },
    )?];

    let grid: Vec<PruneConfig> = cfg
        .schemes
        .iter()
        .flat_map(|&scheme| {
            cfg.rates
                .iter()
                .map(move |&rate| PruneConfig { scheme, rate })
        })
        .collect();
    let recipe = progressive::RowRecipe {
        admm: &cfg.admm,
        admm_batch: cfg.admm_batch,
        rounds: cfg.progressive_rounds,
        retrain: &cfg.retrain,
    };
    let pruned_rows = svc.shard_map(&grid, |&pc| {
        let pm = progressive::prune_and_retrain(
            &spec, &dense, pc, &recipe, &members,
        )?;
        score_row(
            &spec,
            &pm.params,
            (&members, &non),
            &pool,
            RowMeta {
                label: pc.scheme.name().into(),
                scheme: Some(pc.scheme),
                rate: pc.rate,
                comp_rate: pm.comp_rate,
            },
        )
    })?;
    rows.extend(pruned_rows);

    Ok(MiaReport {
        model: spec.id.clone(),
        threads: cfg.threads,
        progressive_rounds: cfg.progressive_rounds,
        shadow_pool: pool.pool,
        rows,
        secs: sw.secs(),
    })
}
