fn main() -> anyhow::Result<()> {
    repro::coordinator::cli::main()
}
