//! Execute phase of the mobile stack (the executor side of the
//! plan/executor split).
//!
//! [`Executor`] is a thin interpreter over a compiled
//! [`ExecutionPlan`](super::plan::ExecutionPlan): every schedule step is
//! pre-resolved (no tag lookups, no shape inference), feature maps ping-pong
//! through the plan-sized buffer [`Arena`] (zero heap allocations per
//! inference after construction), and conv layers dispatch through the
//! [`ConvKernel`] registry — a dense reference kernel, the pattern-sparse
//! scalar kernel consuming the packed payload + row-grouped codelets, a
//! row-tiled variant, the width-vectorized [`PatternVec`] /
//! [`PatternVecTiled`] kernels built on [`super::simd`] (DESIGN.md §12),
//! and — on quantized plans ([`ElemType::I8`]) — the [`QuantScalar`] /
//! [`QuantVec`] kernels, which consume i8 taps with exact i32
//! accumulation and requantize to f32 on the way out (DESIGN.md §14).
//! Dispatch is either uniform ([`KernelSel::Uniform`]) or per layer
//! through the [`KernelChoice`](super::costmodel::KernelChoice) the plan
//! compiler baked into each [`LayerPlan`] ([`KernelSel::Auto`]). Conv
//! layers run multi-threaded via `std::thread::scope` across the plan's
//! cost-balanced per-thread filter blocks; [`Executor::execute_batch`]
//! and [`execute_batch_parallel`] cover throughput scenarios.
//!
//! All pattern kernels add each output element's taps in the identical
//! kernel → row → tap order with identical rounding (no FMA
//! contraction), so switching kernel kind — including what the
//! autotuner picks — never changes results bit for bit (property-tested
//! below). The quantized kernels reach the same guarantee by a
//! different route: i8×i8→i32 accumulation is exact, so their results
//! are order-insensitive by arithmetic, and the per-tensor activation
//! scale is computed sequentially on the calling thread
//! ([`quantize_activations`]) so it never depends on the thread count.
//!
//! Numerics are verified against the PJRT `fwd_eval` artifact in
//! rust/tests/pjrt_parity.rs (with `--features pjrt`) and against the dense
//! reference kernel by property tests below and in
//! rust/tests/mobile_integration.rs.

use anyhow::{bail, Context, Result};

use crate::config::Act;
use crate::tensor::{Chw, Tensor};

use super::ir::{ConvIR, ModelIR};
use super::plan::{
    self, Arena, ElemType, ExecutionPlan, FilterBlock, LayerPlan,
    PackedKernel, PlanStep,
};
use super::simd::{axpy_row, qaxpy_row};

pub use super::passes::StyleRows;
pub use super::plan::same_pad_lo;

/// Owned feature map: (C, H, W) row-major. The executor's input type; all
/// intermediates live in the arena as flat slices viewed through [`Chw`].
#[derive(Clone, Debug)]
pub struct Fmap {
    pub c: usize,
    pub hw: usize,
    pub data: Vec<f32>,
}

impl Fmap {
    pub fn zeros(c: usize, hw: usize) -> Self {
        Fmap {
            c,
            hw,
            data: vec![0.0; c * hw * hw],
        }
    }

    pub fn from_tensor_chw(t: &Tensor) -> Result<Self> {
        let s = t.shape();
        if s.len() != 3 || s[1] != s[2] {
            bail!("expected (C,H,H) tensor, got {s:?}");
        }
        Ok(Fmap {
            c: s[0],
            hw: s[1],
            data: t.data().to_vec(),
        })
    }

    #[inline]
    pub fn plane(&self, ch: usize) -> &[f32] {
        &self.data[ch * self.hw * self.hw..(ch + 1) * self.hw * self.hw]
    }

    #[inline]
    pub fn view(&self) -> Chw<'_> {
        Chw::new(self.c, self.hw, &self.data)
    }
}

/// Valid output-x range for which ix = ox*stride + dx lies in [0, ihw).
/// Shared with the pruning scheduler's host convolutions
/// (crate::admm::scheduler), which stream taps in the same order.
#[inline]
pub(crate) fn x_range(
    out_hw: usize,
    stride: usize,
    dx: i64,
    ihw: i64,
) -> (usize, usize) {
    // smallest ox with ox*stride + dx >= 0
    let ox0 = if dx >= 0 {
        0
    } else {
        ((-dx) as usize).div_ceil(stride)
    };
    // largest ox with ox*stride + dx < ihw; div_euclid (not truncating /)
    // so a negative numerator still floors — with `/`, ihw - dx - 1 < 0
    // yielded ox1 = 1 and an out-of-bounds read for e.g. in=2 k=3 s=2
    let mut ox1 = out_hw;
    if (out_hw as i64 - 1) * stride as i64 + dx >= ihw {
        ox1 = ((ihw - dx - 1).div_euclid(stride as i64) + 1).max(0) as usize;
    }
    (ox0.min(out_hw), ox1.min(out_hw))
}

// ---------------------------------------------------------------------------
// Disjoint output planes shared across worker threads
// ---------------------------------------------------------------------------

/// Raw view of a conv output buffer as per-filter planes, shared across the
/// worker threads of one layer. Race freedom comes from the plan: the
/// per-thread [`FilterBlock`]s partition the filter schedule, so each plane
/// is written by exactly one thread (asserted at plan build).
pub struct OutPlanes<'a> {
    base: *mut f32,
    plane: usize,
    n: usize,
    _life: std::marker::PhantomData<&'a mut [f32]>,
}

unsafe impl Send for OutPlanes<'_> {}
unsafe impl Sync for OutPlanes<'_> {}

impl<'a> OutPlanes<'a> {
    pub fn new(buf: &'a mut [f32], plane: usize) -> Self {
        let n = if plane == 0 { 0 } else { buf.len() / plane };
        OutPlanes {
            base: buf.as_mut_ptr(),
            plane,
            n,
            _life: std::marker::PhantomData,
        }
    }

    /// Mutable view of filter `f`'s output plane.
    ///
    /// # Safety
    /// Each plane index must be held by at most one caller at a time. The
    /// executor guarantees this by handing each worker thread a disjoint
    /// filter block.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    pub unsafe fn plane_mut(&self, f: usize) -> &'a mut [f32] {
        assert!(f < self.n, "plane {f} out of {}", self.n);
        std::slice::from_raw_parts_mut(
            self.base.add(f * self.plane),
            self.plane,
        )
    }
}

// ---------------------------------------------------------------------------
// Conv kernel registry
// ---------------------------------------------------------------------------

/// Dynamically quantized view of a layer input: the activations of
/// [`ConvInput::x`] rounded to i8 with one per-tensor `scale`
/// (`x ≈ data * scale`). Produced by [`quantize_activations`] on the
/// calling thread before workers fan out, so the mapping never depends
/// on the thread count.
#[derive(Clone, Copy)]
pub struct QuantView<'a> {
    pub data: &'a [i8],
    pub scale: f32,
}

/// Input handed to a conv kernel: the f32 feature map plus, on
/// quantized plans, its i8 view. f32 kernels read only `x`; quantized
/// kernels read only `qx` and panic if it is missing — the executor
/// pairs kernels with payloads through [`KernelKind::for_elem`], so
/// the mismatch is unreachable from the public API.
#[derive(Clone, Copy)]
pub struct ConvInput<'a> {
    pub x: Chw<'a>,
    pub qx: Option<QuantView<'a>>,
}

impl<'a> ConvInput<'a> {
    /// f32-only input (no quantized view).
    pub fn f32(x: Chw<'a>) -> Self {
        ConvInput { x, qx: None }
    }
}

/// Dynamic per-tensor activation quantization: symmetric i8 with
/// `scale = maxabs / 127` (1.0 for an all-zero map; non-finite values
/// are ignored for the scale and quantize to 0). Runs sequentially on
/// the calling thread — the scan order is fixed, so the resulting bytes
/// (and every downstream integer accumulation) are identical at any
/// thread or worker count.
pub(crate) fn quantize_activations(src: &[f32], dst: &mut [i8]) -> f32 {
    debug_assert_eq!(src.len(), dst.len());
    let mut maxabs = 0.0f32;
    for &v in src {
        let a = v.abs();
        if a.is_finite() && a > maxabs {
            maxabs = a;
        }
    }
    let scale = if maxabs > 0.0 { maxabs / 127.0 } else { 1.0 };
    let inv = 1.0 / scale;
    for (d, &v) in dst.iter_mut().zip(src) {
        // saturating float→int cast: NaN lands on 0 deterministically
        *d = (v * inv).round().clamp(-127.0, 127.0) as i8;
    }
    scale
}

/// A conv inner-loop implementation. Kernels compute complete output
/// planes (bias fill → accumulate → activation) for every filter of the
/// block they are handed, so blocks parallelize without a fix-up pass.
/// `acc` is per-block i32 scratch (at least one output plane) used only
/// by the quantized kernels; the f32 kernels receive an empty slice.
pub trait ConvKernel: Sync {
    fn name(&self) -> &'static str;
    fn run_block(
        &self,
        c: &ConvIR,
        lp: &LayerPlan,
        block: &FilterBlock,
        input: ConvInput<'_>,
        acc: &mut [i32],
        out: &OutPlanes<'_>,
    );
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// dense direct conv — the baseline frameworks' compute shape and the
    /// numerics reference
    DenseRef,
    /// pattern-sparse scalar: packed payload + row-grouped codelets
    PatternScalar,
    /// pattern-sparse with output-row tiling (locality on large fmaps)
    PatternTiled,
    /// pattern-sparse with width-lane vectorized tap codelets
    PatternVec,
    /// vectorized codelets plus output-row / filter-group cache tiling
    PatternVecTiled,
    /// quantized pattern-sparse scalar: i8 taps, exact i32 accumulation
    QuantScalar,
    /// quantized pattern-sparse with vectorized widening codelets
    QuantVec,
}

/// The f32 kernel kinds — the autotuner's candidate grid on f32 plans
/// and the set every plan artifact round-trip probes.
pub const KERNEL_KINDS: [KernelKind; 5] = [
    KernelKind::DenseRef,
    KernelKind::PatternScalar,
    KernelKind::PatternTiled,
    KernelKind::PatternVec,
    KernelKind::PatternVecTiled,
];

/// Kernel kinds that consume i8 payloads; f32 selections land on these
/// through [`KernelKind::for_elem`] on quantized plans.
pub const QUANT_KERNEL_KINDS: [KernelKind; 2] =
    [KernelKind::QuantScalar, KernelKind::QuantVec];

impl KernelKind {
    pub fn name(self) -> &'static str {
        kernel(self).name()
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "dense" => KernelKind::DenseRef,
            "sparse" | "pattern" | "scalar" => KernelKind::PatternScalar,
            "tiled" => KernelKind::PatternTiled,
            "vec" => KernelKind::PatternVec,
            "vec-tiled" | "vectiled" => KernelKind::PatternVecTiled,
            "quant" | "quant-scalar" => KernelKind::QuantScalar,
            "quant-vec" | "qvec" => KernelKind::QuantVec,
            _ => bail!(
                "unknown kernel {s:?} \
                 (dense|scalar|tiled|vec|vec-tiled|quant|quant-vec)"
            ),
        })
    }

    /// Project a selection onto a kernel that can consume `elem`
    /// payloads: on i8 plans the vector-shaped f32 kinds land on
    /// [`KernelKind::QuantVec`] and everything else on
    /// [`KernelKind::QuantScalar`]; on f32 plans the quantized kinds
    /// map back to their pattern equivalents. Identity whenever the
    /// kind already matches the element type, so the f32 path is
    /// untouched by this hook.
    pub fn for_elem(self, elem: ElemType) -> Self {
        match elem {
            ElemType::F32 => match self {
                KernelKind::QuantScalar => KernelKind::PatternScalar,
                KernelKind::QuantVec => KernelKind::PatternVec,
                k => k,
            },
            ElemType::I8 => match self {
                KernelKind::PatternVec
                | KernelKind::PatternVecTiled
                | KernelKind::QuantVec => KernelKind::QuantVec,
                _ => KernelKind::QuantScalar,
            },
        }
    }
}

/// How the executor picks the conv kernel for each layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelSel {
    /// force one kernel kind for every layer
    Uniform(KernelKind),
    /// per-layer dispatch through the
    /// [`KernelChoice`](super::costmodel::KernelChoice) baked into the
    /// plan — analytic defaults, or the autotuner's winners on a tuned
    /// plan
    Auto,
}

impl From<KernelKind> for KernelSel {
    fn from(k: KernelKind) -> Self {
        KernelSel::Uniform(k)
    }
}

impl KernelSel {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "auto" => KernelSel::Auto,
            _ => KernelSel::Uniform(KernelKind::parse(s)?),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelSel::Auto => "auto",
            KernelSel::Uniform(k) => k.name(),
        }
    }
}

static DENSE_REF: DenseRef = DenseRef;
static PATTERN_SCALAR: PatternScalar = PatternScalar;
static PATTERN_TILED: PatternTiled = PatternTiled;
static PATTERN_VEC: PatternVec = PatternVec;
static PATTERN_VEC_TILED: PatternVecTiled = PatternVecTiled;
static QUANT_SCALAR: QuantScalar = QuantScalar;
static QUANT_VEC: QuantVec = QuantVec;

/// Resolve a kernel implementation from the registry.
pub fn kernel(kind: KernelKind) -> &'static dyn ConvKernel {
    match kind {
        KernelKind::DenseRef => &DENSE_REF,
        KernelKind::PatternScalar => &PATTERN_SCALAR,
        KernelKind::PatternTiled => &PATTERN_TILED,
        KernelKind::PatternVec => &PATTERN_VEC,
        KernelKind::PatternVecTiled => &PATTERN_VEC_TILED,
        KernelKind::QuantScalar => &QUANT_SCALAR,
        KernelKind::QuantVec => &QUANT_VEC,
    }
}

#[inline]
fn finish_plane(act: Act, o: &mut [f32]) {
    if act == Act::Relu {
        for v in o.iter_mut() {
            *v = v.max(0.0);
        }
    }
}

/// Dense direct convolution over the original weights (multiplies the
/// zeros; kept branchless — it is the timing baseline and the reference).
pub struct DenseRef;

impl ConvKernel for DenseRef {
    fn name(&self) -> &'static str {
        "dense-ref"
    }

    fn run_block(
        &self,
        c: &ConvIR,
        lp: &LayerPlan,
        block: &FilterBlock,
        input: ConvInput<'_>,
        _acc: &mut [i32],
        out: &OutPlanes<'_>,
    ) {
        let x = input.x;
        let ihw = lp.in_hw as i64;
        let w = c.w.data();
        for &f in &lp.exec_order[block.span.clone()] {
            // Safety: block filters are disjoint across threads.
            let o = unsafe { out.plane_mut(f) };
            o.fill(lp.bias[f]);
            for ch in 0..lp.c {
                let xin = x.plane(ch);
                let wbase = (f * lp.c + ch) * lp.kh * lp.kw;
                for ky in 0..lp.kh {
                    let dy = ky as i64 - lp.pad;
                    for kx in 0..lp.kw {
                        let wv = w[wbase + ky * lp.kw + kx];
                        let dx = kx as i64 - lp.pad;
                        accumulate_tap(lp, o, xin, wv, dy, dx, ihw);
                    }
                }
            }
            finish_plane(lp.act, o);
        }
    }
}

/// One (dy, dx) weight tap streamed over every valid output position.
#[inline]
fn accumulate_tap(
    lp: &LayerPlan,
    o: &mut [f32],
    xin: &[f32],
    wv: f32,
    dy: i64,
    dx: i64,
    ihw: i64,
) {
    for oy in 0..lp.out_hw {
        let iy = (oy * lp.stride) as i64 + dy;
        if iy < 0 || iy >= ihw {
            continue;
        }
        let irow = iy as usize * lp.in_hw;
        let orow = oy * lp.out_hw;
        let (ox0, ox1) = x_range(lp.out_hw, lp.stride, dx, ihw);
        let mut ix = (ox0 * lp.stride) as i64 + dx;
        for ox in ox0..ox1 {
            o[orow + ox] += wv * xin[irow + ix as usize];
            ix += lp.stride as i64;
        }
    }
}

/// Pattern-sparse scalar kernel: walks the packed payload in the reordered
/// schedule; each pattern row is one streaming codelet (the
/// load-redundancy-eliminated shape).
pub struct PatternScalar;

impl ConvKernel for PatternScalar {
    fn name(&self) -> &'static str {
        "pattern-scalar"
    }

    fn run_block(
        &self,
        _c: &ConvIR,
        lp: &LayerPlan,
        block: &FilterBlock,
        input: ConvInput<'_>,
        _acc: &mut [i32],
        out: &OutPlanes<'_>,
    ) {
        let x = input.x;
        let payload = lp.payload.f32_taps();
        let ihw = lp.in_hw as i64;
        for &f in &lp.exec_order[block.span.clone()] {
            // Safety: block filters are disjoint across threads.
            let o = unsafe { out.plane_mut(f) };
            o.fill(lp.bias[f]);
            for k in &lp.kernels[lp.filter_ranges[f].clone()] {
                let xin = x.plane(k.ch as usize);
                let pay = &payload[k.off as usize..];
                for (ky, taps) in &lp.style_rows[k.style as usize] {
                    let dy = *ky as i64 - lp.pad;
                    for oy in 0..lp.out_hw {
                        let iy = (oy * lp.stride) as i64 + dy;
                        if iy < 0 || iy >= ihw {
                            continue;
                        }
                        let irow = iy as usize * lp.in_hw;
                        let orow = oy * lp.out_hw;
                        // row codelet: all taps of this row share one
                        // input-row load stream
                        for (kx, slot) in taps {
                            let wv = pay[*slot];
                            let dx = *kx as i64 - lp.pad;
                            let (ox0, ox1) =
                                x_range(lp.out_hw, lp.stride, dx, ihw);
                            let mut ix = (ox0 * lp.stride) as i64 + dx;
                            for ox in ox0..ox1 {
                                o[orow + ox] +=
                                    wv * xin[irow + ix as usize];
                                ix += lp.stride as i64;
                            }
                        }
                    }
                }
            }
            finish_plane(lp.act, o);
        }
    }
}

/// Pattern-sparse kernel with output-row tiling: kernels revisit a small
/// band of input rows while it is cache-hot instead of streaming the whole
/// plane per kernel. The tile height comes from the layer's
/// [`KernelChoice`](super::costmodel::KernelChoice) — the analytic
/// L1-band default, or whatever the autotuner measured as fastest.
pub struct PatternTiled;

impl ConvKernel for PatternTiled {
    fn name(&self) -> &'static str {
        "pattern-tiled"
    }

    fn run_block(
        &self,
        _c: &ConvIR,
        lp: &LayerPlan,
        block: &FilterBlock,
        input: ConvInput<'_>,
        _acc: &mut [i32],
        out: &OutPlanes<'_>,
    ) {
        let x = input.x;
        let payload = lp.payload.f32_taps();
        let ihw = lp.in_hw as i64;
        let row_tile = (lp.choice.row_tile as usize).max(1);
        for &f in &lp.exec_order[block.span.clone()] {
            // Safety: block filters are disjoint across threads.
            let o = unsafe { out.plane_mut(f) };
            o.fill(lp.bias[f]);
            let mut oy0 = 0;
            while oy0 < lp.out_hw {
                let oy1 = (oy0 + row_tile).min(lp.out_hw);
                for k in &lp.kernels[lp.filter_ranges[f].clone()] {
                    let xin = x.plane(k.ch as usize);
                    let pay = &payload[k.off as usize..];
                    for (ky, taps) in &lp.style_rows[k.style as usize] {
                        let dy = *ky as i64 - lp.pad;
                        for oy in oy0..oy1 {
                            let iy = (oy * lp.stride) as i64 + dy;
                            if iy < 0 || iy >= ihw {
                                continue;
                            }
                            let irow = iy as usize * lp.in_hw;
                            let orow = oy * lp.out_hw;
                            for (kx, slot) in taps {
                                let wv = pay[*slot];
                                let dx = *kx as i64 - lp.pad;
                                let (ox0, ox1) = x_range(
                                    lp.out_hw, lp.stride, dx, ihw,
                                );
                                let mut ix =
                                    (ox0 * lp.stride) as i64 + dx;
                                for ox in ox0..ox1 {
                                    o[orow + ox] +=
                                        wv * xin[irow + ix as usize];
                                    ix += lp.stride as i64;
                                }
                            }
                        }
                    }
                }
                oy0 = oy1;
            }
            finish_plane(lp.act, o);
        }
    }
}

/// All codelets of filter `f` restricted to output rows `[oy0, oy1)`,
/// each tap streamed as a width-lane [`axpy_row`]. The valid output-x
/// window is hoisted per tap (it is row-invariant), so the hot loop is
/// pure slicing + vector arithmetic.
///
/// Per output element the taps accumulate in kernel → row → tap order —
/// the same order as [`PatternScalar`] — with one rounded multiply and
/// one rounded add each, so all pattern kernels agree bit for bit.
#[inline]
fn vec_filter(
    lp: &LayerPlan,
    kernels: &[PackedKernel],
    x: Chw<'_>,
    o: &mut [f32],
    ihw: i64,
    oy0: usize,
    oy1: usize,
) {
    let payload = lp.payload.f32_taps();
    for k in kernels {
        let xin = x.plane(k.ch as usize);
        let pay = &payload[k.off as usize..];
        for (ky, taps) in &lp.style_rows[k.style as usize] {
            let dy = *ky as i64 - lp.pad;
            for (kx, slot) in taps {
                let wv = pay[*slot];
                let dx = *kx as i64 - lp.pad;
                let (ox0, ox1) = x_range(lp.out_hw, lp.stride, dx, ihw);
                if ox0 >= ox1 {
                    continue;
                }
                for oy in oy0..oy1 {
                    let iy = (oy * lp.stride) as i64 + dy;
                    if iy < 0 || iy >= ihw {
                        continue;
                    }
                    let irow = iy as usize * lp.in_hw;
                    let orow = oy * lp.out_hw;
                    let ix0 = (irow as i64
                        + (ox0 * lp.stride) as i64
                        + dx) as usize;
                    axpy_row(
                        &mut o[orow + ox0..orow + ox1],
                        &xin[ix0..],
                        wv,
                        lp.stride,
                    );
                }
            }
        }
    }
}

/// Width-vectorized pattern kernel: every row codelet streams
/// [`LANES`](super::simd::LANES)-wide fmap vectors through
/// [`axpy_row`]; border columns and widths that do not divide the lane
/// width fall back to the scalar tail inside the codelet.
pub struct PatternVec;

impl ConvKernel for PatternVec {
    fn name(&self) -> &'static str {
        "pattern-vec"
    }

    fn run_block(
        &self,
        _c: &ConvIR,
        lp: &LayerPlan,
        block: &FilterBlock,
        input: ConvInput<'_>,
        _acc: &mut [i32],
        out: &OutPlanes<'_>,
    ) {
        let x = input.x;
        let ihw = lp.in_hw as i64;
        for &f in &lp.exec_order[block.span.clone()] {
            // Safety: block filters are disjoint across threads.
            let o = unsafe { out.plane_mut(f) };
            o.fill(lp.bias[f]);
            vec_filter(
                lp,
                &lp.kernels[lp.filter_ranges[f].clone()],
                x,
                o,
                ihw,
                0,
                lp.out_hw,
            );
            finish_plane(lp.act, o);
        }
    }
}

/// Vectorized codelets plus two cache-level tilings driven by the
/// layer's [`KernelChoice`](super::costmodel::KernelChoice): output rows
/// in bands of `row_tile` (the input row band is revisited while hot)
/// and filters in groups of `fblock` (an output-channel block streams
/// the same input band before it is evicted).
pub struct PatternVecTiled;

impl ConvKernel for PatternVecTiled {
    fn name(&self) -> &'static str {
        "pattern-vec-tiled"
    }

    fn run_block(
        &self,
        _c: &ConvIR,
        lp: &LayerPlan,
        block: &FilterBlock,
        input: ConvInput<'_>,
        _acc: &mut [i32],
        out: &OutPlanes<'_>,
    ) {
        let x = input.x;
        let ihw = lp.in_hw as i64;
        let row_tile = (lp.choice.row_tile as usize).max(1);
        let fblock = (lp.choice.fblock as usize).max(1);
        let filters = &lp.exec_order[block.span.clone()];
        for group in filters.chunks(fblock) {
            // Safety (all three plane_mut uses): block filters are
            // disjoint across threads, and within this thread the
            // borrows are sequential — each ends before the next
            // plane_mut call.
            for &f in group {
                let o = unsafe { out.plane_mut(f) };
                o.fill(lp.bias[f]);
            }
            let mut oy0 = 0;
            while oy0 < lp.out_hw {
                let oy1 = (oy0 + row_tile).min(lp.out_hw);
                for &f in group {
                    let o = unsafe { out.plane_mut(f) };
                    vec_filter(
                        lp,
                        &lp.kernels[lp.filter_ranges[f].clone()],
                        x,
                        o,
                        ihw,
                        oy0,
                        oy1,
                    );
                }
                oy0 = oy1;
            }
            for &f in group {
                finish_plane(lp.act, unsafe { out.plane_mut(f) });
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Quantized kernels
// ---------------------------------------------------------------------------

/// Requantize one accumulated i32 plane into its f32 output plane:
/// `o = acc * s + b`, then the activation epilogue. `s` folds the
/// filter's weight scale and the input's activation scale.
#[inline]
fn requantize_plane(o: &mut [f32], acc: &[i32], s: f32, b: f32, act: Act) {
    for (ov, &av) in o.iter_mut().zip(acc) {
        *ov = av as f32 * s + b;
    }
    finish_plane(act, o);
}

/// Quantized pattern-sparse scalar kernel: the same packed-payload walk
/// as [`PatternScalar`], but taps are i8, products accumulate exactly
/// in the per-block i32 scratch, and each finished plane is requantized
/// to f32 in one pass (`acc * weight_scale * input_scale + bias`).
/// Exact integer accumulation makes the result independent of
/// evaluation order, so bit-reproducibility holds by arithmetic rather
/// than by ordering discipline (DESIGN.md §14).
pub struct QuantScalar;

impl ConvKernel for QuantScalar {
    fn name(&self) -> &'static str {
        "quant-scalar"
    }

    fn run_block(
        &self,
        _c: &ConvIR,
        lp: &LayerPlan,
        block: &FilterBlock,
        input: ConvInput<'_>,
        acc: &mut [i32],
        out: &OutPlanes<'_>,
    ) {
        let q = input
            .qx
            .expect("quantized kernel dispatched without an i8 input");
        let (taps, scales) = lp.payload.i8_taps();
        let ihw = lp.in_hw as i64;
        let ihw_sq = lp.in_hw * lp.in_hw;
        let plane = lp.out_hw * lp.out_hw;
        let acc = &mut acc[..plane];
        for &f in &lp.exec_order[block.span.clone()] {
            acc.fill(0);
            for k in &lp.kernels[lp.filter_ranges[f].clone()] {
                let ch = k.ch as usize;
                let xin = &q.data[ch * ihw_sq..(ch + 1) * ihw_sq];
                let pay = &taps[k.off as usize..];
                for (ky, row) in &lp.style_rows[k.style as usize] {
                    let dy = *ky as i64 - lp.pad;
                    for oy in 0..lp.out_hw {
                        let iy = (oy * lp.stride) as i64 + dy;
                        if iy < 0 || iy >= ihw {
                            continue;
                        }
                        let irow = iy as usize * lp.in_hw;
                        let orow = oy * lp.out_hw;
                        for (kx, slot) in row {
                            let wv = pay[*slot] as i32;
                            let dx = *kx as i64 - lp.pad;
                            let (ox0, ox1) =
                                x_range(lp.out_hw, lp.stride, dx, ihw);
                            let mut ix = (ox0 * lp.stride) as i64 + dx;
                            for ox in ox0..ox1 {
                                acc[orow + ox] +=
                                    wv * xin[irow + ix as usize] as i32;
                                ix += lp.stride as i64;
                            }
                        }
                    }
                }
            }
            // Safety: block filters are disjoint across threads.
            let o = unsafe { out.plane_mut(f) };
            requantize_plane(
                o,
                acc,
                scales[f] * q.scale,
                lp.bias[f],
                lp.act,
            );
        }
    }
}

/// Quantized vectorized kernel: the [`QuantScalar`] walk with each tap
/// streamed as a widening [`qaxpy_row`] codelet over the i32 scratch
/// (and the row-invariant output-x window hoisted per tap, as in
/// [`vec_filter`]). Same bits as [`QuantScalar`] for free: integer
/// accumulation is exact, so vector shape cannot change results.
pub struct QuantVec;

impl ConvKernel for QuantVec {
    fn name(&self) -> &'static str {
        "quant-vec"
    }

    fn run_block(
        &self,
        _c: &ConvIR,
        lp: &LayerPlan,
        block: &FilterBlock,
        input: ConvInput<'_>,
        acc: &mut [i32],
        out: &OutPlanes<'_>,
    ) {
        let q = input
            .qx
            .expect("quantized kernel dispatched without an i8 input");
        let (taps, scales) = lp.payload.i8_taps();
        let ihw = lp.in_hw as i64;
        let ihw_sq = lp.in_hw * lp.in_hw;
        let plane = lp.out_hw * lp.out_hw;
        let acc = &mut acc[..plane];
        for &f in &lp.exec_order[block.span.clone()] {
            acc.fill(0);
            for k in &lp.kernels[lp.filter_ranges[f].clone()] {
                let ch = k.ch as usize;
                let xin = &q.data[ch * ihw_sq..(ch + 1) * ihw_sq];
                let pay = &taps[k.off as usize..];
                for (ky, row) in &lp.style_rows[k.style as usize] {
                    let dy = *ky as i64 - lp.pad;
                    for (kx, slot) in row {
                        let wv = pay[*slot] as i32;
                        let dx = *kx as i64 - lp.pad;
                        let (ox0, ox1) =
                            x_range(lp.out_hw, lp.stride, dx, ihw);
                        if ox0 >= ox1 {
                            continue;
                        }
                        for oy in 0..lp.out_hw {
                            let iy = (oy * lp.stride) as i64 + dy;
                            if iy < 0 || iy >= ihw {
                                continue;
                            }
                            let irow = iy as usize * lp.in_hw;
                            let orow = oy * lp.out_hw;
                            let ix0 = (irow as i64
                                + (ox0 * lp.stride) as i64
                                + dx) as usize;
                            qaxpy_row(
                                &mut acc[orow + ox0..orow + ox1],
                                &xin[ix0..],
                                wv,
                                lp.stride,
                            );
                        }
                    }
                }
            }
            // Safety: block filters are disjoint across threads.
            let o = unsafe { out.plane_mut(f) };
            requantize_plane(
                o,
                acc,
                scales[f] * q.scale,
                lp.bias[f],
                lp.act,
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Executor
// ---------------------------------------------------------------------------

/// Dispatch one layer's filter blocks to `k`, spawning scoped workers
/// for blocks past the first (block 0 always runs on the calling
/// thread). Quantized kernels receive disjoint `qacc.len() / blocks`
/// i32 scratch chunks — each at least one output plane, because the
/// arena sizes `qacc` as `threads × max_plane` and the plan never
/// builds more blocks than threads. f32 kernels receive (and ignore)
/// empty scratch.
pub(crate) fn dispatch_blocks(
    c: &ConvIR,
    lp: &LayerPlan,
    k: &'static dyn ConvKernel,
    input: ConvInput<'_>,
    qacc: &mut [i32],
    planes: &OutPlanes<'_>,
) {
    if lp.blocks.len() <= 1 {
        if let Some(b) = lp.blocks.first() {
            k.run_block(c, lp, b, input, qacc, planes);
        }
        return;
    }
    let per = (qacc.len() / lp.blocks.len()).max(1);
    std::thread::scope(|s| {
        // `&mut []` is 'static by const promotion — the empty default
        // when qacc itself is empty (f32 dispatch)
        let mut chunks = qacc.chunks_mut(per);
        let acc0 = chunks.next().unwrap_or(&mut []);
        for b in &lp.blocks[1..] {
            let acc = chunks.next().unwrap_or(&mut []);
            s.spawn(move || k.run_block(c, lp, b, input, acc, planes));
        }
        k.run_block(c, lp, &lp.blocks[0], input, acc0, planes);
    });
}

/// Run one conv layer: resolve the kernel kind (`forced`, or the
/// layer's baked [`KernelChoice`](super::costmodel::KernelChoice) when
/// `forced` is `None`), project it onto the layer's element type via
/// [`KernelKind::for_elem`], and dispatch the plan's filter blocks.
fn run_conv(
    p: &ExecutionPlan,
    forced: Option<KernelKind>,
    layer: usize,
    input: ConvInput<'_>,
    qacc: &mut [i32],
    out: &mut [f32],
) {
    let lp = &p.layers[layer];
    let kind = forced
        .unwrap_or(lp.choice.kind)
        .for_elem(lp.payload.elem());
    let k = kernel(kind);
    let c = &p.ir.convs[lp.conv];
    let plane = lp.out_hw * lp.out_hw;
    debug_assert!(out.len() >= lp.a * plane);
    let planes = OutPlanes::new(out, plane);
    dispatch_blocks(c, lp, k, input, qacc, &planes);
}

fn max_pool2(x: Chw<'_>, out: &mut [f32]) {
    let oh = x.hw / 2;
    for ch in 0..x.c {
        let p = x.plane(ch);
        let ob = ch * oh * oh;
        for y in 0..oh {
            for xx in 0..oh {
                let i = 2 * y * x.hw + 2 * xx;
                out[ob + y * oh + xx] = p[i]
                    .max(p[i + 1])
                    .max(p[i + x.hw])
                    .max(p[i + x.hw + 1]);
            }
        }
    }
}

/// The execute phase: interprets a compiled plan over a preallocated
/// arena. Construct once, call [`Executor::execute_into`] per frame —
/// the steady-state path performs zero heap allocations
/// ([`Executor::alloc_events`] stays 0; asserted in the integration
/// tests with a counting global allocator).
pub struct Executor<'p> {
    plan: &'p ExecutionPlan,
    /// `None` = auto: per-layer dispatch through the plan's choices.
    /// Projected onto the plan's element type at dispatch time, so any
    /// selection is valid on any plan.
    kernel: Option<KernelKind>,
    arena: Arena,
}

impl<'p> Executor<'p> {
    pub fn new(plan: &'p ExecutionPlan, kind: KernelKind) -> Self {
        Executor::with_sel(plan, KernelSel::Uniform(kind))
    }

    /// Executor that dispatches each conv layer through its baked
    /// [`KernelChoice`](super::costmodel::KernelChoice).
    pub fn auto(plan: &'p ExecutionPlan) -> Self {
        Executor::with_sel(plan, KernelSel::Auto)
    }

    pub fn with_sel(plan: &'p ExecutionPlan, sel: KernelSel) -> Self {
        let forced = match sel {
            KernelSel::Uniform(kind) => Some(kind),
            KernelSel::Auto => None,
        };
        Executor {
            plan,
            kernel: forced,
            arena: Arena::for_plan(plan),
        }
    }

    pub fn plan(&self) -> &'p ExecutionPlan {
        self.plan
    }

    /// Name of the kernel that actually runs (the forced selection
    /// projected onto the plan's element type), or `"auto"`.
    pub fn kernel_name(&self) -> &'static str {
        match self.kernel {
            Some(k) => k.for_elem(self.plan.elem).name(),
            None => "auto",
        }
    }

    /// Arena growth events since construction (0 ⇔ no heap allocation on
    /// the inference path).
    pub fn alloc_events(&self) -> usize {
        self.arena.alloc_events()
    }

    /// Single-image inference into a caller-provided logits slice
    /// (`classes` long). Allocation-free after construction.
    pub fn execute_into(
        &mut self,
        img: &Fmap,
        out: &mut [f32],
    ) -> Result<()> {
        let p = self.plan;
        if img.c != p.in_dims.c || img.hw != p.in_dims.hw {
            bail!(
                "image ({}, {}hw) does not match plan input ({}, {}hw)",
                img.c,
                img.hw,
                p.in_dims.c,
                p.in_dims.hw
            );
        }
        // Fmap fields are pub, so a caller can hand us dims that disagree
        // with the buffer; a bail here beats a copy_from_slice panic
        if img.data.len() != p.in_dims.elems() {
            bail!(
                "image buffer holds {} elems, dims ({}, {}hw) need {}",
                img.data.len(),
                img.c,
                img.hw,
                p.in_dims.elems()
            );
        }
        if out.len() != p.ir.classes {
            bail!(
                "logits slice len {} != {} classes",
                out.len(),
                p.ir.classes
            );
        }
        let kernel = self.kernel;
        let a = &mut self.arena;
        a.ping
            .slice_mut(p.in_dims.elems())
            .copy_from_slice(&img.data);
        let mut cur_ping = true;
        let mut cur = p.in_dims;
        for (step, &after) in p.steps.iter().zip(&p.dims) {
            match step {
                PlanStep::Conv { layer } => {
                    let lp = &p.layers[*layer];
                    let (src, dst) = if cur_ping {
                        (&a.ping, &mut a.pong)
                    } else {
                        (&a.pong, &mut a.ping)
                    };
                    let n = lp.c * lp.in_hw * lp.in_hw;
                    let x = Chw::new(lp.c, lp.in_hw, src.slice(n));
                    let qx = if p.elem == ElemType::I8 {
                        let scale = quantize_activations(
                            x.data,
                            &mut a.qin[..n],
                        );
                        Some(QuantView {
                            data: &a.qin[..n],
                            scale,
                        })
                    } else {
                        None
                    };
                    run_conv(
                        p,
                        kernel,
                        *layer,
                        ConvInput { x, qx },
                        &mut a.qacc,
                        dst.slice_mut(lp.out_elems()),
                    );
                    cur_ping = !cur_ping;
                }
                PlanStep::Pool => {
                    let (src, dst) = if cur_ping {
                        (&a.ping, &mut a.pong)
                    } else {
                        (&a.pong, &mut a.ping)
                    };
                    let x = Chw::new(cur.c, cur.hw, src.slice(cur.elems()));
                    max_pool2(x, dst.slice_mut(after.elems()));
                    cur_ping = !cur_ping;
                }
                PlanStep::Save { slot } => {
                    let n = cur.elems();
                    let src = if cur_ping { &a.ping } else { &a.pong };
                    a.slots[*slot]
                        .slice_mut(n)
                        .copy_from_slice(src.slice(n));
                }
                PlanStep::Proj { layer, slot } => {
                    let lp = &p.layers[*layer];
                    let n = lp.c * lp.in_hw * lp.in_hw;
                    let x =
                        Chw::new(lp.c, lp.in_hw, a.slots[*slot].slice(n));
                    let qx = if p.elem == ElemType::I8 {
                        let scale = quantize_activations(
                            x.data,
                            &mut a.qin[..n],
                        );
                        Some(QuantView {
                            data: &a.qin[..n],
                            scale,
                        })
                    } else {
                        None
                    };
                    run_conv(
                        p,
                        kernel,
                        *layer,
                        ConvInput { x, qx },
                        &mut a.qacc,
                        a.proj_scratch.slice_mut(lp.out_elems()),
                    );
                    let n = lp.out_elems();
                    let s = &a.proj_scratch;
                    a.slots[*slot]
                        .slice_mut(n)
                        .copy_from_slice(s.slice(n));
                }
                PlanStep::Add { slot } => {
                    let n = cur.elems();
                    let dst = if cur_ping { &mut a.ping } else { &mut a.pong };
                    let d = dst.slice_mut(n);
                    let s = a.slots[*slot].slice(n);
                    for (x, y) in d.iter_mut().zip(s) {
                        *x += y;
                    }
                }
                PlanStep::Relu => {
                    let dst = if cur_ping { &mut a.ping } else { &mut a.pong };
                    for v in dst.slice_mut(cur.elems()).iter_mut() {
                        *v = v.max(0.0);
                    }
                }
                PlanStep::Gap => {
                    let src = if cur_ping { &a.ping } else { &a.pong };
                    let x = Chw::new(cur.c, cur.hw, src.slice(cur.elems()));
                    let g = a.gap.slice_mut(cur.c);
                    let inv = 1.0 / (cur.hw * cur.hw) as f32;
                    for (ch, gv) in g.iter_mut().enumerate() {
                        *gv = x.plane(ch).iter().sum::<f32>() * inv;
                    }
                }
                PlanStep::Fc => {
                    let cdim = p.ir.fc_w.cols();
                    let g = &a.gap.slice(p.gap_len)[..cdim];
                    for (k, l) in out.iter_mut().enumerate() {
                        let row = p.ir.fc_w.row(k);
                        *l = p.ir.fc_b.data()[k]
                            + row
                                .iter()
                                .zip(g)
                                .map(|(w, v)| w * v)
                                .sum::<f32>();
                    }
                    return Ok(());
                }
            }
            cur = after;
        }
        bail!("plan has no fc step")
    }

    /// Single-image inference; returns freshly allocated class logits
    /// (convenience wrapper — use [`Executor::execute_into`] on the
    /// allocation-free path).
    pub fn execute(&mut self, img: &Fmap) -> Vec<f32> {
        let mut out = vec![0.0f32; self.plan.ir.classes];
        self.execute_into(img, &mut out)
            .expect("image does not match plan");
        out
    }

    /// Sequential batch entry point: amortizes the arena across frames.
    /// Errs (instead of panicking) on an empty batch or any image whose
    /// dims do not match the plan input.
    pub fn execute_batch(
        &mut self,
        imgs: &[Fmap],
    ) -> Result<Vec<Vec<f32>>> {
        if imgs.is_empty() {
            bail!("execute_batch: empty batch");
        }
        let classes = self.plan.ir.classes;
        let mut out = Vec::with_capacity(imgs.len());
        for (i, img) in imgs.iter().enumerate() {
            let mut logits = vec![0.0f32; classes];
            self.execute_into(img, &mut logits)
                .with_context(|| format!("batch image {i}"))?;
            out.push(logits);
        }
        Ok(out)
    }
}

/// Throughput entry point: shard `imgs` across `workers` scoped threads,
/// each with its own executor (one arena allocation per worker per call).
/// Compile the plan with `threads = 1` for this mode so per-layer and
/// per-image parallelism do not multiply. Errs on an empty batch or any
/// image whose dims do not match the plan input (checked up front, so no
/// worker starts on a doomed batch).
pub fn execute_batch_parallel(
    plan: &ExecutionPlan,
    kind: impl Into<KernelSel>,
    imgs: &[Fmap],
    workers: usize,
) -> Result<Vec<Vec<f32>>> {
    let sel = kind.into();
    if imgs.is_empty() {
        bail!("execute_batch_parallel: empty batch");
    }
    for (i, img) in imgs.iter().enumerate() {
        if img.c != plan.in_dims.c
            || img.hw != plan.in_dims.hw
            || img.data.len() != plan.in_dims.elems()
        {
            bail!(
                "batch image {i} ({}, {}hw, {} elems) does not match \
                 plan input ({}, {}hw, {} elems)",
                img.c,
                img.hw,
                img.data.len(),
                plan.in_dims.c,
                plan.in_dims.hw,
                plan.in_dims.elems()
            );
        }
    }
    let w = workers.max(1).min(imgs.len());
    if w <= 1 {
        return Executor::with_sel(plan, sel).execute_batch(imgs);
    }
    let chunk = imgs.len().div_ceil(w);
    let mut results: Vec<Result<Vec<Vec<f32>>>> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = imgs
            .chunks(chunk)
            .map(|ch| {
                s.spawn(move || {
                    Executor::with_sel(plan, sel).execute_batch(ch)
                })
            })
            .collect();
        results = handles
            .into_iter()
            .map(|h| h.join().expect("batch worker panicked"))
            .collect();
    });
    let mut out = Vec::with_capacity(imgs.len());
    for r in results {
        out.extend(r?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Compatibility surface (pre-split API)
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// dense direct conv (baseline frameworks' shape)
    Dense,
    /// compressed pattern-aware execution (our compiler's output)
    Sparse,
}

impl EngineKind {
    pub fn kernel(self) -> KernelKind {
        match self {
            EngineKind::Dense => KernelKind::DenseRef,
            EngineKind::Sparse => KernelKind::PatternScalar,
        }
    }
}

/// Compiled model: a single-threaded [`ExecutionPlan`] (compatibility
/// wrapper around [`plan::compile_plan`]).
pub struct CompiledModel {
    pub plan: ExecutionPlan,
}

impl CompiledModel {
    pub fn report(&self) -> &super::passes::CompileReport {
        &self.plan.report
    }
}

/// Run the compiler passes over a model IR (single-threaded plan).
pub fn compile(ir: ModelIR) -> CompiledModel {
    CompiledModel {
        plan: plan::compile_plan(ir, 1).expect("IR schedule does not lower"),
    }
}

/// Single-image inference; returns class logits. Convenience wrapper that
/// builds a fresh executor per call — latency-sensitive callers should
/// hold an [`Executor`].
pub fn infer(m: &CompiledModel, image: &Fmap, kind: EngineKind) -> Vec<f32> {
    Executor::new(&m.plan, kind.kernel()).execute(image)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::util::propcheck::check;

    #[test]
    fn x_range_covers_valid_indices() {
        // small ihw with stride 2 exercises the negative-numerator floor
        // (in=2, k=3, s=2 ⇒ dx=2 ≥ ihw: ox1 must be 0, not 1)
        for ihw in 1..=9i64 {
            for stride in 1..=2usize {
                for dx in -2i64..=2 {
                    let out_hw = (ihw as usize).div_ceil(stride);
                    let (ox0, ox1) = x_range(out_hw, stride, dx, ihw);
                    for ox in 0..out_hw {
                        let ix = (ox * stride) as i64 + dx;
                        let valid = ix >= 0 && ix < ihw;
                        let inside = ox >= ox0 && ox < ox1;
                        assert_eq!(
                            valid, inside,
                            "ihw={ihw} s={stride} dx={dx} ox={ox}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn kernel_registry_roundtrip() {
        for kind in KERNEL_KINDS {
            assert_eq!(kernel(kind).name(), kind.name());
        }
        for kind in QUANT_KERNEL_KINDS {
            assert_eq!(kernel(kind).name(), kind.name());
        }
        assert_eq!(
            KernelKind::parse("sparse").unwrap(),
            KernelKind::PatternScalar
        );
        assert_eq!(
            KernelKind::parse("tiled").unwrap(),
            KernelKind::PatternTiled
        );
        assert_eq!(
            KernelKind::parse("quant").unwrap(),
            KernelKind::QuantScalar
        );
        assert_eq!(
            KernelKind::parse("quant-vec").unwrap(),
            KernelKind::QuantVec
        );
        assert!(KernelKind::parse("simd").is_err());
        assert_eq!(EngineKind::Dense.kernel(), KernelKind::DenseRef);
        assert_eq!(EngineKind::Sparse.kernel(), KernelKind::PatternScalar);
        // element projection: identity on matching elem, total otherwise
        for kind in KERNEL_KINDS {
            assert_eq!(kind.for_elem(ElemType::F32), kind);
            let qk = kind.for_elem(ElemType::I8);
            assert!(
                QUANT_KERNEL_KINDS.contains(&qk),
                "{kind:?} -> {qk:?}"
            );
        }
        assert_eq!(
            KernelKind::PatternVec.for_elem(ElemType::I8),
            KernelKind::QuantVec
        );
        assert_eq!(
            KernelKind::DenseRef.for_elem(ElemType::I8),
            KernelKind::QuantScalar
        );
        assert_eq!(
            KernelKind::QuantVec.for_elem(ElemType::F32),
            KernelKind::PatternVec
        );
        assert_eq!(
            KernelKind::QuantScalar.for_elem(ElemType::F32),
            KernelKind::PatternScalar
        );
    }

    /// Run `kind` (projected onto the layer's element type) over every
    /// block of a standalone layer plan, quantizing the input when the
    /// payload is i8.
    fn run_kernel_full(
        kind: KernelKind,
        c: &ConvIR,
        lp: &LayerPlan,
        x: Chw<'_>,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; lp.out_elems()];
        let plane = lp.out_hw * lp.out_hw;
        let planes = OutPlanes::new(&mut out, plane);
        let mut qbuf = vec![0i8; x.data.len()];
        let qx = match lp.payload.elem() {
            ElemType::F32 => None,
            ElemType::I8 => {
                let scale = quantize_activations(x.data, &mut qbuf);
                Some(QuantView {
                    data: &qbuf,
                    scale,
                })
            }
        };
        let input = ConvInput { x, qx };
        let mut acc = vec![0i32; plane];
        let k = kernel(kind.for_elem(lp.payload.elem()));
        for b in &lp.blocks {
            k.run_block(c, lp, b, input, &mut acc, &planes);
        }
        out
    }

    fn random_pruned_conv(
        rng: &mut Pcg32,
        a: usize,
        cch: usize,
        ksz: usize,
        stride: usize,
        in_hw: usize,
    ) -> ConvIR {
        let ks = ksz * ksz;
        let mut w = Tensor::zeros(&[a, cch, ksz, ksz]);
        let mut pattern = Vec::with_capacity(a * cch);
        for ki in 0..a * cch {
            let mut p: u16 = 0;
            // ~20% of kernels fully connectivity-pruned (pattern = 0)
            if rng.below(5) != 0 {
                for t in 0..ks {
                    if rng.below(2) == 1 {
                        p |= 1 << t;
                    }
                }
            }
            for t in 0..ks {
                if p & (1 << t) != 0 {
                    w.data_mut()[ki * ks + t] = rng.normal();
                }
            }
            pattern.push(p);
        }
        let (out_hw, _) = same_pad_lo(in_hw, ksz, stride);
        let act = if rng.below(2) == 0 { Act::Relu } else { Act::None };
        let bias: Vec<f32> = (0..a).map(|_| rng.normal()).collect();
        ConvIR {
            op_idx: 0,
            a,
            c: cch,
            kh: ksz,
            kw: ksz,
            stride,
            act,
            in_hw,
            out_hw,
            w,
            bias: Tensor::from_vec(&[a], bias).unwrap(),
            pattern,
            tag: String::new(),
            is_proj: false,
        }
    }

    /// Property (paper §V-C semantics preservation): every planned
    /// sparse kernel — scalar, tiled, and both vectorized variants —
    /// reproduces the dense reference *exactly* across randomized
    /// pattern masks, strides {1,2}, kernel sizes {1,3}, fully-pruned
    /// (pattern = 0) kernels, and fmap widths that do not divide the
    /// lane width (the vectorized codelets' scalar tail).
    ///
    /// Exact `==` is the right bar: per output element every kernel
    /// accumulates taps in the same kernel → row → tap order with the
    /// same separate-multiply-then-add rounding, and the dense
    /// reference only adds extra `0.0 * x` terms for pruned taps —
    /// which can flip the sign of a zero but never change a value.
    #[test]
    fn prop_sparse_kernels_match_dense_reference() {
        check("sparse-vs-dense-kernels", 2024, 60, 8, |g| {
            let ksz = if g.rng.below(2) == 0 { 1 } else { 3 };
            let stride = 1 + g.rng.below(2);
            let a = g.dim_up_to(6);
            let cch = g.dim_up_to(4);
            // up to 21: well past LANES, and usually not a multiple of it
            let in_hw = 2 + g.rng.below(20);
            let c = random_pruned_conv(g.rng, a, cch, ksz, stride, in_hw);
            let threads = 1 + g.rng.below(3);
            let lp = LayerPlan::for_conv(&c, threads);
            let xdata = g.vec_f32(cch * in_hw * in_hw);
            let x = Chw::new(cch, in_hw, &xdata);
            let dense = run_kernel_full(KernelKind::DenseRef, &c, &lp, x);
            for kind in [
                KernelKind::PatternScalar,
                KernelKind::PatternTiled,
                KernelKind::PatternVec,
                KernelKind::PatternVecTiled,
            ] {
                let got = run_kernel_full(kind, &c, &lp, x);
                for (i, (ge, de)) in got.iter().zip(&dense).enumerate() {
                    if ge != de {
                        return Err(format!(
                            "{:?} diverges at {i}: {ge} vs {de} \
                             (k={ksz} s={stride} a={a} c={cch} hw={in_hw})",
                            kind
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// Property (the autotuner's determinism story): kernel choice is a
    /// pure shape decision. All four pattern kernels produce
    /// bit-identical output planes for *any* (row_tile, fblock) tile
    /// shape, so autotuning can swap kernels and tiles freely without
    /// ever changing results.
    #[test]
    fn prop_pattern_kernels_bit_identical() {
        check("pattern-kernels-bit-identical", 777, 50, 8, |g| {
            let ksz = if g.rng.below(2) == 0 { 1 } else { 3 };
            let stride = 1 + g.rng.below(2);
            let a = g.dim_up_to(6);
            let cch = g.dim_up_to(4);
            let in_hw = 2 + g.rng.below(20);
            let c = random_pruned_conv(g.rng, a, cch, ksz, stride, in_hw);
            let threads = 1 + g.rng.below(3);
            let mut lp = LayerPlan::for_conv(&c, threads);
            // adversarial tile shapes, including degenerate 1x1 tiles
            // and tiles larger than the plane
            lp.choice.row_tile =
                1 + g.rng.below(2 * lp.out_hw + 1) as u16;
            lp.choice.fblock = 1 + g.rng.below(a + 2) as u16;
            let xdata = g.vec_f32(cch * in_hw * in_hw);
            let x = Chw::new(cch, in_hw, &xdata);
            let want =
                run_kernel_full(KernelKind::PatternScalar, &c, &lp, x);
            for kind in [
                KernelKind::PatternTiled,
                KernelKind::PatternVec,
                KernelKind::PatternVecTiled,
            ] {
                let got = run_kernel_full(kind, &c, &lp, x);
                for (i, (ge, we)) in got.iter().zip(&want).enumerate() {
                    if ge.to_bits() != we.to_bits() {
                        return Err(format!(
                            "{:?} bit-drifts at {i}: {ge:?} vs {we:?} \
                             (rt={} fb={} k={ksz} s={stride} hw={in_hw})",
                            kind, lp.choice.row_tile, lp.choice.fblock
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// Property (DESIGN.md §14): the quantized kernels agree bit for
    /// bit with each other — exact i32 accumulation makes the result
    /// order-free, so vector shape cannot drift — and track the f32
    /// dense reference within the per-filter rounding bound
    /// `ntaps(f) · 127 · w_scale(f) · x_scale` (half-ulp weight and
    /// activation rounding per tap), with slack for the f32
    /// requantize multiply.
    #[test]
    fn prop_quant_kernels_bit_identical_and_track_f32() {
        check("quant-kernels", 4242, 50, 8, |g| {
            let ksz = if g.rng.below(2) == 0 { 1 } else { 3 };
            let stride = 1 + g.rng.below(2);
            let a = g.dim_up_to(6);
            let cch = g.dim_up_to(4);
            let in_hw = 2 + g.rng.below(20);
            let c = random_pruned_conv(g.rng, a, cch, ksz, stride, in_hw);
            let threads = 1 + g.rng.below(3);
            let lp = LayerPlan::for_conv(&c, threads);
            let mut qlp = LayerPlan::for_conv(&c, threads);
            qlp.quantize();
            let xdata = g.vec_f32(cch * in_hw * in_hw);
            let x = Chw::new(cch, in_hw, &xdata);
            let dense = run_kernel_full(KernelKind::DenseRef, &c, &lp, x);
            let qs =
                run_kernel_full(KernelKind::QuantScalar, &c, &qlp, x);
            let qv = run_kernel_full(KernelKind::QuantVec, &c, &qlp, x);
            for (i, (sv, vv)) in qs.iter().zip(&qv).enumerate() {
                if sv.to_bits() != vv.to_bits() {
                    return Err(format!(
                        "quant-vec bit-drifts at {i}: {vv:?} vs {sv:?} \
                         (k={ksz} s={stride} a={a} c={cch} hw={in_hw})"
                    ));
                }
            }
            let mut xmax = 0.0f32;
            for &v in &xdata {
                xmax = xmax.max(v.abs());
            }
            let x_scale = if xmax > 0.0 { xmax / 127.0 } else { 1.0 };
            let (_, scales) = qlp.payload.i8_taps();
            let plane = qlp.out_hw * qlp.out_hw;
            for f in 0..a {
                let mut ntaps = 0usize;
                for k in &qlp.kernels[qlp.filter_ranges[f].clone()] {
                    ntaps += qlp.styles[k.style as usize].count_ones()
                        as usize;
                }
                let bound = ntaps as f32 * 127.0 * scales[f] * x_scale
                    * 1.5
                    + 1e-4;
                for i in 0..plane {
                    let d = (qs[f * plane + i] - dense[f * plane + i])
                        .abs();
                    if d > bound {
                        return Err(format!(
                            "filter {f} elem {i}: |Δ|={d} > {bound} \
                             (ntaps={ntaps})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// End-to-end parity across all four pruning schemes: a model pruned
    /// with each scheme compiles and executes identically under every
    /// pattern kernel (bit-identical logits vs the scalar kernel, exact
    /// equality vs dense).
    #[test]
    fn all_pruning_schemes_execute_identically_across_kernels() {
        use crate::mobile::synth;
        use crate::pruning::Scheme;
        for scheme in [
            Scheme::Irregular,
            Scheme::Filter,
            Scheme::Column,
            Scheme::Pattern,
        ] {
            let (spec, mut params) =
                synth::vgg_style("parity_vgg", 12, 5, &[4, 6], 17);
            synth::scheme_prune(&spec, &mut params, scheme, 0.3);
            let ir = ModelIR::build(&spec, &params).unwrap();
            let p = plan::compile_plan(ir, 2).unwrap();
            let mut rng = Pcg32::seeded(99);
            let mut img = Fmap::zeros(p.in_dims.c, p.in_dims.hw);
            for v in img.data.iter_mut() {
                *v = rng.normal();
            }
            let dense =
                Executor::new(&p, KernelKind::DenseRef).execute(&img);
            let want =
                Executor::new(&p, KernelKind::PatternScalar).execute(&img);
            assert_eq!(
                dense,
                want,
                "{}: scalar vs dense",
                scheme.name()
            );
            for kind in [
                KernelKind::PatternTiled,
                KernelKind::PatternVec,
                KernelKind::PatternVecTiled,
            ] {
                let got = Executor::new(&p, kind).execute(&img);
                assert_eq!(
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "{}: {} vs scalar",
                    scheme.name(),
                    kind.name()
                );
            }
            // per-layer auto dispatch is one of the above kernels per
            // layer, so it must land on the same bits too
            let auto = Executor::auto(&p).execute(&img);
            assert_eq!(
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                auto.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{}: auto vs scalar",
                scheme.name()
            );
        }
    }

    /// A fully connectivity-pruned layer (every pattern = 0) must still
    /// produce bias+activation planes, identically to dense-over-zeros.
    #[test]
    fn fully_pruned_layer_yields_bias_planes() {
        let mut rng = Pcg32::seeded(77);
        let mut c = random_pruned_conv(&mut rng, 4, 3, 3, 1, 6);
        c.w = Tensor::zeros(&[4, 3, 3, 3]);
        c.pattern = vec![0; 12];
        let lp = LayerPlan::for_conv(&c, 2);
        let xdata: Vec<f32> = (0..3 * 36).map(|_| rng.normal()).collect();
        let x = Chw::new(3, 6, &xdata);
        let dense = run_kernel_full(KernelKind::DenseRef, &c, &lp, x);
        let sparse =
            run_kernel_full(KernelKind::PatternScalar, &c, &lp, x);
        assert_eq!(dense, sparse);
        for (f, plane) in sparse.chunks(36).enumerate() {
            let want = match c.act {
                Act::Relu => c.bias.data()[f].max(0.0),
                Act::None => c.bias.data()[f],
            };
            assert!(plane.iter().all(|&v| v == want));
        }
    }
}
