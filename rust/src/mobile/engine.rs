//! Mobile execution engines: a dense reference executor and the
//! pattern-aware sparse executor that consumes the compiler's output
//! (compressed storage + filter reorder + row-grouped inner loops).
//!
//! Both run real single-image (batch-1, the mobile latency setting)
//! inference on host buffers. Numerics are verified against the PJRT
//! `fwd_eval` artifact in rust/tests/mobile_integration.rs, so the
//! compiler passes are provably semantics-preserving.

use anyhow::{bail, Result};

use crate::config::Act;
use crate::tensor::Tensor;

use super::ir::{CompressedLayer, ConvIR, IrOp, ModelIR};
use super::passes;

/// Row-grouped taps of one pattern style: [(ky, [(kx, payload_slot)])].
pub type StyleRows = Vec<(usize, Vec<(usize, usize)>)>;

/// Padding per JAX 'SAME': out = ceil(in/s); lo = pad_total/2.
pub fn same_pad_lo(in_hw: usize, k: usize, stride: usize) -> (usize, i64) {
    let out = in_hw.div_ceil(stride);
    let pad_total =
        ((out - 1) * stride + k).saturating_sub(in_hw);
    (out, (pad_total / 2) as i64)
}

/// Feature map: (C, H, W) row-major.
#[derive(Clone, Debug)]
pub struct Fmap {
    pub c: usize,
    pub hw: usize,
    pub data: Vec<f32>,
}

impl Fmap {
    pub fn zeros(c: usize, hw: usize) -> Self {
        Fmap {
            c,
            hw,
            data: vec![0.0; c * hw * hw],
        }
    }

    pub fn from_tensor_chw(t: &Tensor) -> Result<Self> {
        let s = t.shape();
        if s.len() != 3 || s[1] != s[2] {
            bail!("expected (C,H,H) tensor, got {s:?}");
        }
        Ok(Fmap {
            c: s[0],
            hw: s[1],
            data: t.data().to_vec(),
        })
    }

    #[inline]
    pub fn plane(&self, ch: usize) -> &[f32] {
        &self.data[ch * self.hw * self.hw..(ch + 1) * self.hw * self.hw]
    }
}

fn apply_act(act: Act, buf: &mut [f32]) {
    if act == Act::Relu {
        for v in buf {
            *v = v.max(0.0);
        }
    }
}

/// Dense direct convolution (the baseline engines' compute shape).
pub fn conv_dense(c: &ConvIR, x: &Fmap) -> Fmap {
    debug_assert_eq!(x.c, c.c);
    debug_assert_eq!(x.hw, c.in_hw);
    let (out_hw, pad) = same_pad_lo(c.in_hw, c.kh, c.stride);
    debug_assert_eq!(out_hw, c.out_hw);
    let mut out = Fmap::zeros(c.a, out_hw);
    let ihw = x.hw as i64;
    for f in 0..c.a {
        let obase = f * out_hw * out_hw;
        out.data[obase..obase + out_hw * out_hw]
            .fill(c.bias.data()[f]);
        for ch in 0..c.c {
            let plane = x.plane(ch);
            let wbase = (f * c.c + ch) * c.kh * c.kw;
            for ky in 0..c.kh {
                for kx in 0..c.kw {
                    let wv = c.w.data()[wbase + ky * c.kw + kx];
                    if wv == 0.0 {
                        // dense engines do the multiply anyway; keeping it
                        // branchless here matters only for timing, and the
                        // cost model charges dense MACs regardless.
                    }
                    for oy in 0..out_hw {
                        let iy = (oy * c.stride) as i64 + ky as i64 - pad;
                        if iy < 0 || iy >= ihw {
                            continue;
                        }
                        let irow = (iy as usize) * x.hw;
                        let orow = obase + oy * out_hw;
                        for ox in 0..out_hw {
                            let ix =
                                (ox * c.stride) as i64 + kx as i64 - pad;
                            if ix < 0 || ix >= ihw {
                                continue;
                            }
                            out.data[orow + ox] +=
                                wv * plane[irow + ix as usize];
                        }
                    }
                }
            }
        }
    }
    apply_act(c.act, &mut out.data);
    out
}

/// Pattern-aware sparse convolution: executes the compressed form, filters
/// visited in the compiler's reordered schedule, taps grouped by input row
/// (the load-redundancy-eliminated codelet shape).
pub fn conv_sparse(
    c: &ConvIR,
    comp: &CompressedLayer,
    exec_order: &[usize],
    x: &Fmap,
) -> Fmap {
    debug_assert_eq!(x.c, c.c);
    let (out_hw, pad) = same_pad_lo(c.in_hw, c.kh, c.stride);
    let mut out = Fmap::zeros(c.a, out_hw);
    let ihw = x.hw as i64;
    // Pre-split every pattern style into row-grouped taps:
    // style -> [(ky, [(kx, payload_slot)])]
    let style_rows: Vec<StyleRows> = comp
        .styles
        .iter()
        .map(|&pat| passes::row_group(pat, c.kh, c.kw))
        .collect();
    for &f in exec_order {
        let obase = f * out_hw * out_hw;
        out.data[obase..obase + out_hw * out_hw].fill(comp.bias[f]);
        for (ch, style, payload) in &comp.filters[f] {
            let plane = x.plane(*ch as usize);
            for (ky, taps) in &style_rows[*style as usize] {
                for oy in 0..out_hw {
                    let iy =
                        (oy * c.stride) as i64 + *ky as i64 - pad;
                    if iy < 0 || iy >= ihw {
                        continue;
                    }
                    let irow = (iy as usize) * x.hw;
                    let orow = obase + oy * out_hw;
                    // row codelet: all taps of this row share the input
                    // row (one load stream instead of popcount streams)
                    for (kx, slot) in taps {
                        let wv = payload[*slot];
                        let dx = *kx as i64 - pad;
                        // interior fast path without per-x bounds checks
                        let (ox0, ox1) = x_range(
                            out_hw, c.stride, dx, ihw,
                        );
                        let mut ix =
                            (ox0 * c.stride) as i64 + dx;
                        for ox in ox0..ox1 {
                            out.data[orow + ox] +=
                                wv * plane[irow + ix as usize];
                            ix += c.stride as i64;
                        }
                    }
                }
            }
        }
    }
    apply_act(c.act, &mut out.data);
    out
}

/// Valid output-x range for which ix = ox*stride + dx lies in [0, ihw).
#[inline]
fn x_range(out_hw: usize, stride: usize, dx: i64, ihw: i64) -> (usize, usize) {
    // smallest ox with ox*stride + dx >= 0
    let ox0 = if dx >= 0 {
        0
    } else {
        ((-dx) as usize).div_ceil(stride)
    };
    // largest ox with ox*stride + dx < ihw
    let mut ox1 = out_hw;
    if (out_hw as i64 - 1) * stride as i64 + dx >= ihw {
        ox1 = ((ihw - dx - 1) / stride as i64 + 1).max(0) as usize;
    }
    (ox0.min(out_hw), ox1.min(out_hw))
}

fn max_pool2(x: &Fmap) -> Fmap {
    let oh = x.hw / 2;
    let mut out = Fmap::zeros(x.c, oh);
    for ch in 0..x.c {
        let p = x.plane(ch);
        let ob = ch * oh * oh;
        for y in 0..oh {
            for xx in 0..oh {
                let i = 2 * y * x.hw + 2 * xx;
                out.data[ob + y * oh + xx] = p[i]
                    .max(p[i + 1])
                    .max(p[i + x.hw])
                    .max(p[i + x.hw + 1]);
            }
        }
    }
    out
}

/// Compiled model: IR + per-layer compressed weights + execution schedule.
pub struct CompiledModel {
    pub ir: ModelIR,
    pub compressed: Vec<CompressedLayer>,
    pub exec_order: Vec<Vec<usize>>,
    pub report: passes::CompileReport,
}

/// Run the three compiler passes over a model IR.
pub fn compile(ir: ModelIR) -> CompiledModel {
    let compressed: Vec<CompressedLayer> =
        ir.convs.iter().map(CompressedLayer::compress).collect();
    let exec_order: Vec<Vec<usize>> = ir
        .convs
        .iter()
        .map(passes::reorder_filters)
        .collect();
    let report = passes::CompileReport::build(&ir, &compressed, &exec_order);
    CompiledModel {
        ir,
        compressed,
        exec_order,
        report,
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// dense direct conv (baseline frameworks' shape)
    Dense,
    /// compressed pattern-aware execution (our compiler's output)
    Sparse,
}

/// Single-image inference; returns class logits.
pub fn infer(m: &CompiledModel, image: &Fmap, kind: EngineKind) -> Vec<f32> {
    let mut saved: std::collections::HashMap<String, Fmap> =
        std::collections::HashMap::new();
    let mut t = image.clone();
    let mut gap: Vec<f32> = Vec::new();
    for op in &m.ir.ops {
        match op {
            IrOp::Conv(ci) => {
                let c = &m.ir.convs[*ci];
                t = match kind {
                    EngineKind::Dense => conv_dense(c, &t),
                    EngineKind::Sparse => conv_sparse(
                        c,
                        &m.compressed[*ci],
                        &m.exec_order[*ci],
                        &t,
                    ),
                };
            }
            IrOp::Proj(ci) => {
                let c = &m.ir.convs[*ci];
                let src = saved.get(&c.tag).expect("saved fmap").clone();
                let proj = match kind {
                    EngineKind::Dense => conv_dense(c, &src),
                    EngineKind::Sparse => conv_sparse(
                        c,
                        &m.compressed[*ci],
                        &m.exec_order[*ci],
                        &src,
                    ),
                };
                saved.insert(c.tag.clone(), proj);
            }
            IrOp::Pool => t = max_pool2(&t),
            IrOp::Save { tag } => {
                saved.insert(tag.clone(), t.clone());
            }
            IrOp::Add { tag } => {
                let s = &saved[tag];
                for (a, b) in t.data.iter_mut().zip(&s.data) {
                    *a += b;
                }
            }
            IrOp::Relu => apply_act(Act::Relu, &mut t.data),
            IrOp::Gap => {
                gap = (0..t.c)
                    .map(|ch| {
                        t.plane(ch).iter().sum::<f32>()
                            / (t.hw * t.hw) as f32
                    })
                    .collect();
            }
            IrOp::Fc => {
                let cls = m.ir.classes;
                let cdim = m.ir.fc_w.cols();
                let mut logits = vec![0.0f32; cls];
                for (k, l) in logits.iter_mut().enumerate() {
                    let row = m.ir.fc_w.row(k);
                    *l = m.ir.fc_b.data()[k]
                        + row
                            .iter()
                            .zip(&gap[..cdim])
                            .map(|(w, g)| w * g)
                            .sum::<f32>();
                }
                return logits;
            }
        }
    }
    panic!("model has no fc head");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_pad_matches_jax() {
        // (in, k, s) -> (out, pad_lo) spot-checked against jax SAME
        assert_eq!(same_pad_lo(16, 3, 1), (16, 1));
        assert_eq!(same_pad_lo(16, 3, 2), (8, 0));
        assert_eq!(same_pad_lo(8, 3, 2), (4, 0));
        assert_eq!(same_pad_lo(16, 1, 1), (16, 0));
        assert_eq!(same_pad_lo(16, 1, 2), (8, 0));
        assert_eq!(same_pad_lo(15, 3, 2), (8, 1));
    }

    #[test]
    fn x_range_covers_valid_indices() {
        for stride in 1..=2usize {
            for dx in -2i64..=2 {
                let ihw = 9i64;
                let out_hw = 9usize.div_ceil(stride);
                let (ox0, ox1) = x_range(out_hw, stride, dx, ihw);
                for ox in 0..out_hw {
                    let ix = (ox * stride) as i64 + dx;
                    let valid = ix >= 0 && ix < ihw;
                    let inside = ox >= ox0 && ox < ox1;
                    assert_eq!(valid, inside, "s={stride} dx={dx} ox={ox}");
                }
            }
        }
    }
}
