//! Layer-wise weight IR for the mobile compiler.
//!
//! A [`ModelIR`] is extracted from a (possibly pruned) parameter set plus
//! the manifest op list; each conv layer records, per kernel, its pattern
//! style (9-bit tap bitmask) and connectivity status — "a layer-wise weight
//! representation incorporating information of layer shape, pattern style,
//! connectivity status, etc." (paper §V-C).

use anyhow::{bail, Result};

use crate::config::{Act, ConvOp, ModelSpec, Op};
use crate::tensor::Tensor;

/// One convolution layer in compiler form.
#[derive(Clone, Debug)]
pub struct ConvIR {
    /// op index in the model spec
    pub op_idx: usize,
    pub a: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub act: Act,
    pub in_hw: usize,
    pub out_hw: usize,
    /// dense weights, (A, C, kh, kw) row-major
    pub w: Tensor,
    pub bias: Tensor,
    /// per (filter, channel) kernel: tap bitmask (bit t = tap kept);
    /// 0 ⇒ kernel pruned away entirely (connectivity pruning)
    pub pattern: Vec<u16>,
    /// residual tag for proj layers ("" for main-path convs)
    pub tag: String,
    pub is_proj: bool,
}

impl ConvIR {
    pub fn kernel_size(&self) -> usize {
        self.kh * self.kw
    }

    pub fn n_kernels(&self) -> usize {
        self.a * self.c
    }

    pub fn kept_kernels(&self) -> usize {
        self.pattern.iter().filter(|&&p| p != 0).count()
    }

    /// MACs actually executed by the sparse engine.
    pub fn sparse_macs(&self) -> usize {
        let per_pos: usize = self
            .pattern
            .iter()
            .map(|p| p.count_ones() as usize)
            .sum();
        per_pos * self.out_hw * self.out_hw
    }

    pub fn dense_macs(&self) -> usize {
        self.a * self.c * self.kernel_size() * self.out_hw * self.out_hw
    }

    fn extract_pattern(w: &Tensor, a: usize, c: usize, ks: usize) -> Vec<u16> {
        (0..a * c)
            .map(|ki| {
                let base = ki * ks;
                (0..ks).fold(0u16, |m, t| {
                    if w.data()[base + t] != 0.0 {
                        m | (1 << t)
                    } else {
                        m
                    }
                })
            })
            .collect()
    }

    fn from_op(op_idx: usize, op: &ConvOp, params: &[Tensor], is_proj: bool) -> Self {
        let w = params[op.w].clone();
        let ks = op.kh * op.kw;
        let pattern = Self::extract_pattern(&w, op.a, op.c, ks);
        ConvIR {
            op_idx,
            a: op.a,
            c: op.c,
            kh: op.kh,
            kw: op.kw,
            stride: op.stride,
            act: op.act,
            in_hw: op.in_hw,
            out_hw: op.out_hw,
            w,
            bias: params[op.b].clone(),
            pattern,
            tag: op.tag.clone(),
            is_proj,
        }
    }
}

/// Non-conv ops the engine must interpret.
#[derive(Clone, Debug)]
pub enum IrOp {
    Conv(usize),
    Pool,
    Save { tag: String },
    Proj(usize),
    Add { tag: String },
    Relu,
    Gap,
    Fc,
}

#[derive(Clone, Debug)]
pub struct ModelIR {
    pub model_id: String,
    pub in_hw: usize,
    pub classes: usize,
    pub convs: Vec<ConvIR>,
    pub ops: Vec<IrOp>,
    pub fc_w: Tensor,
    pub fc_b: Tensor,
}

impl ModelIR {
    pub fn build(spec: &ModelSpec, params: &[Tensor]) -> Result<Self> {
        let mut convs = Vec::new();
        let mut ops = Vec::new();
        let mut fc: Option<(Tensor, Tensor)> = None;
        for (oi, op) in spec.ops.iter().enumerate() {
            match op {
                Op::Conv(c) => {
                    ops.push(IrOp::Conv(convs.len()));
                    convs.push(ConvIR::from_op(oi, c, params, false));
                }
                Op::Proj(c) => {
                    ops.push(IrOp::Proj(convs.len()));
                    convs.push(ConvIR::from_op(oi, c, params, true));
                }
                Op::Pool => ops.push(IrOp::Pool),
                Op::Save { tag } => ops.push(IrOp::Save { tag: tag.clone() }),
                Op::Add { tag } => ops.push(IrOp::Add { tag: tag.clone() }),
                Op::Relu => ops.push(IrOp::Relu),
                Op::Gap => ops.push(IrOp::Gap),
                Op::Fc { w, b, .. } => {
                    ops.push(IrOp::Fc);
                    fc = Some((params[*w].clone(), params[*b].clone()));
                }
            }
        }
        let Some((fc_w, fc_b)) = fc else {
            bail!("model has no fc head");
        };
        Ok(ModelIR {
            model_id: spec.id.clone(),
            in_hw: spec.in_hw,
            classes: spec.classes,
            convs,
            ops,
            fc_w,
            fc_b,
        })
    }

    pub fn total_weights(&self) -> usize {
        self.convs.iter().map(|c| c.w.len()).sum::<usize>() + self.fc_w.len()
    }

    pub fn nonzero_weights(&self) -> usize {
        self.convs
            .iter()
            .map(|c| c.w.count_nonzero())
            .sum::<usize>()
            + self.fc_w.count_nonzero()
    }
}

/// Compressed weight storage (paper's second compiler optimization): per
/// kept kernel a (channel, pattern-style-id) header + the payload taps —
/// the FKW-style format that removes CSR's per-weight indices.
#[derive(Clone, Debug)]
pub struct CompressedLayer {
    /// distinct pattern styles in this layer (the "pattern table")
    pub styles: Vec<u16>,
    /// per filter: (channel, style index into `styles`, payload taps)
    pub filters: Vec<Vec<(u32, u16, Vec<f32>)>>,
    pub bias: Vec<f32>,
}

impl CompressedLayer {
    pub fn compress(c: &ConvIR) -> Self {
        let ks = c.kernel_size();
        let mut styles: Vec<u16> = c
            .pattern
            .iter()
            .copied()
            .filter(|&p| p != 0)
            .collect();
        styles.sort_unstable();
        styles.dedup();
        let style_idx = |pat: u16| -> u16 {
            styles.binary_search(&pat).unwrap() as u16
        };
        let mut filters = Vec::with_capacity(c.a);
        for f in 0..c.a {
            let mut kernels = Vec::new();
            for ch in 0..c.c {
                let pat = c.pattern[f * c.c + ch];
                if pat == 0 {
                    continue; // connectivity-pruned
                }
                let base = (f * c.c + ch) * ks;
                let payload: Vec<f32> = (0..ks)
                    .filter(|&t| pat & (1 << t) != 0)
                    .map(|t| c.w.data()[base + t])
                    .collect();
                kernels.push((ch as u32, style_idx(pat), payload));
            }
            filters.push(kernels);
        }
        CompressedLayer {
            styles,
            filters,
            bias: c.bias.data().to_vec(),
        }
    }

    /// Storage footprint in bytes: style table (2B/style) + per kernel a
    /// 4B channel+style header + 4B per payload tap + bias.
    pub fn bytes(&self) -> usize {
        let header = 2 * self.styles.len();
        let kernels: usize = self
            .filters
            .iter()
            .flatten()
            .map(|(_, _, p)| 4 + 4 * p.len())
            .sum();
        header + kernels + 4 * self.bias.len()
    }

    /// Reconstruct the dense weight tensor (round-trip check).
    pub fn decompress(&self, c: &ConvIR) -> Tensor {
        let ks = c.kernel_size();
        let mut w = Tensor::zeros(&[c.a, c.c, c.kh, c.kw]);
        for (f, kernels) in self.filters.iter().enumerate() {
            for (ch, si, payload) in kernels {
                let pat = self.styles[*si as usize];
                let base = (f * c.c + *ch as usize) * ks;
                let mut pi = 0;
                for t in 0..ks {
                    if pat & (1 << t) != 0 {
                        w.data_mut()[base + t] = payload[pi];
                        pi += 1;
                    }
                }
            }
        }
        w
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::{schemes, LayerShape};
    use crate::rng::Pcg32;

    fn pruned_conv_ir(a: usize, c: usize, alpha: f64, seed: u64) -> ConvIR {
        let mut rng = Pcg32::seeded(seed);
        let shape = LayerShape {
            p: a,
            c,
            kh: 3,
            kw: 3,
        };
        let w = Tensor::from_vec(
            &[a, c * 9],
            (0..a * c * 9).map(|_| rng.normal()).collect(),
        )
        .unwrap();
        let pr = schemes::pattern(&w, &shape, alpha);
        ConvIR {
            op_idx: 0,
            a,
            c,
            kh: 3,
            kw: 3,
            stride: 1,
            act: Act::Relu,
            in_hw: 8,
            out_hw: 8,
            w: pr.w.reshape(&[a, c, 3, 3]).unwrap(),
            bias: Tensor::zeros(&[a]),
            pattern: vec![],
            tag: String::new(),
            is_proj: false,
        }
        .with_pattern()
    }

    impl ConvIR {
        fn with_pattern(mut self) -> Self {
            self.pattern =
                ConvIR::extract_pattern(&self.w, self.a, self.c, 9);
            self
        }
    }

    #[test]
    fn pattern_extraction_counts_taps() {
        let ir = pruned_conv_ir(6, 4, 4.0 / 9.0, 1);
        // alpha 4/9 keeps all kernels with exactly 4 taps
        for &p in &ir.pattern {
            assert_eq!(p.count_ones(), 4);
        }
        assert_eq!(ir.sparse_macs(), 6 * 4 * 4 * 64);
        assert_eq!(ir.dense_macs(), 6 * 4 * 9 * 64);
    }

    #[test]
    fn connectivity_pruned_kernels_have_zero_pattern() {
        let ir = pruned_conv_ir(6, 4, 0.2, 2);
        let kept = ir.kept_kernels();
        assert_eq!(kept, (2.25f64 * 0.2 * 24.0).floor() as usize);
        assert!(ir.pattern.iter().any(|&p| p == 0));
    }

    #[test]
    fn compression_roundtrip_and_size() {
        let ir = pruned_conv_ir(8, 6, 0.25, 3);
        let comp = CompressedLayer::compress(&ir);
        let back = comp.decompress(&ir);
        assert_eq!(back, ir.w);
        // compressed bytes well below dense storage
        let dense_bytes = ir.w.len() * 4;
        assert!(
            comp.bytes() < dense_bytes / 2,
            "{} vs {}",
            comp.bytes(),
            dense_bytes
        );
    }
}
