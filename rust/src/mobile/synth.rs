//! Synthetic in-Rust model specs for the mobile stack.
//!
//! The mobile compiler + executor only need a [`ModelSpec`] and a
//! parameter set; nothing about them requires the PJRT manifest. This
//! module builds small VGG-style and residual specs directly in Rust so
//! the mobile tests, benches, and examples run on machines without the
//! AOT artifacts (and without the `pjrt` feature).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::config::{Act, ConvOp, ModelSpec, Op, ParamSpec};
use crate::pruning::{project, LayerShape, Scheme};
use crate::tensor::Tensor;
use crate::train::params::init_params;

use super::plan::same_pad_lo;

/// Incremental [`ModelSpec`] builder that tracks the feature-map shape so
/// every `ConvOp` gets consistent `in_hw`/`out_hw` and the residual ops
/// get shape-compatible projections.
pub struct SpecBuilder {
    id: String,
    classes: usize,
    in_hw: usize,
    ops: Vec<Op>,
    params: Vec<ParamSpec>,
    prunable: Vec<usize>,
    hw: usize,
    c: usize,
    saved: BTreeMap<String, (usize, usize)>,
}

impl SpecBuilder {
    pub fn new(id: &str, in_hw: usize, classes: usize, in_c: usize) -> Self {
        SpecBuilder {
            id: id.to_string(),
            classes,
            in_hw,
            ops: Vec::new(),
            params: Vec::new(),
            prunable: Vec::new(),
            hw: in_hw,
            c: in_c,
            saved: BTreeMap::new(),
        }
    }

    fn conv_params(&mut self, a: usize, c: usize, k: usize) -> (usize, usize) {
        let i = self.params.len();
        self.params.push(ParamSpec {
            name: format!("conv{i}_w"),
            shape: vec![a, c, k, k],
        });
        self.params.push(ParamSpec {
            name: format!("conv{i}_b"),
            shape: vec![a],
        });
        (i, i + 1)
    }

    fn conv_op(
        &mut self,
        a: usize,
        c: usize,
        k: usize,
        stride: usize,
        act: Act,
        prunable: bool,
        in_hw: usize,
        tag: &str,
    ) -> ConvOp {
        let (w, b) = self.conv_params(a, c, k);
        let (out_hw, _) = same_pad_lo(in_hw, k, stride);
        ConvOp {
            w,
            b,
            stride,
            act,
            prunable,
            a,
            c,
            kh: k,
            kw: k,
            in_hw,
            out_hw,
            tag: tag.to_string(),
        }
    }

    /// Main-path conv: consumes the current feature map.
    pub fn conv(
        &mut self,
        a: usize,
        k: usize,
        stride: usize,
        act: Act,
        prunable: bool,
    ) -> &mut Self {
        let op = self.conv_op(a, self.c, k, stride, act, prunable, self.hw, "");
        self.hw = op.out_hw;
        self.c = a;
        if prunable {
            self.prunable.push(self.ops.len());
        }
        self.ops.push(Op::Conv(op));
        self
    }

    pub fn pool(&mut self) -> &mut Self {
        self.ops.push(Op::Pool);
        self.hw /= 2;
        self
    }

    pub fn save(&mut self, tag: &str) -> &mut Self {
        self.saved.insert(tag.to_string(), (self.c, self.hw));
        self.ops.push(Op::Save {
            tag: tag.to_string(),
        });
        self
    }

    /// 1x1 projection conv over the feature map saved under `tag`
    /// (downsampling shortcut of a residual stage).
    pub fn proj(&mut self, a: usize, stride: usize, tag: &str) -> &mut Self {
        let (c, hw) = self.saved[tag];
        let op = self.conv_op(a, c, 1, stride, Act::None, false, hw, tag);
        self.ops.push(Op::Proj(op));
        self
    }

    pub fn add(&mut self, tag: &str) -> &mut Self {
        self.ops.push(Op::Add {
            tag: tag.to_string(),
        });
        self
    }

    pub fn relu(&mut self) -> &mut Self {
        self.ops.push(Op::Relu);
        self
    }

    pub fn finish(mut self) -> ModelSpec {
        self.ops.push(Op::Gap);
        let i = self.params.len();
        self.params.push(ParamSpec {
            name: "fc_w".into(),
            shape: vec![self.classes, self.c],
        });
        self.params.push(ParamSpec {
            name: "fc_b".into(),
            shape: vec![self.classes],
        });
        self.ops.push(Op::Fc {
            w: i,
            b: i + 1,
            a: self.classes,
            c: self.c,
        });
        ModelSpec {
            id: self.id,
            arch: "synth".into(),
            classes: self.classes,
            in_hw: self.in_hw,
            ops: self.ops,
            params: self.params,
            prunable: self.prunable,
            artifacts: Default::default(),
        }
    }
}

/// VGG-style spec: per stage two prunable 3x3 convs then a 2x2 max-pool.
/// Returns the spec plus He-initialized parameters.
pub fn vgg_style(
    id: &str,
    in_hw: usize,
    classes: usize,
    widths: &[usize],
    seed: u64,
) -> (ModelSpec, Vec<Tensor>) {
    let mut b = SpecBuilder::new(id, in_hw, classes, 3);
    for &w in widths {
        b.conv(w, 3, 1, Act::Relu, true);
        b.conv(w, 3, 1, Act::Relu, true);
        b.pool();
    }
    let spec = b.finish();
    let params = init_params(&spec, seed);
    (spec, params)
}

/// Residual spec: a stem conv, one identity block, then one downsampling
/// block per extra width (stride-2 main path + 1x1 stride-2 projection
/// shortcut). Exercises every executor step kind: Save, Proj, Add, Relu.
pub fn res_style(
    id: &str,
    in_hw: usize,
    classes: usize,
    widths: &[usize],
    seed: u64,
) -> (ModelSpec, Vec<Tensor>) {
    assert!(!widths.is_empty());
    let mut b = SpecBuilder::new(id, in_hw, classes, 3);
    b.conv(widths[0], 3, 1, Act::Relu, true);
    // identity residual block on the stem width
    b.save("id0");
    b.conv(widths[0], 3, 1, Act::Relu, true);
    b.conv(widths[0], 3, 1, Act::None, true);
    b.add("id0");
    b.relu();
    // one downsampling block per subsequent width
    for (i, &w) in widths.iter().enumerate().skip(1) {
        let tag = format!("s{i}");
        b.save(&tag);
        b.conv(w, 3, 2, Act::Relu, true);
        b.conv(w, 3, 1, Act::None, true);
        b.proj(w, 2, &tag);
        b.add(&tag);
        b.relu();
    }
    let spec = b.finish();
    let params = init_params(&spec, seed);
    (spec, params)
}

/// Build a synthetic spec by family name — the CLI's `--spec vgg|res`
/// switch. Both families use the same input/classes/widths so deploy
/// and serve runs are comparable across kinds.
pub fn spec_by_kind(
    kind: &str,
    id: &str,
    in_hw: usize,
    classes: usize,
    widths: &[usize],
    seed: u64,
) -> Result<(ModelSpec, Vec<Tensor>)> {
    match kind {
        "vgg" => Ok(vgg_style(id, in_hw, classes, widths, seed)),
        "res" => Ok(res_style(id, in_hw, classes, widths, seed)),
        other => bail!("unknown spec kind {other:?} (vgg|res)"),
    }
}

/// Prune every prunable conv of `spec` in place with `scheme` at
/// remaining-weight ratio `alpha` (the kernel parity tests run every
/// scheme through the same compile + execute path).
pub fn scheme_prune(
    spec: &ModelSpec,
    params: &mut [Tensor],
    scheme: Scheme,
    alpha: f64,
) {
    for (_, op) in spec.prunable_convs() {
        let shape = LayerShape::from_conv(op);
        let wg = params[op.w]
            .clone()
            .reshape(&[shape.p, shape.q()])
            .unwrap();
        let pr = project(scheme, &wg, &shape, alpha).unwrap();
        let s4 = params[op.w].shape().to_vec();
        params[op.w] = pr.w.clone().reshape(&s4).unwrap();
    }
}

/// Pattern-prune every prunable conv of `spec` in place at remaining-weight
/// ratio `alpha` (4-of-9 patterns + connectivity, paper §IV-D).
pub fn pattern_prune(spec: &ModelSpec, params: &mut [Tensor], alpha: f64) {
    scheme_prune(spec, params, Scheme::Pattern, alpha);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mobile::ir::ModelIR;

    #[test]
    fn vgg_spec_shapes_are_consistent() {
        let (spec, params) = vgg_style("v", 16, 5, &[4, 8], 3);
        assert_eq!(spec.prunable.len(), 4);
        assert_eq!(params.len(), spec.params.len());
        let ir = ModelIR::build(&spec, &params).unwrap();
        assert_eq!(ir.convs.len(), 4);
        assert_eq!(ir.fc_w.shape(), &[5, 8]);
        // stage hw: 16 -> pool 8 -> pool 4
        assert_eq!(ir.convs[0].in_hw, 16);
        assert_eq!(ir.convs[2].in_hw, 8);
    }

    #[test]
    fn res_spec_builds_ir_with_projs() {
        let (spec, params) = res_style("r", 16, 5, &[4, 8], 4);
        let ir = ModelIR::build(&spec, &params).unwrap();
        let projs: Vec<_> =
            ir.convs.iter().filter(|c| c.is_proj).collect();
        assert_eq!(projs.len(), 1);
        assert_eq!(projs[0].kh, 1);
        assert_eq!(projs[0].stride, 2);
        assert_eq!(projs[0].in_hw, 16);
        assert_eq!(projs[0].out_hw, 8);
    }

    #[test]
    fn spec_by_kind_dispatches_and_rejects() {
        let (v, _) = spec_by_kind("vgg", "k", 8, 4, &[4], 1).unwrap();
        assert_eq!(v.id, "k");
        let (r, _) = spec_by_kind("res", "k", 8, 4, &[4], 1).unwrap();
        assert!(r.ops.iter().any(|o| matches!(o, Op::Add { .. })));
        let err = spec_by_kind("mlp", "k", 8, 4, &[4], 1)
            .unwrap_err()
            .to_string();
        assert!(err.contains("vgg|res"), "{err}");
    }

    #[test]
    fn pattern_prune_zeroes_weights() {
        let (spec, mut params) = vgg_style("v", 8, 4, &[4], 5);
        let before: usize = params.iter().map(|t| t.count_nonzero()).sum();
        pattern_prune(&spec, &mut params, 0.25);
        let after: usize = params.iter().map(|t| t.count_nonzero()).sum();
        assert!(after < before / 2, "{after} vs {before}");
    }
}
