//! Fixed-width f32 lane arithmetic for the vectorized pattern kernels
//! (DESIGN.md §12).
//!
//! [`F32Lanes`] is a plain `[f32; LANES]` wrapper whose operations are
//! written as fully unrolled per-lane loops. There are no intrinsics and
//! no unstable features: the loops are shaped so LLVM's auto-vectorizer
//! lowers them to the widest SIMD the target baseline offers (SSE2 /
//! NEON without flags, AVX2 with `-C target-cpu`). `LANES = 8` matches
//! one AVX2 register and two NEON/SSE registers — wide enough to keep
//! the vector units busy, narrow enough that border columns handled in
//! scalar code stay cheap.
//!
//! Numerics contract: [`F32Lanes::mul_add`] is a *separate* multiply and
//! add per lane — deliberately not `f32::mul_add` — so each output
//! element sees exactly the same rounding sequence as the scalar
//! kernels. This is what makes kernel choice a pure shape decision:
//! every pattern kernel produces bit-identical planes (see the
//! `prop_pattern_kernels_bit_identical` property in `engine`).

/// Lane width of the vectorized kernels, in f32 elements.
pub const LANES: usize = 8;

/// A fixed-width vector of f32 lanes; the register block of the
/// vectorized pattern codelets.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct F32Lanes(pub [f32; LANES]);

impl F32Lanes {
    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        F32Lanes([v; LANES])
    }

    /// Load `LANES` contiguous elements from the front of `s`.
    #[inline(always)]
    pub fn load(s: &[f32]) -> Self {
        let mut v = [0.0f32; LANES];
        v.copy_from_slice(&s[..LANES]);
        F32Lanes(v)
    }

    /// Load `LANES` elements at stride `stride` from the front of `s`
    /// (`s[0], s[stride], ...`). `s` must hold at least
    /// `(LANES - 1) * stride + 1` elements.
    #[inline(always)]
    pub fn load_strided(s: &[f32], stride: usize) -> Self {
        let mut v = [0.0f32; LANES];
        for (i, lane) in v.iter_mut().enumerate() {
            *lane = s[i * stride];
        }
        F32Lanes(v)
    }

    /// Store the lanes to the front of `d`.
    #[inline(always)]
    pub fn store(self, d: &mut [f32]) {
        d[..LANES].copy_from_slice(&self.0);
    }

    /// Per-lane `self + w * x` as a rounded multiply followed by a
    /// rounded add (never a fused multiply-add), matching the scalar
    /// kernels' `o += w * x` bit for bit.
    #[inline(always)]
    pub fn mul_add(self, w: f32, x: F32Lanes) -> Self {
        let mut v = self.0;
        for (lane, xv) in v.iter_mut().zip(x.0) {
            *lane += w * xv;
        }
        F32Lanes(v)
    }

    /// Per-lane maximum with a scalar (the ReLU epilogue shape).
    #[inline(always)]
    pub fn max(self, floor: f32) -> Self {
        let mut v = self.0;
        for lane in v.iter_mut() {
            *lane = lane.max(floor);
        }
        F32Lanes(v)
    }
}

/// Vectorized tap codelet — the inner loop of the pattern-vec kernels:
/// `o[i] += w * x[i * stride]` for every `i`, `LANES` outputs at a time
/// with a scalar tail. `o` and `x` are pre-sliced by the caller so that
/// `o.len()` outputs are written and `x` holds the matching strided
/// inputs (`x.len() >= (o.len() - 1) * stride + 1`).
///
/// Each element is updated by one rounded multiply and one rounded add
/// in ascending index order, exactly as the scalar kernels do — the
/// vectorization changes instruction shape, never results.
#[inline]
pub fn axpy_row(o: &mut [f32], x: &[f32], w: f32, stride: usize) {
    let n = o.len();
    let mut i = 0;
    if stride == 1 {
        while i + LANES <= n {
            let acc = F32Lanes::load(&o[i..])
                .mul_add(w, F32Lanes::load(&x[i..]));
            acc.store(&mut o[i..]);
            i += LANES;
        }
        for (ov, xv) in o[i..].iter_mut().zip(&x[i..n]) {
            *ov += w * xv;
        }
    } else {
        let mut ix = 0;
        while i + LANES <= n {
            let acc = F32Lanes::load(&o[i..])
                .mul_add(w, F32Lanes::load_strided(&x[ix..], stride));
            acc.store(&mut o[i..]);
            i += LANES;
            ix += LANES * stride;
        }
        for ov in o[i..].iter_mut() {
            *ov += w * x[ix];
            ix += stride;
        }
    }
}

/// Quantized tap codelet — the inner loop of the quant-vec kernel:
/// `acc[i] += w * x[i * stride] as i32` for every `i`. The i8→i32
/// widening multiply-accumulate is written as a plain indexed loop so
/// LLVM auto-vectorizes it (pmaddwd-style on x86, smlal on NEON)
/// without intrinsics, mirroring [`axpy_row`].
///
/// Unlike the f32 codelets there is no ordering contract to uphold:
/// i8×i8 products are at most 16129 in magnitude, so i32 accumulation
/// is *exact* and any evaluation order produces the same bits. The
/// quantized kernels are deterministic by arithmetic, not by ordering
/// discipline (DESIGN.md §14).
#[inline]
pub fn qaxpy_row(acc: &mut [i32], x: &[i8], w: i32, stride: usize) {
    if stride == 1 {
        for (av, &xv) in acc.iter_mut().zip(x) {
            *av += w * xv as i32;
        }
    } else {
        let mut ix = 0;
        for av in acc.iter_mut() {
            *av += w * x[ix] as i32;
            ix += stride;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn lane_ops_match_scalar() {
        let a: Vec<f32> = (0..LANES).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..LANES).map(|i| 1.0 - i as f32).collect();
        let got = F32Lanes::load(&a).mul_add(2.0, F32Lanes::load(&b));
        for i in 0..LANES {
            assert_eq!(got.0[i], a[i] + 2.0 * b[i]);
        }
        let m = got.max(0.0);
        for i in 0..LANES {
            assert_eq!(m.0[i], (a[i] + 2.0 * b[i]).max(0.0));
        }
        assert_eq!(F32Lanes::splat(3.0).0, [3.0; LANES]);
    }

    #[test]
    fn strided_load_gathers() {
        let s: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let v = F32Lanes::load_strided(&s, 3);
        for i in 0..LANES {
            assert_eq!(v.0[i], (3 * i) as f32);
        }
    }

    #[test]
    fn axpy_row_is_bit_identical_to_scalar_loop() {
        let mut rng = Pcg32::seeded(9);
        for stride in 1..=3usize {
            // odd lengths exercise the scalar tail
            for n in [0usize, 1, 5, 8, 9, 16, 23] {
                let w = rng.normal();
                let x: Vec<f32> = (0..n.saturating_sub(1) * stride + 1)
                    .map(|_| rng.normal())
                    .collect();
                let base: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
                let mut want = base.clone();
                for (i, ov) in want.iter_mut().enumerate() {
                    *ov += w * x[i * stride];
                }
                let mut got = base;
                axpy_row(&mut got, &x, w, stride);
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "stride={stride} n={n}"
                );
            }
        }
    }

    #[test]
    fn qaxpy_row_matches_scalar_reference() {
        let mut rng = Pcg32::seeded(21);
        for stride in 1..=3usize {
            for n in [0usize, 1, 5, 8, 9, 16, 23] {
                let w = (rng.below(255) as i32) - 127;
                let x: Vec<i8> = (0..n.saturating_sub(1) * stride + 1)
                    .map(|_| (rng.below(255) as i32 - 127) as i8)
                    .collect();
                let base: Vec<i32> = (0..n)
                    .map(|_| rng.below(1000) as i32 - 500)
                    .collect();
                let mut want = base.clone();
                for (i, av) in want.iter_mut().enumerate() {
                    *av += w * x[i * stride] as i32;
                }
                let mut got = base;
                qaxpy_row(&mut got, &x, w, stride);
                assert_eq!(got, want, "stride={stride} n={n}");
            }
        }
    }
}
