//! The three pattern-enabled compiler optimizations of paper §V-C.
//!
//! 1. **Filter kernel reorder** — schedule filters so that ones sharing
//!    pattern styles execute consecutively (regular inner loops / balanced
//!    SIMD groups). Outputs are scattered to their original channel slots,
//!    so semantics are untouched (verified in engine tests).
//! 2. **Compressed weight storage** — [`super::ir::CompressedLayer`]
//!    (pattern-style header + payload, no per-weight indices).
//! 3. **Load redundancy elimination** — taps grouped by input row
//!    ([`row_group`]): every row of a pattern is one streaming codelet, so
//!    a 4-tap pattern spanning r rows issues r load streams instead of 4.
//!
//! [`CompileReport`] quantifies each pass for the Fig. 3 cost model.

use super::ir::{CompressedLayer, ConvIR, ModelIR};

/// Row-grouped taps of one pattern style: [(ky, [(kx, payload_slot)])].
pub type StyleRows = Vec<(usize, Vec<(usize, usize)>)>;

/// Group a pattern's taps by kernel row: [(ky, [(kx, payload_slot)])].
/// Payload slots index into the compressed payload (tap order = ascending
/// tap index, matching `CompressedLayer::compress`).
pub fn row_group(pat: u16, kh: usize, kw: usize) -> StyleRows {
    let mut out: Vec<(usize, Vec<(usize, usize)>)> = Vec::new();
    let mut slot = 0usize;
    for t in 0..kh * kw {
        if pat & (1 << t) != 0 {
            let (ky, kx) = (t / kw, t % kw);
            match out.last_mut() {
                Some((y, taps)) if *y == ky => taps.push((kx, slot)),
                _ => out.push((ky, vec![(kx, slot)])),
            }
            slot += 1;
        }
    }
    out
}

/// Filter kernel reorder: execution order grouping filters by their
/// dominant pattern-style signature, larger kernel counts first within a
/// group (load balance across SIMD lanes / threads).
pub fn reorder_filters(c: &ConvIR) -> Vec<usize> {
    // signature: sorted (style, count) multiset of the filter's kernels
    let sig = |f: usize| -> Vec<(u16, usize)> {
        let mut counts = std::collections::BTreeMap::<u16, usize>::new();
        for ch in 0..c.c {
            let p = c.pattern[f * c.c + ch];
            if p != 0 {
                *counts.entry(p).or_insert(0) += 1;
            }
        }
        counts.into_iter().collect()
    };
    let mut order: Vec<usize> = (0..c.a).collect();
    let sigs: Vec<Vec<(u16, usize)>> = (0..c.a).map(sig).collect();
    let kern_count: Vec<usize> = (0..c.a)
        .map(|f| {
            (0..c.c)
                .filter(|&ch| c.pattern[f * c.c + ch] != 0)
                .count()
        })
        .collect();
    order.sort_by(|&x, &y| {
        sigs[x]
            .cmp(&sigs[y])
            .then(kern_count[y].cmp(&kern_count[x]))
            .then(x.cmp(&y))
    });
    // The pass is a schedule choice, so it never has to regress: keep the
    // grouped order only if it actually reduces style switches (random
    // near-unique patterns can make grouping a wash).
    let identity: Vec<usize> = (0..c.a).collect();
    if style_switches(c, &order) <= style_switches(c, &identity) {
        order
    } else {
        identity
    }
}

/// Pattern-style switches encountered while walking the execution order —
/// the branch-divergence proxy the reorder pass minimizes.
pub fn style_switches(c: &ConvIR, order: &[usize]) -> usize {
    let mut switches = 0usize;
    let mut last: Option<u16> = None;
    for &f in order {
        for ch in 0..c.c {
            let p = c.pattern[f * c.c + ch];
            if p == 0 {
                continue;
            }
            if last != Some(p) {
                switches += 1;
                last = Some(p);
            }
        }
    }
    switches
}

/// Loads per output position for one layer, without (naive) and with
/// (row-grouped) load redundancy elimination.
pub fn lre_loads(c: &ConvIR) -> (usize, usize) {
    let mut naive = 0usize;
    let mut optimized = 0usize;
    for &p in &c.pattern {
        if p == 0 {
            continue;
        }
        naive += p.count_ones() as usize;
        optimized += row_group(p, c.kh, c.kw).len();
    }
    (naive, optimized)
}

/// Per-model compile summary consumed by the cost model and reports.
#[derive(Clone, Debug)]
pub struct CompileReport {
    pub layers: Vec<LayerReport>,
}

#[derive(Clone, Debug)]
pub struct LayerReport {
    pub dense_macs: usize,
    pub sparse_macs: usize,
    pub dense_bytes: usize,
    pub compressed_bytes: usize,
    pub styles: usize,
    /// style switches before/after filter kernel reorder
    pub switches_before: usize,
    pub switches_after: usize,
    /// loads per output position before/after LRE
    pub loads_naive: usize,
    pub loads_lre: usize,
}

impl CompileReport {
    pub fn build(
        ir: &ModelIR,
        compressed: &[CompressedLayer],
        orders: &[Vec<usize>],
    ) -> Self {
        let layers = ir
            .convs
            .iter()
            .zip(compressed)
            .zip(orders)
            .map(|((c, comp), order)| {
                let identity: Vec<usize> = (0..c.a).collect();
                let (naive, lre) = lre_loads(c);
                LayerReport {
                    dense_macs: c.dense_macs(),
                    sparse_macs: c.sparse_macs(),
                    dense_bytes: c.w.len() * 4 + c.bias.len() * 4,
                    compressed_bytes: comp.bytes(),
                    styles: comp.styles.len(),
                    switches_before: style_switches(c, &identity),
                    switches_after: style_switches(c, order),
                    loads_naive: naive,
                    loads_lre: lre,
                }
            })
            .collect();
        CompileReport { layers }
    }

    pub fn total_dense_macs(&self) -> usize {
        self.layers.iter().map(|l| l.dense_macs).sum()
    }

    pub fn total_sparse_macs(&self) -> usize {
        self.layers.iter().map(|l| l.sparse_macs).sum()
    }

    pub fn total_compressed_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.compressed_bytes).sum()
    }

    pub fn total_dense_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.dense_bytes).sum()
    }

    /// Average loads/MAC improvement from LRE (≥ 1).
    pub fn lre_gain(&self) -> f64 {
        let naive: usize = self.layers.iter().map(|l| l.loads_naive).sum();
        let lre: usize = self.layers.iter().map(|l| l.loads_lre).sum();
        naive as f64 / lre.max(1) as f64
    }

    /// Reorder gain: style switches removed (≥ 1).
    pub fn reorder_gain(&self) -> f64 {
        let before: usize =
            self.layers.iter().map(|l| l.switches_before).sum();
        let after: usize =
            self.layers.iter().map(|l| l.switches_after).sum();
        before as f64 / after.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Act;
    use crate::rng::Pcg32;
    use crate::tensor::Tensor;

    fn mk_conv(a: usize, c: usize, patterns: &[u16]) -> ConvIR {
        let mut rng = Pcg32::seeded(1);
        let ks = 9;
        let mut w = Tensor::zeros(&[a, c, 3, 3]);
        for ki in 0..a * c {
            let p = patterns[ki % patterns.len()];
            for t in 0..ks {
                if p & (1 << t) != 0 {
                    w.data_mut()[ki * ks + t] = rng.normal();
                }
            }
        }
        let pattern: Vec<u16> = (0..a * c)
            .map(|ki| patterns[ki % patterns.len()])
            .collect();
        ConvIR {
            op_idx: 0,
            a,
            c,
            kh: 3,
            kw: 3,
            stride: 1,
            act: Act::Relu,
            in_hw: 8,
            out_hw: 8,
            w,
            bias: Tensor::zeros(&[a]),
            pattern,
            tag: String::new(),
            is_proj: false,
        }
    }

    #[test]
    fn row_group_slots_are_payload_order() {
        // pattern taps 0,2,4,8 -> rows: (0,[0,2]), (1,[1]), (2,[2])
        let pat: u16 = 1 | (1 << 2) | (1 << 4) | (1 << 8);
        let rows = row_group(pat, 3, 3);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0], (0, vec![(0, 0), (2, 1)]));
        assert_eq!(rows[1], (1, vec![(1, 2)]));
        assert_eq!(rows[2], (2, vec![(2, 3)]));
    }

    #[test]
    fn reorder_is_permutation_and_reduces_switches() {
        // alternate two styles across filters -> reorder groups them
        let c = mk_conv(8, 4, &[0b000011011, 0b110110000]);
        let order = reorder_filters(&c);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..8).collect::<Vec<_>>());
        let identity: Vec<usize> = (0..8).collect();
        let before = style_switches(&c, &identity);
        let after = style_switches(&c, &order);
        assert!(after <= before, "{after} > {before}");
    }

    #[test]
    fn lre_counts_rows_vs_taps() {
        // style with taps spread over 2 rows: naive 4 loads, lre 2
        let c = mk_conv(2, 2, &[0b000011011]); // taps 0,1,3,4 -> rows 0,1
        let (naive, opt) = lre_loads(&c);
        assert_eq!(naive, 4 * 4);
        assert_eq!(opt, 2 * 4);
    }
}
