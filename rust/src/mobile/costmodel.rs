//! Mobile cost modeling: the calibrated analytical latency model for the
//! Fig. 3 comparison (we have no physical S10 — DESIGN.md §2), plus the
//! kernel-shape side of the cost question — the per-layer
//! [`KernelChoice`] with its analytic defaults and the plan-time
//! empirical autotuner (DESIGN.md §12).
//!
//! Calibration strategy (analytical model): per-framework *dense*
//! execution efficiencies are fit so the dense ResNet-18/ImageNet frame
//! times land in the ranges the paper reports for TFLite/TVM/MNN; our
//! framework's *additional* gains then come only from the measured
//! compiler-pass outputs (sparse MACs, compressed bytes, LRE load
//! reduction, reorder regularity) — i.e. the speedup side of Fig. 3 is
//! produced by the passes, not by calibration.
//!
//! Autotuner strategy: the seed's Pallas GEMM (python/compile/kernels/
//! matmul.py) sizes its grid by capping each block at a default and
//! rounding small dimensions up to the hardware alignment.
//! [`analytic_row_tile`] ports that heuristic to the conv codelets (cap
//! the output-row band at [`ROW_TILE_CAP`], align to the lane width,
//! size by an L1 budget), and [`autotune_layer`] replaces the static
//! table with measurement: at plan-compile time each candidate
//! (kernel-kind, row-tile, filter-block) shape is timed on the layer's
//! *real packed payload* with the plan's *real thread blocks*, and the
//! winner is baked into the plan. Autotuning picks shapes only — every
//! pattern kernel produces bit-identical planes (see `engine`), so a
//! noisy timer can never change results.

use crate::rng::Pcg32;
use crate::tensor::Chw;
use crate::util::Stopwatch;

use super::engine::{self, ConvInput, KernelKind, OutPlanes, QuantView};
use super::ir::{ConvIR, ModelIR};
use super::passes::CompileReport;
use super::plan::{ElemType, LayerPlan};
use super::simd::LANES;

/// A mobile SoC target (peak numbers are fp32-effective, not marketing).
#[derive(Clone, Copy, Debug)]
pub struct Target {
    pub name: &'static str,
    pub cpu_gflops: f64,
    pub cpu_gbps: f64,
    pub gpu_gflops: f64,
    pub gpu_gbps: f64,
}

/// Snapdragon 855: Kryo 485 octa-core (1×2.84 + 3×2.42 + 4×1.78 GHz, 128-bit
/// NEON ≈ 8 fp32 FLOP/cycle/core) and Adreno 640 (~898 GFLOPs peak fp32).
pub const GALAXY_S10: Target = Target {
    name: "Samsung Galaxy S10 (Snapdragon 855)",
    cpu_gflops: 140.0,
    cpu_gbps: 34.1,
    gpu_gflops: 898.0,
    gpu_gbps: 34.1,
};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Device {
    Cpu,
    Gpu,
}

/// Execution-engine model: how much of the target's peak a framework's
/// dense conv kernels achieve, plus fixed dispatch overhead per layer.
/// Efficiencies are the calibrated quantities (see module doc).
#[derive(Clone, Copy, Debug)]
pub struct EngineModel {
    pub name: &'static str,
    pub cpu_eff: f64,
    pub gpu_eff: f64,
    /// per-layer dispatch/synchronization overhead (ms)
    pub layer_overhead_ms: f64,
    /// can it execute the pattern-sparse compressed form?
    pub sparse_aware: bool,
    /// inherent per-FLOP efficiency loss of sparse codelets vs dense GEMM
    /// (irregular access, shorter inner loops); partially recovered by the
    /// measured LRE/reorder gains. This is why 6x compression yields ~2-4x
    /// speedup, matching the paper's Fig. 3 ratios.
    pub sparse_penalty: f64,
}

/// Baseline frameworks run the same pattern-pruned models but cannot
/// exploit the sparsity (paper §V-C: "the same pattern-based sparse models
/// are used for TFLite, TVM and MNN").
pub const TFLITE: EngineModel = EngineModel {
    name: "TFLite",
    cpu_eff: 0.25,
    gpu_eff: 0.040,
    layer_overhead_ms: 0.10,
    sparse_aware: false,
    sparse_penalty: 1.0,
};

pub const TVM: EngineModel = EngineModel {
    name: "TVM",
    cpu_eff: 0.455,
    gpu_eff: 0.073,
    layer_overhead_ms: 0.06,
    sparse_aware: false,
    sparse_penalty: 1.0,
};

pub const MNN: EngineModel = EngineModel {
    name: "MNN",
    cpu_eff: 0.50,
    gpu_eff: 0.080,
    layer_overhead_ms: 0.05,
    sparse_aware: false,
    sparse_penalty: 1.0,
};

/// Our compiler-assisted framework: dense-equivalent kernel quality just
/// below MNN; the Fig. 3 advantage comes from executing ~1/comp_rate of the
/// MACs (sparse codelets at `sparse_penalty` efficiency, recovered in part
/// by the measured LRE/reorder pass gains).
pub const OURS: EngineModel = EngineModel {
    name: "Ours",
    cpu_eff: 0.22,
    gpu_eff: 0.075,
    layer_overhead_ms: 0.04,
    sparse_aware: true,
    sparse_penalty: 0.58,
};

pub const ALL_ENGINES: [EngineModel; 4] = [TFLITE, TVM, MNN, OURS];

/// Analytic description of one conv layer (either from a compiled ModelIR
/// or from the paper-scale architecture tables below).
#[derive(Clone, Copy, Debug)]
pub struct AnalyticLayer {
    pub dense_macs: usize,
    pub sparse_macs: usize,
    pub dense_bytes: usize,
    pub compressed_bytes: usize,
    /// activation traffic (in + out fmaps), bytes
    pub act_bytes: usize,
    /// loads-per-MAC improvement from LRE (≥1)
    pub lre_gain: f64,
    /// style-switch reduction from filter reorder (≥1)
    pub reorder_gain: f64,
}

#[derive(Clone, Debug)]
pub struct AnalyticModel {
    pub name: String,
    pub layers: Vec<AnalyticLayer>,
}

impl AnalyticModel {
    pub fn from_compiled(ir: &ModelIR, report: &CompileReport) -> Self {
        let layers = ir
            .convs
            .iter()
            .zip(&report.layers)
            .map(|(c, l)| AnalyticLayer {
                dense_macs: l.dense_macs,
                sparse_macs: l.sparse_macs,
                dense_bytes: l.dense_bytes,
                compressed_bytes: l.compressed_bytes,
                act_bytes: 4 * (c.c * c.in_hw * c.in_hw
                    + c.a * c.out_hw * c.out_hw),
                lre_gain: l.loads_naive as f64
                    / l.loads_lre.max(1) as f64,
                reorder_gain: l.switches_before as f64
                    / l.switches_after.max(1) as f64,
            })
            .collect();
        AnalyticModel {
            name: ir.model_id.clone(),
            layers,
        }
    }

    /// Paper-scale conv stack: (out_ch, in_ch, out_hw) per 3x3 conv layer,
    /// pattern-pruned at overall CONV compression `comp_rate` (kept ratio =
    /// 1/comp_rate; 4-of-9 patterns + connectivity to reach it). Pass gains
    /// use the fleet averages measured on our compiled mini models.
    pub fn paper_scale(
        name: &str,
        convs: &[(usize, usize, usize)],
        comp_rate: f64,
        lre_gain: f64,
        reorder_gain: f64,
    ) -> Self {
        let kept = 1.0 / comp_rate;
        let layers = convs
            .iter()
            .map(|&(a, c, out_hw)| {
                let dense_macs = a * c * 9 * out_hw * out_hw;
                let sparse_macs =
                    (dense_macs as f64 * kept).round() as usize;
                let dense_bytes = a * c * 9 * 4 + a * 4;
                // 4 payload + 4 header bytes per kept kernel
                let kept_kernels = (a as f64 * c as f64 * kept * 9.0
                    / 4.0)
                    .round() as usize;
                let compressed_bytes = kept_kernels * (4 + 16) + a * 4;
                AnalyticLayer {
                    dense_macs,
                    sparse_macs,
                    dense_bytes,
                    compressed_bytes,
                    act_bytes: 4 * (c * (out_hw * out_hw * 4)
                        + a * out_hw * out_hw),
                    lre_gain,
                    reorder_gain,
                }
            })
            .collect();
        AnalyticModel {
            name: name.into(),
            layers,
        }
    }
}

/// ResNet-18 @ 224x224 (ImageNet) 3x3 conv stack.
pub fn resnet18_imagenet() -> Vec<(usize, usize, usize)> {
    let mut v = vec![(64, 64, 56); 4];
    v.extend([(128, 64, 28), (128, 128, 28), (128, 128, 28), (128, 128, 28)]);
    v.extend([(256, 128, 14), (256, 256, 14), (256, 256, 14), (256, 256, 14)]);
    v.extend([(512, 256, 7), (512, 512, 7), (512, 512, 7), (512, 512, 7)]);
    v
}

/// VGG-16 @ 32x32 (CIFAR) conv stack.
pub fn vgg16_cifar() -> Vec<(usize, usize, usize)> {
    vec![
        (64, 3, 32),
        (64, 64, 32),
        (128, 64, 16),
        (128, 128, 16),
        (256, 128, 8),
        (256, 256, 8),
        (256, 256, 8),
        (512, 256, 4),
        (512, 512, 4),
        (512, 512, 4),
        (512, 512, 2),
        (512, 512, 2),
        (512, 512, 2),
    ]
}

/// Modeled execution cost of one filter of a pattern-pruned conv layer, in
/// abstract work units. Used by the plan compiler to load-balance the
/// reordered filter schedule across worker threads: taps actually executed
/// dominate (one MAC per tap per output position), kept kernels add a
/// per-kernel stream-setup term, and a constant covers schedule overhead
/// so fully connectivity-pruned filters still get nonzero weight.
pub fn filter_exec_cost(c: &super::ir::ConvIR, f: usize) -> u64 {
    let mut taps = 0u64;
    let mut kernels = 0u64;
    for ch in 0..c.c {
        let p = c.pattern[f * c.c + ch];
        if p != 0 {
            kernels += 1;
            taps += p.count_ones() as u64;
        }
    }
    let plane = (c.out_hw * c.out_hw) as u64;
    taps * plane + kernels * (plane / 4 + 8) + 64
}

// ---------------------------------------------------------------------------
// Kernel choice: analytic defaults + plan-time empirical autotuner
// ---------------------------------------------------------------------------

/// The conv kernel shape baked into a [`LayerPlan`]: which registry
/// kernel runs the layer and the cache-tile parameters the tiled
/// kernels read. Carried through the plan artifact (section 6 of the
/// `serve::artifact` format) so serve traffic runs the tuned codelets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KernelChoice {
    pub kind: KernelKind,
    /// output-row tile height for the row-tiled kernels (≥ 1)
    pub row_tile: u16,
    /// filters per cache group in the vec-tiled kernel (≥ 1)
    pub fblock: u16,
    /// true when an empirical autotuning run picked this choice (false
    /// for the analytic default)
    pub tuned: bool,
}

impl std::fmt::Display for KernelChoice {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} rt={} fb={}{}",
            self.kind.name(),
            self.row_tile,
            self.fblock,
            if self.tuned { " (tuned)" } else { "" }
        )
    }
}

/// L1 budget for one input row band, bytes: half a typical 32 KiB L1D,
/// leaving the other half for the output rows and payload stream.
const L1_BAND_BYTES: usize = 16 * 1024;

/// Cap on the row tile (the seed GEMM's block-size-default spirit).
pub const ROW_TILE_CAP: usize = 64;

/// Cap on the vec-tiled filter group.
const FBLOCK_CAP: usize = 8;

fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Analytic output-row tile: size the revisited input band to the L1
/// budget, align up to the lane width, cap at [`ROW_TILE_CAP`] — the
/// port of the seed GEMM's `min(BLOCK, round_up(dim, align))` rule.
pub fn analytic_row_tile(in_hw: usize, kh: usize, stride: usize) -> u16 {
    // one output band of height T touches T*stride + kh input rows
    let budget_rows = L1_BAND_BYTES / (4 * in_hw.max(1));
    let tile = budget_rows.saturating_sub(kh) / stride.max(1);
    round_up(tile.max(1), LANES / 2).min(ROW_TILE_CAP) as u16
}

/// Analytic per-layer default (no measurement): vectorized codelets
/// whenever a full lane fits in an output row, with cache tiling once
/// the plane outgrows the L1 band. This is what `compile_plan` bakes
/// in; the autotuner overrides it when enabled.
pub fn default_choice(c: &ConvIR) -> KernelChoice {
    let row_tile = analytic_row_tile(c.in_hw, c.kh, c.stride);
    let fblock = FBLOCK_CAP.min(c.a.max(1)) as u16;
    let kind = if c.out_hw < LANES {
        KernelKind::PatternScalar
    } else if (row_tile as usize) < c.out_hw {
        KernelKind::PatternVecTiled
    } else {
        KernelKind::PatternVec
    };
    KernelChoice {
        kind,
        row_tile,
        fblock,
        tuned: false,
    }
}

/// Autotuner effort knobs.
#[derive(Clone, Copy, Debug)]
pub struct TuneConfig {
    /// timed executions per candidate per round (after one warm-up)
    pub reps: usize,
    /// measurement rounds; each candidate keeps its best round, so
    /// transient noise in one round cannot crown a loser
    pub rounds: usize,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig { reps: 3, rounds: 2 }
    }
}

impl TuneConfig {
    /// Cheapest useful setting (CI smoke): one round, one rep.
    pub fn smoke() -> Self {
        TuneConfig { reps: 1, rounds: 1 }
    }
}

/// One layer's autotuning outcome: the winner plus every candidate's
/// best measured time (for the `repro deploy` table).
#[derive(Clone, Debug)]
pub struct LayerTune {
    pub layer: usize,
    pub chosen: KernelChoice,
    /// (candidate, best ms over rounds), in search order
    pub timings: Vec<(KernelChoice, f64)>,
}

/// Whole-plan autotuning outcome, returned alongside the plan by
/// `PassManager` when tuning is enabled.
#[derive(Clone, Debug, Default)]
pub struct TuneReport {
    pub layers: Vec<LayerTune>,
}

/// Project a baked choice onto the quantized kernel set — applied by
/// the plan compiler's quantize pass so an i8 plan's per-layer choices
/// name kernels that can actually consume the payload. Tile parameters
/// and the tuned bit are preserved.
pub fn quantized_choice(mut c: KernelChoice) -> KernelChoice {
    c.kind = c.kind.for_elem(ElemType::I8);
    c
}

/// Candidate (kernel-kind, row-tile, filter-block) shapes for one
/// layer: the scalar baseline, straight vec, analytic tiled, and a
/// small grid of vec-tiled shapes around the analytic tile. On i8
/// layers the grid is the quantized kernel pair instead — their exact
/// integer accumulation makes every shape bit-identical, so the race
/// is purely about speed there too.
fn candidates(c: &ConvIR, elem: ElemType) -> Vec<KernelChoice> {
    let analytic = default_choice(c);
    let rt = analytic.row_tile;
    let mk = |kind, row_tile, fblock| KernelChoice {
        kind,
        row_tile,
        fblock,
        tuned: false,
    };
    if elem == ElemType::I8 {
        // quant kernels ignore the tile parameters today; keep the
        // analytic tile so a tiled variant can slot into the same grid
        return vec![
            mk(KernelKind::QuantScalar, rt, 1),
            mk(KernelKind::QuantVec, rt, 1),
        ];
    }
    let mut v = vec![
        mk(KernelKind::PatternScalar, rt, 1),
        mk(KernelKind::PatternVec, rt, 1),
        mk(KernelKind::PatternTiled, rt, 1),
    ];
    let mut tiles = vec![rt];
    for t in [LANES as u16, (2 * LANES) as u16] {
        if t != rt && (t as usize) <= ROW_TILE_CAP {
            tiles.push(t);
        }
    }
    let fbs: &[u16] = &[2, analytic.fblock.max(1)];
    for &t in &tiles {
        for &fb in fbs {
            let cand = mk(KernelKind::PatternVecTiled, t, fb);
            if !v.contains(&cand) {
                v.push(cand);
            }
        }
    }
    v
}

/// Execute one full layer with `kind` through the executor's own block
/// dispatch (block 0 on the calling thread, the rest on scoped
/// workers) so the measurement sees the plan's real (layer,
/// thread-count) shape.
fn run_layer_once(
    c: &ConvIR,
    lp: &LayerPlan,
    kind: KernelKind,
    input: ConvInput<'_>,
    qacc: &mut [i32],
    out: &mut [f32],
) {
    let planes = OutPlanes::new(out, lp.out_hw * lp.out_hw);
    engine::dispatch_blocks(
        c,
        lp,
        engine::kernel(kind),
        input,
        qacc,
        &planes,
    );
}

/// Empirical plan-time autotuner for one layer: times every candidate
/// shape on the layer's real packed payload and block partition, bakes
/// the winner into `lp.choice`, and returns the full timing table.
///
/// The input fmap is synthetic (seeded, per-layer stream) — only time
/// is measured, and kernel results are data-independent bit-identical
/// across candidates, so the tuner can never change numerics.
pub fn autotune_layer(
    c: &ConvIR,
    lp: &mut LayerPlan,
    layer: usize,
    cfg: &TuneConfig,
) -> LayerTune {
    let elem = lp.payload.elem();
    let cands = candidates(c, elem);
    let mut best_ms = vec![f64::INFINITY; cands.len()];
    let mut rng = Pcg32::new(0x5eed, layer as u64);
    let xdata: Vec<f32> = (0..lp.c * lp.in_hw * lp.in_hw)
        .map(|_| rng.normal())
        .collect();
    let x = Chw::new(lp.c, lp.in_hw, &xdata);
    let mut qbuf: Vec<i8> = Vec::new();
    let input = match elem {
        ElemType::F32 => ConvInput::f32(x),
        ElemType::I8 => {
            qbuf.resize(xdata.len(), 0);
            let scale = engine::quantize_activations(&xdata, &mut qbuf);
            ConvInput {
                x,
                qx: Some(QuantView {
                    data: &qbuf,
                    scale,
                }),
            }
        }
    };
    let mut qacc = match elem {
        ElemType::F32 => Vec::new(),
        ElemType::I8 => {
            vec![0i32; lp.blocks.len().max(1) * lp.out_hw * lp.out_hw]
        }
    };
    let mut out = vec![0.0f32; lp.out_elems()];
    let reps = cfg.reps.max(1);
    for _round in 0..cfg.rounds.max(1) {
        for (ci, cand) in cands.iter().enumerate() {
            lp.choice = *cand;
            // one warm-up pulls the payload and fmap into cache
            run_layer_once(c, lp, cand.kind, input, &mut qacc, &mut out);
            let t = Stopwatch::start();
            for _ in 0..reps {
                run_layer_once(
                    c,
                    lp,
                    cand.kind,
                    input,
                    &mut qacc,
                    &mut out,
                );
            }
            let ms = t.ms() / reps as f64;
            if ms < best_ms[ci] {
                best_ms[ci] = ms;
            }
        }
    }
    let mut winner = 0;
    for i in 1..cands.len() {
        if best_ms[i] < best_ms[winner] {
            winner = i;
        }
    }
    let mut chosen = cands[winner];
    chosen.tuned = true;
    lp.choice = chosen;
    LayerTune {
        layer,
        chosen,
        timings: cands.into_iter().zip(best_ms).collect(),
    }
}

/// Predicted end-to-end single-frame latency (ms).
pub fn latency_ms(
    model: &AnalyticModel,
    engine: &EngineModel,
    target: &Target,
    device: Device,
) -> f64 {
    let (peak_gflops, gbps, eff) = match device {
        Device::Cpu => (target.cpu_gflops, target.cpu_gbps, engine.cpu_eff),
        Device::Gpu => (target.gpu_gflops, target.gpu_gbps, engine.gpu_eff),
    };
    let mut total = 0.0;
    for l in &model.layers {
        let (macs, wbytes, eff_l) = if engine.sparse_aware {
            // LRE + reorder recover part of the sparse-codelet penalty;
            // cap the combined recovery at 2x.
            let bonus =
                (1.0 + 0.35 * (l.lre_gain - 1.0) + 0.10 * (l.reorder_gain - 1.0).min(3.0))
                    .min(2.0);
            (
                l.sparse_macs,
                l.compressed_bytes,
                eff * engine.sparse_penalty * bonus,
            )
        } else {
            (l.dense_macs, l.dense_bytes, eff)
        };
        let flops = 2.0 * macs as f64;
        let t_compute = flops / (peak_gflops * 1e9 * eff_l);
        let bytes = (wbytes + l.act_bytes) as f64;
        // memory efficiency tracks kernel quality (tiling locality)
        let t_mem = bytes / (gbps * 1e9 * (eff_l * 2.5).min(0.85));
        total += t_compute.max(t_mem) + engine.layer_overhead_ms * 1e-3;
    }
    total * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_r18(engine: &EngineModel) -> (f64, f64) {
        let m = AnalyticModel::paper_scale(
            "resnet18",
            &resnet18_imagenet(),
            6.0,
            1.8,
            2.0,
        );
        (
            latency_ms(&m, engine, &GALAXY_S10, Device::Cpu),
            latency_ms(&m, engine, &GALAXY_S10, Device::Gpu),
        )
    }

    #[test]
    fn resnet18_calibration_matches_paper_band() {
        // Paper: ours 25ms CPU; 4.2x vs TFLite, 2.3x vs TVM, 2.1x vs MNN.
        let (ours_cpu, _) = paper_r18(&OURS);
        assert!(
            (18.0..32.0).contains(&ours_cpu),
            "ours cpu {ours_cpu:.1}ms"
        );
        let (tfl, _) = paper_r18(&TFLITE);
        let (tvm, _) = paper_r18(&TVM);
        let (mnn, _) = paper_r18(&MNN);
        let s_tfl = tfl / ours_cpu;
        let s_tvm = tvm / ours_cpu;
        let s_mnn = mnn / ours_cpu;
        assert!((3.0..5.5).contains(&s_tfl), "tflite speedup {s_tfl:.2}");
        assert!((1.8..3.0).contains(&s_tvm), "tvm speedup {s_tvm:.2}");
        assert!((1.6..2.8).contains(&s_mnn), "mnn speedup {s_mnn:.2}");
        // ordering: tflite slowest, ours fastest
        assert!(tfl > tvm && tvm >= mnn && mnn > ours_cpu);
    }

    #[test]
    fn gpu_is_faster_than_cpu_for_all_engines() {
        for e in &ALL_ENGINES {
            let (cpu, gpu) = paper_r18(e);
            assert!(gpu < cpu, "{}: gpu {gpu} >= cpu {cpu}", e.name);
        }
    }

    #[test]
    fn ours_meets_realtime_on_both_models() {
        // Paper: real-time = 33 ms/frame; both testing models satisfy it.
        let r18 = AnalyticModel::paper_scale(
            "resnet18",
            &resnet18_imagenet(),
            6.0,
            1.8,
            2.0,
        );
        let vgg = AnalyticModel::paper_scale(
            "vgg16",
            &vgg16_cifar(),
            12.0,
            1.8,
            2.0,
        );
        for m in [&r18, &vgg] {
            let t = latency_ms(m, &OURS, &GALAXY_S10, Device::Cpu);
            assert!(t < 33.0, "{}: {t:.1}ms", m.name);
        }
    }

    #[test]
    fn filter_exec_cost_orders_by_work() {
        use crate::config::Act;
        use crate::mobile::ir::ConvIR;
        use crate::tensor::Tensor;
        let c = ConvIR {
            op_idx: 0,
            a: 3,
            c: 2,
            kh: 3,
            kw: 3,
            stride: 1,
            act: Act::Relu,
            in_hw: 8,
            out_hw: 8,
            w: Tensor::zeros(&[3, 2, 3, 3]),
            bias: Tensor::zeros(&[3]),
            // filter 0: two 4-tap kernels; filter 1: one 2-tap kernel;
            // filter 2: fully connectivity-pruned
            pattern: vec![0b1111, 0b1111, 0b11, 0, 0, 0],
            tag: String::new(),
            is_proj: false,
        };
        let c0 = filter_exec_cost(&c, 0);
        let c1 = filter_exec_cost(&c, 1);
        let c2 = filter_exec_cost(&c, 2);
        assert!(c0 > c1 && c1 > c2, "{c0} {c1} {c2}");
        assert_eq!(c2, 64);
    }

    #[test]
    fn quantized_choice_projects_onto_quant_kernels() {
        let ch = KernelChoice {
            kind: KernelKind::PatternVecTiled,
            row_tile: 16,
            fblock: 4,
            tuned: true,
        };
        let q = quantized_choice(ch);
        assert_eq!(q.kind, KernelKind::QuantVec);
        assert_eq!(q.row_tile, 16);
        assert_eq!(q.fblock, 4);
        assert!(q.tuned);
        assert_eq!(quantized_choice(q), q);
    }

    #[test]
    fn sparse_awareness_is_the_differentiator() {
        // same kernel quality without sparse execution ≈ MNN-class time
        let m = AnalyticModel::paper_scale(
            "resnet18",
            &resnet18_imagenet(),
            6.0,
            1.8,
            2.0,
        );
        let dense_ours = EngineModel {
            sparse_aware: false,
            ..OURS
        };
        let t_dense = latency_ms(&m, &dense_ours, &GALAXY_S10, Device::Cpu);
        let t_sparse = latency_ms(&m, &OURS, &GALAXY_S10, Device::Cpu);
        assert!(
            t_dense / t_sparse > 2.5,
            "sparse gain only {:.2}x",
            t_dense / t_sparse
        );
    }
}
