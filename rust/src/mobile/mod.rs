//! Compiler-assisted mobile acceleration framework (paper §V-C, Fig. 3).
//!
//! The paper ships pattern-pruned models through a compiler with three
//! pattern-enabled optimizations — filter kernel reorder, compressed weight
//! storage, and load redundancy elimination — and measures end-to-end
//! inference on a Samsung Galaxy S10 against TFLite/TVM/MNN.
//!
//! Here the passes are implemented for real over a layer-wise weight IR
//! ([`ir`]), the generated sparse form actually executes on the host CPU
//! ([`engine`], verified bit-for-bit against the PJRT reference), and a
//! calibrated analytical cost model ([`costmodel`]) translates the
//! operation/byte counts into Kryo-485/Adreno-640-class latencies for the
//! Fig. 3 comparison (DESIGN.md §2 and §5 document the substitution).

pub mod costmodel;
pub mod engine;
pub mod ir;
pub mod passes;
