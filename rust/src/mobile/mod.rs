//! Compiler-assisted mobile acceleration framework (paper §V-C, Fig. 3).
//!
//! The paper ships pattern-pruned models through a compiler with three
//! pattern-enabled optimizations — filter kernel reorder, compressed weight
//! storage, and load redundancy elimination — and measures end-to-end
//! inference on a Samsung Galaxy S10 against TFLite/TVM/MNN.
//!
//! The stack is split into a compile phase and an execute phase:
//!
//! * [`ir`] — layer-wise weight IR extracted from a (pruned) parameter set;
//! * [`passes`] — the three compiler passes and the [`passes::CompileReport`]
//!   that quantifies them;
//! * [`plan`] — the [`plan::PassManager`] lowers the IR into an
//!   [`plan::ExecutionPlan`]: packed payload buffers, row-grouped codelets
//!   resolved once, cost-balanced per-thread filter blocks, and exact
//!   arena sizing;
//! * [`engine`] — the thin multi-threaded executor over a plan, with a
//!   [`engine::ConvKernel`] registry (dense reference, pattern-sparse
//!   scalar, row-tiled, and width-vectorized variants) and batch entry
//!   points;
//! * [`simd`] — the fixed-width f32 lane arithmetic behind the
//!   vectorized kernels (auto-vectorized, no intrinsics);
//! * [`costmodel`] — a calibrated analytical model translating the pass
//!   outputs into Kryo-485/Adreno-640-class latencies for the Fig. 3
//!   comparison (DESIGN.md §2 and §5 document the substitution);
//! * [`synth`] — synthetic in-Rust model specs so all of the above tests
//!   and benches without PJRT artifacts.

pub mod costmodel;
pub mod engine;
pub mod ir;
pub mod passes;
pub mod plan;
pub mod simd;
pub mod synth;
