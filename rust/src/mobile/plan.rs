//! Compile phase of the mobile stack (the plan side of the plan/executor
//! split).
//!
//! [`PassManager`] lowers a [`ModelIR`] through the three pattern-enabled
//! compiler passes of paper §V-C — filter kernel reorder, compressed weight
//! storage, load redundancy elimination — into an [`ExecutionPlan`]:
//!
//! * per layer a [`LayerPlan`] with one **contiguous packed payload
//!   buffer** (no per-kernel `Vec`s), the pattern-style row-grouped
//!   codelets resolved **once** at compile time, and the reordered filter
//!   schedule pre-partitioned into per-thread [`FilterBlock`]s
//!   load-balanced with [`costmodel::filter_exec_cost`];
//! * the op stream lowered to [`PlanStep`]s with every residual tag
//!   resolved to an arena slot index and every intermediate shape computed
//!   at compile time;
//! * exact sizing for a ping-pong [`Arena`] so the execute phase performs
//!   **zero heap allocations** per inference.
//!
//! The executor ([`super::engine`]) is a thin interpreter over this plan;
//! every future backend (SIMD, quantized, sharded serving) plugs in behind
//! the same boundary.

use anyhow::{bail, Result};

use crate::config::Act;
use crate::tensor::ScratchBuf;
use crate::util::Stopwatch;

use super::costmodel::{self, KernelChoice, TuneConfig, TuneReport};
use super::ir::{CompressedLayer, ConvIR, IrOp, ModelIR};
use super::passes::{self, CompileReport, StyleRows};

/// Padding per JAX 'SAME': out = ceil(in/s); lo = pad_total/2.
pub fn same_pad_lo(in_hw: usize, k: usize, stride: usize) -> (usize, i64) {
    let out = in_hw.div_ceil(stride);
    let pad_total = ((out - 1) * stride + k).saturating_sub(in_hw);
    (out, (pad_total / 2) as i64)
}

/// Element representation of the packed payload (DESIGN.md §14). `F32`
/// is the bit-exact default; `I8` stores symmetric per-filter-quantized
/// taps with an `f32` scale table and executes with exact `i32`
/// accumulation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElemType {
    F32,
    I8,
}

impl ElemType {
    pub fn name(self) -> &'static str {
        match self {
            ElemType::F32 => "f32",
            ElemType::I8 => "i8",
        }
    }
}

/// A layer's packed taps, generic over element representation. The
/// variants deliberately share the slot layout — `taps[k.off + slot]`
/// addresses the same logical weight in both — so every kernel walks
/// identical codelets and only the element arithmetic differs.
#[derive(Clone, Debug)]
pub enum Payload {
    /// full-precision taps (the packing pass output, byte-for-byte the
    /// pre-refactor `Vec<f32>` payload)
    F32(Vec<f32>),
    /// symmetric per-filter quantization: `w ≈ taps as f32 * scales[f]`
    /// where `f` is the filter owning the kernel the tap belongs to
    I8 { taps: Vec<i8>, scales: Vec<f32> },
}

impl Payload {
    /// Tap count (element layout is identical across representations).
    pub fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::I8 { taps, .. } => taps.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn elem(&self) -> ElemType {
        match self {
            Payload::F32(_) => ElemType::F32,
            Payload::I8 { .. } => ElemType::I8,
        }
    }

    /// Serialized footprint: 4 bytes per f32 tap, or 1 byte per i8 tap
    /// plus 4 per per-filter scale.
    pub fn bytes(&self) -> usize {
        match self {
            Payload::F32(v) => 4 * v.len(),
            Payload::I8 { taps, scales } => taps.len() + 4 * scales.len(),
        }
    }

    /// Full-precision taps. Panics on a quantized payload — the executor
    /// maps every kernel selection onto the plan's element type, so an
    /// f32 kernel can never be dispatched on an i8 plan.
    pub fn f32_taps(&self) -> &[f32] {
        match self {
            Payload::F32(v) => v,
            Payload::I8 { .. } => {
                panic!("f32 tap view requested on an i8 payload")
            }
        }
    }

    /// Quantized taps plus the per-filter scale table (panics on f32,
    /// mirroring [`Payload::f32_taps`]).
    pub fn i8_taps(&self) -> (&[i8], &[f32]) {
        match self {
            Payload::I8 { taps, scales } => (taps, scales),
            Payload::F32(_) => {
                panic!("i8 tap view requested on an f32 payload")
            }
        }
    }
}

/// Header of one kept kernel in a layer's packed payload buffer: channel,
/// pattern-style index, and the offset of its taps in
/// [`LayerPlan::payload`]. The payload length is implicit — it equals the
/// style's tap count, and the row-grouped codelet indexes it by slot.
#[derive(Clone, Copy, Debug)]
pub struct PackedKernel {
    pub ch: u32,
    pub style: u16,
    pub off: u32,
}

/// Contiguous span of the reordered filter schedule assigned to one worker
/// thread, with its modeled cost (for reporting / balance assertions).
#[derive(Clone, Debug)]
pub struct FilterBlock {
    /// range into [`LayerPlan::exec_order`]
    pub span: std::ops::Range<usize>,
    pub cost: u64,
}

/// One conv layer lowered to directly executable form.
#[derive(Clone, Debug)]
pub struct LayerPlan {
    /// index into `ExecutionPlan::ir.convs` (dense weights for the
    /// reference kernel live there)
    pub conv: usize,
    pub a: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub in_hw: usize,
    pub out_hw: usize,
    pub pad: i64,
    pub act: Act,
    pub bias: Vec<f32>,
    /// all kept kernels' taps, packed back to back
    pub payload: Payload,
    /// kept-kernel headers, grouped per filter
    pub kernels: Vec<PackedKernel>,
    /// per original filter index: its span in `kernels`
    pub filter_ranges: Vec<std::ops::Range<usize>>,
    /// distinct pattern styles of the layer
    pub styles: Vec<u16>,
    /// per style: row-grouped codelet, resolved once at compile time
    pub style_rows: Vec<StyleRows>,
    /// filter schedule after the reorder pass
    pub exec_order: Vec<usize>,
    /// per-thread partition of `exec_order` (cost-balanced, non-empty)
    pub blocks: Vec<FilterBlock>,
    /// conv kernel shape for auto dispatch: the analytic default, or
    /// the autotuner's measured winner on a tuned plan
    pub choice: KernelChoice,
}

impl LayerPlan {
    pub fn build(
        conv: usize,
        c: &ConvIR,
        comp: &CompressedLayer,
        exec_order: Vec<usize>,
        threads: usize,
    ) -> Self {
        let styles = comp.styles.clone();
        let style_rows: Vec<StyleRows> = styles
            .iter()
            .map(|&pat| passes::row_group(pat, c.kh, c.kw))
            .collect();
        let mut payload = Vec::new();
        let mut kernels = Vec::new();
        let mut filter_ranges = Vec::with_capacity(c.a);
        for f in 0..c.a {
            let start = kernels.len();
            for (ch, style, taps) in &comp.filters[f] {
                kernels.push(PackedKernel {
                    ch: *ch,
                    style: *style,
                    off: payload.len() as u32,
                });
                payload.extend_from_slice(taps);
            }
            filter_ranges.push(start..kernels.len());
        }
        let (out_hw, pad) = same_pad_lo(c.in_hw, c.kh, c.stride);
        debug_assert_eq!(out_hw, c.out_hw);
        // the OutPlanes aliasing argument rests on this: exec_order must
        // be a duplicate-free permutation of 0..a, or two worker blocks
        // could hold &mut to the same output plane
        debug_assert!(
            {
                let mut seen = vec![false; c.a];
                exec_order.len() == c.a
                    && exec_order.iter().all(|&f| {
                        f < c.a && !std::mem::replace(&mut seen[f], true)
                    })
            },
            "exec_order is not a permutation of 0..{}",
            c.a
        );
        let blocks = balance_blocks(c, &exec_order, threads);
        LayerPlan {
            conv,
            a: c.a,
            c: c.c,
            kh: c.kh,
            kw: c.kw,
            stride: c.stride,
            in_hw: c.in_hw,
            out_hw,
            pad,
            act: c.act,
            bias: comp.bias.clone(),
            payload: Payload::F32(payload),
            kernels,
            filter_ranges,
            styles,
            style_rows,
            exec_order,
            blocks,
            choice: costmodel::default_choice(c),
        }
    }

    /// Compile a single conv layer standalone (reorder + compress + pack):
    /// the harness the kernel property-tests drive.
    pub fn for_conv(c: &ConvIR, threads: usize) -> Self {
        let order = passes::reorder_filters(c);
        let comp = CompressedLayer::compress(c);
        LayerPlan::build(0, c, &comp, order, threads)
    }

    pub fn out_elems(&self) -> usize {
        self.a * self.out_hw * self.out_hw
    }

    /// Post-training symmetric per-filter quantization of the packed
    /// payload (DESIGN.md §14): per filter, `scale = maxabs / 127` over
    /// all of its kept taps (1.0 for an all-zero filter so requantize
    /// never divides by zero), and every tap becomes
    /// `round(w / scale)` clamped to ±127. `f32::round` ties away from
    /// zero deterministically, so the scale table and the i8 taps are a
    /// pure function of the f32 payload. No-op on an already-quantized
    /// payload.
    pub fn quantize(&mut self) {
        let Payload::F32(taps) = &self.payload else {
            return;
        };
        let mut scales = vec![1.0f32; self.a];
        for (f, r) in self.filter_ranges.iter().enumerate() {
            let mut maxabs = 0.0f32;
            for k in &self.kernels[r.clone()] {
                let n = self.styles[k.style as usize].count_ones() as usize;
                for &v in &taps[k.off as usize..k.off as usize + n] {
                    maxabs = maxabs.max(v.abs());
                }
            }
            if maxabs > 0.0 {
                scales[f] = maxabs / 127.0;
            }
        }
        let mut q = vec![0i8; taps.len()];
        for (f, r) in self.filter_ranges.iter().enumerate() {
            let inv = 1.0 / scales[f];
            for k in &self.kernels[r.clone()] {
                let n = self.styles[k.style as usize].count_ones() as usize;
                let off = k.off as usize;
                for i in 0..n {
                    let v = (taps[off + i] * inv).round();
                    q[off + i] = v.clamp(-127.0, 127.0) as i8;
                }
            }
        }
        self.payload = Payload::I8 { taps: q, scales };
    }
}

/// Partition the reordered filter schedule into at most `threads`
/// contiguous, cost-balanced, non-empty blocks. Contiguity preserves the
/// reorder pass's style grouping inside each worker; the greedy split
/// re-targets the remaining budget after each block so early overshoot
/// doesn't starve the tail.
fn balance_blocks(
    c: &ConvIR,
    exec_order: &[usize],
    threads: usize,
) -> Vec<FilterBlock> {
    let n = exec_order.len();
    let t = threads.max(1).min(n.max(1));
    let costs: Vec<u64> = exec_order
        .iter()
        .map(|&f| costmodel::filter_exec_cost(c, f))
        .collect();
    let mut remaining: u64 = costs.iter().sum();
    let mut blocks = Vec::with_capacity(t);
    let mut start = 0usize;
    let mut acc = 0u64;
    for (i, &cost) in costs.iter().enumerate() {
        acc += cost;
        let blocks_left = (t - blocks.len()) as u64;
        let filters_left = n - i - 1;
        // close the block once it reaches its fair share, or when the
        // remaining filters are exactly enough to keep later blocks
        // non-empty
        let target = remaining / blocks_left.max(1);
        if blocks.len() + 1 < t
            && i + 1 < n
            && (acc >= target || filters_left <= t - blocks.len() - 1)
        {
            remaining -= acc;
            blocks.push(FilterBlock {
                span: start..i + 1,
                cost: acc,
            });
            start = i + 1;
            acc = 0;
        }
    }
    blocks.push(FilterBlock {
        span: start..n,
        cost: acc,
    });
    debug_assert!(blocks.iter().all(|b| !b.span.is_empty() || n == 0));
    debug_assert_eq!(
        blocks.iter().map(|b| b.span.len()).sum::<usize>(),
        n
    );
    blocks
}

/// Feature-map shape after a schedule step.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepDims {
    pub c: usize,
    pub hw: usize,
}

impl StepDims {
    pub fn elems(&self) -> usize {
        self.c * self.hw * self.hw
    }
}

/// One lowered op: residual tags are resolved to arena slot indices, conv
/// ops to layer-plan indices — the executor interprets these with zero
/// name lookups and zero shape inference.
#[derive(Clone, Debug)]
pub enum PlanStep {
    Conv { layer: usize },
    Pool,
    Save { slot: usize },
    Proj { layer: usize, slot: usize },
    Add { slot: usize },
    Relu,
    Gap,
    Fc,
}

/// Compile-time statistics of a plan (reported by `repro deploy` and the
/// benches; per-pass wall times quantify plan construction cost).
#[derive(Clone, Debug)]
pub struct PlanStats {
    pub pass_ms: Vec<(&'static str, f64)>,
    /// packed payload taps across all layers, bytes
    pub payload_bytes: usize,
    /// packed kernel headers across all layers, bytes
    pub header_bytes: usize,
    /// preallocated arena footprint, bytes
    pub arena_bytes: usize,
    /// worker blocks across all layers
    pub n_blocks: usize,
    pub threads: usize,
}

/// The compiled model: everything the execute phase needs, resolved.
#[derive(Clone, Debug)]
pub struct ExecutionPlan {
    pub ir: ModelIR,
    pub layers: Vec<LayerPlan>,
    pub steps: Vec<PlanStep>,
    /// feature-map dims *after* each step (parallel to `steps`)
    pub dims: Vec<StepDims>,
    /// input image dims
    pub in_dims: StepDims,
    /// element size of each residual save slot
    pub slot_sizes: Vec<usize>,
    /// max elements either ping-pong buffer must hold
    pub fmap_elems: usize,
    /// max elements a Proj output needs (0 when the model has none)
    pub proj_scratch_elems: usize,
    /// channel count entering Gap
    pub gap_len: usize,
    pub threads: usize,
    /// element representation of every layer payload (`F32` unless the
    /// quantize pass ran)
    pub elem: ElemType,
    pub report: CompileReport,
    pub stats: PlanStats,
}

impl ExecutionPlan {
    pub fn classes(&self) -> usize {
        self.ir.classes
    }

    /// i32 accumulator elements one worker block needs for the widest
    /// conv output plane (0 on f32 plans — the arena sizes its quantized
    /// scratch from this, so the f32 path carries no extra footprint).
    pub fn qacc_elems(&self) -> usize {
        if self.elem == ElemType::F32 {
            return 0;
        }
        self.layers
            .iter()
            .map(|l| l.out_hw * l.out_hw)
            .max()
            .unwrap_or(0)
    }

    /// Structural integrity check for plans that did not come out of
    /// [`PassManager::compile`] — the artifact loader
    /// (`crate::serve::artifact`) must not trust bytes from disk, so it
    /// re-establishes here every invariant the executor's unsafe output
    /// aliasing and arena sizing rely on: per-layer exec_order
    /// permutations, block partitions, payload/style bounds, and schedule
    /// slot/layer indices.
    pub fn validate(&self) -> Result<()> {
        for (li, lp) in self.layers.iter().enumerate() {
            if lp.conv >= self.ir.convs.len() {
                bail!("layer {li}: conv index {} out of range", lp.conv);
            }
            // the dense reference kernel walks conv.w by the layer's
            // geometry, so the two must agree exactly
            let ci = &self.ir.convs[lp.conv];
            if ci.a != lp.a
                || ci.c != lp.c
                || ci.kh != lp.kh
                || ci.kw != lp.kw
                || ci.stride != lp.stride
                || ci.in_hw != lp.in_hw
                || ci.out_hw != lp.out_hw
                || ci.w.shape()
                    != [lp.a, lp.c, lp.kh, lp.kw].as_slice()
            {
                bail!(
                    "layer {li}: geometry disagrees with conv {}",
                    lp.conv
                );
            }
            // stride drives div_ceil in x_range; pad/out_hw must be the
            // SAME-padding values compile would derive
            if lp.stride == 0 {
                bail!("layer {li}: zero stride");
            }
            let (out, pad) = same_pad_lo(lp.in_hw, lp.kh, lp.stride);
            if out != lp.out_hw || pad != lp.pad {
                bail!(
                    "layer {li}: pad {}/out_hw {} inconsistent with \
                     SAME geometry ({pad}/{out})",
                    lp.pad,
                    lp.out_hw
                );
            }
            // arity before allocation: a decoded lp.a is untrusted, so
            // reject a mismatch before sizing anything by it
            if lp.exec_order.len() != lp.a {
                bail!("layer {li}: exec_order arity != {} filters", lp.a);
            }
            if lp.bias.len() != lp.a {
                bail!("layer {li}: bias arity != {} filters", lp.a);
            }
            // exec_order must be a duplicate-free permutation of 0..a
            // (the OutPlanes race-freedom argument)
            let mut seen = vec![false; lp.a];
            if !lp.exec_order.iter().all(|&f| {
                f < lp.a && !std::mem::replace(&mut seen[f], true)
            }) {
                bail!("layer {li}: exec_order is not a permutation");
            }
            // blocks partition exec_order contiguously
            let mut pos = 0usize;
            for b in &lp.blocks {
                if b.span.start != pos || b.span.end < b.span.start {
                    bail!("layer {li}: blocks do not partition exec_order");
                }
                pos = b.span.end;
            }
            if pos != lp.exec_order.len() {
                bail!("layer {li}: blocks do not cover exec_order");
            }
            // filter_ranges cover kernels contiguously, one per filter
            if lp.filter_ranges.len() != lp.a {
                bail!("layer {li}: filter_ranges arity");
            }
            let mut kpos = 0usize;
            for r in &lp.filter_ranges {
                if r.start != kpos || r.end < r.start {
                    bail!("layer {li}: filter_ranges not contiguous");
                }
                kpos = r.end;
            }
            if kpos != lp.kernels.len() {
                bail!("layer {li}: filter_ranges do not cover kernels");
            }
            if lp.style_rows.len() != lp.styles.len() {
                bail!("layer {li}: style_rows/styles arity");
            }
            // tile parameters drive loop strides in the tiled kernels;
            // zero would spin forever, so reject it at load time even
            // though the kernels also clamp defensively
            if lp.choice.row_tile == 0 || lp.choice.fblock == 0 {
                bail!(
                    "layer {li}: kernel choice has zero tile \
                     (row_tile {}, fblock {})",
                    lp.choice.row_tile,
                    lp.choice.fblock
                );
            }
            for k in &lp.kernels {
                let style = k.style as usize;
                if style >= lp.styles.len() {
                    bail!("layer {li}: kernel style {style} out of range");
                }
                if (k.ch as usize) >= lp.c {
                    bail!("layer {li}: kernel channel {} out of range", k.ch);
                }
                let taps = lp.styles[style].count_ones() as usize;
                if k.off as usize + taps > lp.payload.len() {
                    bail!("layer {li}: kernel payload out of bounds");
                }
            }
            // the executor prepares quantized inputs iff plan.elem says
            // so; a layer disagreeing would hand an f32 kernel an i8
            // payload (or starve a quant kernel of its input view)
            if lp.payload.elem() != self.elem {
                bail!(
                    "layer {li}: payload is {} but the plan is {}",
                    lp.payload.elem().name(),
                    self.elem.name()
                );
            }
            if let Payload::I8 { scales, .. } = &lp.payload {
                if scales.len() != lp.a {
                    bail!("layer {li}: scale table arity != {} filters", lp.a);
                }
                // requantization multiplies by scale; non-finite or
                // non-positive scales could only come from corruption
                if !scales.iter().all(|s| s.is_finite() && *s > 0.0) {
                    bail!("layer {li}: non-positive quantization scale");
                }
            }
        }
        if self.steps.len() != self.dims.len() {
            bail!("steps/dims arity mismatch");
        }
        for (si, step) in self.steps.iter().enumerate() {
            match step {
                PlanStep::Conv { layer } => {
                    if *layer >= self.layers.len() {
                        bail!("step {si}: conv layer {layer} out of range");
                    }
                }
                PlanStep::Proj { layer, slot } => {
                    if *layer >= self.layers.len()
                        || *slot >= self.slot_sizes.len()
                    {
                        bail!("step {si}: proj layer/slot out of range");
                    }
                }
                PlanStep::Save { slot } | PlanStep::Add { slot } => {
                    if *slot >= self.slot_sizes.len() {
                        bail!("step {si}: slot {slot} out of range");
                    }
                }
                _ => {}
            }
        }
        if !matches!(self.steps.last(), Some(PlanStep::Fc)) {
            bail!("plan does not end in an fc step");
        }
        // replay the schedule's shape chain (what lower_schedule
        // established at compile time): every conv input must match the
        // running feature-map dims, so step reads always fit the
        // fmap-sized arena buffers
        let mut cur = self.in_dims;
        for (si, (step, d)) in
            self.steps.iter().zip(&self.dims).enumerate()
        {
            let expect = match step {
                PlanStep::Conv { layer } => {
                    let lp = &self.layers[*layer];
                    if lp.c != cur.c || lp.in_hw != cur.hw {
                        bail!(
                            "step {si}: conv expects ({}, {}hw), chain \
                             has ({}, {}hw)",
                            lp.c,
                            lp.in_hw,
                            cur.c,
                            cur.hw
                        );
                    }
                    StepDims {
                        c: lp.a,
                        hw: lp.out_hw,
                    }
                }
                PlanStep::Pool => StepDims {
                    c: cur.c,
                    hw: cur.hw / 2,
                },
                _ => cur,
            };
            if *d != expect {
                bail!(
                    "step {si}: recorded dims ({}, {}hw) != derived \
                     ({}, {}hw)",
                    d.c,
                    d.hw,
                    expect.c,
                    expect.hw
                );
            }
            cur = expect;
        }
        // fc head: the executor indexes fc_w rows by class and reads
        // fc_w.cols() gap entries, so mismatched decoded tensors must
        // fail here, not panic mid-inference
        let classes = self.ir.classes;
        if self.ir.fc_w.shape().len() != 2
            || self.ir.fc_w.rows() != classes
            || self.ir.fc_b.len() != classes
        {
            bail!(
                "fc head {:?}/{:?} does not match {classes} classes",
                self.ir.fc_w.shape(),
                self.ir.fc_b.shape()
            );
        }
        if self.ir.fc_w.cols() > self.gap_len {
            bail!(
                "fc input dim {} exceeds gap buffer {}",
                self.ir.fc_w.cols(),
                self.gap_len
            );
        }
        // arena sizing must equal the schedule-derived maximum (what the
        // compiler computes), so a corrupt size can neither starve the
        // ping-pong buffers nor balloon the allocation
        let max_elems = self
            .dims
            .iter()
            .map(|d| d.elems())
            .fold(self.in_dims.elems(), usize::max);
        if self.fmap_elems != max_elems {
            bail!(
                "fmap_elems {} != schedule maximum {max_elems}",
                self.fmap_elems
            );
        }
        // every other arena input is schedule-derivable too; recompute
        // them exactly as lower_schedule does (slot/layer indices were
        // range-checked above)
        let mut slots = vec![0usize; self.slot_sizes.len()];
        let mut proj_scratch = 0usize;
        let mut gap = 0usize;
        for (step, d) in self.steps.iter().zip(&self.dims) {
            match step {
                PlanStep::Save { slot } => {
                    slots[*slot] = slots[*slot].max(d.elems());
                }
                PlanStep::Proj { layer, slot } => {
                    let out = self.layers[*layer].out_elems();
                    slots[*slot] = slots[*slot].max(out);
                    proj_scratch = proj_scratch.max(out);
                }
                PlanStep::Gap => gap = gap.max(d.c),
                _ => {}
            }
        }
        if self.slot_sizes != slots
            || self.proj_scratch_elems != proj_scratch
            || self.gap_len != gap
        {
            bail!(
                "arena sizing (slots {:?}, proj {}, gap {}) disagrees \
                 with the schedule (slots {slots:?}, proj \
                 {proj_scratch}, gap {gap})",
                self.slot_sizes,
                self.proj_scratch_elems,
                self.gap_len
            );
        }
        Ok(())
    }
}

/// The pass pipeline. Passes run in a fixed order (reorder → compress →
/// pack/row-group → schedule lowering), each timed into
/// [`PlanStats::pass_ms`]. With [`PassManager::with_tuning`] an extra
/// autotune pass measures candidate kernel shapes per layer on the real
/// packed payload and bakes the winners into the plan.
pub struct PassManager {
    threads: usize,
    tune: Option<TuneConfig>,
    quantize: bool,
}

impl PassManager {
    pub fn new(threads: usize) -> Self {
        PassManager {
            threads: threads.max(1),
            tune: None,
            quantize: false,
        }
    }

    /// Enable the empirical kernel autotuner
    /// ([`costmodel::autotune_layer`]) as a final compile pass.
    pub fn with_tuning(mut self, cfg: TuneConfig) -> Self {
        self.tune = Some(cfg);
        self
    }

    /// Enable the post-training INT8 quantization pass
    /// ([`LayerPlan::quantize`]): per-filter scale tables are computed
    /// at compile time, every layer's baked kernel choice is remapped
    /// onto the quantized codelets, and (when tuning is also enabled)
    /// the autotuner races the quantized candidate grid.
    pub fn with_quantize(mut self) -> Self {
        self.quantize = true;
        self
    }

    pub fn compile(&self, ir: ModelIR) -> Result<ExecutionPlan> {
        self.compile_reported(ir).map(|(plan, _)| plan)
    }

    /// Compile and also return the autotuner's timing tables (empty
    /// `None` unless [`PassManager::with_tuning`] was set).
    pub fn compile_reported(
        &self,
        ir: ModelIR,
    ) -> Result<(ExecutionPlan, Option<TuneReport>)> {
        let mut pass_ms = Vec::new();

        let t = Stopwatch::start();
        let orders: Vec<Vec<usize>> =
            ir.convs.iter().map(passes::reorder_filters).collect();
        pass_ms.push(("reorder", t.ms()));

        let t = Stopwatch::start();
        let compressed: Vec<CompressedLayer> =
            ir.convs.iter().map(CompressedLayer::compress).collect();
        pass_ms.push(("compress", t.ms()));

        // lower the schedule before packing: it validates the op stream's
        // shape chain, so a malformed IR fails here instead of producing
        // layer plans with inconsistent geometry
        let t = Stopwatch::start();
        let sched = lower_schedule(&ir)?;
        pass_ms.push(("schedule", t.ms()));

        let t = Stopwatch::start();
        let mut layers: Vec<LayerPlan> = ir
            .convs
            .iter()
            .zip(orders.iter())
            .enumerate()
            .map(|(i, (c, order))| {
                LayerPlan::build(
                    i,
                    c,
                    &compressed[i],
                    order.clone(),
                    self.threads,
                )
            })
            .collect();
        pass_ms.push(("pack+rowgroup", t.ms()));

        // quantization runs after packing (it rewrites the packed taps
        // in place) and before autotuning (the tuner must race the
        // payload the executor will actually stream)
        let elem = if self.quantize {
            let t = Stopwatch::start();
            for lp in layers.iter_mut() {
                lp.quantize();
                lp.choice = costmodel::quantized_choice(lp.choice);
            }
            pass_ms.push(("quantize", t.ms()));
            ElemType::I8
        } else {
            ElemType::F32
        };

        // empirical kernel autotuning runs last: it needs the packed
        // payload and the thread-block partition exactly as the
        // executor will see them
        let tune_report = self.tune.as_ref().map(|cfg| {
            let t = Stopwatch::start();
            let tuned = layers
                .iter_mut()
                .enumerate()
                .map(|(i, lp)| {
                    costmodel::autotune_layer(
                        &ir.convs[lp.conv],
                        lp,
                        i,
                        cfg,
                    )
                })
                .collect();
            pass_ms.push(("autotune", t.ms()));
            TuneReport { layers: tuned }
        });

        let report = CompileReport::build(&ir, &compressed, &orders);

        let payload_bytes: usize =
            layers.iter().map(|l| l.payload.bytes()).sum();
        let header_bytes: usize = layers
            .iter()
            .map(|l| std::mem::size_of::<PackedKernel>() * l.kernels.len())
            .sum();
        let arena_elems = 2 * sched.fmap_elems
            + sched.slot_sizes.iter().sum::<usize>()
            + sched.proj_scratch_elems
            + sched.gap_len;
        let stats = PlanStats {
            pass_ms,
            payload_bytes,
            header_bytes,
            arena_bytes: 4 * arena_elems,
            n_blocks: layers.iter().map(|l| l.blocks.len()).sum(),
            threads: self.threads,
        };

        Ok((
            ExecutionPlan {
                ir,
                layers,
                steps: sched.steps,
                dims: sched.dims,
                in_dims: sched.in_dims,
                slot_sizes: sched.slot_sizes,
                fmap_elems: sched.fmap_elems,
                proj_scratch_elems: sched.proj_scratch_elems,
                gap_len: sched.gap_len,
                threads: self.threads,
                elem,
                report,
                stats,
            },
            tune_report,
        ))
    }
}

/// Compile `ir` into an execution plan for `threads` worker threads
/// (analytic kernel choices; deterministic).
pub fn compile_plan(ir: ModelIR, threads: usize) -> Result<ExecutionPlan> {
    PassManager::new(threads).compile(ir)
}

/// Compile with the empirical kernel autotuner enabled: every layer's
/// measured winning (kernel-kind, row-tile, filter-block) shape is
/// baked into the plan, and the per-candidate timing tables come back
/// alongside it.
pub fn compile_plan_tuned(
    ir: ModelIR,
    threads: usize,
    cfg: TuneConfig,
) -> Result<(ExecutionPlan, TuneReport)> {
    let (plan, report) = PassManager::new(threads)
        .with_tuning(cfg)
        .compile_reported(ir)?;
    Ok((plan, report.unwrap_or_default()))
}

/// Compile with post-training INT8 quantization: per-filter scale
/// tables baked at compile time, quantized codelets resolved, the
/// payload ~4x smaller than [`compile_plan`]'s.
pub fn compile_plan_quant(
    ir: ModelIR,
    threads: usize,
) -> Result<ExecutionPlan> {
    PassManager::new(threads).with_quantize().compile(ir)
}

struct Schedule {
    steps: Vec<PlanStep>,
    dims: Vec<StepDims>,
    in_dims: StepDims,
    slot_sizes: Vec<usize>,
    fmap_elems: usize,
    proj_scratch_elems: usize,
    gap_len: usize,
}

/// Lower the IR op stream: resolve residual tags to slots and compute
/// every intermediate shape, so the executor never inspects strings or
/// infers sizes.
fn lower_schedule(ir: &ModelIR) -> Result<Schedule> {
    let in_c = ir
        .ops
        .iter()
        .find_map(|op| match op {
            IrOp::Conv(ci) => Some(ir.convs[*ci].c),
            _ => None,
        })
        .unwrap_or(3);
    let in_dims = StepDims {
        c: in_c,
        hw: ir.in_hw,
    };
    let mut cur = in_dims;
    let mut fmap_elems = cur.elems();
    let mut proj_scratch_elems = 0usize;
    let mut gap_len = 0usize;
    let mut slots: Vec<usize> = Vec::new();
    let mut slot_dims: Vec<StepDims> = Vec::new();
    let mut tag_slot: std::collections::BTreeMap<String, usize> =
        std::collections::BTreeMap::new();
    let mut steps = Vec::with_capacity(ir.ops.len());
    let mut dims = Vec::with_capacity(ir.ops.len());
    let mut saw_fc = false;
    for op in &ir.ops {
        let step = match op {
            IrOp::Conv(ci) => {
                let c = &ir.convs[*ci];
                if c.c != cur.c || c.in_hw != cur.hw {
                    bail!(
                        "conv {} expects ({}, {}hw), schedule has \
                         ({}, {}hw)",
                        ci,
                        c.c,
                        c.in_hw,
                        cur.c,
                        cur.hw
                    );
                }
                cur = StepDims {
                    c: c.a,
                    hw: c.out_hw,
                };
                PlanStep::Conv { layer: *ci }
            }
            IrOp::Pool => {
                cur = StepDims {
                    c: cur.c,
                    hw: cur.hw / 2,
                };
                PlanStep::Pool
            }
            IrOp::Save { tag } => {
                let slot = *tag_slot.entry(tag.clone()).or_insert_with(|| {
                    slots.push(0);
                    slot_dims.push(cur);
                    slots.len() - 1
                });
                slots[slot] = slots[slot].max(cur.elems());
                slot_dims[slot] = cur;
                PlanStep::Save { slot }
            }
            IrOp::Proj(ci) => {
                let c = &ir.convs[*ci];
                let Some(&slot) = tag_slot.get(&c.tag) else {
                    bail!("proj references unsaved tag {:?}", c.tag);
                };
                let saved = slot_dims[slot];
                if c.c != saved.c || c.in_hw != saved.hw {
                    bail!(
                        "proj {} expects ({}, {}hw), saved tag {:?} holds \
                         ({}, {}hw)",
                        ci,
                        c.c,
                        c.in_hw,
                        c.tag,
                        saved.c,
                        saved.hw
                    );
                }
                let out = c.a * c.out_hw * c.out_hw;
                slots[slot] = slots[slot].max(out);
                slot_dims[slot] = StepDims {
                    c: c.a,
                    hw: c.out_hw,
                };
                proj_scratch_elems = proj_scratch_elems.max(out);
                PlanStep::Proj { layer: *ci, slot }
            }
            IrOp::Add { tag } => {
                let Some(&slot) = tag_slot.get(tag) else {
                    bail!("add references unsaved tag {tag:?}");
                };
                if slot_dims[slot] != cur {
                    bail!(
                        "add {tag:?}: saved fmap is ({}, {}hw) but the \
                         main path is ({}, {}hw)",
                        slot_dims[slot].c,
                        slot_dims[slot].hw,
                        cur.c,
                        cur.hw
                    );
                }
                PlanStep::Add { slot }
            }
            IrOp::Relu => PlanStep::Relu,
            IrOp::Gap => {
                gap_len = gap_len.max(cur.c);
                PlanStep::Gap
            }
            IrOp::Fc => {
                saw_fc = true;
                PlanStep::Fc
            }
        };
        fmap_elems = fmap_elems.max(cur.elems());
        steps.push(step);
        dims.push(cur);
    }
    if !saw_fc {
        bail!("model has no fc head");
    }
    Ok(Schedule {
        steps,
        dims,
        in_dims,
        slot_sizes: slots,
        fmap_elems,
        proj_scratch_elems,
        gap_len,
    })
}

/// Preallocated ping-pong buffer arena sized from the plan. Every buffer
/// is a [`ScratchBuf`], so [`Arena::alloc_events`] counts any slice
/// request that outgrew its preallocation — the executor's zero-alloc
/// invariant is `alloc_events() == 0` after construction.
#[derive(Clone, Debug)]
pub struct Arena {
    pub ping: ScratchBuf,
    pub pong: ScratchBuf,
    pub slots: Vec<ScratchBuf>,
    pub proj_scratch: ScratchBuf,
    pub gap: ScratchBuf,
    /// quantized-activation scratch (one i8 per fmap element; empty on
    /// f32 plans). Sized once here and sliced per conv step, so the
    /// quantized path keeps the zero-alloc invariant.
    pub qin: Vec<i8>,
    /// i32 accumulator planes, one max-sized plane per worker block
    /// (empty on f32 plans)
    pub qacc: Vec<i32>,
}

impl Arena {
    pub fn for_plan(p: &ExecutionPlan) -> Self {
        let qin_elems = match p.elem {
            ElemType::F32 => 0,
            ElemType::I8 => p.fmap_elems,
        };
        Arena {
            ping: ScratchBuf::with_len(p.fmap_elems),
            pong: ScratchBuf::with_len(p.fmap_elems),
            slots: p
                .slot_sizes
                .iter()
                .map(|&n| ScratchBuf::with_len(n))
                .collect(),
            proj_scratch: ScratchBuf::with_len(p.proj_scratch_elems),
            gap: ScratchBuf::with_len(p.gap_len),
            qin: vec![0; qin_elems],
            qacc: vec![0; p.threads.max(1) * p.qacc_elems()],
        }
    }

    /// Total growth events since construction (0 ⇔ the inference path has
    /// performed no heap allocation through the arena).
    pub fn alloc_events(&self) -> usize {
        self.ping.grows()
            + self.pong.grows()
            + self.slots.iter().map(|s| s.grows()).sum::<usize>()
            + self.proj_scratch.grows()
            + self.gap.grows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::tensor::Tensor;

    fn mk_conv(a: usize, c: usize, patterns: &[u16]) -> ConvIR {
        let mut rng = Pcg32::seeded(11);
        let ks = 9;
        let mut w = Tensor::zeros(&[a, c, 3, 3]);
        for ki in 0..a * c {
            let p = patterns[ki % patterns.len()];
            for t in 0..ks {
                if p & (1 << t) != 0 {
                    w.data_mut()[ki * ks + t] = rng.normal();
                }
            }
        }
        let pattern: Vec<u16> = (0..a * c)
            .map(|ki| patterns[ki % patterns.len()])
            .collect();
        ConvIR {
            op_idx: 0,
            a,
            c,
            kh: 3,
            kw: 3,
            stride: 1,
            act: Act::Relu,
            in_hw: 8,
            out_hw: 8,
            w,
            bias: Tensor::zeros(&[a]),
            pattern,
            tag: String::new(),
            is_proj: false,
        }
    }

    #[test]
    fn packed_payload_matches_compressed_layer() {
        let c = mk_conv(6, 4, &[0b000011011, 0b110110000, 0]);
        let comp = CompressedLayer::compress(&c);
        let lp = LayerPlan::for_conv(&c, 2);
        // every kept kernel appears once, payload slices agree
        let mut n = 0;
        for f in 0..c.a {
            for (i, (ch, style, taps)) in
                comp.filters[f].iter().enumerate()
            {
                let k = lp.kernels[lp.filter_ranges[f].start + i];
                assert_eq!(k.ch, *ch);
                assert_eq!(k.style, *style);
                let got = &lp.payload.f32_taps()
                    [k.off as usize..k.off as usize + taps.len()];
                assert_eq!(got, taps.as_slice());
                n += 1;
            }
        }
        assert_eq!(n, lp.kernels.len());
        assert_eq!(lp.styles, comp.styles);
        assert_eq!(lp.style_rows.len(), lp.styles.len());
    }

    #[test]
    fn quantize_builds_per_filter_scales_and_shrinks_payload() {
        let c = mk_conv(6, 4, &[0b000011011, 0b110110000, 0]);
        let mut lp = LayerPlan::for_conv(&c, 2);
        let f32_taps = lp.payload.f32_taps().to_vec();
        let f32_bytes = lp.payload.bytes();
        lp.quantize();
        assert_eq!(lp.payload.elem(), ElemType::I8);
        assert_eq!(lp.payload.len(), f32_taps.len());
        // 1 byte/tap + 4 bytes/filter scale vs 4 bytes/tap
        assert_eq!(lp.payload.bytes(), f32_taps.len() + 4 * lp.a);
        assert!(lp.payload.bytes() * 10 <= f32_bytes * 3 + 40 * lp.a);
        let (q, scales) = lp.payload.i8_taps();
        assert_eq!(scales.len(), lp.a);
        for (f, r) in lp.filter_ranges.iter().enumerate() {
            // scale = maxabs/127 over the filter's kept taps (1.0 when
            // the filter kept nothing)
            let mut maxabs = 0.0f32;
            for k in &lp.kernels[r.clone()] {
                let n = lp.styles[k.style as usize].count_ones() as usize;
                for &v in &f32_taps[k.off as usize..k.off as usize + n] {
                    maxabs = maxabs.max(v.abs());
                }
            }
            if maxabs > 0.0 {
                assert_eq!(scales[f], maxabs / 127.0, "filter {f}");
            } else {
                assert_eq!(scales[f], 1.0, "filter {f}");
            }
            // dequantized taps are within half a step of the original
            for k in &lp.kernels[r.clone()] {
                let n = lp.styles[k.style as usize].count_ones() as usize;
                for i in 0..n {
                    let idx = k.off as usize + i;
                    let back = q[idx] as f32 * scales[f];
                    assert!(
                        (back - f32_taps[idx]).abs() <= scales[f] * 0.5,
                        "tap {idx}: {} -> {back}",
                        f32_taps[idx]
                    );
                }
            }
        }
        // idempotent: a second call is a no-op
        let snapshot = q.to_vec();
        lp.quantize();
        assert_eq!(lp.payload.i8_taps().0, snapshot.as_slice());
    }

    #[test]
    fn quantized_plans_compile_validate_and_report_small_payloads() {
        use super::super::synth;
        // wide enough that taps dominate the 4-byte-per-filter scale
        // tables, as on any real model (the ≤0.3x criterion)
        let (spec, mut params) =
            synth::vgg_style("q", 16, 5, &[24, 32], 4);
        synth::pattern_prune(&spec, &mut params, 0.25);
        let ir = ModelIR::build(&spec, &params).unwrap();
        let f32_plan = compile_plan(ir.clone(), 2).unwrap();
        let q_plan = compile_plan_quant(ir, 2).unwrap();
        q_plan.validate().unwrap();
        assert_eq!(q_plan.elem, ElemType::I8);
        assert!(q_plan.qacc_elems() > 0);
        assert_eq!(f32_plan.qacc_elems(), 0);
        // acceptance criterion: quantized payload ≤ 0.3x of the f32 plan
        assert!(
            q_plan.stats.payload_bytes * 10
                <= f32_plan.stats.payload_bytes * 3,
            "i8 payload {} vs f32 {}",
            q_plan.stats.payload_bytes,
            f32_plan.stats.payload_bytes
        );
        // a layer whose elem disagrees with the plan must be rejected
        let mut bad = q_plan.clone();
        bad.layers[0].payload =
            Payload::F32(vec![0.0; bad.layers[0].payload.len()]);
        assert!(bad.validate().is_err());
        // corrupt scale tables must be rejected
        let mut bad = q_plan.clone();
        if let Payload::I8 { scales, .. } = &mut bad.layers[0].payload {
            scales[0] = -1.0;
        }
        assert!(bad.validate().is_err());
        let mut bad = q_plan;
        if let Payload::I8 { scales, .. } = &mut bad.layers[0].payload {
            scales.pop();
        }
        assert!(bad.validate().is_err());
    }

    #[test]
    fn blocks_partition_schedule_and_balance_cost() {
        let c = mk_conv(16, 4, &[0b000011011, 0b110110000, 0b000000111]);
        for threads in [1usize, 2, 3, 4, 16, 64] {
            let lp = LayerPlan::for_conv(&c, threads);
            assert!(lp.blocks.len() <= threads.max(1));
            assert!(!lp.blocks.is_empty());
            // partition: concatenated spans cover exec_order exactly
            let mut pos = 0;
            for b in &lp.blocks {
                assert_eq!(b.span.start, pos);
                assert!(!b.span.is_empty());
                pos = b.span.end;
            }
            assert_eq!(pos, lp.exec_order.len());
            if threads == 4 {
                let max = lp.blocks.iter().map(|b| b.cost).max().unwrap();
                let min = lp.blocks.iter().map(|b| b.cost).min().unwrap();
                assert!(
                    max <= 3 * min.max(1),
                    "imbalanced blocks: max {max} min {min}"
                );
            }
        }
    }

    #[test]
    fn same_pad_matches_jax() {
        // (in, k, s) -> (out, pad_lo) spot-checked against jax SAME
        assert_eq!(same_pad_lo(16, 3, 1), (16, 1));
        assert_eq!(same_pad_lo(16, 3, 2), (8, 0));
        assert_eq!(same_pad_lo(8, 3, 2), (4, 0));
        assert_eq!(same_pad_lo(16, 1, 1), (16, 0));
        assert_eq!(same_pad_lo(16, 1, 2), (8, 0));
        assert_eq!(same_pad_lo(15, 3, 2), (8, 1));
    }

    #[test]
    fn arena_sizes_from_plan_and_counts_growth() {
        use super::super::synth;
        let (spec, params) = synth::vgg_style("t", 8, 4, &[4, 6], 1);
        let ir = ModelIR::build(&spec, &params).unwrap();
        let plan = compile_plan(ir, 2).unwrap();
        let mut arena = Arena::for_plan(&plan);
        assert_eq!(arena.alloc_events(), 0);
        arena.ping.slice_mut(plan.fmap_elems);
        assert_eq!(arena.alloc_events(), 0);
        arena.ping.slice_mut(plan.fmap_elems + 1);
        assert_eq!(arena.alloc_events(), 1);
    }

    #[test]
    fn validate_accepts_compiled_plans_and_catches_tampering() {
        use super::super::synth;
        let (spec, params) = synth::res_style("val", 8, 4, &[4, 6], 2);
        let ir = ModelIR::build(&spec, &params).unwrap();
        let plan = compile_plan(ir, 2).unwrap();
        plan.validate().unwrap();
        // duplicate filter in a layer's schedule -> two worker blocks
        // could alias one output plane
        let mut bad = plan.clone();
        bad.layers[0].exec_order[0] = bad.layers[0].exec_order[1];
        assert!(bad.validate().is_err());
        // schedule step pointing past the layer table
        let mut bad = plan.clone();
        for s in bad.steps.iter_mut() {
            if let PlanStep::Conv { layer } = s {
                *layer = bad.layers.len();
                break;
            }
        }
        assert!(bad.validate().is_err());
        // kernel payload offset past the packed buffer
        let mut bad = plan.clone();
        if let Some(k) = bad.layers[0].kernels.first_mut() {
            k.off = u32::MAX;
        }
        assert!(bad.validate().is_err());
        // bias shorter than the filter count would panic o.fill(bias[f])
        let mut bad = plan.clone();
        bad.layers[0].bias.pop();
        assert!(bad.validate().is_err());
        // fc head narrower than the class count
        let mut bad = plan.clone();
        bad.ir.fc_b = crate::tensor::Tensor::zeros(&[1]);
        assert!(bad.validate().is_err());
        // ballooned arena sizing
        let mut bad = plan.clone();
        bad.fmap_elems += 1;
        assert!(bad.validate().is_err());
        // truncated block partition
        let mut bad = plan;
        bad.layers[0].blocks.pop();
        if bad.layers[0].blocks.is_empty() {
            bad.layers[0].blocks.push(FilterBlock {
                span: 0..0,
                cost: 0,
            });
        }
        assert!(bad.validate().is_err());
    }

    #[test]
    fn schedule_lowering_resolves_tags_and_dims() {
        use super::super::synth;
        let (spec, params) = synth::res_style("r", 8, 4, &[4, 8], 1);
        let ir = ModelIR::build(&spec, &params).unwrap();
        let plan = compile_plan(ir, 1).unwrap();
        // residual model: has Save/Proj/Add steps, all slots sized
        let mut saves = 0;
        let mut projs = 0;
        let mut adds = 0;
        for s in &plan.steps {
            match s {
                PlanStep::Save { slot }
                | PlanStep::Proj { slot, .. }
                | PlanStep::Add { slot } => {
                    assert!(*slot < plan.slot_sizes.len());
                    assert!(plan.slot_sizes[*slot] > 0);
                    match s {
                        PlanStep::Save { .. } => saves += 1,
                        PlanStep::Proj { .. } => projs += 1,
                        _ => adds += 1,
                    }
                }
                _ => {}
            }
        }
        assert!(saves > 0 && projs > 0 && adds > 0);
        assert_eq!(plan.steps.len(), plan.dims.len());
        assert!(plan.fmap_elems > 0);
        assert!(plan.gap_len > 0);
        // last step is Fc with classes dims recorded in ir
        assert!(matches!(plan.steps.last(), Some(PlanStep::Fc)));
    }
}
