//! Serving-tier bench: compile-once-vs-load plan artifacts, and
//! dynamic-batching throughput swept over batch window × worker counts
//! under closed-loop concurrent load (ISSUE acceptance: batching must
//! beat single-request serving at >= 8 concurrent clients on the
//! synthetic VGG spec).

use std::sync::Arc;

use repro::config::ServeConfig;
use repro::mobile::engine::KernelKind;
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::{compile_plan, ExecutionPlan};
use repro::mobile::synth;
use repro::serve::artifact;
use repro::serve::loadgen::{self, LoadGenConfig, LoadMode};
use repro::serve::server::Server;
use repro::serve::stats::{bench, section};

const CLIENTS: usize = 8;
const REQUESTS: usize = 96;

fn serve_qps(plan: &Arc<ExecutionPlan>, cfg: &ServeConfig) -> f64 {
    let server =
        Server::start(plan.clone(), KernelKind::PatternScalar, cfg);
    let load = loadgen::run(
        &server.handle(),
        plan.in_dims,
        &LoadGenConfig {
            mode: LoadMode::Closed { clients: CLIENTS },
            requests: REQUESTS,
            seed: 42,
        },
    );
    let report = server.shutdown();
    assert_eq!(report.errors, 0);
    println!(
        "serve  w={} batch={:<2} wait={:>4}us bt={}   {:>8.1} req/s   \
         p95 {:>6} us   mean batch {:.2}",
        cfg.workers,
        cfg.max_batch,
        cfg.max_wait_us,
        cfg.batch_threads,
        load.achieved_qps,
        report.latency.p95_us,
        report.mean_batch
    );
    load.achieved_qps
}

fn main() {
    let in_hw = 32;
    let (spec, mut params) =
        synth::vgg_style("bench_serve_vgg", in_hw, 10, &[32, 64], 9);
    synth::pattern_prune(&spec, &mut params, 1.0 / 8.0);
    let ir = ModelIR::build(&spec, &params).unwrap();

    section("plan compile vs artifact load (pay lowering once)");
    let mut pool: Vec<_> = (0..13).map(|_| ir.clone()).collect();
    bench("compile_plan (PassManager lowering)", 2, 10, || {
        let ir = pool.pop().expect("clone pool exhausted");
        std::hint::black_box(compile_plan(ir, 1).unwrap());
    });
    let plan = Arc::new(compile_plan(ir, 1).unwrap());
    let bytes = artifact::encode_plan(&plan);
    println!(
        "artifact size: {} bytes ({} layers)",
        bytes.len(),
        plan.layers.len()
    );
    bench("artifact encode", 2, 10, || {
        std::hint::black_box(artifact::encode_plan(&plan));
    });
    bench("artifact decode (validated load)", 2, 10, || {
        std::hint::black_box(artifact::decode_plan(&bytes).unwrap());
    });
    let dir = std::env::temp_dir()
        .join(format!("repro_bench_serve_{}", std::process::id()));
    let path = dir.join("plan.rpln");
    artifact::save(&plan, &path).unwrap();
    let loaded = artifact::load(&path).unwrap();
    artifact::verify_roundtrip(&plan, &loaded, 2, 7).unwrap();
    println!("artifact round-trip verified (bit-identical outputs)");
    std::fs::remove_dir_all(&dir).ok();

    section(format!(
        "dynamic batching vs single-request serving \
         ({CLIENTS} closed-loop clients, {REQUESTS} requests)"
    )
    .as_str());
    let single = serve_qps(
        &plan,
        &ServeConfig {
            workers: 1,
            max_batch: 1,
            max_wait_us: 0,
            queue_cap: 256,
            batch_threads: 1,
        },
    );
    // same executor-thread budget: isolates batch formation itself
    let batched = serve_qps(
        &plan,
        &ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait_us: 500,
            queue_cap: 256,
            batch_threads: 1,
        },
    );
    // the full serving tier: batching + intra-batch parallel execution
    let batched_par = serve_qps(
        &plan,
        &ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait_us: 500,
            queue_cap: 256,
            batch_threads: 4,
        },
    );
    println!(
        "batch formation alone (1 executor thread): {:.2}x; \
         dynamic batching + intra-batch parallelism: {:.2}x \
         over single-request serving",
        batched / single.max(1e-9),
        batched_par / single.max(1e-9)
    );

    section("batch window x worker sweep");
    for workers in [1usize, 2, 4] {
        for max_batch in [1usize, 4, 8] {
            for wait_us in [0u64, 200, 1000] {
                serve_qps(
                    &plan,
                    &ServeConfig {
                        workers,
                        max_batch,
                        max_wait_us: wait_us,
                        queue_cap: 256,
                        batch_threads: if max_batch > 1 { 2 } else { 1 },
                    },
                );
            }
        }
    }
}
