//! Serving-tier bench: compile-once-vs-load plan artifacts, dynamic
//! batching throughput swept over batch window × worker counts under
//! closed-loop concurrent load, and tuned-plan serving with per-layer
//! auto kernel dispatch. Results (with an environment fingerprint) land
//! in `BENCH_serve.json`; set `BENCH_SMOKE=1` for the cheap CI shape.

use std::sync::Arc;

use repro::config::ServeConfig;
use repro::mobile::costmodel::TuneConfig;
use repro::mobile::engine::{KernelKind, KernelSel};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::{
    compile_plan, compile_plan_quant, compile_plan_tuned, ExecutionPlan,
};
use repro::mobile::synth;
use repro::serve::artifact;
use repro::serve::gateway::{Gateway, Priority, TenantConfig};
use repro::serve::loadgen::{self, LoadGenConfig, LoadMode, TenantLoad};
use repro::serve::server::Server;
use repro::serve::stats::{section, BenchLog};

const CLIENTS: usize = 8;

fn serve_qps(
    plan: &Arc<ExecutionPlan>,
    kernel: KernelSel,
    cfg: &ServeConfig,
    requests: usize,
) -> f64 {
    let server = Server::builder(plan.clone())
        .config(cfg)
        .kernel(kernel)
        .spawn()
        .unwrap();
    let load = loadgen::run(
        &server.handle(),
        plan.in_dims,
        &LoadGenConfig {
            mode: LoadMode::Closed { clients: CLIENTS },
            requests,
            seed: 42,
        },
    );
    let report = server.shutdown();
    assert_eq!(report.errors, 0);
    println!(
        "serve  k={:<14} w={} batch={:<2} wait={:>4}us bt={}   \
         {:>8.1} req/s   p95 {:>6} us   mean batch {:.2}",
        kernel.name(),
        cfg.workers,
        cfg.max_batch,
        cfg.max_wait_us,
        cfg.batch_threads,
        load.achieved_qps,
        report.latency.p95_us,
        report.mean_batch
    );
    load.achieved_qps
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let requests = if smoke { 32 } else { 96 };
    let mut log =
        BenchLog::new(if smoke { "serve-smoke" } else { "serve" });

    let in_hw = 32;
    let (spec, mut params) =
        synth::vgg_style("bench_serve_vgg", in_hw, 10, &[32, 64], 9);
    synth::pattern_prune(&spec, &mut params, 1.0 / 8.0);
    let ir = ModelIR::build(&spec, &params).unwrap();

    section("plan compile vs artifact load (pay lowering once)");
    let (reps, warm) = if smoke { (4, 1) } else { (10, 2) };
    let mut pool: Vec<_> =
        (0..reps + warm + 1).map(|_| ir.clone()).collect();
    log.bench("compile_plan (PassManager lowering)", warm, reps, || {
        let ir = pool.pop().expect("clone pool exhausted");
        std::hint::black_box(compile_plan(ir, 1).unwrap());
    });
    let plan = Arc::new(compile_plan(ir.clone(), 1).unwrap());
    let bytes = artifact::encode_plan(&plan);
    println!(
        "artifact size: {} bytes ({} layers)",
        bytes.len(),
        plan.layers.len()
    );
    log.metric("artifact_bytes", bytes.len() as f64);
    log.bench("artifact encode", warm, reps, || {
        std::hint::black_box(artifact::encode_plan(&plan));
    });
    log.bench("artifact decode (validated load)", warm, reps, || {
        std::hint::black_box(artifact::decode_plan(&bytes).unwrap());
    });
    let dir = std::env::temp_dir()
        .join(format!("repro_bench_serve_{}", std::process::id()));
    let path = dir.join("plan.rpln");
    artifact::save(&plan, &path).unwrap();
    let loaded = artifact::load(&path).unwrap();
    artifact::verify_roundtrip(&plan, &loaded, 2, 7).unwrap();
    println!("artifact round-trip verified (bit-identical outputs)");
    std::fs::remove_dir_all(&dir).ok();

    section(format!(
        "dynamic batching vs single-request serving \
         ({CLIENTS} closed-loop clients, {requests} requests)"
    )
    .as_str());
    let scalar = KernelSel::Uniform(KernelKind::PatternScalar);
    let single = serve_qps(
        &plan,
        scalar,
        &ServeConfig {
            workers: 1,
            max_batch: 1,
            max_wait_us: 0,
            queue_cap: 256,
            batch_threads: 1,
        },
        requests,
    );
    // same executor-thread budget: isolates batch formation itself
    let batched = serve_qps(
        &plan,
        scalar,
        &ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait_us: 500,
            queue_cap: 256,
            batch_threads: 1,
        },
        requests,
    );
    // the full serving tier: batching + intra-batch parallel execution
    let batched_par = serve_qps(
        &plan,
        scalar,
        &ServeConfig {
            workers: 1,
            max_batch: 8,
            max_wait_us: 500,
            queue_cap: 256,
            batch_threads: 4,
        },
        requests,
    );
    println!(
        "batch formation alone (1 executor thread): {:.2}x; \
         dynamic batching + intra-batch parallelism: {:.2}x \
         over single-request serving",
        batched / single.max(1e-9),
        batched_par / single.max(1e-9)
    );
    log.metric("qps_single", single);
    log.metric("qps_batched", batched);
    log.metric("qps_batched_parallel", batched_par);
    log.metric("batching_speedup", batched / single.max(1e-9));
    log.metric(
        "batching_parallel_speedup",
        batched_par / single.max(1e-9),
    );

    section("tuned plan + per-layer auto kernel dispatch");
    let cfg =
        if smoke { TuneConfig::smoke() } else { TuneConfig::default() };
    let (tuned, report) =
        compile_plan_tuned(ir.clone(), 1, cfg).unwrap();
    println!("autotuned {} layers", report.layers.len());
    let tuned = Arc::new(tuned);
    let serve_cfg = ServeConfig {
        workers: 1,
        max_batch: 8,
        max_wait_us: 500,
        queue_cap: 256,
        batch_threads: 1,
    };
    let qps_scalar = serve_qps(&tuned, scalar, &serve_cfg, requests);
    let qps_auto =
        serve_qps(&tuned, KernelSel::Auto, &serve_cfg, requests);
    println!(
        "auto (tuned codelets) over uniform scalar: {:.2}x",
        qps_auto / qps_scalar.max(1e-9)
    );
    log.metric("qps_tuned_scalar", qps_scalar);
    log.metric("qps_tuned_auto", qps_auto);
    log.metric(
        "auto_over_scalar_speedup",
        qps_auto / qps_scalar.max(1e-9),
    );

    section("int8 quantized plan serving vs f32 (same spec, same load)");
    let qplan = Arc::new(compile_plan_quant(ir, 1).unwrap());
    log.metric(
        "artifact_bytes_i8",
        artifact::encode_plan(&qplan).len() as f64,
    );
    log.metric(
        "payload_ratio_i8",
        qplan.stats.payload_bytes as f64
            / plan.stats.payload_bytes.max(1) as f64,
    );
    let qps_f32 = serve_qps(&plan, KernelSel::Auto, &serve_cfg, requests);
    let qps_quant =
        serve_qps(&qplan, KernelSel::Auto, &serve_cfg, requests);
    println!(
        "quantized serving over f32 (auto dispatch): {:.2}x",
        qps_quant / qps_f32.max(1e-9)
    );
    log.metric("qps_f32_auto", qps_f32);
    log.metric("qps_quant_auto", qps_quant);
    log.metric(
        "quant_over_f32_speedup",
        qps_quant / qps_f32.max(1e-9),
    );

    section("batch window x worker sweep");
    let sweep_workers: &[usize] =
        if smoke { &[1, 2] } else { &[1, 2, 4] };
    for &workers in sweep_workers {
        for max_batch in [1usize, 4, 8] {
            for wait_us in [0u64, 200, 1000] {
                serve_qps(
                    &plan,
                    scalar,
                    &ServeConfig {
                        workers,
                        max_batch,
                        max_wait_us: wait_us,
                        queue_cap: 256,
                        batch_threads: if max_batch > 1 { 2 } else { 1 },
                    },
                    requests,
                );
            }
        }
    }

    section("multi-tenant gateway (shared worker pool, skewed load)");
    let names = ["hot", "warm", "cold"];
    let prios = [Priority::High, Priority::Normal, Priority::Low];
    let qps = loadgen::skewed_qps(512.0, names.len(), 1.0);
    let mut builder = Gateway::builder()
        .workers(2)
        .max_batch(8)
        .max_wait_us(500)
        .batch_threads(1);
    let mut loads = Vec::new();
    for (ti, name) in names.iter().enumerate() {
        builder = builder.tenant(
            TenantConfig::new(name).priority(prios[ti]).queue_cap(256),
            plan.clone(),
            scalar,
        );
        loads.push(TenantLoad::new(name, qps[ti], requests));
    }
    let trace = loadgen::multi_tenant_trace(&loads, None, 42);
    let gateway = builder.spawn().unwrap();
    let gw_load =
        loadgen::replay(&gateway.handle(), &loads, &trace, 42, 0.0)
            .unwrap();
    let gw_report = gateway.shutdown();
    assert_eq!(gw_load.shed + gw_load.rejected, 0);
    for c in &gw_load.per_tenant {
        let qps = c.completed as f64 / gw_load.wall_secs.max(1e-9);
        let t = gw_report.tenant(&c.tenant).expect("tenant report");
        println!(
            "gateway tenant {:<5} ({:<6}): {:>8.1} req/s   p95 {:>6} us \
             mean batch {:.2}",
            c.tenant,
            t.priority.name(),
            qps,
            t.report.latency.p95_us,
            t.report.mean_batch
        );
        log.metric(&format!("gateway_qps_{}", c.tenant), qps);
    }
    log.metric(
        "gateway_qps_total",
        gw_load.completed as f64 / gw_load.wall_secs.max(1e-9),
    );

    log.write("BENCH_serve.json").unwrap();
}
