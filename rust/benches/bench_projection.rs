//! Bench: Π_Sₙ projection throughput for all four pruning schemes (the
//! proximal step of every ADMM iteration) at the layer sizes of the model
//! zoo and at paper-scale (512×4608, ResNet-18's largest 3x3 layer).
//! Results land in `BENCH_projection.json`.

use repro::serve::stats::{section, BenchLog};
use repro::pruning::{project, project_par, LayerShape, Scheme};
use repro::rng::Pcg32;
use repro::tensor::Tensor;

fn randw(p: usize, q: usize, seed: u64) -> Tensor {
    let mut r = Pcg32::seeded(seed);
    Tensor::from_vec(&[p, q], (0..p * q).map(|_| r.normal()).collect()).unwrap()
}

fn main() {
    let mut log = BenchLog::new("projection");
    section("projection throughput (proximal step, Eqn. 11)");
    let shapes = [
        ("vgg-mini conv2 (32x288)", 32usize, 32usize),
        ("vgg-mini conv7 (128x1152)", 128, 128),
        ("resnet18 conv (512x4608)", 512, 512),
    ];
    for (name, p, c) in shapes {
        let shape = LayerShape {
            p,
            c,
            kh: 3,
            kw: 3,
        };
        let w = randw(shape.p, shape.q(), 42);
        for scheme in Scheme::all() {
            log.bench(
                &format!("{name} {}", scheme.name()),
                2,
                10,
                || {
                    std::hint::black_box(
                        project(scheme, &w, &shape, 1.0 / 8.0).unwrap(),
                    );
                },
            );
        }
    }

    section("parallel projection (project_par) thread scaling, paper-scale layer");
    let shape = LayerShape {
        p: 512,
        c: 512,
        kh: 3,
        kw: 3,
    };
    let w = randw(shape.p, shape.q(), 7);
    for scheme in [Scheme::Pattern, Scheme::Column, Scheme::Irregular] {
        for threads in [1usize, 2, 4] {
            log.bench(
                &format!("512x4608 {} par x{threads}", scheme.name()),
                2,
                10,
                || {
                    std::hint::black_box(
                        project_par(scheme, &w, &shape, 1.0 / 8.0, threads)
                            .unwrap(),
                    );
                },
            );
        }
    }

    log.write("BENCH_projection.json").unwrap();
}
