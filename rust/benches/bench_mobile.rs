//! Bench (Fig. 3): mobile plan/executor latency on a synthetic VGG-style
//! model (no PJRT artifacts required) — plan construction vs steady-state
//! execution, scalar-vs-vectorized kernel comparison at 1 and 4 threads,
//! thread scaling, the plan-time kernel autotuner, batch throughput —
//! plus the Galaxy-S10 cost-model estimates for every framework at paper
//! scale. Results (with an environment fingerprint) land in
//! `BENCH_mobile.json`; set `BENCH_SMOKE=1` for the cheap CI shape.

use repro::mobile::costmodel::{
    self, latency_ms, AnalyticModel, Device, TuneConfig, ALL_ENGINES,
    GALAXY_S10,
};
use repro::mobile::engine::{
    execute_batch_parallel, Executor, Fmap, KernelKind, KERNEL_KINDS,
};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::{
    compile_plan, compile_plan_quant, compile_plan_tuned,
};
use repro::mobile::synth;
use repro::rng::Pcg32;
use repro::serve::stats::{bench, section, BenchLog};

fn rand_image(hw: usize, seed: u64) -> Fmap {
    let mut rng = Pcg32::seeded(seed);
    Fmap {
        c: 3,
        hw,
        data: (0..3 * hw * hw).map(|_| rng.uniform()).collect(),
    }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let (reps, warm) = if smoke { (4, 1) } else { (15, 3) };
    let widths: &[usize] =
        if smoke { &[8, 12] } else { &[32, 64, 96] };
    let mut log = BenchLog::new(if smoke { "mobile-smoke" } else { "mobile" });

    let in_hw = 32;
    let (spec, mut params) =
        synth::vgg_style("bench_vgg", in_hw, 10, widths, 9);
    let img = rand_image(in_hw, 2);

    section("plan construction vs steady-state execution (8x pattern)");
    synth::pattern_prune(&spec, &mut params, 1.0 / 8.0);
    let ir = ModelIR::build(&spec, &params).unwrap();
    // pre-clone the IR outside the timed closure so the numbers measure
    // pass + lowering cost, not a deep copy of the dense weight tensors
    for threads in [1usize, 4] {
        let mut pool: Vec<_> =
            (0..reps + warm + 1).map(|_| ir.clone()).collect();
        log.bench(
            &format!("plan construction ({threads} thread(s))"),
            warm.min(2),
            reps.min(10),
            || {
                let ir = pool.pop().expect("clone pool exhausted");
                std::hint::black_box(compile_plan(ir, threads).unwrap());
            },
        );
    }
    let plan1 = compile_plan(ir.clone(), 1).unwrap();
    let mut logits = vec![0.0f32; plan1.ir.classes];
    for kind in KERNEL_KINDS {
        let mut ex = Executor::new(&plan1, kind);
        log.bench(
            &format!("execute {} (1 thread)", kind.name()),
            warm,
            reps,
            || {
                ex.execute_into(&img, &mut logits).unwrap();
                std::hint::black_box(&logits);
            },
        );
        assert_eq!(ex.alloc_events(), 0, "steady state must not allocate");
    }

    section("scalar vs vectorized pattern kernels (target: >= 1.5x)");
    // 1-thread numbers come from the registry comparison above; redo the
    // same three kernels on a 4-thread plan so the speedup is measured
    // under the real multi-threaded block partition too.
    let plan4 = compile_plan(ir.clone(), 4).unwrap();
    for kind in [
        KernelKind::PatternScalar,
        KernelKind::PatternVec,
        KernelKind::PatternVecTiled,
    ] {
        let mut ex = Executor::new(&plan4, kind);
        log.bench(
            &format!("execute {} (4 threads)", kind.name()),
            warm,
            reps,
            || {
                ex.execute_into(&img, &mut logits).unwrap();
                std::hint::black_box(&logits);
            },
        );
    }
    for threads in [1usize, 4] {
        let scalar = log
            .median_of(&format!("execute pattern-scalar ({} thread{})",
                threads, if threads == 1 { "" } else { "s" }))
            .expect("scalar entry benched above");
        for kind in [KernelKind::PatternVec, KernelKind::PatternVecTiled]
        {
            let vec_ms = log
                .median_of(&format!(
                    "execute {} ({} thread{})",
                    kind.name(),
                    threads,
                    if threads == 1 { "" } else { "s" }
                ))
                .expect("vec entry benched above");
            let speedup = scalar / vec_ms.max(1e-9);
            println!(
                "speedup {} over pattern-scalar ({} thread(s)): \
                 {speedup:.2}x (target >= 1.5x)",
                kind.name(),
                threads
            );
            log.metric(
                &format!("speedup_{}_{}t", kind.name(), threads),
                speedup,
            );
        }
    }

    section("executor thread scaling (8x pattern, scalar vs vec)");
    for kind in [KernelKind::PatternScalar, KernelKind::PatternVec] {
        let mut curve = Vec::new();
        for threads in [1usize, 2, 4, 8] {
            let plan = compile_plan(ir.clone(), threads).unwrap();
            let mut ex = Executor::new(&plan, kind);
            let r = log.bench(
                &format!("{} @ {threads} threads", kind.name()),
                warm,
                reps,
                || {
                    ex.execute_into(&img, &mut logits).unwrap();
                    std::hint::black_box(&logits);
                },
            );
            curve.push((threads, r.median_ms));
        }
        let base = curve[0].1;
        for &(threads, ms) in &curve[1..] {
            log.metric(
                &format!("scaling_{}_{}t", kind.name(), threads),
                base / ms.max(1e-9),
            );
        }
    }

    section("plan-time kernel autotuner (4 threads)");
    let cfg = if smoke { TuneConfig::smoke() } else { TuneConfig::default() };
    let t = std::time::Instant::now();
    let (tuned_plan, report) =
        compile_plan_tuned(ir.clone(), 4, cfg).unwrap();
    println!(
        "autotune: {} layers in {:.1} ms",
        report.layers.len(),
        t.elapsed().as_secs_f64() * 1e3
    );
    println!("{:>5}  {:>10}  {:<30}  {}", "layer", "geometry", "chosen",
        "candidates");
    for lt in &report.layers {
        let lp = &tuned_plan.layers[lt.layer];
        // KernelChoice's Display ignores width flags; pad the rendered
        // string so the table stays aligned
        let chosen = lt.chosen.to_string();
        println!(
            "{:>5}  {:>4}x{:<2}s{}  {chosen:<30}  {}",
            lt.layer,
            lp.a,
            lp.in_hw,
            lp.stride,
            lt.timings.len()
        );
    }
    let mut ex = Executor::auto(&tuned_plan);
    log.bench("execute autotuned plan (4 threads)", warm, reps, || {
        ex.execute_into(&img, &mut logits).unwrap();
        std::hint::black_box(&logits);
    });
    if let (Some(scalar), Some(tuned)) = (
        log.median_of("execute pattern-scalar (4 threads)"),
        log.median_of("execute autotuned plan (4 threads)"),
    ) {
        let speedup = scalar / tuned.max(1e-9);
        println!(
            "speedup autotuned over pattern-scalar (4 threads): \
             {speedup:.2}x"
        );
        log.metric("speedup_autotuned_4t", speedup);
    }

    section("int8 quantized path vs f32 (8x pattern, 4 threads)");
    let qplan4 = compile_plan_quant(ir.clone(), 4).unwrap();
    let ratio = qplan4.stats.payload_bytes as f64
        / plan4.stats.payload_bytes.max(1) as f64;
    println!(
        "payload f32 {} B -> i8 {} B ({ratio:.2}x)",
        plan4.stats.payload_bytes, qplan4.stats.payload_bytes
    );
    log.metric("payload_bytes_f32", plan4.stats.payload_bytes as f64);
    log.metric("payload_bytes_i8", qplan4.stats.payload_bytes as f64);
    log.metric("payload_ratio_i8", ratio);
    let mut fex = Executor::auto(&plan4);
    let f32_r = log.bench("execute f32 auto (4 threads)", warm, reps, || {
        fex.execute_into(&img, &mut logits).unwrap();
        std::hint::black_box(&logits);
    });
    let mut qex = Executor::auto(&qplan4);
    let i8_r = log.bench("execute i8 auto (4 threads)", warm, reps, || {
        qex.execute_into(&img, &mut logits).unwrap();
        std::hint::black_box(&logits);
    });
    let speedup_i8 = f32_r.median_ms / i8_r.median_ms.max(1e-9);
    println!("speedup i8 over f32 (4 threads, auto): {speedup_i8:.2}x");
    log.metric("speedup_i8_4t", speedup_i8);

    section("sparse executor vs compression rate (4 threads)");
    for rate in [4.0, 8.0, 12.0, 16.0] {
        let (spec_r, mut params_r) =
            synth::vgg_style("bench_vgg", in_hw, 10, widths, 9);
        synth::pattern_prune(&spec_r, &mut params_r, 1.0 / rate);
        let plan = compile_plan(
            ModelIR::build(&spec_r, &params_r).unwrap(),
            4,
        )
        .unwrap();
        if rate == 4.0 {
            let mut ex = Executor::new(&plan, KernelKind::DenseRef);
            bench("dense engine (rate-independent)", warm, reps.min(10), || {
                ex.execute_into(&img, &mut logits).unwrap();
                std::hint::black_box(&logits);
            });
        }
        let mut ex = Executor::new(&plan, KernelKind::PatternScalar);
        bench(&format!("sparse engine @ {rate}x"), warm, reps, || {
            ex.execute_into(&img, &mut logits).unwrap();
            std::hint::black_box(&logits);
        });
    }

    section("batch throughput (8x pattern, 16-image batch)");
    let batch: Vec<Fmap> =
        (0..16).map(|i| rand_image(in_hw, 100 + i)).collect();
    let mut ex = Executor::new(&plan1, KernelKind::PatternScalar);
    bench("execute_batch sequential (1 thread)", 2, reps.min(8), || {
        std::hint::black_box(ex.execute_batch(&batch).unwrap());
    });
    for workers in [2usize, 4] {
        bench(
            &format!("execute_batch_parallel @ {workers} workers"),
            2,
            reps.min(8),
            || {
                std::hint::black_box(
                    execute_batch_parallel(
                        &plan1,
                        KernelKind::PatternScalar,
                        &batch,
                        workers,
                    )
                    .unwrap(),
                );
            },
        );
    }

    section("Galaxy S10 cost model, paper-scale (Fig. 3 estimates)");
    let models = [
        AnalyticModel::paper_scale(
            "VGG-16 CIFAR-100 12x",
            &costmodel::vgg16_cifar(),
            12.0,
            1.8,
            2.0,
        ),
        AnalyticModel::paper_scale(
            "ResNet-18 ImageNet 6x",
            &costmodel::resnet18_imagenet(),
            6.0,
            1.8,
            2.0,
        ),
    ];
    for m in &models {
        for dev in [Device::Cpu, Device::Gpu] {
            for e in &ALL_ENGINES {
                println!(
                    "estimate {:24} {:?} {:8} {:>8.1} ms",
                    m.name,
                    dev,
                    e.name,
                    latency_ms(m, e, &GALAXY_S10, dev)
                );
            }
        }
    }

    log.write("BENCH_mobile.json").unwrap();
}
