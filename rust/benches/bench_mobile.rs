//! Bench (Fig. 3): mobile engine latency — real host execution of dense vs
//! compiled-sparse inference at several compression rates, plus the
//! Galaxy-S10 cost-model estimates for every framework at paper scale.

use repro::bench_harness::{bench, section};
use repro::mobile::costmodel::{
    self, latency_ms, AnalyticModel, Device, ALL_ENGINES, GALAXY_S10,
};
use repro::mobile::engine::{self, EngineKind, Fmap};
use repro::mobile::ir::ModelIR;
use repro::pruning::{project, LayerShape, Scheme};
use repro::rng::Pcg32;
use repro::runtime::Runtime;
use repro::train::params::init_params;

fn main() {
    let rt = Runtime::new("artifacts").expect("run `make artifacts`");
    let spec = rt.model("vgg_sv20").unwrap().clone();

    section("host engine latency vs compression (vgg_sv20, pattern)");
    for rate in [4.0, 8.0, 12.0, 16.0] {
        let mut params = init_params(&spec, 9);
        for (_, op) in spec.prunable_convs() {
            let shape = LayerShape::from_conv(op);
            let wg = params[op.w]
                .clone()
                .reshape(&[shape.p, shape.q()])
                .unwrap();
            let pr =
                project(Scheme::Pattern, &wg, &shape, 1.0 / rate).unwrap();
            let s4 = params[op.w].shape().to_vec();
            params[op.w] = pr.w.clone().reshape(&s4).unwrap();
        }
        let compiled =
            engine::compile(ModelIR::build(&spec, &params).unwrap());
        let mut rng = Pcg32::seeded(2);
        let img = Fmap {
            c: 3,
            hw: spec.in_hw,
            data: (0..3 * spec.in_hw * spec.in_hw)
                .map(|_| rng.uniform())
                .collect(),
        };
        if rate == 4.0 {
            bench("dense engine (rate-independent)", 3, 15, || {
                std::hint::black_box(engine::infer(
                    &compiled,
                    &img,
                    EngineKind::Dense,
                ));
            });
        }
        bench(&format!("sparse engine @ {rate}x"), 3, 15, || {
            std::hint::black_box(engine::infer(
                &compiled,
                &img,
                EngineKind::Sparse,
            ));
        });
    }

    section("Galaxy S10 cost model, paper-scale (Fig. 3 estimates)");
    let models = [
        AnalyticModel::paper_scale(
            "VGG-16 CIFAR-100 12x",
            &costmodel::vgg16_cifar(),
            12.0,
            1.8,
            2.0,
        ),
        AnalyticModel::paper_scale(
            "ResNet-18 ImageNet 6x",
            &costmodel::resnet18_imagenet(),
            6.0,
            1.8,
            2.0,
        ),
    ];
    for m in &models {
        for dev in [Device::Cpu, Device::Gpu] {
            for e in &ALL_ENGINES {
                println!(
                    "estimate {:24} {:?} {:8} {:>8.1} ms",
                    m.name,
                    dev,
                    e.name,
                    latency_ms(m, e, &GALAXY_S10, dev)
                );
            }
        }
    }
}
