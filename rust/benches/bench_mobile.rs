//! Bench (Fig. 3): mobile plan/executor latency on a synthetic VGG-style
//! model (no PJRT artifacts required) — plan construction vs steady-state
//! execution, kernel comparison, thread scaling, batch throughput — plus
//! the Galaxy-S10 cost-model estimates for every framework at paper scale.

use repro::serve::stats::{bench, section};
use repro::mobile::costmodel::{
    self, latency_ms, AnalyticModel, Device, ALL_ENGINES, GALAXY_S10,
};
use repro::mobile::engine::{
    execute_batch_parallel, Executor, Fmap, KernelKind, KERNEL_KINDS,
};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::compile_plan;
use repro::mobile::synth;
use repro::rng::Pcg32;

fn rand_image(hw: usize, seed: u64) -> Fmap {
    let mut rng = Pcg32::seeded(seed);
    Fmap {
        c: 3,
        hw,
        data: (0..3 * hw * hw).map(|_| rng.uniform()).collect(),
    }
}

fn main() {
    let in_hw = 32;
    let (spec, mut params) =
        synth::vgg_style("bench_vgg", in_hw, 10, &[32, 64, 96], 9);
    let img = rand_image(in_hw, 2);

    section("plan construction vs steady-state execution (8x pattern)");
    synth::pattern_prune(&spec, &mut params, 1.0 / 8.0);
    let ir = ModelIR::build(&spec, &params).unwrap();
    // pre-clone the IR outside the timed closure so the numbers measure
    // pass + lowering cost, not a deep copy of the dense weight tensors
    for threads in [1usize, 4] {
        let mut pool: Vec<_> = (0..13).map(|_| ir.clone()).collect();
        bench(
            &format!("plan construction ({threads} thread(s))"),
            2,
            10,
            || {
                let ir = pool.pop().expect("clone pool exhausted");
                std::hint::black_box(compile_plan(ir, threads).unwrap());
            },
        );
    }
    let plan1 = compile_plan(ir.clone(), 1).unwrap();
    let mut logits = vec![0.0f32; plan1.ir.classes];
    for kind in KERNEL_KINDS {
        let mut ex = Executor::new(&plan1, kind);
        bench(&format!("execute {} (1 thread)", kind.name()), 3, 15, || {
            ex.execute_into(&img, &mut logits).unwrap();
            std::hint::black_box(&logits);
        });
        assert_eq!(ex.alloc_events(), 0, "steady state must not allocate");
    }

    section("sparse executor thread scaling (8x pattern)");
    for threads in [1usize, 2, 4, 8] {
        let plan = compile_plan(ir.clone(), threads).unwrap();
        let mut ex = Executor::new(&plan, KernelKind::PatternScalar);
        bench(&format!("sparse @ {threads} threads"), 3, 15, || {
            ex.execute_into(&img, &mut logits).unwrap();
            std::hint::black_box(&logits);
        });
    }

    section("sparse executor vs compression rate (4 threads)");
    for rate in [4.0, 8.0, 12.0, 16.0] {
        let (spec_r, mut params_r) =
            synth::vgg_style("bench_vgg", in_hw, 10, &[32, 64, 96], 9);
        synth::pattern_prune(&spec_r, &mut params_r, 1.0 / rate);
        let plan = compile_plan(
            ModelIR::build(&spec_r, &params_r).unwrap(),
            4,
        )
        .unwrap();
        if rate == 4.0 {
            let mut ex = Executor::new(&plan, KernelKind::DenseRef);
            bench("dense engine (rate-independent)", 3, 10, || {
                ex.execute_into(&img, &mut logits).unwrap();
                std::hint::black_box(&logits);
            });
        }
        let mut ex = Executor::new(&plan, KernelKind::PatternScalar);
        bench(&format!("sparse engine @ {rate}x"), 3, 15, || {
            ex.execute_into(&img, &mut logits).unwrap();
            std::hint::black_box(&logits);
        });
    }

    section("batch throughput (8x pattern, 16-image batch)");
    let batch: Vec<Fmap> =
        (0..16).map(|i| rand_image(in_hw, 100 + i)).collect();
    let mut ex = Executor::new(&plan1, KernelKind::PatternScalar);
    bench("execute_batch sequential (1 thread)", 2, 8, || {
        std::hint::black_box(ex.execute_batch(&batch).unwrap());
    });
    for workers in [2usize, 4] {
        bench(
            &format!("execute_batch_parallel @ {workers} workers"),
            2,
            8,
            || {
                std::hint::black_box(
                    execute_batch_parallel(
                        &plan1,
                        KernelKind::PatternScalar,
                        &batch,
                        workers,
                    )
                    .unwrap(),
                );
            },
        );
    }

    section("Galaxy S10 cost model, paper-scale (Fig. 3 estimates)");
    let models = [
        AnalyticModel::paper_scale(
            "VGG-16 CIFAR-100 12x",
            &costmodel::vgg16_cifar(),
            12.0,
            1.8,
            2.0,
        ),
        AnalyticModel::paper_scale(
            "ResNet-18 ImageNet 6x",
            &costmodel::resnet18_imagenet(),
            6.0,
            1.8,
            2.0,
        ),
    ];
    for m in &models {
        for dev in [Device::Cpu, Device::Gpu] {
            for e in &ALL_ENGINES {
                println!(
                    "estimate {:24} {:?} {:8} {:>8.1} ms",
                    m.name,
                    dev,
                    e.name,
                    latency_ms(m, e, &GALAXY_S10, dev)
                );
            }
        }
    }
}
