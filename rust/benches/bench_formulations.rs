//! Bench (Table IV): per-iteration runtime of problem (3) (layer-wise)
//! vs problem (2) (whole-model) on VGG-Mini — the paper reports 4.9x;
//! the same asymmetry (layer-wise costs N primal solves + N forward
//! refreshes) must reproduce here. Results land in
//! `BENCH_formulations.json` (written even when the PJRT runtime is
//! unavailable, so CI always gets the artifact).

use repro::admm::{prune_layerwise, prune_whole, DataSource};
use repro::config::AdmmConfig;
use repro::pruning::Scheme;
use repro::runtime::Runtime;
use repro::serve::stats::{section, BenchLog};
use repro::train::params::init_params;

fn main() {
    let mut log = BenchLog::new("formulations");
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            log.write("BENCH_formulations.json").unwrap();
            println!(
                "(skipping PJRT formulation benches: {e}; run `make \
                 artifacts` to see them)"
            );
            return;
        }
    };
    let model = rt.model("vgg_sv10").unwrap().clone();
    let params = init_params(&model, 1);
    let cfg = AdmmConfig {
        rhos: vec![1e-3],
        iters_per_rho: 1,
        primal_steps: 3,
        lr: 1e-3,
        lr_layer: 1e-3,
        gauss_seidel: true,
        seed: 1,
        threads: 1,
    };
    rt.warm("vgg_sv10", "fwd_acts").unwrap();
    rt.warm("vgg_sv10", "whole_primal_step").unwrap();
    for n in 0..model.prunable.len() {
        rt.warm("vgg_sv10", &format!("layer_primal_{n}")).unwrap();
    }

    section("Table IV: per-iteration runtime, VGG irregular 16x");
    let r3 = log.bench("problem (3) layer-wise iter", 1, 5, || {
        std::hint::black_box(
            prune_layerwise(
                &rt,
                "vgg_sv10",
                &params,
                Scheme::Irregular,
                1.0 / 16.0,
                &cfg,
                DataSource::Synthetic,
            )
            .unwrap(),
        );
    });
    let r2 = log.bench("problem (2) whole-model iter", 1, 5, || {
        std::hint::black_box(
            prune_whole(
                &rt,
                "vgg_sv10",
                &params,
                Scheme::Irregular,
                1.0 / 16.0,
                &cfg,
            )
            .unwrap(),
        );
    });
    let ratio = r3.mean_ms / r2.mean_ms.max(1e-9);
    println!(
        "\nproblem(3)/problem(2) per-iter ratio: {ratio:.2}x (paper: \
         4.9x; < N={} because problem (2) optimizes all weights at once)",
        model.prunable.len()
    );
    log.metric("layerwise_over_whole_ratio", ratio);
    log.write("BENCH_formulations.json").unwrap();
}
