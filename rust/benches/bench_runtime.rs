//! Bench: PJRT execute overhead + Literal marshalling — the L3↔XLA
//! boundary cost that the perf pass drives down (EXPERIMENTS.md §Perf).
//! Results land in `BENCH_runtime.json` (written even when the PJRT
//! runtime is unavailable, so CI always gets the artifact).

use repro::runtime::Runtime;
use repro::serve::stats::{section, BenchLog};
use repro::tensor::Tensor;
use repro::train::params::init_params;

fn main() {
    let mut log = BenchLog::new("runtime");
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            log.write("BENCH_runtime.json").unwrap();
            println!(
                "(skipping PJRT runtime benches: {e}; run `make \
                 artifacts` to see them)"
            );
            return;
        }
    };
    section("PJRT execute (lenet fwd_eval, batch 100)");
    let model = rt.model("lenet_sv10").unwrap().clone();
    let params = init_params(&model, 1);
    let x = Tensor::zeros(&[rt.manifest.batches.eval, 3, 16, 16]);
    rt.warm("lenet_sv10", "fwd_eval").unwrap();
    log.bench("lenet fwd_eval end-to-end", 3, 20, || {
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        inputs.push(&x);
        std::hint::black_box(
            rt.exec("lenet_sv10", "fwd_eval", &inputs).unwrap(),
        );
    });

    section("PJRT execute (vgg train_step, batch 64)");
    let vgg = rt.model("vgg_sv10").unwrap().clone();
    let vp = init_params(&vgg, 1);
    let xb = Tensor::zeros(&[rt.manifest.batches.train, 3, 16, 16]);
    let yb = Tensor::zeros(&[rt.manifest.batches.train, 10]);
    let lr = Tensor::scalar(0.01);
    rt.warm("vgg_sv10", "train_step").unwrap();
    log.bench("vgg train_step end-to-end", 2, 10, || {
        let mut inputs: Vec<&Tensor> = vp.iter().collect();
        inputs.push(&xb);
        inputs.push(&yb);
        inputs.push(&lr);
        std::hint::black_box(
            rt.exec("vgg_sv10", "train_step", &inputs).unwrap(),
        );
    });

    let s = rt.stats();
    let marshal_share =
        s.marshal_secs / (s.exec_secs + s.marshal_secs).max(1e-12);
    println!(
        "\ncumulative: {} execs, exec {:.3}s, marshal {:.3}s \
         (marshal share {:.1}%)",
        s.executions,
        s.exec_secs,
        s.marshal_secs,
        100.0 * marshal_share
    );
    log.metric("marshal_share", marshal_share);
    log.write("BENCH_runtime.json").unwrap();
}
