//! Bench: PJRT execute overhead + Literal marshalling — the L3↔XLA
//! boundary cost that the perf pass drives down (EXPERIMENTS.md §Perf).

use repro::serve::stats::{bench, section};
use repro::runtime::Runtime;
use repro::tensor::Tensor;
use repro::train::params::init_params;

fn main() {
    let rt = Runtime::new("artifacts").expect("run `make artifacts`");
    section("PJRT execute (lenet fwd_eval, batch 100)");
    let model = rt.model("lenet_sv10").unwrap().clone();
    let params = init_params(&model, 1);
    let x = Tensor::zeros(&[rt.manifest.batches.eval, 3, 16, 16]);
    rt.warm("lenet_sv10", "fwd_eval").unwrap();
    bench("lenet fwd_eval end-to-end", 3, 20, || {
        let mut inputs: Vec<&Tensor> = params.iter().collect();
        inputs.push(&x);
        std::hint::black_box(
            rt.exec("lenet_sv10", "fwd_eval", &inputs).unwrap(),
        );
    });

    section("PJRT execute (vgg train_step, batch 64)");
    let vgg = rt.model("vgg_sv10").unwrap().clone();
    let vp = init_params(&vgg, 1);
    let xb = Tensor::zeros(&[rt.manifest.batches.train, 3, 16, 16]);
    let yb = Tensor::zeros(&[rt.manifest.batches.train, 10]);
    let lr = Tensor::scalar(0.01);
    rt.warm("vgg_sv10", "train_step").unwrap();
    bench("vgg train_step end-to-end", 2, 10, || {
        let mut inputs: Vec<&Tensor> = vp.iter().collect();
        inputs.push(&xb);
        inputs.push(&yb);
        inputs.push(&lr);
        std::hint::black_box(
            rt.exec("vgg_sv10", "train_step", &inputs).unwrap(),
        );
    });

    let s = rt.stats();
    println!(
        "\ncumulative: {} execs, exec {:.3}s, marshal {:.3}s \
         (marshal share {:.1}%)",
        s.executions,
        s.exec_secs,
        s.marshal_secs,
        100.0 * s.marshal_secs / (s.exec_secs + s.marshal_secs)
    );
}
