//! Bench (Tables I-III context + the scheduler): layer-wise ADMM pruning
//! cost.
//!
//! Group 1 runs the **host scheduler** (`admm::scheduler`) on a synthetic
//! VGG spec — no artifacts or PJRT needed — serial vs parallel plus
//! thread scaling, and prints the 4-thread speedup explicitly. Group 2 is
//! the per-scheme cost at full parallelism. Group 3 keeps the original
//! PJRT per-iteration benches (lenet, problem (3)) and is skipped with a
//! note when no runtime is available. Results land in `BENCH_admm.json`
//! (written before the PJRT early-out so the host groups always record).

use repro::admm::scheduler::{prune_layerwise_par, SchedulerCfg};
use repro::admm::{prune_layerwise, DataSource};
use repro::serve::stats::{section, BenchLog};
use repro::config::AdmmConfig;
use repro::mobile::synth::vgg_style;
use repro::pruning::Scheme;
use repro::runtime::Runtime;
use repro::train::params::init_params;

fn host_cfg(threads: usize) -> SchedulerCfg {
    SchedulerCfg::new(
        AdmmConfig {
            rhos: vec![1e-2, 1e-1],
            iters_per_rho: 2,
            primal_steps: 3,
            lr: 1e-2,
            lr_layer: 5e-3,
            gauss_seidel: true,
            seed: 1,
            threads: 1,
        },
        8,
        threads,
    )
}

fn main() {
    let mut log = BenchLog::new("admm");
    // synthetic VGG spec: 6 prunable 3x3 convs over three width stages
    let (spec, params) = vgg_style("vgg_bench", 16, 10, &[8, 16, 32], 1);

    section("host scheduler: serial vs parallel layer-wise pruning (synthetic VGG)");
    let mut mean_ms = std::collections::BTreeMap::new();
    for threads in [1usize, 2, 4] {
        let cfg = host_cfg(threads);
        let r = log.bench(
            &format!("prune pattern 8x  {threads} thread(s)"),
            1,
            5,
            || {
                std::hint::black_box(
                    prune_layerwise_par(
                        &spec,
                        &params,
                        Scheme::Pattern,
                        1.0 / 8.0,
                        &cfg,
                    )
                    .unwrap(),
                );
            },
        );
        mean_ms.insert(threads, r.mean_ms);
    }
    println!(
        "layer-wise speedup vs serial: {:.2}x at 2 threads, {:.2}x at 4 threads",
        mean_ms[&1] / mean_ms[&2],
        mean_ms[&1] / mean_ms[&4]
    );
    log.metric("prune_speedup_2t", mean_ms[&1] / mean_ms[&2].max(1e-9));
    log.metric("prune_speedup_4t", mean_ms[&1] / mean_ms[&4].max(1e-9));

    section("host scheduler: per-scheme cost at 4 threads");
    let cfg4 = host_cfg(4);
    for scheme in Scheme::all() {
        log.bench(
            &format!("prune {} 8x  4 threads", scheme.name()),
            1,
            3,
            || {
                std::hint::black_box(
                    prune_layerwise_par(
                        &spec,
                        &params,
                        scheme,
                        1.0 / 8.0,
                        &cfg4,
                    )
                    .unwrap(),
                );
            },
        );
    }

    // ---- PJRT artifact benches (original groups) -------------------------
    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            // the host-scheduler results above are still worth recording
            log.write("BENCH_admm.json").unwrap();
            println!("\n(skipping PJRT artifact benches: {e})");
            return;
        }
    };
    let model = rt.model("lenet_sv10").unwrap().clone();
    let lenet_params = init_params(&model, 1);
    // one-iteration config: the bench times a single full ADMM iteration
    // (synthetic batch + target acts + per-layer primal/proximal/dual)
    let cfg = AdmmConfig {
        rhos: vec![1e-3],
        iters_per_rho: 1,
        primal_steps: 3,
        lr: 1e-3,
        lr_layer: 1e-3,
        gauss_seidel: true,
        seed: 1,
        threads: 1,
    };
    for a in ["fwd_acts", "layer_primal_0", "layer_primal_1"] {
        rt.warm("lenet_sv10", a).unwrap();
    }
    section("one ADMM iteration (lenet, layer-wise problem (3), PJRT)");
    for scheme in Scheme::all() {
        log.bench(&format!("admm iter {}", scheme.name()), 1, 5, || {
            std::hint::black_box(
                prune_layerwise(
                    &rt,
                    "lenet_sv10",
                    &lenet_params,
                    scheme,
                    1.0 / 8.0,
                    &cfg,
                    DataSource::Synthetic,
                )
                .unwrap(),
            );
        });
    }

    section("Gauss-Seidel vs Jacobi activation refresh (ablation)");
    for (name, gs) in [("gauss-seidel", true), ("jacobi", false)] {
        let mut c = cfg.clone();
        c.gauss_seidel = gs;
        log.bench(&format!("admm iter irregular {name}"), 1, 5, || {
            std::hint::black_box(
                prune_layerwise(
                    &rt,
                    "lenet_sv10",
                    &lenet_params,
                    Scheme::Irregular,
                    1.0 / 8.0,
                    &c,
                    DataSource::Synthetic,
                )
                .unwrap(),
            );
        });
    }

    log.write("BENCH_admm.json").unwrap();
}
