//! Bench (Tables I-III context): per-iteration cost of the
//! privacy-preserving ADMM pruning loop per scheme, on the lenet model —
//! isolates the L3 orchestration + primal/proximal split from the
//! experiment-scale training noise.

use repro::admm::{prune_layerwise, DataSource};
use repro::bench_harness::{bench, section};
use repro::config::AdmmConfig;
use repro::pruning::Scheme;
use repro::runtime::Runtime;
use repro::train::params::init_params;

fn main() {
    let rt = Runtime::new("artifacts").expect("run `make artifacts`");
    let model = rt.model("lenet_sv10").unwrap().clone();
    let params = init_params(&model, 1);
    // one-iteration config: the bench times a single full ADMM iteration
    // (synthetic batch + target acts + per-layer primal/proximal/dual)
    let cfg = AdmmConfig {
        rhos: vec![1e-3],
        iters_per_rho: 1,
        primal_steps: 3,
        lr: 1e-3,
        lr_layer: 1e-3,
        gauss_seidel: true,
        seed: 1,
    };
    for a in ["fwd_acts", "layer_primal_0", "layer_primal_1"] {
        rt.warm("lenet_sv10", a).unwrap();
    }
    section("one ADMM iteration (lenet, layer-wise problem (3))");
    for scheme in Scheme::all() {
        bench(&format!("admm iter {}", scheme.name()), 1, 5, || {
            std::hint::black_box(
                prune_layerwise(
                    &rt,
                    "lenet_sv10",
                    &params,
                    scheme,
                    1.0 / 8.0,
                    &cfg,
                    DataSource::Synthetic,
                )
                .unwrap(),
            );
        });
    }

    section("Gauss-Seidel vs Jacobi activation refresh (ablation)");
    for (name, gs) in [("gauss-seidel", true), ("jacobi", false)] {
        let mut c = cfg.clone();
        c.gauss_seidel = gs;
        bench(&format!("admm iter irregular {name}"), 1, 5, || {
            std::hint::black_box(
                prune_layerwise(
                    &rt,
                    "lenet_sv10",
                    &params,
                    Scheme::Irregular,
                    1.0 / 8.0,
                    &c,
                    DataSource::Synthetic,
                )
                .unwrap(),
            );
        });
    }
}
