//! Mobile deployment deep-dive: prune a model with the pattern scheme,
//! compile it through the PassManager into an ExecutionPlan, run every
//! registered kernel for real (multi-threaded), and print the Fig. 3-style
//! latency comparison (measured host + estimated Galaxy-S10 numbers for
//! every framework).
//!
//! Run: `cargo run --release --features pjrt --example mobile_deploy`
//! (pruning runs through the PJRT runtime; the mobile compile/execute
//! stack itself has no PJRT dependency — see `cargo bench bench_mobile`
//! for the artifact-free path).

use anyhow::Result;
use repro::config::Preset;
use repro::coordinator::{Ctx, Method};
use repro::mobile::costmodel::{
    self, latency_ms, AnalyticModel, Device, ALL_ENGINES, GALAXY_S10,
};
use repro::mobile::engine::{Executor, Fmap, KernelKind, KERNEL_KINDS};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::PassManager;
use repro::pruning::Scheme;
use repro::report::human_bytes;
use repro::rng::Pcg32;

fn main() -> Result<()> {
    let ctx = Ctx::new("artifacts", Preset::Quick)?;
    let model_id = "vgg_sv20";
    let rate = 12.0;

    println!("pattern-pruning {model_id} at {rate}x (privacy-preserving) ...");
    let (params, _, comp, _, _) =
        ctx.prune(model_id, Method::Privacy, Scheme::Pattern, rate)?;
    let spec = ctx.rt.model(model_id)?.clone();
    let plan = PassManager::new(ctx.threads)
        .compile(ModelIR::build(&spec, &params)?)?;
    let rep = &plan.report;

    println!("\ncompiler report (achieved {comp:.1}x, {} threads):", plan.threads);
    println!(
        "{:>5} {:>12} {:>12} {:>8} {:>10} {:>10} {:>9}",
        "layer", "dense MACs", "sparse MACs", "styles", "bytes", "(dense)", "LRE"
    );
    for (i, l) in rep.layers.iter().enumerate() {
        println!(
            "{:>5} {:>12} {:>12} {:>8} {:>10} {:>10} {:>8.2}x",
            i,
            l.dense_macs,
            l.sparse_macs,
            l.styles,
            l.compressed_bytes,
            l.dense_bytes,
            l.loads_naive as f64 / l.loads_lre.max(1) as f64
        );
    }
    println!(
        "plan: payload {} + headers {}, arena {}, {} worker blocks",
        human_bytes(plan.stats.payload_bytes),
        human_bytes(plan.stats.header_bytes),
        human_bytes(plan.stats.arena_bytes),
        plan.stats.n_blocks
    );
    for (name, ms) in &plan.stats.pass_ms {
        println!("  pass {name:14} {ms:9.3} ms");
    }

    // real execution through the kernel registry
    let mut rng = Pcg32::seeded(5);
    let img = Fmap {
        c: 3,
        hw: spec.in_hw,
        data: (0..3 * spec.in_hw * spec.in_hw).map(|_| rng.uniform()).collect(),
    };
    println!("\nmeasured host-CPU latency (batch 1):");
    let mut logits = vec![0.0f32; plan.ir.classes];
    let mut times = std::collections::BTreeMap::new();
    for kind in KERNEL_KINDS {
        let mut ex = Executor::new(&plan, kind);
        for _ in 0..3 {
            ex.execute_into(&img, &mut logits)?;
        }
        let t = std::time::Instant::now();
        for _ in 0..50 {
            ex.execute_into(&img, &mut logits)?;
            std::hint::black_box(&logits);
        }
        let ms = t.elapsed().as_secs_f64() * 1e3 / 50.0;
        println!("  {:14}: {ms:.3} ms/frame", ex.kernel_name());
        times.insert(kind.name(), ms);
    }
    println!(
        "  speedup (sparse vs dense): {:.2}x",
        times[KernelKind::DenseRef.name()]
            / times[KernelKind::PatternScalar.name()]
    );

    // Fig. 3 estimated numbers at paper scale
    println!("\nestimated Galaxy S10 latency, paper-scale models (Fig. 3):");
    let models = [
        AnalyticModel::paper_scale(
            "VGG-16 CIFAR-100 12x",
            &costmodel::vgg16_cifar(),
            12.0,
            rep.lre_gain(),
            rep.reorder_gain(),
        ),
        AnalyticModel::paper_scale(
            "ResNet-18 ImageNet 6x",
            &costmodel::resnet18_imagenet(),
            6.0,
            rep.lre_gain(),
            rep.reorder_gain(),
        ),
    ];
    for m in &models {
        for dev in [Device::Cpu, Device::Gpu] {
            print!("  {:24} {dev:?}:", m.name);
            for e in &ALL_ENGINES {
                print!(
                    "  {}={:.1}ms",
                    e.name,
                    latency_ms(m, e, &GALAXY_S10, dev)
                );
            }
            println!();
        }
    }
    println!(
        "\nreal-time bound is 33 ms/frame; 'Ours' stays under it on both \
         models (paper §V-C)."
    );
    Ok(())
}
