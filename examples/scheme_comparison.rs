//! Scheme comparison: all four pruning schemes × {privacy-preserving ADMM,
//! greedy uniform} on one model — a compact Table I + Table V slice that
//! shows (a) structured schemes trade accuracy for hardware-friendliness
//! and (b) ADMM beats greedy projection when data is unavailable.
//!
//! Run: `cargo run --release --example scheme_comparison [--model res_sv10]`

use anyhow::Result;
use repro::config::Preset;
use repro::coordinator::{Ctx, Method};
use repro::pruning::Scheme;
use repro::report::{loss_cell, pct, rate, Table};

fn main() -> Result<()> {
    let model = std::env::args()
        .skip_while(|a| a != "--model")
        .nth(1)
        .unwrap_or_else(|| "res_sv10".into());
    let ctx = Ctx::new("artifacts", Preset::Quick)?;

    let mut t = Table::new(
        &format!("Scheme comparison on {model}"),
        &[
            "Scheme",
            "Method",
            "Comp. Rate",
            "Base Acc",
            "Pruned Acc",
            "Acc Loss",
        ],
    );
    for (scheme, r) in [
        (Scheme::Irregular, 8.0),
        (Scheme::Column, 6.0),
        (Scheme::Filter, 4.0),
        (Scheme::Pattern, 8.0),
    ] {
        for method in [Method::Uniform, Method::Privacy] {
            let row = ctx.prune_retrain(&model, method, scheme, r)?;
            t.row(&[
                scheme.name().into(),
                method.name().into(),
                rate(row.comp_rate),
                pct(row.base_acc),
                pct(row.prune_acc),
                loss_cell(row.base_acc, row.prune_acc),
            ]);
        }
    }
    println!("{}", t.render());
    Ok(())
}
