//! Quickstart: the whole framework in one minute on the micro model.
//!
//! Demonstrates every public-API stage: dataset generation, pre-training
//! through PJRT, the four pruning schemes of Fig. 1 (rendered in ASCII),
//! privacy-preserving ADMM pruning on uniform-random synthetic data, and
//! masked retraining.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! The prune CLI accepts `--threads N` (`repro prune --model lenet_sv10
//! --threads 4`): N workers drive the proximal projections here and the
//! whole layer-wise solve in the host scheduler (`repro exp sweep`,
//! `admm::scheduler` — no artifacts needed). Pruning results are
//! bit-identical at any thread count.

use anyhow::Result;
use repro::admm::{prune_layerwise, DataSource};
use repro::config::{AdmmConfig, Preset, TrainConfig};
use repro::data::SynthVision;
use repro::pruning::{self, LayerShape, Scheme};
use repro::runtime::Runtime;
use repro::train::{self, params::init_params};

const MODEL: &str = "lenet_sv10";

fn main() -> Result<()> {
    let rt = Runtime::new("artifacts")?;
    let model = rt.model(MODEL)?.clone();
    println!(
        "model {MODEL}: {} params, {} prunable conv layers",
        model.params.len(),
        model.prunable.len()
    );

    // 1. the client's confidential dataset + pre-training
    let tr = SynthVision::generate(model.classes, model.in_hw, 400, 11, 0);
    let te = SynthVision::generate(model.classes, model.in_hw, 200, 11, 1);
    let mut params = init_params(&model, 1);
    let mut cfg = TrainConfig::pretrain(Preset::Smoke);
    cfg.steps = 60;
    cfg.log_every = 20;
    println!("\n[client] pre-training 60 steps ...");
    let trace = train::pretrain(&rt, MODEL, &mut params, &tr, &te, &cfg)?;
    for (s, a) in &trace.accs {
        println!("  step {s:3}  test acc {a:.3}");
    }
    let base = trace.final_acc();

    // 2. Fig. 1: the four pruning schemes on the first conv layer
    let (_, op) = model.prunable_convs()[1];
    let shape = LayerShape::from_conv(op);
    let wg = params[op.w]
        .clone()
        .reshape(&[shape.p, shape.q()])?;
    println!("\nFig. 1 — pruning schemes on conv1 ({}x{} GEMM), α=1/4:",
             shape.p, shape.q());
    for scheme in Scheme::all() {
        let pr = pruning::project(scheme, &wg, &shape, 0.25)?;
        println!(
            "-- {} (kept {}/{}):",
            scheme.name(),
            pr.kept(),
            wg.len()
        );
        print!("{}", pruning::render_ascii(&pr.mask, &shape));
    }

    // 3. privacy-preserving ADMM pruning (designer side, synthetic data)
    println!("[designer] ADMM pruning (irregular 4x) on uniform-random synthetic data ...");
    let out = prune_layerwise(
        &rt,
        MODEL,
        &params,
        Scheme::Irregular,
        0.25,
        &AdmmConfig::preset(Preset::Smoke),
        DataSource::Synthetic,
    )?;
    println!(
        "  compression {:.1}x, final residual {:.3e}",
        out.comp_rate,
        out.trace.residual.last().copied().unwrap_or(0.0)
    );

    // 4. client retrains with the mask function
    let mut pruned = out.params.clone();
    let mut rcfg = TrainConfig::retrain(Preset::Smoke);
    rcfg.steps = 60;
    rcfg.log_every = 0;
    let rtr = train::retrain_masked(
        &rt, MODEL, &mut pruned, &out.masks, &tr, &te, &rcfg,
    )?;
    println!(
        "\n[client] retrained: base acc {base:.3} -> pruned acc {:.3} at {:.1}x",
        rtr.final_acc(),
        out.comp_rate
    );
    Ok(())
}
