//! Quickstart: the whole framework in one minute on the micro model.
//!
//! Two halves:
//!
//! 1. **Serving tier (artifact-free, always runs)** — compile a pruned
//!    synthetic VGG into an `ExecutionPlan`, save/load it as a
//!    checksummed plan artifact (bit-identical round trip), compile its
//!    INT8 quantized twin and print the accuracy/size/speed deltas
//!    (the `repro deploy --quantize` table), serve a
//!    seeded closed-loop trace through the dynamic-batching server,
//!    arm the deterministic chaos harness (injected worker panics ->
//!    typed errors + supervised restarts), then multiplex two
//!    differently-pruned tenants through the multi-tenant gateway
//!    (priority classes + per-tenant reports) and print the
//!    latency/batch reports.
//! 2. **Privacy tier (artifact-free, always runs)** — a miniature of
//!    `repro exp mia`: train a dense host target on a small member set,
//!    attack it with the confidence-threshold and shadow-model
//!    membership-inference attacks, prune+retrain one variant, and
//!    print the privacy-vs-compression table (pruning should lower the
//!    measured attack advantage).
//! 3. **PJRT pipeline (needs `artifacts/`)** — dataset generation,
//!    pre-training, the four pruning schemes of Fig. 1 (ASCII),
//!    privacy-preserving ADMM pruning on synthetic data, and masked
//!    retraining. Skipped with a note when no artifacts are present.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! The prune CLI accepts `--threads N` (`repro prune --model lenet_sv10
//! --threads 4`): N workers drive the proximal projections here and the
//! whole layer-wise solve in the host scheduler (`repro exp sweep`,
//! `admm::scheduler` — no artifacts needed). Pruning results are
//! bit-identical at any thread count. The serving tier is driven the same
//! way: `repro serve --clients 8 --batch 8 --artifact /tmp/plan.rpln`.

use std::sync::Arc;

use anyhow::Result;
use repro::admm::{prune_layerwise, DataSource};
use repro::config::{AdmmConfig, Preset, ServeConfig, TrainConfig};
use repro::data::SynthVision;
use repro::mobile::engine::{Executor, Fmap, KernelKind};
use repro::mobile::ir::ModelIR;
use repro::mobile::plan::{compile_plan, compile_plan_quant};
use repro::mobile::synth;
use repro::privacy::{self, MiaConfig};
use repro::pruning::{self, LayerShape, Scheme};
use repro::rng::Pcg32;
use repro::runtime::Runtime;
use repro::serve::artifact;
use repro::serve::faults::{FaultPlan, FaultSite};
use repro::serve::gateway::{Gateway, Priority, TenantConfig};
use repro::serve::loadgen::{self, LoadGenConfig, LoadMode, TenantLoad};
use repro::serve::server::Server;
use repro::train::{self, params::init_params};

const MODEL: &str = "lenet_sv10";

/// Serving walkthrough on a synthetic spec: compile -> artifact round
/// trip -> dynamic-batching server -> seeded load -> report.
fn serve_walkthrough() -> Result<()> {
    println!("=== serving tier (synthetic, artifact-free) ===");
    let (spec, mut params) =
        synth::vgg_style("qs_vgg", 16, 10, &[8, 12], 1);
    synth::pattern_prune(&spec, &mut params, 1.0 / 8.0);
    let plan = compile_plan(ModelIR::build(&spec, &params)?, 1)?;
    println!(
        "[deploy] compiled plan: {} layers, payload {} B, arena {} B",
        plan.layers.len(),
        plan.stats.payload_bytes,
        plan.stats.arena_bytes
    );
    // every layer carries a baked kernel choice (analytic here; `repro
    // deploy --kernel auto` or `compile_plan_tuned` races real codelets)
    for (i, lp) in plan.layers.iter().enumerate() {
        println!(
            "[deploy]   layer {i}: {:>3} filters @ {:>2}x{:<2} -> kernel {}",
            lp.a, lp.in_hw, lp.in_hw, lp.choice
        );
    }

    // plan artifact: save once, redeploy without recompiling
    let dir = std::env::temp_dir()
        .join(format!("repro_quickstart_{}", std::process::id()));
    let path = dir.join("qs_vgg.rpln");
    artifact::save(&plan, &path)?;
    let loaded = artifact::load(&path)?;
    artifact::verify_roundtrip(&plan, &loaded, 3, 42)?;
    println!(
        "[deploy] artifact round-trip OK ({} bytes, bit-identical \
         outputs)",
        std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0)
    );
    std::fs::remove_dir_all(&dir).ok();

    // INT8 quantized twin of the same IR: per-filter weight scales are
    // baked at compile time, activations quantize dynamically at run
    // time, and i8 x i8 -> i32 accumulation keeps the outputs
    // bit-reproducible at any thread count. This is the
    // `repro deploy --spec vgg --quantize` accuracy/size/speed table.
    println!("=== int8 quantized twin (repro deploy --quantize) ===");
    let qplan = compile_plan_quant(ModelIR::build(&spec, &params)?, 1)?;
    println!(
        "[quantize] payload {} B -> {} B ({:.2}x of f32)",
        plan.stats.payload_bytes,
        qplan.stats.payload_bytes,
        qplan.stats.payload_bytes as f64
            / plan.stats.payload_bytes.max(1) as f64
    );
    let mut fex = Executor::auto(&plan);
    let mut qex = Executor::auto(&qplan);
    let mut rng = Pcg32::seeded(5);
    let probes: Vec<Fmap> = (0..4)
        .map(|_| Fmap {
            c: 3,
            hw: 16,
            data: (0..3 * 16 * 16).map(|_| rng.uniform()).collect(),
        })
        .collect();
    let mut max_abs = 0.0f32;
    for img in &probes {
        for (w, g) in fex.execute(img).iter().zip(&qex.execute(img)) {
            max_abs = max_abs.max((w - g).abs());
        }
    }
    println!(
        "[quantize] max abs logit err vs f32 over {} probes: {max_abs:.3e}",
        probes.len()
    );
    fn ms_per_frame(ex: &mut Executor<'_>, img: &Fmap) -> f64 {
        for _ in 0..2 {
            ex.execute(img);
        }
        let t = std::time::Instant::now();
        for _ in 0..10 {
            std::hint::black_box(ex.execute(img));
        }
        t.elapsed().as_secs_f64() * 100.0
    }
    let f32_ms = ms_per_frame(&mut fex, &probes[0]);
    let i8_ms = ms_per_frame(&mut qex, &probes[0]);
    println!(
        "[quantize] inference {f32_ms:.3} ms/frame (f32) -> \
         {i8_ms:.3} ms/frame (i8, {:.2}x)\n",
        f32_ms / i8_ms.max(1e-9)
    );
    // dynamic-batching server under a seeded closed-loop trace; the
    // builder is the one way to stand a server up
    let plan = Arc::new(loaded);
    let cfg = ServeConfig::preset(Preset::Smoke);
    let server = Server::builder(plan.clone())
        .config(&cfg)
        .kernel(KernelKind::PatternScalar)
        .spawn()?;
    let load = loadgen::run(
        &server.handle(),
        plan.in_dims,
        &LoadGenConfig {
            mode: LoadMode::Closed { clients: 4 },
            requests: 32,
            seed: 42,
        },
    );
    let report = server.shutdown();
    println!(
        "[serve] {} requests, {:.1} req/s, p95 {} us, mean batch {:.2}\n",
        load.completed,
        load.achieved_qps,
        report.latency.p95_us,
        report.mean_batch
    );

    // deterministic chaos: arm the fault injector and watch the
    // supervisor convert worker panics into typed errors + restarts.
    // The fault schedule is a pure function of (seed, site, request
    // id), so the victim set is identical at any worker count — this
    // is `repro serve --chaos 7` in miniature.
    println!("=== deterministic chaos (repro serve --chaos 7) ===");
    let faults =
        Arc::new(FaultPlan::new(7).rate(FaultSite::WorkerPanic, 150));
    let chaos_server = Server::builder(plan.clone())
        .config(&cfg)
        .kernel(KernelKind::PatternScalar)
        .chaos(faults.clone())
        .spawn()?;
    let chaos_load = loadgen::run(
        &chaos_server.handle(),
        plan.in_dims,
        &LoadGenConfig {
            mode: LoadMode::Open { qps: 100_000.0 },
            requests: 32,
            seed: 42,
        },
    );
    let chaos_report = chaos_server.shutdown();
    println!("[chaos] {}", faults.summary());
    println!(
        "[chaos] {} of 32 completed, {} lost to injected panics, \
         {} worker restart(s) — typed errors, no hangs\n",
        chaos_load.completed,
        chaos_report.worker_lost,
        chaos_report.restarts
    );

    // multi-tenant gateway: two tenants with their own pruned plans and
    // priority classes share one worker pool; a seeded virtual-time
    // trace is replayed deterministically and each tenant gets its own
    // latency/batch report
    println!("=== multi-tenant gateway (two tenants, one pool) ===");
    let (spec_b, mut params_b) =
        synth::res_style("qs_res", 16, 10, &[8, 12], 2);
    synth::pattern_prune(&spec_b, &mut params_b, 1.0 / 4.0);
    let plan_b =
        Arc::new(compile_plan(ModelIR::build(&spec_b, &params_b)?, 1)?);
    let gateway = Gateway::builder()
        .workers(2)
        .max_batch(4)
        .max_wait_us(200)
        .tenant(
            TenantConfig::new("vgg8x").priority(Priority::High),
            plan.clone(),
            KernelKind::PatternScalar,
        )
        .tenant(
            TenantConfig::new("res4x").priority(Priority::Low),
            plan_b.clone(),
            KernelKind::PatternScalar,
        )
        .spawn()?;
    let loads = [
        TenantLoad::new("vgg8x", 48.0, 24),
        TenantLoad::new("res4x", 16.0, 8),
    ];
    let trace = loadgen::multi_tenant_trace(&loads, None, 42);
    let gw_load =
        loadgen::replay(&gateway.handle(), &loads, &trace, 42, 0.0)?;
    let gw_report = gateway.shutdown();
    for c in &gw_load.per_tenant {
        let t = gw_report.tenant(&c.tenant).expect("tenant report");
        println!(
            "[gateway] tenant {:<6} ({:<6}): {} issued, {} completed, \
             p95 {} us, mean batch {:.2}",
            c.tenant,
            t.priority.name(),
            c.issued,
            c.completed,
            t.report.latency.p95_us,
            t.report.mean_batch
        );
    }
    println!();
    Ok(())
}

/// Privacy tier walkthrough: membership-inference attacks against a
/// dense host-trained target and one pruned+retrained variant — the
/// `repro exp mia` experiment in miniature. All datasets are carved
/// from one data seed by PCG *split* id (members / non-member probes /
/// each shadow's train + held-out sets), so they share a task
/// distribution but no samples.
fn privacy_walkthrough() -> Result<()> {
    println!("=== privacy tier (repro exp mia, miniature) ===");
    let mut cfg = MiaConfig::preset(Preset::Smoke);
    cfg.classes = 6;
    cfg.hw = 8;
    cfg.widths = vec![4, 6];
    cfg.n_members = 32;
    cfg.n_non = 32;
    cfg.n_shadows = 1;
    cfg.train.steps = 80;
    cfg.train.batch = 8;
    cfg.retrain.steps = 30;
    cfg.retrain.batch = 8;
    cfg.schemes = vec![Scheme::Pattern];
    cfg.rates = vec![8.0];
    cfg.threads = 2;
    let report = privacy::run_mia(&cfg)?;
    println!("{}", privacy::report::mia_table(&report).render());
    println!(
        "[privacy] confidence-attack advantage: dense {:.3} -> pruned \
         {:.3} — pruning the model also prunes its memorization \
         (`repro exp mia --preset smoke` runs the full grid; \
         --progressive N prunes through an N-rung rate ladder)\n",
        report.dense().conf.advantage,
        report.mean_pruned_advantage()
    );
    Ok(())
}

fn main() -> Result<()> {
    serve_walkthrough()?;
    privacy_walkthrough()?;

    let rt = match Runtime::new("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            println!(
                "(skipping the PJRT pipeline half: {e:#}; run `make \
                 artifacts` / enable --features pjrt to see it)"
            );
            return Ok(());
        }
    };
    let model = rt.model(MODEL)?.clone();
    println!(
        "model {MODEL}: {} params, {} prunable conv layers",
        model.params.len(),
        model.prunable.len()
    );

    // 1. the client's confidential dataset + pre-training
    let tr = SynthVision::generate(model.classes, model.in_hw, 400, 11, 0);
    let te = SynthVision::generate(model.classes, model.in_hw, 200, 11, 1);
    let mut params = init_params(&model, 1);
    let mut cfg = TrainConfig::pretrain(Preset::Smoke);
    cfg.steps = 60;
    cfg.log_every = 20;
    println!("\n[client] pre-training 60 steps ...");
    let trace = train::pretrain(&rt, MODEL, &mut params, &tr, &te, &cfg)?;
    for (s, a) in &trace.accs {
        println!("  step {s:3}  test acc {a:.3}");
    }
    let base = trace.final_acc();

    // 2. Fig. 1: the four pruning schemes on the first conv layer
    let (_, op) = model.prunable_convs()[1];
    let shape = LayerShape::from_conv(op);
    let wg = params[op.w]
        .clone()
        .reshape(&[shape.p, shape.q()])?;
    println!("\nFig. 1 — pruning schemes on conv1 ({}x{} GEMM), α=1/4:",
             shape.p, shape.q());
    for scheme in Scheme::all() {
        let pr = pruning::project(scheme, &wg, &shape, 0.25)?;
        println!(
            "-- {} (kept {}/{}):",
            scheme.name(),
            pr.kept(),
            wg.len()
        );
        print!("{}", pruning::render_ascii(&pr.mask, &shape));
    }

    // 3. privacy-preserving ADMM pruning (designer side, synthetic data)
    println!("[designer] ADMM pruning (irregular 4x) on uniform-random synthetic data ...");
    let out = prune_layerwise(
        &rt,
        MODEL,
        &params,
        Scheme::Irregular,
        0.25,
        &AdmmConfig::preset(Preset::Smoke),
        DataSource::Synthetic,
    )?;
    println!(
        "  compression {:.1}x, final residual {:.3e}",
        out.comp_rate,
        out.trace.residual.last().copied().unwrap_or(0.0)
    );

    // 4. client retrains with the mask function
    let mut pruned = out.params.clone();
    let mut rcfg = TrainConfig::retrain(Preset::Smoke);
    rcfg.steps = 60;
    rcfg.log_every = 0;
    let rtr = train::retrain_masked(
        &rt, MODEL, &mut pruned, &out.masks, &tr, &te, &rcfg,
    )?;
    println!(
        "\n[client] retrained: base acc {base:.3} -> pruned acc {:.3} at {:.1}x",
        rtr.final_acc(),
        out.comp_rate
    );
    Ok(())
}
