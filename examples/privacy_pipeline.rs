//! End-to-end driver (DESIGN.md §4, EXPERIMENTS.md §E2E): the full
//! three-party workflow of paper Fig. 2(b) on a real (synthetic-vision)
//! workload, with loss curves logged at every stage.
//!
//!   client:   pre-train ResNet-Mini on the confidential dataset
//!   designer: privacy-preserving ADMM pattern-pruning at 8x using ONLY
//!             uniform-random pixels (never sees the dataset)
//!   client:   masked retraining, recovers accuracy
//!   deploy:   compile for mobile; sparse vs dense execution
//!
//! Run: `cargo run --release --example privacy_pipeline [--preset quick]`
//! Writes runs/privacy_pipeline.log with the loss curves.
//!
//! The designer's prune stage is also available multi-threaded: `repro
//! prune --model res_sv10 --threads 4` parallelizes the proximal
//! projections, and the host scheduler (`admm::scheduler`, `repro exp
//! sweep`) solves the per-layer ADMM subproblems concurrently with
//! bit-identical results at any thread count (DESIGN.md §10).

use std::fmt::Write as _;

use anyhow::Result;
use repro::admm::{prune_layerwise, DataSource};
use repro::config::{AdmmConfig, Preset, TrainConfig};
use repro::coordinator::Ctx;
use repro::mobile::engine::{self, EngineKind, Fmap};
use repro::mobile::ir::ModelIR;
use repro::pruning::Scheme;
use repro::rng::Pcg32;
use repro::train;

const MODEL: &str = "res_sv10";

fn main() -> Result<()> {
    let preset = std::env::args()
        .skip_while(|a| a != "--preset")
        .nth(1)
        .map(|p| Preset::parse(&p))
        .transpose()?
        .unwrap_or(Preset::Quick);
    let ctx = Ctx::new("artifacts", preset)?;
    let mut log = String::new();

    // -- stage 1: client pre-training --------------------------------------
    let (tr, te) = ctx.data(MODEL)?;
    let spec = ctx.rt.model(MODEL)?.clone();
    let mut params = train::params::init_params(&spec, 0xBA5E);
    let cfg = TrainConfig::pretrain(preset);
    println!("[1/4] client pre-trains {MODEL} ({} steps) ...", cfg.steps);
    let t0 = std::time::Instant::now();
    let trace = train::pretrain(&ctx.rt, MODEL, &mut params, &tr, &te, &cfg)?;
    let base = trace.final_acc();
    let _ = writeln!(log, "# pretrain loss curve (step, loss)");
    for (i, l) in trace.losses.iter().enumerate() {
        let _ = writeln!(log, "{i} {l}");
    }
    let _ = writeln!(log, "# pretrain acc curve (step, acc)");
    for (s, a) in &trace.accs {
        let _ = writeln!(log, "{s} {a}");
        println!("      step {s:4}  test acc {a:.3}");
    }
    println!("      base accuracy {base:.3} ({:.0}s)", t0.elapsed().as_secs_f64());

    // -- stage 2: designer prunes on synthetic data ------------------------
    let admm_cfg = AdmmConfig::preset(preset);
    println!(
        "[2/4] system designer runs privacy-preserving ADMM (pattern 8x), \
         {} iterations on uniform-random pixels ...",
        admm_cfg.rhos.len() * admm_cfg.iters_per_rho
    );
    let t1 = std::time::Instant::now();
    let out = prune_layerwise(
        &ctx.rt,
        MODEL,
        &params,
        Scheme::Pattern,
        1.0 / 8.0,
        &admm_cfg,
        DataSource::Synthetic,
    )?;
    let _ = writeln!(log, "# admm primal loss per iteration");
    for (i, l) in out.trace.primal_loss.iter().enumerate() {
        let _ = writeln!(log, "{i} {l}");
    }
    let _ = writeln!(log, "# admm residual per iteration");
    for (i, r) in out.trace.residual.iter().enumerate() {
        let _ = writeln!(log, "{i} {r}");
    }
    println!(
        "      compression {:.1}x, residual {:.2e} -> {:.2e} ({:.0}s)",
        out.comp_rate,
        out.trace.residual.first().copied().unwrap_or(0.0),
        out.trace.residual.last().copied().unwrap_or(0.0),
        t1.elapsed().as_secs_f64()
    );

    // accuracy before retraining (pruned, no recovery yet)
    let acc_no_retrain =
        train::evaluate(&ctx.rt, MODEL, &out.params, &te)?;
    println!("      pruned accuracy before retraining: {acc_no_retrain:.3}");

    // -- stage 3: client retrains with the mask ----------------------------
    let rcfg = TrainConfig::retrain(preset);
    println!("[3/4] client retrains with mask function ({} steps) ...", rcfg.steps);
    let mut pruned = out.params.clone();
    let t2 = std::time::Instant::now();
    let rtr = train::retrain_masked(
        &ctx.rt, MODEL, &mut pruned, &out.masks, &tr, &te, &rcfg,
    )?;
    let _ = writeln!(log, "# retrain loss curve (step, loss)");
    for (i, l) in rtr.losses.iter().enumerate() {
        let _ = writeln!(log, "{i} {l}");
    }
    println!(
        "      retrained accuracy {:.3} (base {base:.3}, loss {:+.3}) ({:.0}s)",
        rtr.final_acc(),
        base - rtr.final_acc(),
        t2.elapsed().as_secs_f64()
    );

    // -- stage 4: mobile deployment ----------------------------------------
    println!("[4/4] compiling for mobile ...");
    let compiled = engine::compile(ModelIR::build(&spec, &pruned)?);
    let rep = compiled.report();
    println!(
        "      MACs {:.2}x down, weights {:.2}x down, LRE {:.2}x, reorder {:.2}x",
        rep.total_dense_macs() as f64 / rep.total_sparse_macs().max(1) as f64,
        rep.total_dense_bytes() as f64
            / rep.total_compressed_bytes().max(1) as f64,
        rep.lre_gain(),
        rep.reorder_gain()
    );
    let mut rng = Pcg32::seeded(3);
    let img = Fmap {
        c: 3,
        hw: spec.in_hw,
        data: (0..3 * spec.in_hw * spec.in_hw).map(|_| rng.uniform()).collect(),
    };
    for kind in [EngineKind::Dense, EngineKind::Sparse] {
        for _ in 0..3 {
            engine::infer(&compiled, &img, kind);
        }
        let t = std::time::Instant::now();
        for _ in 0..30 {
            std::hint::black_box(engine::infer(&compiled, &img, kind));
        }
        println!(
            "      host {kind:?}: {:.3} ms/frame",
            t.elapsed().as_secs_f64() * 1e3 / 30.0
        );
    }

    std::fs::create_dir_all("runs")?;
    std::fs::write("runs/privacy_pipeline.log", log)?;
    println!("\nloss curves -> runs/privacy_pipeline.log");
    let stats = ctx.rt.stats();
    println!(
        "PJRT: {} executions, {:.1}s exec, {:.1}s compile, {:.1}s marshal",
        stats.executions, stats.exec_secs, stats.compile_secs, stats.marshal_secs
    );
    Ok(())
}
