"""Model zoo: architecture builders producing a JSON-serializable op list.

The op list is the single source of truth for model semantics. It is
interpreted twice:
  * here in Python (model.py) to build the L2 JAX graphs that get lowered
    to HLO artifacts, and
  * in Rust (``rust/src/mobile/``) by the mobile execution engine, which
    runs the same ops directly on host buffers.
The op list is embedded verbatim in ``artifacts/manifest.json``.

Op vocabulary (all shapes NCHW):
  {"op":"conv", "w":i, "b":i, "stride":s, "act":"relu"|"none",
   "prunable":bool, "A":out_ch, "C":in_ch, "kh":k, "kw":k,
   "in_hw":h, "out_hw":h'}                      3x3 (or 1x1) convolution
  {"op":"pool"}                                 2x2 max pool, stride 2
  {"op":"save", "tag":t}                        stash current tensor
  {"op":"proj", "tag":t, "w":i, "b":i, ...}     1x1 conv applied to stash
  {"op":"add", "tag":t}                         residual add from stash
  {"op":"relu"}                                 standalone activation
  {"op":"gap"}                                  global average pool -> (B,C)
  {"op":"fc", "w":i, "b":i, "A":cls, "C":ch}    classifier GEMM

The models are scaled-down analogues of the paper's VGG-16 / ResNet-18 /
ResNet-50 (DESIGN.md §2): same layer types and pruning-relevant structure,
sized for a CPU-only reproduction.
"""


class ArchBuilder:
    def __init__(self, in_ch, in_hw):
        self.ops = []
        self.params = []
        self.ch = in_ch
        self.hw = in_hw
        self._tag = 0

    def _add_param(self, name, shape):
        self.params.append({"name": name, "shape": list(shape)})
        return len(self.params) - 1

    def conv(self, out_ch, stride=1, act="relu", k=3, prunable=None):
        n = sum(1 for o in self.ops if o["op"] in ("conv", "proj"))
        wi = self._add_param(f"conv{n}_w", (out_ch, self.ch, k, k))
        bi = self._add_param(f"conv{n}_b", (out_ch,))
        out_hw = self.hw // stride
        self.ops.append(
            {
                "op": "conv",
                "w": wi,
                "b": bi,
                "stride": stride,
                "act": act,
                # pattern pruning needs 3x3 kernels (paper §IV-D.4)
                "prunable": (k == 3) if prunable is None else prunable,
                "A": out_ch,
                "C": self.ch,
                "kh": k,
                "kw": k,
                "in_hw": self.hw,
                "out_hw": out_hw,
            }
        )
        self.ch, self.hw = out_ch, out_hw
        return self

    def pool(self):
        self.ops.append({"op": "pool"})
        self.hw //= 2
        return self

    def res_block(self, out_ch, stride=1):
        """Two 3x3 convs + identity/projection skip (ResNet basic block)."""
        tag = f"r{self._tag}"
        self._tag += 1
        in_ch, in_hw = self.ch, self.hw
        self.ops.append({"op": "save", "tag": tag})
        self.conv(out_ch, stride=stride, act="relu")
        self.conv(out_ch, stride=1, act="none")
        if stride != 1 or in_ch != out_ch:
            n = sum(1 for o in self.ops if o["op"] in ("conv", "proj"))
            wi = self._add_param(f"conv{n}_w", (out_ch, in_ch, 1, 1))
            bi = self._add_param(f"conv{n}_b", (out_ch,))
            self.ops.append(
                {
                    "op": "proj",
                    "tag": tag,
                    "w": wi,
                    "b": bi,
                    "stride": stride,
                    "act": "none",
                    "prunable": False,
                    "A": out_ch,
                    "C": in_ch,
                    "kh": 1,
                    "kw": 1,
                    "in_hw": in_hw,
                    "out_hw": in_hw // stride,
                }
            )
        self.ops.append({"op": "add", "tag": tag})
        self.ops.append({"op": "relu"})
        return self

    def head(self, classes):
        wi = self._add_param("fc_w", (classes, self.ch))
        bi = self._add_param("fc_b", (self.ch,))  # placeholder, fixed below
        self.params[bi]["shape"] = [classes]
        self.ops.append({"op": "gap"})
        self.ops.append(
            {"op": "fc", "w": wi, "b": bi, "A": classes, "C": self.ch}
        )
        return self


def vgg_mini(classes, in_hw=16):
    """VGG-16 analogue: 8 stacked 3x3 convs with interleaved max pools."""
    b = ArchBuilder(3, in_hw)
    b.conv(16).conv(16).pool()
    b.conv(32).conv(32).pool()
    b.conv(64).conv(64).pool()
    b.conv(128).conv(128)
    b.head(classes)
    return b


def resnet_mini(classes, in_hw=16):
    """ResNet-18 analogue: stem + 3 basic blocks (7 prunable 3x3 convs)."""
    b = ArchBuilder(3, in_hw)
    b.conv(16)
    b.res_block(16, stride=1)
    b.res_block(32, stride=2)
    b.res_block(64, stride=2)
    b.head(classes)
    return b


def resnet_deep(classes, in_hw=16):
    """ResNet-50 analogue: stem + 4 basic blocks (9 prunable 3x3 convs)."""
    b = ArchBuilder(3, in_hw)
    b.conv(16)
    b.res_block(16, stride=1)
    b.res_block(32, stride=2)
    b.res_block(64, stride=2)
    b.res_block(64, stride=1)
    b.head(classes)
    return b


def lenet_micro(classes, in_hw=16):
    """Tiny 2-conv net used by fast integration tests and the quickstart."""
    b = ArchBuilder(3, in_hw)
    b.conv(8).pool()
    b.conv(16).pool()
    b.head(classes)
    return b


ARCHS = {
    "vgg_mini": vgg_mini,
    "resnet_mini": resnet_mini,
    "resnet_deep": resnet_deep,
    "lenet_micro": lenet_micro,
}


def build(arch, classes, in_hw):
    b = ARCHS[arch](classes, in_hw)
    return {
        "arch": arch,
        "classes": classes,
        "in_hw": in_hw,
        "ops": b.ops,
        "params": b.params,
        "prunable": [
            i for i, o in enumerate(b.ops)
            if o["op"] == "conv" and o["prunable"]
        ],
    }
