"""AOT lowering: every L2 graph -> HLO *text* artifact + manifest.json.

HLO text (NOT ``lowered.compiler_ir().serialize()``): the Rust side links
xla_extension 0.5.1 whose proto importer rejects the 64-bit instruction ids
emitted by jax >= 0.5; the HLO text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Run as ``python -m compile.aot --out-dir ../artifacts`` (what `make
artifacts` does). Python is build-time only: after this completes, the Rust
binary is self-contained.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import arch, model

F32 = "float32"


# model-id -> (arch, classes, in_hw). SynthVision-10/20 stand in for
# CIFAR-10/100 and ImageNet (DESIGN.md §2); res32 is the 32x32 "ImageNet"
# variant used by exp table3.
CONFIGS = {
    "lenet_sv10": ("lenet_micro", 10, 16),
    "vgg_sv10": ("vgg_mini", 10, 16),
    "res_sv10": ("resnet_mini", 10, 16),
    "vgg_sv20": ("vgg_mini", 20, 16),
    "res_sv20": ("resnet_mini", 20, 16),
    "resdeep_sv20": ("resnet_deep", 20, 16),
    "res32_sv20": ("resnet_mini", 20, 32),
}

BATCHES = {"train": 64, "admm": 32, "eval": 100}


def sds(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jax.numpy.float32)


def to_hlo_text(fn, in_specs):
    lowered = jax.jit(fn).lower(*in_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def graph_catalog(spec):
    """Name -> (fn, [(input-name, shape)]). Output shapes are derived with
    jax.eval_shape at lowering time."""
    np_ = model.n_params(spec)
    pconvs = model.prunable_convs(spec)
    hw, cls = spec["in_hw"], spec["classes"]
    p_ins = [(p["name"], p["shape"]) for p in spec["params"]]

    def x_in(b):
        return ("x", [b, 3, hw, hw])

    def y_in(b):
        return ("y1h", [b, cls])

    cat = {}
    cat["fwd_eval"] = (
        model.make_fwd_eval(spec),
        p_ins + [x_in(BATCHES["eval"])],
    )
    cat["fwd_acts"] = (
        model.make_fwd_acts(spec),
        p_ins + [x_in(BATCHES["admm"])],
    )
    cat["train_step"] = (
        model.make_train_step(spec),
        p_ins + [x_in(BATCHES["train"]), y_in(BATCHES["train"]), ("lr", [])],
    )
    mask_ins = [
        (f"mask{j}", list(model.gemm_shape(op)))
        for j, (_, op) in enumerate(pconvs)
    ]
    cat["masked_train_step"] = (
        model.make_masked_train_step(spec),
        p_ins
        + mask_ins
        + [x_in(BATCHES["train"]), y_in(BATCHES["train"]), ("lr", [])],
    )
    b = BATCHES["admm"]
    for j, (oi, op) in enumerate(pconvs):
        a, q = model.gemm_shape(op)
        ins = [
            ("w", [op["A"], op["C"], op["kh"], op["kw"]]),
            ("b", [op["A"]]),
            ("act_in", [b, op["C"], op["in_hw"], op["in_hw"]]),
            ("target", [b, op["A"], op["out_hw"], op["out_hw"]]),
            ("z", [a, q]),
            ("u", [a, q]),
            ("rho", []),
            ("lr", []),
        ]
        cat[f"layer_primal_{j}"] = (model.make_layer_primal_step(spec, oi), ins)
    z_ins = [
        (f"z{j}", list(model.gemm_shape(op)))
        for j, (_, op) in enumerate(pconvs)
    ]
    u_ins = [
        (f"u{j}", list(model.gemm_shape(op)))
        for j, (_, op) in enumerate(pconvs)
    ]
    cat["whole_primal_step"] = (
        model.make_whole_primal_step(spec),
        p_ins
        + [x_in(b), ("tlogits", [b, cls])]
        + z_ins
        + u_ins
        + [("rho", []), ("lr", [])],
    )
    bt = BATCHES["train"]
    cat["admm_train_primal_step"] = (
        model.make_admm_train_primal_step(spec),
        p_ins
        + [x_in(bt), y_in(bt)]
        + z_ins
        + u_ins
        + [("rho", []), ("lr", [])],
    )
    return cat


def build_model(model_id, out_dir, only_graph=None, force=False):
    arch_name, classes, in_hw = CONFIGS[model_id]
    spec = arch.build(arch_name, classes, in_hw)
    cat = graph_catalog(spec)
    artifacts = {}
    for name, (fn, ins) in sorted(cat.items()):
        if only_graph and name != only_graph:
            continue
        in_specs = [sds(s) for _, s in ins]
        out_info = jax.eval_shape(fn, *in_specs)
        outs = [list(o.shape) for o in jax.tree_util.tree_leaves(out_info)]
        fname = f"{model_id}_{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        from . import kernels

        key = hashlib.sha256(
            json.dumps(
                [ins, outs, name, model_id, kernels.BLOCK_M,
                 kernels.BLOCK_N, kernels.BLOCK_K, kernels.use_pallas()]
            ).encode()
        ).hexdigest()[:16]
        keypath = path + ".key"
        if (
            not force
            and os.path.exists(path)
            and os.path.exists(keypath)
            and open(keypath).read() == key
        ):
            pass  # up to date
        else:
            text = to_hlo_text(fn, in_specs)
            with open(path, "w") as f:
                f.write(text)
            with open(keypath, "w") as f:
                f.write(key)
            print(f"  lowered {fname} ({len(text)} chars)", flush=True)
        artifacts[name] = {
            "file": fname,
            "inputs": [{"name": n, "shape": s} for n, s in ins],
            "outputs": outs,
        }
    return {
        "arch": arch_name,
        "classes": classes,
        "in_hw": in_hw,
        "ops": spec["ops"],
        "params": spec["params"],
        "prunable": spec["prunable"],
        "batches": BATCHES,
        "artifacts": artifacts,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(CONFIGS))
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"models": {}, "batches": BATCHES}
    if os.path.exists(manifest_path):
        try:
            manifest = json.load(open(manifest_path))
        except Exception:
            pass
    for model_id in args.models.split(","):
        model_id = model_id.strip()
        if not model_id:
            continue
        print(f"[aot] {model_id}", flush=True)
        manifest["models"][model_id] = build_model(
            model_id, args.out_dir, force=args.force
        )
    manifest["batches"] = BATCHES
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    n_art = sum(len(m["artifacts"]) for m in manifest["models"].values())
    print(f"[aot] manifest: {len(manifest['models'])} models, "
          f"{n_art} artifacts")


if __name__ == "__main__":
    main()
