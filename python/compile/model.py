"""L2: JAX compute graphs for the framework, all routed through the L1
Pallas GEMM kernels (kernels.*).

Every function here is built as a *flat positional* closure over a model
spec (arch.build(...)) so it lowers to an HLO module whose parameter order
is exactly the order recorded in artifacts/manifest.json — the Rust runtime
marshals Literals by that order.

Graphs produced per model (see aot.py):
  fwd_eval            (params..., x)                      -> logits
  fwd_acts            (params..., x)                      -> logits, conv
                      inputs and post-activation outputs of every prunable
                      conv layer (the F_{:n-1}(X) / F'_{:n}(X) tensors of
                      paper Eqn. (3))
  train_step          (params..., x, y1h, lr)             -> params', loss
  masked_train_step   (params..., masks..., x, y1h, lr)   -> params', loss
                      — the client retraining step: the mask function zeroes
                      gradients of pruned weights (paper observation (iii))
  layer_primal_step_n (w, b, act_in, target, z, u, rho, lr) -> w', b', loss
                      — one SGD step on the ADMM primal of Eqn. (8)/(9)
  whole_primal_step   (params..., x, tlogits, z..., u..., rho, lr)
                      -> params', loss — the problem-(2) primal step

ρ and lr are *runtime inputs* (f32 scalars), so one compiled executable
serves the paper's entire ρ-schedule with no recompiles on the Rust side.
"""

import jax
import jax.numpy as jnp
from jax import lax

from . import kernels


# --------------------------------------------------------------------------
# Core ops
# --------------------------------------------------------------------------


def im2col(x, kh, kw, stride):
    """NCHW -> (C*kh*kw, B*Ho*Wo) patch matrix; ordering matches an OIHW
    weight reshape (verified by test_model.py against lax conv)."""
    patches = lax.conv_general_dilated_patches(
        x,
        (kh, kw),
        (stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    b, q, h, w = patches.shape
    return patches.transpose(1, 0, 2, 3).reshape(q, b * h * w), (b, h, w)


def conv_apply(x, w4, bias, stride, act, mask=None):
    """Convolution as im2col GEMM on the Pallas hot path."""
    a, c, kh, kw = w4.shape
    xcol, (b, h, w) = im2col(x, kh, kw, stride)
    wg = w4.reshape(a, c * kh * kw)
    if mask is None:
        y = kernels.matmul_bias_act(wg, xcol, bias, act=act)
    else:
        y = kernels.masked_matmul_bias_act(wg, mask, xcol, bias, act=act)
    return y.reshape(a, b, h, w).transpose(1, 0, 2, 3)


def max_pool2(x):
    return lax.reduce_window(
        x, -jnp.inf, lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def forward(spec, params, x, masks=None, collect=False):
    """Interpret the op list. ``masks`` maps op-index -> (A, C*kh*kw) mask
    for prunable convs. With ``collect``, also returns the input and
    post-activation output of every prunable conv (paper Eqn. (3) tensors).
    """
    saved = {}
    conv_in, conv_out = [], []
    t = x
    logits = None
    for oi, op in enumerate(spec["ops"]):
        kind = op["op"]
        if kind == "conv":
            mask = masks.get(oi) if masks else None
            if collect and op["prunable"]:
                conv_in.append(t)
            t = conv_apply(
                t, params[op["w"]], params[op["b"]], op["stride"],
                op["act"], mask=mask,
            )
            if collect and op["prunable"]:
                conv_out.append(t)
        elif kind == "pool":
            t = max_pool2(t)
        elif kind == "save":
            saved[op["tag"]] = t
        elif kind == "proj":
            saved[op["tag"]] = conv_apply(
                saved[op["tag"]], params[op["w"]], params[op["b"]],
                op["stride"], op["act"],
            )
        elif kind == "add":
            t = t + saved[op["tag"]]
        elif kind == "relu":
            t = jnp.maximum(t, 0.0)
        elif kind == "gap":
            t = t.mean(axis=(2, 3))  # (B, C)
        elif kind == "fc":
            logits = kernels.matmul_bias_act(
                params[op["w"]], t.T, params[op["b"]], act="none"
            ).T
        else:
            raise ValueError(f"unknown op {kind!r}")
    assert logits is not None
    if collect:
        return logits, conv_in, conv_out
    return logits


def ce_loss(spec, params, x, y1h):
    logits = forward(spec, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y1h * logp, axis=-1))


# --------------------------------------------------------------------------
# Graph builders (flat positional signatures for AOT lowering)
# --------------------------------------------------------------------------


def n_params(spec):
    return len(spec["params"])


def prunable_convs(spec):
    """[(op_index, op_dict)] of prunable conv layers, in network order."""
    return [(i, spec["ops"][i]) for i in spec["prunable"]]


def gemm_shape(op):
    return (op["A"], op["C"] * op["kh"] * op["kw"])


def make_fwd_eval(spec):
    np_ = n_params(spec)

    def f(*args):
        params, x = list(args[:np_]), args[np_]
        return (forward(spec, params, x),)

    return f


def make_fwd_acts(spec):
    np_ = n_params(spec)

    def f(*args):
        params, x = list(args[:np_]), args[np_]
        logits, cin, cout = forward(spec, params, x, collect=True)
        return tuple([logits] + cin + cout)

    return f


def make_train_step(spec):
    np_ = n_params(spec)

    def f(*args):
        params = list(args[:np_])
        x, y1h, lr = args[np_], args[np_ + 1], args[np_ + 2]
        loss, grads = jax.value_and_grad(
            lambda ps: ce_loss(spec, ps, x, y1h)
        )(params)
        new = [p - lr * g for p, g in zip(params, grads)]
        return tuple(new + [loss])

    return f


def make_masked_train_step(spec):
    np_ = n_params(spec)
    pconvs = prunable_convs(spec)
    nm = len(pconvs)

    def f(*args):
        params = list(args[:np_])
        masks_flat = args[np_:np_ + nm]
        x, y1h, lr = args[np_ + nm], args[np_ + nm + 1], args[np_ + nm + 2]
        masks = {oi: m for (oi, _), m in zip(pconvs, masks_flat)}

        def loss_fn(ps):
            logits = forward(spec, ps, x, masks=masks)
            logp = jax.nn.log_softmax(logits, axis=-1)
            return -jnp.mean(jnp.sum(y1h * logp, axis=-1))

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new = [p - lr * g for p, g in zip(params, grads)]
        # keep stored weights clean: zero the pruned coordinates on export
        for (oi, op), m in zip(pconvs, masks_flat):
            wi = op["w"]
            new[wi] = new[wi] * m.reshape(new[wi].shape)
        return tuple(new + [loss])

    return f


def make_layer_primal_step(spec, oi):
    """One SGD step on Eqn. (8)+(9): distillation term (per-sample squared
    Frobenius norm) + ρ/2‖W − Z + U‖²_F, differentiated w.r.t. (W, b)."""
    op = spec["ops"][oi]

    def f(w4, bias, act_in, target, z, u, rho, lr):
        a, c, kh, kw = w4.shape

        def loss_fn(wb):
            w4_, b_ = wb
            out = conv_apply(act_in, w4_, b_, op["stride"], op["act"])
            bsz = act_in.shape[0]
            dist = jnp.sum((out - target) ** 2) / bsz
            wg = w4_.reshape(a, c * kh * kw)
            pen = 0.5 * rho * jnp.sum((wg - z + u) ** 2)
            return dist + pen

        loss, (dw, db) = jax.value_and_grad(loss_fn)((w4, bias))
        return w4 - lr * dw, bias - lr * db, loss

    return f


def make_admm_train_primal_step(spec):
    """Primal step of the *traditional* ADMM pruning baseline (ADMM†,
    Zhang et al. [9]): cross-entropy on the client's real training data +
    the ADMM penalty — this is the no-privacy comparator in Tables I-III."""
    np_ = n_params(spec)
    pconvs = prunable_convs(spec)
    nz = len(pconvs)

    def f(*args):
        params = list(args[:np_])
        x, y1h = args[np_], args[np_ + 1]
        zs = args[np_ + 2:np_ + 2 + nz]
        us = args[np_ + 2 + nz:np_ + 2 + 2 * nz]
        rho, lr = args[np_ + 2 + 2 * nz], args[np_ + 3 + 2 * nz]

        def loss_fn(ps):
            logits = forward(spec, ps, x)
            logp = jax.nn.log_softmax(logits, axis=-1)
            ce = -jnp.mean(jnp.sum(y1h * logp, axis=-1))
            pen = 0.0
            for (oi_, op), z, u in zip(pconvs, zs, us):
                wg = ps[op["w"]].reshape(z.shape)
                pen = pen + 0.5 * rho * jnp.sum((wg - z + u) ** 2)
            return ce + pen

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new = [p - lr * g for p, g in zip(params, grads)]
        return tuple(new + [loss])

    return f


def make_whole_primal_step(spec):
    """One SGD step on Eqn. (2) + the ADMM penalty over all prunable convs
    (problem-(2) formulation, Table IV)."""
    np_ = n_params(spec)
    pconvs = prunable_convs(spec)
    nz = len(pconvs)

    def f(*args):
        params = list(args[:np_])
        x, tlogits = args[np_], args[np_ + 1]
        zs = args[np_ + 2:np_ + 2 + nz]
        us = args[np_ + 2 + nz:np_ + 2 + 2 * nz]
        rho, lr = args[np_ + 2 + 2 * nz], args[np_ + 3 + 2 * nz]

        def loss_fn(ps):
            logits = forward(spec, ps, x)
            bsz = x.shape[0]
            dist = jnp.sum((logits - tlogits) ** 2) / bsz
            pen = 0.0
            for (oi_, op), z, u in zip(pconvs, zs, us):
                wg = ps[op["w"]].reshape(z.shape)
                pen = pen + 0.5 * rho * jnp.sum((wg - z + u) ** 2)
            return dist + pen

        loss, grads = jax.value_and_grad(loss_fn)(params)
        new = [p - lr * g for p, g in zip(params, grads)]
        return tuple(new + [loss])

    return f
