# Pure-jnp correctness oracle for the Pallas kernels.
#
# Every public op in matmul.py has an entry here with identical semantics
# expressed with plain jnp contractions; pytest (test_kernel.py) asserts
# allclose between the two over hypothesis-driven shape/dtype sweeps.
import jax.numpy as jnp


def _act(name, x):
    if name == "relu":
        return jnp.maximum(x, 0.0)
    if name == "none":
        return x
    raise ValueError(f"unknown activation {name!r}")


def matmul(a, b):
    return a.astype(jnp.float32) @ b.astype(jnp.float32)


def matmul_bias_act(a, b, bias, act="relu"):
    y = matmul(a, b) + bias.astype(jnp.float32).reshape(-1, 1)
    return _act(act, y)


def masked_matmul_bias_act(w, mask, x, bias, act="relu"):
    wm = w.astype(jnp.float32) * mask.astype(jnp.float32)
    y = matmul(wm, x) + bias.astype(jnp.float32).reshape(-1, 1)
    return _act(act, y)
